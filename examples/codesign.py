"""Hardware/parallelism co-design (paper §VI as a closed loop).

The planner and the hardware search run as *one* loop: the full
(hardware variant x parallel plan) product is flattened into a single
shared-pool sweep, ranked jointly, and the winner comes back as a
co-design recommendation — a full serializable HardwareSpec plus the
best plan on it (the "inspire the design of future accelerators" loop).

    PYTHONPATH=src python examples/codesign.py
    PYTHONPATH=src python examples/codesign.py --tiny   # CI smoke
"""

import argparse

from repro.api import (
    HardwareSearchSpace,
    HardwareSpec,
    PlannerCfg,
    plan_codesign,
    resolve_hardware,
)
from repro.configs import get_config


def main(tiny: bool = False, workers: int = 0):
    if tiny:
        arch = get_config("yi-6b")
        base = resolve_hardware("tpu_v5e_2x2")
        cfg = PlannerCfg(
            global_batch=8, seq_len=128, max_plans=3,
            microbatch_sizes=(1,),
            hardware_search=HardwareSearchSpace(tile_flops=(100e12, 197e12)),
            workers=workers,
        )
    else:
        arch = get_config("yi-6b")
        base = resolve_hardware("wafer_scale")
        cfg = PlannerCfg(
            global_batch=64, seq_len=2048, max_plans=8,
            microbatch_sizes=(1, 2),
            hardware_search=HardwareSearchSpace(
                tile_flops=(8e12, 16e12, 32e12),
                inter_bw=(128e9, 256e9),
                mesh_shapes=((5, 4), (4, 4)),   # inter-tile grid variants
            ),
            workers=workers,
        )

    res = plan_codesign(arch, base, cfg)
    report = res.report
    print(f"co-design: {report.arch} over {report.num_hardware} hardware "
          f"variants x plans ({report.num_candidates} joint candidates, "
          f"{report.num_failed} failed; {report.executor})")
    print(report.table(top=8))
    print(f"\nrecommendation: {res.summary()}")

    # the recommendation is data: the winning machine dumps to
    # --hardware-json compatible JSON and reloads losslessly
    text = res.hardware.to_json(indent=2)
    assert HardwareSpec.from_json(text).to_dict() == res.hardware.to_dict()
    print(f"winning hardware spec ({len(text)} bytes of JSON):")
    print(text)


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--tiny", action="store_true",
                    help="seconds-scale config for CI smoke runs")
    ap.add_argument("--workers", type=int, default=0,
                    help="0 = serial; N = shared process pool of N")
    main(**vars(ap.parse_args()))
