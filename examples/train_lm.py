"""End-to-end driver: train a reduced assigned architecture for a few
hundred steps on CPU with the full substrate (prefetching data pipeline,
Adam + cosine schedule, checkpoint/restart, straggler monitor).

    PYTHONPATH=src python examples/train_lm.py --arch yi-6b --steps 200

Any of the 10 assigned archs works: --arch mamba2-2.7b, hymba-1.5b, ...
"""

import argparse

from repro.launch.train import main as train_main


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi-6b")
    ap.add_argument("--steps", type=int, default=200)
    args = ap.parse_args()
    train_main(["--arch", args.arch, "--scale", "small", "--steps", str(args.steps),
                "--global-batch", "16", "--seq-len", "256",
                "--microbatches", "2", "--ckpt-dir", f"/tmp/ckpt_{args.arch}"])
