"""Hardware x parallelism co-search (paper §VI / Table VI exploration).

Sweeps tile compute, inter-tile NoC bandwidth, and the inter-tile grid
shape of the wafer-scale config *jointly* with the parallelism plan, and
prints the ranked hardware x plan points plus the JSON round-trip of the
winning machine — the whole loop the declarative hardware API opens.

    PYTHONPATH=src python examples/hardware_search.py
    PYTHONPATH=src python examples/hardware_search.py --tiny   # CI smoke
"""

import argparse

from repro.api import (
    Experiment,
    HardwareSearchSpace,
    HardwareSpec,
    SearchSpace,
    resolve_hardware,
)


def main(tiny: bool = False):
    if tiny:
        base = resolve_hardware("tpu_v5e_2x2")
        hw_search = HardwareSearchSpace(tile_flops=(100e12, 197e12))
        search = SearchSpace(max_plans=3, microbatch_sizes=(1,))
        batch, seq = 8, 128
    else:
        base = resolve_hardware("wafer_scale")
        hw_search = HardwareSearchSpace(
            tile_flops=(8e12, 16e12, 32e12),
            inter_bw=(128e9, 256e9),
            mesh_shapes=((5, 4), (4, 4)),       # inter-tile grid variants
        )
        search = SearchSpace(max_plans=8, microbatch_sizes=(1, 2))
        batch, seq = 64, 2048

    exp = Experiment(arch="yi-6b", hardware=base, search=search,
                     hardware_search=hw_search, global_batch=batch,
                     seq_len=seq)
    report = exp.sweep()
    print(f"hardware x parallelism search: {report.arch} on {report.hardware}")
    print(f"  {report.num_hardware} hardware variants x "
          f"{report.num_candidates // max(1, report.num_hardware)} plans each, "
          f"{report.num_failed} failed")
    print(report.table(top=10))

    best = report.best
    print(f"\nwinning machine: {best.hardware} "
          f"({best.throughput:.2f} samples/s with pp={best.plan.pp} "
          f"dp={best.plan.dp} tp={best.plan.tp})")

    # the winner is data: dump it, reload it, and it simulates identically
    winner = next(s for s in hw_search.enumerate_specs(base)
                  if s.name == best.hardware)
    text = winner.to_json(indent=2)
    assert HardwareSpec.from_json(text).to_dict() == winner.to_dict()
    print(f"winner serializes to {len(text)} bytes of JSON "
          "(python -m repro hardware / --hardware-json compatible)")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--tiny", action="store_true",
                    help="seconds-scale config for CI smoke runs")
    main(**vars(ap.parse_args()))
