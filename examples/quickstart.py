"""Quickstart: simulate LLM training on a wafer-scale tiled accelerator
with PALM and let the planner pick the parallelism — all through the
typed Experiment API.

    PYTHONPATH=src python examples/quickstart.py
    PYTHONPATH=src python examples/quickstart.py --tiny   # CI smoke config
"""

import argparse

from repro.api import Experiment, Layout, ParallelPlan, Schedule, SearchSpace
from repro.core import transformer_lm_graph


def main(tiny: bool = False):
    # --- 1. one simulation ---
    if tiny:
        # smoke config: 4-layer toy transformer on a 4-chip pod
        hardware = "tpu_v5e_2x2"
        plan = ParallelPlan(pp=2, dp=2, tp=1, microbatch=1, global_batch=8,
                            schedule=Schedule.ONE_F_ONE_B, layout=Layout.S_SHAPE)
        builder = lambda p: transformer_lm_graph(
            "T-tiny", 4, 256, 4, seq_len=128,
            batch=p.microbatch * p.dp, vocab=1024, gated_mlp=False)
        name = "T-tiny on tpu_v5e_2x2"
    else:
        # T-18B, the paper's §V-B baseline plan, on the Table VI wafer
        hardware = "wafer_scale"   # 5x4 tiles of 4x4 cores
        plan = ParallelPlan(pp=20, dp=2, tp=8, microbatch=1, global_batch=256,
                            schedule=Schedule.ONE_F_ONE_B, layout=Layout.S_SHAPE)
        builder = lambda p: transformer_lm_graph(
            "T-18B", 40, 6144, 48, seq_len=2048,
            batch=p.microbatch * p.dp, vocab=51200, gated_mlp=False)
        name = "T-18B on wafer-scale"

    rep = Experiment(hardware=hardware, plan=plan, graph_builder=builder).run()
    print(f"{name}: {rep.throughput:.2f} samples/s, "
          f"bubble {rep.bubble_ratio:.1%}, "
          f"peak stage memory {rep.peak_memory_bytes / 1e9:.2f} GB, "
          f"{rep.event_count} events")

    # --- 2. PALM as auto-parallelism planner for an assigned arch ---
    sweep = Experiment(
        arch="yi-6b",
        hardware="tpu_v5e_2x2" if tiny else "wafer_scale",
        search=SearchSpace(max_plans=4 if tiny else 12,
                           microbatch_sizes=(1, 2)),
        global_batch=16 if tiny else 128,
        seq_len=128 if tiny else 2048,
    ).sweep()
    print(f"\nplanner ranking for {sweep.arch} (top 5):")
    print(sweep.table(top=5))


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--tiny", action="store_true",
                    help="seconds-scale config for CI smoke runs")
    main(**vars(ap.parse_args()))
