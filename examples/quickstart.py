"""Quickstart: simulate LLM training on a wafer-scale tiled accelerator
with PALM and let the planner pick the parallelism.

    PYTHONPATH=src python examples/quickstart.py
"""

from repro.core import ParallelPlan, simulate, transformer_lm_graph, wafer_scale
from repro.core.planner import PlannerCfg, plan_parallelism
from repro.configs import get_config


def main():
    hw = wafer_scale()   # paper Table VI: 5x4 tiles of 4x4 cores

    # --- 1. one simulation: T-18B, the paper's §V-B baseline plan ---
    plan = ParallelPlan(pp=20, dp=2, tp=8, microbatch=1, global_batch=256,
                        schedule="1f1b", layout="s_shape")
    graph = transformer_lm_graph("T-18B", 40, 6144, 48, seq_len=2048,
                                 batch=plan.microbatch * plan.dp, vocab=51200,
                                 gated_mlp=False)
    res = simulate(graph, hw, plan)
    print(f"T-18B on wafer-scale: {res.throughput:.2f} samples/s, "
          f"bubble {res.bubble_ratio:.1%}, "
          f"peak stage memory {max(m.total for m in res.stage_memory)/1e9:.2f} GB, "
          f"{res.event_count} events")

    # --- 2. PALM as auto-parallelism planner for an assigned arch ---
    arch = get_config("yi-6b")
    results = plan_parallelism(arch, hw, PlannerCfg(
        global_batch=128, seq_len=2048, max_plans=12, microbatch_sizes=(1, 2)))
    print(f"\nplanner ranking for {arch.name} (top 5):")
    for r in results[:5]:
        p = r.plan
        print(f"  pp={p.pp:<3d} dp={p.dp:<3d} tp={p.tp:<3d} mb={p.microbatch} "
              f"{p.layout:8s} -> {r.throughput:8.2f} samples/s")


if __name__ == "__main__":
    main()
