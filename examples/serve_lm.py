"""Batched serving example: prefill a batch of prompts then decode with
the KV/SSM cache; reports tokens/s (CPU-scale model).

    PYTHONPATH=src python examples/serve_lm.py --arch yi-6b
    PYTHONPATH=src python examples/serve_lm.py --arch mamba2-2.7b   # SSM cache
"""

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.launch.train import scale_arch
from repro.models import RunCfg, decode_step, init_cache, init_params
from repro.serving import greedy_generate


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi-6b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new-tokens", type=int, default=48)
    args = ap.parse_args()

    arch = scale_arch(get_config(args.arch), "small")
    if arch.embeds_input:
        raise SystemExit(f"{arch.name} takes precomputed embeddings; "
                         "use an LM arch for this example")
    cfg = RunCfg(q_chunk=0, remat=False)
    params = init_params(arch, jax.random.PRNGKey(0), cfg)
    prompts = jax.random.randint(jax.random.PRNGKey(1),
                                 (args.batch, args.prompt_len), 0, arch.vocab)

    t0 = time.time()
    out = greedy_generate(arch, params, prompts, args.new_tokens, cfg)
    dt = time.time() - t0
    total_new = args.batch * args.new_tokens
    print(f"{arch.name}: generated {out.shape} in {dt:.2f}s "
          f"({total_new / dt:.1f} tok/s on CPU, batch={args.batch})")
    print("first sequence:", out[0][:16].tolist())


if __name__ == "__main__":
    main()
