"""Batched serving example: prefill a batch of prompts then decode with
the KV/SSM cache; reports tokens/s (CPU-scale model).

    PYTHONPATH=src python examples/serve_lm.py --arch yi-6b
    PYTHONPATH=src python examples/serve_lm.py --arch mamba2-2.7b   # SSM cache

With ``--plan-mesh`` the example closes the paper's §V-B loop for
serving: ``plan_serving`` sweeps decode-step splits through the PALM
simulator for ``--hardware``, the suggested ``(data, model)`` mesh is
built via ``launch.mesh.make_serving_mesh`` (on forced host devices for
the CPU dry-run), and generation runs under that sharding:

    PYTHONPATH=src python examples/serve_lm.py --plan-mesh --hardware tpu_v5e_2x2
"""

import argparse
import os
import time


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi-6b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new-tokens", type=int, default=48)
    ap.add_argument("--plan-mesh", action="store_true",
                    help="pick the (data, model) mesh with plan_serving and "
                         "shard the decode loop over it")
    ap.add_argument("--hardware", default="tpu_v5e_2x2",
                    help="hardware preset plan_serving simulates "
                         "(--plan-mesh only)")
    args = ap.parse_args()

    if args.plan_mesh:
        # the split covers every device of the simulated hardware; force
        # that many host devices before jax initializes its backend
        from repro.api import resolve_hardware   # jax-free import
        n = resolve_hardware(args.hardware).num_devices
        flag = f"--xla_force_host_platform_device_count={n}"
        os.environ["XLA_FLAGS"] = \
            (os.environ.get("XLA_FLAGS", "") + " " + flag).strip()

    import jax

    from repro.configs import get_config
    from repro.launch.mesh import make_serving_mesh
    from repro.launch.train import scale_arch
    from repro.models import RunCfg, init_params
    from repro.serving import greedy_generate, plan_serving

    arch = scale_arch(get_config(args.arch), "small")
    if arch.embeds_input:
        raise SystemExit(f"{arch.name} takes precomputed embeddings; "
                         "use an LM arch for this example")
    cfg = RunCfg(q_chunk=0, remat=False)

    mesh = None
    if args.plan_mesh:
        mesh_axes, report = plan_serving(
            arch, hardware=args.hardware, batch=args.batch,
            context_len=args.prompt_len + args.new_tokens)
        best = report.best
        print(f"plan_serving on {args.hardware}: mesh {mesh_axes} "
              f"({best.throughput:.1f} simulated decode steps/s, "
              f"{report.num_candidates} splits ranked)")
        mesh = make_serving_mesh(mesh_axes)

    params = init_params(arch, jax.random.PRNGKey(0), cfg)
    prompts = jax.random.randint(jax.random.PRNGKey(1),
                                 (args.batch, args.prompt_len), 0, arch.vocab)

    t0 = time.time()
    out = greedy_generate(arch, params, prompts, args.new_tokens, cfg, mesh=mesh)
    dt = time.time() - t0
    total_new = args.batch * args.new_tokens
    where = f"{len(jax.devices())} devices" if mesh is not None else "CPU"
    print(f"{arch.name}: generated {out.shape} in {dt:.2f}s "
          f"({total_new / dt:.1f} tok/s on {where}, batch={args.batch})")
    print("first sequence:", out[0][:16].tolist())


if __name__ == "__main__":
    main()
