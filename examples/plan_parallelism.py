"""Reproduce the paper's §V-B parallelism exploration on an assigned
architecture: sweep (pp, dp, tp, layout, comm placement) with the typed
Experiment API and print the ranked table (Fig. 8/10 style).

    PYTHONPATH=src python examples/plan_parallelism.py --arch dbrx-132b
    PYTHONPATH=src python examples/plan_parallelism.py --arch yi-6b --workers 8
"""

import argparse

from repro.api import Experiment, Layout, SearchSpace


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="dbrx-132b")
    ap.add_argument("--seq-len", type=int, default=2048)
    ap.add_argument("--workers", type=int, default=0,
                    help="0 = serial; N = process-pool sweep")
    ap.add_argument("--json", default=None, help="write SweepReport JSON here")
    args = ap.parse_args()

    # the paper's exploration grid: pp in {10, 20}, 16-way (dp x tp) splits,
    # both layouts, both TP comm-group placements (comm1/comm2). Each dp
    # group gets global_batch = 64 * dp so every plan runs the same 64
    # microbatches per replica (constant bubble fraction across dp) —
    # one Experiment per dp, merged into a single ranking.
    report = None
    for tp in (1, 2, 4, 8):
        dp = 16 // tp
        exp = Experiment(
            arch=args.arch,
            hardware="wafer_scale",
            search=SearchSpace(degrees=[(pp, dp, tp) for pp in (10, 20)],
                               layouts=(Layout.S_SHAPE, Layout.LINE),
                               tp_contiguous=(True, False),
                               microbatch_sizes=(1,),
                               max_plans=16),
            seq_len=args.seq_len,
            global_batch=64 * dp,
        )
        part = exp.sweep(workers=args.workers)
        if report is None:
            report = part
        else:
            report.runs.extend(part.runs)
            report.num_candidates += part.num_candidates
            report.num_pruned_memory += part.num_pruned_memory
            report.num_failed += part.num_failed
    report.runs.sort(key=lambda r: -r.throughput)

    print(f"== {report.arch} on {report.hardware} "
          f"({report.executor}; {report.num_candidates} candidates, "
          f"{report.num_failed} infeasible) ==")
    print(report.table(top=12))
    best = report.best
    p = best.plan
    print(f"\nbest plan: pp={p.pp} dp={p.dp} tp={p.tp} {p.layout} "
          f"{'comm1' if p.tp_contiguous else 'comm2'} "
          f"-> {best.throughput:.3f} samples/s")
    if args.json:
        with open(args.json, "w") as f:
            f.write(report.to_json(indent=2) + "\n")
        print(f"[report written to {args.json}]")


if __name__ == "__main__":
    main()
