"""Reproduce the paper's §V-B parallelism exploration on an assigned
architecture: sweep (pp, dp, tp, layout) with PALM and print the ranked
table plus the mapping/comm-group deltas (Fig. 8/10 style).

    PYTHONPATH=src python examples/plan_parallelism.py --arch dbrx-132b
"""

import argparse

from repro.configs import get_config
from repro.core import ParallelPlan, simulate, wafer_scale
from repro.core.workload import arch_to_graph


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="dbrx-132b")
    ap.add_argument("--seq-len", type=int, default=2048)
    args = ap.parse_args()

    arch = get_config(args.arch)
    hw = wafer_scale()
    print(f"== {arch.name} on {hw.name} ({hw.num_devices} cores) ==")
    print(f"{'pp':>3s} {'dp':>3s} {'tp':>3s} {'layout':>8s} {'comm':>5s} "
          f"{'samples/s':>10s} {'bubble':>7s} {'mem/tile GB':>11s}")
    rows = []
    for pp in (10, 20):
        for tp in (1, 2, 4, 8):
            dp = 16 // tp
            for layout in ("s_shape", "line"):
                for contig in (True, False):
                    plan = ParallelPlan(
                        pp=pp, dp=dp, tp=tp, microbatch=1,
                        global_batch=64 * dp, schedule="1f1b", layout=layout,
                        tp_contiguous=contig)
                    g = arch_to_graph(arch, args.seq_len, plan.microbatch * dp)
                    try:
                        res = simulate(g, hw, plan)
                    except ValueError:
                        continue
                    mem = max(m.total for m in res.stage_memory) / 1e9
                    rows.append((res.throughput, pp, dp, tp, layout, contig,
                                 res.bubble_ratio, mem))
    rows.sort(reverse=True)
    for (thpt, pp, dp, tp, layout, contig, bubble, mem) in rows[:12]:
        print(f"{pp:3d} {dp:3d} {tp:3d} {layout:>8s} "
              f"{'comm1' if contig else 'comm2':>5s} {thpt:10.3f} "
              f"{bubble:7.1%} {mem:11.2f}")
    best = rows[0]
    print(f"\nbest plan: pp={best[1]} dp={best[2]} tp={best[3]} {best[4]} "
          f"{'comm1' if best[5] else 'comm2'} -> {best[0]:.3f} samples/s")


if __name__ == "__main__":
    main()
