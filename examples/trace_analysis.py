"""Columnar trace analytics: simulate a training pipeline, inspect its
event timeline (per-stage utilization, bubble fraction, critical path,
NoC/DRAM occupancy), and export it for Chrome/Perfetto.

    PYTHONPATH=src python examples/trace_analysis.py
    PYTHONPATH=src python examples/trace_analysis.py --tiny   # CI smoke

The same schema comes out of every PALM entry point — training sweeps
(``Experiment.sweep(return_timelines=True)``), serving planning
(``plan_serving(collect_timeline=True)``), the CLI
(``python -m repro simulate --trace-out``), and the dry-run
(``python -m repro.launch.dryrun --palm-trace``) — so any two timelines
load side by side in one ui.perfetto.dev view.
"""

import argparse
import json
from pathlib import Path

from repro.api import Experiment, ParallelPlan, chrome_trace
from repro.core import KIND_DRAM, KIND_FD, KIND_NOC
from repro.core.trace import KIND_NAMES


def main(tiny: bool = False, out_dir: Path = Path("artifacts")):
    arch = "yi-6b"
    hardware = "tpu_v5e_2x2" if tiny else "grayskull"
    plan = (ParallelPlan(pp=2, dp=2, tp=1, microbatch=1, global_batch=8)
            if tiny else
            ParallelPlan(pp=4, dp=2, tp=2, microbatch=2, global_batch=64))
    rep = Experiment(arch=arch, hardware=hardware, plan=plan,
                     seq_len=128 if tiny else 1024,
                     global_batch=plan.global_batch,
                     collect_timeline=True).run()
    trace = rep.trace

    print(f"{arch} on {hardware}: {rep.throughput:.2f} samples/s, "
          f"{len(trace)} trace events over {trace.total_time * 1e3:.2f} ms")

    # --- per-stage utilization & bubble ---
    print("\nper-stage utilization (FD+BD+GU):")
    for s, u in trace.stage_utilization().items():
        print(f"  stage {s}: {'#' * int(40 * u):<40s} {u:6.1%}")
    print(f"bubble fraction: {trace.bubble_fraction():.1%}")

    # --- critical path: which events bound the iteration ---
    path = trace.critical_path()
    busy = sum(r.duration for r in path)
    print(f"\ncritical path: {len(path)} events, "
          f"{busy / trace.total_time:.0%} of the horizon is on-chain work")
    for r in path[:3] + path[-3:]:
        print(f"  stage {r.stage} {KIND_NAMES[r.kind]:>4s} mb{r.micro}: "
              f"{r.start * 1e6:9.1f} -> {r.end * 1e6:9.1f} us")

    # --- resource lanes ---
    for kind, label in ((KIND_NOC, "NoC links"), (KIND_DRAM, "DRAM channels")):
        occ = trace.resource_occupancy(kind)
        if occ:
            hottest = max(occ, key=occ.get)
            print(f"{label}: {len(occ)} busy, hottest id {hottest} "
                  f"at {occ[hottest]:.1%}")

    # --- slicing: the warmup phase only ---
    warmup = trace.slice_time(0.0, trace.total_time / 4)
    fd_share = len(warmup.filter(kinds=(KIND_FD,))) / max(1, len(warmup))
    print(f"first quarter of the run: {len(warmup)} events, "
          f"{fd_share:.0%} forward")

    # --- export: Perfetto JSON + columnar npz ---
    out_dir.mkdir(parents=True, exist_ok=True)
    perfetto = out_dir / "trace_analysis.json"
    perfetto.write_text(json.dumps(chrome_trace(trace, label=arch)))
    print(f"\nwrote {perfetto} (load in chrome://tracing or ui.perfetto.dev)")
    try:
        npz = out_dir / "trace_analysis.npz"
        trace.to_npz(npz)
        print(f"wrote {npz} ({npz.stat().st_size} B for "
              f"{trace.nbytes} B of columns)")
    except RuntimeError:
        print("numpy unavailable: skipped the .npz export")


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--tiny", action="store_true",
                    help="seconds-scale CI smoke configuration")
    ap.add_argument("--out", type=Path, default=Path("artifacts"))
    args = ap.parse_args()
    main(tiny=args.tiny, out_dir=args.out)
