"""Guided hardware/parallelism co-design (paper §VI on a budget).

Where ``examples/codesign.py`` exhaustively ranks every (hardware
variant x parallel plan) point, this drives the same loop through
``repro.search``: successive halving climbs the simulation-fidelity
ladder (analytical NoC + 2 microbatches -> macro NoC + 4 microbatches ->
full fidelity), spending the expensive full-fidelity simulations only on
candidates the cheap rungs rank near the top. The exhaustive loop runs
too, so the script prints the quality/cost trade side by side.

    PYTHONPATH=src python examples/guided_codesign.py
    PYTHONPATH=src python examples/guided_codesign.py --tiny   # CI smoke
"""

import argparse
import dataclasses

from repro.api import (
    HardwareSearchSpace,
    PlannerCfg,
    plan_codesign,
    resolve_hardware,
)
from repro.configs import get_config


def main(tiny: bool = False, workers: int = 0, seed: int = 0):
    arch = get_config("yi-6b")
    if tiny:
        base = resolve_hardware("tpu_v5e_2x2")
        cfg = PlannerCfg(
            global_batch=8, seq_len=128, max_plans=4, microbatch_sizes=(1,),
            hardware_search=HardwareSearchSpace(
                tile_flops=(100e12, 197e12),
                dram_bandwidth=(400e9, 819e9)),
            workers=workers,
        )
    else:
        base = resolve_hardware("tpu_v5e_2x2")
        cfg = PlannerCfg(
            global_batch=16, seq_len=256, max_plans=8,
            microbatch_sizes=(1, 2),
            hardware_search=HardwareSearchSpace(
                tile_flops=(50e12, 100e12, 197e12),
                intra_bw=(25e9, 50e9),
                dram_bandwidth=(400e9, 819e9),
                max_specs=64),
            workers=workers,
        )

    exhaustive = plan_codesign(arch, base, cfg)       # today's full loop
    guided_cfg = dataclasses.replace(cfg, search_strategy="sh",
                                     search_seed=seed)
    guided = plan_codesign(arch, base, guided_cfg)
    search = guided.report.search

    print(f"space: {exhaustive.report.num_candidates} joint candidates over "
          f"{exhaustive.report.num_hardware} hardware variants")
    print(f"exhaustive: {exhaustive.summary()}")
    print(f"guided sh:  {guided.summary()}")
    print(f"  {search.summary()}")
    print(f"  rungs: " + " -> ".join(
        f"{r.fidelity}[{r.evaluated}->{r.promoted}]" for r in search.rungs))
    quality = guided.throughput / exhaustive.throughput
    savings = exhaustive.report.num_candidates / max(1, search.full_fidelity_sims)
    print(f"  quality {quality:.1%} of the exhaustive optimum at "
          f"{savings:.1f}x fewer full-fidelity simulations")
    curve = ", ".join(f"({int(n)}: {t:.2f})" for n, t in search.best_curve)
    print(f"  best-so-far curve (full sims: samples/s): {curve}")

    assert quality >= 0.98, "guided search fell outside the 2% quality gate"
    # the default budget is a fifth of the space (rounded up); the strict
    # <= 1/5 acceptance gate runs in benchmarks/bench_search.py
    assert search.full_fidelity_sims <= search.budget


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--tiny", action="store_true",
                    help="seconds-scale config for CI smoke runs")
    ap.add_argument("--workers", type=int, default=0,
                    help="0 = serial; N = shared process pool of N")
    ap.add_argument("--seed", type=int, default=0,
                    help="search RNG seed (fixed seed = reproducible run)")
    main(**vars(ap.parse_args()))
