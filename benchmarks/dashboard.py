"""Perf-tracking dashboard: fold bench JSON artifacts into one trend table.

Every benchmark entry point (``bench_sweep_engine.py --json``,
``bench_search.py --json``, CI's uploaded ``bench-*`` artifacts, local
``BENCH_*.json`` dumps) writes the same document shape::

    {"suite": ..., "tiny": ..., "elapsed_s": ...,
     "rows": [{"name": ..., "us_per_call": ..., "derived": ...}, ...],
     "lines": [...]}

This tool collects any number of those files (newest column last, by
file mtime), pivots them into one (suite, metric) x run table, and emits
markdown — and optionally a self-contained HTML page — so perf trends
across PRs/CI runs are one glance instead of N JSON diffs. Rows whose
latest value regressed by more than ``--regression-pct`` against the
previous run are flagged.

    python benchmarks/dashboard.py artifacts/*.json --out dashboard.md
    python benchmarks/dashboard.py artifacts/*.json --html dashboard.html
"""

from __future__ import annotations

import argparse
import html
import json
import sys
from pathlib import Path
from typing import Dict, List, Optional, Tuple

# (suite, metric) -> {column label -> (us_per_call, derived)}
Table = Dict[Tuple[str, str], Dict[str, Tuple[float, str]]]


def load_artifacts(paths: List[Path]) -> Tuple[Table, List[str]]:
    """Parse artifact files into the pivot table; returns (table, column
    labels in mtime order). Files without a ``rows`` block are skipped
    with a warning (they are not bench artifacts)."""
    table: Table = {}
    labeled: List[Tuple[float, str, Path]] = []
    seen: Dict[str, int] = {}
    for path in paths:
        label = path.stem
        if label in seen:               # same stem from different dirs
            seen[label] += 1
            label = f"{label}#{seen[label]}"
        else:
            seen[label] = 1
        labeled.append((path.stat().st_mtime, label, path))
    # mtime order, label as the tie-break (restored CI caches can flatten
    # mtimes; history files embed the run number in the name)
    labeled.sort(key=lambda t: (t[0], t[1]))
    columns: List[str] = []
    for _, label, path in labeled:
        try:
            doc = json.loads(path.read_text())
        except (ValueError, OSError) as e:
            print(f"[dashboard] skipping {path}: {e}", file=sys.stderr)
            continue
        rows = doc.get("rows")
        if not isinstance(rows, list):
            print(f"[dashboard] skipping {path}: no bench rows",
                  file=sys.stderr)
            continue
        suite = str(doc.get("suite", path.stem))
        columns.append(label)
        for row in rows:
            try:
                us = float(row["us_per_call"])
            except (KeyError, TypeError, ValueError):
                continue
            key = (suite, str(row.get("name", "?")))
            table.setdefault(key, {})[label] = (us, str(row.get("derived", "")))
        if "elapsed_s" in doc:
            table.setdefault((suite, "suite_elapsed"), {})[label] = (
                float(doc["elapsed_s"]) * 1e6, "tiny" if doc.get("tiny") else "full")
    return table, columns


def _fmt_us(us: float) -> str:
    if us >= 1e6:
        return f"{us / 1e6:.2f}s"
    if us >= 1e3:
        return f"{us / 1e3:.1f}ms"
    return f"{us:.1f}us"


def _trend(vals: List[Optional[float]], regression_pct: float) -> str:
    """Latest-vs-previous movement tag for a metric row.

    The REGRESSED flag assumes higher-is-worse and only fires on rows in
    real latency magnitudes (>= 1 ms): benches also store status rows
    (0.0 = ok) and ratio/quality gates (~1.0, higher is *better*) in the
    same column, and those must not be direction-flagged."""
    present = [v for v in vals if v is not None]
    if len(present) < 2 or present[-2] <= 0:
        return ""
    change = (present[-1] - present[-2]) / present[-2] * 100.0
    tag = f"{change:+.1f}%"
    if change > regression_pct and present[-1] >= 1e3:
        tag += " REGRESSED"
    return tag


def render_markdown(table: Table, columns: List[str],
                    regression_pct: float = 25.0) -> str:
    lines = ["# PALM bench trends", "",
             f"{len(columns)} runs, {len(table)} metrics "
             "(values are per-call latency; `derived` of the newest run "
             "in parentheses).", ""]
    header = ["suite", "metric", *columns, "trend"]
    lines.append("| " + " | ".join(header) + " |")
    lines.append("|" + "|".join("---" for _ in header) + "|")
    for (suite, metric) in sorted(table):
        cells = table[(suite, metric)]
        vals = [cells.get(c, (None, ""))[0] for c in columns]
        rendered = [(_fmt_us(v) if v is not None else "-") for v in vals]
        newest = next((cells[c] for c in reversed(columns) if c in cells),
                      None)
        if newest is not None and newest[1]:
            for i in range(len(rendered) - 1, -1, -1):
                if vals[i] is not None:
                    rendered[i] += f" ({newest[1]})"
                    break
        trend = _trend(vals, regression_pct)
        # self-gated rows (batched-tier parity, the repro.obs overhead
        # gate, roofline cross-check) carry ";MISMATCH" in derived when
        # the bench-side gate failed — surface that as loudly as a trend
        # regression
        if newest is not None and "MISMATCH" in newest[1]:
            trend = (trend + " GATE-FAIL").strip()
        lines.append("| " + " | ".join(
            [suite, metric, *rendered, trend]) + " |")
    return "\n".join(lines) + "\n"


def render_html(markdown: str) -> str:
    """Minimal self-contained HTML wrapper around the markdown table
    (no external deps; the table is re-rendered as a real <table>)."""
    rows = [l for l in markdown.splitlines() if l.startswith("|")]
    body = []
    for i, line in enumerate(rows):
        cells = [c.strip() for c in line.strip("|").split("|")]
        if i == 1:
            continue                    # the |---| separator
        tag = "th" if i == 0 else "td"
        tds = "".join(
            f"<{tag} class='r'>{html.escape(c)}</{tag}>"
            if ("REGRESSED" in c or "GATE-FAIL" in c)
            else f"<{tag}>{html.escape(c)}</{tag}>"
            for c in cells)
        body.append(f"<tr>{tds}</tr>")
    return ("<!doctype html><meta charset='utf-8'>"
            "<title>PALM bench trends</title>"
            "<style>body{font-family:sans-serif}table{border-collapse:"
            "collapse}td,th{border:1px solid #999;padding:4px 8px;"
            "text-align:left}tr:nth-child(even){background:#f4f4f4}"
            ".r{color:#b00}</style>"
            "<h1>PALM bench trends</h1><table>"
            + "".join(body) + "</table>")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("artifacts", type=Path, nargs="+",
                    help="bench JSON files (BENCH_*.json / CI artifacts)")
    ap.add_argument("--out", type=Path, default=None, metavar="FILE",
                    help="write the markdown table here (default: stdout)")
    ap.add_argument("--html", type=Path, default=None, metavar="FILE",
                    help="also write a self-contained HTML page here")
    ap.add_argument("--regression-pct", type=float, default=25.0,
                    help="flag metrics whose newest value regressed by "
                         "more than this vs the previous run")
    ap.add_argument("--fail-on-regression", action="store_true",
                    help="exit 2 when any metric row carries a REGRESSED "
                         "flag (CI perf-gate mode)")
    args = ap.parse_args(argv)

    table, columns = load_artifacts(args.artifacts)
    if not table:
        print("error: no bench rows found in the given artifacts",
              file=sys.stderr)
        return 1
    md = render_markdown(table, columns, regression_pct=args.regression_pct)
    if args.out is None:
        print(md, end="")
    else:
        args.out.parent.mkdir(parents=True, exist_ok=True)
        args.out.write_text(md)
        print(f"[dashboard written to {args.out}]")
    if args.html is not None:
        args.html.parent.mkdir(parents=True, exist_ok=True)
        args.html.write_text(render_html(md))
        print(f"[dashboard written to {args.html}]")
    if args.fail_on_regression:
        regressed = []
        for (suite, metric), cells in sorted(table.items()):
            vals = [cells.get(c, (None, ""))[0] for c in columns]
            if "REGRESSED" in _trend(vals, args.regression_pct):
                regressed.append(f"{suite}/{metric}")
        if regressed:
            print("error: perf regressions detected: "
                  + ", ".join(regressed), file=sys.stderr)
            return 2
    return 0


if __name__ == "__main__":
    sys.exit(main())
