"""PALM §IV-A complexity claim: Virtual Tile Aggregation + cached routing.

Naive modeling is O(2N^2) simulation objects for an N x N array; virtual
tile aggregation reduces it to O(N^2 + M), and with the analytical
(macro) NoC model to O(M), M = #operators. We sweep the array size at
fixed workload and show the event count / wall time of the macro
simulator is ~flat in N (while a per-link detailed NoC grows), and both
agree on throughput within a few percent on the wafer config.

Second section (hardware-API PR acceptance): the compiled topologies
memoize routes and path metrics, so every NoC transfer costs an O(1)
lookup instead of re-walking X-Y routing and re-scanning per-link
bandwidths. We time the detailed simulator with caching on vs off
(``cache_routing=False`` recovers the per-call baseline) and report the
speedup.

Third section (two-tier core acceptance gate): on a contention-free
16x16-mesh sweep the analytic fast tier (``engine="fast"``,
:mod:`repro.core.fastpath`) must be bit-identical to the event tier on
``total_time`` and throughput ranking while running >= 10x faster in
aggregate wall-clock. A second pass under ``engine="auto"`` records the
tier-selection counts (how many plans the contention classifier accepted
for the fast tier vs sent to the event-kernel refinement tier).

Standalone (CI perf-gate):

    PYTHONPATH=src python benchmarks/bench_sim_scaling.py --tiny \
        --json artifacts/bench_sim_scaling.json
"""

from __future__ import annotations

# allow `python benchmarks/bench_sim_scaling.py` (CI perf-gate) in
# addition to `python -m benchmarks.run --only sim_scaling`
if __package__ in (None, ""):
    import sys
    from pathlib import Path
    sys.path.insert(0, str(Path(__file__).resolve().parents[1]))
    __package__ = "benchmarks"

import argparse
import sys
import time
from pathlib import Path

from repro.core import (
    DRAMSpec,
    NoCMode,
    Schedule,
    HardwareSpec,
    MeshSpec,
    ParallelPlan,
    PipelineSimulator,
    TileSpec,
    map_graph,
    simulate,
    transformer_lm_graph,
)
from .common import Report, write_bench_json

GB = 1e9

# gate threshold: aggregate event-tier / fast-tier wall-clock on the
# contention-free sweep (the two-tier-core acceptance criterion)
FASTPATH_GATE_SPEEDUP = 10.0


def _mesh_hw(n: int, cache_routing: bool = True) -> HardwareSpec:
    spec = MeshSpec(rows=n, cols=n, intra_bw=1024 * GB, inter_bw=256 * GB,
                    link_latency=2e-8, tile_shape=(4, 4))
    topo = spec.compile(cache_routing=cache_routing)
    return HardwareSpec(
        name=f"mesh{n}", topology=topo,
        tile=TileSpec(flops=16e12, sram_bytes=3.75e6),
        dram=DRAMSpec(bandwidth=256 * GB, response_time=3e-7, channels=n),
        dram_ports=tuple(topo.device(r, 0) for r in range(0, n, 4)),
    )


def _workload():
    plan = ParallelPlan(pp=4, dp=2, tp=8, microbatch=1,
                        global_batch=16, schedule=Schedule.ONE_F_ONE_B,
                        recompute="always", training=True)
    graph = transformer_lm_graph("T", 24, 4096, 32, 2048, 2, vocab=51200)
    return graph, plan


def _gate_plan(pp: int, dp: int, tp: int, global_batch: int) -> ParallelPlan:
    # recompute="never" + generous per-stage DRAM channels keeps every
    # stream uncontended, so the whole sweep is fast-tier eligible
    return ParallelPlan(pp=pp, dp=dp, tp=tp, microbatch=2,
                        global_batch=global_batch * dp,
                        schedule=Schedule.ONE_F_ONE_B, recompute="never")


def _fastpath_gate(report: Report, tiny: bool) -> None:
    graph = transformer_lm_graph("T", 24, 4096, 32, 2048, 2, vocab=51200)
    hw = _mesh_hw(16)
    if tiny:
        cases = [(NoCMode.MACRO, pp, dp, tp, 32)
                 for pp, dp, tp in ((4, 1, 1), (4, 2, 1), (2, 1, 2))]
    else:
        cases = ([(NoCMode.MACRO, pp, dp, tp, 64) for pp, dp, tp in
                  ((4, 1, 1), (2, 1, 8), (4, 1, 4), (4, 2, 1), (2, 1, 2))]
                 + [(NoCMode.DETAILED, pp, dp, tp, 32)
                    for pp, dp, tp in ((4, 1, 1), (2, 1, 2))])

    report.log("== two-tier core gate: fast tier vs event tier, 16x16 mesh ==")
    report.log(f"{'mode':>9s} {'plan':>12s} {'M':>3s} {'event_ms':>9s} "
               f"{'fast_ms':>8s} {'speedup':>8s} {'identical':>9s}")
    tot_event = tot_fast = 0.0
    identical = True
    ev_rank = []
    fp_rank = []
    for mode, pp, dp, tp, gb in cases:
        plan = _gate_plan(pp, dp, tp, gb)
        mapped = map_graph(graph, hw, plan)
        t0 = time.perf_counter()
        ev = PipelineSimulator(mapped, noc_mode=mode, engine="event").run()
        t_event = time.perf_counter() - t0
        t0 = time.perf_counter()
        fp = PipelineSimulator(mapped, noc_mode=mode, engine="fast").run()
        t_fast = time.perf_counter() - t0
        same = (ev.total_time == fp.total_time
                and ev.throughput == fp.throughput
                and ev.noc_bytes == fp.noc_bytes
                and ev.dram_bytes == fp.dram_bytes)
        identical = identical and same
        name = f"pp{pp}dp{dp}tp{tp}"
        ev_rank.append((ev.throughput, name))
        fp_rank.append((fp.throughput, name))
        tot_event += t_event
        tot_fast += t_fast
        speedup = t_event / t_fast if t_fast > 0 else float("inf")
        report.log(f"{str(mode):>9s} {name:>12s} {plan.num_microbatches:3d} "
                   f"{t_event * 1e3:9.1f} {t_fast * 1e3:8.1f} "
                   f"{speedup:7.1f}x {str(same):>9s}")
        report.add(f"fastpath_n16_{mode}_{name}", t_fast * 1e6,
                   f"event_ms={t_event * 1e3:.1f};speedup={speedup:.1f}")

    ranking_ok = (sorted(ev_rank, reverse=True)
                  == sorted(fp_rank, reverse=True))
    aggregate = tot_event / tot_fast if tot_fast > 0 else float("inf")
    gate_ok = (identical and ranking_ok
               and aggregate >= FASTPATH_GATE_SPEEDUP)
    report.log(f"aggregate {tot_event * 1e3:.0f} ms event vs "
               f"{tot_fast * 1e3:.0f} ms fast = {aggregate:.1f}x "
               f"(gate >= {FASTPATH_GATE_SPEEDUP:.0f}x); bit-identical: "
               f"{identical}; ranking identical: {ranking_ok}")
    report.add("fastpath_gate_speedup", tot_fast * 1e6,
               f"{aggregate:.1f}x" + ("" if gate_ok else ";MISMATCH"))

    # tier-selection accounting: engine="auto" over eligible + contended
    # plans; the classifier must take the fast tier on the clean ones and
    # fall back (bit-identically priced by the event kernel) on the rest
    auto_cases = ([(4, 1, 1), (4, 2, 1), (2, 2, 2)] if tiny else
                  [(4, 1, 1), (4, 2, 1), (2, 1, 2), (2, 2, 2), (4, 2, 2)])
    n_fast = 0
    for pp, dp, tp in auto_cases:
        plan = _gate_plan(pp, dp, tp, 32)
        mapped = map_graph(graph, hw, plan)
        res = PipelineSimulator(mapped, noc_mode=NoCMode.MACRO,
                                engine="auto").run()
        n_fast += res.engine == "fast"
    report.log(f"tier selection (engine=auto): fast={n_fast}/"
               f"{len(auto_cases)} plans, event={len(auto_cases) - n_fast} "
               f"(contended fall back to the refinement tier)")
    report.add("fastpath_tier_counts", 0.0,
               f"fast={n_fast}/{len(auto_cases)}")


def run(report: Report, tiny: bool = False):
    report.log("== Virtual Tile Aggregation: simulation cost vs array size ==")
    report.log(f"{'N x N':>6s} {'tiles':>6s} {'mode':>9s} {'events':>9s} "
               f"{'wall_ms':>8s} {'thpt':>8s}")
    graph, plan = _workload()
    for n in (8, 16) if tiny else (8, 16, 24, 32):
        hw = _mesh_hw(n)
        for mode in (NoCMode.MACRO, NoCMode.DETAILED):
            t0 = time.perf_counter()
            res = simulate(graph, hw, plan, noc_mode=mode)
            wall = (time.perf_counter() - t0) * 1e3
            report.log(f"{n:6d} {n*n:6d} {str(mode):>9s} {res.event_count:9d} "
                       f"{wall:8.1f} {res.throughput:8.2f}")
            report.add(f"simscale_n{n}_{mode}", wall * 1e3,
                       f"events={res.event_count};thpt={res.throughput:.3f}")
    report.log("macro events are O(M): flat in N^2 (the aggregation claim); "
               "detailed grows with ring sizes/links")

    report.log("")
    report.log("== cached routing (compiled topology) vs per-call baseline ==")
    report.log(f"{'N x N':>6s} {'mode':>9s} {'cached_ms':>10s} "
               f"{'percall_ms':>11s} {'speedup':>8s}")
    cache_cases = (((16, NoCMode.DETAILED),) if tiny else
                   ((16, NoCMode.DETAILED), (32, NoCMode.DETAILED),
                    (32, NoCMode.MACRO)))
    for n, mode in cache_cases:
        walls = {}
        thpts = {}
        for cached in (True, False):
            hw = _mesh_hw(n, cache_routing=cached)
            t0 = time.perf_counter()
            res = simulate(graph, hw, plan, noc_mode=mode)
            walls[cached] = (time.perf_counter() - t0) * 1e3
            thpts[cached] = res.throughput
        assert thpts[True] == thpts[False], "routing cache changed results"
        speedup = walls[False] / walls[True]
        report.log(f"{n:6d} {str(mode):>9s} {walls[True]:10.1f} "
                   f"{walls[False]:11.1f} {speedup:7.2f}x")
        report.add(f"routecache_n{n}_{mode}", walls[True] * 1e3,
                   f"percall_ms={walls[False]:.1f};speedup={speedup:.2f}")
    report.log("identical throughputs; the speedup is pure routing overhead "
               "removed from the NoC hot path")

    report.log("")
    _fastpath_gate(report, tiny)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--tiny", action="store_true",
                    help="seconds-scale config for CI perf-gate runs")
    ap.add_argument("--json", type=Path, default=None, metavar="FILE",
                    help="write the {rows, lines} JSON report here")
    args = ap.parse_args(argv)

    report = Report()
    t0 = time.time()
    run(report, tiny=args.tiny)
    elapsed = time.time() - t0
    report.log(f"[sim_scaling: {elapsed:.1f}s]")

    if args.json is not None:
        write_bench_json(report, "sim_scaling", args.tiny, elapsed, args.json)

    # the fast-tier gate rows double as the CI acceptance check
    return 1 if any(row.endswith("MISMATCH") for row in report.rows) else 0


if __name__ == "__main__":
    sys.exit(main())
