"""PALM §IV-A complexity claim: Virtual Tile Aggregation + cached routing.

Naive modeling is O(2N^2) simulation objects for an N x N array; virtual
tile aggregation reduces it to O(N^2 + M), and with the analytical
(macro) NoC model to O(M), M = #operators. We sweep the array size at
fixed workload and show the event count / wall time of the macro
simulator is ~flat in N (while a per-link detailed NoC grows), and both
agree on throughput within a few percent on the wafer config.

Second section (hardware-API PR acceptance): the compiled topologies
memoize routes and path metrics, so every NoC transfer costs an O(1)
lookup instead of re-walking X-Y routing and re-scanning per-link
bandwidths. We time the detailed simulator with caching on vs off
(``cache_routing=False`` recovers the per-call baseline) and report the
speedup.
"""

from __future__ import annotations

import dataclasses
import time

from repro.core import (
    DRAMSpec,
    NoCMode,
    Schedule,
    HardwareSpec,
    MeshSpec,
    ParallelPlan,
    TileSpec,
    simulate,
    transformer_lm_graph,
    wafer_scale,
)
from .common import Report

GB = 1e9


def _mesh_hw(n: int, cache_routing: bool = True) -> HardwareSpec:
    spec = MeshSpec(rows=n, cols=n, intra_bw=1024 * GB, inter_bw=256 * GB,
                    link_latency=2e-8, tile_shape=(4, 4))
    topo = spec.compile(cache_routing=cache_routing)
    return HardwareSpec(
        name=f"mesh{n}", topology=topo,
        tile=TileSpec(flops=16e12, sram_bytes=3.75e6),
        dram=DRAMSpec(bandwidth=256 * GB, response_time=3e-7, channels=n),
        dram_ports=tuple(topo.device(r, 0) for r in range(0, n, 4)),
    )


def _workload():
    plan = ParallelPlan(pp=4, dp=2, tp=8, microbatch=1,
                        global_batch=16, schedule=Schedule.ONE_F_ONE_B,
                        recompute="always", training=True)
    graph = transformer_lm_graph("T", 24, 4096, 32, 2048, 2, vocab=51200)
    return graph, plan


def run(report: Report):
    report.log("== Virtual Tile Aggregation: simulation cost vs array size ==")
    report.log(f"{'N x N':>6s} {'tiles':>6s} {'mode':>9s} {'events':>9s} "
               f"{'wall_ms':>8s} {'thpt':>8s}")
    graph, plan = _workload()
    for n in (8, 16, 24, 32):
        hw = _mesh_hw(n)
        for mode in (NoCMode.MACRO, NoCMode.DETAILED):
            t0 = time.perf_counter()
            res = simulate(graph, hw, plan, noc_mode=mode)
            wall = (time.perf_counter() - t0) * 1e3
            report.log(f"{n:6d} {n*n:6d} {str(mode):>9s} {res.event_count:9d} "
                       f"{wall:8.1f} {res.throughput:8.2f}")
            report.add(f"simscale_n{n}_{mode}", wall * 1e3,
                       f"events={res.event_count};thpt={res.throughput:.3f}")
    report.log("macro events are O(M): flat in N^2 (the aggregation claim); "
               "detailed grows with ring sizes/links")

    report.log("")
    report.log("== cached routing (compiled topology) vs per-call baseline ==")
    report.log(f"{'N x N':>6s} {'mode':>9s} {'cached_ms':>10s} "
               f"{'percall_ms':>11s} {'speedup':>8s}")
    for n, mode in ((16, NoCMode.DETAILED), (32, NoCMode.DETAILED),
                    (32, NoCMode.MACRO)):
        walls = {}
        thpts = {}
        for cached in (True, False):
            hw = _mesh_hw(n, cache_routing=cached)
            t0 = time.perf_counter()
            res = simulate(graph, hw, plan, noc_mode=mode)
            walls[cached] = (time.perf_counter() - t0) * 1e3
            thpts[cached] = res.throughput
        assert thpts[True] == thpts[False], "routing cache changed results"
        speedup = walls[False] / walls[True]
        report.log(f"{n:6d} {str(mode):>9s} {walls[True]:10.1f} "
                   f"{walls[False]:11.1f} {speedup:7.2f}x")
        report.add(f"routecache_n{n}_{mode}", walls[True] * 1e3,
                   f"percall_ms={walls[False]:.1f};speedup={speedup:.2f}")
    report.log("identical throughputs; the speedup is pure routing overhead "
               "removed from the NoC hot path")
