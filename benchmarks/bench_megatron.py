"""Paper Table IV: PALM vs Megatron published throughput on a GPU cluster.

The paper replaces PALM's 2-D topology with a GPU-cluster topology and
simulates Megatron's published training runs (Narayanan et al. 2021,
Selene A100 cluster). Published seq/s and the (TP, DP, PP) settings are
taken from the paper's own Table IV. Full activation recomputation is on
(Megatron used it for all these models). The single global calibration
constant is ``a100_cluster``'s sustained-GEMM efficiency (0.52 of peak),
which is the same kind of peak-to-sustained calibration the paper's
"published data" comparisons imply. Claim under test: error <= ~16%,
average < 15%.
"""

from __future__ import annotations

import dataclasses

from repro.core import (
    NoCMode,
    ParallelPlan,
    Schedule,
    a100_cluster,
    simulate,
    transformer_lm_graph,
)
from .common import Report, pct_err, timed

# (name, layers, hidden, heads, TP, DP, PP, global_batch, microbatch, published seq/s)
TABLE_IV = [
    ("T-18B", 40, 6144, 48, 8, 32, 1, 1024, 4, 116.415),
    ("T-39B", 48, 8192, 64, 8, 32, 2, 1536, 4, 111.565),
    ("T-76B", 60, 10240, 80, 8, 32, 4, 1792, 2, 115.898),
    ("T-145B", 80, 12288, 96, 8, 24, 8, 2304, 2, 95.720),
    ("T-310B", 96, 16384, 128, 8, 15, 16, 2160, 1, 58.738),
    ("T-530B", 105, 20480, 128, 8, 9, 35, 2520, 1, 47.440),
]

SEQ = 2048
VOCAB = 51200


def simulate_model(name, layers, hidden, heads, tp, dp, pp, batch, mb):
    num_gpus = tp * dp * pp
    hw = a100_cluster(num_gpus, d_model=hidden)
    plan = ParallelPlan(
        pp=pp, dp=dp, tp=tp, microbatch=mb, global_batch=batch,
        schedule=Schedule.ONE_F_ONE_B, optimizer="adam", recompute="always",
        training=True)
    graph = transformer_lm_graph(
        name, num_layers=layers, d_model=hidden, n_heads=heads,
        seq_len=SEQ, batch=mb * dp, vocab=VOCAB, gated_mlp=False)
    return simulate(graph, hw, plan, noc_mode=NoCMode.MACRO)


def run(report: Report):
    report.log("== Table IV: Megatron GPU-cluster throughput (seq/s) ==")
    report.log(f"{'model':8s} {'TP,DP,PP':10s} {'PALM(ours)':>11s} "
               f"{'paper-PALM':>10s} {'published':>10s} {'err%':>6s}")
    paper_palm = {"T-18B": 114.294, "T-39B": 100.230, "T-76B": 96.601,
                  "T-145B": 83.888, "T-310B": 51.140, "T-530B": 40.007}
    errs = []
    for (name, L, H, nh, tp, dp, pp, B, mb, ref) in TABLE_IV:
        res, us = timed(simulate_model, name, L, H, nh, tp, dp, pp, B, mb)
        err = pct_err(res.throughput, ref)
        errs.append(err)
        report.log(f"{name:8s} {tp},{dp},{pp:<6d} {res.throughput:11.3f} "
                   f"{paper_palm[name]:10.3f} {ref:10.3f} {err:6.2f}")
        report.add(f"megatron_{name}", us,
                   f"seq_s={res.throughput:.3f};published={ref};err_pct={err:.2f}")
    avg = sum(errs) / len(errs)
    report.log(f"average error: {avg:.2f}%  (paper claims <15% avg, <=15.7% max)")
    report.add("megatron_avg_err", 0.0, f"avg_err_pct={avg:.2f}")
    return avg
