"""Paper §V-B: LLM training on the Table VI wafer-scale architecture.

* Table VII — baseline (TP=8, DP=2, PP=20) throughput for T-18B/76B/145B
  vs the GPU-published numbers (linear compute equivalence): paper gaps
  0.9 / 14.9 / 13.6 %.
* Fig 10 — parallelism sweep: optimal TP per Eq. (6) is ~2 for T-18B/76B
  (comm-size optimum) while T-145B peaks at TP=4 (architecture effect);
  S-shaped stage layout beats Line; TP-contiguous comm groups (comm1)
  beat spread ones (comm2); best-vs-worst >= 2x.
"""

from __future__ import annotations

import dataclasses

from repro.core import (BoundaryMode, Layout, NoCMode, ParallelPlan, Schedule,
                        simulate, transformer_lm_graph, wafer_scale)
from .common import Report, pct_err

MODELS = {
    "T-18B": (40, 6144, 48),
    "T-76B": (60, 10240, 80),
    "T-145B": (80, 12288, 96),
}
PUBLISHED = {"T-18B": 7.2760, "T-76B": 1.7968, "T-145B": 0.9896}
PAPER_PALM = {"T-18B": 7.3457, "T-76B": 2.0652, "T-145B": 1.1238}
SEQ = 2048


def wafer_run(name, tp, dp, pp=20, layout=Layout.S_SHAPE, tp_contiguous=True,
              microbatch=1, num_microbatches=128,
              boundary_mode=BoundaryMode.PAIRWISE):
    """Fixed microbatch COUNT across sweep points so pipeline-bubble
    fraction is constant and Eq. (6)'s comm trade-off is what varies."""
    L, H, nh = MODELS[name]
    hw = wafer_scale()
    gb = num_microbatches * dp * microbatch
    # recompute="auto": PALM recomputes only under memory pressure (§IV-A);
    # the wafer streams activations to off-chip DRAM instead
    plan = ParallelPlan(pp=pp, dp=dp, tp=tp, microbatch=microbatch,
                        global_batch=gb, schedule=Schedule.ONE_F_ONE_B, layout=layout,
                        tp_contiguous=tp_contiguous, recompute="auto",
                        training=True)
    graph = transformer_lm_graph(name, L, H, nh, SEQ, microbatch * dp,
                                 vocab=51200, gated_mlp=False)
    res = simulate(graph, hw, plan, noc_mode=NoCMode.MACRO,
                   boundary_mode=boundary_mode)
    return res.throughput


def run(report: Report):
    report.log("== Table VII: wafer-scale baseline (TP=8, DP=2, PP=20), samples/s ==")
    report.log(f"{'model':8s} {'PALM(ours)':>11s} {'paper-PALM':>11s} "
               f"{'published':>10s} {'gap%':>6s}")
    for name in MODELS:
        thpt = wafer_run(name, tp=8, dp=2)
        gap = pct_err(thpt, PUBLISHED[name])
        report.log(f"{name:8s} {thpt:11.4f} {PAPER_PALM[name]:11.4f} "
                   f"{PUBLISHED[name]:10.4f} {gap:6.2f}")
        report.add(f"wafer_{name}", 0.0,
                   f"samples_s={thpt:.4f};published={PUBLISHED[name]};gap_pct={gap:.2f}")

    report.log("")
    report.log("== Fig 10: parallelism / mapping / comm-group sweep ==")
    header = f"{'model':8s} " + " ".join(f"TP={t:<2d}" for t in (1, 2, 4, 8, 16))
    report.log(header + "   (s_shape + comm1)")
    best_tp = {}
    sweep = {}
    for name in MODELS:
        row = {}
        for tp in (1, 2, 4, 8, 16):
            dp = 16 // tp
            row[tp] = wafer_run(name, tp=tp, dp=dp)
        sweep[name] = row
        best_tp[name] = max(row, key=row.get)
        report.log(f"{name:8s} " + " ".join(f"{row[t]:5.2f}" for t in (1, 2, 4, 8, 16))
                   + f"   best TP={best_tp[name]}")
        report.add(f"wafer_sweep_{name}", 0.0,
                   f"best_tp={best_tp[name]};" +
                   ";".join(f"tp{t}={row[t]:.3f}" for t in row))

    # mapping + comm-group comparison at tp=4, dp=4 (both axes >1 so the
    # comm1/comm2 group-placement choice is live)
    report.log("")
    report.log(f"{'model':8s} {'s+comm1':>8s} {'s+comm2':>8s} {'line+comm1':>10s} "
               f"{'line+comm2':>10s} {'worst-case TP':>14s} {'total gap x':>11s}")
    for name in MODELS:
        tp = 4
        dp = 16 // tp
        variants = {
            "s1": wafer_run(name, tp, dp, layout=Layout.S_SHAPE, tp_contiguous=True),
            "s2": wafer_run(name, tp, dp, layout=Layout.S_SHAPE, tp_contiguous=False),
            "l1": wafer_run(name, tp, dp, layout=Layout.LINE, tp_contiguous=True),
            "l2": wafer_run(name, tp, dp, layout=Layout.LINE, tp_contiguous=False),
        }
        worst_parallelism = min(sweep[name].values())
        worst = min(min(variants.values()), worst_parallelism)
        gap = variants["s1"] / worst
        report.log(f"{name:8s} {variants['s1']:8.3f} {variants['s2']:8.3f} "
                   f"{variants['l1']:10.3f} {variants['l2']:10.3f} "
                   f"{worst_parallelism:14.3f} {gap:11.2f}")
        report.add(f"wafer_mapping_{name}", 0.0,
                   ";".join(f"{k}={v:.3f}" for k, v in variants.items())
                   + f";total_gap_x={gap:.2f}")
    return best_tp
