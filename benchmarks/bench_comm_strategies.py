"""Paper §V-C / Fig 11-12: inter-tile-group communication strategies.

Strategy 1 (Eq. 7): all-reduce in source group -> p2p to adapters ->
broadcast in destination. Strategy 2 (Eq. 8): partial reduce onto k
senders -> p2p -> all-reduce among adapters -> broadcast.

Experiment (per paper): 12-tile source/destination groups moving a
BERT-base layer gradient. Case A: source tiles form a physical ring
(4x4 block perimeter = exactly 12 tiles) -> strategy 1 wins (paper:
3.08x). Case B: ring broken by an extra off-ring tile -> strategy 2
wins (paper: 1.23x), and its time is U-shaped in the adapter count.
"""

from __future__ import annotations

from repro.core import Environment, NoCMode, NoCModel, wafer_scale
from .common import Report

# BERT-base per-layer gradient ~ 12 * 768^2 * 2B ~ 14 MB
NBYTES = 12 * 768 * 768 * 2


def _perimeter(topo, r0, c0, n=4):
    """4x4 block perimeter in ring order: exactly 12 tiles."""
    cells = [(r0, c0 + i) for i in range(n)]
    cells += [(r0 + i, c0 + n - 1) for i in range(1, n)]
    cells += [(r0 + n - 1, c0 + n - 2 - i) for i in range(n - 1)]
    cells += [(r0 + n - 2 - i, c0) for i in range(n - 2)]
    return [topo.device(r, c) for (r, c) in cells]


def strategy_time(src, dst, strategy: int, adapters: int) -> float:
    hw = wafer_scale()
    env = Environment()
    noc = NoCModel(env, hw, mode=NoCMode.DETAILED)
    proc = env.process(noc.group_to_group(src, dst, NBYTES,
                                          strategy=strategy,
                                          num_adapters=adapters))
    env.run(until_event=proc)
    return env.now


def run(report: Report):
    hw = wafer_scale()
    topo = hw.topology
    ring_src = _perimeter(topo, 0, 0)
    ring_dst = _perimeter(topo, 0, 5)
    # broken ring: replace one perimeter tile with a remote tile — every
    # pipelined ring chunk now crosses the slow long path (paper: "adds a
    # tile to disrupt ring formation")
    broken_src = ring_src[:-1] + [topo.device(19, 15)]

    report.log("== Fig 12: inter-group comm strategies (12-tile groups, "
               f"{NBYTES/1e6:.1f} MB) ==")
    report.log(f"{'case':10s} {'adapters':>8s} {'S1(us)':>9s} {'S2(us)':>9s} {'S2/S1':>6s}")
    results = {}
    for case, src in (("ring", ring_src), ("non-ring", broken_src)):
        per_case = {}
        for k in (1, 2, 3, 4, 6, 12):
            t1 = strategy_time(src, ring_dst, 1, k)
            t2 = strategy_time(src, ring_dst, 2, k)
            per_case[k] = (t1, t2)
            report.log(f"{case:10s} {k:8d} {t1*1e6:9.1f} {t2*1e6:9.1f} {t2/t1:6.2f}")
            report.add(f"comm_{case}_k{k}", t1 * 1e6,
                       f"s1_us={t1*1e6:.1f};s2_us={t2*1e6:.1f}")
        results[case] = per_case

    # Claims under test (paper Fig. 12):
    #  (a) ring case: S1 wins at every adapter count; the advantage grows
    #      with adapters (paper headline 3.08x lies inside our range);
    #  (b) non-ring: S2 wins in the small-adapter regime (paper 1.23x);
    #  (c) S2's time vs adapters is U-shaped (improves then declines).
    all_k = (1, 2, 3, 4, 6, 12)
    ring_ratios = [results["ring"][k][1] / results["ring"][k][0] for k in all_k]
    s1_always_wins_ring = all(r > 1.0 for r in ring_ratios)
    non_ratios = [results["non-ring"][k][0] / results["non-ring"][k][1]
                  for k in (1, 2, 3, 4, 6)]
    r_non = max(non_ratios)
    s2_curve = [results["non-ring"][k][1] for k in (1, 2, 3, 4, 6)]
    kmin = s2_curve.index(min(s2_curve))
    u_shaped = 0 < kmin < len(s2_curve) - 1
    report.log(f"ring: S1 wins at every k: {s1_always_wins_ring}; advantage "
               f"{min(ring_ratios):.2f}-{max(ring_ratios):.2f}x "
               f"(paper headline 3.08x in range: "
               f"{min(ring_ratios) <= 3.08 <= max(ring_ratios)}); "
               f"non-ring: S2 up to {r_non:.2f}x better (paper: 1.23x); "
               f"S2-vs-adapters U-shaped: {u_shaped}")
    report.add("comm_strategy_claims", 0.0,
               f"ring_s1_wins_all_k={s1_always_wins_ring};"
               f"ring_adv_max_x={max(ring_ratios):.2f};"
               f"nonring_s2_better_x={r_non:.2f};u_shaped={u_shaped}")
    return max(ring_ratios), r_non
