"""Serving-simulator gate: continuous vs static batching goodput.

Sweeps SLO goodput against offered request rate for both batching
policies on a rigged workload (high-variance decode lengths, so static
batches are held hostage by their longest request while continuous
batching recycles slots every iteration). Gates:

* on the rigged point, continuous batching must deliver >= 1.5x the
  static-batching goodput;
* fixed-seed serving sweeps are bit-reproducible, serial == process pool
  (the same determinism contract the sweep engine holds for training).

Standalone (CI bench-smoke):

    PYTHONPATH=src python benchmarks/bench_serving.py --tiny \
        --json artifacts/bench_serving.json
"""

from __future__ import annotations

# allow `python benchmarks/bench_serving.py` (CI bench-smoke) in addition
# to `python -m benchmarks.run --only serving`
if __package__ in (None, ""):
    import sys
    from pathlib import Path
    sys.path.insert(0, str(Path(__file__).resolve().parents[1]))
    __package__ = "benchmarks"

import argparse
import sys
import time
from pathlib import Path

from repro.serving import ServingSpec, WorkloadSpec, simulate_serving

from .common import Report, write_bench_json

# the rigged-point advantage the gate demands
_GOODPUT_FACTOR = 1.5

_ARCH, _HW = "hymba-1.5b", "grayskull"


def _spec(policy: str, rate: float, num_requests: int) -> ServingSpec:
    workload = WorkloadSpec(rate=rate, num_requests=num_requests, seed=1,
                            prompt_mean=64, prompt_cv=0.5,
                            decode_mean=16, decode_cv=2.0)
    return ServingSpec(workload=workload, max_batch=4, ctx_bucket=128,
                       policy=policy, slo_ttft_ms=1500.0, slo_tpot_ms=250.0)


def run(report: Report, tiny: bool = False) -> None:
    rates = (0.5, 1.0) if tiny else (0.5, 1.0, 2.0, 4.0)
    num_requests = 24 if tiny else 40

    gate_rate = 1.0
    goodput = {}
    for policy in ("continuous", "static"):
        for rate in rates:
            t0 = time.perf_counter()
            rep = simulate_serving(_ARCH, _HW, None,
                                   _spec(policy, rate, num_requests))
            dt = time.perf_counter() - t0
            goodput[(policy, rate)] = rep.goodput_rps
            report.log(f"{policy:>10s} @ {rate:>4.1f} req/s offered: "
                       f"goodput {rep.goodput_rps:.3f} req/s, "
                       f"SLO attainment {rep.slo_attainment:.0%}, "
                       f"{rep.preemptions} preemptions ({dt:.2f}s)")
            report.add(f"serving_{policy}_rate{rate:g}", dt * 1e6,
                       f"goodput_{rep.goodput_rps:.4f}")

    cont, stat = goodput[("continuous", gate_rate)], goodput[("static", gate_rate)]
    ratio = cont / stat if stat > 0 else float("inf")
    ok = ratio >= _GOODPUT_FACTOR
    report.log(f"rigged point ({gate_rate} req/s): continuous/static "
               f"goodput = {ratio:.2f}x (gate >= {_GOODPUT_FACTOR}x)")
    report.add("serving_goodput_gate", ratio, "ok" if ok else "MISMATCH")

    # determinism gate: same seed, serial report == report recomputed from
    # a fresh simulator (fresh cost memo) — bit for bit
    a = simulate_serving(_ARCH, _HW, None,
                         _spec("continuous", gate_rate, num_requests))
    b = simulate_serving(_ARCH, _HW, None,
                         _spec("continuous", gate_rate, num_requests))
    report.add("serving_repro_gate", 0.0,
               "ok" if a.to_json() == b.to_json() else "MISMATCH")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--tiny", action="store_true",
                    help="seconds-scale config for CI bench-smoke runs")
    ap.add_argument("--json", type=Path, default=None, metavar="FILE",
                    help="write the {rows, lines} JSON report here")
    args = ap.parse_args(argv)

    report = Report()
    t0 = time.time()
    run(report, tiny=args.tiny)
    elapsed = time.time() - t0
    report.log(f"[serving: {elapsed:.1f}s]")

    if args.json is not None:
        write_bench_json(report, "serving", args.tiny, elapsed, args.json)

    return 1 if any(row.endswith("MISMATCH") for row in report.rows) else 0


if __name__ == "__main__":
    sys.exit(main())
