"""Benchmark-suite registry.

Each paper table/figure reproduction registers here once; the driver
(``benchmarks/run.py``) and any downstream tooling iterate the registry
instead of hard-coding module lists. A suite is a module exposing
``run(report: Report) -> None``.
"""

from __future__ import annotations

import importlib
from dataclasses import dataclass
from typing import List, Optional


@dataclass(frozen=True)
class Suite:
    name: str           # CLI name (--only NAME)
    module: str         # module under the benchmarks package
    ref: str            # which paper table/figure (or deliverable) it covers


SUITES: List[Suite] = [
    Suite("allreduce", "bench_allreduce", "Fig 6 + fabric collectives"),
    Suite("congestion", "bench_congestion", "Fig 7"),
    Suite("megatron", "bench_megatron", "Table IV"),
    Suite("grayskull", "bench_grayskull", "Table V"),
    Suite("waferscale", "bench_waferscale", "Table VII + Fig 9/10"),
    Suite("comm_strategies", "bench_comm_strategies", "Fig 11/12"),
    Suite("sim_scaling", "bench_sim_scaling", "§IV-A complexity claim"),
    Suite("roofline", "roofline", "deliverable (g)"),
    Suite("crosscheck", "bench_crosscheck", "PALM vs XLA (beyond-paper)"),
    Suite("sweep_engine", "bench_sweep_engine", "§V-B sweep: serial vs pool"),
    Suite("search", "bench_search", "§VI guided multi-fidelity co-design"),
    Suite("serving", "bench_serving", "serving: continuous vs static goodput"),
]


def get_suite(name: str) -> Suite:
    for s in SUITES:
        if s.name == name:
            return s
    raise KeyError(f"unknown suite {name!r}; known: {[s.name for s in SUITES]}")


def load_module(suite: Suite):
    return importlib.import_module(f".{suite.module}", package=__package__)


def iter_suites(only: Optional[str] = None) -> List[Suite]:
    if only is not None:
        return [get_suite(only)]
    return list(SUITES)
