"""Benchmark driver — one registered suite per paper table/figure.

    PYTHONPATH=src:. python -m benchmarks.run [--only NAME] [--list]

Suites live in ``benchmarks/registry.py``; each is a module exposing
``run(report)``. Prints human-readable tables followed by the
``name,us_per_call,derived`` CSV block (written to artifacts/bench.csv
as well).
"""

from __future__ import annotations

import argparse
import sys
import time
from pathlib import Path

from .common import Report
from .registry import iter_suites, load_module


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--only", type=str, default=None)
    ap.add_argument("--list", action="store_true",
                    help="list registered suites and exit")
    args = ap.parse_args(argv)

    if args.list:
        for s in iter_suites():
            print(f"{s.name:16s} {s.module:24s} {s.ref}")
        return 0

    try:
        suites = iter_suites(args.only)
    except KeyError as e:
        print(f"error: {e.args[0]}", file=sys.stderr)
        return 2

    report = Report()
    for suite in suites:
        report.log(f"\n######## {suite.name} ({suite.ref}) ########")
        t0 = time.time()
        try:
            load_module(suite).run(report)
        except Exception as e:  # keep the suite going; record the failure
            import traceback
            report.log(f"[{suite.name} FAILED] {e}")
            traceback.print_exc()
            report.add(f"{suite.name}_FAILED", 0.0, repr(e))
        report.log(f"[{suite.name}: {time.time()-t0:.1f}s]")

    report.log("\n=== CSV (name,us_per_call,derived) ===")
    print(report.csv())
    out = Path(__file__).resolve().parents[1] / "artifacts" / "bench.csv"
    out.parent.mkdir(exist_ok=True)
    out.write_text(report.csv() + "\n")
    return 0


if __name__ == "__main__":
    sys.exit(main())
