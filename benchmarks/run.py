"""Benchmark driver — one module per paper table/figure.

    PYTHONPATH=src:. python -m benchmarks.run [--only NAME]

Prints human-readable tables followed by the ``name,us_per_call,derived``
CSV block (written to artifacts/bench.csv as well).
"""

from __future__ import annotations

import argparse
import sys
import time
from pathlib import Path

from .common import Report


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--only", type=str, default=None)
    args = ap.parse_args(argv)

    from . import (
        bench_allreduce,
        bench_comm_strategies,
        bench_congestion,
        bench_crosscheck,
        bench_grayskull,
        bench_megatron,
        bench_sim_scaling,
        bench_waferscale,
        roofline,
    )

    suites = [
        ("allreduce", bench_allreduce),        # Fig 6
        ("congestion", bench_congestion),      # Fig 7
        ("megatron", bench_megatron),          # Table IV
        ("grayskull", bench_grayskull),        # Table V
        ("waferscale", bench_waferscale),      # Table VII + Fig 9/10
        ("comm_strategies", bench_comm_strategies),  # Fig 11/12
        ("sim_scaling", bench_sim_scaling),    # §IV-A complexity claim
        ("roofline", roofline),                # deliverable (g)
        ("crosscheck", bench_crosscheck),      # PALM vs XLA (beyond-paper)
    ]

    report = Report()
    for name, mod in suites:
        if args.only and name != args.only:
            continue
        report.log(f"\n######## {name} ########")
        t0 = time.time()
        try:
            mod.run(report)
        except Exception as e:  # keep the suite going; record the failure
            import traceback
            report.log(f"[{name} FAILED] {e}")
            traceback.print_exc()
            report.add(f"{name}_FAILED", 0.0, repr(e))
        report.log(f"[{name}: {time.time()-t0:.1f}s]")

    report.log("\n=== CSV (name,us_per_call,derived) ===")
    print(report.csv())
    out = Path(__file__).resolve().parents[1] / "artifacts" / "bench.csv"
    out.parent.mkdir(exist_ok=True)
    out.write_text(report.csv() + "\n")
    return 0


if __name__ == "__main__":
    sys.exit(main())
