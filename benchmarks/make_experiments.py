"""Generate the data-driven sections of EXPERIMENTS.md (§Dry-run table,
§Roofline table) from artifacts/dryrun*/ and splice them into the
document between the AUTOGEN markers.

    PYTHONPATH=src:. python -m benchmarks.make_experiments
"""

from __future__ import annotations

import json
from pathlib import Path

ROOT = Path(__file__).resolve().parents[1]
OPT = ROOT / "artifacts" / "dryrun"
BASE = ROOT / "artifacts" / "dryrun_baseline"

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI = 3 * 50e9

ORDER_SHAPES = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def load(d):
    out = {}
    for f in sorted(d.glob("*.json")):
        r = json.loads(f.read_text())
        out[(r["arch"], r["shape"], r["mesh"])] = r
    return out


def terms(rec):
    e = rec["extrapolated"]
    comp = e["flops"] / PEAK_FLOPS
    mem = e["bytes"] / HBM_BW
    coll = max(0.0, e["coll"]["total"]) / ICI
    bound = max(comp, mem, coll)
    dom = ("compute" if bound == comp else
           "memory" if bound == mem else "collective")
    useful = rec["model_flops"] / max(1.0, e["flops"] * rec["chips"])
    return comp, mem, coll, dom, useful, (comp / bound if bound else 0.0)


def dryrun_table(cells):
    lines = ["| arch | shape | mesh | compile | GB/chip (args+temp) | "
             "collective GB/chip | status |",
             "|---|---|---|---|---|---|---|"]
    for (a, s, m), r in sorted(cells.items(),
                               key=lambda kv: (kv[0][0], ORDER_SHAPES.index(kv[0][1]), kv[0][2])):
        if not r.get("applicable", True):
            lines.append(f"| {a} | {s} | {m} | — | — | — | "
                         f"skipped: {r['skip_reason']} |")
            continue
        if not r.get("ok"):
            lines.append(f"| {a} | {s} | {m} | — | — | — | FAILED |")
            continue
        mem = r["full"]["memory"]
        gb = (mem["argument_size_in_bytes"] + mem["temp_size_in_bytes"]) / 1e9
        coll = max(0.0, r["extrapolated"]["coll"]["total"]) / 1e9
        lines.append(f"| {a} | {s} | {m} | {r['full']['compile_s']}s | "
                     f"{gb:.2f} | {coll:.1f} | OK |")
    return "\n".join(lines)


def roofline_table(cells, baseline=None):
    lines = ["| arch | shape | mesh | compute s | memory s | collective s | "
             "dominant | MF/HLO | roofline frac |",
             "|---|---|---|---|---|---|---|---|---|"]
    for (a, s, m), r in sorted(cells.items(),
                               key=lambda kv: (kv[0][0], ORDER_SHAPES.index(kv[0][1]), kv[0][2])):
        if not (r.get("ok") and "extrapolated" in r):
            continue
        comp, mem, coll, dom, useful, frac = terms(r)
        lines.append(f"| {a} | {s} | {m} | {comp:.4f} | {mem:.4f} | "
                     f"{coll:.4f} | {dom} | {useful:.2f} | {frac:.1%} |")
    return "\n".join(lines)


def main():
    opt = load(OPT)
    base = load(BASE) if BASE.exists() else {}
    doc = (ROOT / "EXPERIMENTS.md").read_text()

    blocks = {
        "DRYRUN_TABLE": dryrun_table(opt),
        "ROOFLINE_TABLE": roofline_table(opt),
        "ROOFLINE_BASELINE_TABLE": roofline_table(base) if base else "(no baseline snapshot)",
    }
    for key, body in blocks.items():
        start = f"<!-- AUTOGEN:{key} -->"
        end = f"<!-- AUTOGEN:{key}:END -->"
        if start in doc and end in doc:
            pre, rest = doc.split(start, 1)
            _, post = rest.split(end, 1)
            doc = pre + start + "\n" + body + "\n" + end + post
    (ROOT / "EXPERIMENTS.md").write_text(doc)
    print("EXPERIMENTS.md tables regenerated "
          f"({len(opt)} optimized cells, {len(base)} baseline cells)")


if __name__ == "__main__":
    main()
