"""Paper Fig. 7: congestion model vs analytical model under stacked tasks.

Overlapping all-reduce + all-to-all + DRAM read/write tasks on the same
tile region of a wafer-style mesh. The analytical model ignores resource
occupancy, so it under-predicts: the paper reports the analytical model
is up to 50% lower, ~30% at 5 tasks x 8 MB, stabilising as size grows.
We reproduce the sweep over (#tasks, size) and report the gap.
"""

from __future__ import annotations

from repro.core import DRAMModel, Environment, NoCModel, wafer_scale
from .common import Report


def _tasks(env, noc, dram, n_tasks: int, nbytes: float):
    """First n of: all-reduce, all-to-all, DRAM read, DRAM write, second
    all-reduce — ALL placed on the same row-0 tile group (the paper
    stacks tasks on one region so they contend for the same links)."""
    topo = noc.topo
    row = [topo.device(0, c) for c in range(8)]
    procs = []
    defs = [
        lambda: noc.collective("all_reduce", row, nbytes),
        lambda: noc.collective("all_to_all", row, nbytes),
        lambda: dram.access(row[5], nbytes, write=False),   # NoC leg to west port
        lambda: dram.access(row[6], nbytes, write=True),
        lambda: noc.collective("all_reduce", row, nbytes),
    ]
    for fn in defs[:n_tasks]:
        procs.append(env.process(fn()))
    return procs


def stacked_time(n_tasks: int, nbytes: float, mode: str) -> float:
    hw = wafer_scale()
    env = Environment()
    noc = NoCModel(env, hw, mode=mode)
    dram = DRAMModel(env, hw, noc)
    procs = _tasks(env, noc, dram, n_tasks, nbytes)
    env.run(until_event=env.all_of(procs))
    return env.now


def run(report: Report):
    report.log("== Fig 7: congestion (event-driven) vs analytical under "
               "stacked comm/DRAM tasks ==")
    report.log(f"{'tasks':>5s} {'MB':>4s} {'congestion(us)':>15s} "
               f"{'analytical(us)':>15s} {'gap%':>6s}")
    gap_at_5x8 = 0.0
    max_gap = 0.0
    for n in (2, 3, 4, 5):
        for mb in (1, 4, 8, 16, 32):
            nbytes = mb * 1e6
            t_c = stacked_time(n, nbytes, "detailed")
            t_a = stacked_time(n, nbytes, "analytical")
            gap = (t_c - t_a) / t_c * 100.0
            max_gap = max(max_gap, gap)
            if n == 5 and mb == 8:
                gap_at_5x8 = gap
            report.log(f"{n:5d} {mb:4d} {t_c*1e6:15.1f} {t_a*1e6:15.1f} {gap:6.1f}")
            report.add(f"congestion_n{n}_{mb}MB", t_c * 1e6,
                       f"analytical_us={t_a*1e6:.1f};gap_pct={gap:.1f}")
    report.log(f"gap at 5 tasks x 8MB: {gap_at_5x8:.1f}% "
               f"(paper: ~30%); max gap: {max_gap:.1f}% (paper: <=50%)")
    report.add("congestion_claims", 0.0,
               f"gap_5x8_pct={gap_at_5x8:.1f};max_gap_pct={max_gap:.1f}")
    return gap_at_5x8, max_gap
