"""§V-B plan sweep through the Experiment API: process-pool SweepEngine
must reproduce the serial ranking exactly while cutting wall-clock,
memory-cap pruning must happen before simulation (pruned plans cost a
mapping, not an event-driven run), and the merged hardware x plan sweep
must beat the legacy pool-per-variant execution (one shared pool,
workers initialized once, vs one pool spawned per hardware variant).

Last section (batched-fast-tier acceptance gate): on a 16x16-mesh
hardware x plan co-design sweep the batched analytic tier
(:mod:`repro.core.fastbatch`, grouping fast-path-eligible jobs by chain
shape signature and replaying whole groups as vectorized passes) must
reproduce the per-job fast tier's ranking, ``total_time`` and
``throughput`` bit-identically — and an event-tier cross-check — while
running >= 5x faster in sweep wall-clock. Skipped without numpy (CI
bench-smoke): ``run_fast_batch`` then degrades to the scalar tier,
which the unit suite covers.

Standalone (CI bench-smoke / perf-gate):

    PYTHONPATH=src python benchmarks/bench_sweep_engine.py --tiny \
        --json artifacts/bench_sweep_engine.json
"""

from __future__ import annotations

# allow `python benchmarks/bench_sweep_engine.py` (CI bench-smoke) in
# addition to `python -m benchmarks.run --only sweep_engine`
if __package__ in (None, ""):
    import sys
    from pathlib import Path
    sys.path.insert(0, str(Path(__file__).resolve().parents[1]))
    __package__ = "benchmarks"

import argparse
import dataclasses
import os
import pickle
import sys
import time
from pathlib import Path

from repro.api import Experiment, HardwareSearchSpace, SearchSpace
from repro.api.report import run_rank_key

from .common import Report, write_bench_json

GB = 1e9

# gate threshold: per-job fast-tier / batched fast-tier sweep wall-clock
# on the 16x16-mesh co-design sweep (the batched-tier acceptance
# criterion; measured ~6x)
BATCHED_GATE_SPEEDUP = 5.0

# gate threshold: metrics-on / metrics-off sweep wall-clock on the same
# rig (the repro.obs acceptance criterion: bounded overhead when enabled)
METRICS_OVERHEAD_GATE = 1.05


def _sweep_exp(memory_cap=None, tiny=False) -> Experiment:
    return Experiment(
        arch="yi-6b",
        hardware="grayskull",
        search=SearchSpace(max_plans=8 if tiny else 24,
                           microbatch_sizes=(1,) if tiny else (1, 2)),
        global_batch=32,
        seq_len=256 if tiny else 512,
        memory_cap=memory_cap,
    )


def _hw_exp(tiny=False) -> Experiment:
    """Hardware x plan product for the shared-pool vs pool-per-variant
    comparison."""
    return Experiment(
        arch="yi-6b",
        hardware="grayskull",
        search=SearchSpace(max_plans=4 if tiny else 8,
                           microbatch_sizes=(1,)),
        hardware_search=HardwareSearchSpace(
            tile_flops=(1.5e12, 3.07e12) if tiny else (1.5e12, 3.07e12, 6e12),
            dram_bandwidth=(6.25e9, 12.5e9),
        ),
        global_batch=32,
        seq_len=256 if tiny else 512,
    )


def _legacy_sim_payload(sim) -> dict:
    """The pre-columnar wire shape of one timeline-carrying SimResult: the
    event timeline as a Python tuple list plus the scalar per-stage busy
    dict, alongside the scalar digests (the NoC occupancy dict the legacy
    form also carried is omitted — a conservative baseline)."""
    return {
        "total_time": sim.total_time,
        "throughput": sim.throughput,
        "stage_memory": [dataclasses.asdict(m) for m in sim.stage_memory],
        "recompute": sim.recompute,
        "event_count": sim.event_count,
        "noc_bytes": sim.noc_bytes,
        "dram_bytes": sim.dram_bytes,
        "timeline": sim.trace.compute_tuples(),
        "stage_busy": dict(sim.stage_busy),
    }


def _ipc_exp(tiny=False) -> Experiment:
    """Timeline-carrying sweep with realistic micro-batch counts (the
    payload a planner shipping timelines back actually sees; macro-mode
    events are O(M), so these stay seconds-scale)."""
    return Experiment(
        arch="yi-6b",
        hardware="grayskull",
        search=SearchSpace(
            degrees=((4, 1, 2), (2, 2, 2), (1, 2, 4), (4, 2, 1)),
            microbatch_sizes=(1,), layouts=("s_shape",),
            max_plans=4 if tiny else 8),
        global_batch=128 if tiny else 256,
        seq_len=256 if tiny else 512,
    )


def _timeline_ipc(report: Report, tiny: bool) -> None:
    """Timeline-IPC micro-benchmark: the bytes + time a
    ``return_timelines=True`` sweep ships through the process pool, legacy
    pickled-SimResult form vs the columnar compressed Trace form.

    Also the acceptance gate for the columnar refactor: the ranking and
    per-run total_time of the timeline sweep must be bit-identical to the
    scalar sweep's, and the payload reduction must be >= 3x."""
    exp = _ipc_exp(tiny=tiny)
    plain = exp.sweep(workers=0)
    timed = exp.sweep(workers=0, return_timelines=True)

    identical = ([(r.plan, r.total_time, r.throughput) for r in plain.runs]
                 == [(r.plan, r.total_time, r.throughput) for r in timed.runs])
    report.add("timeline_ranking_parity", 0.0,
               "ok" if identical else "MISMATCH")

    sims = [r.sim for r in timed.runs]
    events = sum(len(s.trace) for s in sims)

    t0 = time.perf_counter()
    legacy_bytes = pickle.dumps([_legacy_sim_payload(s) for s in sims],
                                protocol=pickle.HIGHEST_PROTOCOL)
    pickle.loads(legacy_bytes)
    t_legacy = time.perf_counter() - t0

    t0 = time.perf_counter()
    col_bytes = pickle.dumps(sims, protocol=pickle.HIGHEST_PROTOCOL)
    pickle.loads(col_bytes)
    t_col = time.perf_counter() - t0

    ratio = len(legacy_bytes) / len(col_bytes) if col_bytes else float("inf")
    report.log(f"timeline IPC ({len(sims)} runs, {events} events): legacy "
               f"{len(legacy_bytes)} B / {t_legacy * 1e3:.1f} ms vs columnar "
               f"{len(col_bytes)} B / {t_col * 1e3:.1f} ms "
               f"({ratio:.2f}x smaller)")
    report.add("timeline_ipc_legacy_bytes", float(len(legacy_bytes)),
               f"{events}_events")
    report.add("timeline_ipc_columnar_bytes", float(len(col_bytes)),
               f"ratio_{ratio:.2f}x")
    report.add("timeline_ipc_legacy_us", t_legacy * 1e6, "pickle+unpickle")
    report.add("timeline_ipc_columnar_us", t_col * 1e6, "pickle+unpickle")
    report.add("timeline_ipc_reduction", ratio,
               "ok" if ratio >= 3.0 else "MISMATCH")


def _pool_per_variant(exp: Experiment, workers: int):
    """Legacy execution shape: one process pool spawned per hardware
    variant (the baseline the shared-pool job stream replaces)."""
    specs = exp.hardware_search.enumerate_specs(exp.hardware_spec)
    runs = []
    for spec in specs:
        sub = exp.with_(hardware=spec, hardware_search=None)
        runs.extend(sub.sweep(workers=workers).runs)
    runs.sort(key=run_rank_key)
    return runs


# ---------------------------------------------------------------------------
# batched fast tier: vectorized group replay vs per-job fast tier
# ---------------------------------------------------------------------------

def _batched_exp(tiny: bool, engine: str, flops, drams) -> Experiment:
    """16x16-mesh hardware x plan co-design sweep: two pipeline plans
    crossed with a wide (tile_flops x dram_bandwidth) grid. Every
    variant shares each plan's chain *structure* and differs only in
    the float leaves the hardware axes scale — the exact shape the
    batched tier groups on, so the whole sweep collapses into one
    vectorized replay per plan."""
    from repro.core import transformer_lm_graph

    from .bench_sim_scaling import _mesh_hw

    return Experiment(
        graph_builder=lambda p: transformer_lm_graph(
            "T", 8, 1024, 16, seq_len=256, batch=p.microbatch * p.dp,
            vocab=8192),
        hardware=_mesh_hw(16),
        hardware_search=HardwareSearchSpace(
            tile_flops=flops, dram_bandwidth=drams, max_specs=128),
        search=SearchSpace(degrees=((4, 1, 1), (2, 1, 2)),
                           microbatch_sizes=(1,), layouts=("s_shape",),
                           max_plans=2),
        global_batch=256 if tiny else 320,
        seq_len=256,
        engine=engine,
    )


def _batched_gate(report: Report, tiny: bool) -> None:
    """Batched-fast-tier acceptance gate: >= 5x sweep wall-clock vs the
    per-job fast tier with bit-identical rankings, ``total_time`` and
    ``throughput`` — cross-checked against the event tier."""
    try:
        from repro.core.fastbatch import available
    except ImportError:                     # pragma: no cover
        def available():
            return False
    if not available():
        report.log("batched fast tier: numpy unavailable — gate skipped "
                   "(run_fast_batch degrades to the scalar fast tier; "
                   "covered by tests/test_fastbatch.py)")
        return

    from repro.api.sweep import SweepEngine

    flops = tuple(f * 1e12 for f in (2, 2.5, 3, 3.5, 4, 5, 6, 7, 8,
                                     10, 12, 14, 16, 20, 24, 32))
    drams = tuple(d * GB for d in (16, 32, 48, 64, 96, 128, 192, 256))
    exp = _batched_exp(tiny, "auto", flops, drams)

    perjob_eng = SweepEngine(workers=0, batch_fastpath=False)
    t0 = time.perf_counter()
    perjob = exp.sweep(workers=0, engine=perjob_eng)
    t_perjob = time.perf_counter() - t0

    batched_eng = SweepEngine(workers=0, profile=True)
    t0 = time.perf_counter()
    batched = exp.sweep(workers=0, engine=batched_eng)
    t_batched = time.perf_counter() - t0
    prof = batched_eng.last_profile

    key = lambda r: (r.hardware, r.plan, r.total_time, r.throughput)
    scalar_parity = [key(r) for r in perjob.runs] == \
                    [key(r) for r in batched.runs]
    # every job must actually have taken the fast tier (otherwise the
    # speedup measures event-kernel fallbacks, not the batched replay)
    engines_ok = all(r.extra.get("engine") == "fast" for r in batched.runs)

    # event-tier cross-check: the full sweep in full mode; --tiny prices
    # a 2x2 corner sub-grid of the same axes (the scalar fast tier is
    # itself gated bit-identical to the event tier per-plan in
    # bench_sim_scaling's 10x gate)
    ev_exp = (exp.with_(engine="event") if not tiny else
              _batched_exp(tiny, "event", (4e12, 16e12),
                           (64 * GB, 256 * GB)))
    t0 = time.perf_counter()
    event = ev_exp.sweep(workers=0)
    t_event = time.perf_counter() - t0
    ev_hw = {r.hardware for r in event.runs}
    sub = [r for r in batched.runs if r.hardware in ev_hw]
    event_parity = [key(r) for r in event.runs] == [key(r) for r in sub]

    speedup = t_perjob / t_batched if t_batched > 0 else float("inf")
    parity_ok = scalar_parity and engines_ok and event_parity
    gate_ok = parity_ok and speedup >= BATCHED_GATE_SPEEDUP

    report.log("== batched fast tier gate: vectorized group replay vs "
               "per-job fast tier, 16x16 mesh ==")
    report.log(f"{len(batched.runs)} jobs in {prof.get('groups', 0)} "
               f"signature groups ({prof.get('batched_jobs', 0)} batched); "
               f"per-job {t_perjob:.2f}s vs batched {t_batched:.2f}s "
               f"({speedup:.2f}x, gate >= {BATCHED_GATE_SPEEDUP:.0f}x)")
    report.log(f"bit-identical to per-job tier: {scalar_parity}; all fast: "
               f"{engines_ok}; event cross-check ({len(event.runs)} jobs, "
               f"{t_event:.2f}s): {event_parity}")
    report.add("batched_perjob_us", t_perjob * 1e6,
               f"{len(perjob.runs)}_jobs")
    report.add("batched_sweep_us", t_batched * 1e6,
               f"speedup_{speedup:.2f}x")
    report.add("batched_parity", 0.0, "ok" if parity_ok else "MISMATCH")
    report.add("batched_gate_speedup", t_batched * 1e6,
               f"{speedup:.1f}x" + ("" if gate_ok else ";MISMATCH"))


def _metrics_overhead_gate(report: Report, tiny: bool) -> None:
    """repro.obs acceptance gate: ``metrics=True`` on the 16x16-mesh
    co-design sweep costs <= 5% sweep wall-clock over metrics-off, while
    leaving the ranking bit-identical and attaching the metrics document
    to the report and every run. Interleaved min-of-two timing keeps the
    tight ratio gate robust against scheduler noise."""
    from repro.api.sweep import SweepEngine

    flops = tuple(f * 1e12 for f in (2, 3, 4, 6, 8, 12, 16, 24))
    drams = tuple(d * GB for d in (32, 64, 128, 256))
    exp_off = _batched_exp(tiny, "auto", flops, drams)
    exp_on = exp_off.with_(metrics=True)

    t_off, t_on = float("inf"), float("inf")
    off = on = None
    for _ in range(2):
        t0 = time.perf_counter()
        off = exp_off.sweep(workers=0, engine=SweepEngine(workers=0))
        t_off = min(t_off, time.perf_counter() - t0)
        t0 = time.perf_counter()
        on = exp_on.sweep(workers=0, engine=SweepEngine(workers=0))
        t_on = min(t_on, time.perf_counter() - t0)

    key = lambda r: (r.hardware, r.plan, r.total_time, r.throughput)
    parity = [key(r) for r in off.runs] == [key(r) for r in on.runs]
    # the no-op registry adds nothing; the live one lands on every report
    clean_off = off.metrics is None and all(r.metrics is None
                                           for r in off.runs)
    attached = (on.metrics is not None
                and all(r.metrics is not None for r in on.runs))
    ratio = t_on / t_off if t_off > 0 else float("inf")
    gate_ok = (parity and clean_off and attached
               and ratio <= METRICS_OVERHEAD_GATE)

    report.log("== repro.obs overhead gate: metrics-on vs metrics-off "
               "sweep, 16x16 mesh ==")
    report.log(f"{len(on.runs)} jobs; off {t_off:.2f}s vs on {t_on:.2f}s "
               f"({ratio:.3f}x, gate <= {METRICS_OVERHEAD_GATE:.2f}x); "
               f"ranking parity: {parity}; metrics attached: {attached}; "
               f"off-run clean: {clean_off}")
    report.add("metrics_off_sweep_us", t_off * 1e6, f"{len(off.runs)}_jobs")
    report.add("metrics_sweep_us", t_on * 1e6,
               f"overhead_{ratio:.3f}x" + ("" if gate_ok else ";MISMATCH"))


def run(report: Report, tiny: bool = False) -> None:
    exp = _sweep_exp(tiny=tiny)

    t0 = time.perf_counter()
    serial = exp.sweep(workers=0)
    t_serial = time.perf_counter() - t0

    workers = min(8, os.cpu_count() or 1)
    t0 = time.perf_counter()
    pooled = exp.sweep(workers=workers)
    t_pool = time.perf_counter() - t0

    parity = [r.plan for r in serial.runs] == [r.plan for r in pooled.runs]
    speedup = t_serial / t_pool if t_pool > 0 else float("inf")
    report.log(f"{serial.num_candidates} candidate plans; "
               f"serial {t_serial:.2f}s vs process[{workers}] {t_pool:.2f}s "
               f"({speedup:.2f}x); ranking parity: {parity}")
    report.add("sweep_serial", t_serial * 1e6, f"{serial.num_candidates}_plans")
    report.add("sweep_pool", t_pool * 1e6, f"speedup_{speedup:.2f}x")
    report.add("sweep_parity", 0.0, "ok" if parity else "MISMATCH")

    # memory-cap pruning is pre-simulation: a tight cap must cut wall-clock,
    # not just filter the output
    cap = sorted(r.peak_memory_bytes for r in serial.runs)[len(serial.runs) // 2]
    t0 = time.perf_counter()
    pruned = _sweep_exp(memory_cap=cap, tiny=tiny).sweep(workers=0)
    t_pruned = time.perf_counter() - t0
    report.log(f"memory_cap={cap / 1e9:.2f} GB: {pruned.num_pruned_memory} plans "
               f"pruned pre-simulation; {t_pruned:.2f}s vs {t_serial:.2f}s uncapped")
    report.add("sweep_pruned", t_pruned * 1e6,
               f"{pruned.num_pruned_memory}_pruned")

    # merged hardware x plan sweep: one shared pool over the flattened
    # (variant, plan) job stream vs one pool spawned per variant
    hw_exp = _hw_exp(tiny=tiny)
    t0 = time.perf_counter()
    merged = hw_exp.sweep(workers=workers)
    t_shared = time.perf_counter() - t0

    t0 = time.perf_counter()
    legacy_runs = _pool_per_variant(hw_exp, workers)
    t_legacy = time.perf_counter() - t0

    hw_parity = ([(r.hardware, r.plan) for r in merged.runs]
                 == [(r.hardware, r.plan) for r in legacy_runs])
    hw_speedup = t_legacy / t_shared if t_shared > 0 else float("inf")
    report.log(f"hardware x plan: {merged.num_hardware} variants, "
               f"{merged.num_candidates} joint candidates; shared pool "
               f"{t_shared:.2f}s vs pool-per-variant {t_legacy:.2f}s "
               f"({hw_speedup:.2f}x); ranking parity: {hw_parity}")
    report.add("hw_sweep_shared_pool", t_shared * 1e6,
               f"{merged.num_candidates}_jobs")
    report.add("hw_sweep_pool_per_variant", t_legacy * 1e6,
               f"speedup_{hw_speedup:.2f}x")
    report.add("hw_sweep_parity", 0.0, "ok" if hw_parity else "MISMATCH")

    # return_timelines IPC: legacy pickled-SimResult vs columnar Trace
    _timeline_ipc(report, tiny)

    # batched fast tier vs per-job fast tier (skipped without numpy)
    report.log("")
    _batched_gate(report, tiny)

    # repro.obs: metrics-enabled sweep overhead must stay bounded
    report.log("")
    _metrics_overhead_gate(report, tiny)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--tiny", action="store_true",
                    help="seconds-scale config for CI bench-smoke runs")
    ap.add_argument("--json", type=Path, default=None, metavar="FILE",
                    help="write the {rows, lines} JSON report here")
    args = ap.parse_args(argv)

    report = Report()
    t0 = time.time()
    run(report, tiny=args.tiny)
    elapsed = time.time() - t0
    report.log(f"[sweep_engine: {elapsed:.1f}s]")

    if args.json is not None:
        write_bench_json(report, "sweep_engine", args.tiny, elapsed, args.json)

    # parity rows double as a smoke gate for CI
    return 1 if any(row.endswith("MISMATCH") for row in report.rows) else 0


if __name__ == "__main__":
    sys.exit(main())
