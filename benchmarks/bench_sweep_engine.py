"""§V-B plan sweep through the Experiment API: process-pool SweepEngine
must reproduce the serial ranking exactly while cutting wall-clock, and
memory-cap pruning must happen before simulation (pruned plans cost a
mapping, not an event-driven run)."""

from __future__ import annotations

import os
import time

from repro.api import Experiment, SearchSpace

from .common import Report


def _sweep_exp(memory_cap=None) -> Experiment:
    return Experiment(
        arch="yi-6b",
        hardware="grayskull",
        search=SearchSpace(max_plans=24, microbatch_sizes=(1, 2)),
        global_batch=32,
        seq_len=512,
        memory_cap=memory_cap,
    )


def run(report: Report) -> None:
    exp = _sweep_exp()

    t0 = time.perf_counter()
    serial = exp.sweep(workers=0)
    t_serial = time.perf_counter() - t0

    workers = min(8, os.cpu_count() or 1)
    t0 = time.perf_counter()
    pooled = exp.sweep(workers=workers)
    t_pool = time.perf_counter() - t0

    parity = [r.plan for r in serial.runs] == [r.plan for r in pooled.runs]
    speedup = t_serial / t_pool if t_pool > 0 else float("inf")
    report.log(f"{serial.num_candidates} candidate plans; "
               f"serial {t_serial:.2f}s vs process[{workers}] {t_pool:.2f}s "
               f"({speedup:.2f}x); ranking parity: {parity}")
    report.add("sweep_serial", t_serial * 1e6, f"{serial.num_candidates}_plans")
    report.add("sweep_pool", t_pool * 1e6, f"speedup_{speedup:.2f}x")
    report.add("sweep_parity", 0.0, "ok" if parity else "MISMATCH")

    # memory-cap pruning is pre-simulation: a tight cap must cut wall-clock,
    # not just filter the output
    cap = sorted(r.peak_memory_bytes for r in serial.runs)[len(serial.runs) // 2]
    t0 = time.perf_counter()
    pruned = _sweep_exp(memory_cap=cap).sweep(workers=0)
    t_pruned = time.perf_counter() - t0
    report.log(f"memory_cap={cap / 1e9:.2f} GB: {pruned.num_pruned_memory} plans "
               f"pruned pre-simulation; {t_pruned:.2f}s vs {t_serial:.2f}s uncapped")
    report.add("sweep_pruned", t_pruned * 1e6,
               f"{pruned.num_pruned_memory}_pruned")
