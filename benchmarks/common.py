"""Shared helpers for the paper-table benchmarks."""

from __future__ import annotations

import time
from typing import Callable, Dict, List


def timed(fn: Callable, *args, **kw):
    t0 = time.perf_counter()
    out = fn(*args, **kw)
    return out, (time.perf_counter() - t0) * 1e6  # us


class Report:
    """Collects ``name,us_per_call,derived`` CSV rows (benchmarks/run.py
    contract) plus human-readable tables."""

    def __init__(self):
        self.rows: List[str] = []
        self.lines: List[str] = []

    def add(self, name: str, us: float, derived: str):
        self.rows.append(f"{name},{us:.1f},{derived}")

    def log(self, line: str = ""):
        self.lines.append(line)
        print(line, flush=True)

    def csv(self) -> str:
        return "\n".join(self.rows)


def pct_err(sim: float, ref: float) -> float:
    return abs(sim - ref) / ref * 100.0
