"""Shared helpers for the paper-table benchmarks."""

from __future__ import annotations

import json
import time
from typing import Callable, Dict, List


def timed(fn: Callable, *args, **kw):
    t0 = time.perf_counter()
    out = fn(*args, **kw)
    return out, (time.perf_counter() - t0) * 1e6  # us


class Report:
    """Collects ``name,us_per_call,derived`` CSV rows (benchmarks/run.py
    contract) plus human-readable tables."""

    def __init__(self):
        self.rows: List[str] = []
        self.lines: List[str] = []

    def add(self, name: str, us: float, derived: str):
        self.rows.append(f"{name},{us:.1f},{derived}")

    def log(self, line: str = ""):
        self.lines.append(line)
        print(line, flush=True)

    def csv(self) -> str:
        return "\n".join(self.rows)


def write_bench_json(report: Report, suite: str, tiny: bool,
                     elapsed_s: float, path) -> None:
    """Write the standard bench artifact document (the shape CI uploads
    and ``benchmarks/dashboard.py`` consumes). The single place that
    unpacks Report's ``name,us_per_call,derived`` row contract."""
    doc = {
        "suite": suite,
        "tiny": tiny,
        "elapsed_s": elapsed_s,
        "rows": [dict(zip(("name", "us_per_call", "derived"),
                          row.split(",", 2)))
                 for row in report.rows],
        "lines": report.lines,
    }
    path.parent.mkdir(parents=True, exist_ok=True)
    path.write_text(json.dumps(doc, indent=2) + "\n")
    print(f"[bench report written to {path}]")


def pct_err(sim: float, ref: float) -> float:
    return abs(sim - ref) / ref * 100.0
