"""Guided co-design search gate (ISSUE 5 acceptance): on a rigged large
hardware x plan space, multi-fidelity guided search must land within 2%
of the exhaustive-optimum throughput while spending at most a fifth of
the exhaustive full-fidelity simulations; ``--search exhaustive`` must be
bit-identical to the legacy sweep path; and fixed-seed guided runs must
be bit-reproducible across executors (serial == process pool).

Standalone (CI bench-smoke):

    PYTHONPATH=src python benchmarks/bench_search.py --tiny \
        --json artifacts/bench_search.json
"""

from __future__ import annotations

# allow `python benchmarks/bench_search.py` (CI bench-smoke) in addition
# to `python -m benchmarks.run --only search`
if __package__ in (None, ""):
    import sys
    from pathlib import Path
    sys.path.insert(0, str(Path(__file__).resolve().parents[1]))
    __package__ = "benchmarks"

import argparse
import sys
import time
from pathlib import Path

from repro.api import Experiment, HardwareSearchSpace, SearchSpace

from .common import Report, write_bench_json

# the full-fidelity savings factor the gate demands (<= 1/5 of the sims)
_SAVINGS = 5
# allowed quality loss vs the exhaustive optimum
_QUALITY = 0.98


def _rigged_exp(tiny: bool = False) -> Experiment:
    """A co-design space with a planted optimum: one corner of the
    hardware grid (max tile flops + max DRAM bandwidth) dominates, which
    is what a guided search must find without visiting everything."""
    if tiny:
        hw = HardwareSearchSpace(tile_flops=(100e12, 197e12),
                                 dram_bandwidth=(400e9, 819e9))
        space = SearchSpace(max_plans=4, microbatch_sizes=(1,))
    else:
        hw = HardwareSearchSpace(tile_flops=(50e12, 100e12, 197e12),
                                 intra_bw=(25e9, 50e9),
                                 dram_bandwidth=(400e9, 819e9),
                                 max_specs=64)
        space = SearchSpace(max_plans=8, microbatch_sizes=(1, 2))
    return Experiment(
        arch="yi-6b",
        hardware="tpu_v5e_2x2",
        search=space,
        hardware_search=hw,
        global_batch=8 if tiny else 16,
        seq_len=128 if tiny else 256,
    )


def run(report: Report, tiny: bool = False) -> None:
    exp = _rigged_exp(tiny=tiny)

    t0 = time.perf_counter()
    exhaustive = exp.sweep(workers=0)
    t_exhaustive = time.perf_counter() - t0
    best_thpt = exhaustive.best.throughput
    report.log(f"exhaustive: {exhaustive.num_candidates} candidates "
               f"({exhaustive.num_hardware} hardware variants) in "
               f"{t_exhaustive:.2f}s; optimum {exhaustive.best.hardware} "
               f"@ {best_thpt:.3f} samples/s")

    # gate 1: --search exhaustive IS today's path, bit for bit
    via_strategy = exp.sweep(workers=0, strategy="exhaustive")
    identical = via_strategy.to_json() == exhaustive.to_json()
    report.add("search_exhaustive_parity", 0.0,
               "ok" if identical else "MISMATCH")

    budget = max(1, exhaustive.num_candidates // _SAVINGS)
    for strategy in ("sh", "evolve", "random"):
        t0 = time.perf_counter()
        guided = exp.sweep(workers=0, strategy=strategy,
                           search_budget=budget, seed=0)
        t_guided = time.perf_counter() - t0
        s = guided.search
        best = guided.best
        quality = best.throughput / best_thpt if best else 0.0
        frac = s.full_fidelity_sims / exhaustive.num_candidates
        found = (f"best {best.hardware} @ {best.throughput:.3f}" if best
                 else "NO feasible run")
        report.log(f"{strategy}: {found} ({quality:.1%} of optimum) "
                   f"with {s.full_fidelity_sims} full-fidelity sims "
                   f"({frac:.1%} of space; by fidelity {s.sims_per_fidelity}) "
                   f"in {t_guided:.2f}s")
        report.add(f"search_{strategy}_wallclock", t_guided * 1e6,
                   f"{s.full_fidelity_sims}_full_sims")
        # gate 2 (sh — the headline multi-fidelity strategy): within 2%
        # of the optimum at <= 1/5 of the full-fidelity simulations
        if strategy == "sh":
            ok = quality >= _QUALITY and frac <= 1.0 / _SAVINGS
            report.add("search_quality_gate", quality,
                       "ok" if ok else "MISMATCH")
        # gate 3: fixed seed is bit-reproducible, serial == pool
        pooled = exp.sweep(workers=2, strategy=strategy,
                           search_budget=budget, seed=0)
        ds, dp = guided.to_dict(), pooled.to_dict()
        ds.pop("executor"), dp.pop("executor")
        report.add(f"search_{strategy}_repro", 0.0,
                   "ok" if ds == dp else "MISMATCH")

    speedup = t_exhaustive / t_guided if t_guided > 0 else float("inf")
    report.add("search_exhaustive_wallclock", t_exhaustive * 1e6,
               f"{exhaustive.num_candidates}_candidates")
    report.log(f"exhaustive {t_exhaustive:.2f}s vs guided (last) "
               f"{t_guided:.2f}s ({speedup:.2f}x)")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--tiny", action="store_true",
                    help="seconds-scale config for CI bench-smoke runs")
    ap.add_argument("--json", type=Path, default=None, metavar="FILE",
                    help="write the {rows, lines} JSON report here")
    args = ap.parse_args(argv)

    report = Report()
    t0 = time.time()
    run(report, tiny=args.tiny)
    elapsed = time.time() - t0
    report.log(f"[search: {elapsed:.1f}s]")

    if args.json is not None:
        write_bench_json(report, "search", args.tiny, elapsed, args.json)

    # gate rows double as a smoke gate for CI
    return 1 if any(row.endswith("MISMATCH") for row in report.rows) else 0


if __name__ == "__main__":
    sys.exit(main())
