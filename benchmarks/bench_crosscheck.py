"""Beyond-paper capstone: PALM prediction vs XLA dry-run roofline.

PALM predicts step time for the assigned archs on the TPU v5e pod from
its own cost model (hardware.tpu_v5e_pod + workload IR); the dry-run
derives a lower bound for the same (arch, train_4k, single-pod) cell
from the compiled XLA artifact (max of the three roofline terms). The
paper validates against *published* numbers; having both the simulator
and the executable system lets us close the loop internally:
PALM_time >= XLA_bound (PALM models overheads the roofline ignores) and
within a small factor of it (PALM is not wildly pessimistic).
"""

from __future__ import annotations

import json
from pathlib import Path

from repro.configs import ARCHS, get_config
from repro.core import NoCMode, ParallelPlan, Schedule, simulate, tpu_v5e_pod
from repro.core.workload import arch_to_graph
from .common import Report

ARTIFACTS = Path(__file__).resolve().parents[1] / "artifacts" / "dryrun"
PEAK, HBM, ICI = 197e12, 819e9, 3 * 50e9


def xla_bound(arch_name: str) -> float:
    f = ARTIFACTS / f"{arch_name}__train_4k__single.json"
    if not f.exists():
        return float("nan")
    r = json.loads(f.read_text())
    if not r.get("ok"):
        return float("nan")
    e = r["extrapolated"]
    return max(e["flops"] / PEAK, e["bytes"] / HBM,
               max(0.0, e["coll"]["total"]) / ICI)


def palm_time(arch_name: str) -> float:
    arch = get_config(arch_name)
    hw = tpu_v5e_pod(16, 16)
    plan = ParallelPlan(pp=1, dp=16, tp=16, microbatch=1, global_batch=256,
                        schedule=Schedule.ONE_F_ONE_B, recompute="never", training=True)
    graph = arch_to_graph(arch, seq_len=4096, batch=16, training=True)
    res = simulate(graph, hw, plan, noc_mode=NoCMode.MACRO)
    return res.total_time


def run(report: Report):
    report.log("== PALM prediction vs XLA dry-run roofline bound "
               "(train_4k, 256-chip v5e pod) ==")
    report.log(f"{'arch':24s} {'PALM s/step':>11s} {'XLA bound s':>11s} {'ratio':>6s}")
    ok = 0
    for name in sorted(ARCHS):
        bound = xla_bound(name)
        if bound != bound:       # NaN: no artifact
            continue
        t = palm_time(name)
        ratio = t / bound
        ok += 1
        report.log(f"{name:24s} {t:11.2f} {bound:11.2f} {ratio:6.2f}")
        report.add(f"crosscheck_{name}", 0.0,
                   f"palm_s={t:.3f};xla_bound_s={bound:.3f};ratio={ratio:.2f}")
    report.log(f"({ok} archs cross-checked; the XLA memory term is a "
               "fusion-inflated upper bound on this backend, so ratios <1 "
               "indicate XLA-side over-counting rather than PALM optimism)")
