"""Roofline analysis (deliverable g): read dry-run artifacts and emit the
per-(arch x shape x mesh) three-term roofline table, preceded by the
sim-domain roofline the repro.obs registry records (one source of truth
with ``python -m repro metrics`` and the dashboard).

Terms (TPU v5e per chip): compute = FLOPs / 197 TF/s; memory =
bytes / 819 GB/s; collective = collective-bytes / (3 links x 50 GB/s).
FLOPs/bytes/collective-bytes are the trip-count-corrected per-device
numbers extrapolated from the unrolled probe compiles (see
launch/dryrun.py); MODEL_FLOPS = 6 N_active D (train) / 2 N D (serve).
"""

from __future__ import annotations

import json
from pathlib import Path

from .common import Report

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW_PER_LINK = 50e9
ICI_LINKS = 3          # usable links per chip on a 2-D torus (conservative)

ARTIFACTS = Path(__file__).resolve().parents[1] / "artifacts" / "dryrun"


def load_cells():
    cells = []
    for f in sorted(ARTIFACTS.glob("*.json")):
        r = json.loads(f.read_text())
        if r.get("ok") and "extrapolated" in r:
            cells.append(r)
    return cells


def terms(rec):
    e = rec["extrapolated"]
    chips = rec["chips"]
    compute = e["flops"] / PEAK_FLOPS
    memory = e["bytes"] / HBM_BW
    coll = e["coll"]["total"] / (ICI_LINKS * ICI_BW_PER_LINK)
    dom = max(("compute", compute), ("memory", memory), ("collective", coll),
              key=lambda kv: kv[1])
    useful = rec["model_flops"] / max(1.0, e["flops"] * chips)
    bound = max(compute, memory, coll)
    frac = compute / bound if bound > 0 else 0.0
    return {"compute_s": compute, "memory_s": memory, "collective_s": coll,
            "dominant": dom[0], "useful_ratio": useful,
            "roofline_fraction": frac}


def sim_roofline(report: Report):
    """Sim-domain roofline from the repro.obs registry: the
    ``stages.roofline_utilization`` series the scheduler records with
    ``metrics=True`` — the same numbers ``python -m repro metrics``
    prints and the dashboard rolls up, so the roofline table and the
    simulator share one source of truth. Cross-checked in-place against
    an independent recomputation from the same document (flops /
    (total_time x tile peak)); tests/test_obs.py pins the identity."""
    from repro.api import Experiment, ParallelPlan, resolve_hardware

    hw = resolve_hardware("tpu_v5e_2x2")
    run_rep = Experiment(
        arch="yi-6b", hardware=hw, seq_len=128,
        plan=ParallelPlan(pp=2, dp=1, tp=2, microbatch=1, global_batch=8),
        global_batch=8, metrics=True).run()
    sim = run_rep.metrics["sim"]
    util = sim["stages"]["roofline_utilization"]
    flops = sim["stages"]["flops"]
    denom = sim["total_time"] * hw.tile.flops
    ok = denom > 0 and all(
        abs(u - f / denom) <= 1e-9 * max(1.0, abs(u))
        for u, f in zip(util, flops))

    report.log("")
    report.log("== Sim-domain roofline (repro.obs, metrics=True) ==")
    report.log(f"{'stage':>5s} {'flops':>16s} {'roofline%':>10s} "
               f"{'busy%':>7s}")
    busy = sim["stages"]["busy_fraction"]
    for s, (f, u, b) in enumerate(zip(flops, util, busy)):
        report.log(f"{s:>5d} {f:>16.4g} {100 * u:>9.2f}% {100 * b:>6.1f}%")
    report.add("roofline_sim_utilization", 0.0,
               f"max_{max(util):.4f}" + ("" if ok else ";MISMATCH"))


def run(report: Report):
    cells = load_cells()
    sim_roofline(report)
    report.log("")
    report.log("== Roofline terms per (arch x shape x mesh) — seconds/step "
               "per chip ==")
    report.log(f"{'arch':22s} {'shape':12s} {'mesh':7s} {'compute':>9s} "
               f"{'memory':>9s} {'collect.':>9s} {'dominant':>10s} "
               f"{'MF/HLO':>7s} {'roofl%':>7s}")
    for rec in cells:
        t = terms(rec)
        report.log(f"{rec['arch']:22s} {rec['shape']:12s} {rec['mesh']:7s} "
                   f"{t['compute_s']:9.4f} {t['memory_s']:9.4f} "
                   f"{t['collective_s']:9.4f} {t['dominant']:>10s} "
                   f"{t['useful_ratio']:7.3f} {100*t['roofline_fraction']:6.1f}%")
        report.add(f"roofline_{rec['arch']}_{rec['shape']}_{rec['mesh']}", 0.0,
                   f"compute_s={t['compute_s']:.5f};memory_s={t['memory_s']:.5f};"
                   f"collective_s={t['collective_s']:.5f};dom={t['dominant']};"
                   f"useful={t['useful_ratio']:.3f};"
                   f"roofline_frac={t['roofline_fraction']:.3f}")
    if not cells:
        report.log("(no dry-run artifacts found — run "
                   "`python -m repro.launch.dryrun --all` first)")
    return cells
