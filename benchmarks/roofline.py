"""Roofline analysis (deliverable g): read dry-run artifacts and emit the
per-(arch x shape x mesh) three-term roofline table.

Terms (TPU v5e per chip): compute = FLOPs / 197 TF/s; memory =
bytes / 819 GB/s; collective = collective-bytes / (3 links x 50 GB/s).
FLOPs/bytes/collective-bytes are the trip-count-corrected per-device
numbers extrapolated from the unrolled probe compiles (see
launch/dryrun.py); MODEL_FLOPS = 6 N_active D (train) / 2 N D (serve).
"""

from __future__ import annotations

import json
from pathlib import Path

from .common import Report

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW_PER_LINK = 50e9
ICI_LINKS = 3          # usable links per chip on a 2-D torus (conservative)

ARTIFACTS = Path(__file__).resolve().parents[1] / "artifacts" / "dryrun"


def load_cells():
    cells = []
    for f in sorted(ARTIFACTS.glob("*.json")):
        r = json.loads(f.read_text())
        if r.get("ok") and "extrapolated" in r:
            cells.append(r)
    return cells


def terms(rec):
    e = rec["extrapolated"]
    chips = rec["chips"]
    compute = e["flops"] / PEAK_FLOPS
    memory = e["bytes"] / HBM_BW
    coll = e["coll"]["total"] / (ICI_LINKS * ICI_BW_PER_LINK)
    dom = max(("compute", compute), ("memory", memory), ("collective", coll),
              key=lambda kv: kv[1])
    useful = rec["model_flops"] / max(1.0, e["flops"] * chips)
    bound = max(compute, memory, coll)
    frac = compute / bound if bound > 0 else 0.0
    return {"compute_s": compute, "memory_s": memory, "collective_s": coll,
            "dominant": dom[0], "useful_ratio": useful,
            "roofline_fraction": frac}


def run(report: Report):
    cells = load_cells()
    report.log("== Roofline terms per (arch x shape x mesh) — seconds/step "
               "per chip ==")
    report.log(f"{'arch':22s} {'shape':12s} {'mesh':7s} {'compute':>9s} "
               f"{'memory':>9s} {'collect.':>9s} {'dominant':>10s} "
               f"{'MF/HLO':>7s} {'roofl%':>7s}")
    for rec in cells:
        t = terms(rec)
        report.log(f"{rec['arch']:22s} {rec['shape']:12s} {rec['mesh']:7s} "
                   f"{t['compute_s']:9.4f} {t['memory_s']:9.4f} "
                   f"{t['collective_s']:9.4f} {t['dominant']:>10s} "
                   f"{t['useful_ratio']:7.3f} {100*t['roofline_fraction']:6.1f}%")
        report.add(f"roofline_{rec['arch']}_{rec['shape']}_{rec['mesh']}", 0.0,
                   f"compute_s={t['compute_s']:.5f};memory_s={t['memory_s']:.5f};"
                   f"collective_s={t['collective_s']:.5f};dom={t['dominant']};"
                   f"useful={t['useful_ratio']:.3f};"
                   f"roofline_frac={t['roofline_fraction']:.3f}")
    if not cells:
        report.log("(no dry-run artifacts found — run "
                   "`python -m repro.launch.dryrun --all` first)")
    return cells
