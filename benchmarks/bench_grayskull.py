"""Paper Table V: ResNet-50 + BERT-base inference on Tenstorrent Grayskull.

Pipelined inference (continuous input, no backward; throughput excludes
setup/drain per §V-A3). The paper adjusts the mapping strategy and
reports <13% error vs published throughput (ResNet50: 22431 samples/s
int8 [50]; BERT-base: 2830 samples/s [40]). We sweep a small set of
(pp, dp, microbatch) mappings like the paper did and report the best.
"""

from __future__ import annotations

from repro.core import (Layout, NoCMode, ParallelPlan, bert_base_graph,
                        grayskull, resnet50_graph, simulate)
from .common import Report, pct_err

PUBLISHED = {"resnet50": 22431.0, "bert_base": 2830.0}
PAPER_PALM = {"resnet50": 23033.46, "bert_base": 3190.12}


def best_throughput(builder, plans) -> float:
    hw = grayskull()
    best = 0.0
    for plan in plans:
        graph = builder(plan)
        res = simulate(graph, hw, plan, noc_mode=NoCMode.MACRO)
        best = max(best, res.throughput)
    return best


def run(report: Report):
    report.log("== Table V: Grayskull inference throughput (samples/s) ==")
    results = {}

    # ResNet50 has 55 ops: near-layer-wise pipelines (one or two ops per
    # core group) use the full 120-core array, as Grayskull's dataflow does.
    # stream_overlap=False + weight_multicast=False: Tensix cores have
    # ~1 MB SRAM — no room to double-buffer weight streams against compute
    # (unlike the wafer's 60 MB tiles), and the runtime streams weights
    # per-core, so DRAM serialises with compute, per Fig. 5.
    plans_r = [ParallelPlan(pp=pp, dp=dp, tp=tp, microbatch=mb,
                            global_batch=mb * dp * 64, training=False,
                            layout=Layout.S_SHAPE, stream_overlap=False,
                            weight_multicast=False)
               for pp, dp, tp in ((52, 2, 1), (40, 3, 1), (28, 4, 1),
                                  (28, 2, 2), (24, 5, 1), (20, 3, 2),
                                  (14, 2, 4), (13, 2, 4), (10, 3, 4))
               for mb in (2, 4, 8)]
    results["resnet50"] = best_throughput(
        lambda p: resnet50_graph(batch=p.microbatch * p.dp), plans_r)

    plans_b = [ParallelPlan(pp=pp, dp=dp, tp=1, microbatch=mb,
                            global_batch=mb * dp * 64, training=False,
                            layout=Layout.S_SHAPE, stream_overlap=False,
                            weight_multicast=False)
               for pp, dp in ((13, 8), (13, 4), (6, 16)) for mb in (1, 2, 4)]
    results["bert_base"] = best_throughput(
        lambda p: bert_base_graph(batch=p.microbatch * p.dp), plans_b)

    errs = []
    report.log(f"{'model':10s} {'PALM(ours)':>11s} {'paper-PALM':>11s} "
               f"{'published':>10s} {'err%':>6s}")
    for name in ("resnet50", "bert_base"):
        err = pct_err(results[name], PUBLISHED[name])
        errs.append(err)
        report.log(f"{name:10s} {results[name]:11.1f} {PAPER_PALM[name]:11.1f} "
                   f"{PUBLISHED[name]:10.1f} {err:6.2f}")
        report.add(f"grayskull_{name}", 0.0,
                   f"samples_s={results[name]:.1f};published={PUBLISHED[name]};"
                   f"err_pct={err:.2f}")
    report.log(f"max error: {max(errs):.2f}% (paper: <13%)")
    return max(errs)
