"""Paper Fig. 6: ring all-reduce validation on 4 and 16 workers.

The paper validates PALM's NoC model against a real GPU system with ring
topology from Astra-Sim 2.0 [38], claiming <=5% error. The published raw
numbers are not redistributable; the load-bearing property is that the
event-driven link-resource model converges to the analytically exact
ring cost  T = 2(P-1) * (S/P / BW + hop_lat)  that the real system
follows at these sizes (bandwidth-dominated regime). We assert the
detailed event-driven simulation matches that reference within 5% on 4
and 16 workers across 1-128 MB, and additionally that the macro
(O(1)-event) mode matches the detailed mode.
"""

from __future__ import annotations

from repro.core import DRAMSpec, Environment, GPUCluster, HardwareSpec, NoCModel, TileSpec
from repro.core.noc import collective_steps
from .common import Report, pct_err

GB = 1e9
BW = 300 * GB
LAT = 2e-6


def _ring_hw(p: int) -> HardwareSpec:
    """GPU node with a switch: every rank-to-rank path is (up, down) —
    the logical-ring-over-NVSwitch system Fig. 6 measures."""
    topo = GPUCluster(p, gpus_per_node=p, nvlink_bw=BW, nvlink_latency=LAT)
    return HardwareSpec(name=f"ring{p}", topology=topo,
                        tile=TileSpec(flops=1e12, sram_bytes=1e6),
                        dram=DRAMSpec(bandwidth=1e12))


def simulate_allreduce(p: int, nbytes: float, mode: str) -> float:
    hw = _ring_hw(p)
    env = Environment()
    noc = NoCModel(env, hw, mode=mode)
    group = list(range(p))
    proc = env.process(noc.collective("all_reduce", group, nbytes))
    env.run(until_event=proc)
    return env.now


def reference_ring_time(p: int, nbytes: float) -> float:
    """Bandwidth-optimal ring all-reduce: 2(P-1) steps of S/P at link BW
    plus the 2-hop (up+down) switch latency per step — the curve real
    NVSwitch systems follow in the bandwidth regime."""
    steps = collective_steps("all_reduce", p)
    return steps * (nbytes / p / BW + 2 * LAT)


def run(report: Report):
    report.log("== Fig 6: ring all-reduce, PALM detailed vs reference ==")
    report.log(f"{'P':>3s} {'MB':>6s} {'detailed(us)':>13s} {'ref(us)':>10s} "
               f"{'macro(us)':>10s} {'err%':>6s}")
    worst = 0.0
    for p in (4, 16):
        for mb in (1, 4, 16, 64, 128):
            nbytes = mb * 1e6
            t_det = simulate_allreduce(p, nbytes, "detailed")
            t_mac = simulate_allreduce(p, nbytes, "macro")
            t_ref = reference_ring_time(p, nbytes)
            err = pct_err(t_det, t_ref)
            worst = max(worst, err)
            report.log(f"{p:3d} {mb:6d} {t_det*1e6:13.1f} {t_ref*1e6:10.1f} "
                       f"{t_mac*1e6:10.1f} {err:6.2f}")
            report.add(f"allreduce_p{p}_{mb}MB", t_det * 1e6,
                       f"ref_us={t_ref*1e6:.1f};err_pct={err:.2f}")
    report.log(f"worst error vs ring reference: {worst:.2f}% (paper: <=5%)")
    report.add("allreduce_worst_err", 0.0, f"worst_err_pct={worst:.2f}")
    return worst
