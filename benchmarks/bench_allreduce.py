"""Paper Fig. 6: ring all-reduce validation on 4 and 16 workers — plus
the scale-out fabric collective-algorithm comparison.

The paper validates PALM's NoC model against a real GPU system with ring
topology from Astra-Sim 2.0 [38], claiming <=5% error. The published raw
numbers are not redistributable; the load-bearing property is that the
event-driven link-resource model converges to the analytically exact
ring cost  T = 2(P-1) * (S/P / BW + hop_lat)  that the real system
follows at these sizes (bandwidth-dominated regime). We assert the
detailed event-driven simulation matches that reference within 5% on 4
and 16 workers across 1-128 MB, and additionally that the macro
(O(1)-event) mode matches the detailed mode.

The fabric section compares the cross-chip collective families
(:mod:`repro.fabric`) — flat ring vs binomial tree vs hierarchical
(per-level reduce-scatter/all-gather) — on the 2-node ``cluster_2x2``
preset and an 8-chip 3-tier rack, gated on two properties:

* every simulated cost respects the alpha-beta bandwidth lower bound;
* hierarchical beats (or ties) the flat ring for small messages at the
  higher chip count — the latency regime hierarchical collectives exist
  for (fewer rounds, and upper-tier traffic shrunk by the level fan-in).

Standalone (CI bench-smoke):

    PYTHONPATH=src python benchmarks/bench_allreduce.py --tiny \
        --json artifacts/bench_allreduce.json
"""

from __future__ import annotations

# allow `python benchmarks/bench_allreduce.py` (CI bench-smoke) in
# addition to `python -m benchmarks.run --only allreduce`
if __package__ in (None, ""):
    import sys
    from pathlib import Path
    sys.path.insert(0, str(Path(__file__).resolve().parents[1]))
    __package__ = "benchmarks"

import argparse
import dataclasses
import sys
import time
from pathlib import Path

from repro.core import DRAMSpec, Environment, GPUCluster, HardwareSpec, NoCModel, TileSpec
from repro.core.noc import collective_steps
from repro.core.topology import MeshSpec
from repro.fabric import FabricSpec, alpha_beta_lower_bound, cluster_2x2, rack_2x2x2
from repro.fabric.model import FabricModel

from .common import Report, pct_err, write_bench_json

GB = 1e9
BW = 300 * GB
LAT = 2e-6


def _ring_hw(p: int) -> HardwareSpec:
    """GPU node with a switch: every rank-to-rank path is (up, down) —
    the logical-ring-over-NVSwitch system Fig. 6 measures."""
    topo = GPUCluster(p, gpus_per_node=p, nvlink_bw=BW, nvlink_latency=LAT)
    return HardwareSpec(name=f"ring{p}", topology=topo,
                        tile=TileSpec(flops=1e12, sram_bytes=1e6),
                        dram=DRAMSpec(bandwidth=1e12))


def simulate_allreduce(p: int, nbytes: float, mode: str) -> float:
    hw = _ring_hw(p)
    env = Environment()
    noc = NoCModel(env, hw, mode=mode)
    group = list(range(p))
    proc = env.process(noc.collective("all_reduce", group, nbytes))
    env.run(until_event=proc)
    return env.now


def reference_ring_time(p: int, nbytes: float) -> float:
    """Bandwidth-optimal ring all-reduce: 2(P-1) steps of S/P at link BW
    plus the 2-hop (up+down) switch latency per step — the curve real
    NVSwitch systems follow in the bandwidth regime."""
    steps = collective_steps("all_reduce", p)
    return steps * (nbytes / p / BW + 2 * LAT)


# ---------------------------------------------------------------------------
# Fabric collective families (cross-chip all-reduce)
# ---------------------------------------------------------------------------

def _fabric_hw(fabric: FabricSpec) -> HardwareSpec:
    """One device per chip: intra-chip legs are no-ops, so the simulated
    time is the pure fabric schedule cost."""
    return HardwareSpec(
        name=f"fab_{fabric.name}",
        topology=MeshSpec(rows=1, cols=1, intra_bw=1e12),
        tile=TileSpec(flops=1e12, sram_bytes=1e6),
        dram=DRAMSpec(bandwidth=1e12),
        fabric=fabric)


def simulate_fabric_allreduce(fabric: FabricSpec, nbytes: float,
                              collective: str, mode: str = "detailed") -> float:
    spec = dataclasses.replace(fabric, collective=collective)
    hw = _fabric_hw(spec)
    env = Environment()
    fm = FabricModel(env, hw, mode=mode)
    group = list(range(spec.num_chips))      # one device per chip
    proc = env.process(fm.collective("all_reduce", group, nbytes))
    env.run(until_event=proc)
    return env.now


def fabric_allreduce_bound(fab: FabricSpec, nbytes: float) -> float:
    """Per-level alpha-beta bandwidth bound for cluster all-reduce: the
    payload entering level L is the level-(L-1) reduce-scatter output
    ``n / chips_per_child(L)``, and no algorithm moves it across the
    level in less than the ring term ``2(d-1)/d * payload / bw``."""
    return sum(
        alpha_beta_lower_bound("all_reduce", lvl.degree,
                               nbytes / fab.chips_per_child(i), lvl.bandwidth)
        for i, lvl in enumerate(fab.levels))


def run_fabric(report: Report, tiny: bool = False) -> int:
    """Ring vs tree vs hierarchical across message sizes; returns the
    number of gate violations (0 = pass)."""
    report.log()
    report.log("== fabric: cross-chip all-reduce, ring vs tree vs "
               "hierarchical ==")
    presets = [("cluster_2x2", cluster_2x2()), ("rack_2x2x2", rack_2x2x2())]
    sizes_kb = (64, 1024) if tiny else (64, 1024, 16384)
    report.log(f"{'fabric':>12s} {'KB':>7s} {'ring(us)':>10s} "
               f"{'tree(us)':>10s} {'hier(us)':>10s} {'bound(us)':>10s}")
    violations = 0
    small_kb = sizes_kb[0]
    for name, fab in presets:
        p = fab.num_chips
        for kb in sizes_kb:
            nbytes = kb * 1e3
            times = {c: simulate_fabric_allreduce(fab, nbytes, c)
                     for c in ("ring", "tree", "hierarchical")}
            bound = fabric_allreduce_bound(fab, nbytes)
            for c, t in times.items():
                if t < bound * (1 - 1e-9):
                    violations += 1
                    report.log(f"  !! {name}/{c} @ {kb}KB beats the "
                               f"alpha-beta bound ({t:.2e} < {bound:.2e})")
                    report.add(f"fabric_bound_{name}_{c}_{kb}KB", t * 1e6,
                               "MISMATCH")
            report.log(f"{name:>12s} {kb:7d} {times['ring']*1e6:10.1f} "
                       f"{times['tree']*1e6:10.1f} "
                       f"{times['hierarchical']*1e6:10.1f} {bound*1e6:10.1f}")
            report.add(f"fabric_allreduce_{name}_{kb}KB",
                       times["hierarchical"] * 1e6,
                       f"ring_us={times['ring']*1e6:.1f};"
                       f"tree_us={times['tree']*1e6:.1f};"
                       f"bound_us={bound*1e6:.1f}")
            # latency-regime gate at the higher chip count
            if kb == small_kb and p >= 8:
                ok = times["hierarchical"] <= times["ring"] * (1 + 1e-9)
                if not ok:
                    violations += 1
                report.add(f"fabric_hier_vs_ring_{name}", 0.0,
                           f"hier_us={times['hierarchical']*1e6:.1f};"
                           f"ring_us={times['ring']*1e6:.1f};"
                           + ("ok" if ok else "MISMATCH"))
    report.log(f"fabric gate violations: {violations}")
    return violations


def run(report: Report, tiny: bool = False):
    report.log("== Fig 6: ring all-reduce, PALM detailed vs reference ==")
    report.log(f"{'P':>3s} {'MB':>6s} {'detailed(us)':>13s} {'ref(us)':>10s} "
               f"{'macro(us)':>10s} {'err%':>6s}")
    worst = 0.0
    sizes = (1, 16) if tiny else (1, 4, 16, 64, 128)
    for p in (4, 16):
        for mb in sizes:
            nbytes = mb * 1e6
            t_det = simulate_allreduce(p, nbytes, "detailed")
            t_mac = simulate_allreduce(p, nbytes, "macro")
            t_ref = reference_ring_time(p, nbytes)
            err = pct_err(t_det, t_ref)
            worst = max(worst, err)
            report.log(f"{p:3d} {mb:6d} {t_det*1e6:13.1f} {t_ref*1e6:10.1f} "
                       f"{t_mac*1e6:10.1f} {err:6.2f}")
            report.add(f"allreduce_p{p}_{mb}MB", t_det * 1e6,
                       f"ref_us={t_ref*1e6:.1f};err_pct={err:.2f}")
    report.log(f"worst error vs ring reference: {worst:.2f}% (paper: <=5%)")
    report.add("allreduce_worst_err", 0.0, f"worst_err_pct={worst:.2f}")
    run_fabric(report, tiny=tiny)
    return worst


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--tiny", action="store_true",
                    help="seconds-scale config for CI bench-smoke runs")
    ap.add_argument("--json", type=Path, default=None, metavar="FILE",
                    help="write the {rows, lines} JSON report here")
    args = ap.parse_args(argv)

    report = Report()
    t0 = time.time()
    run(report, tiny=args.tiny)
    elapsed = time.time() - t0
    report.log(f"[allreduce: {elapsed:.1f}s]")

    if args.json is not None:
        write_bench_json(report, "allreduce", args.tiny, elapsed, args.json)

    return 1 if any(row.endswith("MISMATCH") for row in report.rows) else 0


if __name__ == "__main__":
    sys.exit(main())
