"""Parallel sweep engine for Experiment plan searches.

Executes plan sweeps through a ``concurrent.futures`` process pool (or
serially with ``workers=0``) with two structural optimizations over the
legacy ``sweep_plans`` loop:

* **Graph-construction memoization** — the workload graph depends only on
  the per-iteration batch (``microbatch * dp``), not the full plan, so
  plans sharing a batch share one graph build (per process).
* **Early infeasibility pruning** — per-tile memory is a property of the
  *mapped* graph, so the ``memory_cap`` check runs before the event-driven
  simulation and infeasible plans cost a mapping, not a full run.

Results are deterministic: the engine evaluates plans in enumeration
order and ranks by simulated throughput, so serial and process-pool
sweeps produce identical SweepReports.
"""

from __future__ import annotations

import os
import pickle
import warnings
from concurrent.futures import ProcessPoolExecutor
from typing import Dict, List, Optional, Sequence, Tuple

from ..core.parallelism import ParallelPlan, map_graph
from ..core.scheduler import PipelineSimulator, plan_memory
from .report import RunReport, SweepReport

__all__ = ["SweepEngine", "run_one"]

# outcome tags for one plan evaluation
_OK, _PRUNED, _FAILED = "ok", "pruned", "failed"


def _evaluate(exp, plan: ParallelPlan, graph_cache: Dict) -> Tuple[str, object]:
    """Evaluate one plan: build (memoized) graph, map, prune on memory,
    simulate. Returns (tag, RunReport | reason)."""
    try:
        if exp.graph_builder is None:
            key = plan.microbatch * plan.dp
            graph = graph_cache.get(key)
            if graph is None:
                graph = exp.build_graph(plan)
                graph_cache[key] = graph
        else:
            graph = exp.build_graph(plan)   # builder may depend on full plan
        hw = exp.hardware_spec
        mapped = map_graph(graph, hw, plan)
        mem_plan = None
        if exp.memory_cap is not None:
            mem_plan = plan_memory(mapped)
            if max(m.total for m in mem_plan[0]) > exp.memory_cap:
                return (_PRUNED, None)
        sim = PipelineSimulator(mapped, noc_mode=exp.noc_mode,
                                boundary_mode=exp.boundary_mode,
                                memory_plan=mem_plan)
        result = sim.run()
    except (ValueError, KeyError, TypeError) as e:
        return (_FAILED, f"{type(e).__name__}: {e}")
    return (_OK, RunReport.from_sim(exp.arch_name, hw.name, plan, result))


def run_one(exp, plan: ParallelPlan) -> RunReport:
    """Simulate one fixed plan (Experiment.run body)."""
    graph = exp.build_graph(plan)
    hw = exp.hardware_spec
    mapped = map_graph(graph, hw, plan)
    sim = PipelineSimulator(mapped, noc_mode=exp.noc_mode,
                            boundary_mode=exp.boundary_mode,
                            collect_timeline=exp.collect_timeline)
    return RunReport.from_sim(exp.arch_name, hw.name, plan, sim.run())


# -- process-pool plumbing ---------------------------------------------------
# The Experiment is shipped once per worker (initializer) instead of once
# per task; each worker keeps its own graph memo across tasks.
_WORKER: Dict = {}


def _init_worker(exp_bytes: bytes) -> None:
    _WORKER["exp"] = pickle.loads(exp_bytes)
    _WORKER["graphs"] = {}


def _eval_in_worker(plan: ParallelPlan) -> Tuple[str, object]:
    return _evaluate(_WORKER["exp"], plan, _WORKER["graphs"])


class SweepEngine:
    """Executes a plan sweep for an Experiment.

    ``workers=0`` (default) runs serially in-process; ``workers=N`` uses an
    N-process pool; ``workers=None`` uses one process per CPU.
    """

    def __init__(self, workers: Optional[int] = 0):
        self.workers = os.cpu_count() if workers is None else workers

    def sweep(self, exp, plans: Sequence[ParallelPlan]) -> SweepReport:
        plans = list(plans)
        outcomes, executor = self._evaluate_all(exp, plans)

        runs: List[RunReport] = []
        pruned = failed = 0
        for tag, payload in outcomes:
            if tag == _OK:
                runs.append(payload)
            elif tag == _PRUNED:
                pruned += 1
            else:
                failed += 1
        runs.sort(key=lambda r: -r.throughput)
        return SweepReport(
            arch=exp.arch_name,
            hardware=exp.hardware_spec.name,
            runs=runs,
            num_candidates=len(plans),
            num_pruned_memory=pruned,
            num_failed=failed,
            executor=executor,
        )

    def _evaluate_all(self, exp, plans: Sequence[ParallelPlan]):
        if self.workers >= 2 and len(plans) > 1:
            try:
                exp_bytes = pickle.dumps(exp)
            except Exception as e:   # e.g. lambda graph_builder
                warnings.warn(
                    f"experiment not picklable ({e}); sweeping serially",
                    RuntimeWarning, stacklevel=3)
            else:
                n = min(self.workers, len(plans))
                with ProcessPoolExecutor(
                        max_workers=n,
                        initializer=_init_worker,
                        initargs=(exp_bytes,)) as pool:
                    return list(pool.map(_eval_in_worker, plans)), f"process[{n}]"
        graphs: Dict = {}
        return [_evaluate(exp, plan, graphs) for plan in plans], "serial"
