"""Parallel sweep engine for Experiment plan and hardware x plan searches.

Executes sweeps through a ``concurrent.futures`` process pool (or
serially with ``workers=0``) with three structural optimizations over the
legacy ``sweep_plans`` loop:

* **Graph-construction memoization** — the workload graph depends only on
  the per-iteration batch (``microbatch * dp``), not the full plan or the
  hardware, so plans sharing a batch share one graph build per process
  (across hardware variants too).
* **Early infeasibility pruning** — per-tile memory is a property of the
  *mapped* graph, so the ``memory_cap`` check runs before the event-driven
  simulation and infeasible plans cost a mapping, not a full run.
* **One shared pool for hardware sweeps** — a hardware x plan sweep is a
  single flat job stream of ``(variant, plan)`` pairs evaluated by one
  process pool whose workers are initialized once with the pickled
  experiment and every variant spec, instead of spawning a fresh pool per
  hardware variant (see ``benchmarks/bench_sweep_engine.py`` for the
  speedup over the pool-per-variant baseline).

``return_timelines=True`` ships each run's event timeline back attached
to ``RunReport.trace`` (and the full :class:`SimResult` to ``.sim``).
The timeline crosses the pool in *columnar* form: :class:`Trace` pickles
through its compressed struct-of-arrays wire format
(``Trace.to_bytes``), which is several times smaller than the legacy
tuple-list ``SimResult`` payload (measured in
``benchmarks/bench_sweep_engine.py``). Reports stay scalar (and JSON
stays compact) by default.

Results are deterministic: the engine evaluates jobs in enumeration
order and ranks by simulated throughput, so serial and process-pool
sweeps produce identical SweepReports.
"""

from __future__ import annotations

import dataclasses
import os
import pickle
import warnings
from concurrent.futures import ProcessPoolExecutor
from typing import Dict, List, Optional, Sequence, Tuple

from ..core.enums import NoCMode
from ..core.hardware import HardwareSpec
from ..core.parallelism import ParallelPlan, map_graph
from ..core.scheduler import PipelineSimulator, plan_memory
from ..core.trace import (
    KIND_BD,
    KIND_CODES,
    KIND_DRAM,
    KIND_FABRIC,
    KIND_FD,
    KIND_GU,
    KIND_NAMES,
    KIND_NOC,
)
from .report import RunReport, SweepReport

__all__ = ["SweepEngine", "run_one"]

# outcome tags for one plan evaluation
_OK, _PRUNED, _FAILED = "ok", "pruned", "failed"

# a job is (hardware-variant index, plan) — or (variant, plan, fidelity)
# where fidelity is a reduced-cost evaluation knob (see
# :class:`repro.search.Fidelity`): anything with ``apply(plan)`` and a
# ``noc_mode`` attribute. Plain plan sweeps use variant index 0.
Job = Tuple[int, ParallelPlan]

# lane-drop priority when a trace payload budget is exceeded: resource
# lanes go first, FD/BD last (they carry the pipeline structure)
_LANE_DROP_ORDER = (KIND_FABRIC, KIND_DRAM, KIND_NOC, KIND_GU, KIND_BD, KIND_FD)

# cap on per-outcome diagnostic records kept in a SweepReport (counters
# stay exact; records exist so planners can explain representative
# failures, not to mirror the whole job stream)
_MAX_RECORDS = 128


def _plan_summary(plan: ParallelPlan) -> Dict:
    """Compact identity of a plan for pruned/failed diagnostics."""
    return {"pp": plan.pp, "dp": plan.dp, "tp": plan.tp,
            "microbatch": plan.microbatch}


def _lane_codes(lanes) -> Optional[Tuple[int, ...]]:
    """Normalize a lane filter (names or kind codes) to sorted codes."""
    if lanes is None:
        return None
    out = set()
    for lane in lanes:
        if isinstance(lane, str):
            if lane.upper() not in KIND_CODES:
                raise ValueError(f"unknown trace lane {lane!r}; known: "
                                 f"{', '.join(KIND_NAMES)}")
            out.add(KIND_CODES[lane.upper()])
        else:
            if not 0 <= int(lane) < len(KIND_NAMES):
                raise ValueError(f"unknown trace lane code {lane!r}")
            out.add(int(lane))
    return tuple(sorted(out))


def _apply_trace_policy(report: RunReport,
                        lanes: Optional[Tuple[int, ...]],
                        budget: Optional[int]) -> RunReport:
    """Lane-filter (and budget-bound) the trace a run ships back through
    the pool. Scalar digests were extracted before this runs, so reports
    keep exact bubble/occupancy numbers whatever lanes survive."""
    trace = report.trace
    if trace is None or (lanes is None and budget is None):
        return report
    present = {int(k) for k in trace.kind}
    keep = set(lanes) if lanes is not None else set(range(len(KIND_NAMES)))
    filtered = trace
    if lanes is not None and not present <= keep:
        filtered = trace.filter(kinds=sorted(keep))
    dropped: List[str] = []
    if budget is not None:
        for kind in _LANE_DROP_ORDER:
            if filtered.nbytes <= budget:
                break
            if kind in keep and kind in present:
                keep.discard(kind)
                dropped.append(KIND_NAMES[kind])
                filtered = filtered.filter(kinds=sorted(keep))
    if filtered is trace:
        return report
    report.trace = filtered
    if report.sim is not None:
        report.sim = dataclasses.replace(report.sim, trace=filtered)
    if dropped:
        report.extra["trace_lanes_dropped"] = dropped
    return report


def _evaluate(exp, plan: ParallelPlan, graph_cache: Dict,
              hw: HardwareSpec,
              return_timelines: bool = False,
              trace_resources: bool = False,
              fidelity=None,
              trace_lanes: Optional[Tuple[int, ...]] = None,
              trace_budget_bytes: Optional[int] = None) -> Tuple[str, object]:
    """Evaluate one (hardware, plan) job: build (memoized) graph, map,
    prune on memory, simulate. Returns (tag, RunReport | reason).

    ``fidelity`` optionally cheapens the simulation (coarser NoC model
    and/or fewer microbatches) for multi-fidelity search rungs; the graph
    memo is unaffected because the per-iteration batch
    (``microbatch * dp``) does not change.

    Memory-pruned jobs carry a diagnostic payload (peak/cap/deficit
    bytes) so planners can explain *why* nothing was feasible instead of
    raising a bare error; :meth:`SweepEngine.sweep_jobs` merges it with
    the job's plan/hardware identity into ``SweepReport.pruned_records``.

    With ``exp.serving`` set (a :class:`repro.serving.system.ServingSpec`)
    the job is scored by the traffic-driven serving simulator instead of
    one pipeline iteration: ``RunReport.throughput`` becomes the SLO
    *goodput* (requests meeting both SLOs per second), the full
    :class:`ServingReport` dict rides in ``extra["serving"]``, and the
    per-request trace ships back when timelines were requested. The
    pre-simulation memory pruning is unchanged."""
    try:
        noc_mode = exp.noc_mode
        engine = getattr(exp, "engine", "event")
        if fidelity is not None:
            plan = fidelity.apply(plan)
            if fidelity.noc_mode is not None:
                noc_mode = NoCMode(fidelity.noc_mode)
            if getattr(fidelity, "engine", None) is not None:
                engine = fidelity.engine
        if exp.graph_builder is None:
            # arch_to_graph depends only on (arch, seq_len, batch, mode) —
            # never on the hardware — so the memo is shared across variants
            key = plan.microbatch * plan.dp
            graph = graph_cache.get(key)
            if graph is None:
                graph = exp.build_graph(plan)
                graph_cache[key] = graph
        else:
            graph = exp.build_graph(plan)   # builder may depend on full plan
        mapped = map_graph(graph, hw, plan)
        mem_plan = None
        if exp.memory_cap is not None:
            mem_plan = plan_memory(mapped)
            peak = max(m.total for m in mem_plan[0])
            if peak > exp.memory_cap:
                return (_PRUNED, {"peak_bytes": peak,
                                  "cap_bytes": exp.memory_cap,
                                  "deficit_bytes": peak - exp.memory_cap})
        serving = getattr(exp, "serving", None)
        if serving is not None:
            from ..serving.system import ServingSimulator  # lazy: no cycle
            if fidelity is not None:
                serving = fidelity.apply_serving(serving)
            ssim = ServingSimulator(
                exp.arch_config, hw, plan, serving, noc_mode=noc_mode,
                boundary_mode=exp.boundary_mode,
                collect_trace=return_timelines or trace_resources)
            srep = ssim.run()
            report = RunReport(
                arch=exp.arch_name, hardware=hw.name, plan=plan,
                total_time=srep.sim_time, throughput=srep.goodput_rps,
                bubble_ratio=0.0,
                peak_memory_bytes=(max(m.total for m in mem_plan[0])
                                   if mem_plan is not None else 0.0),
                recompute=False,
                event_count=srep.steps.get("events", 0),
                noc_bytes=0.0, dram_bytes=0.0,
                extra={"serving": srep.to_dict()},
                trace=srep.trace if return_timelines else None)
            if return_timelines:
                report = _apply_trace_policy(report, trace_lanes,
                                             trace_budget_bytes)
            return (_OK, report)
        # compute lanes are always recorded; resource busy lanes stay off
        # unless the experiment asked for them (collect_timeline=True) so
        # default timeline sweeps keep pool payloads lean
        sim = PipelineSimulator(mapped, noc_mode=noc_mode,
                                boundary_mode=exp.boundary_mode,
                                memory_plan=mem_plan,
                                collect_timeline=trace_resources,
                                engine=engine)
        result = sim.run()
        # the scalar occupancy digest is an in-process convenience; drop
        # it so serial and pooled sweeps return identical, lean results
        result.noc_occupancy_fallback.clear()
    except (ValueError, KeyError, TypeError) as e:
        return (_FAILED, f"{type(e).__name__}: {e}")
    report = RunReport.from_sim(exp.arch_name, hw.name, plan, result,
                                keep_sim=return_timelines)
    if return_timelines:
        report = _apply_trace_policy(report, trace_lanes, trace_budget_bytes)
    return (_OK, report)


def run_one(exp, plan: ParallelPlan) -> RunReport:
    """Simulate one fixed plan (Experiment.run body)."""
    graph = exp.build_graph(plan)
    hw = exp.hardware_spec
    mapped = map_graph(graph, hw, plan)
    sim = PipelineSimulator(mapped, noc_mode=exp.noc_mode,
                            boundary_mode=exp.boundary_mode,
                            collect_timeline=exp.collect_timeline,
                            engine=getattr(exp, "engine", "event"))
    return RunReport.from_sim(exp.arch_name, hw.name, plan, sim.run(),
                              keep_sim=exp.collect_timeline)


# -- process-pool plumbing ---------------------------------------------------
# The Experiment and every hardware-variant spec are shipped once per
# worker (initializer) instead of once per task; each worker keeps its own
# per-variant graph memo across tasks.
_WORKER: Dict = {}


def _init_worker(exp_bytes: bytes, specs_bytes: bytes,
                 return_timelines: bool, trace_resources: bool,
                 trace_lanes: Optional[Tuple[int, ...]] = None,
                 trace_budget_bytes: Optional[int] = None) -> None:
    _WORKER["exp"] = pickle.loads(exp_bytes)
    _WORKER["specs"] = pickle.loads(specs_bytes)
    _WORKER["graphs"] = {}
    _WORKER["return_timelines"] = return_timelines
    _WORKER["trace_resources"] = trace_resources
    _WORKER["trace_lanes"] = trace_lanes
    _WORKER["trace_budget_bytes"] = trace_budget_bytes


def _eval_in_worker(job) -> Tuple[str, object]:
    variant, plan, fidelity = job if len(job) == 3 else (*job, None)
    return _evaluate(_WORKER["exp"], plan, _WORKER["graphs"],
                     hw=_WORKER["specs"][variant],
                     return_timelines=_WORKER["return_timelines"],
                     trace_resources=_WORKER["trace_resources"],
                     fidelity=fidelity,
                     trace_lanes=_WORKER["trace_lanes"],
                     trace_budget_bytes=_WORKER["trace_budget_bytes"])


class SweepEngine:
    """Executes a plan sweep — or a merged hardware x plan sweep — for an
    Experiment.

    ``workers=0`` (default) runs serially in-process; ``workers=N`` uses an
    N-process pool; ``workers=None`` uses one process per CPU.
    ``return_timelines=True`` attaches each run's columnar event timeline
    to ``RunReport.trace`` (and the :class:`SimResult` to ``.sim``);
    timelines cross the pool in compressed columnar form.
    ``trace_resources=True`` (``Experiment.collect_timeline``) further
    records NoC-link / DRAM-channel busy intervals into those traces —
    richer, but a bigger pool payload.

    ``trace_lanes`` restricts the lanes shipped back (names like
    ``("FD", "BD", "NOC")`` or kind codes), and ``trace_budget_bytes``
    bounds the worst-case per-run columnar payload: lanes are dropped
    in the fixed priority DRAM, NOC, GU, BD, FD until the trace fits
    (dropped lanes are recorded in ``RunReport.extra``). Report scalars
    (bubble ratio, occupancies) are computed *before* filtering, so they
    are exact regardless of what ships.

    Used as a context manager the engine keeps one process pool alive
    across ``sweep``/``sweep_jobs``/``evaluate_jobs`` calls (workers stay
    warm across search generations); otherwise each call owns its pool.
    """

    def __init__(self, workers: Optional[int] = 0,
                 return_timelines: bool = False,
                 trace_resources: bool = False,
                 trace_lanes: Optional[Sequence] = None,
                 trace_budget_bytes: Optional[int] = None):
        self.workers = os.cpu_count() if workers is None else workers
        self.return_timelines = return_timelines
        self.trace_resources = trace_resources
        self.trace_lanes = _lane_codes(trace_lanes)
        self.trace_budget_bytes = trace_budget_bytes
        self._persist = False
        self._pool: Optional[ProcessPoolExecutor] = None
        self._pool_key: Optional[Tuple[bytes, bytes]] = None
        # how many process pools this engine has created (tests assert a
        # persistent engine initializes exactly once across planner calls)
        self.pool_inits = 0
        # serial-path graph memo kept warm across calls in persistent mode
        self._memo_exp = None
        self._memo_graphs: Dict = {}

    # -- persistent-pool lifecycle ------------------------------------------
    def __enter__(self) -> "SweepEngine":
        self._persist = True
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    def close(self) -> None:
        """Shut the persistent pool down (no-op outside a with-block)."""
        self._shutdown_pool()
        self._persist = False
        self._memo_exp = None
        self._memo_graphs = {}

    def _serial_memo(self, exp) -> Dict:
        """Graph memo for the serial path: per-call normally, kept warm
        across calls (per experiment) in persistent mode."""
        if not self._persist:
            return {}
        if self._memo_exp is not exp:
            self._memo_exp, self._memo_graphs = exp, {}
        return self._memo_graphs

    def _shutdown_pool(self) -> None:
        if self._pool is not None:
            self._pool.shutdown()
            self._pool = None
            self._pool_key = None

    def sweep(self, exp, plans: Sequence[ParallelPlan]) -> SweepReport:
        """Plan sweep on the experiment's single hardware spec."""
        hw = exp.hardware_spec
        return self.sweep_jobs(exp, [hw], [(0, p) for p in plans],
                               hardware_name=hw.name)

    def sweep_jobs(self, exp, specs: Sequence[HardwareSpec],
                   jobs: Sequence[Job], *, hardware_name: str,
                   num_hardware: int = 1,
                   extra_failed: int = 0) -> SweepReport:
        """Evaluate a flat ``(variant index, plan)`` job stream against the
        given hardware variants through one shared executor and return the
        merged ranked report. ``extra_failed`` accounts for variants that
        failed before any job was enumerated (e.g. too few devices)."""
        specs, jobs = list(specs), list(jobs)
        outcomes, executor = self.evaluate_jobs(exp, specs, jobs)

        runs: List[RunReport] = []
        pruned = failed = 0
        pruned_records: List[Dict] = []
        failed_records: List[Dict] = []
        for job, (tag, payload) in zip(jobs, outcomes):
            if tag == _OK:
                runs.append(payload)
                continue
            variant, plan = job[0], job[1]
            record = {"plan": _plan_summary(plan),
                      "hardware": specs[variant].name}
            if tag == _PRUNED:
                pruned += 1
                if isinstance(payload, dict):
                    record.update(payload)
                if len(pruned_records) < _MAX_RECORDS:
                    pruned_records.append(record)
            else:
                failed += 1
                record["reason"] = payload
                if len(failed_records) < _MAX_RECORDS:
                    failed_records.append(record)
        runs.sort(key=lambda r: -r.throughput)
        return SweepReport(
            arch=exp.arch_name,
            hardware=hardware_name,
            runs=runs,
            num_candidates=len(jobs),
            num_pruned_memory=pruned,
            num_failed=failed + extra_failed,
            executor=executor,
            num_hardware=num_hardware,
            pruned_records=pruned_records,
            failed_records=failed_records,
        )

    def evaluate_jobs(self, exp, specs: Sequence[HardwareSpec],
                      jobs: Sequence[Job]) -> Tuple[List[Tuple[str, object]], str]:
        """Raw evaluation of a job stream: ``(tag, payload)`` outcomes in
        job order plus the executor label. Jobs may carry a per-job
        fidelity as a third element (multi-fidelity search rungs)."""
        jobs = list(jobs)
        # a 1-job batch is cheaper in-process — unless a persistent pool
        # exists (or will): search generations can shrink to one candidate
        # and must keep hitting the warm workers
        if self.workers >= 2 and (len(jobs) > 1 or self._persist):
            try:
                exp_bytes = pickle.dumps(exp)
                specs_bytes = pickle.dumps(list(specs))
            except Exception as e:   # e.g. lambda graph_builder
                warnings.warn(
                    f"experiment not picklable ({e}); sweeping serially",
                    RuntimeWarning, stacklevel=3)
            else:
                initargs = (exp_bytes, specs_bytes, self.return_timelines,
                            self.trace_resources, self.trace_lanes,
                            self.trace_budget_bytes)
                if self._persist:
                    key = (exp_bytes, specs_bytes)
                    if self._pool is None or self._pool_key != key:
                        self._shutdown_pool()
                        self._pool = ProcessPoolExecutor(
                            max_workers=self.workers,
                            initializer=_init_worker, initargs=initargs)
                        self._pool_key = key
                        self.pool_inits += 1
                    return (list(self._pool.map(_eval_in_worker, jobs)),
                            f"process[{self.workers}]")
                n = min(self.workers, len(jobs))
                self.pool_inits += 1
                with ProcessPoolExecutor(
                        max_workers=n,
                        initializer=_init_worker,
                        initargs=initargs) as pool:
                    return list(pool.map(_eval_in_worker, jobs)), f"process[{n}]"
        graphs = self._serial_memo(exp)
        out = []
        for job in jobs:
            variant, plan, fidelity = job if len(job) == 3 else (*job, None)
            out.append(_evaluate(exp, plan, graphs, hw=specs[variant],
                                 return_timelines=self.return_timelines,
                                 trace_resources=self.trace_resources,
                                 fidelity=fidelity,
                                 trace_lanes=self.trace_lanes,
                                 trace_budget_bytes=self.trace_budget_bytes))
        return out, "serial"
