"""Parallel sweep engine for Experiment plan and hardware x plan searches.

Executes sweeps through a ``concurrent.futures`` process pool (or
serially with ``workers=0``) with four structural optimizations over the
legacy ``sweep_plans`` loop:

* **Graph-construction memoization** — the workload graph depends only on
  the per-iteration batch (``microbatch * dp``), not the full plan or the
  hardware, so plans sharing a batch share one graph build per process
  (across hardware variants too).
* **Early infeasibility pruning** — per-tile memory is a property of the
  *mapped* graph, so the ``memory_cap`` check runs before the event-driven
  simulation and infeasible plans cost a mapping, not a full run.
* **One shared pool for hardware sweeps** — a hardware x plan sweep is a
  single flat job stream of ``(variant, plan)`` pairs evaluated by one
  process pool whose workers are initialized once with the pickled
  experiment and every variant spec, instead of spawning a fresh pool per
  hardware variant (see ``benchmarks/bench_sweep_engine.py`` for the
  speedup over the pool-per-variant baseline).
* **Batched fast tier** — fast-path-eligible jobs (``engine`` ``"auto"``
  or ``"fast"``) are collected and priced through
  :func:`repro.core.fastbatch.run_fast_batch`, which groups
  configurations by chain *shape signature* and replays whole groups in
  vectorized numpy passes instead of one Python chain walk per job.
  Results are bit-identical to the per-job tiers; jobs the batch rejects
  (contention, ineligibility) fall back to the per-job path one at a
  time. Workers receive contiguous job *shards* so each worker batches
  its share instead of evaluating job-at-a-time streams.

``return_timelines=True`` ships each run's event timeline back attached
to ``RunReport.trace`` (and the full :class:`SimResult` to ``.sim``).
The timeline crosses the pool in *columnar* form: :class:`Trace` pickles
through its compressed struct-of-arrays wire format
(``Trace.to_bytes``), which is several times smaller than the legacy
tuple-list ``SimResult`` payload (measured in
``benchmarks/bench_sweep_engine.py``). Reports stay scalar (and JSON
stays compact) by default.

Results are deterministic: the engine evaluates jobs in enumeration
order and ranks by :func:`~repro.api.report.run_rank_key` (throughput,
then canonical hardware/plan identity), so serial, process-pool and
batched sweeps produce identical SweepReports.

:func:`shared_engine` hands out module-level *persistent* engines (one
per flag combination) whose process pools and memos stay warm across
planner calls — ``plan_parallelism`` / ``plan_codesign`` /
``plan_serving`` and the CLI all reuse them, so back-to-back planning
questions about the same experiment stop re-pickling and re-classifying
from scratch.
"""

from __future__ import annotations

import atexit
import dataclasses
import os
import pickle
import warnings
from concurrent.futures import ProcessPoolExecutor
from time import perf_counter
from typing import Dict, List, Optional, Sequence, Tuple

from ..core.enums import NoCMode
from ..core.fastbatch import run_fast_batch
from ..core.fastpath import reason_code
from ..core.hardware import HardwareSpec
from ..core.parallelism import ParallelPlan, map_graph
from ..core.scheduler import PipelineSimulator, plan_memory
from ..obs.registry import NULL_REGISTRY, make_registry
from ..core.trace import (
    KIND_BD,
    KIND_CODES,
    KIND_DRAM,
    KIND_FABRIC,
    KIND_FD,
    KIND_GU,
    KIND_NAMES,
    KIND_NOC,
)
from .report import RunReport, SweepReport, run_rank_key

__all__ = ["SweepEngine", "run_one", "shared_engine", "close_shared_engines"]

# outcome tags for one plan evaluation
_OK, _PRUNED, _FAILED = "ok", "pruned", "failed"

# a job is (hardware-variant index, plan) — or (variant, plan, fidelity)
# where fidelity is a reduced-cost evaluation knob (see
# :class:`repro.search.Fidelity`): anything with ``apply(plan)`` and a
# ``noc_mode`` attribute. Plain plan sweeps use variant index 0.
Job = Tuple[int, ParallelPlan]

# lane-drop priority when a trace payload budget is exceeded: resource
# lanes go first, FD/BD last (they carry the pipeline structure)
_LANE_DROP_ORDER = (KIND_FABRIC, KIND_DRAM, KIND_NOC, KIND_GU, KIND_BD, KIND_FD)

# cap on per-outcome diagnostic records kept in a SweepReport (counters
# stay exact; records exist so planners can explain representative
# failures, not to mirror the whole job stream)
_MAX_RECORDS = 128


def _plan_summary(plan: ParallelPlan) -> Dict:
    """Compact identity of a plan for pruned/failed diagnostics."""
    return {"pp": plan.pp, "dp": plan.dp, "tp": plan.tp,
            "microbatch": plan.microbatch}


def _lane_codes(lanes) -> Optional[Tuple[int, ...]]:
    """Normalize a lane filter (names or kind codes) to sorted codes."""
    if lanes is None:
        return None
    out = set()
    for lane in lanes:
        if isinstance(lane, str):
            if lane.upper() not in KIND_CODES:
                raise ValueError(f"unknown trace lane {lane!r}; known: "
                                 f"{', '.join(KIND_NAMES)}")
            out.add(KIND_CODES[lane.upper()])
        else:
            if not 0 <= int(lane) < len(KIND_NAMES):
                raise ValueError(f"unknown trace lane code {lane!r}")
            out.add(int(lane))
    return tuple(sorted(out))


def _apply_trace_policy(report: RunReport,
                        lanes: Optional[Tuple[int, ...]],
                        budget: Optional[int]) -> RunReport:
    """Lane-filter (and budget-bound) the trace a run ships back through
    the pool. Scalar digests were extracted before this runs, so reports
    keep exact bubble/occupancy numbers whatever lanes survive."""
    trace = report.trace
    if trace is None or (lanes is None and budget is None):
        return report
    present = {int(k) for k in trace.kind}
    keep = set(lanes) if lanes is not None else set(range(len(KIND_NAMES)))
    filtered = trace
    if lanes is not None and not present <= keep:
        filtered = trace.filter(kinds=sorted(keep))
    dropped: List[str] = []
    if budget is not None:
        for kind in _LANE_DROP_ORDER:
            if filtered.nbytes <= budget:
                break
            if kind in keep and kind in present:
                keep.discard(kind)
                dropped.append(KIND_NAMES[kind])
                filtered = filtered.filter(kinds=sorted(keep))
    if filtered is trace:
        return report
    report.trace = filtered
    if report.sim is not None:
        report.sim = dataclasses.replace(report.sim, trace=filtered)
    if dropped:
        report.extra["trace_lanes_dropped"] = dropped
    return report


def _prepare(exp, plan: ParallelPlan, graph_cache: Dict, hw: HardwareSpec,
             return_timelines: bool = False,
             trace_resources: bool = False,
             fidelity=None,
             trace_lanes: Optional[Tuple[int, ...]] = None,
             trace_budget_bytes: Optional[int] = None,
             registry=NULL_REGISTRY):
    """First half of one (hardware, plan) evaluation: resolve fidelity,
    build the (memoized) graph, map, prune on memory — and either settle
    the outcome without a pipeline run or hand back a constructed, unrun
    simulator.

    Returns ``("done", (tag, payload))`` when the job is decided here
    (serving jobs, memory-pruned jobs, mapping failures) or
    ``("sim", (sim, plan, engine))`` when a pipeline simulation remains.
    The split exists so :func:`_evaluate_many` can collect the
    simulators of a whole job stream and price them through the batched
    fast tier (:mod:`repro.core.fastbatch`) instead of one at a time.

    ``fidelity`` optionally cheapens the simulation (coarser NoC model,
    fewer microbatches and/or a cheaper simulator tier) for
    multi-fidelity search rungs; the graph memo is unaffected because
    the per-iteration batch (``microbatch * dp``) does not change.

    Memory-pruned jobs carry a diagnostic payload (peak/cap/deficit
    bytes) so planners can explain *why* nothing was feasible instead of
    raising a bare error; :meth:`SweepEngine.sweep_jobs` merges it with
    the job's plan/hardware identity into ``SweepReport.pruned_records``.

    With ``exp.serving`` set (a :class:`repro.serving.system.ServingSpec`)
    the job is scored by the traffic-driven serving simulator instead of
    one pipeline iteration: ``RunReport.throughput`` becomes the SLO
    *goodput* (requests meeting both SLOs per second), the full
    :class:`ServingReport` dict rides in ``extra["serving"]``, and the
    per-request trace ships back when timelines were requested. The
    pre-simulation memory pruning is unchanged."""
    try:
        noc_mode = exp.noc_mode
        engine = getattr(exp, "engine", "event")
        if fidelity is not None:
            resolve = getattr(fidelity, "resolve", None)
            if resolve is not None:
                plan, noc_mode, engine = resolve(plan, noc_mode, engine)
            else:   # duck-typed fidelity: apply() + optional knobs
                plan = fidelity.apply(plan)
                if fidelity.noc_mode is not None:
                    noc_mode = NoCMode(fidelity.noc_mode)
                if getattr(fidelity, "engine", None) is not None:
                    engine = fidelity.engine
        if exp.graph_builder is None:
            # arch_to_graph depends only on (arch, seq_len, batch, mode) —
            # never on the hardware — so the memo is shared across variants
            key = plan.microbatch * plan.dp
            graph = graph_cache.get(key)
            if graph is None:
                registry.counter("host.sweep.graph_memo.misses").inc()
                graph = exp.build_graph(plan)
                graph_cache[key] = graph
            else:
                registry.counter("host.sweep.graph_memo.hits").inc()
        else:
            graph = exp.build_graph(plan)   # builder may depend on full plan
        mapped = map_graph(graph, hw, plan)
        mem_plan = None
        if exp.memory_cap is not None:
            mem_plan = plan_memory(mapped)
            peak = max(m.total for m in mem_plan[0])
            if peak > exp.memory_cap:
                return ("done", (_PRUNED, {"peak_bytes": peak,
                                           "cap_bytes": exp.memory_cap,
                                           "deficit_bytes":
                                               peak - exp.memory_cap}))
        serving = getattr(exp, "serving", None)
        if serving is not None:
            from ..serving.system import ServingSimulator  # lazy: no cycle
            if fidelity is not None:
                serving = fidelity.apply_serving(serving)
            ssim = ServingSimulator(
                exp.arch_config, hw, plan, serving, noc_mode=noc_mode,
                boundary_mode=exp.boundary_mode,
                collect_trace=return_timelines or trace_resources,
                metrics=bool(getattr(exp, "metrics", False)))
            srep = ssim.run()
            report = RunReport(
                arch=exp.arch_name, hardware=hw.name, plan=plan,
                total_time=srep.sim_time, throughput=srep.goodput_rps,
                bubble_ratio=0.0,
                peak_memory_bytes=(max(m.total for m in mem_plan[0])
                                   if mem_plan is not None else 0.0),
                recompute=False,
                event_count=srep.steps.get("events", 0),
                noc_bytes=0.0, dram_bytes=0.0,
                extra={"serving": srep.to_dict()},
                trace=srep.trace if return_timelines else None,
                metrics=getattr(srep, "metrics", None))
            if return_timelines:
                report = _apply_trace_policy(report, trace_lanes,
                                             trace_budget_bytes)
            return ("done", (_OK, report))
        # compute lanes are always recorded; resource busy lanes stay off
        # unless the experiment asked for them (collect_timeline=True) so
        # default timeline sweeps keep pool payloads lean
        sim = PipelineSimulator(mapped, noc_mode=noc_mode,
                                boundary_mode=exp.boundary_mode,
                                memory_plan=mem_plan,
                                collect_timeline=trace_resources,
                                engine=engine,
                                metrics=bool(getattr(exp, "metrics", False)))
    except (ValueError, KeyError, TypeError) as e:
        return ("done", (_FAILED, f"{type(e).__name__}: {e}"))
    return ("sim", (sim, plan, engine))


def _finish(exp, plan: ParallelPlan, hw: HardwareSpec, result,
            return_timelines: bool,
            trace_lanes: Optional[Tuple[int, ...]],
            trace_budget_bytes: Optional[int]) -> Tuple[str, object]:
    """Second half of one evaluation: wrap a SimResult into the ranked
    RunReport (and apply the trace shipping policy)."""
    report = RunReport.from_sim(exp.arch_name, hw.name, plan, result,
                                keep_sim=return_timelines)
    if return_timelines:
        report = _apply_trace_policy(report, trace_lanes, trace_budget_bytes)
    return (_OK, report)


def _run_and_finish(exp, plan: ParallelPlan, hw: HardwareSpec, sim,
                    return_timelines: bool,
                    trace_lanes: Optional[Tuple[int, ...]],
                    trace_budget_bytes: Optional[int]) -> Tuple[str, object]:
    """Per-job simulation path (also the fallback for jobs the batched
    fast tier rejects): run the simulator's own tier dispatch and report.
    ``FastPathIneligible`` (engine="fast" strict mode) propagates."""
    try:
        result = sim.run()
        # the scalar occupancy digest is an in-process convenience; drop
        # it so serial and pooled sweeps return identical, lean results
        result.noc_occupancy_fallback.clear()
    except (ValueError, KeyError, TypeError) as e:
        return (_FAILED, f"{type(e).__name__}: {e}")
    return _finish(exp, plan, hw, result, return_timelines, trace_lanes,
                   trace_budget_bytes)


def _evaluate(exp, plan: ParallelPlan, graph_cache: Dict,
              hw: HardwareSpec,
              return_timelines: bool = False,
              trace_resources: bool = False,
              fidelity=None,
              trace_lanes: Optional[Tuple[int, ...]] = None,
              trace_budget_bytes: Optional[int] = None) -> Tuple[str, object]:
    """Evaluate one (hardware, plan) job: build (memoized) graph, map,
    prune on memory, simulate. Returns (tag, RunReport | reason).
    Composition of :func:`_prepare` and :func:`_run_and_finish`."""
    kind, payload = _prepare(exp, plan, graph_cache, hw,
                             return_timelines=return_timelines,
                             trace_resources=trace_resources,
                             fidelity=fidelity,
                             trace_lanes=trace_lanes,
                             trace_budget_bytes=trace_budget_bytes)
    if kind == "done":
        return payload
    sim, plan, _engine = payload
    return _run_and_finish(exp, plan, hw, sim, return_timelines,
                           trace_lanes, trace_budget_bytes)


def _evaluate_many(exp, specs: Sequence[HardwareSpec], jobs: Sequence,
                   graph_cache: Dict, *,
                   return_timelines: bool = False,
                   trace_resources: bool = False,
                   trace_lanes: Optional[Tuple[int, ...]] = None,
                   trace_budget_bytes: Optional[int] = None,
                   batch_fastpath: bool = True,
                   classify_memo: Optional[Dict] = None,
                   profile: Optional[Dict] = None,
                   registry=NULL_REGISTRY) -> List[Tuple[str, object]]:
    """Evaluate a job stream with the batched fast tier.

    Every job is prepared (graph/map/prune) in enumeration order; jobs
    whose engine admits the fast tier (``"auto"``/``"fast"``) are
    collected and priced together through
    :func:`repro.core.fastbatch.run_fast_batch`, the rest run the
    per-job path inline. Batch-rejected jobs (contended, ineligible)
    fall back to the per-job path one at a time — for ``engine="auto"``
    that lands in the event kernel, for strict ``engine="fast"`` it
    re-raises ``FastPathIneligible`` exactly like the scalar tier.
    Outcomes come back in job order and are bitwise what the per-job
    loop would have produced."""
    outcomes: List = [None] * len(jobs)
    batch: List[Tuple[int, object, ParallelPlan, HardwareSpec]] = []
    for i, job in enumerate(jobs):
        variant, plan, fidelity = job if len(job) == 3 else (*job, None)
        hw = specs[variant]
        kind, payload = _prepare(exp, plan, graph_cache, hw,
                                 return_timelines=return_timelines,
                                 trace_resources=trace_resources,
                                 fidelity=fidelity,
                                 trace_lanes=trace_lanes,
                                 trace_budget_bytes=trace_budget_bytes,
                                 registry=registry)
        if kind == "done":
            outcomes[i] = payload
            continue
        sim, plan, engine = payload
        if batch_fastpath and engine in ("auto", "fast"):
            batch.append((i, sim, plan, hw))
        else:
            outcomes[i] = _run_and_finish(exp, plan, hw, sim,
                                          return_timelines, trace_lanes,
                                          trace_budget_bytes)
            reason = getattr(sim, "fastpath_reason", None)
            if reason is not None:
                registry.counter(
                    "host.fastpath.reject." + reason_code(reason)).inc()
    if batch:
        try:
            results = run_fast_batch([sim for _, sim, _, _ in batch],
                                     classify_memo=classify_memo,
                                     profile=profile)
        except (ValueError, KeyError, TypeError):
            # batch compilation tripped on one config; re-run every job
            # through the per-job path, which scopes the error to the
            # config that raised it (exact scalar semantics)
            results = [(None, "batch compilation failed")] * len(batch)
        for (i, sim, plan, hw), (result, _reason) in zip(batch, results):
            if result is not None:
                if sim.metrics:
                    # the batched tier bypasses sim.run(), so attach the
                    # metrics document here (same derivation either way)
                    from ..obs.simmetrics import run_metrics
                    result.metrics = run_metrics(sim, result)
                outcomes[i] = _finish(exp, plan, hw, result,
                                      return_timelines, trace_lanes,
                                      trace_budget_bytes)
                continue
            # per-job retry: its own fast attempt re-derives the rejection
            # reason (or succeeds, e.g. after a batch compilation failure),
            # so the machine-readable cause reflects the final outcome
            t0 = perf_counter()
            outcomes[i] = _run_and_finish(exp, plan, hw, sim,
                                          return_timelines, trace_lanes,
                                          trace_budget_bytes)
            reason = getattr(sim, "fastpath_reason", None)
            if reason is not None:
                registry.counter(
                    "host.fastpath.reject." + reason_code(reason)).inc()
            if profile is not None:
                profile["fallback_us"] = (profile.get("fallback_us", 0)
                                          + int((perf_counter() - t0) * 1e6))
                profile["fallback_jobs"] = profile.get("fallback_jobs", 0) + 1
    if registry:
        registry.counter("host.sweep.jobs").inc(len(jobs))
        for outcome in outcomes:
            tag, payload = outcome
            if tag == _OK:
                registry.counter("host.sweep.engine."
                                 + payload.extra.get("engine", "event")).inc()
            elif tag == _PRUNED:
                registry.counter("host.sweep.pruned").inc()
            else:
                registry.counter("host.sweep.failed").inc()
    return outcomes


def run_one(exp, plan: ParallelPlan) -> RunReport:
    """Simulate one fixed plan (Experiment.run body)."""
    graph = exp.build_graph(plan)
    hw = exp.hardware_spec
    mapped = map_graph(graph, hw, plan)
    sim = PipelineSimulator(mapped, noc_mode=exp.noc_mode,
                            boundary_mode=exp.boundary_mode,
                            collect_timeline=exp.collect_timeline,
                            engine=getattr(exp, "engine", "event"),
                            metrics=bool(getattr(exp, "metrics", False)))
    return RunReport.from_sim(exp.arch_name, hw.name, plan, sim.run(),
                              keep_sim=exp.collect_timeline)


def _merge_profile(dst: Dict, src: Dict) -> None:
    for k, v in src.items():
        dst[k] = dst.get(k, 0) + v


def _shards(jobs: List, n: int) -> List[List]:
    """Split a job stream into <= n contiguous, near-equal shards (in
    order, no empties) so pooled workers batch their share of the stream
    instead of receiving it job-at-a-time."""
    n = max(1, min(n, len(jobs)))
    size, extra = divmod(len(jobs), n)
    out, i = [], 0
    for j in range(n):
        step = size + (1 if j < extra else 0)
        if step:
            out.append(jobs[i:i + step])
        i += step
    return out


# -- process-pool plumbing ---------------------------------------------------
# The Experiment and every hardware-variant spec are shipped once per
# worker (initializer) instead of once per task; each worker keeps its own
# per-variant graph memo and classifier memo across tasks.
_WORKER: Dict = {}


def _init_worker(exp_bytes: bytes, specs_bytes: bytes,
                 return_timelines: bool, trace_resources: bool,
                 trace_lanes: Optional[Tuple[int, ...]] = None,
                 trace_budget_bytes: Optional[int] = None,
                 batch_fastpath: bool = True) -> None:
    _WORKER["exp"] = pickle.loads(exp_bytes)
    _WORKER["specs"] = pickle.loads(specs_bytes)
    _WORKER["graphs"] = {}
    _WORKER["classify"] = {}
    _WORKER["return_timelines"] = return_timelines
    _WORKER["trace_resources"] = trace_resources
    _WORKER["trace_lanes"] = trace_lanes
    _WORKER["trace_budget_bytes"] = trace_budget_bytes
    _WORKER["batch_fastpath"] = batch_fastpath


def _eval_shard_in_worker(shard) -> Tuple[List[Tuple[str, object]], Dict, Dict]:
    """Evaluate one contiguous job shard in a pool worker; returns the
    shard's outcomes plus its fast-tier profile delta and host-metrics
    registry document for merging in the parent."""
    exp = _WORKER["exp"]
    profile: Dict = {}
    registry = make_registry(bool(getattr(exp, "metrics", False)))
    with registry.span("host.pool.shard"):
        outcomes = _evaluate_many(
            exp, _WORKER["specs"], shard, _WORKER["graphs"],
            return_timelines=_WORKER["return_timelines"],
            trace_resources=_WORKER["trace_resources"],
            trace_lanes=_WORKER["trace_lanes"],
            trace_budget_bytes=_WORKER["trace_budget_bytes"],
            batch_fastpath=_WORKER["batch_fastpath"],
            classify_memo=_WORKER["classify"],
            profile=profile,
            registry=registry)
    return outcomes, profile, registry.to_dict()


class SweepEngine:
    """Executes a plan sweep — or a merged hardware x plan sweep — for an
    Experiment.

    ``workers=0`` (default) runs serially in-process; ``workers=N`` uses an
    N-process pool; ``workers=None`` uses one process per CPU.
    ``return_timelines=True`` attaches each run's columnar event timeline
    to ``RunReport.trace`` (and the :class:`SimResult` to ``.sim``);
    timelines cross the pool in compressed columnar form.
    ``trace_resources=True`` (``Experiment.collect_timeline``) further
    records NoC-link / DRAM-channel busy intervals into those traces —
    richer, but a bigger pool payload.

    ``trace_lanes`` restricts the lanes shipped back (names like
    ``("FD", "BD", "NOC")`` or kind codes), and ``trace_budget_bytes``
    bounds the worst-case per-run columnar payload: lanes are dropped
    in the fixed priority DRAM, NOC, GU, BD, FD until the trace fits
    (dropped lanes are recorded in ``RunReport.extra``). Report scalars
    (bubble ratio, occupancies) are computed *before* filtering, so they
    are exact regardless of what ships.

    ``batch_fastpath`` (default on) routes fast-tier-eligible jobs
    through the vectorized batched evaluator
    (:mod:`repro.core.fastbatch`) — bit-identical results, one numpy
    pass per chain-shape group instead of one Python replay per job.
    ``profile=True`` attaches the per-phase accounting
    (compile/batch-eval/validate/fallback microseconds and job counters)
    of each call to its ``SweepReport.profile``; the cumulative totals
    are always kept on ``engine.profile_totals``.

    Used as a context manager the engine keeps one process pool alive
    across ``sweep``/``sweep_jobs``/``evaluate_jobs`` calls (workers stay
    warm across search generations); otherwise each call owns its pool.
    :func:`shared_engine` maintains module-level persistent engines for
    reuse across planner calls.
    """

    def __init__(self, workers: Optional[int] = 0,
                 return_timelines: bool = False,
                 trace_resources: bool = False,
                 trace_lanes: Optional[Sequence] = None,
                 trace_budget_bytes: Optional[int] = None,
                 batch_fastpath: bool = True,
                 profile: bool = False):
        self.workers = os.cpu_count() if workers is None else workers
        self.return_timelines = return_timelines
        self.trace_resources = trace_resources
        self.trace_lanes = _lane_codes(trace_lanes)
        self.trace_budget_bytes = trace_budget_bytes
        self.batch_fastpath = batch_fastpath
        self.profile = profile
        # cumulative per-phase fast-tier accounting across calls; the
        # per-call delta lands on each SweepReport when profile=True
        self.profile_totals: Dict[str, int] = {}
        self.last_profile: Dict[str, int] = {}
        # merged host-domain registry document of the last evaluate_jobs
        # call (parent + every pool shard); None when the experiment did
        # not enable metrics
        self.last_metrics: Optional[Dict] = None
        self._persist = False
        self._pool: Optional[ProcessPoolExecutor] = None
        self._pool_key: Optional[Tuple[bytes, bytes]] = None
        # how many process pools this engine has created (tests assert a
        # persistent engine initializes exactly once across planner calls)
        self.pool_inits = 0
        # serial-path graph + classifier memos kept warm across calls in
        # persistent mode
        self._memo_exp = None
        self._memo_graphs: Dict = {}
        self._memo_classify: Dict = {}

    # -- persistent-pool lifecycle ------------------------------------------
    def __enter__(self) -> "SweepEngine":
        self._persist = True
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    def close(self) -> None:
        """Shut the persistent pool down (no-op outside a with-block)."""
        self._shutdown_pool()
        self._persist = False
        self._memo_exp = None
        self._memo_graphs = {}
        self._memo_classify = {}

    def _serial_memo(self, exp) -> Tuple[Dict, Dict]:
        """(graph memo, classifier memo) for the serial path: per-call
        normally, kept warm across calls (per experiment) in persistent
        mode. Both are scoped to one experiment — classifier keys are
        (hardware name, plan summary), unique within an experiment's
        variants but not across experiments."""
        if not self._persist:
            return {}, {}
        if self._memo_exp is not exp:
            self._memo_exp = exp
            self._memo_graphs, self._memo_classify = {}, {}
        return self._memo_graphs, self._memo_classify

    def _shutdown_pool(self) -> None:
        if self._pool is not None:
            self._pool.shutdown()
            self._pool = None
            self._pool_key = None

    def sweep(self, exp, plans: Sequence[ParallelPlan]) -> SweepReport:
        """Plan sweep on the experiment's single hardware spec."""
        hw = exp.hardware_spec
        return self.sweep_jobs(exp, [hw], [(0, p) for p in plans],
                               hardware_name=hw.name)

    def sweep_jobs(self, exp, specs: Sequence[HardwareSpec],
                   jobs: Sequence[Job], *, hardware_name: str,
                   num_hardware: int = 1,
                   extra_failed: int = 0) -> SweepReport:
        """Evaluate a flat ``(variant index, plan)`` job stream against the
        given hardware variants through one shared executor and return the
        merged ranked report. ``extra_failed`` accounts for variants that
        failed before any job was enumerated (e.g. too few devices)."""
        specs, jobs = list(specs), list(jobs)
        outcomes, executor = self.evaluate_jobs(exp, specs, jobs)

        runs: List[RunReport] = []
        pruned = failed = 0
        pruned_records: List[Dict] = []
        failed_records: List[Dict] = []
        for job, (tag, payload) in zip(jobs, outcomes):
            if tag == _OK:
                runs.append(payload)
                continue
            variant, plan = job[0], job[1]
            record = {"plan": _plan_summary(plan),
                      "hardware": specs[variant].name}
            if tag == _PRUNED:
                pruned += 1
                if isinstance(payload, dict):
                    record.update(payload)
                if len(pruned_records) < _MAX_RECORDS:
                    pruned_records.append(record)
            else:
                failed += 1
                record["reason"] = payload
                if len(failed_records) < _MAX_RECORDS:
                    failed_records.append(record)
        runs.sort(key=run_rank_key)
        return SweepReport(
            arch=exp.arch_name,
            hardware=hardware_name,
            runs=runs,
            num_candidates=len(jobs),
            num_pruned_memory=pruned,
            num_failed=failed + extra_failed,
            executor=executor,
            num_hardware=num_hardware,
            pruned_records=pruned_records,
            failed_records=failed_records,
            profile=dict(self.last_profile) if self.profile else None,
            metrics=self._report_metrics(exp, outcomes),
        )

    def _report_metrics(self, exp, outcomes) -> Optional[Dict]:
        """SweepReport.metrics document: job-order sim-domain aggregate
        (bit-identical across tiers/executors) + the call's merged host
        registry. None when the experiment did not enable metrics."""
        if not getattr(exp, "metrics", False):
            return None
        from ..obs.simmetrics import aggregate_run_metrics

        return {"sim": aggregate_run_metrics(outcomes),
                "host": self.last_metrics or {}}

    def evaluate_jobs(self, exp, specs: Sequence[HardwareSpec],
                      jobs: Sequence[Job]) -> Tuple[List[Tuple[str, object]], str]:
        """Raw evaluation of a job stream: ``(tag, payload)`` outcomes in
        job order plus the executor label. Jobs may carry a per-job
        fidelity as a third element (multi-fidelity search rungs)."""
        jobs = list(jobs)
        call_profile: Dict[str, int] = {}
        call_registry = make_registry(bool(getattr(exp, "metrics", False)))
        t_call = perf_counter()
        try:
            # a 1-job batch is cheaper in-process — unless a persistent pool
            # exists (or will): search generations can shrink to one candidate
            # and must keep hitting the warm workers
            if self.workers >= 2 and (len(jobs) > 1 or self._persist):
                try:
                    exp_bytes = pickle.dumps(exp)
                    specs_bytes = pickle.dumps(list(specs))
                except Exception as e:   # e.g. lambda graph_builder
                    warnings.warn(
                        f"experiment not picklable ({e}); sweeping serially",
                        RuntimeWarning, stacklevel=3)
                else:
                    initargs = (exp_bytes, specs_bytes, self.return_timelines,
                                self.trace_resources, self.trace_lanes,
                                self.trace_budget_bytes, self.batch_fastpath)
                    if self._persist:
                        key = (exp_bytes, specs_bytes)
                        if self._pool is None or self._pool_key != key:
                            self._shutdown_pool()
                            self._pool = ProcessPoolExecutor(
                                max_workers=self.workers,
                                initializer=_init_worker, initargs=initargs)
                            self._pool_key = key
                            self.pool_inits += 1
                        parts = list(self._pool.map(
                            _eval_shard_in_worker,
                            _shards(jobs, self.workers)))
                        for _, prof, mdoc in parts:
                            _merge_profile(call_profile, prof)
                            call_registry.merge_dict(mdoc)
                        call_registry.counter("host.pool.shards").inc(
                            len(parts))
                        call_registry.gauge("host.pool.workers").set(
                            self.workers)
                        return ([o for out, _, _ in parts for o in out],
                                f"process[{self.workers}]")
                    n = min(self.workers, len(jobs))
                    self.pool_inits += 1
                    with ProcessPoolExecutor(
                            max_workers=n,
                            initializer=_init_worker,
                            initargs=initargs) as pool:
                        parts = list(pool.map(_eval_shard_in_worker,
                                              _shards(jobs, n)))
                    for _, prof, mdoc in parts:
                        _merge_profile(call_profile, prof)
                        call_registry.merge_dict(mdoc)
                    call_registry.counter("host.pool.shards").inc(len(parts))
                    call_registry.gauge("host.pool.workers").set(n)
                    return ([o for out, _, _ in parts for o in out],
                            f"process[{n}]")
            graphs, classify = self._serial_memo(exp)
            outcomes = _evaluate_many(
                exp, list(specs), jobs, graphs,
                return_timelines=self.return_timelines,
                trace_resources=self.trace_resources,
                trace_lanes=self.trace_lanes,
                trace_budget_bytes=self.trace_budget_bytes,
                batch_fastpath=self.batch_fastpath,
                classify_memo=classify,
                profile=call_profile,
                registry=call_registry)
            return outcomes, "serial"
        finally:
            self.last_profile = call_profile
            _merge_profile(self.profile_totals, call_profile)
            if call_registry:
                # satellite of the obs layer: the fast-tier phase profile
                # is itself a set of host counters
                for k, v in call_profile.items():
                    call_registry.counter("host.fastbatch." + k).inc(v)
                call_registry.counter("host.sweep.evaluate.us").inc(
                    (perf_counter() - t_call) * 1e6)
                call_registry.counter("host.sweep.evaluate.calls").inc()
                self.last_metrics = call_registry.to_dict()
            else:
                self.last_metrics = None


# -- module-level engine reuse ----------------------------------------------
# One persistent engine per flag combination: planner entry points
# (plan_parallelism / plan_codesign / plan_serving, and the CLI) call
# shared_engine() instead of constructing throwaway engines, so the
# process pool and serial memos stay warm across *calls* — back-to-back
# co-design questions about the same experiment re-pickle nothing.
_SHARED: Dict[Tuple, SweepEngine] = {}


def shared_engine(workers: Optional[int] = 0,
                  return_timelines: bool = False,
                  trace_resources: bool = False,
                  trace_lanes: Optional[Sequence] = None,
                  trace_budget_bytes: Optional[int] = None) -> SweepEngine:
    """Return the module-level persistent :class:`SweepEngine` for a flag
    combination, creating (and entering) it on first use.

    The engine is already persistent (``__enter__`` has been called):
    its process pool is keyed by the pickled (experiment, specs) pair
    and survives across calls, and its serial-path graph/classifier
    memos stay warm per experiment. Callers must NOT close it — it is
    shared; :func:`close_shared_engines` (registered atexit) tears all
    shared engines down."""
    key = (os.cpu_count() if workers is None else workers,
           bool(return_timelines), bool(trace_resources),
           _lane_codes(trace_lanes), trace_budget_bytes)
    eng = _SHARED.get(key)
    if eng is None:
        eng = SweepEngine(workers=workers,
                          return_timelines=return_timelines,
                          trace_resources=trace_resources,
                          trace_lanes=trace_lanes,
                          trace_budget_bytes=trace_budget_bytes)
        eng.__enter__()
        _SHARED[key] = eng
    return eng


def close_shared_engines() -> None:
    """Shut down every :func:`shared_engine` pool (also runs atexit)."""
    for eng in _SHARED.values():
        eng.close()
    _SHARED.clear()


atexit.register(close_shared_engines)
