"""Parallel sweep engine for Experiment plan and hardware x plan searches.

Executes sweeps through a ``concurrent.futures`` process pool (or
serially with ``workers=0``) with three structural optimizations over the
legacy ``sweep_plans`` loop:

* **Graph-construction memoization** — the workload graph depends only on
  the per-iteration batch (``microbatch * dp``), not the full plan or the
  hardware, so plans sharing a batch share one graph build per process
  (across hardware variants too).
* **Early infeasibility pruning** — per-tile memory is a property of the
  *mapped* graph, so the ``memory_cap`` check runs before the event-driven
  simulation and infeasible plans cost a mapping, not a full run.
* **One shared pool for hardware sweeps** — a hardware x plan sweep is a
  single flat job stream of ``(variant, plan)`` pairs evaluated by one
  process pool whose workers are initialized once with the pickled
  experiment and every variant spec, instead of spawning a fresh pool per
  hardware variant (see ``benchmarks/bench_sweep_engine.py`` for the
  speedup over the pool-per-variant baseline).

``return_timelines=True`` ships each run's event timeline back attached
to ``RunReport.trace`` (and the full :class:`SimResult` to ``.sim``).
The timeline crosses the pool in *columnar* form: :class:`Trace` pickles
through its compressed struct-of-arrays wire format
(``Trace.to_bytes``), which is several times smaller than the legacy
tuple-list ``SimResult`` payload (measured in
``benchmarks/bench_sweep_engine.py``). Reports stay scalar (and JSON
stays compact) by default.

Results are deterministic: the engine evaluates jobs in enumeration
order and ranks by simulated throughput, so serial and process-pool
sweeps produce identical SweepReports.
"""

from __future__ import annotations

import os
import pickle
import warnings
from concurrent.futures import ProcessPoolExecutor
from typing import Dict, List, Optional, Sequence, Tuple

from ..core.hardware import HardwareSpec
from ..core.parallelism import ParallelPlan, map_graph
from ..core.scheduler import PipelineSimulator, plan_memory
from .report import RunReport, SweepReport

__all__ = ["SweepEngine", "run_one"]

# outcome tags for one plan evaluation
_OK, _PRUNED, _FAILED = "ok", "pruned", "failed"

# a job is (hardware-variant index, plan); plain plan sweeps use index 0
Job = Tuple[int, ParallelPlan]


def _evaluate(exp, plan: ParallelPlan, graph_cache: Dict,
              hw: HardwareSpec,
              return_timelines: bool = False,
              trace_resources: bool = False) -> Tuple[str, object]:
    """Evaluate one (hardware, plan) job: build (memoized) graph, map,
    prune on memory, simulate. Returns (tag, RunReport | reason)."""
    try:
        if exp.graph_builder is None:
            # arch_to_graph depends only on (arch, seq_len, batch, mode) —
            # never on the hardware — so the memo is shared across variants
            key = plan.microbatch * plan.dp
            graph = graph_cache.get(key)
            if graph is None:
                graph = exp.build_graph(plan)
                graph_cache[key] = graph
        else:
            graph = exp.build_graph(plan)   # builder may depend on full plan
        mapped = map_graph(graph, hw, plan)
        mem_plan = None
        if exp.memory_cap is not None:
            mem_plan = plan_memory(mapped)
            if max(m.total for m in mem_plan[0]) > exp.memory_cap:
                return (_PRUNED, None)
        # compute lanes are always recorded; resource busy lanes stay off
        # unless the experiment asked for them (collect_timeline=True) so
        # default timeline sweeps keep pool payloads lean
        sim = PipelineSimulator(mapped, noc_mode=exp.noc_mode,
                                boundary_mode=exp.boundary_mode,
                                memory_plan=mem_plan,
                                collect_timeline=trace_resources)
        result = sim.run()
        # the scalar occupancy digest is an in-process convenience; drop
        # it so serial and pooled sweeps return identical, lean results
        result.noc_occupancy_fallback.clear()
    except (ValueError, KeyError, TypeError) as e:
        return (_FAILED, f"{type(e).__name__}: {e}")
    return (_OK, RunReport.from_sim(exp.arch_name, hw.name, plan, result,
                                    keep_sim=return_timelines))


def run_one(exp, plan: ParallelPlan) -> RunReport:
    """Simulate one fixed plan (Experiment.run body)."""
    graph = exp.build_graph(plan)
    hw = exp.hardware_spec
    mapped = map_graph(graph, hw, plan)
    sim = PipelineSimulator(mapped, noc_mode=exp.noc_mode,
                            boundary_mode=exp.boundary_mode,
                            collect_timeline=exp.collect_timeline)
    return RunReport.from_sim(exp.arch_name, hw.name, plan, sim.run(),
                              keep_sim=exp.collect_timeline)


# -- process-pool plumbing ---------------------------------------------------
# The Experiment and every hardware-variant spec are shipped once per
# worker (initializer) instead of once per task; each worker keeps its own
# per-variant graph memo across tasks.
_WORKER: Dict = {}


def _init_worker(exp_bytes: bytes, specs_bytes: bytes,
                 return_timelines: bool, trace_resources: bool) -> None:
    _WORKER["exp"] = pickle.loads(exp_bytes)
    _WORKER["specs"] = pickle.loads(specs_bytes)
    _WORKER["graphs"] = {}
    _WORKER["return_timelines"] = return_timelines
    _WORKER["trace_resources"] = trace_resources


def _eval_in_worker(job: Job) -> Tuple[str, object]:
    variant, plan = job
    return _evaluate(_WORKER["exp"], plan, _WORKER["graphs"],
                     hw=_WORKER["specs"][variant],
                     return_timelines=_WORKER["return_timelines"],
                     trace_resources=_WORKER["trace_resources"])


class SweepEngine:
    """Executes a plan sweep — or a merged hardware x plan sweep — for an
    Experiment.

    ``workers=0`` (default) runs serially in-process; ``workers=N`` uses an
    N-process pool; ``workers=None`` uses one process per CPU.
    ``return_timelines=True`` attaches each run's columnar event timeline
    to ``RunReport.trace`` (and the :class:`SimResult` to ``.sim``);
    timelines cross the pool in compressed columnar form.
    ``trace_resources=True`` (``Experiment.collect_timeline``) further
    records NoC-link / DRAM-channel busy intervals into those traces —
    richer, but a bigger pool payload.
    """

    def __init__(self, workers: Optional[int] = 0,
                 return_timelines: bool = False,
                 trace_resources: bool = False):
        self.workers = os.cpu_count() if workers is None else workers
        self.return_timelines = return_timelines
        self.trace_resources = trace_resources

    def sweep(self, exp, plans: Sequence[ParallelPlan]) -> SweepReport:
        """Plan sweep on the experiment's single hardware spec."""
        hw = exp.hardware_spec
        return self.sweep_jobs(exp, [hw], [(0, p) for p in plans],
                               hardware_name=hw.name)

    def sweep_jobs(self, exp, specs: Sequence[HardwareSpec],
                   jobs: Sequence[Job], *, hardware_name: str,
                   num_hardware: int = 1,
                   extra_failed: int = 0) -> SweepReport:
        """Evaluate a flat ``(variant index, plan)`` job stream against the
        given hardware variants through one shared executor and return the
        merged ranked report. ``extra_failed`` accounts for variants that
        failed before any job was enumerated (e.g. too few devices)."""
        specs, jobs = list(specs), list(jobs)
        outcomes, executor = self._evaluate_all(exp, specs, jobs)

        runs: List[RunReport] = []
        pruned = failed = 0
        for tag, payload in outcomes:
            if tag == _OK:
                runs.append(payload)
            elif tag == _PRUNED:
                pruned += 1
            else:
                failed += 1
        runs.sort(key=lambda r: -r.throughput)
        return SweepReport(
            arch=exp.arch_name,
            hardware=hardware_name,
            runs=runs,
            num_candidates=len(jobs),
            num_pruned_memory=pruned,
            num_failed=failed + extra_failed,
            executor=executor,
            num_hardware=num_hardware,
        )

    def _evaluate_all(self, exp, specs: Sequence[HardwareSpec],
                      jobs: Sequence[Job]):
        if self.workers >= 2 and len(jobs) > 1:
            try:
                exp_bytes = pickle.dumps(exp)
                specs_bytes = pickle.dumps(list(specs))
            except Exception as e:   # e.g. lambda graph_builder
                warnings.warn(
                    f"experiment not picklable ({e}); sweeping serially",
                    RuntimeWarning, stacklevel=3)
            else:
                n = min(self.workers, len(jobs))
                with ProcessPoolExecutor(
                        max_workers=n,
                        initializer=_init_worker,
                        initargs=(exp_bytes, specs_bytes,
                                  self.return_timelines,
                                  self.trace_resources)) as pool:
                    return list(pool.map(_eval_in_worker, jobs)), f"process[{n}]"
        graphs: Dict = {}
        return [_evaluate(exp, plan, graphs, hw=specs[variant],
                          return_timelines=self.return_timelines,
                          trace_resources=self.trace_resources)
                for variant, plan in jobs], "serial"
