"""Structured results for the Experiment API.

A :class:`RunReport` is the digest of one simulation: the typed
:class:`ParallelPlan` that ran, where it ran, and the performance PALM
predicts. A :class:`SweepReport` is a ranked collection of RunReports
plus sweep accounting (how many plans were pruned before simulation and
why).

Both round-trip through ``to_json`` / ``from_json`` so benchmarks and
downstream tools can persist sweeps without pickling simulator objects;
plans serialize as plain dicts (:func:`plan_to_dict`).

A RunReport stays scalar by default: when a sweep runs with
``return_timelines=True`` the columnar :class:`~repro.core.trace.Trace`
rides along in ``trace`` (and the full :class:`SimResult` in ``sim``),
both excluded from JSON and from equality so scalar reports and their
round-trips are unaffected. ``to_dict(include_trace=True)`` embeds the
trace's compact JSON-safe dict, and :meth:`RunReport.trace_summary`
digests it (per-stage utilization, bubble fraction, critical path,
resource occupancy) without shipping the event columns.
"""

from __future__ import annotations

import dataclasses
import json
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, TYPE_CHECKING

if TYPE_CHECKING:                       # search builds on api; keep it lazy
    from ..search.report import SearchReport

from ..core.enums import Layout, Schedule
from ..core.parallelism import ParallelPlan, plan_sort_key
from ..core.scheduler import SimResult
from ..core.trace import Trace

__all__ = ["RunReport", "SweepReport", "plan_to_dict", "plan_from_dict",
           "run_rank_key"]

# ParallelPlan fields that are not JSON-scalar and rarely swept; they are
# serialized only when set so reports stay compact.
_PLAN_OPTIONAL = ("stage_binding", "tile_binding")


def plan_to_dict(plan: ParallelPlan) -> Dict[str, Any]:
    d = dataclasses.asdict(plan)
    d["schedule"] = str(plan.schedule)
    d["layout"] = str(plan.layout)
    for k in _PLAN_OPTIONAL:
        if d.get(k) is None:
            d.pop(k, None)
    return d


def plan_from_dict(d: Dict[str, Any]) -> ParallelPlan:
    kw = dict(d)
    kw["schedule"] = Schedule(kw.get("schedule", "1f1b"))
    kw["layout"] = Layout(kw.get("layout", "s_shape"))
    return ParallelPlan(**kw)


def run_rank_key(run: "RunReport"):
    """Total ranking order for sweep runs: throughput (best first) with a
    deterministic tie-break on the run's canonical (hardware, plan)
    identity. Ties on throughput are common — hardware axes that don't
    touch a bottleneck produce bit-equal results — and a plain
    ``-throughput`` sort would leave their order to job arrival, which
    differs between executors and between the batched and per-job fast
    tiers. Every ranking in the tree (sweep, search assembly, legacy
    ``sweep_plans``, benches) tie-breaks on the same
    :func:`~repro.core.parallelism.plan_sort_key` so rankings compare
    exactly."""
    return (-run.throughput, run.hardware, plan_sort_key(run.plan))


@dataclass
class RunReport:
    """One simulated (plan, hardware, workload) point."""

    arch: str
    hardware: str
    plan: ParallelPlan
    total_time: float
    throughput: float
    bubble_ratio: float
    peak_memory_bytes: float
    recompute: bool
    event_count: int
    noc_bytes: float
    dram_bytes: float
    extra: Dict[str, Any] = field(default_factory=dict)
    # full SimResult when the sweep ran with return_timelines=True; never
    # part of JSON-by-default, never compared
    sim: Optional[SimResult] = field(default=None, compare=False, repr=False)
    # the columnar event timeline (same object the sim holds); shipped
    # across the process pool in compressed columnar form
    trace: Optional[Trace] = field(default=None, compare=False, repr=False)
    # repro.obs metrics document ({"sim": ..., "host": ...}) when the run
    # recorded metrics; the sim half is deterministic, the host half is
    # not, so the field stays out of equality (JSON keeps it — it is
    # plain data and what `python -m repro metrics` reads back)
    metrics: Optional[Dict[str, Any]] = field(default=None, compare=False,
                                              repr=False)

    @classmethod
    def from_sim(cls, arch: str, hardware: str, plan: ParallelPlan,
                 result: SimResult, keep_sim: bool = False,
                 **extra: Any) -> "RunReport":
        # surface which simulator tier produced the numbers (fast tier is
        # bit-identical, so this is attribution, not a result qualifier)
        if getattr(result, "engine", "event") != "event":
            extra.setdefault("engine", result.engine)
        return cls(
            arch=arch,
            hardware=hardware,
            plan=plan,
            total_time=result.total_time,
            throughput=result.throughput,
            bubble_ratio=result.bubble_ratio,
            peak_memory_bytes=max((m.total for m in result.stage_memory),
                                  default=0.0),
            recompute=result.recompute,
            event_count=result.event_count,
            noc_bytes=result.noc_bytes,
            dram_bytes=result.dram_bytes,
            extra=dict(extra),
            sim=result if keep_sim else None,
            trace=result.trace if keep_sim else None,
            metrics=getattr(result, "metrics", None),
        )

    def trace_summary(self) -> Optional[Dict[str, Any]]:
        """JSON-safe analytics digest of the attached trace (None when the
        run carried no timeline)."""
        return None if self.trace is None else self.trace.summary()

    def to_dict(self, include_trace: bool = False) -> Dict[str, Any]:
        # drop sim/trace before asdict: event columns are not part of the
        # default JSON form, and deep-converting thousands of events just
        # to pop them is waste
        src = self
        if self.sim is not None or self.trace is not None:
            src = dataclasses.replace(self, sim=None, trace=None)
        d = dataclasses.asdict(src)
        d["plan"] = plan_to_dict(self.plan)
        d.pop("sim", None)
        d.pop("trace", None)
        if d.get("metrics") is None:
            d.pop("metrics", None)
        if include_trace and self.trace is not None:
            d["trace"] = self.trace.to_dict()
        return d

    def to_json(self, **kw: Any) -> str:
        return json.dumps(self.to_dict(), **kw)

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "RunReport":
        d = dict(d)
        d["plan"] = plan_from_dict(d["plan"])
        d.pop("sim", None)
        trace = d.pop("trace", None)
        if trace is not None:
            d["trace"] = Trace.from_dict(trace)
        return cls(**d)

    @classmethod
    def from_json(cls, s: str) -> "RunReport":
        return cls.from_dict(json.loads(s))

    def summary(self) -> str:
        p = self.plan
        return (f"pp={p.pp} dp={p.dp} tp={p.tp} mb={p.microbatch} "
                f"{p.schedule}/{p.layout} -> {self.throughput:.2f} samples/s, "
                f"bubble {self.bubble_ratio:.1%}, "
                f"peak mem {self.peak_memory_bytes / 1e9:.2f} GB")


@dataclass
class SweepReport:
    """Ranked sweep outcome (best plan first) + pruning accounting."""

    arch: str
    hardware: str
    runs: List[RunReport]                # sorted by throughput, best first
    num_candidates: int = 0              # plans enumerated
    num_pruned_memory: int = 0           # dropped by the pre-sim memory check
    num_failed: int = 0                  # raised during mapping/simulation
    executor: str = "serial"
    num_hardware: int = 1                # hardware variants swept (§VI search)
    # variant name -> HardwareSpec dict for hardware x plan sweeps, so the
    # winning machine is recoverable from the report alone (co-design)
    hardware_specs: Dict[str, Dict[str, Any]] = field(default_factory=dict)
    # representative per-outcome diagnostics (capped; counters above stay
    # exact): memory-pruned plans carry peak/cap/deficit bytes, failed
    # plans the raised error — so planners can say *why* nothing fit
    pruned_records: List[Dict[str, Any]] = field(default_factory=list)
    failed_records: List[Dict[str, Any]] = field(default_factory=list)
    # guided-search accounting (repro.search): per-rung history, sims per
    # fidelity, best-so-far curve. None for exhaustive sweeps.
    search: Optional["SearchReport"] = None
    # per-phase timing/count accounting of the batched fast tier
    # (compile/batch-eval/validate/fallback microseconds plus job
    # counters) when the sweep ran with profiling on; timings vary run to
    # run, so the field is excluded from equality
    profile: Optional[Dict[str, Any]] = field(default=None, compare=False)
    # repro.obs metrics document ({"sim": ..., "host": ...}): the sim half
    # aggregates compare=True run scalars in job order (bit-identical
    # across engine tiers and executors); the host half is the merged
    # registry of the parent process and every pool shard
    metrics: Optional[Dict[str, Any]] = field(default=None, compare=False)

    @property
    def best(self) -> Optional[RunReport]:
        return self.runs[0] if self.runs else None

    def best_hardware_dict(self) -> Optional[Dict[str, Any]]:
        """HardwareSpec dict of the best run's variant (None when the sweep
        had no hardware search or the variant spec was not serializable)."""
        if self.best is None:
            return None
        return self.hardware_specs.get(self.best.hardware)

    def to_dict(self) -> Dict[str, Any]:
        # leave runs (their sims could be huge) and the typed search report
        # out of the asdict recursion; both serialize themselves
        d = dataclasses.asdict(dataclasses.replace(self, runs=[], search=None))
        d["runs"] = [r.to_dict() for r in self.runs]
        if self.search is not None:
            d["search"] = self.search.to_dict()
        else:
            d.pop("search", None)
        if self.profile is None:
            d.pop("profile", None)
        if self.metrics is None:
            d.pop("metrics", None)
        return d

    def to_json(self, **kw: Any) -> str:
        return json.dumps(self.to_dict(), **kw)

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "SweepReport":
        d = dict(d)
        d["runs"] = [RunReport.from_dict(r) for r in d.get("runs", [])]
        search = d.pop("search", None)
        if search is not None:
            from ..search.report import SearchReport
            d["search"] = SearchReport.from_dict(search)
        return cls(**d)

    @classmethod
    def from_json(cls, s: str) -> "SweepReport":
        return cls.from_dict(json.loads(s))

    def table(self, top: int = 10) -> str:
        # hardware column only for hardware x parallelism sweeps
        hw_col = self.num_hardware > 1
        width = max([len("hardware")] +
                    [len(r.hardware) for r in self.runs[:top]]) if hw_col else 0
        head = f"{'hardware':>{width}s} " if hw_col else ""
        lines = [f"{head}{'pp':>3s} {'dp':>3s} {'tp':>3s} {'mb':>3s} "
                 f"{'schedule':>8s} {'layout':>8s} {'samples/s':>10s} "
                 f"{'bubble':>7s} {'mem GB':>7s}"]
        for r in self.runs[:top]:
            p = r.plan
            prefix = f"{r.hardware:>{width}s} " if hw_col else ""
            lines.append(
                f"{prefix}{p.pp:3d} {p.dp:3d} {p.tp:3d} {p.microbatch:3d} "
                f"{str(p.schedule):>8s} {str(p.layout):>8s} {r.throughput:10.3f} "
                f"{r.bubble_ratio:7.1%} {r.peak_memory_bytes / 1e9:7.2f}")
        return "\n".join(lines)
