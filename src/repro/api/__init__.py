"""Unified Experiment API — the canonical front door to the PALM simulator.

One typed entry point for the three workflows the repo exposes:

* **simulate** — ``Experiment(arch=..., plan=ParallelPlan(...)).run()``
* **sweep**    — ``Experiment(arch=..., search=SearchSpace(...)).sweep()``,
  optionally crossed with a :class:`HardwareSearchSpace` to rank
  hardware x parallelism points (the paper's §VI exploration)
* **plan**     — :func:`repro.core.planner.plan_parallelism` (built on the
  same engine), or ``python -m repro plan`` from the shell.

Configuration is fully typed: enums (:class:`Schedule`, :class:`Layout`,
:class:`NoCMode`, :class:`BoundaryMode`) for modes, declarative
serializable :class:`HardwareSpec` for machines (presets are data —
dump one with ``python -m repro hardware``, tweak the JSON, load it with
``--hardware-json``). Results come back as JSON-round-trip
:class:`RunReport` / :class:`SweepReport` dataclasses.
"""

from ..core.enums import BoundaryMode, Layout, NoCMode, Schedule
from ..core.hardware import (
    GPUClusterSpec,
    HardwareSpec,
    HierarchicalSpec,
    MeshSpec,
    TopologySpec,
)
from ..core.parallelism import ParallelPlan
from ..core.trace import Trace, TraceDiff, TraceRecorder, chrome_trace
from ..core.trace import diff as trace_diff
from ..core.planner import (
    CodesignResult,
    PlannerCfg,
    plan_codesign,
    plan_parallelism,
)
from .experiment import (
    Experiment,
    HARDWARE_PRESETS,
    HardwareSearchSpace,
    SearchSpace,
    resolve_hardware,
)
from .report import (
    RunReport,
    SweepReport,
    plan_from_dict,
    plan_to_dict,
    run_rank_key,
)
from .sweep import SweepEngine, close_shared_engines, shared_engine

__all__ = [
    "BoundaryMode",
    "CodesignResult",
    "Experiment",
    "GPUClusterSpec",
    "HARDWARE_PRESETS",
    "HardwareSearchSpace",
    "HardwareSpec",
    "HierarchicalSpec",
    "Layout",
    "MeshSpec",
    "NoCMode",
    "ParallelPlan",
    "PlannerCfg",
    "RunReport",
    "Schedule",
    "SearchSpace",
    "SweepEngine",
    "SweepReport",
    "TopologySpec",
    "Trace",
    "TraceDiff",
    "TraceRecorder",
    "chrome_trace",
    "close_shared_engines",
    "trace_diff",
    "plan_codesign",
    "plan_from_dict",
    "plan_parallelism",
    "plan_to_dict",
    "resolve_hardware",
    "run_rank_key",
    "shared_engine",
]
