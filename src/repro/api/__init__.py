"""Unified Experiment API — the canonical front door to the PALM simulator.

One typed entry point for the three workflows the repo exposes:

* **simulate** — ``Experiment(arch=..., plan=ParallelPlan(...)).run()``
* **sweep**    — ``Experiment(arch=..., search=SearchSpace(...)).sweep()``
* **plan**     — :func:`repro.core.planner.plan_parallelism` (built on the
  same engine), or ``python -m repro plan`` from the shell.

Strings like ``schedule="1f1b"`` are replaced by typed enums
(:class:`Schedule`, :class:`Layout`, :class:`NoCMode`,
:class:`BoundaryMode`); legacy strings are coerced with a
DeprecationWarning for one release. Results come back as JSON-round-trip
:class:`RunReport` / :class:`SweepReport` dataclasses.
"""

from ..core.enums import BoundaryMode, Layout, NoCMode, Schedule
from ..core.parallelism import ParallelPlan
from .experiment import Experiment, HARDWARE_PRESETS, SearchSpace, resolve_hardware
from .report import RunReport, SweepReport, plan_from_dict, plan_to_dict
from .sweep import SweepEngine

__all__ = [
    "BoundaryMode",
    "Experiment",
    "HARDWARE_PRESETS",
    "Layout",
    "NoCMode",
    "ParallelPlan",
    "RunReport",
    "Schedule",
    "SearchSpace",
    "SweepEngine",
    "SweepReport",
    "plan_from_dict",
    "plan_to_dict",
    "resolve_hardware",
]
