"""``python -m repro`` — simulate / sweep / plan / hardware from the shell.

    python -m repro simulate --arch yi-6b --hardware wafer_scale \
        --pp 4 --dp 2 --tp 2 --global-batch 64
    python -m repro sweep --arch yi-6b --hardware grayskull \
        --global-batch 64 --max-plans 24 --workers 4 --json sweep.json
    python -m repro sweep --arch yi-6b --hardware wafer_scale \
        --hw-flops 8e12 16e12 --hw-mesh 4x4 5x4 --global-batch 64
    python -m repro plan --arch dbrx-132b --hardware wafer_scale
    python -m repro plan --arch yi-6b --hardware wafer_scale \
        --hw-flops 8e12 16e12 --hw-mesh 5x4 4x4 --codesign-json best_hw.json
    python -m repro plan --arch yi-6b --hardware wafer_scale \
        --hw-flops 8e12 16e12 32e12 --search sh --search-budget 12 --seed 0
    python -m repro hardware --hardware wafer_scale > wafer.json
    python -m repro simulate --arch yi-6b --hardware-json wafer.json ...
    python -m repro trace-diff base.npz variant.npz
    python -m repro sweep --arch yi-6b ... --metrics --json sweep.json
    python -m repro metrics sweep.json

Every enum-valued flag takes the typed values (``--schedule 1f1b``,
``--noc-mode macro``); hardware is a preset name, an ``a100x<N>`` /
``tpu_v5e_<R>x<C>`` parameterized name, or a ``--hardware-json`` file
(the schema ``python -m repro hardware`` emits). Outputs are the
RunReport / SweepReport JSON documents when ``--json`` is given, human
tables otherwise.
"""

from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path
from typing import List, Optional, Tuple

from ..configs import list_archs
from ..core.enums import BoundaryMode, Layout, NoCMode, Schedule
from ..core.hardware import HardwareSpec
from ..core.parallelism import ParallelPlan
from .experiment import (
    Experiment,
    HARDWARE_PRESETS,
    HardwareSearchSpace,
    SearchSpace,
    resolve_hardware,
)

__all__ = ["main"]


def _mesh_shape(s: str) -> Tuple[int, int]:
    try:
        r, c = s.lower().split("x")
        return (int(r), int(c))
    except ValueError:
        raise argparse.ArgumentTypeError(f"mesh shape must be RxC, got {s!r}")


def _add_hardware(ap: argparse.ArgumentParser) -> None:
    ap.add_argument("--hardware", default="wafer_scale",
                    help=f"preset: {', '.join(sorted(HARDWARE_PRESETS))}, "
                         "a100x<N>, or tpu_v5e_<R>x<C>")
    ap.add_argument("--hardware-json", type=Path, default=None, metavar="FILE",
                    help="load the HardwareSpec from this JSON file "
                         "(overrides --hardware; schema: "
                         "`python -m repro hardware`)")
    ap.add_argument("--d-model", type=int, default=None,
                    help="calibrate the a100 sustained-GEMM efficiency curve "
                         "at this hidden size (a100x<N> only)")
    ap.add_argument("--fabric", default=None, metavar="PRESET",
                    help="attach a scale-out fabric preset (board_pair, "
                         "cluster_2x2, rack_2x2x2) replicating the chip into "
                         "a multi-chip cluster")
    ap.add_argument("--fabric-json", type=Path, default=None, metavar="FILE",
                    help="attach the FabricSpec in this JSON file (overrides "
                         "--fabric; schema: `python -m repro fabric`)")


def _resolve_fabric_args(args):
    """FabricSpec from --fabric/--fabric-json (None when neither given)."""
    if getattr(args, "fabric_json", None) is not None:
        from ..fabric import FabricSpec
        return FabricSpec.from_json(args.fabric_json.read_text())
    if getattr(args, "fabric", None) is not None:
        from ..fabric import FABRIC_PRESETS
        builder = FABRIC_PRESETS.get(args.fabric)
        if builder is None:
            raise ValueError(f"unknown fabric preset {args.fabric!r}; "
                             f"known: {', '.join(sorted(FABRIC_PRESETS))}")
        return builder()
    return None


def _resolve_hardware_args(args) -> "HardwareSpec | str":
    fabric = _resolve_fabric_args(args)
    if args.hardware_json is not None:
        if args.d_model is not None:
            raise ValueError("--d-model calibrates the a100x<N> preset; it "
                             "cannot recalibrate a --hardware-json file")
        hw = HardwareSpec.from_json(args.hardware_json.read_text())
    elif args.d_model is not None:
        hw = resolve_hardware(args.hardware, d_model=args.d_model)
    elif fabric is not None:
        hw = resolve_hardware(args.hardware)
    else:
        return args.hardware
    if fabric is not None:
        hw = hw.with_(fabric=fabric)
    return hw


def _add_common(ap: argparse.ArgumentParser) -> None:
    ap.add_argument("--arch", required=True,
                    help=f"arch-config name (e.g. {', '.join(list_archs()[:3])}, "
                         "T-18B, ...)")
    _add_hardware(ap)
    ap.add_argument("--seq-len", type=int, default=2048)
    ap.add_argument("--global-batch", type=int, default=256)
    ap.add_argument("--inference", action="store_true",
                    help="simulate an inference pipeline instead of training")
    ap.add_argument("--noc-mode", type=NoCMode, choices=list(NoCMode),
                    default=NoCMode.MACRO)
    ap.add_argument("--boundary-mode", type=BoundaryMode,
                    choices=list(BoundaryMode), default=BoundaryMode.PAIRWISE)
    ap.add_argument("--json", type=Path, default=None, metavar="FILE",
                    help="write the report JSON here ('-' for stdout)")


def _add_plan_flags(ap: argparse.ArgumentParser) -> None:
    ap.add_argument("--pp", type=int, default=1)
    ap.add_argument("--dp", type=int, default=1)
    ap.add_argument("--tp", type=int, default=1)
    ap.add_argument("--microbatch", type=int, default=1)
    ap.add_argument("--schedule", type=Schedule, choices=list(Schedule),
                    default=Schedule.ONE_F_ONE_B)
    ap.add_argument("--layout", type=Layout, choices=list(Layout),
                    default=Layout.S_SHAPE)
    ap.add_argument("--activation-offload", action="store_true",
                    help="park saved activations off-device between FD and "
                         "BD (smaller footprint, extra DRAM traffic)")
    ap.add_argument("--trace-out", type=Path, default=None, metavar="FILE",
                    help="write the run's event timeline as Chrome/Perfetto "
                         "traceEvents JSON (open in chrome://tracing or "
                         "ui.perfetto.dev; '-' for stdout)")
    ap.add_argument("--trace-npz", type=Path, default=None, metavar="FILE",
                    help="write the columnar trace as a compressed .npz "
                         "archive (needs numpy)")
    ap.add_argument("--engine", choices=["auto", "event", "fast"],
                    default="event",
                    help="simulator tier: 'event' = generator/heap kernel, "
                         "'auto' = bit-identical closed-form fast path with "
                         "fallback on contention, 'fast' = fast path or fail "
                         "(see docs/simulator.md)")


def _add_sweep_flags(ap: argparse.ArgumentParser) -> None:
    ap.add_argument("--max-plans", type=int, default=64)
    ap.add_argument("--microbatch-sizes", type=int, nargs="+", default=[1, 2, 4])
    ap.add_argument("--schedules", type=Schedule, nargs="+",
                    choices=list(Schedule), default=[Schedule.ONE_F_ONE_B])
    ap.add_argument("--layouts", type=Layout, nargs="+",
                    choices=list(Layout), default=[Layout.S_SHAPE, Layout.LINE])
    ap.add_argument("--interleave", type=int, nargs="+", default=[1],
                    help="virtual-stage degrees (interleaved 1F1B)")
    ap.add_argument("--zero-stages", type=int, nargs="+", default=[0],
                    choices=[0, 1, 2, 3], help="ZeRO optimizer-sharding stages")
    ap.add_argument("--comm-strategies", type=int, nargs="+", default=[1],
                    choices=[1, 2],
                    help="inter-tile-group boundary strategies (Fig. 11; "
                         "needs --boundary-mode strategy to differ)")
    ap.add_argument("--activation-offload", type=int, nargs="+", default=[0],
                    choices=[0, 1],
                    help="activation-offload axis (0 = resident, 1 = park "
                         "saved activations off-device; sweep both with "
                         "'0 1')")
    ap.add_argument("--memory-cap", type=float, default=None,
                    help="bytes per tile; infeasible plans pruned pre-simulation")
    ap.add_argument("--engine", choices=["auto", "event", "fast"],
                    default="event",
                    help="simulator tier per candidate: 'event' = generator/"
                         "heap kernel, 'auto'/'fast' = bit-identical fast "
                         "tier, evaluated in vectorized batches across the "
                         "sweep (see docs/simulator.md)")
    ap.add_argument("--profile", action="store_true",
                    help="print (and embed in --json artifacts) the batched "
                         "fast tier's per-phase timing table: compile / "
                         "batch-eval / validate / fallback")
    ap.add_argument("--workers", type=int, default=0,
                    help="0 = serial, N = process pool of N, -1 = all cores")
    ap.add_argument("--top", type=int, default=10)
    ap.add_argument("--search", default="exhaustive",
                    choices=["exhaustive", "random", "sh", "evolve"],
                    help="guided search strategy (repro.search): exhaustive "
                         "evaluates every candidate; random/sh/evolve spend "
                         "at most --search-budget full-fidelity simulations "
                         "(sh climbs cheap fidelity rungs first)")
    ap.add_argument("--search-budget", type=int, default=None, metavar="N",
                    help="max full-fidelity simulations for guided search "
                         "(default: a fifth of the space)")
    ap.add_argument("--seed", type=int, default=None,
                    help="guided-search RNG seed (fixed seed = "
                         "bit-reproducible run, serial or pooled; "
                         "default 0)")
    hw = ap.add_argument_group(
        "hardware search (cross the plan sweep with hardware variants)")
    hw.add_argument("--hw-flops", type=float, nargs="+", default=[],
                    help="per-tile peak FLOP/s values to sweep")
    hw.add_argument("--hw-sram", type=float, nargs="+", default=[],
                    help="per-tile SRAM bytes to sweep")
    hw.add_argument("--hw-intra-bw", type=float, nargs="+", default=[],
                    help="intra-tile NoC bandwidths (bytes/s) to sweep")
    hw.add_argument("--hw-inter-bw", type=float, nargs="+", default=[],
                    help="inter-tile NoC bandwidths (bytes/s) to sweep")
    hw.add_argument("--hw-mesh", type=_mesh_shape, nargs="+", default=[],
                    metavar="RxC", help="mesh shapes to sweep (e.g. 8x8 16x16)")
    hw.add_argument("--hw-dram-channels", type=int, nargs="+", default=[],
                    help="DRAM channel counts to sweep")
    hw.add_argument("--hw-dram-bw", type=float, nargs="+", default=[],
                    help="DRAM channel bandwidths (bytes/s) to sweep")
    hw.add_argument("--hw-fabric-bw", type=float, nargs="+", default=[],
                    help="outermost fabric-level bandwidths (bytes/s) to "
                         "sweep (hardware must carry a fabric: --fabric / "
                         "--fabric-json)")
    hw.add_argument("--hw-fabric-coll", nargs="+", default=[],
                    choices=["hierarchical", "ring", "tree", "hd"],
                    help="cross-chip collective families to sweep")
    hw.add_argument("--hw-max-specs", type=int, default=32,
                    help="cap on enumerated hardware variants")


def _hardware_search(args) -> Optional[HardwareSearchSpace]:
    space = HardwareSearchSpace(
        tile_flops=tuple(args.hw_flops),
        sram_bytes=tuple(args.hw_sram),
        intra_bw=tuple(args.hw_intra_bw),
        inter_bw=tuple(args.hw_inter_bw),
        mesh_shapes=tuple(args.hw_mesh),
        dram_channels=tuple(args.hw_dram_channels),
        dram_bandwidth=tuple(args.hw_dram_bw),
        fabric_bw=tuple(args.hw_fabric_bw),
        fabric_collectives=tuple(args.hw_fabric_coll),
        max_specs=args.hw_max_specs,
    )
    has_axes = any((space.tile_flops, space.sram_bytes, space.intra_bw,
                    space.inter_bw, space.mesh_shapes, space.dram_channels,
                    space.dram_bandwidth, space.fabric_bw,
                    space.fabric_collectives))
    return space if has_axes else None


def _emit(report, json_target: Optional[Path]) -> None:
    if json_target is None:
        return
    text = report.to_json(indent=2)
    if str(json_target) == "-":
        print(text)
    else:
        json_target.write_text(text + "\n")
        print(f"[report written to {json_target}]")


def _add_metrics_flags(ap: argparse.ArgumentParser) -> None:
    ap.add_argument("--metrics", action="store_true",
                    help="record the repro.obs metrics registry (sim-domain "
                         "roofline/bubble/traffic plus host-domain tier and "
                         "timing counters) and print its summary; rides in "
                         "--json reports under 'metrics' "
                         "(see docs/observability.md)")
    ap.add_argument("--metrics-out", type=Path, default=None, metavar="FILE",
                    help="write the metrics document JSON here ('-' for "
                         "stdout; implies --metrics)")


def _want_metrics(args) -> bool:
    return bool(getattr(args, "metrics", False)
                or getattr(args, "metrics_out", None) is not None)


def _emit_metrics(report, args) -> None:
    if not _want_metrics(args):
        return
    metrics = getattr(report, "metrics", None)
    if metrics is None:
        return                          # e.g. a sweep with zero runs
    out = getattr(args, "metrics_out", None)
    if out is not None:
        text = json.dumps(metrics, indent=2)
        if str(out) == "-":
            print(text)
        else:
            out.write_text(text + "\n")
            print(f"[metrics written to {out}]")
    else:
        from ..obs.registry import summarize_metrics
        print(summarize_metrics(
            metrics, title=f"{report.arch} on {report.hardware}"))


def _cmd_simulate(args) -> int:
    plan = ParallelPlan(pp=args.pp, dp=args.dp, tp=args.tp,
                        microbatch=args.microbatch,
                        global_batch=args.global_batch,
                        schedule=args.schedule, layout=args.layout,
                        activation_offload=args.activation_offload,
                        training=not args.inference)
    want_trace = args.trace_out is not None or args.trace_npz is not None
    if args.trace_npz is not None:
        from ..core import trace as trace_mod
        if trace_mod._np is None:       # fail before paying for the sim
            raise ValueError("--trace-npz needs numpy (this install runs "
                             "the dependency-free core); use --trace-out")
    exp = Experiment(arch=args.arch, hardware=_resolve_hardware_args(args),
                     plan=plan, seq_len=args.seq_len,
                     global_batch=args.global_batch,
                     training=not args.inference, noc_mode=args.noc_mode,
                     boundary_mode=args.boundary_mode,
                     collect_timeline=want_trace,
                     engine=args.engine,
                     metrics=_want_metrics(args))
    report = exp.run()
    print(f"{report.arch} on {report.hardware}: {report.summary()}")
    if want_trace:
        _emit_trace(report, args)
    _emit_metrics(report, args)
    _emit(report, args.json)
    return 0


def _emit_trace(report, args) -> None:
    from ..core.trace import chrome_trace
    trace = report.trace
    if trace is None:       # defensive: collect_timeline was on
        raise ValueError("simulation produced no trace")
    if args.trace_out is not None:
        from ..obs.tracks import activity_counters, metrics_counters
        counters = activity_counters(trace)
        counters.update(metrics_counters(getattr(report, "metrics", None),
                                         trace.total_time))
        doc = chrome_trace(trace, label=f"{report.arch}@{report.hardware}",
                           counters=counters)
        text = json.dumps(doc)
        if str(args.trace_out) == "-":
            print(text)
        else:
            args.trace_out.write_text(text + "\n")
            summary = report.trace_summary()
            print(f"[trace written to {args.trace_out}: "
                  f"{summary['events']} events, "
                  f"bubble {summary['bubble_fraction']:.1%}]")
    if args.trace_npz is not None:
        trace.to_npz(args.trace_npz)
        print(f"[columnar trace written to {args.trace_npz}]")


def _make_sweep_experiment(args) -> Experiment:
    search = SearchSpace(schedules=tuple(args.schedules),
                         layouts=tuple(args.layouts),
                         microbatch_sizes=tuple(args.microbatch_sizes),
                         interleave=tuple(args.interleave),
                         zero_stages=tuple(args.zero_stages),
                         comm_strategies=tuple(args.comm_strategies),
                         activation_offload=tuple(
                             bool(v) for v in args.activation_offload),
                         max_plans=args.max_plans)
    return Experiment(arch=args.arch, hardware=_resolve_hardware_args(args),
                      search=search, hardware_search=_hardware_search(args),
                      seq_len=args.seq_len, global_batch=args.global_batch,
                      training=not args.inference, noc_mode=args.noc_mode,
                      boundary_mode=args.boundary_mode,
                      memory_cap=args.memory_cap,
                      engine=getattr(args, "engine", "event"),
                      metrics=_want_metrics(args))


def _sweep_call_kwargs(args) -> dict:
    kw = {"workers": None if args.workers < 0 else args.workers,
          "profile": getattr(args, "profile", False)}
    if args.search != "exhaustive":
        kw.update(strategy=args.search, search_budget=args.search_budget,
                  seed=args.seed or 0)
    elif args.search_budget is not None or args.seed is not None:
        # never let a "capped" sweep silently run the whole product
        raise ValueError("--search-budget/--seed only apply to guided "
                         "search; add --search {random,sh,evolve}")
    return kw


def _print_search_note(report) -> None:
    if report.search is not None:
        print(f"[search {report.search.summary()}]")


# (phase label, microseconds key, jobs key) rows of the --profile table;
# keys match repro.core.fastbatch.run_fast_batch's profile dict plus the
# sweep layer's fallback accounting
_PROFILE_PHASES = (
    ("compile", "compile_us", "batched_jobs"),
    ("batch-eval", "eval_us", "batched_jobs"),
    ("validate", "validate_us", "contended_jobs"),
    ("fallback", "fallback_us", "fallback_jobs"),
)


def _print_profile(report) -> None:
    prof = getattr(report, "profile", None)
    if prof is None:
        return
    print("[batched fast tier profile]")
    print(f"  {'phase':>10s} {'time (ms)':>10s} {'jobs':>6s}")
    for label, tkey, jkey in _PROFILE_PHASES:
        print(f"  {label:>10s} {prof.get(tkey, 0) / 1e3:>10.2f} "
              f"{prof.get(jkey, 0):>6d}")
    print(f"  {prof.get('groups', 0)} chain-shape group(s) over "
          f"{prof.get('batched_jobs', 0)} batched job(s); "
          f"{prof.get('scalar_jobs', 0)} scalar, "
          f"{prof.get('ineligible_jobs', 0)} ineligible")
    gens = prof.get("generations")
    if gens:                            # guided search: one row per rung
        print(f"  {'rung':>10s} {'jobs':>6s} {'batched':>8s} "
              f"{'eval (ms)':>10s}")
        for i, g in enumerate(gens):
            print(f"  {i:>10d} {g.get('jobs', 0):>6d} "
                  f"{g.get('batched_jobs', 0):>8d} "
                  f"{g.get('eval_us', 0) / 1e3:>10.2f}")


def _cmd_sweep(args) -> int:
    exp = _make_sweep_experiment(args)
    report = exp.sweep(**_sweep_call_kwargs(args))
    hw_note = (f", {report.num_hardware} hardware variants"
               if report.num_hardware > 1 else "")
    print(f"== sweep: {report.arch} on {report.hardware} "
          f"({report.executor}; {report.num_candidates} candidates{hw_note}, "
          f"{report.num_pruned_memory} memory-pruned, "
          f"{report.num_failed} failed) ==")
    _print_search_note(report)
    print(report.table(top=args.top))
    _print_profile(report)
    _emit_metrics(report, args)
    _emit(report, args.json)
    return 0 if report.runs else 1


def _cmd_plan(args) -> int:
    report = _make_sweep_experiment(args).sweep(**_sweep_call_kwargs(args))
    best = report.best
    if best is None:
        print("no feasible plan found", file=sys.stderr)
        return 1
    p = best.plan
    print(f"best plan for {report.arch} on {report.hardware}:")
    _print_search_note(report)
    if report.num_hardware > 1:
        print(f"  hardware: {best.hardware}  (co-design over "
              f"{report.num_hardware} variants)")
    print(f"  pp={p.pp} dp={p.dp} tp={p.tp} microbatch={p.microbatch} "
          f"schedule={p.schedule} layout={p.layout}")
    print(f"  -> {best.throughput:.3f} samples/s, bubble {best.bubble_ratio:.1%}, "
          f"peak memory {best.peak_memory_bytes / 1e9:.2f} GB/tile")
    _print_profile(report)
    _emit_metrics(report, args)
    if args.codesign_json is not None:
        spec_dict = report.best_hardware_dict()
        if spec_dict is None:
            print("error: --codesign-json needs a hardware search "
                  "(--hw-* axes)", file=sys.stderr)
            return 2
        from ..core.planner import CodesignResult
        res = CodesignResult(hardware=HardwareSpec.from_dict(spec_dict),
                             plan=p, run=best, report=report)
        text = res.to_json(indent=2)
        if str(args.codesign_json) == "-":
            print(text)
        else:
            args.codesign_json.write_text(text + "\n")
            print(f"[co-design recommendation written to {args.codesign_json}]")
    _emit(best if args.best_only else report, args.json)
    return 0


def _serving_workload(args):
    from ..serving.workload import WorkloadSpec, workload_from_json
    if args.replay is not None:
        return workload_from_json(args.replay.read_text())
    return WorkloadSpec(kind=args.workload, rate=args.rate,
                        num_requests=args.num_requests, seed=args.seed,
                        prompt_mean=args.prompt_mean, prompt_cv=args.prompt_cv,
                        decode_mean=args.decode_mean, decode_cv=args.decode_cv,
                        burst_factor=args.burst_factor,
                        burst_dwell_s=args.burst_dwell_s)


def _cmd_serve_sim(args) -> int:
    from ..serving.system import ServingSpec, simulate_serving
    from ..serving.workload import workload_to_json
    workload = _serving_workload(args)
    spec = ServingSpec(workload=workload,
                       slo_ttft_ms=args.slo_ttft_ms,
                       slo_tpot_ms=args.slo_tpot_ms,
                       max_batch=args.max_batch,
                       kv_budget_bytes=args.kv_budget,
                       policy=args.policy,
                       ctx_bucket=args.ctx_bucket)
    plan = None
    if args.dp != 1 or args.tp != 1 or args.pp != 1:
        plan = ParallelPlan(pp=args.pp, dp=args.dp, tp=args.tp,
                            microbatch=1, global_batch=args.dp,
                            schedule=Schedule.GPIPE, training=False)
    want_trace = args.trace_out is not None or args.trace_npz is not None
    report = simulate_serving(args.arch, _resolve_hardware_args(args), plan,
                              spec, noc_mode=args.noc_mode,
                              boundary_mode=args.boundary_mode,
                              collect_trace=want_trace,
                              metrics=_want_metrics(args))
    print(report.summary())
    if args.workload_out is not None:
        args.workload_out.write_text(
            workload_to_json(workload.generate()) + "\n")
        print(f"[replayable workload trace written to {args.workload_out}]")
    if want_trace:
        trace = report.trace
        if args.trace_out is not None:
            from ..core.trace import chrome_trace
            from ..obs.tracks import serving_counters
            doc = chrome_trace(trace, label=f"{report.arch}@{report.hardware}",
                               counters=serving_counters(report))
            text = json.dumps(doc)
            if str(args.trace_out) == "-":
                print(text)
            else:
                args.trace_out.write_text(text + "\n")
                print(f"[serving trace written to {args.trace_out}: "
                      f"{len(trace)} spans]")
        if args.trace_npz is not None:
            trace.to_npz(args.trace_npz)
            print(f"[columnar trace written to {args.trace_npz}]")
    _emit_metrics(report, args)
    _emit(report, args.json)
    return 0


def _cmd_serve_plan(args) -> int:
    from ..serving.planner import plan_serving
    try:
        mesh, report = plan_serving(
            args.arch, _resolve_hardware_args(args), batch=args.batch,
            context_len=args.context_len, workers=args.workers,
            memory_cap=args.memory_cap)
    except RuntimeError as e:           # infeasibility, with diagnostics
        print(f"error: {e}", file=sys.stderr)
        return 1
    best = report.best
    print(f"best serving split for {report.arch} on {report.hardware}: "
          f"data={mesh['data']} model={mesh['model']} "
          f"({best.throughput:.3f} decode steps/s over "
          f"{report.num_candidates} splits, "
          f"{report.num_pruned_memory} memory-pruned, "
          f"{report.num_failed} failed)")
    _emit(report, args.json)
    return 0


def _load_trace(path: Path):
    """Load a columnar trace: ``.npz`` (``simulate --trace-npz``) or a
    JSON file holding ``Trace.to_dict()`` (or a RunReport dict embedding
    one under ``"trace"``)."""
    from ..core.trace import Trace
    if path.suffix == ".npz":
        try:
            return Trace.from_npz(path)
        except RuntimeError as e:       # numpy-free install
            raise ValueError(str(e))
    doc = json.loads(path.read_text())
    if "traceEvents" in doc:
        raise ValueError(
            f"{path} is a Chrome traceEvents export; trace-diff needs the "
            "columnar form (simulate --trace-npz, or a report with an "
            "embedded trace dict)")
    if "trace" in doc and isinstance(doc["trace"], dict):
        doc = doc["trace"]
    if "stage" not in doc:
        raise ValueError(f"{path} does not contain a columnar trace dict")
    return Trace.from_dict(doc)


def _cmd_trace_diff(args) -> int:
    """Diff two timelines (hardware / plan A/B studies)."""
    from ..core.trace import diff
    d = diff(_load_trace(args.a), _load_trace(args.b))
    print(f"trace diff: {args.a} (A) vs {args.b} (B)")
    print(d.table(top=args.top))
    _emit(d, args.json)
    return 0


def _cmd_metrics(args) -> int:
    """Summarize the repro.obs metrics document embedded in a report JSON
    (``simulate/sweep/plan/serve-sim --json`` run with ``--metrics``), a
    bare metrics document (``--metrics-out``), or — with ``--runs`` — the
    per-run metrics inside a SweepReport."""
    from ..obs.registry import summarize_metrics
    doc = json.loads(args.report.read_text())
    if "metrics" in doc or "runs" in doc:       # a report document
        metrics = doc.get("metrics")
        title = f"{doc.get('arch', '?')} on {doc.get('hardware', '?')}"
    elif "sim" in doc or "host" in doc:         # a bare metrics document
        metrics, title = doc, str(args.report)
    else:
        metrics, title = None, None
    if args.runs:
        shown = 0
        for run in doc.get("runs", []):
            m = run.get("metrics")
            if m is None:
                continue
            plan = run.get("plan", {})
            label = (f"pp={plan.get('pp')} dp={plan.get('dp')} "
                     f"tp={plan.get('tp')} mb={plan.get('microbatch')} "
                     f"on {run.get('hardware', '?')}")
            print(summarize_metrics(m, title=label))
            shown += 1
        if not shown:
            print("error: no per-run metrics in this report; re-run the "
                  "sweep with --metrics", file=sys.stderr)
            return 1
        return 0
    if metrics is None:
        print(f"error: {args.report} carries no metrics document; re-run "
              "with --metrics (or --metrics-out)", file=sys.stderr)
        return 1
    if args.json is not None:
        text = json.dumps(metrics, indent=2)
        if str(args.json) == "-":
            print(text)
        else:
            args.json.write_text(text + "\n")
            print(f"[metrics written to {args.json}]")
        return 0
    print(summarize_metrics(metrics, title=title))
    return 0


def _cmd_hardware(args) -> int:
    """Dump a resolved HardwareSpec as JSON (the --hardware-json schema)."""
    hw = _resolve_hardware_args(args)
    spec = resolve_hardware(hw) if isinstance(hw, str) else hw
    text = spec.to_json(indent=2)
    if args.json is None or str(args.json) == "-":
        print(text)
    else:
        args.json.write_text(text + "\n")
        print(f"[hardware spec written to {args.json}]", file=sys.stderr)
    return 0


def _cmd_fabric(args) -> int:
    """Dump a FabricSpec as JSON (the --fabric-json schema)."""
    from ..fabric import FABRIC_PRESETS, FabricSpec
    if args.fabric_json is not None:
        spec = FabricSpec.from_json(args.fabric_json.read_text())
    else:
        builder = FABRIC_PRESETS.get(args.preset)
        if builder is None:
            raise ValueError(f"unknown fabric preset {args.preset!r}; "
                             f"known: {', '.join(sorted(FABRIC_PRESETS))}")
        spec = builder()
    text = spec.to_json(indent=2)
    if args.json is None or str(args.json) == "-":
        print(text)
    else:
        args.json.write_text(text + "\n")
        print(f"[fabric spec written to {args.json}]", file=sys.stderr)
    return 0


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro",
        description="PALM performance simulator — typed Experiment front door")
    sub = ap.add_subparsers(dest="command", required=True)

    sim = sub.add_parser("simulate", help="simulate one fixed parallel plan")
    _add_common(sim)
    _add_plan_flags(sim)
    _add_metrics_flags(sim)
    sim.set_defaults(fn=_cmd_simulate)

    swp = sub.add_parser("sweep", help="rank a (hardware x) parallelism search space")
    _add_common(swp)
    _add_sweep_flags(swp)
    _add_metrics_flags(swp)
    swp.set_defaults(fn=_cmd_sweep)

    pln = sub.add_parser("plan", help="print the best plan for an arch/hardware")
    _add_common(pln)
    _add_sweep_flags(pln)
    _add_metrics_flags(pln)
    pln.add_argument("--best-only", action="store_true",
                     help="with --json, write only the best RunReport")
    pln.add_argument("--codesign-json", type=Path, default=None, metavar="FILE",
                     help="with --hw-* axes, write the co-design "
                          "recommendation (winning hardware spec JSON + "
                          "plan) here ('-' for stdout)")
    pln.set_defaults(fn=_cmd_plan)

    ssv = sub.add_parser(
        "serve-sim",
        help="traffic-driven serving simulation (continuous batching, "
             "KV-cache pressure, TTFT/TPOT/goodput SLO metrics)")
    ssv.add_argument("--arch", required=True,
                     help=f"arch-config name (e.g. {', '.join(list_archs()[:3])})")
    _add_hardware(ssv)
    wl = ssv.add_argument_group("workload (seeded request traffic)")
    wl.add_argument("--workload", default="poisson",
                    choices=["poisson", "bursty"],
                    help="arrival process (bursty = 2-state MMPP)")
    wl.add_argument("--rate", type=float, default=4.0,
                    help="offered request rate (req/s)")
    wl.add_argument("--num-requests", type=int, default=64)
    wl.add_argument("--seed", type=int, default=0)
    wl.add_argument("--prompt-mean", type=int, default=512)
    wl.add_argument("--prompt-cv", type=float, default=0.0,
                    help="lognormal coefficient of variation (0 = fixed)")
    wl.add_argument("--decode-mean", type=int, default=64)
    wl.add_argument("--decode-cv", type=float, default=0.0)
    wl.add_argument("--burst-factor", type=float, default=4.0,
                    help="bursty only: burst-state rate multiplier")
    wl.add_argument("--burst-dwell-s", type=float, default=2.0,
                    help="bursty only: mean dwell per MMPP state (s)")
    wl.add_argument("--replay", type=Path, default=None, metavar="FILE",
                    help="replay a recorded workload trace JSON "
                         "(overrides the generator flags)")
    wl.add_argument("--workload-out", type=Path, default=None, metavar="FILE",
                    help="write the generated workload as a replayable "
                         "trace JSON")
    sv = ssv.add_argument_group("serving engine")
    sv.add_argument("--slo-ttft-ms", type=float, default=2000.0,
                    help="time-to-first-token SLO (ms)")
    sv.add_argument("--slo-tpot-ms", type=float, default=200.0,
                    help="time-per-output-token SLO (ms)")
    sv.add_argument("--max-batch", type=int, default=32)
    sv.add_argument("--policy", default="continuous",
                    choices=["continuous", "static"],
                    help="continuous = iteration-level admission; static = "
                         "batches drain fully before the next forms")
    sv.add_argument("--kv-budget", type=float, default=None,
                    help="KV-cache byte budget (default: derived from DRAM "
                         "headroom after weights/activations)")
    sv.add_argument("--ctx-bucket", type=int, default=512,
                    help="context-length rounding for step-cost memoization")
    sv.add_argument("--pp", type=int, default=1)
    sv.add_argument("--dp", type=int, default=1)
    sv.add_argument("--tp", type=int, default=1)
    ssv.add_argument("--noc-mode", type=NoCMode, choices=list(NoCMode),
                     default=NoCMode.MACRO)
    ssv.add_argument("--boundary-mode", type=BoundaryMode,
                     choices=list(BoundaryMode), default=BoundaryMode.PAIRWISE)
    ssv.add_argument("--trace-out", type=Path, default=None, metavar="FILE",
                     help="write the per-request serving timeline as "
                          "Chrome/Perfetto traceEvents JSON ('-' for stdout)")
    ssv.add_argument("--trace-npz", type=Path, default=None, metavar="FILE",
                     help="write the columnar trace as .npz (needs numpy)")
    ssv.add_argument("--json", type=Path, default=None, metavar="FILE",
                     help="write the ServingReport JSON here ('-' for stdout)")
    _add_metrics_flags(ssv)
    ssv.set_defaults(fn=_cmd_serve_sim)

    spl = sub.add_parser(
        "serve-plan",
        help="pick the best (data, model) serving split by simulated "
             "decode throughput")
    spl.add_argument("--arch", required=True,
                     help=f"arch-config name (e.g. {', '.join(list_archs()[:3])})")
    _add_hardware(spl)
    spl.add_argument("--batch", type=int, default=8,
                     help="decode batch the split must serve")
    spl.add_argument("--context-len", type=int, default=4096,
                     help="KV-cache context length for the decode step")
    spl.add_argument("--workers", type=int, default=0,
                     help="0 = serial, N = process pool of N")
    spl.add_argument("--memory-cap", type=float, default=None,
                     help="bytes per tile; infeasible splits are pruned and "
                          "explained (per-split deficits) when nothing fits")
    spl.add_argument("--json", type=Path, default=None, metavar="FILE",
                     help="write the SweepReport JSON here ('-' for stdout)")
    spl.set_defaults(fn=_cmd_serve_plan)

    tdf = sub.add_parser(
        "trace-diff",
        help="diff two simulation timelines (per-stage/per-lane busy & "
             "bubble deltas; A/B hardware studies)")
    tdf.add_argument("a", type=Path, help="baseline trace (.npz or trace-dict JSON)")
    tdf.add_argument("b", type=Path, help="comparison trace (.npz or trace-dict JSON)")
    tdf.add_argument("--top", type=int, default=10,
                     help="NoC/DRAM lanes shown, ranked by |occupancy delta|")
    tdf.add_argument("--json", type=Path, default=None, metavar="FILE",
                     help="write the full diff JSON here ('-' for stdout)")
    tdf.set_defaults(fn=_cmd_trace_diff)

    mtr = sub.add_parser(
        "metrics",
        help="summarize the repro.obs metrics inside a report JSON "
             "(produced by --metrics / --metrics-out)")
    mtr.add_argument("report", type=Path,
                     help="RunReport/SweepReport/ServingReport JSON, or a "
                          "bare metrics document")
    mtr.add_argument("--runs", action="store_true",
                     help="summarize each run's metrics inside a "
                          "SweepReport instead of the sweep roll-up")
    mtr.add_argument("--json", type=Path, default=None, metavar="FILE",
                     help="re-emit the metrics document as JSON ('-' for "
                          "stdout) instead of the text summary")
    mtr.set_defaults(fn=_cmd_metrics)

    hwc = sub.add_parser(
        "hardware",
        help="dump a hardware preset as tweakable --hardware-json JSON")
    _add_hardware(hwc)
    hwc.add_argument("--json", type=Path, default=None, metavar="FILE",
                     help="write the spec here instead of stdout")
    hwc.set_defaults(fn=_cmd_hardware)

    fbc = sub.add_parser(
        "fabric",
        help="dump a fabric preset as tweakable --fabric-json JSON")
    fbc.add_argument("--preset", default="cluster_2x2",
                     help="fabric preset: board_pair, cluster_2x2, "
                          "rack_2x2x2")
    fbc.add_argument("--fabric-json", type=Path, default=None, metavar="FILE",
                     help="round-trip this FabricSpec JSON file instead of "
                          "a preset (validates the schema)")
    fbc.add_argument("--json", type=Path, default=None, metavar="FILE",
                     help="write the spec here instead of stdout")
    fbc.set_defaults(fn=_cmd_fabric)

    args = ap.parse_args(argv)
    try:
        return args.fn(args)
    except (ValueError, KeyError) as e:   # spec errors, not crashes
        print(f"error: {e}", file=sys.stderr)
        return 2
