"""Declarative experiment spec — the canonical PALM front door.

An :class:`Experiment` names a workload (an arch-config registry entry or
an explicit :class:`ArchConfig` / :class:`ComputationGraph`), a hardware
spec (preset name, :class:`HardwareSpec`, or a ``--hardware-json`` file),
and either one fixed :class:`ParallelPlan` or a typed :class:`SearchSpace`
to sweep — optionally crossed with a :class:`HardwareSearchSpace` so one
sweep ranks hardware x parallelism points (the paper's §VI hardware
exploration). It validates eagerly — bad pp/dp/tp factorizations, unknown
schedules, or unsatisfiable batch settings fail before any simulation
starts — which is what makes thousand-point sweeps practical.

    from repro.api import Experiment, SearchSpace, Schedule

    exp = Experiment(arch="yi-6b", hardware="wafer_scale",
                     search=SearchSpace(schedules=(Schedule.ONE_F_ONE_B,)),
                     global_batch=128, seq_len=2048)
    report = exp.sweep(workers=8)      # SweepReport, ranked best-first
"""

from __future__ import annotations

import dataclasses
import functools
import itertools
import json
from dataclasses import dataclass, field
from typing import (
    TYPE_CHECKING,
    Callable,
    Dict,
    List,
    Optional,
    Sequence,
    Tuple,
    Union,
)

from ..configs import get_config
from ..configs.base import ArchConfig
from ..core.enums import BoundaryMode, Layout, NoCMode, Schedule
from ..core.graph import ComputationGraph
from ..core.hardware import (
    HARDWARE_PRESETS,
    GPUClusterSpec,
    HardwareSpec,
    HierarchicalSpec,
    MeshSpec,
    TopologySpec,
    a100_cluster,
    tpu_v5e_pod,
)
from ..core.parallelism import ParallelPlan
from ..core.workload import arch_to_graph
from ..serving.system import ServingSpec
from .report import RunReport, SweepReport

if TYPE_CHECKING:
    from .sweep import SweepEngine

__all__ = ["Experiment", "SearchSpace", "HardwareSearchSpace",
           "resolve_hardware", "HARDWARE_PRESETS"]


def resolve_hardware(hw: Union[str, HardwareSpec],
                     d_model: Optional[int] = None) -> HardwareSpec:
    """Accept a HardwareSpec or a preset name (``a100x<N>`` builds a GPU
    cluster of N devices, ``tpu_v5e_<R>x<C>`` a pod slice,
    ``tpu_v5e_torus_<R>x<C>`` the same slice with wraparound ICI links).

    ``d_model`` selects the point on the a100 sustained-GEMM efficiency
    curve (cuBLAS efficiency grows with matrix size); it is only
    meaningful for ``a100x<N>`` names.
    """
    if isinstance(hw, HardwareSpec):
        if d_model is not None:
            raise ValueError("d_model calibration applies to the a100x<N> "
                             "preset name, not an explicit HardwareSpec")
        return hw
    if not isinstance(hw, str):
        raise TypeError(f"hardware must be HardwareSpec or str, got {type(hw).__name__}")
    if hw.startswith("a100x"):
        try:
            return a100_cluster(int(hw[len("a100x"):]), d_model=d_model)
        except ValueError:
            pass
    if d_model is not None:
        raise ValueError(f"d_model calibration only applies to a100x<N>, "
                         f"not {hw!r}")
    if hw in HARDWARE_PRESETS:
        return HARDWARE_PRESETS[hw]()
    for prefix, torus in (("tpu_v5e_torus_", True), ("tpu_v5e_", False)):
        if hw.startswith(prefix):        # e.g. tpu_v5e_4x4, tpu_v5e_torus_4x4
            try:
                rows, cols = hw[len(prefix):].split("x")
                return tpu_v5e_pod(int(rows), int(cols), torus=torus)
            except ValueError:
                pass
    raise ValueError(f"unknown hardware preset {hw!r}; known: "
                     f"{sorted(HARDWARE_PRESETS) + ['a100x<N>', 'tpu_v5e_<R>x<C>', 'tpu_v5e_torus_<R>x<C>']}")


def _divisor_splits(n: int) -> List[Tuple[int, int, int]]:
    """(pp, dp, tp) triples with pp*dp*tp == n."""
    out = []
    for pp in (d for d in range(1, n + 1) if n % d == 0):
        rest = n // pp
        for dp in (d for d in range(1, rest + 1) if rest % d == 0):
            out.append((pp, dp, rest // dp))
    return out


@dataclass
class SearchSpace:
    """Typed sweep axes for parallelism search (§V-B).

    ``degrees`` fixes explicit (pp, dp, tp) triples; when ``None`` every
    divisor factorization of the device count is considered, filtered by
    arch shape (pp bounded by layer count, tp by head/feature count).
    ``interleave`` sweeps virtual-stage counts (interleaved 1F1B),
    ``zero_stages`` the ZeRO optimizer-sharding stage,
    ``comm_strategies`` the inter-tile-group boundary strategy (Fig. 11;
    only distinguishable under ``BoundaryMode.STRATEGY``), and
    ``activation_offload`` whether saved activations are parked off-device
    between FD and BD (smaller footprint, extra DRAM traffic — the
    pre-simulation memory-cap estimate accounts for it, so pruning stays
    exact).
    """

    degrees: Optional[Sequence[Tuple[int, int, int]]] = None
    schedules: Sequence[Schedule] = (Schedule.ONE_F_ONE_B,)
    layouts: Sequence[Layout] = (Layout.S_SHAPE, Layout.LINE)
    microbatch_sizes: Sequence[int] = (1, 2, 4)
    tp_contiguous: Sequence[bool] = (True,)
    interleave: Sequence[int] = (1,)
    zero_stages: Sequence[int] = (0,)
    comm_strategies: Sequence[int] = (1,)
    activation_offload: Sequence[bool] = (False,)
    max_plans: int = 64

    def __post_init__(self):
        self.schedules = tuple(Schedule(s) for s in self.schedules)
        self.layouts = tuple(Layout(l) for l in self.layouts)
        if self.max_plans < 1:
            raise ValueError("max_plans must be >= 1")
        if any(b < 1 for b in self.microbatch_sizes):
            raise ValueError("microbatch sizes must be >= 1")
        if any(v < 1 for v in self.interleave):
            raise ValueError("interleave degrees must be >= 1")
        if any(z not in (0, 1, 2, 3) for z in self.zero_stages):
            raise ValueError("zero_stages must be in 0..3")
        if any(c not in (1, 2) for c in self.comm_strategies):
            raise ValueError("comm_strategies must be 1 or 2 (Fig. 11)")
        self.activation_offload = tuple(bool(v) for v in self.activation_offload)

    def enumerate_plans(self, hardware: HardwareSpec, global_batch: int,
                        training: bool = True,
                        arch: Optional[ArchConfig] = None) -> List[ParallelPlan]:
        """Materialize the plan list, arch-filtered and budget-pruned
        (diverse (pp, dp, tp) triples are kept first)."""
        n = hardware.num_devices
        triples = list(self.degrees) if self.degrees is not None else _divisor_splits(n)
        plans: List[ParallelPlan] = []
        for (pp, dp, tp) in triples:
            if pp * dp * tp > n:
                raise ValueError(
                    f"plan (pp={pp}, dp={dp}, tp={tp}) needs {pp * dp * tp} "
                    f"devices but {hardware.name} has {n}")
            if arch is not None:
                if pp > max(1, arch.num_layers):
                    continue
                if tp > max(arch.n_heads, arch.d_model // 64, 1):
                    continue
            for b in self.microbatch_sizes:
                if global_batch % (b * dp):
                    continue
                for sched in (self.schedules if training else (Schedule.GPIPE,)):
                    for layout in self.layouts:
                        for contig in self.tp_contiguous:
                            for virt in self.interleave:
                                if virt > 1 and pp == 1:
                                    continue   # interleaving needs a pipeline
                                if arch is not None and \
                                        pp * virt > max(1, arch.num_layers):
                                    continue
                                for zero in self.zero_stages:
                                    for strat in self.comm_strategies:
                                        for off in (self.activation_offload
                                                    if training else (False,)):
                                            plans.append(ParallelPlan(
                                                pp=pp, dp=dp, tp=tp, microbatch=b,
                                                global_batch=global_batch,
                                                schedule=sched, layout=layout,
                                                tp_contiguous=contig,
                                                interleave=virt, zero=zero,
                                                comm_strategy=strat,
                                                activation_offload=off,
                                                training=training))
        # budget: prefer diverse (pp, dp, tp) triples first
        seen, pruned = set(), []
        for p in plans:
            key = (p.pp, p.dp, p.tp)
            if key not in seen or len(pruned) < self.max_plans // 2:
                pruned.append(p)
                seen.add(key)
            if len(pruned) >= self.max_plans:
                break
        return pruned


# ---------------------------------------------------------------------------
# Hardware search space (§VI hardware exploration)
# ---------------------------------------------------------------------------

def _fmt(v: float) -> str:
    """Compact axis value for variant names: 16e12 -> '16T', 2.56e11 -> '256G'."""
    for scale, suffix in ((1e12, "T"), (1e9, "G"), (1e6, "M"), (1e3, "K")):
        if abs(v) >= scale:
            x = v / scale
            return (f"{x:.0f}" if x == int(x) else f"{x:g}") + suffix
    return f"{v:g}"


@dataclass
class HardwareSearchSpace:
    """Sweep axes over a base :class:`HardwareSpec` (tile compute/SRAM, NoC
    bandwidths, mesh shape, DRAM channels/bandwidth).

    Each axis left empty keeps the base value; the cartesian product of
    the provided axes (capped at ``max_specs``) is materialized as derived
    HardwareSpecs via the declarative topology specs, so topology axes
    (``intra_bw``/``inter_bw``/``mesh_shapes``) require the base topology
    to be a :class:`MeshSpec` or :class:`HierarchicalSpec`.

    When the mesh shape changes, edge DRAM ports are re-placed evenly
    along the *same edges* they occupy in the base layout (per-edge counts
    preserved, so two-edge layouts like ``wafer_scale``'s west+east
    columns stay two-edge); interior ports count toward the west edge.
    """

    tile_flops: Sequence[float] = ()
    sram_bytes: Sequence[float] = ()
    intra_bw: Sequence[float] = ()
    inter_bw: Sequence[float] = ()
    mesh_shapes: Sequence[Tuple[int, int]] = ()
    dram_channels: Sequence[int] = ()
    dram_bandwidth: Sequence[float] = ()
    # scale-out fabric axes (base hardware must carry a FabricSpec):
    # bandwidth of the outermost fabric level, and the cross-chip
    # collective family ("hierarchical"/"ring"/"tree"/"hd")
    fabric_bw: Sequence[float] = ()
    fabric_collectives: Sequence[str] = ()
    max_specs: int = 32

    def __post_init__(self):
        self.mesh_shapes = tuple((int(r), int(c)) for r, c in self.mesh_shapes)
        if self.max_specs < 1:
            raise ValueError("max_specs must be >= 1")
        from ..fabric.spec import COLLECTIVE_FAMILIES  # pure data, no cycle
        for fam in self.fabric_collectives:
            if fam not in COLLECTIVE_FAMILIES:
                raise ValueError(
                    f"unknown fabric collective {fam!r}; "
                    f"expected one of {COLLECTIVE_FAMILIES}")

    # axis name -> (values, variant-name tag, formatter)
    def _axes(self):
        return [
            ("tile_flops", self.tile_flops, "flops", _fmt),
            ("sram_bytes", self.sram_bytes, "sram", _fmt),
            ("intra_bw", self.intra_bw, "intra", _fmt),
            ("inter_bw", self.inter_bw, "inter", _fmt),
            ("mesh_shape", self.mesh_shapes, "mesh", lambda v: f"{v[0]}x{v[1]}"),
            ("dram_channels", self.dram_channels, "ch", str),
            ("dram_bandwidth", self.dram_bandwidth, "dram", _fmt),
            ("fabric_bw", self.fabric_bw, "fab", _fmt),
            ("fabric_collective", self.fabric_collectives, "coll", str),
        ]

    def enumerate_specs(self, base: HardwareSpec) -> List[HardwareSpec]:
        """Derived HardwareSpecs (cartesian product of the provided axes),
        capped at ``max_specs``."""
        axes = [(name, tuple(vals) or (None,), tag, fmt)
                for name, vals, tag, fmt in self._axes()]
        specs: List[HardwareSpec] = []
        for combo in itertools.product(*(vals for _, vals, _, _ in axes)):
            if len(specs) >= self.max_specs:
                break
            chosen = {name: v for (name, _, _, _), v in zip(axes, combo)
                      if v is not None}
            tags = [f"{tag}{fmt(chosen[name])}"
                    for name, _, tag, fmt in axes if name in chosen]
            specs.append(self._derive(base, chosen, tags))
        return specs

    def _derive(self, base: HardwareSpec, chosen: dict,
                tags: List[str]) -> HardwareSpec:
        tile = base.tile
        if "tile_flops" in chosen:
            tile = dataclasses.replace(tile, flops=chosen["tile_flops"])
        if "sram_bytes" in chosen:
            tile = dataclasses.replace(tile, sram_bytes=chosen["sram_bytes"])
        dram = base.dram
        if "dram_channels" in chosen:
            dram = dataclasses.replace(dram, channels=chosen["dram_channels"])
        if "dram_bandwidth" in chosen:
            dram = dataclasses.replace(dram, bandwidth=chosen["dram_bandwidth"])

        topo_axes = {k: chosen[k] for k in ("intra_bw", "inter_bw", "mesh_shape")
                     if k in chosen}
        topo_spec: Optional[TopologySpec] = base.topology_spec
        dram_ports = base.dram_ports
        if topo_axes:
            if topo_spec is None:
                raise ValueError(
                    f"hardware {base.name!r} has no declarative topology spec; "
                    "topology axes (intra_bw/inter_bw/mesh_shapes) need one")
            new_spec = self._mutate_topology(topo_spec, topo_axes)
            if "mesh_shape" in topo_axes and dram_ports:
                dram_ports = _replace_edge_ports(topo_spec, new_spec,
                                                 dram_ports)
            topo_spec = new_spec

        fabric = base.fabric
        fabric_axes = {k for k in ("fabric_bw", "fabric_collective")
                       if k in chosen}
        if fabric_axes:
            if fabric is None:
                raise ValueError(
                    f"hardware {base.name!r} has no fabric spec; fabric axes "
                    "(fabric_bw/fabric_collectives) need one")
            if "fabric_bw" in chosen:
                # the outermost level is the usual bottleneck — that's the
                # knob worth sweeping
                top = fabric.num_levels - 1
                fabric = fabric.with_level(top, bandwidth=chosen["fabric_bw"])
            if "fabric_collective" in chosen:
                fabric = dataclasses.replace(
                    fabric, collective=chosen["fabric_collective"])

        name = base.name + ("~" + "~".join(tags) if tags else "")
        return HardwareSpec(
            name=name,
            topology=topo_spec if topo_spec is not None else base.topology,
            tile=tile, dram=dram, dram_ports=dram_ports,
            precision_bytes=base.precision_bytes, fabric=fabric)

    @staticmethod
    def _mutate_topology(spec: TopologySpec, axes: dict) -> TopologySpec:
        if isinstance(spec, MeshSpec):
            kw = {}
            if "intra_bw" in axes:
                kw["intra_bw"] = axes["intra_bw"]
            if "inter_bw" in axes:
                kw["inter_bw"] = axes["inter_bw"]
            if "mesh_shape" in axes:
                kw["rows"], kw["cols"] = axes["mesh_shape"]
                tr, tc = spec.tile_shape
                if kw["rows"] % tr or kw["cols"] % tc:
                    # silently flattening to tile_shape (1,1) would turn every
                    # link into a slow inter-tile hop — refuse instead
                    raise ValueError(
                        f"mesh shape {kw['rows']}x{kw['cols']} does not divide "
                        f"the base tile_shape {spec.tile_shape}; pick divisible "
                        "shapes (or use a HierarchicalSpec base, where "
                        "mesh_shapes varies the inter-tile grid)")
            return dataclasses.replace(spec, **kw)
        if isinstance(spec, HierarchicalSpec):
            kw = {}
            if "intra_bw" in axes:
                kw["tile"] = dataclasses.replace(spec.tile,
                                                 intra_bw=axes["intra_bw"])
            if "inter_bw" in axes:
                kw["inter_bw"] = axes["inter_bw"]
            if "mesh_shape" in axes:
                # mesh_shape names the inter-tile grid for hierarchical specs
                kw["grid_rows"], kw["grid_cols"] = axes["mesh_shape"]
            return dataclasses.replace(spec, **kw)
        if isinstance(spec, GPUClusterSpec):
            kw = {}
            if "intra_bw" in axes:
                kw["nvlink_bw"] = axes["intra_bw"]
            if "inter_bw" in axes:
                kw["nic_bw"] = axes["inter_bw"]
            if "mesh_shape" in axes:
                raise ValueError("mesh_shapes does not apply to a GPU cluster; "
                                 "sweep hardware names (a100x<N>) instead")
            return dataclasses.replace(spec, **kw)
        raise ValueError(f"cannot sweep topology axes of {type(spec).__name__}")


# deterministic edge order for placement and tie-breaking
_EDGE_ORDER = ("west", "east", "north", "south")


def _flat_mesh(spec: TopologySpec) -> MeshSpec:
    return spec.flatten() if isinstance(spec, HierarchicalSpec) else spec


def _replace_edge_ports(base: TopologySpec, new: TopologySpec,
                        ports: Sequence[int]) -> Tuple[int, ...]:
    """Re-place DRAM ports on a re-shaped mesh, preserving the base
    layout's per-edge distribution.

    Each base port is attributed to the edge it lies on (corner ports go
    to whichever of their edges carries more ports overall, so e.g.
    ``wafer_scale``'s west+east columns stay a two-edge layout and
    ``grayskull``'s top row stays north); interior ports count toward the
    west edge. Each edge's ports are then spread evenly along the same
    edge of the new mesh, capped at the edge length.
    """
    base_mesh, new_mesh = _flat_mesh(base), _flat_mesh(new)
    membership = [base_mesh.device_edges(p) or ("west",) for p in ports]
    totals = {e: sum(e in m for m in membership) for e in _EDGE_ORDER}
    counts = dict.fromkeys(_EDGE_ORDER, 0)
    for edges in membership:
        best = max(edges, key=lambda e: (totals[e], -_EDGE_ORDER.index(e)))
        counts[best] += 1
    placed: Dict[int, None] = {}            # ordered, collision-free
    for edge in _EDGE_ORDER:
        devs = new_mesh.edge_devices(edge)
        k = min(counts[edge], len(devs))
        for i in range(k):
            want = (i * len(devs)) // k
            # a corner shared with an already-placed edge would silently
            # drop a port — slide to the nearest free device on this edge
            for offset in range(len(devs)):
                cand = devs[(want + offset) % len(devs)]
                if cand not in placed:
                    placed[cand] = None
                    break
    return tuple(placed)


@dataclass
class Experiment:
    """One declarative simulation/sweep spec. Exactly one of ``plan`` /
    ``search`` drives it: a fixed plan means :meth:`run`, a search space
    means :meth:`sweep`. Adding a ``hardware_search`` crosses either with
    hardware variants derived from ``hardware``."""

    arch: Union[str, ArchConfig, None] = None
    hardware: Union[str, HardwareSpec] = "wafer_scale"
    plan: Optional[ParallelPlan] = None
    search: Optional[SearchSpace] = None
    hardware_search: Optional[HardwareSearchSpace] = None
    graph_builder: Optional[Callable[[ParallelPlan], ComputationGraph]] = None
    seq_len: int = 2048
    global_batch: int = 256
    training: bool = True
    decode: bool = False                # serve-step graphs (1-token decode)
    noc_mode: NoCMode = NoCMode.MACRO
    boundary_mode: BoundaryMode = BoundaryMode.PAIRWISE
    memory_cap: Optional[float] = None  # bytes per tile; pre-sim feasibility
    # record NoC/DRAM busy-interval lanes into the trace (compute lanes are
    # always recorded); in sweeps this also implies return_timelines
    collect_timeline: bool = False
    # score candidates with the traffic-driven serving simulator instead
    # of one pipeline iteration: RunReport.throughput becomes SLO goodput
    # and the full ServingReport rides in RunReport.extra["serving"]
    serving: Optional[ServingSpec] = None
    # simulator tier (repro.core.fastpath): "event" always runs the heap
    # kernel, "auto" takes the bit-identical closed-form fast tier when
    # the run is contention-free, "fast" demands it (raises otherwise).
    # A multi-fidelity rung's own ``engine`` overrides this per rung.
    engine: str = "event"
    # record repro.obs metrics: sim-domain documents attach to every
    # RunReport (and a job-order aggregate + merged host registry to
    # SweepReport.metrics). Off by default — the disabled path is the
    # no-op registry and adds zero rows and zero overhead.
    metrics: bool = False

    def __post_init__(self):
        self.noc_mode = NoCMode(self.noc_mode)
        self.boundary_mode = BoundaryMode(self.boundary_mode)
        self.validate()

    # -- resolution ---------------------------------------------------------
    @property
    def arch_config(self) -> Optional[ArchConfig]:
        if self.arch is None:
            return None
        return get_config(self.arch) if isinstance(self.arch, str) else self.arch

    @functools.cached_property
    def hardware_spec(self) -> HardwareSpec:
        # cached: sweeps resolve the spec once per Experiment (per process),
        # not once per plan evaluation
        return resolve_hardware(self.hardware)

    @property
    def arch_name(self) -> str:
        cfg = self.arch_config
        return cfg.name if cfg is not None else "<custom graph>"

    def build_graph(self, plan: ParallelPlan) -> ComputationGraph:
        """Graph for one plan (per-iteration batch = microbatch * dp)."""
        if self.graph_builder is not None:
            return self.graph_builder(plan)
        return arch_to_graph(self.arch_config, self.seq_len,
                             plan.microbatch * plan.dp,
                             training=self.training, decode=self.decode)

    # -- validation ---------------------------------------------------------
    def validate(self) -> None:
        if self.plan is None and self.search is None:
            raise ValueError("Experiment needs a fixed `plan` or a `search` space")
        if self.plan is not None and self.search is not None:
            raise ValueError("Experiment takes `plan` or `search`, not both")
        if self.arch is None and self.graph_builder is None:
            raise ValueError("Experiment needs an `arch` (registry name or "
                             "ArchConfig) or a custom `graph_builder`")
        if isinstance(self.arch, str):
            get_config(self.arch)       # raises KeyError with known names
        hw = self.hardware_spec          # raises on unknown preset
        if self.plan is not None:
            p = self.plan
            need = p.pp * p.dp * p.tp
            if need > hw.num_devices:
                raise ValueError(
                    f"plan (pp={p.pp}, dp={p.dp}, tp={p.tp}) needs {need} "
                    f"devices but {hw.name} has {hw.num_devices}")
            if p.global_batch % (p.microbatch * p.dp):
                raise ValueError(
                    f"global_batch {p.global_batch} not divisible by "
                    f"microbatch*dp = {p.microbatch * p.dp}")
        if self.seq_len < 1 or self.global_batch < 1:
            raise ValueError("seq_len and global_batch must be >= 1")
        if self.engine not in ("event", "auto", "fast"):
            raise ValueError(f"unknown engine {self.engine!r} "
                             "(expected 'event', 'auto' or 'fast')")
        if self.serving is not None:
            if self.training:
                raise ValueError("serving experiments score decode traffic; "
                                 "set training=False")
            if self.arch is None:
                raise ValueError("serving experiments need an `arch` (the KV "
                                 "model derives from the ArchConfig)")

    # -- execution ----------------------------------------------------------
    def run(self) -> RunReport:
        """Simulate the fixed plan; returns a RunReport."""
        if self.plan is None:
            raise ValueError("run() needs a fixed plan; use sweep() for a search")
        from .sweep import run_one          # local import: sweep imports report
        return run_one(self, self.plan)

    def sweep(self, workers: int = 0,
              return_timelines: bool = False,
              strategy: Optional[str] = None,
              search_budget: Optional[int] = None,
              seed: Optional[int] = None,
              engine: Optional["SweepEngine"] = None,
              profile: bool = False) -> SweepReport:
        """Evaluate the search space; ``workers=0`` is serial, ``workers=N``
        uses an N-process pool, ``workers=None`` uses all cores. With a
        ``hardware_search``, the full (hardware variant x plan) product is
        flattened into one job stream evaluated by a single shared pool
        and the merged report ranks hardware x parallelism points.
        ``return_timelines=True`` ships each run's columnar event timeline
        back on ``RunReport.trace`` — and the full :class:`SimResult` on
        ``RunReport.sim`` — in compressed struct-of-arrays form (reports
        stay scalar by default).

        ``strategy`` selects guided search (:mod:`repro.search`):
        ``"random"`` / ``"sh"`` / ``"evolve"`` evaluate only a budgeted
        subset of the space at full fidelity (``search_budget``, default
        a fifth of the space) and nest a :class:`SearchReport` into the
        result; ``None`` or ``"exhaustive"`` is the legacy exhaustive
        path, unchanged.

        ``engine`` lends an open (usually persistent, ``with``-entered)
        :class:`SweepEngine` whose warm process pool is reused instead of
        constructing one per call; it is used as-is and never closed, and
        its ``workers``/``return_timelines`` settings win over the
        same-named arguments here (see also
        :func:`repro.api.sweep.shared_engine` for the module-level
        registry the planners use).

        Fast-path-eligible jobs (experiment/fidelity ``engine`` of
        ``"auto"`` or ``"fast"``) are priced through the vectorized
        batched fast tier (:mod:`repro.core.fastbatch`) — bit-identical
        results, whole chain-shape groups per numpy pass.
        ``profile=True`` attaches its per-phase accounting
        (compile/batch-eval/validate/fallback) to
        ``SweepReport.profile`` — for guided search the totals span every
        generation and a ``generations`` sub-list carries the per-rung
        deltas."""
        return_timelines = return_timelines or self.collect_timeline
        if strategy not in (None, "exhaustive"):
            from ..search import run_search     # search builds on api
            return run_search(self, strategy=strategy, budget=search_budget,
                              seed=seed or 0, workers=workers,
                              return_timelines=return_timelines,
                              engine=engine, profile=profile)
        if search_budget is not None or seed is not None:
            # never let a "capped" sweep silently run the whole product
            raise ValueError("search_budget/seed only apply to guided "
                             "search; pass strategy='random'/'sh'/'evolve'")
        if self.hardware_search is not None:
            return self._sweep_hardware(workers, return_timelines, engine,
                                        profile=profile)
        if self.search is None:
            if self.plan is not None:   # degenerate single-point sweep
                plans = [self.plan]
            else:
                raise ValueError("sweep() needs a `search` space")
        else:
            plans = self.search.enumerate_plans(
                self.hardware_spec, self.global_batch,
                training=self.training, arch=self.arch_config)
        from .sweep import SweepEngine
        eng = engine if engine is not None else SweepEngine(
            workers=workers, return_timelines=return_timelines,
            trace_resources=self.collect_timeline, profile=profile)
        return eng.sweep(self, plans)

    def _hardware_label(self, num_hardware: int) -> str:
        """Report hardware name: the base spec for single-machine sweeps,
        a variant-count label for hardware x plan sweeps."""
        base = self.hardware_spec
        return (base.name if num_hardware == 1
                else f"{base.name} (x{num_hardware} hardware variants)")

    def _record_hardware_specs(self, report: SweepReport,
                               specs: Sequence[HardwareSpec]) -> None:
        """Store each kept variant's spec dict on the report so the
        winning machine is recoverable from the report alone."""
        for spec in specs:
            try:
                # normalize through JSON (tuples -> lists) so stored dicts
                # compare equal across a report to_json/from_json round-trip
                report.hardware_specs[spec.name] = json.loads(spec.to_json())
            except ValueError:
                pass        # custom topology without a declarative spec

    def _plans_for(self, spec: HardwareSpec) -> List[ParallelPlan]:
        """Plan list for one hardware variant (raises ValueError when the
        variant cannot host the fixed plan / explicit search degrees)."""
        if self.search is not None:
            return self.search.enumerate_plans(
                spec, self.global_batch,
                training=self.training, arch=self.arch_config)
        # fixed plan: reuse Experiment validation against this variant
        self.with_(hardware=spec, hardware_search=None)
        return [self.plan]

    def _sweep_hardware(self, workers: int,
                        return_timelines: bool = False,
                        engine: Optional["SweepEngine"] = None,
                        profile: bool = False) -> SweepReport:
        """Merged hardware x plan sweep: flatten every variant's plan list
        into one (variant, plan) job stream and evaluate it through one
        shared process pool (workers are initialized once with all variant
        specs; each worker's graph memo is shared across variants)."""
        from .sweep import Job, SweepEngine
        base = self.hardware_spec
        specs = self.hardware_search.enumerate_specs(base)
        kept: List[HardwareSpec] = []
        jobs: List[Job] = []
        failed = 0
        for spec in specs:
            try:
                # a variant can be too small for a fixed plan or for explicit
                # search degrees — count it failed, keep the other variants
                plans = self._plans_for(spec)
            except ValueError:
                failed += 1
                continue
            jobs.extend((len(kept), p) for p in plans)
            kept.append(spec)
        if engine is None:
            engine = SweepEngine(workers=workers,
                                 return_timelines=return_timelines,
                                 trace_resources=self.collect_timeline,
                                 profile=profile)
        report = engine.sweep_jobs(
            self, kept, jobs,
            hardware_name=self._hardware_label(len(specs)),
            num_hardware=len(specs),
            extra_failed=failed)
        self._record_hardware_specs(report, kept)
        return report

    def with_(self, **kw) -> "Experiment":
        return dataclasses.replace(self, **kw)
