"""Declarative experiment spec — the canonical PALM front door.

An :class:`Experiment` names a workload (an arch-config registry entry or
an explicit :class:`ArchConfig` / :class:`ComputationGraph`), a hardware
spec (preset name or instance), and either one fixed
:class:`ParallelPlan` or a typed :class:`SearchSpace` to sweep. It
validates eagerly — bad pp/dp/tp factorizations, unknown schedules, or
unsatisfiable batch settings fail before any simulation starts — which is
what makes thousand-point sweeps practical.

    from repro.api import Experiment, SearchSpace, Schedule

    exp = Experiment(arch="yi-6b", hardware="wafer_scale",
                     search=SearchSpace(schedules=(Schedule.ONE_F_ONE_B,)),
                     global_batch=128, seq_len=2048)
    report = exp.sweep(workers=8)      # SweepReport, ranked best-first
"""

from __future__ import annotations

import dataclasses
import functools
from dataclasses import dataclass, field
from typing import Callable, List, Optional, Sequence, Tuple, Union

from ..configs import get_config
from ..configs.base import ArchConfig
from ..core.enums import BoundaryMode, Layout, NoCMode, Schedule, coerce
from ..core.graph import ComputationGraph
from ..core.hardware import (
    HardwareSpec,
    a100_cluster,
    grayskull,
    tpu_v5e_pod,
    wafer_scale,
)
from ..core.parallelism import ParallelPlan
from ..core.workload import arch_to_graph
from .report import RunReport, SweepReport

__all__ = ["Experiment", "SearchSpace", "resolve_hardware", "HARDWARE_PRESETS"]

HARDWARE_PRESETS = {
    "grayskull": grayskull,
    "wafer_scale": wafer_scale,
    "tpu_v5e": tpu_v5e_pod,
}


def resolve_hardware(hw: Union[str, HardwareSpec]) -> HardwareSpec:
    """Accept a HardwareSpec or a preset name (``a100x<N>`` builds a GPU
    cluster of N devices)."""
    if isinstance(hw, HardwareSpec):
        return hw
    if not isinstance(hw, str):
        raise TypeError(f"hardware must be HardwareSpec or str, got {type(hw).__name__}")
    if hw in HARDWARE_PRESETS:
        return HARDWARE_PRESETS[hw]()
    if hw.startswith("a100x"):
        try:
            return a100_cluster(int(hw[len("a100x"):]))
        except ValueError:
            pass
    if hw.startswith("tpu_v5e_"):        # e.g. tpu_v5e_4x4
        try:
            rows, cols = hw[len("tpu_v5e_"):].split("x")
            return tpu_v5e_pod(int(rows), int(cols))
        except ValueError:
            pass
    raise ValueError(f"unknown hardware preset {hw!r}; known: "
                     f"{sorted(HARDWARE_PRESETS) + ['a100x<N>', 'tpu_v5e_<R>x<C>']}")


def _divisor_splits(n: int) -> List[Tuple[int, int, int]]:
    """(pp, dp, tp) triples with pp*dp*tp == n."""
    out = []
    for pp in (d for d in range(1, n + 1) if n % d == 0):
        rest = n // pp
        for dp in (d for d in range(1, rest + 1) if rest % d == 0):
            out.append((pp, dp, rest // dp))
    return out


@dataclass
class SearchSpace:
    """Typed sweep axes for parallelism search (§V-B).

    ``degrees`` fixes explicit (pp, dp, tp) triples; when ``None`` every
    divisor factorization of the device count is considered, filtered by
    arch shape (pp bounded by layer count, tp by head/feature count).
    """

    degrees: Optional[Sequence[Tuple[int, int, int]]] = None
    schedules: Sequence[Schedule] = (Schedule.ONE_F_ONE_B,)
    layouts: Sequence[Layout] = (Layout.S_SHAPE, Layout.LINE)
    microbatch_sizes: Sequence[int] = (1, 2, 4)
    tp_contiguous: Sequence[bool] = (True,)
    max_plans: int = 64

    def __post_init__(self):
        self.schedules = tuple(coerce(Schedule, s, "schedule") for s in self.schedules)
        self.layouts = tuple(coerce(Layout, l, "layout") for l in self.layouts)
        if self.max_plans < 1:
            raise ValueError("max_plans must be >= 1")
        if any(b < 1 for b in self.microbatch_sizes):
            raise ValueError("microbatch sizes must be >= 1")

    def enumerate_plans(self, hardware: HardwareSpec, global_batch: int,
                        training: bool = True,
                        arch: Optional[ArchConfig] = None) -> List[ParallelPlan]:
        """Materialize the plan list, arch-filtered and budget-pruned
        (diverse (pp, dp, tp) triples are kept first)."""
        n = hardware.num_devices
        triples = list(self.degrees) if self.degrees is not None else _divisor_splits(n)
        plans: List[ParallelPlan] = []
        for (pp, dp, tp) in triples:
            if pp * dp * tp > n:
                raise ValueError(
                    f"plan (pp={pp}, dp={dp}, tp={tp}) needs {pp * dp * tp} "
                    f"devices but {hardware.name} has {n}")
            if arch is not None:
                if pp > max(1, arch.num_layers):
                    continue
                if tp > max(arch.n_heads, arch.d_model // 64, 1):
                    continue
            for b in self.microbatch_sizes:
                if global_batch % (b * dp):
                    continue
                for sched in (self.schedules if training else (Schedule.GPIPE,)):
                    for layout in self.layouts:
                        for contig in self.tp_contiguous:
                            plans.append(ParallelPlan(
                                pp=pp, dp=dp, tp=tp, microbatch=b,
                                global_batch=global_batch, schedule=sched,
                                layout=layout, tp_contiguous=contig,
                                training=training))
        # budget: prefer diverse (pp, dp, tp) triples first
        seen, pruned = set(), []
        for p in plans:
            key = (p.pp, p.dp, p.tp)
            if key not in seen or len(pruned) < self.max_plans // 2:
                pruned.append(p)
                seen.add(key)
            if len(pruned) >= self.max_plans:
                break
        return pruned


@dataclass
class Experiment:
    """One declarative simulation/sweep spec. Exactly one of ``plan`` /
    ``search`` drives it: a fixed plan means :meth:`run`, a search space
    means :meth:`sweep`."""

    arch: Union[str, ArchConfig, None] = None
    hardware: Union[str, HardwareSpec] = "wafer_scale"
    plan: Optional[ParallelPlan] = None
    search: Optional[SearchSpace] = None
    graph_builder: Optional[Callable[[ParallelPlan], ComputationGraph]] = None
    seq_len: int = 2048
    global_batch: int = 256
    training: bool = True
    decode: bool = False                # serve-step graphs (1-token decode)
    noc_mode: NoCMode = NoCMode.MACRO
    boundary_mode: BoundaryMode = BoundaryMode.PAIRWISE
    memory_cap: Optional[float] = None  # bytes per tile; pre-sim feasibility
    collect_timeline: bool = False

    def __post_init__(self):
        self.noc_mode = coerce(NoCMode, self.noc_mode, "noc_mode")
        self.boundary_mode = coerce(BoundaryMode, self.boundary_mode,
                                    "boundary_mode")
        self.validate()

    # -- resolution ---------------------------------------------------------
    @property
    def arch_config(self) -> Optional[ArchConfig]:
        if self.arch is None:
            return None
        return get_config(self.arch) if isinstance(self.arch, str) else self.arch

    @functools.cached_property
    def hardware_spec(self) -> HardwareSpec:
        # cached: sweeps resolve the spec once per Experiment (per process),
        # not once per plan evaluation
        return resolve_hardware(self.hardware)

    @property
    def arch_name(self) -> str:
        cfg = self.arch_config
        return cfg.name if cfg is not None else "<custom graph>"

    def build_graph(self, plan: ParallelPlan) -> ComputationGraph:
        """Graph for one plan (per-iteration batch = microbatch * dp)."""
        if self.graph_builder is not None:
            return self.graph_builder(plan)
        return arch_to_graph(self.arch_config, self.seq_len,
                             plan.microbatch * plan.dp,
                             training=self.training, decode=self.decode)

    # -- validation ---------------------------------------------------------
    def validate(self) -> None:
        if self.plan is None and self.search is None:
            raise ValueError("Experiment needs a fixed `plan` or a `search` space")
        if self.plan is not None and self.search is not None:
            raise ValueError("Experiment takes `plan` or `search`, not both")
        if self.arch is None and self.graph_builder is None:
            raise ValueError("Experiment needs an `arch` (registry name or "
                             "ArchConfig) or a custom `graph_builder`")
        if isinstance(self.arch, str):
            get_config(self.arch)       # raises KeyError with known names
        hw = self.hardware_spec          # raises on unknown preset
        if self.plan is not None:
            p = self.plan
            need = p.pp * p.dp * p.tp
            if need > hw.num_devices:
                raise ValueError(
                    f"plan (pp={p.pp}, dp={p.dp}, tp={p.tp}) needs {need} "
                    f"devices but {hw.name} has {hw.num_devices}")
            if p.global_batch % (p.microbatch * p.dp):
                raise ValueError(
                    f"global_batch {p.global_batch} not divisible by "
                    f"microbatch*dp = {p.microbatch * p.dp}")
        if self.seq_len < 1 or self.global_batch < 1:
            raise ValueError("seq_len and global_batch must be >= 1")

    # -- execution ----------------------------------------------------------
    def run(self) -> RunReport:
        """Simulate the fixed plan; returns a RunReport."""
        if self.plan is None:
            raise ValueError("run() needs a fixed plan; use sweep() for a search")
        from .sweep import run_one          # local import: sweep imports report
        return run_one(self, self.plan)

    def sweep(self, workers: int = 0) -> SweepReport:
        """Evaluate the search space; ``workers=0`` is serial, ``workers=N``
        uses an N-process pool, ``workers=None`` uses all cores."""
        if self.search is None:
            if self.plan is not None:   # degenerate single-point sweep
                plans = [self.plan]
            else:
                raise ValueError("sweep() needs a `search` space")
        else:
            plans = self.search.enumerate_plans(
                self.hardware_spec, self.global_batch,
                training=self.training, arch=self.arch_config)
        from .sweep import SweepEngine
        return SweepEngine(workers=workers).sweep(self, plans)

    def with_(self, **kw) -> "Experiment":
        return dataclasses.replace(self, **kw)
