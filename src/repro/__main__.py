"""Entry point: ``python -m repro {simulate,sweep,plan}``."""

import sys

from .api.cli import main

if __name__ == "__main__":
    sys.exit(main())
