"""dbrx-132b: fine-grained MoE, 16 experts top-4 [hf:databricks/dbrx-base;
unverified]."""

from .base import ArchConfig

CONFIG = ArchConfig(
    name="dbrx-132b",
    family="moe",
    num_layers=40,
    d_model=6144,
    n_heads=48,
    n_kv=8,
    d_ff=10752,
    vocab=100352,
    n_experts=16,
    top_k=4,
    d_ff_expert=10752,
    mlp="gated_silu",
    source="hf:databricks/dbrx-base; unverified",
)
