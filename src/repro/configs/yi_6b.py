"""yi-6b: llama-arch dense GQA [arXiv:2403.04652; hf]."""

from .base import ArchConfig

CONFIG = ArchConfig(
    name="yi-6b",
    family="dense",
    num_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv=4,
    d_ff=11008,
    vocab=64000,
    mlp="gated_silu",
    source="arXiv:2403.04652; hf",
)
