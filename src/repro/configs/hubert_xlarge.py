"""hubert-xlarge: encoder-only audio backbone (w2v2 arch; frame-embedding
frontend is a stub) [arXiv:2106.07447; unverified]."""

from .base import ArchConfig

CONFIG = ArchConfig(
    name="hubert-xlarge",
    family="audio",
    num_layers=48,
    d_model=1280,
    n_heads=16,
    n_kv=16,
    d_ff=5120,
    vocab=504,
    causal=False,
    embeds_input=True,
    mlp="gelu",
    source="arXiv:2106.07447; unverified",
)
