"""Config registry: 10 assigned archs + the paper's Megatron T-series
(Table IV workloads: Narayanan et al. 2021 configs, seq 2048, vocab 51200)."""

from __future__ import annotations

from typing import Dict, List

from .base import ArchConfig
from .yi_6b import CONFIG as YI_6B
from .nemotron_4_340b import CONFIG as NEMOTRON
from .granite_3_8b import CONFIG as GRANITE
from .minitron_4b import CONFIG as MINITRON
from .hymba_1p5b import CONFIG as HYMBA
from .granite_moe_3b import CONFIG as GRANITE_MOE
from .dbrx_132b import CONFIG as DBRX
from .llava_next_34b import CONFIG as LLAVA
from .hubert_xlarge import CONFIG as HUBERT
from .mamba2_2p7b import CONFIG as MAMBA2

__all__ = ["ARCHS", "PAPER_MODELS", "get_config", "list_archs"]

ARCHS: Dict[str, ArchConfig] = {
    c.name: c
    for c in [YI_6B, NEMOTRON, GRANITE, MINITRON, HYMBA,
              GRANITE_MOE, DBRX, LLAVA, HUBERT, MAMBA2]
}


def _t(name: str, layers: int, hidden: int, heads: int) -> ArchConfig:
    return ArchConfig(
        name=name, family="dense", num_layers=layers, d_model=hidden,
        n_heads=heads, n_kv=heads, d_ff=4 * hidden, vocab=51200,
        mlp="gelu", source="Megatron [28] / PALM Table IV",
    )


# Megatron model table (Narayanan et al. 2021) used by PALM Table IV/VII.
PAPER_MODELS: Dict[str, ArchConfig] = {
    "T-18B": _t("T-18B", 40, 6144, 48),
    "T-39B": _t("T-39B", 48, 8192, 64),
    "T-76B": _t("T-76B", 60, 10240, 80),
    "T-145B": _t("T-145B", 80, 12288, 96),
    "T-310B": _t("T-310B", 96, 16384, 128),
    "T-530B": _t("T-530B", 105, 20480, 128),
}


def get_config(name: str) -> ArchConfig:
    if name in ARCHS:
        return ARCHS[name]
    if name in PAPER_MODELS:
        return PAPER_MODELS[name]
    raise KeyError(f"unknown arch {name!r}; known: {sorted(ARCHS) + sorted(PAPER_MODELS)}")


def list_archs() -> List[str]:
    return sorted(ARCHS)
