"""Config schema shared by the model zoo, PALM planner, and launchers."""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Dict, Optional, Tuple

__all__ = ["ArchConfig", "ShapeConfig", "SHAPES", "shape_applicable"]


@dataclass(frozen=True)
class ArchConfig:
    """One architecture. Families: dense | moe | hybrid | ssm | vlm | audio.

    ``block`` selects the layer mixer: "attn" (transformer), "ssm"
    (Mamba2 SSD), "hymba" (parallel attn + ssm heads sharing one block).
    """

    name: str
    family: str
    num_layers: int
    d_model: int
    n_heads: int
    n_kv: int
    d_ff: int
    vocab: int
    head_dim: int = 0                     # 0 -> d_model // n_heads
    block: str = "attn"
    mlp: str = "gated_silu"               # gated_silu | squared_relu | gelu
    causal: bool = True                   # False for encoder-only (hubert)
    tie_embeddings: bool = False
    # attention variants
    window: int = 0                       # 0 = full attention; >0 sliding window
    # MoE
    n_experts: int = 0
    top_k: int = 0
    d_ff_expert: int = 0
    # SSM (mamba2 / hymba)
    ssm_state: int = 0
    ssm_headdim: int = 64
    d_inner: int = 0                      # 0 -> 2 * d_model
    conv_width: int = 4
    # modality frontend stub: inputs are precomputed embeddings, not tokens
    embeds_input: bool = False
    source: str = ""                      # provenance tag from the assignment

    def __post_init__(self):
        if self.head_dim == 0 and self.n_heads:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)
        if self.block in ("ssm", "hymba") and self.d_inner == 0:
            object.__setattr__(self, "d_inner", 2 * self.d_model)

    # -- derived -------------------------------------------------------------
    @property
    def is_encoder_only(self) -> bool:
        return not self.causal

    @property
    def has_attention(self) -> bool:
        return self.block in ("attn", "hymba")

    @property
    def subquadratic(self) -> bool:
        """Can this arch run 500k-token decode? (SSM state or windowed KV)."""
        return self.block == "ssm" or (self.block == "hymba" and self.window > 0)

    def param_count(self) -> float:
        """Approximate parameter count (embedding + blocks + head)."""
        H, L = self.d_model, self.num_layers
        # embeds-input archs (stub frontend) have no token-embedding table
        p = self.vocab * H * (1 if (self.tie_embeddings or self.embeds_input) else 2)
        per_layer = 2 * H  # norms
        if self.has_attention:
            q = self.n_heads * self.head_dim
            kv = 2 * self.n_kv * self.head_dim
            per_layer += H * (q + kv) + q * H
        if self.block in ("ssm", "hymba"):
            d_in_proj = 2 * self.d_inner + 2 * self.ssm_state + self.ssm_n_heads
            per_layer += H * d_in_proj + self.d_inner * H + self.d_inner * self.conv_width
        if self.n_experts:
            per_layer += self.n_experts * 3 * H * self.d_ff_expert + H * self.n_experts
        elif self.d_ff:
            mults = 3 if self.mlp == "gated_silu" else 2  # gate only when gated
            per_layer += mults * H * self.d_ff
        return float(p + L * per_layer)

    @property
    def ssm_n_heads(self) -> int:
        return max(1, self.d_inner // self.ssm_headdim) if self.d_inner else 0

    def active_param_count(self) -> float:
        """MoE: only top-k experts are active per token (for MODEL_FLOPS)."""
        if not self.n_experts:
            return self.param_count()
        H, L = self.d_model, self.num_layers
        inactive = (self.n_experts - self.top_k) * 3 * H * self.d_ff_expert
        return self.param_count() - L * inactive


@dataclass(frozen=True)
class ShapeConfig:
    """One input-shape cell (assigned per-arch shape set)."""

    name: str
    seq_len: int
    global_batch: int
    kind: str     # "train" | "prefill" | "decode"


SHAPES: Dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}


def shape_applicable(arch: ArchConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    """Spec-mandated skips (see DESIGN.md §4)."""
    if shape.kind == "decode" and arch.is_encoder_only:
        return False, "encoder-only arch has no autoregressive decode step"
    if shape.name == "long_500k" and not arch.subquadratic:
        return False, "pure full-attention arch: 512k decode needs sub-quadratic attention"
    return True, ""
