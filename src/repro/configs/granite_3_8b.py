"""granite-3-8b: dense GQA [hf:ibm-granite/granite-3.0-2b-base; hf]."""

from .base import ArchConfig

CONFIG = ArchConfig(
    name="granite-3-8b",
    family="dense",
    num_layers=40,
    d_model=4096,
    n_heads=32,
    n_kv=8,
    d_ff=12800,
    vocab=49155,
    mlp="gated_silu",
    source="hf:ibm-granite/granite-3.0-2b-base; hf",
)
