"""llava-next-34b: VLM backbone (anyres tiling frontend is a stub —
``input_specs()`` supplies precomputed patch embeddings)
[hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified]."""

from .base import ArchConfig

CONFIG = ArchConfig(
    name="llava-next-34b",
    family="vlm",
    num_layers=60,
    d_model=7168,
    n_heads=56,
    n_kv=8,
    d_ff=20480,
    vocab=64000,
    embeds_input=True,
    mlp="gated_silu",
    source="hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified",
)
