"""granite-moe-3b-a800m: MoE 40 experts top-8
[hf:ibm-granite/granite-3.0-1b-a400m-base; hf]."""

from .base import ArchConfig

CONFIG = ArchConfig(
    name="granite-moe-3b-a800m",
    family="moe",
    num_layers=32,
    d_model=1536,
    n_heads=24,
    n_kv=8,
    d_ff=512,
    vocab=49155,
    head_dim=64,
    n_experts=40,
    top_k=8,
    d_ff_expert=512,
    mlp="gated_silu",
    source="hf:ibm-granite/granite-3.0-1b-a400m-base; hf",
)
