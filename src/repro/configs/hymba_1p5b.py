"""hymba-1.5b: hybrid — parallel attention + mamba heads per block
[arXiv:2411.13676; hf].

Executable model uses sliding-window attention in every block (the SSM
path carries global context, per the Hymba design); the reference model's
3 global-attention layers are kept in the PALM workload IR but not the
homogeneous scanned JAX stack — see DESIGN.md §4.
"""

from .base import ArchConfig

CONFIG = ArchConfig(
    name="hymba-1.5b",
    family="hybrid",
    num_layers=32,
    d_model=1600,
    n_heads=25,
    n_kv=5,
    d_ff=5504,
    vocab=32001,
    head_dim=64,
    block="hymba",
    window=1024,
    ssm_state=16,
    ssm_headdim=64,
    mlp="gated_silu",
    source="arXiv:2411.13676; hf",
)
