"""Architecture configs: the 10 assigned architectures + the paper's own
Megatron T-series workloads. ``get_config(name)`` resolves by id; every
config is selectable via ``--arch <id>`` in the launchers."""

from .base import ArchConfig, ShapeConfig, SHAPES, shape_applicable
from .registry import ARCHS, PAPER_MODELS, get_config, list_archs

__all__ = [
    "ArchConfig",
    "ShapeConfig",
    "SHAPES",
    "ARCHS",
    "PAPER_MODELS",
    "get_config",
    "list_archs",
    "shape_applicable",
]
