"""minitron-4b: pruned nemotron, dense GQA [arXiv:2407.14679; hf]."""

from .base import ArchConfig

CONFIG = ArchConfig(
    name="minitron-4b",
    family="dense",
    num_layers=32,
    d_model=3072,
    n_heads=24,
    n_kv=8,
    d_ff=9216,
    vocab=256000,
    mlp="squared_relu",
    source="arXiv:2407.14679; hf",
)
