"""nemotron-4-340b: dense GQA, squared-ReLU MLP [arXiv:2402.16819; unverified]."""

from .base import ArchConfig

CONFIG = ArchConfig(
    name="nemotron-4-340b",
    family="dense",
    num_layers=96,
    d_model=18432,
    n_heads=96,
    n_kv=8,
    d_ff=73728,
    vocab=256000,
    mlp="squared_relu",
    source="arXiv:2402.16819; unverified",
)
