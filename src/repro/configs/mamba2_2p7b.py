"""mamba2-2.7b: attention-free SSD (state-space duality)
[arXiv:2405.21060; unverified]."""

from .base import ArchConfig

CONFIG = ArchConfig(
    name="mamba2-2.7b",
    family="ssm",
    num_layers=64,
    d_model=2560,
    n_heads=0,
    n_kv=0,
    d_ff=0,
    vocab=50280,
    block="ssm",
    ssm_state=128,
    ssm_headdim=64,
    d_inner=5120,
    source="arXiv:2405.21060; unverified",
)
