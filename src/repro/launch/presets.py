"""Per-(arch x shape) launch presets: microbatching, precision policy,
sequence parallelism — the memory-fit levers of DESIGN.md §6.

Defaults: fp32 params + fp32 Adam moments, fp32 grad accumulation,
G microbatches such that each data-parallel row sees 1 sequence per
microbatch. Heavy archs (nemotron-4-340b) switch moments + grad
accumulation to bf16 and enable sequence-parallel residuals.
"""

from __future__ import annotations

import dataclasses
from typing import Tuple

import jax.numpy as jnp

from ..configs.base import ArchConfig, ShapeConfig
from ..models.lm import RunCfg
from ..train.optim import OptimizerCfg
from ..train.step import TrainCfg

__all__ = ["train_cfg_for", "run_cfg_for", "microbatches_for"]

# archs whose per-chip footprint needs the bf16-state policy
_BF16_STATE = {"nemotron-4-340b"}
# sequence-parallel residuals for the memory/collective-bound archs.
# §Perf iteration 5 tried default-on: REFUTED for small archs — XLA:CPU
# lowers reduce-scatter as all-reduce+slice, so the SP pattern is charged
# the full AR volume *plus* the seq all-gathers (on TPU the RS is real and
# SP wins); keep it selective and note the backend artifact.
_SEQ_SHARD_ALL = False
_SEQ_SHARD = {"nemotron-4-340b", "llava-next-34b", "dbrx-132b"}


def microbatches_for(arch: ArchConfig, shape: ShapeConfig, dp_total: int) -> int:
    if shape.kind != "train":
        return 1
    g = max(1, shape.global_batch // dp_total)
    return g


def run_cfg_for(arch: ArchConfig, shape: ShapeConfig) -> RunCfg:
    # Perf iteration 1 (EXPERIMENTS.md §Perf): serving keeps bf16 params
    # (fp32 masters are a training-only need) and 512-token query chunks
    # at 32k context (halves the per-chunk fp32 score buffers).
    train = shape.kind == "train"
    q_chunk = (1024 if train else 512) if shape.seq_len > 2048 else 0
    return RunCfg(
        compute_dtype=jnp.bfloat16,
        param_dtype=jnp.float32 if train else jnp.bfloat16,
        q_chunk=q_chunk,
        ssd_chunk=256,
        remat=train,
        scan_layers=True,
        seq_shard=_SEQ_SHARD_ALL or arch.name in _SEQ_SHARD,
    )


def train_cfg_for(arch: ArchConfig, shape: ShapeConfig, dp_total: int) -> TrainCfg:
    run = run_cfg_for(arch, shape)
    bf16_state = arch.name in _BF16_STATE
    opt = OptimizerCfg(moment_dtype=jnp.bfloat16 if bf16_state else jnp.float32)
    return TrainCfg(
        run=run,
        opt=opt,
        num_microbatches=microbatches_for(arch, shape, dp_total),
        grad_accum_dtype=jnp.bfloat16 if bf16_state else jnp.float32,
    )
