"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch yi-6b --shape train_4k --mesh single
    PYTHONPATH=src python -m repro.launch.dryrun --all          # every live cell, subprocess-isolated

Per cell this produces (artifacts/dryrun/<cell>.json):

* the FULL production compile (scanned layers, real microbatching):
  ``memory_analysis()`` proves per-device fit; compile success proves the
  sharding config is coherent;
* trip-corrected roofline inputs: XLA's ``cost_analysis`` counts while
  bodies ONCE (verified), so FLOPs / bytes / collective-bytes are
  extrapolated from 4 (train) or 2 (serve) small UNROLLED probe compiles
  via the exact linear model  f(L, G) = a + bL + cG + dLG  — probes hold
  per-microbatch batch size constant, so shard shapes match the full run;
* MODEL_FLOPS (6·N_active·D for training) for the useful-compute ratio.
"""

# MUST precede any jax import (jax locks device count on first init).
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import argparse
import dataclasses
import json
import subprocess
import sys
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp

from ..configs import ARCHS, SHAPES, get_config, shape_applicable
from ..models.lm import RunCfg, init_cache, init_params, loss_fn
from ..parallel.sharding import ShardingPlanner
from ..serving.serve import make_prefill_step, make_serve_step
from ..train.optim import apply_optimizer, init_opt_state
from ..train.step import TrainCfg, make_train_step
from .hlo_analysis import collective_bytes
from .input_specs import decode_input_specs, prefill_input_specs, train_input_specs
from .mesh import make_production_mesh
from .presets import run_cfg_for, train_cfg_for

ARTIFACT_DIR = Path(__file__).resolve().parents[3] / "artifacts" / "dryrun"


# ---------------------------------------------------------------------------
# probe steps (fully unrolled: no while loops => cost_analysis is exact)
# ---------------------------------------------------------------------------

def _probe_train_step(arch, cfg: TrainCfg, mesh, G: int):
    run = dataclasses.replace(
        cfg.run, scan_layers=False, mesh=mesh,
        batch_axes=("pod", "data") if "pod" in mesh.axis_names else ("data",))

    def step(params, opt_state, batch):
        def mb_loss(p, mb):
            return loss_fn(arch, p, mb, run)
        grads = jax.tree.map(lambda p: jnp.zeros(p.shape, cfg.grad_accum_dtype), params)
        loss = 0.0
        for g in range(G):
            mb = jax.tree.map(lambda t: t[g], batch)
            (l, _), gr = jax.value_and_grad(mb_loss, has_aux=True)(params, mb)
            grads = jax.tree.map(lambda a, b: a + b.astype(cfg.grad_accum_dtype), grads, gr)
            loss = loss + l / G
        grads = jax.tree.map(lambda g: g / G, grads)
        new_params, new_opt, _ = apply_optimizer(cfg.opt, params, grads, opt_state)
        return new_params, new_opt, loss

    return step


def _cost(compiled):
    ca = compiled.cost_analysis()
    return {"flops": float(ca.get("flops", 0.0)),
            "bytes": float(ca.get("bytes accessed", 0.0)),
            "coll": collective_bytes(compiled.as_text())}


def _lin2(f11, f21, f12, f22, L, G):
    """Exact interpolation of f(L,G)=a+bL+cG+dLG from (1,1),(2,1),(1,2),(2,2)."""
    d = f22 - f21 - f12 + f11
    b = (f21 - f11) - d
    c = (f12 - f11) - d
    a = f11 - b - c - d
    return a + b * L + c * G + d * L * G


def _lin1(f1, f2, L, L1=1, L2=2):
    """Linear in L from probes at (L1, L2). A negative slope means GSPMD
    chose different strategies for the two probes (partitioning noise) —
    fall back to proportional scaling of the larger probe (monotone)."""
    b = (f2 - f1) / (L2 - L1)
    if b < 0 or f1 < 0 or f2 < 0:
        return max(f1, f2) * L / L2
    return f1 + b * (L - L1)


_SERVE_PROBE_L = (2, 4)


def _extrapolate(probes, L, G=None):
    out = {}
    keys = ["flops", "bytes"]
    l1, l2 = _SERVE_PROBE_L
    for key in keys:
        if G is None:
            out[key] = _lin1(probes[(l1,)][key], probes[(l2,)][key], L, l1, l2)
        else:
            out[key] = _lin2(probes[(1, 1)][key], probes[(2, 1)][key],
                             probes[(1, 2)][key], probes[(2, 2)][key], L, G)
    coll = {}
    kinds = probes[next(iter(probes))]["coll"].keys()
    for k in kinds:
        if G is None:
            coll[k] = _lin1(probes[(l1,)]["coll"][k], probes[(l2,)]["coll"][k],
                            L, l1, l2)
        else:
            coll[k] = _lin2(probes[(1, 1)]["coll"][k], probes[(2, 1)]["coll"][k],
                            probes[(1, 2)]["coll"][k], probes[(2, 2)]["coll"][k], L, G)
    out["coll"] = coll
    return out


def _mem_stats(compiled):
    m = compiled.memory_analysis()
    return {k: int(getattr(m, k)) for k in
            ("argument_size_in_bytes", "output_size_in_bytes",
             "temp_size_in_bytes", "alias_size_in_bytes",
             "generated_code_size_in_bytes")}


def _small(arch, L):
    return dataclasses.replace(arch, num_layers=L)


# ---------------------------------------------------------------------------
# per-cell runners
# ---------------------------------------------------------------------------

def run_train_cell(arch, shape, mesh, record):
    dp_total = 32 if "pod" in mesh.axis_names else 16
    cfg = train_cfg_for(arch, shape, dp_total)
    G = cfg.num_microbatches
    B_mb = shape.global_batch // G

    # --- full production compile (scan) ---
    t0 = time.time()
    params_s = jax.eval_shape(lambda: init_params(arch, jax.random.PRNGKey(0), cfg.run))
    opt_s = jax.eval_shape(lambda: init_opt_state(cfg.opt, params_s))
    batch_s = train_input_specs(arch, shape, G)
    ts = make_train_step(arch, cfg, mesh)
    compiled = ts.jit_with(params_s, batch_s).lower(params_s, opt_s, batch_s).compile()
    record["full"] = {"compile_s": round(time.time() - t0, 2),
                      "memory": _mem_stats(compiled),
                      "cost_scan_raw": _cost(compiled)}

    # --- probes (unrolled, small L, python-loop G) ---
    probes = {}
    for (l, g) in [(1, 1), (2, 1), (1, 2), (2, 2)]:
        a_l = _small(arch, l)
        p_s = jax.eval_shape(lambda: init_params(a_l, jax.random.PRNGKey(0), cfg.run))
        o_s = jax.eval_shape(lambda: init_opt_state(cfg.opt, p_s))
        b_s = jax.tree.map(
            lambda t: jax.ShapeDtypeStruct((g,) + t.shape[1:], t.dtype), batch_s)
        b_s = jax.tree.map(
            lambda t: jax.ShapeDtypeStruct((t.shape[0], B_mb) + t.shape[2:], t.dtype), b_s)
        step = _probe_train_step(a_l, cfg, mesh, g)
        pl_ = ShardingPlanner(mesh, a_l)
        b_sh = jax.tree.map(lambda leaf: pl_.batch(True, leaf.shape), b_s)
        jitted = jax.jit(step,
                         in_shardings=(pl_.params(p_s), pl_.opt_state(p_s), b_sh),
                         out_shardings=(pl_.params(p_s), pl_.opt_state(p_s), None))
        probes[(l, g)] = _cost(jitted.lower(p_s, o_s, b_s).compile())
    record["probes"] = {f"L{l}G{g}": v for (l, g), v in probes.items()}
    record["extrapolated"] = _extrapolate(probes, arch.num_layers, G)
    record["config"] = {"num_microbatches": G, "microbatch_size": B_mb,
                        "seq_shard": cfg.run.seq_shard,
                        "moment_dtype": str(cfg.opt.moment_dtype.__name__
                                            if hasattr(cfg.opt.moment_dtype, "__name__")
                                            else cfg.opt.moment_dtype)}


def run_prefill_cell(arch, shape, mesh, record):
    run = run_cfg_for(arch, shape)
    t0 = time.time()
    params_s = jax.eval_shape(lambda: init_params(arch, jax.random.PRNGKey(0), run))
    batch_s = prefill_input_specs(arch, shape)
    pf = make_prefill_step(arch, run, mesh)
    compiled = pf.jit_with(params_s, batch_s).lower(params_s, batch_s).compile()
    record["full"] = {"compile_s": round(time.time() - t0, 2),
                      "memory": _mem_stats(compiled),
                      "cost_scan_raw": _cost(compiled)}
    probes = {}
    for l in _SERVE_PROBE_L:
        a_l = _small(arch, l)
        r_l = dataclasses.replace(run, scan_layers=False)
        p_s = jax.eval_shape(lambda: init_params(a_l, jax.random.PRNGKey(0), r_l))
        pf_l = make_prefill_step(a_l, r_l, mesh)
        probes[(l,)] = _cost(pf_l.jit_with(p_s, batch_s).lower(p_s, batch_s).compile())
    record["probes"] = {f"L{l[0]}": v for l, v in probes.items()}
    record["extrapolated"] = _extrapolate(probes, arch.num_layers, None)
    record["config"] = {"q_chunk": run.q_chunk}


def run_decode_cell(arch, shape, mesh, record):
    run = run_cfg_for(arch, shape)
    t0 = time.time()
    params_s = jax.eval_shape(lambda: init_params(arch, jax.random.PRNGKey(0), run))
    cache_s, tok_s, pos_s = decode_input_specs(arch, shape, run)
    ss = make_serve_step(arch, run, mesh)
    compiled = ss.jit_with(params_s, cache_s).lower(params_s, cache_s, tok_s, pos_s).compile()
    record["full"] = {"compile_s": round(time.time() - t0, 2),
                      "memory": _mem_stats(compiled),
                      "cost_scan_raw": _cost(compiled)}
    probes = {}
    for l in _SERVE_PROBE_L:
        a_l = _small(arch, l)
        r_l = dataclasses.replace(run, scan_layers=False)
        p_s = jax.eval_shape(lambda: init_params(a_l, jax.random.PRNGKey(0), r_l))
        c_s, t_s, po_s = decode_input_specs(a_l, shape, r_l)
        ss_l = make_serve_step(a_l, r_l, mesh)
        probes[(l,)] = _cost(
            ss_l.jit_with(p_s, c_s).lower(p_s, c_s, t_s, po_s).compile())
    record["probes"] = {f"L{l[0]}": v for l, v in probes.items()}
    record["extrapolated"] = _extrapolate(probes, arch.num_layers, None)
    record["config"] = {"cache_len": shape.seq_len}


def palm_trace_record(arch_name: str, shape_name: str,
                      hardware: str = "tpu_v5e_4x4") -> dict:
    """Run the cell's workload through the PALM event simulator and return
    ``{"trace": <chrome traceEvents dict>, "summary": ..., "plan": ...}``.

    Training cells and serving cells (prefill/decode) emit the *same*
    columnar :class:`~repro.core.trace.Trace` schema, rendered through the
    same :func:`~repro.core.trace.chrome_trace` exporter the CLI's
    ``simulate --trace-out`` uses — so dry-run timelines are directly
    comparable with any other PALM timeline in one Perfetto view.
    """
    import math

    from ..api import Experiment, ParallelPlan, resolve_hardware
    from ..api.report import plan_to_dict
    from ..core.trace import chrome_trace

    arch = get_config(arch_name)
    shape = SHAPES[shape_name]
    hw = resolve_hardware(hardware)
    n = hw.num_devices
    train = shape.kind == "train"
    # simple feasible split: pipeline depth bounded by layer count, data
    # parallelism by the batch, tensor parallelism takes the remainder
    pp = min(4, arch.num_layers, n)
    while pp > 1 and n % pp:
        pp -= 1
    rest = n // pp
    dp = math.gcd(rest, shape.global_batch)
    tp = min(rest // dp, max(1, arch.n_heads))
    plan = ParallelPlan(pp=pp, dp=dp, tp=tp, microbatch=1,
                        global_batch=shape.global_batch, training=train)
    report = Experiment(
        arch=arch, hardware=hw, plan=plan,
        seq_len=shape.seq_len, global_batch=shape.global_batch,
        training=train, decode=shape.kind == "decode",
        collect_timeline=True,
    ).run()
    return {
        "hardware": hw.name,
        "plan": plan_to_dict(plan),
        "summary": report.trace_summary(),
        "throughput": report.throughput,
        "total_time": report.total_time,
        "trace": chrome_trace(report.trace,
                              label=f"{arch_name} {shape_name} (palm)"),
    }


def model_flops(arch, shape) -> float:
    N = arch.active_param_count()
    if shape.kind == "train":
        return 6.0 * N * shape.global_batch * shape.seq_len
    if shape.kind == "prefill":
        return 2.0 * N * shape.global_batch * shape.seq_len
    return 2.0 * N * shape.global_batch  # decode: one token per sequence


def run_cell(arch_name: str, shape_name: str, mesh_kind: str) -> dict:
    arch = get_config(arch_name)
    shape = SHAPES[shape_name]
    ok, reason = shape_applicable(arch, shape)
    record = {"arch": arch_name, "shape": shape_name, "mesh": mesh_kind,
              "kind": shape.kind, "applicable": ok, "skip_reason": reason,
              "chips": 512 if mesh_kind == "multi" else 256,
              "params": arch.param_count(),
              "active_params": arch.active_param_count(),
              "model_flops": model_flops(arch, shape)}
    if not ok:
        return record
    mesh = make_production_mesh(multi_pod=mesh_kind == "multi")
    with jax.default_device(jax.devices("cpu")[0]):
        if shape.kind == "train":
            run_train_cell(arch, shape, mesh, record)
        elif shape.kind == "prefill":
            run_prefill_cell(arch, shape, mesh, record)
        else:
            run_decode_cell(arch, shape, mesh, record)
    record["ok"] = True
    return record


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------

def all_cells():
    for arch_name in sorted(ARCHS):
        for shape_name in ("train_4k", "prefill_32k", "decode_32k", "long_500k"):
            for mesh_kind in ("single", "multi"):
                yield arch_name, shape_name, mesh_kind


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", type=str)
    ap.add_argument("--shape", type=str, choices=list(SHAPES))
    ap.add_argument("--mesh", type=str, default="single", choices=["single", "multi"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--out", type=str, default=str(ARTIFACT_DIR))
    ap.add_argument("--palm-trace", action="store_true",
                    help="first write <cell>.palm_trace.json: the cell's "
                         "workload simulated by PALM, in the same "
                         "Chrome/Perfetto trace schema as `python -m repro "
                         "simulate --trace-out` (the trace itself needs no "
                         "XLA compile; combine with --trace-only to skip "
                         "the compile)")
    ap.add_argument("--trace-only", action="store_true",
                    help="with --palm-trace: stop after writing the trace")
    ap.add_argument("--palm-hardware", type=str, default="tpu_v5e_4x4",
                    help="hardware preset the --palm-trace simulation runs on")
    args = ap.parse_args(argv)

    out_dir = Path(args.out)
    out_dir.mkdir(parents=True, exist_ok=True)

    if args.all:
        # subprocess isolation: one compile job per process (bounds memory,
        # isolates failures, makes the sweep resumable)
        failures = []
        for a, s, m in all_cells():
            path = out_dir / f"{a}__{s}__{m}.json"
            if path.exists() and not args.force:
                print(f"[skip cached] {path.name}")
                continue
            cmd = [sys.executable, "-m", "repro.launch.dryrun",
                   "--arch", a, "--shape", s, "--mesh", m, "--out", str(out_dir)]
            if args.palm_trace:
                cmd += ["--palm-trace", "--palm-hardware", args.palm_hardware]
                if args.trace_only:
                    cmd.append("--trace-only")
            print(f"[run] {a} x {s} x {m}", flush=True)
            r = subprocess.run(cmd, cwd=str(Path(__file__).resolve().parents[2]))
            if r.returncode != 0:
                failures.append((a, s, m))
        print(f"done; {len(failures)} failures: {failures}")
        return 1 if failures else 0

    assert args.arch and args.shape, "--arch and --shape required (or --all)"
    path = out_dir / f"{args.arch}__{args.shape}__{args.mesh}.json"
    if args.palm_trace:
        # event-simulated timeline for this cell (cheap: no XLA compile);
        # same schema as training/serving traces everywhere else
        tpath = out_dir / f"{args.arch}__{args.shape}.palm_trace.json"
        rec = palm_trace_record(args.arch, args.shape, args.palm_hardware)
        tpath.write_text(json.dumps(rec, indent=1))
        s = rec["summary"]
        print(f"[palm trace written to {tpath}: {s['events']} events, "
              f"bubble {s['bubble_fraction']:.1%}]")
        if args.trace_only:
            return 0
    t0 = time.time()
    try:
        record = run_cell(args.arch, args.shape, args.mesh)
    except Exception:
        record = {"arch": args.arch, "shape": args.shape, "mesh": args.mesh,
                  "ok": False, "error": traceback.format_exc()}
        path.write_text(json.dumps(record, indent=1))
        print(record["error"], file=sys.stderr)
        return 1
    record["wall_s"] = round(time.time() - t0, 2)
    path.write_text(json.dumps(record, indent=1))
    status = "OK" if record.get("ok") else f"SKIP ({record.get('skip_reason')})"
    print(f"{args.arch} x {args.shape} x {args.mesh}: {status} "
          f"[{record['wall_s']}s]")
    return 0


if __name__ == "__main__":
    sys.exit(main())
