"""End-to-end training driver.

    PYTHONPATH=src python -m repro.launch.train --arch yi-6b --scale tiny \
        --steps 200 --global-batch 32 --seq-len 256

``--scale tiny|small`` shrinks the selected architecture to a CPU-trainable
variant (same family/block structure); ``--scale full`` uses the exact
assigned config (for real pods). The loop wires together every substrate:
synthetic data pipeline with prefetch, checkpoint/restart, straggler
monitoring, and metrics logging.
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import get_config
from ..configs.base import ArchConfig
from ..models.lm import RunCfg
from ..train.checkpoint import CheckpointManager, restore_latest
from ..train.data import DataCfg, PrefetchIterator, SyntheticDataset
from ..train.fault_tolerance import StragglerMonitor
from ..train.optim import OptimizerCfg
from ..train.step import TrainCfg, init_train_state, make_train_step

__all__ = ["scale_arch", "train_loop", "main"]


def scale_arch(arch: ArchConfig, scale: str) -> ArchConfig:
    """Family-preserving reductions for CPU-scale runs."""
    if scale == "full":
        return arch
    dims = {"tiny": (2, 128, 4, 256), "small": (4, 256, 8, 1024)}[scale]
    L, H, nh, V = dims
    nkv = max(1, min(arch.n_kv, nh // 2)) if arch.n_kv else 0
    return dataclasses.replace(
        arch, num_layers=L, d_model=H, n_heads=nh if arch.n_heads else 0,
        n_kv=nkv, head_dim=H // nh if arch.n_heads else 0,
        d_ff=2 * H if arch.d_ff else 0, vocab=min(arch.vocab, V),
        n_experts=min(arch.n_experts, 4) if arch.n_experts else 0,
        top_k=min(arch.top_k, 2) if arch.top_k else 0,
        d_ff_expert=H if arch.n_experts else 0,
        d_inner=2 * H if arch.block in ("ssm", "hymba") else 0,
        ssm_state=min(arch.ssm_state, 16) if arch.ssm_state else 0,
        ssm_headdim=32 if arch.block in ("ssm", "hymba") else 64,
        window=min(arch.window, 64) if arch.window else 0)


def train_loop(arch: ArchConfig, cfg: TrainCfg, data_cfg: DataCfg, steps: int,
               ckpt_dir=None, log_every: int = 10, ckpt_every: int = 50,
               seed: int = 0, log_fn=print):
    train_step = make_train_step(arch, cfg)
    params, opt_state = init_train_state(arch, cfg, jax.random.PRNGKey(seed))

    start_step = 0
    manager = None
    if ckpt_dir is not None:
        manager = CheckpointManager(ckpt_dir, every_steps=ckpt_every)
        like = {"params": params, "opt_state": opt_state}
        got, state, extra = restore_latest(ckpt_dir, like)
        if got is not None:
            params, opt_state = state["params"], state["opt_state"]
            start_step = extra.get("data_step", got)
            log_fn(f"[restore] resumed from step {got}")

    dataset = SyntheticDataset(arch, data_cfg)
    it = PrefetchIterator(dataset, start_step=start_step)
    monitor = StragglerMonitor()
    losses = []
    try:
        for step in range(start_step, steps):
            batch = next(it)
            t0 = time.time()
            params, opt_state, metrics = train_step(params, opt_state, batch)
            loss = float(metrics["loss"])
            dt = time.time() - t0
            losses.append(loss)
            ev = monitor.record(step, dt)
            if ev:
                log_fn(f"[straggler] step {step}: {ev['ratio']:.1f}x median")
            if step % log_every == 0:
                log_fn(f"step {step}: loss={loss:.4f} "
                       f"lr={float(metrics['lr']):.2e} "
                       f"gnorm={float(metrics['grad_norm']):.3f} {dt:.2f}s")
            if manager is not None:
                manager.maybe_save(step + 1,
                                   {"params": params, "opt_state": opt_state},
                                   extra={"data_step": step + 1})
        if manager is not None:
            manager.wait()
    finally:
        it.close()
    return params, opt_state, losses


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="yi-6b")
    ap.add_argument("--scale", default="tiny", choices=["tiny", "small", "full"])
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--global-batch", type=int, default=16)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--microbatches", type=int, default=2)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    arch = scale_arch(get_config(args.arch), args.scale)
    cfg = TrainCfg(
        run=RunCfg(q_chunk=0, remat=False),
        opt=OptimizerCfg(peak_lr=args.lr, warmup_steps=20, decay_steps=args.steps),
        num_microbatches=args.microbatches)
    data_cfg = DataCfg(seq_len=args.seq_len, global_batch=args.global_batch,
                       num_microbatches=args.microbatches, seed=args.seed)
    _, _, losses = train_loop(arch, cfg, data_cfg, args.steps,
                              ckpt_dir=args.ckpt_dir, seed=args.seed)
    first = np.mean(losses[:10])
    last = np.mean(losses[-10:])
    print(f"done: loss {first:.3f} -> {last:.3f} "
          f"({'improved' if last < first else 'NOT improved'})")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
