"""Launchers: production mesh, multi-pod dry-run, end-to-end training."""
