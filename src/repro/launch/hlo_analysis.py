"""HLO-text analysis: collective-communication byte accounting.

``collective_bytes(text)`` sums the sizes of every all-gather /
all-reduce / reduce-scatter / all-to-all / collective-permute
instruction, per kind. XLA:CPU's optimized-HLO printer omits inline
operand shapes, so we use the **result** shape — i.e. bytes *received*
per device per op (all-gather: the gathered tensor; all-reduce: the
reduced tensor; all-to-all: the exchanged total) on the post-GSPMD
per-device program.

The dry-run calls this on *unrolled probe* compiles (no while loops), so
no trip-count correction is needed; the linear (L, G) model in dryrun.py
extrapolates to the full depth/microbatch count.
"""

from __future__ import annotations

import math
import re
from typing import Dict, Tuple

__all__ = ["collective_bytes", "DTYPE_BYTES"]

DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1,
    "u64": 8, "u32": 4, "u16": 2, "u8": 1,
    "pred": 1, "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")
# "%name = <result shapes> <opcode>(operands...)" — result shapes live
# between '=' and the opcode keyword (XLA:CPU omits inline operand shapes).
_OP_RE = re.compile(
    r"=\s*(?P<result>[^=]*?)\s?"
    r"(?P<kind>all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?P<suffix>-start|-done)?\(")
_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    if dtype not in DTYPE_BYTES:
        return 0
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * DTYPE_BYTES[dtype]


def collective_bytes(hlo_text: str) -> Dict[str, float]:
    """Per-kind result bytes (per device). '-done' ops are skipped so
    async start/done pairs are counted once."""
    out: Dict[str, float] = {k: 0.0 for k in _COLLECTIVES}
    out["total"] = 0.0
    for line in hlo_text.splitlines():
        m = _OP_RE.search(line)
        if not m:
            continue
        if m.group("suffix") == "-done":
            continue
        kind = m.group("kind")
        nbytes = sum(_shape_bytes(d, s)
                     for d, s in _SHAPE_RE.findall(m.group("result")))
        out[kind] += nbytes
        out["total"] += nbytes
    return out
