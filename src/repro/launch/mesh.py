"""Production mesh factory (spec: MULTI-POD DRY-RUN step 1).

Functions, not module-level constants, so importing this module never
touches jax device state.
"""

from __future__ import annotations

from typing import Mapping

import jax

__all__ = ["make_production_mesh", "make_serving_mesh"]


def _make_mesh(shape, axes):
    # jax.sharding.AxisType landed after 0.4.x; older JAX meshes are
    # implicitly Auto, so just drop the kwarg there
    try:
        from jax.sharding import AxisType
    except ImportError:
        return jax.make_mesh(shape, axes)
    return jax.make_mesh(shape, axes,
                         axis_types=(AxisType.Auto,) * len(axes))


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; multi-pod adds a leading 2-pod axis."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make_mesh(shape, axes)


def make_serving_mesh(mesh_axes: Mapping[str, int]):
    """Build the ``(data, model)`` mesh :func:`repro.serving.plan_serving`
    suggests — the simulator picks the split, this materializes it, which
    closes the paper's §V-B loop for serving:

        mesh_axes, report = plan_serving("yi-6b", hardware="tpu_v5e_2x2")
        mesh = make_serving_mesh(mesh_axes)      # {"data": dp, "model": tp}
        step = make_serve_step(arch, cfg, mesh)

    The runtime must expose ``data * model`` devices (a pod slice, or
    ``--xla_force_host_platform_device_count`` for CPU dry-runs).
    """
    shape = (int(mesh_axes["data"]), int(mesh_axes["model"]))
    return _make_mesh(shape, ("data", "model"))
