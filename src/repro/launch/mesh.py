"""Production mesh factory (spec: MULTI-POD DRY-RUN step 1).

A function, not a module-level constant, so importing this module never
touches jax device state."""

from __future__ import annotations

import jax

__all__ = ["make_production_mesh"]


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; multi-pod adds a leading 2-pod axis."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    axis_types = (jax.sharding.AxisType.Auto,) * len(axes)
    return jax.make_mesh(shape, axes, axis_types=axis_types)
