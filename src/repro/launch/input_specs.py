"""ShapeDtypeStruct stand-ins for every model input (spec: MULTI-POD
DRY-RUN step 2) — weak-type-correct, shardable, no device allocation.

``train``   -> {tokens|embeds: [G, B_mb, S(, H)], labels: [G, B_mb, S]}
``prefill`` -> {tokens|embeds: [B, S(, H)]}
``decode``  -> (cache pytree, tokens [B] | embeds [B, H], pos scalar)
"""

from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig, ShapeConfig
from ..models.lm import RunCfg, init_cache

__all__ = ["train_input_specs", "prefill_input_specs", "decode_input_specs",
           "cache_specs"]

SDS = jax.ShapeDtypeStruct


def train_input_specs(arch: ArchConfig, shape: ShapeConfig,
                      num_microbatches: int) -> Dict[str, Any]:
    G = num_microbatches
    B = shape.global_batch // G
    S = shape.seq_len
    batch: Dict[str, Any] = {"labels": SDS((G, B, S), jnp.int32)}
    if arch.embeds_input:
        batch["embeds"] = SDS((G, B, S, arch.d_model), jnp.bfloat16)
    else:
        batch["tokens"] = SDS((G, B, S), jnp.int32)
    return batch


def prefill_input_specs(arch: ArchConfig, shape: ShapeConfig) -> Dict[str, Any]:
    B, S = shape.global_batch, shape.seq_len
    if arch.embeds_input:
        return {"embeds": SDS((B, S, arch.d_model), jnp.bfloat16)}
    return {"tokens": SDS((B, S), jnp.int32)}


def cache_specs(arch: ArchConfig, batch: int, max_len: int, cfg: RunCfg) -> Any:
    return jax.eval_shape(lambda: init_cache(arch, batch, max_len, cfg))


def decode_input_specs(arch: ArchConfig, shape: ShapeConfig,
                       cfg: RunCfg) -> Tuple[Any, Any, Any]:
    B, S = shape.global_batch, shape.seq_len
    cache = cache_specs(arch, B, S, cfg)
    if arch.embeds_input:
        tokens = SDS((B, arch.d_model), jnp.bfloat16)
    else:
        tokens = SDS((B,), jnp.int32)
    pos = SDS((), jnp.int32)
    return cache, tokens, pos
