"""Model-zoo primitives (pure JAX; Pallas fast paths live in repro.kernels).

Design notes:

* Attention is GQA-grouped (no KV repeat — grouped einsum keeps HLO bytes
  honest) with an optional query-chunk scan: memory O(S * q_chunk)
  instead of O(S^2), the XLA-level flash-attention pattern that keeps
  32k-token prefill compilable and is also the faithful cost model for
  the roofline. Sliding-window attention slices the KV span per chunk, so
  window archs (hymba) get the sub-quadratic compute they promise.
* MoE uses sort-free scatter dispatch with static capacity (GShard-style):
  deterministic shapes, expert-parallel shardable, dropped-token fraction
  reported by the router for tests.
* Mamba2 uses the SSD chunked block decomposition (intra-chunk attention
  form + inter-chunk state recurrence), matching kernels/ssd_ref.
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

__all__ = [
    "rmsnorm",
    "rope",
    "attention",
    "decode_attention",
    "mlp",
    "moe",
    "ssd_scan",
    "ssm_decode_step",
    "silu",
    "squared_relu",
]


def silu(x):
    return x * jax.nn.sigmoid(x)


def squared_relu(x):
    r = jax.nn.relu(x)
    return r * r


ACTIVATIONS = {"gated_silu": silu, "squared_relu": squared_relu, "gelu": jax.nn.gelu}


def rmsnorm(x: jax.Array, w: jax.Array, eps: float = 1e-5) -> jax.Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    return (x * lax.rsqrt(var + eps)).astype(dtype) * w.astype(dtype)


def rope(x: jax.Array, positions: jax.Array, theta: float = 10_000.0) -> jax.Array:
    """Rotary embedding. x: [..., S, n, hd]; positions: [..., S]."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # [..., S, half]
    cos = jnp.cos(angles)[..., :, None, :]
    sin = jnp.sin(angles)[..., :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Attention
# ---------------------------------------------------------------------------

def _attend(q: jax.Array, k: jax.Array, v: jax.Array, mask: jax.Array) -> jax.Array:
    """Grouped attention core. q: [B,Q,nkv,g,hd]; k,v: [B,S,nkv,hd];
    mask: [Q,S] boolean (True = attend)."""
    scale = q.shape[-1] ** -0.5
    scores = jnp.einsum("bqkgh,bskh->bkgqs", q, k) * scale
    scores = jnp.where(mask[None, None, None], scores.astype(jnp.float32), -jnp.inf)
    probs = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    probs = jnp.where(jnp.isnan(probs), 0.0, probs)  # fully-masked rows
    return jnp.einsum("bkgqs,bskh->bqkgh", probs, v)


def attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window: int = 0,
    q_chunk: int = 0,
) -> jax.Array:
    """Training/prefill attention.

    q: [B,S,nh,hd]; k,v: [B,S,nkv,hd]. Returns [B,S,nh,hd].
    ``q_chunk > 0`` scans over query chunks (O(S * chunk) memory);
    ``window > 0`` additionally slices KV to the live span per chunk.
    """
    B, S, nh, hd = q.shape
    nkv = k.shape[2]
    g = nh // nkv
    qg = q.reshape(B, S, nkv, g, hd)

    def mask_for(q_pos: jax.Array, k_pos: jax.Array) -> jax.Array:
        m = jnp.ones((q_pos.shape[0], k_pos.shape[0]), dtype=bool)
        if causal:
            m &= q_pos[:, None] >= k_pos[None, :]
        if window:
            m &= k_pos[None, :] > q_pos[:, None] - window
        return m

    if not q_chunk or S <= q_chunk:
        pos = jnp.arange(S)
        out = _attend(qg, k, v, mask_for(pos, pos))
        return out.reshape(B, S, nh, hd)

    assert S % q_chunk == 0, (S, q_chunk)
    n_chunks = S // q_chunk
    qc = qg.reshape(B, n_chunks, q_chunk, nkv, g, hd)

    if window:
        span = min(S, window + q_chunk)  # static KV slice per chunk

        def chunk_fn(_, inputs):
            idx, qi = inputs
            q0 = idx * q_chunk
            k0 = jnp.maximum(q0 + q_chunk - span, 0)
            ks = lax.dynamic_slice_in_dim(k, k0, span, axis=1)
            vs = lax.dynamic_slice_in_dim(v, k0, span, axis=1)
            # dynamic positions -> build mask from absolute indices
            q_pos = q0 + jnp.arange(q_chunk)
            k_pos = k0 + jnp.arange(span)
            m = q_pos[:, None] >= k_pos[None, :]
            m &= k_pos[None, :] > q_pos[:, None] - window
            return None, _attend(qi, ks, vs, m)
    else:
        def chunk_fn(_, inputs):
            idx, qi = inputs
            q0 = idx * q_chunk
            q_pos = q0 + jnp.arange(q_chunk)
            k_pos = jnp.arange(S)
            m = q_pos[:, None] >= k_pos[None, :] if causal else \
                jnp.ones((q_chunk, S), dtype=bool)
            return None, _attend(qi, k, v, m)

    idxs = jnp.arange(n_chunks)
    _, out = lax.scan(chunk_fn, None, (idxs, jnp.moveaxis(qc, 1, 0)))
    out = jnp.moveaxis(out, 0, 1).reshape(B, S, nh, hd)
    return out


def decode_attention(
    q: jax.Array,          # [B, 1, nh, hd]
    k_cache: jax.Array,    # [B, S_max, nkv, hd]
    v_cache: jax.Array,
    cache_len: jax.Array,  # scalar: valid prefix length (new token included)
) -> jax.Array:
    B, Sq, nh, hd = q.shape
    nkv = k_cache.shape[2]
    g = nh // nkv
    qg = q.reshape(B, Sq, nkv, g, hd)
    S = k_cache.shape[1]
    valid = jnp.arange(S)[None, :] < cache_len  # [1, S]
    out = _attend(qg, k_cache, v_cache, jnp.broadcast_to(valid, (Sq, S)))
    return out.reshape(B, Sq, nh, hd)


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------

def mlp(x: jax.Array, params: Dict[str, jax.Array], kind: str,
        constrain=None) -> jax.Array:
    """Gated-SiLU (3 matmuls) / squared-ReLU / GELU (2 matmuls).
    ``constrain`` pins the d_ff-inner activations (Megatron TP hint)."""
    c = constrain or (lambda t: t)
    if kind == "gated_silu":
        return (c(silu(x @ params["wg"])) * c(x @ params["wi"])) @ params["wo"]
    act = ACTIVATIONS[kind]
    return c(act(x @ params["wi"])) @ params["wo"]


# ---------------------------------------------------------------------------
# MoE (scatter dispatch, static capacity)
# ---------------------------------------------------------------------------

def moe(
    x: jax.Array,                      # [T, H] flattened tokens
    params: Dict[str, jax.Array],      # router [H,E], wg/wi [E,H,F], wo [E,F,H]
    top_k: int,
    capacity_factor: float = 1.25,
    gated: bool = True,
    constrain=None,                    # fn([E,C,H]) -> [E,C,H]: EP sharding hook
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Returns (output [T,H], aux dict with load-balance stats)."""
    T, H = x.shape
    E = params["router"].shape[1]
    logits = (x.astype(jnp.float32) @ params["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)                    # [T,E]
    gate_vals, expert_idx = lax.top_k(probs, top_k)            # [T,k]
    gate_vals = gate_vals / jnp.clip(gate_vals.sum(-1, keepdims=True), 1e-9)

    C = int(max(1, capacity_factor * top_k * T / E))
    flat_e = expert_idx.reshape(-1)                            # [T*k]
    onehot = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)        # [T*k, E]
    pos_in_e = (jnp.cumsum(onehot, axis=0) - onehot)           # occupancy before me
    pos = jnp.take_along_axis(pos_in_e, flat_e[:, None], axis=1)[:, 0]
    keep = pos < C                                             # capacity drop
    slot = flat_e * C + jnp.minimum(pos, C - 1)                # [T*k]

    x_rep = jnp.repeat(x, top_k, axis=0)                       # [T*k, H]
    buf = jnp.zeros((E * C, H), dtype=x.dtype)
    buf = buf.at[slot].add(jnp.where(keep[:, None], x_rep, 0))
    he = buf.reshape(E, C, H)
    if constrain is not None:          # expert-parallel: all-to-all emerges here
        he = constrain(he)

    if gated:
        inner = silu(jnp.einsum("ech,ehf->ecf", he, params["wg"])) * \
            jnp.einsum("ech,ehf->ecf", he, params["wi"])
    else:
        inner = jax.nn.gelu(jnp.einsum("ech,ehf->ecf", he, params["wi"]))
    out_e = jnp.einsum("ecf,efh->ech", inner, params["wo"]).reshape(E * C, H)

    gathered = out_e[slot] * (keep[:, None] * gate_vals.reshape(-1)[:, None]).astype(x.dtype)
    out = gathered.reshape(T, top_k, H).sum(axis=1)

    aux = {
        "load": onehot.sum(axis=0),                            # tokens per expert
        "drop_fraction": 1.0 - keep.mean(),
        "router_entropy": -(probs * jnp.log(probs + 1e-9)).sum(-1).mean(),
    }
    return out, aux


def moe_ep(
    x: jax.Array,                      # [T, H] tokens (sharded over data axes)
    params: Dict[str, jax.Array],
    top_k: int,
    mesh,
    capacity_factor: float = 1.25,
    gated: bool = True,
    data_axes: Tuple = ("data",),
    expert_axis: str = "model",
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Expert-parallel MoE via shard_map (the production path).

    Naive GSPMD partitioning of the scatter dispatch synthesizes one-hot
    matmuls costing 13-17x the useful FLOPs (measured — EXPERIMENTS.md
    §Perf iteration 6). Here every model-axis rank routes its (replicated)
    local tokens to ITS experts with plain dense scatter/gather, runs the
    local expert FFNs, and a single psum over the expert axis combines
    partial outputs. Experts are zero-padded to a multiple of the axis
    size (e.g. granite-moe's 40 -> 48 on a 16-way axis).
    """
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    H = x.shape[-1]
    E = params["router"].shape[-1]
    m = mesh.shape[expert_axis]
    E_pad = -(-E // m) * m
    pad_e = E_pad - E

    router = jnp.pad(params["router"], ((0, 0), (0, pad_e)))
    wg = jnp.pad(params["wg"], ((0, pad_e), (0, 0), (0, 0)))
    wi = jnp.pad(params["wi"], ((0, pad_e), (0, 0), (0, 0)))
    wo = jnp.pad(params["wo"], ((0, pad_e), (0, 0), (0, 0)))
    E_loc = E_pad // m

    def inner(x_l, router_r, wg_l, wi_l, wo_l):
        T_l = x_l.shape[0]
        r = jax.lax.axis_index(expert_axis)
        logits = (x_l.astype(jnp.float32) @ router_r.astype(jnp.float32))
        logits = jnp.where(jnp.arange(E_pad)[None, :] < E, logits, -jnp.inf)
        probs = jax.nn.softmax(logits, axis=-1)
        gate_vals, expert_idx = jax.lax.top_k(probs, top_k)       # [T_l, k]
        gate_vals = gate_vals / jnp.clip(gate_vals.sum(-1, keepdims=True), 1e-9)

        C = int(max(1, capacity_factor * top_k * T_l / E_pad))
        flat_e = expert_idx.reshape(-1)                           # [T_l*k]
        local = (flat_e >= r * E_loc) & (flat_e < (r + 1) * E_loc)
        le = jnp.where(local, flat_e - r * E_loc, E_loc)          # E_loc = trash
        onehot = jax.nn.one_hot(le, E_loc + 1, dtype=jnp.int32)
        pos = jnp.take_along_axis(jnp.cumsum(onehot, 0) - onehot,
                                  le[:, None], axis=1)[:, 0]
        keep = local & (pos < C)
        slot = jnp.where(keep, le * C + jnp.minimum(pos, C - 1), E_loc * C)

        x_rep = jnp.repeat(x_l, top_k, axis=0)
        buf = jnp.zeros((E_loc * C + 1, H), x_l.dtype)
        buf = buf.at[slot].add(jnp.where(keep[:, None], x_rep, 0))
        he = buf[:-1].reshape(E_loc, C, H)

        if gated:
            inner_act = silu(jnp.einsum("ech,ehf->ecf", he, wg_l)) * \
                jnp.einsum("ech,ehf->ecf", he, wi_l)
        else:
            inner_act = jax.nn.gelu(jnp.einsum("ech,ehf->ecf", he, wi_l))
        out_e = jnp.einsum("ecf,efh->ech", inner_act, wo_l).reshape(E_loc * C, H)
        out_e = jnp.concatenate([out_e, jnp.zeros((1, H), out_e.dtype)])

        gathered = out_e[slot] * (keep[:, None] * gate_vals.reshape(-1)[:, None]
                                  ).astype(x_l.dtype)
        partial = gathered.reshape(T_l, top_k, H).sum(axis=1)
        out = jax.lax.psum(partial, expert_axis)                  # EP combine
        stat_axes = tuple(data_axes) + (expert_axis,)
        load = jax.lax.psum(onehot[:, :E_loc].sum(0), stat_axes)
        kept = jax.lax.psum(keep.astype(jnp.float32).sum(), stat_axes)
        total = jax.lax.psum(jnp.float32(T_l * top_k), stat_axes) / m
        drop = 1.0 - kept / total
        return out, load, drop

    t_spec = P(data_axes, None)
    e_spec = P(expert_axis, None, None)
    out, load, drop = shard_map(
        inner, mesh=mesh,
        in_specs=(t_spec, P(None, None), e_spec, e_spec, e_spec),
        out_specs=(t_spec, P(None), P()),
        check_rep=False,
    )(x, router, wg, wi, wo)
    aux = {"load": load.astype(jnp.float32),
           "drop_fraction": drop,
           "router_entropy": jnp.zeros((), jnp.float32)}
    return out, aux


# ---------------------------------------------------------------------------
# Mamba2 SSD (state-space duality, chunked)
# ---------------------------------------------------------------------------

def _segsum(a: jax.Array) -> jax.Array:
    """Stable segment-sum: out[..., i, j] = sum_{j < m <= i} a[..., m]
    (lower-triangular cumulative log-decay)."""
    Q = a.shape[-1]
    cs = jnp.cumsum(a, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((Q, Q), dtype=bool), 0)
    return jnp.where(mask, diff, -jnp.inf)


def ssd_scan(
    x: jax.Array,        # [B, S, nh, hp]  (inner activations, headdim hp)
    dt: jax.Array,       # [B, S, nh]      (softplus-ed step size)
    A: jax.Array,        # [nh]            (negative decay rate)
    Bm: jax.Array,       # [B, S, N]       (input matrix, shared across heads)
    Cm: jax.Array,       # [B, S, N]       (output matrix)
    chunk: int = 256,
    initial_state: Optional[jax.Array] = None,   # [B, nh, hp, N]
    return_state: bool = False,
):
    """Chunked SSD forward (Mamba2 'state-space duality' algorithm [2405.21060]).

    h_t = exp(A dt_t) h_{t-1} + dt_t * x_t B_t^T ;  y_t = C_t h_t.
    Intra-chunk runs in attention form; inter-chunk is a state recurrence.
    """
    Bsz, S, nh, hp = x.shape
    N = Bm.shape[-1]
    if S % chunk:
        pad = chunk - S % chunk
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))
    Sp = x.shape[1]
    nc = Sp // chunk

    f32 = jnp.float32
    xc = x.reshape(Bsz, nc, chunk, nh, hp).astype(f32)
    dtc = dt.reshape(Bsz, nc, chunk, nh).astype(f32)
    Bc = Bm.reshape(Bsz, nc, chunk, N).astype(f32)
    Cc = Cm.reshape(Bsz, nc, chunk, N).astype(f32)

    a = dtc * A.astype(f32)[None, None, None, :]        # [B,nc,Q,nh] log-decay
    a_h = jnp.moveaxis(a, -1, 2)                        # [B,nc,nh,Q]
    a_cs = jnp.cumsum(a_h, axis=-1)                     # within-chunk cumsum

    # 1) intra-chunk (attention form): scores[i,j] = C_i.B_j * exp(acs_i-acs_j) * dt_j
    L = jnp.exp(_segsum(a_h))                           # [B,nc,nh,Q,Q]
    cb = jnp.einsum("bcin,bcjn->bcij", Cc, Bc)          # [B,nc,Q,Q]
    scores = cb[:, :, None] * L * jnp.moveaxis(dtc, -1, 2)[:, :, :, None, :]
    y_intra = jnp.einsum("bchij,bcjhp->bcihp", scores, xc)

    # 2) chunk states: S_c = sum_j exp(acs_last - acs_j) * dt_j * B_j x_j^T
    decay_to_end = jnp.exp(a_cs[..., -1:] - a_cs)       # [B,nc,nh,Q]
    w = decay_to_end * jnp.moveaxis(dtc, -1, 2)         # [B,nc,nh,Q]
    states = jnp.einsum("bchj,bcjn,bcjhp->bchpn", w, Bc, xc)  # [B,nc,nh,hp,N]

    # 3) inter-chunk recurrence over chunk boundaries
    chunk_decay = jnp.exp(a_cs[..., -1])                # [B,nc,nh]
    init = jnp.zeros((Bsz, nh, hp, N), f32) if initial_state is None \
        else initial_state.astype(f32)

    def step(h, inp):
        dec, s = inp                                    # dec [B,nh], s [B,nh,hp,N]
        h_new = h * dec[..., None, None] + s
        return h_new, h                                  # emit state *entering* chunk

    (final_state, h_prevs) = lax.scan(
        step, init, (jnp.moveaxis(chunk_decay, 1, 0), jnp.moveaxis(states, 1, 0)))
    h_prev = jnp.moveaxis(h_prevs, 0, 1)                # [B,nc,nh,hp,N]

    # 4) inter-chunk output: y_i += (C_i . h_prev) * exp(acs_i)
    decay_from_start = jnp.exp(a_cs)                    # [B,nc,nh,Q]
    y_inter = jnp.einsum("bcin,bchpn,bchi->bcihp", Cc, h_prev, decay_from_start)

    y = (y_intra + y_inter).reshape(Bsz, Sp, nh, hp)[:, :S].astype(x.dtype)
    if return_state:
        return y, final_state
    return y


def ssm_decode_step(
    x: jax.Array,      # [B, nh, hp]
    dt: jax.Array,     # [B, nh]
    A: jax.Array,      # [nh]
    Bm: jax.Array,     # [B, N]
    Cm: jax.Array,     # [B, N]
    state: jax.Array,  # [B, nh, hp, N]
) -> Tuple[jax.Array, jax.Array]:
    """Single-token SSD recurrence (decode): O(1) per token."""
    f32 = jnp.float32
    dec = jnp.exp(dt.astype(f32) * A.astype(f32))                 # [B,nh]
    upd = jnp.einsum("bh,bhp,bn->bhpn", dt.astype(f32), x.astype(f32), Bm.astype(f32))
    new_state = state * dec[..., None, None] + upd
    y = jnp.einsum("bn,bhpn->bhp", Cm.astype(f32), new_state)
    return y.astype(x.dtype), new_state
