"""JAX model zoo for the assigned architectures (see repro.configs)."""

from .lm import RunCfg, decode_step, forward, init_cache, init_params, loss_fn, param_count

__all__ = ["RunCfg", "decode_step", "forward", "init_cache", "init_params",
           "loss_fn", "param_count"]
