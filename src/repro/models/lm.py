"""Composable LM model zoo: one parameterised decoder/encoder covering all
10 assigned architectures (dense GQA, MoE, SSM, hybrid, encoder-only,
embeds-input backbones).

Params are plain pytrees with layer-stacked leaves ([L, ...]) consumed by
``lax.scan`` — the production pattern (MaxText-style) that keeps HLO size
O(1) in depth, bounds compile time, and gives the remat policy a single
boundary per layer.
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from ..configs.base import ArchConfig
from .layers import (
    attention,
    decode_attention,
    mlp,
    moe,
    rmsnorm,
    rope,
    silu,
    ssd_scan,
    ssm_decode_step,
)

__all__ = ["RunCfg", "init_params", "forward", "loss_fn", "init_cache", "decode_step",
           "param_count"]


@dataclass(frozen=True)
class RunCfg:
    compute_dtype: Any = jnp.bfloat16
    param_dtype: Any = jnp.float32
    q_chunk: int = 1024
    ssd_chunk: int = 256
    remat: bool = True
    scan_layers: bool = True
    capacity_factor: float = 1.25
    logits_fp32: bool = True
    # distribution (None = single-host semantics, constraints are no-ops)
    mesh: Any = None
    batch_axes: Any = ("data",)        # ("pod","data") on multi-pod meshes
    seq_shard: bool = False            # sequence-parallel residual stream
    expert_axis: Any = "model"         # MoE expert-parallel axis


def _cst(x: jax.Array, cfg: "RunCfg", spec_dims: Tuple) -> jax.Array:
    """with_sharding_constraint when a mesh is configured, else identity.
    Axes that don't divide the actual dim are dropped (e.g. 49155 vocab,
    batch-1 decode)."""
    if cfg.mesh is None:
        return x
    from jax.sharding import NamedSharding, PartitionSpec as P
    from ..parallel.sharding import fit_first
    spec = fit_first([P(*spec_dims)], tuple(x.shape), cfg.mesh)
    return lax.with_sharding_constraint(x, NamedSharding(cfg.mesh, spec))


def _residual_spec(cfg: "RunCfg") -> Tuple:
    return (cfg.batch_axes, "model" if cfg.seq_shard else None, None)


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------

def _dense(key, shape, dtype, scale=None):
    fan_in = shape[0] if len(shape) >= 2 else shape[-1]
    scale = (1.0 / fan_in) ** 0.5 if scale is None else scale
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


def _attn_layer_params(arch: ArchConfig, key, L, dtype):
    H, nh, nkv, hd = arch.d_model, arch.n_heads, arch.n_kv, arch.head_dim
    ks = jax.random.split(key, 4)
    out_scale = (1.0 / (nh * hd)) ** 0.5 / (2 * arch.num_layers) ** 0.5
    return {
        "wq": _dense(ks[0], (L, H, nh * hd), dtype),
        "wk": _dense(ks[1], (L, H, nkv * hd), dtype),
        "wv": _dense(ks[2], (L, H, nkv * hd), dtype),
        "wo": _dense(ks[3], (L, nh * hd, H), dtype, scale=out_scale),
    }


def _mlp_layer_params(arch: ArchConfig, key, L, dtype):
    H, F = arch.d_model, arch.d_ff
    ks = jax.random.split(key, 3)
    p = {
        "wi": _dense(ks[0], (L, H, F), dtype),
        "wo": _dense(ks[1], (L, F, H), dtype, scale=(1.0 / F) ** 0.5 / (2 * arch.num_layers) ** 0.5),
    }
    if arch.mlp == "gated_silu":
        p["wg"] = _dense(ks[2], (L, H, F), dtype)
    return p


def _moe_layer_params(arch: ArchConfig, key, L, dtype):
    H, E, F = arch.d_model, arch.n_experts, arch.d_ff_expert
    ks = jax.random.split(key, 4)
    return {
        "router": _dense(ks[0], (L, H, E), dtype, scale=0.02),
        "wg": _dense(ks[1], (L, E, H, F), dtype),
        "wi": _dense(ks[2], (L, E, H, F), dtype),
        "wo": _dense(ks[3], (L, E, F, H), dtype, scale=(1.0 / F) ** 0.5 / (2 * arch.num_layers) ** 0.5),
    }


def _ssm_layer_params(arch: ArchConfig, key, L, dtype):
    H, di, N = arch.d_model, arch.d_inner, arch.ssm_state
    nh = arch.ssm_n_heads
    conv_dim = di + 2 * N
    d_in_proj = 2 * di + 2 * N + nh
    ks = jax.random.split(key, 6)
    dt = jax.random.uniform(ks[4], (L, nh), jnp.float32, 1e-3, 1e-1)
    dt_bias = dt + jnp.log(-jnp.expm1(-dt))  # inverse softplus
    return {
        "in_proj": _dense(ks[0], (L, H, d_in_proj), dtype),
        "conv_w": _dense(ks[1], (L, arch.conv_width, conv_dim), dtype, scale=0.3),
        "conv_b": jnp.zeros((L, conv_dim), dtype),
        "A_log": jnp.log(jax.random.uniform(ks[2], (L, nh), jnp.float32, 1.0, 16.0)),
        "D": jnp.ones((L, nh), jnp.float32),
        "dt_bias": dt_bias.astype(jnp.float32),
        "ssm_norm": jnp.ones((L, di), dtype),
        "out_proj": _dense(ks[3], (L, di, H), dtype, scale=(1.0 / di) ** 0.5 / (2 * arch.num_layers) ** 0.5),
    }


def init_params(arch: ArchConfig, key: jax.Array, cfg: RunCfg = RunCfg()) -> Dict:
    L, H, V = arch.num_layers, arch.d_model, arch.vocab
    dtype = cfg.param_dtype
    keys = jax.random.split(key, 8)
    layers: Dict[str, Any] = {"norm1": jnp.ones((L, H), dtype)}
    if arch.block in ("attn", "hymba"):
        layers["attn"] = _attn_layer_params(arch, keys[0], L, dtype)
    if arch.block in ("ssm", "hymba"):
        layers["ssm"] = _ssm_layer_params(arch, keys[1], L, dtype)
    if arch.block in ("attn", "hymba") and (arch.d_ff or arch.n_experts):
        layers["norm2"] = jnp.ones((L, H), dtype)
        if arch.n_experts:
            layers["moe"] = _moe_layer_params(arch, keys[2], L, dtype)
        else:
            layers["mlp"] = _mlp_layer_params(arch, keys[3], L, dtype)

    params: Dict[str, Any] = {
        "layers": layers,
        "final_norm": jnp.ones((H,), dtype),
        "lm_head": _dense(keys[5], (H, V), dtype, scale=0.02),
    }
    if not arch.embeds_input:
        params["embed"] = _dense(keys[4], (V, H), dtype, scale=0.02)
    return params


def param_count(params) -> int:
    return sum(x.size for x in jax.tree.leaves(params))


# ---------------------------------------------------------------------------
# Blocks
# ---------------------------------------------------------------------------

def _run_attn(arch: ArchConfig, p, h: jax.Array, positions: jax.Array, cfg: RunCfg):
    B, S, H = h.shape
    nh, nkv, hd = arch.n_heads, arch.n_kv, arch.head_dim
    # Megatron-SP pattern: gather sequence, shard heads over "model" —
    # explicit hints so GSPMD never falls back to gathering whole weights
    h = _cst(h, cfg, (cfg.batch_axes, None, None))
    q = _cst(h @ p["wq"], cfg, (cfg.batch_axes, None, "model")).reshape(B, S, nh, hd)
    k = _cst(h @ p["wk"], cfg, (cfg.batch_axes, None, "model")).reshape(B, S, nkv, hd)
    v = _cst(h @ p["wv"], cfg, (cfg.batch_axes, None, "model")).reshape(B, S, nkv, hd)
    q, k = rope(q, positions), rope(k, positions)
    o = attention(q, k, v, causal=arch.causal, window=arch.window, q_chunk=cfg.q_chunk)
    return o.reshape(B, S, nh * hd) @ p["wo"]


def _run_ssm(arch: ArchConfig, p, h: jax.Array, cfg: RunCfg):
    B, S, H = h.shape
    di, N, nh = arch.d_inner, arch.ssm_state, arch.ssm_n_heads
    hp = arch.ssm_headdim
    proj = h @ p["in_proj"]
    z, xbc, dtr = jnp.split(proj, [di, 2 * di + 2 * N], axis=-1)
    # causal depthwise conv over (x, B, C)
    K = arch.conv_width
    padded = jnp.pad(xbc, ((0, 0), (K - 1, 0), (0, 0)))
    conv = sum(padded[:, k:k + S] * p["conv_w"][k] for k in range(K)) + p["conv_b"]
    xbc = silu(conv).astype(h.dtype)
    xs, Bm, Cm = jnp.split(xbc, [di, di + N], axis=-1)
    dt = jax.nn.softplus(dtr.astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])
    y = ssd_scan(xs.reshape(B, S, nh, hp), dt, A, Bm, Cm, chunk=cfg.ssd_chunk)
    y = y + p["D"].astype(y.dtype)[None, None, :, None] * xs.reshape(B, S, nh, hp)
    y = y.reshape(B, S, di)
    y = rmsnorm(y * silu(z), p["ssm_norm"])
    return y @ p["out_proj"]


def _run_ffn(arch: ArchConfig, lp, x: jax.Array, cfg: RunCfg):
    """MLP or MoE sublayer (with pre-norm), returns (delta, aux)."""
    if not (arch.d_ff or arch.n_experts):
        return jnp.zeros_like(x), _zero_aux(arch)
    B, S, H = x.shape
    h2 = rmsnorm(x, lp["norm2"])
    if arch.n_experts:
        if cfg.mesh is not None:
            # shard_map expert parallelism (§Perf iter. 6): local dispatch
            # per expert rank + one psum combine — avoids GSPMD's one-hot-
            # matmul synthesis for cross-shard scatter (13-17x flops)
            from .layers import moe_ep
            h2 = _cst(h2, cfg, (cfg.batch_axes, None, None))
            out, aux = moe_ep(h2.reshape(B * S, H), lp["moe"], arch.top_k,
                              cfg.mesh, cfg.capacity_factor,
                              gated=arch.mlp == "gated_silu",
                              data_axes=cfg.batch_axes,
                              expert_axis=cfg.expert_axis)
        else:
            out, aux = moe(h2.reshape(B * S, H), lp["moe"], arch.top_k,
                           cfg.capacity_factor, gated=arch.mlp == "gated_silu")
        return out.reshape(B, S, H), {"moe_drop": aux["drop_fraction"],
                                      "moe_load_max": aux["load"].max().astype(jnp.float32)}
    h2 = _cst(h2, cfg, (cfg.batch_axes, None, None))
    inner_cst = (lambda t: _cst(t, cfg, (cfg.batch_axes, None, "model"))) \
        if cfg.mesh is not None else None
    return mlp(h2, lp["mlp"], arch.mlp, constrain=inner_cst), _zero_aux(arch)


def _zero_aux(arch: ArchConfig):
    if arch.n_experts:
        return {"moe_drop": jnp.zeros((), jnp.float32),
                "moe_load_max": jnp.zeros((), jnp.float32)}
    return {}


def _block(arch: ArchConfig, cfg: RunCfg, x: jax.Array, lp, positions: jax.Array):
    h = rmsnorm(x, lp["norm1"])
    if arch.block == "attn":
        x = x + _run_attn(arch, lp["attn"], h, positions, cfg)
    elif arch.block == "ssm":
        x = x + _run_ssm(arch, lp["ssm"], h, cfg)
    else:  # hymba: parallel attn + mamba heads, fused mean
        a = _run_attn(arch, lp["attn"], h, positions, cfg)
        s = _run_ssm(arch, lp["ssm"], h, cfg)
        x = x + 0.5 * (a + s)
    delta, aux = _run_ffn(arch, lp, x, cfg)
    return _cst(x + delta, cfg, _residual_spec(cfg)), aux


# ---------------------------------------------------------------------------
# Forward / loss
# ---------------------------------------------------------------------------

def forward(
    arch: ArchConfig,
    params: Dict,
    tokens: Optional[jax.Array] = None,
    embeds: Optional[jax.Array] = None,
    cfg: RunCfg = RunCfg(),
    logits_positions: str = "all",   # "all" | "last" (prefill: avoid B*S*V)
) -> Tuple[jax.Array, Dict]:
    """Returns (logits [B,S,V] or [B,1,V], aux). Input is ``tokens`` [B,S]
    for LM archs or ``embeds`` [B,S,H] for stub-frontend (vlm/audio) archs."""
    if arch.embeds_input:
        assert embeds is not None, f"{arch.name} takes precomputed embeddings"
        x = embeds.astype(cfg.compute_dtype)
    else:
        x = params["embed"].astype(cfg.compute_dtype)[tokens]
    x = _cst(x, cfg, _residual_spec(cfg))
    B, S = x.shape[:2]
    positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))

    # cast BEFORE the layer scan: the FSDP all-gather inside each layer then
    # moves bf16, not fp32 — halves the dominant collective volume
    # (EXPERIMENTS.md §Perf iteration 2)
    cast = lambda t: jax.tree.map(lambda a: a.astype(cfg.compute_dtype)
                                  if a.dtype in (jnp.float32, jnp.bfloat16) and a.ndim > 1
                                  else a, t)
    layers = cast(params["layers"])

    def body(x, lp):
        return _block(arch, cfg, x, lp, positions)

    if cfg.remat:
        body = jax.checkpoint(body, prevent_cse=False)

    if cfg.scan_layers:
        x, aux = lax.scan(body, x, layers)
        aux = jax.tree.map(jnp.mean, aux)
    else:
        aux = _zero_aux(arch)
        L = arch.num_layers
        for i in range(L):
            lp = jax.tree.map(lambda a: a[i], layers)
            x, aux_i = body(x, lp)
            aux = jax.tree.map(lambda a, b: a + b / L, aux, aux_i)

    if logits_positions == "last":
        x = x[:, -1:]                       # prefill: next-token logits only
    x = rmsnorm(x, params["final_norm"].astype(cfg.compute_dtype))
    logits = x @ params["lm_head"].astype(cfg.compute_dtype)
    logits = _cst(logits, cfg, (cfg.batch_axes, None, "model"))  # vocab-sharded
    if cfg.logits_fp32:
        logits = logits.astype(jnp.float32)
    return logits, aux


def loss_fn(
    arch: ArchConfig,
    params: Dict,
    batch: Dict[str, jax.Array],
    cfg: RunCfg = RunCfg(),
) -> Tuple[jax.Array, Dict]:
    """Next-token (or frame-label) cross entropy; batch keys:
    tokens|embeds, labels, and optional loss_mask."""
    logits, aux = forward(arch, params,
                          tokens=batch.get("tokens"),
                          embeds=batch.get("embeds"), cfg=cfg)
    labels = batch["labels"]
    # logsumexp form: avoids materialising a second logits-sized
    # log_softmax buffer; the vocab reduction stays sharded under GSPMD
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = lse - ll
    mask = batch.get("loss_mask")
    if mask is None:
        loss = nll.mean()
    else:
        loss = (nll * mask).sum() / jnp.clip(mask.sum(), 1.0)
    metrics = {"loss": loss, **aux}
    if arch.n_experts:
        loss = loss + 0.0 * aux.get("moe_drop", 0.0)  # keep aux alive
    return loss, metrics


# ---------------------------------------------------------------------------
# Decode (serve_step)
# ---------------------------------------------------------------------------

def init_cache(arch: ArchConfig, batch: int, max_len: int, cfg: RunCfg = RunCfg()) -> Dict:
    """KV / SSM state cache, layer-stacked for scan. Window archs keep a
    ring buffer of ``window`` positions; SSM archs a constant-size state."""
    L = arch.num_layers
    dtype = cfg.compute_dtype
    cache: Dict[str, jax.Array] = {}
    if arch.has_attention:
        span = min(arch.window, max_len) if arch.window else max_len
        kv_shape = (L, batch, span, arch.n_kv, arch.head_dim)
        cache["k"] = jnp.zeros(kv_shape, dtype)
        cache["v"] = jnp.zeros(kv_shape, dtype)
    if arch.block in ("ssm", "hymba"):
        conv_dim = arch.d_inner + 2 * arch.ssm_state
        cache["conv"] = jnp.zeros((L, batch, arch.conv_width - 1, conv_dim), dtype)
        cache["ssm"] = jnp.zeros(
            (L, batch, arch.ssm_n_heads, arch.ssm_headdim, arch.ssm_state), jnp.float32)
    return cache


def _decode_attn(arch: ArchConfig, p, h, c, pos, cfg):
    B = h.shape[0]
    nh, nkv, hd = arch.n_heads, arch.n_kv, arch.head_dim
    q = (h @ p["wq"]).reshape(B, 1, nh, hd)
    k = (h @ p["wk"]).reshape(B, 1, nkv, hd)
    v = (h @ p["wv"]).reshape(B, 1, nkv, hd)
    posb = jnp.broadcast_to(pos[None, None], (B, 1))
    q, k = rope(q, posb), rope(k, posb)
    span = c["k"].shape[1]
    slot = pos % span if arch.window else pos
    k_cache = lax.dynamic_update_slice_in_dim(c["k"], k, slot, axis=1)
    v_cache = lax.dynamic_update_slice_in_dim(c["v"], v, slot, axis=1)
    cache_len = jnp.minimum(pos + 1, span)
    o = decode_attention(q, k_cache, v_cache, cache_len)
    return o.reshape(B, 1, nh * hd) @ p["wo"], {"k": k_cache, "v": v_cache}


def _decode_ssm(arch: ArchConfig, p, h, c, cfg):
    B = h.shape[0]
    di, N, nh, hp = arch.d_inner, arch.ssm_state, arch.ssm_n_heads, arch.ssm_headdim
    proj = (h @ p["in_proj"])[:, 0]                        # [B, d_in_proj]
    z, xbc, dtr = jnp.split(proj, [di, 2 * di + 2 * N], axis=-1)
    # streaming causal conv: state holds last K-1 inputs
    K = arch.conv_width
    hist = jnp.concatenate([c["conv"], xbc[:, None]], axis=1)   # [B,K,conv_dim]
    conv = (hist * p["conv_w"]).sum(axis=1) + p["conv_b"]
    new_conv_state = hist[:, 1:]
    xbc_a = silu(conv).astype(h.dtype)
    xs, Bm, Cm = jnp.split(xbc_a, [di, di + N], axis=-1)
    dt = jax.nn.softplus(dtr.astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])
    y, new_state = ssm_decode_step(xs.reshape(B, nh, hp), dt, A, Bm, Cm, c["ssm"])
    y = y + p["D"].astype(y.dtype)[None, :, None] * xs.reshape(B, nh, hp)
    y = y.reshape(B, 1, di)
    y = rmsnorm(y * silu(z)[:, None], p["ssm_norm"])
    return y @ p["out_proj"], {"conv": new_conv_state, "ssm": new_state}


def decode_step(
    arch: ArchConfig,
    params: Dict,
    cache: Dict,
    tokens: Optional[jax.Array] = None,     # [B] token ids
    embeds: Optional[jax.Array] = None,     # [B, H] for stub-frontend archs
    pos: jax.Array = None,                  # scalar int32: current position
    cfg: RunCfg = RunCfg(),
) -> Tuple[jax.Array, Dict]:
    """One autoregressive step: returns (logits [B,V], new cache)."""
    if arch.embeds_input:
        x = embeds[:, None].astype(cfg.compute_dtype)
    else:
        x = params["embed"].astype(cfg.compute_dtype)[tokens][:, None]

    cast = lambda t: jax.tree.map(lambda a: a.astype(cfg.compute_dtype)
                                  if a.dtype in (jnp.float32, jnp.bfloat16) and a.ndim > 1
                                  else a, t)

    def body(x, scanned):
        lp, c = scanned
        lp = cast(lp)
        h = rmsnorm(x, lp["norm1"])
        new_c = {}
        if arch.block == "attn":
            o, kv = _decode_attn(arch, lp["attn"], h, c, pos, cfg)
            x = x + o
            new_c.update(kv)
        elif arch.block == "ssm":
            o, sc = _decode_ssm(arch, lp["ssm"], h, c, cfg)
            x = x + o
            new_c.update(sc)
        else:
            a, kv = _decode_attn(arch, lp["attn"], h, c, pos, cfg)
            s, sc = _decode_ssm(arch, lp["ssm"], h, c, cfg)
            x = x + 0.5 * (a + s)
            new_c.update(kv); new_c.update(sc)
        delta, _ = _run_ffn(arch, lp, x, cfg)
        return x + delta, new_c

    if cfg.scan_layers:
        x, new_cache = lax.scan(body, x, (params["layers"], cache))
    else:
        L = arch.num_layers
        new_layers = []
        for i in range(L):
            lp = jax.tree.map(lambda a: a[i], params["layers"])
            ci = jax.tree.map(lambda a: a[i], cache)
            x, nc = body(x, (lp, ci))
            new_layers.append(nc)
        new_cache = jax.tree.map(lambda *xs: jnp.stack(xs), *new_layers)
    x = rmsnorm(x, params["final_norm"].astype(cfg.compute_dtype))
    logits = (x @ params["lm_head"].astype(cfg.compute_dtype))[:, 0]
    return logits.astype(jnp.float32), new_cache
