"""PALM as the framework's auto-parallelism planner.

This is the paper's use-case made first-class: given an architecture
config and a hardware spec, sweep parallelism strategies through the
event-driven simulator (the §V-B loop: "directly iterate parallelism
strategies based on simulation results") and emit the best plan. The
launchers consume the result to pick TP/DP/PP degrees, microbatch count,
stage layout and comm strategy.
"""

from __future__ import annotations

import dataclasses
import itertools
import math
from dataclasses import dataclass
from typing import Callable, Iterable, List, Optional, Sequence

from ..configs.base import ArchConfig
from .hardware import HardwareSpec, tpu_v5e_pod
from .parallelism import ParallelPlan
from .simulator import PlanResult, simulate, sweep_plans
from .workload import arch_to_graph

__all__ = ["PlannerCfg", "plan_parallelism"]


@dataclass
class PlannerCfg:
    global_batch: int = 256
    seq_len: int = 4096
    training: bool = True
    schedules: Sequence[str] = ("1f1b",)
    layouts: Sequence[str] = ("s_shape", "line")
    microbatch_sizes: Sequence[int] = (1, 2, 4)
    max_plans: int = 64
    memory_cap: Optional[float] = None     # bytes per tile
    noc_mode: str = "macro"


def _divisor_splits(n: int) -> List[tuple]:
    """(pp, dp, tp) triples with pp*dp*tp == n."""
    out = []
    for pp in [d for d in range(1, n + 1) if n % d == 0]:
        rest = n // pp
        for dp in [d for d in range(1, rest + 1) if rest % d == 0]:
            out.append((pp, dp, rest // dp))
    return out


def plan_parallelism(
    arch: ArchConfig,
    hardware: Optional[HardwareSpec] = None,
    cfg: PlannerCfg = PlannerCfg(),
) -> List[PlanResult]:
    """Sweep (pp, dp, tp, microbatch, layout, schedule) and rank by
    simulated throughput. Returns sorted PlanResults (best first)."""
    hardware = hardware or tpu_v5e_pod()
    n = hardware.num_devices

    plans: List[ParallelPlan] = []
    for (pp, dp, tp) in _divisor_splits(n):
        if pp > max(1, arch.num_layers):
            continue
        if tp > max(arch.n_heads, arch.d_model // 64, 1):
            continue
        for b in cfg.microbatch_sizes:
            if cfg.global_batch % (b * dp):
                continue
            for sched in (cfg.schedules if cfg.training else ("gpipe",)):
                for layout in cfg.layouts:
                    plans.append(ParallelPlan(
                        pp=pp, dp=dp, tp=tp, microbatch=b,
                        global_batch=cfg.global_batch, schedule=sched,
                        layout=layout, training=cfg.training))
    # budget: prefer diverse (pp, dp, tp) triples first
    seen, pruned = set(), []
    for p in plans:
        key = (p.pp, p.dp, p.tp)
        if key not in seen or len(pruned) < cfg.max_plans // 2:
            pruned.append(p)
            seen.add(key)
        if len(pruned) >= cfg.max_plans:
            break

    def builder(plan: ParallelPlan):
        return arch_to_graph(arch, cfg.seq_len, plan.microbatch * plan.dp,
                             training=cfg.training)

    return sweep_plans(builder, hardware, pruned, noc_mode=cfg.noc_mode,
                       memory_cap=cfg.memory_cap)
