"""PALM as the framework's auto-parallelism (and hardware co-design) planner.

This is the paper's use-case made first-class: given an architecture
config and a hardware spec, sweep parallelism strategies through the
event-driven simulator (the §V-B loop: "directly iterate parallelism
strategies based on simulation results") and emit the best plan. The
launchers consume the result to pick TP/DP/PP degrees, microbatch count,
stage layout and comm strategy.

With a :class:`repro.api.HardwareSearchSpace` in :class:`PlannerCfg`, the
planner runs the paper's §VI loop instead: hardware variants and
parallelism plans are ranked *jointly* (one shared-pool sweep over the
flattened hardware x plan product) and :func:`plan_codesign` emits a
co-design recommendation — the best hardware spec (as serializable
:class:`HardwareSpec` JSON) together with the best plan on it.

Since the Experiment API landed this is a thin typed wrapper over
:class:`repro.api.Experiment` + :class:`repro.api.SweepEngine`: plan
enumeration lives in :class:`repro.api.SearchSpace`, evaluation in the
(optionally process-parallel) sweep engine, and results come back as
ranked :class:`repro.api.RunReport` objects (``.plan`` is the typed
ParallelPlan, ``.throughput`` the simulated rate).
"""

from __future__ import annotations

import json
from dataclasses import dataclass, field
from typing import Any, Dict, Optional, Sequence, TYPE_CHECKING, Union

from ..configs.base import ArchConfig
from .enums import Layout, NoCMode, Schedule
from .hardware import HardwareSpec, tpu_v5e_pod

if TYPE_CHECKING:                       # api builds on core; keep it lazy
    from ..api import HardwareSearchSpace, RunReport, SweepReport
    from ..api.sweep import SweepEngine
    from ..serving.system import ServingSpec
    from .parallelism import ParallelPlan

__all__ = ["PlannerCfg", "CodesignResult", "plan_parallelism", "plan_codesign"]


@dataclass
class PlannerCfg:
    global_batch: int = 256
    seq_len: int = 4096
    training: bool = True
    schedules: Sequence[Union[Schedule, str]] = (Schedule.ONE_F_ONE_B,)
    layouts: Sequence[Union[Layout, str]] = (Layout.S_SHAPE, Layout.LINE)
    microbatch_sizes: Sequence[int] = (1, 2, 4)
    max_plans: int = 64
    memory_cap: Optional[float] = None     # bytes per tile
    noc_mode: Union[NoCMode, str] = NoCMode.MACRO
    workers: int = 0                       # 0 = serial; N = process pool
    # co-design: cross the plan sweep with hardware variants (§VI); the
    # merged ranking scores joint (hardware, plan) candidates through one
    # shared-pool sweep
    hardware_search: Optional["HardwareSearchSpace"] = None
    # guided search (repro.search): "exhaustive" evaluates the full
    # product (legacy path); "random" / "sh" / "evolve" spend at most
    # `search_budget` full-fidelity simulations (default: a fifth of the
    # space) steered by cheap reduced-fidelity rungs, seeded for
    # bit-reproducible runs
    search_strategy: str = "exhaustive"
    search_budget: Optional[int] = None
    search_seed: Optional[int] = None      # guided strategies only; 0 default
    # SLO-aware serving objective: with objective="slo" candidates are
    # scored by SLO goodput under this traffic spec (the traffic-driven
    # serving simulator) instead of one training-iteration step time
    slo: Optional["ServingSpec"] = None


@dataclass
class CodesignResult:
    """Joint hardware/parallelism recommendation (§VI co-design loop).

    ``hardware`` is the winning variant as a full serializable spec —
    ``hardware.to_json()`` is ``--hardware-json`` compatible — and
    ``plan`` the best parallel plan on it; ``report`` keeps the whole
    ranked hardware x plan sweep for inspection.
    """

    hardware: HardwareSpec
    plan: "ParallelPlan"
    run: "RunReport"
    report: "SweepReport" = field(repr=False)
    objective: str = "throughput"        # "throughput" | "slo"

    @property
    def throughput(self) -> float:
        return self.run.throughput

    def to_dict(self) -> Dict[str, Any]:
        from ..api.report import plan_to_dict
        return {
            "hardware": self.hardware.to_dict(),
            "plan": plan_to_dict(self.plan),
            "objective": self.objective,
            "throughput": self.run.throughput,
            "total_time": self.run.total_time,
            "bubble_ratio": self.run.bubble_ratio,
            "peak_memory_bytes": self.run.peak_memory_bytes,
            "num_hardware": self.report.num_hardware,
            "num_candidates": self.report.num_candidates,
        }

    def to_json(self, **kw: Any) -> str:
        return json.dumps(self.to_dict(), **kw)

    def summary(self) -> str:
        p = self.plan
        unit = ("req/s SLO goodput" if self.objective == "slo"
                else "samples/s")
        return (f"{self.hardware.name}: pp={p.pp} dp={p.dp} tp={p.tp} "
                f"mb={p.microbatch} {p.schedule}/{p.layout} -> "
                f"{self.run.throughput:.2f} {unit}")


def _resolve_objective(cfg: PlannerCfg, objective: str) -> Optional["ServingSpec"]:
    """Validate the scoring objective; returns the ServingSpec for "slo"."""
    if objective == "throughput":
        return None
    if objective != "slo":
        raise ValueError(f"unknown objective {objective!r}; "
                         "known: throughput, slo")
    from ..serving.system import ServingSpec    # jax-free simulation half
    return cfg.slo if cfg.slo is not None else ServingSpec()


def _make_experiment(arch: ArchConfig, hardware: Optional[HardwareSpec],
                     cfg: PlannerCfg,
                     serving: Optional["ServingSpec"] = None):
    from ..api import Experiment, SearchSpace   # api builds on core

    hardware = hardware or tpu_v5e_pod()
    if serving is not None:
        # SLO objective: score candidates on decode traffic — the plan's
        # own batch is resized per engine step by the StepCostModel, so
        # global_batch only gates which dp splits enumerate
        return Experiment(
            arch=arch,
            hardware=hardware,
            search=SearchSpace(
                layouts=tuple(cfg.layouts),
                microbatch_sizes=(1,),
                max_plans=cfg.max_plans,
            ),
            hardware_search=cfg.hardware_search,
            seq_len=cfg.seq_len,
            global_batch=serving.max_batch,
            training=False,
            decode=True,
            noc_mode=cfg.noc_mode,
            memory_cap=cfg.memory_cap,
            serving=serving,
        )
    return Experiment(
        arch=arch,
        hardware=hardware,
        search=SearchSpace(
            schedules=tuple(cfg.schedules),
            layouts=tuple(cfg.layouts),
            microbatch_sizes=tuple(cfg.microbatch_sizes),
            max_plans=cfg.max_plans,
        ),
        hardware_search=cfg.hardware_search,
        seq_len=cfg.seq_len,
        global_batch=cfg.global_batch,
        training=cfg.training,
        noc_mode=cfg.noc_mode,
        memory_cap=cfg.memory_cap,
    )


def _sweep_kwargs(cfg: PlannerCfg, strategy: Optional[str]) -> Dict[str, Any]:
    strategy = strategy or cfg.search_strategy
    kw: Dict[str, Any] = {"workers": cfg.workers}
    if strategy not in (None, "exhaustive"):
        kw.update(strategy=strategy, search_budget=cfg.search_budget,
                  seed=cfg.search_seed or 0)
    elif cfg.search_budget is not None or cfg.search_seed is not None:
        raise ValueError("PlannerCfg.search_budget/search_seed only apply "
                         "to guided search; set search_strategy to "
                         "'random'/'sh'/'evolve'")
    return kw


def plan_parallelism(
    arch: ArchConfig,
    hardware: Optional[HardwareSpec] = None,
    cfg: PlannerCfg = PlannerCfg(),
    strategy: Optional[str] = None,
    objective: str = "throughput",
    engine: Optional["SweepEngine"] = None,
):
    """Sweep (pp, dp, tp, microbatch, layout, schedule) and rank by
    simulated throughput. Returns sorted RunReports (best first).

    With ``cfg.hardware_search`` set, hardware variants derived from
    ``hardware`` are swept jointly with the plans (one shared process
    pool) and the ranking covers (hardware, plan) pairs — each report's
    ``.hardware`` names the variant. Use :func:`plan_codesign` to get the
    winning variant back as a full :class:`HardwareSpec`.

    ``strategy`` (or ``cfg.search_strategy``) other than ``"exhaustive"``
    runs a guided budgeted search instead of the full product.

    ``objective="slo"`` ranks candidates by SLO goodput under the traffic
    spec in ``cfg.slo`` (the serving simulator) instead of training step
    throughput; each report's full :class:`ServingReport` dict rides in
    ``.extra["serving"]``. ``engine`` lends an open persistent
    :class:`SweepEngine` whose warm pool is reused (never closed here);
    by default the module-level :func:`repro.api.sweep.shared_engine`
    pool is used, so back-to-back planner calls about the same
    experiment re-initialize nothing.
    """
    exp = _make_experiment(arch, hardware, cfg,
                           serving=_resolve_objective(cfg, objective))
    if engine is None:
        from ..api.sweep import shared_engine   # api builds on core
        engine = shared_engine(workers=cfg.workers)
    return exp.sweep(engine=engine, **_sweep_kwargs(cfg, strategy)).runs


def plan_codesign(
    arch: ArchConfig,
    hardware: Optional[HardwareSpec] = None,
    cfg: PlannerCfg = PlannerCfg(),
    strategy: Optional[str] = None,
    objective: str = "throughput",
    engine: Optional["SweepEngine"] = None,
) -> CodesignResult:
    """Joint hardware/parallelism co-design (§VI): rank the flattened
    (hardware variant x plan) product and return the best pair as a
    :class:`CodesignResult` (winning spec + plan + full ranked report).

    ``cfg.hardware_search`` must be set — with no hardware axes there is
    nothing to co-design and :func:`plan_parallelism` is the right call.
    ``strategy`` (or ``cfg.search_strategy``) other than ``"exhaustive"``
    runs the §VI loop as a guided budgeted search (see
    :mod:`repro.search`); the ranked report then carries a nested
    :class:`~repro.search.SearchReport`.

    ``objective="slo"`` co-designs for *serving*: every (hardware, plan)
    pair is scored by SLO goodput under ``cfg.slo`` traffic, so a machine
    that wins on training step time can lose to one with the bandwidth
    headroom decode traffic actually needs. ``engine`` lends an open
    persistent :class:`SweepEngine` (reused, never closed here); defaults
    to the module-level :func:`repro.api.sweep.shared_engine` pool.
    """
    if cfg.hardware_search is None:
        raise ValueError("plan_codesign needs cfg.hardware_search (use "
                         "plan_parallelism for a parallelism-only sweep)")
    exp = _make_experiment(arch, hardware, cfg,
                           serving=_resolve_objective(cfg, objective))
    if engine is None:
        from ..api.sweep import shared_engine   # api builds on core
        engine = shared_engine(workers=cfg.workers)
    report = exp.sweep(engine=engine, **_sweep_kwargs(cfg, strategy))
    best = report.best
    if best is None:
        raise RuntimeError(
            f"no feasible (hardware, plan) candidate for {exp.arch_name}: "
            f"{report.num_candidates} candidates, "
            f"{report.num_pruned_memory} memory-pruned, "
            f"{report.num_failed} failed")
    spec_dict = report.best_hardware_dict()
    if spec_dict is not None:
        spec = HardwareSpec.from_dict(spec_dict)
    elif best.hardware == exp.hardware_spec.name:
        spec = exp.hardware_spec          # winner is the unmodified base
    else:
        # never hand back a base spec that contradicts the winning run
        raise RuntimeError(
            f"winning variant {best.hardware!r} has no serializable "
            "HardwareSpec (custom topology without a declarative spec); "
            "build the base hardware from a TopologySpec to co-design")
    return CodesignResult(hardware=spec, plan=best.plan, run=best,
                          report=report, objective=objective)
