"""PALM as the framework's auto-parallelism planner.

This is the paper's use-case made first-class: given an architecture
config and a hardware spec, sweep parallelism strategies through the
event-driven simulator (the §V-B loop: "directly iterate parallelism
strategies based on simulation results") and emit the best plan. The
launchers consume the result to pick TP/DP/PP degrees, microbatch count,
stage layout and comm strategy.

Since the Experiment API landed this is a thin typed wrapper over
:class:`repro.api.Experiment` + :class:`repro.api.SweepEngine`: plan
enumeration lives in :class:`repro.api.SearchSpace`, evaluation in the
(optionally process-parallel) sweep engine, and results come back as
ranked :class:`repro.api.RunReport` objects (``.plan`` is the typed
ParallelPlan, ``.throughput`` the simulated rate).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional, Sequence, Union

from ..configs.base import ArchConfig
from .enums import Layout, NoCMode, Schedule
from .hardware import HardwareSpec, tpu_v5e_pod

__all__ = ["PlannerCfg", "plan_parallelism"]


@dataclass
class PlannerCfg:
    global_batch: int = 256
    seq_len: int = 4096
    training: bool = True
    schedules: Sequence[Union[Schedule, str]] = (Schedule.ONE_F_ONE_B,)
    layouts: Sequence[Union[Layout, str]] = (Layout.S_SHAPE, Layout.LINE)
    microbatch_sizes: Sequence[int] = (1, 2, 4)
    max_plans: int = 64
    memory_cap: Optional[float] = None     # bytes per tile
    noc_mode: Union[NoCMode, str] = NoCMode.MACRO
    workers: int = 0                       # 0 = serial; N = process pool


def plan_parallelism(
    arch: ArchConfig,
    hardware: Optional[HardwareSpec] = None,
    cfg: PlannerCfg = PlannerCfg(),
):
    """Sweep (pp, dp, tp, microbatch, layout, schedule) and rank by
    simulated throughput. Returns sorted RunReports (best first)."""
    from ..api import Experiment, SearchSpace   # api builds on core

    hardware = hardware or tpu_v5e_pod()
    exp = Experiment(
        arch=arch,
        hardware=hardware,
        search=SearchSpace(
            schedules=tuple(cfg.schedules),
            layouts=tuple(cfg.layouts),
            microbatch_sizes=tuple(cfg.microbatch_sizes),
            max_plans=cfg.max_plans,
        ),
        seq_len=cfg.seq_len,
        global_batch=cfg.global_batch,
        training=cfg.training,
        noc_mode=cfg.noc_mode,
        memory_cap=cfg.memory_cap,
    )
    return exp.sweep(workers=cfg.workers).runs
