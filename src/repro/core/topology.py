"""Topologies and their declarative specs (paper §II-C, §V-A2).

Two layers, mirroring ASTRA-sim-style hierarchical network descriptions:

* **Specs** — :class:`MeshSpec`, :class:`GPUClusterSpec`, and the
  two-level :class:`HierarchicalSpec` (a tile-level core grid composed
  over an inter-tile grid) are frozen dataclasses of pure data. They
  round-trip through ``to_dict``/``from_dict`` so a whole machine can be
  written as JSON, tweaked, and diffed, and :meth:`TopologySpec.compile`
  turns them into concrete topologies.

* **Compiled topologies** — :class:`Mesh2D`, :class:`Torus2D`,
  :class:`GPUCluster` implement the :class:`Topology` routing interface
  with **precomputed per-link bandwidth/latency arrays** and **memoized
  routing**: ``link_bandwidth``/``link_latency`` are O(1) array reads and
  ``route``/``hops``/``path_metrics`` are computed once per (src, dst)
  pair and cached. The NoC model's hot path (Eq. 2: latency sum +
  bottleneck bandwidth along a path) reads :meth:`Topology.path_metrics`
  instead of re-walking the route, which is what makes large detailed
  simulations fast (see ``benchmarks/bench_sim_scaling.py``). Pass
  ``cache_routing=False`` to recover the per-call baseline.

Routes returned by :meth:`Topology.route` are cached lists — treat them
as immutable.
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Tuple, Type

__all__ = [
    "Topology",
    "Mesh2D",
    "Torus2D",
    "GPUCluster",
    "TopologySpec",
    "MeshSpec",
    "GPUClusterSpec",
    "HierarchicalSpec",
    "topology_spec_from_dict",
    "spec_of",
]

GB = 1e9


# ---------------------------------------------------------------------------
# Compiled topologies (routing interface + caches)
# ---------------------------------------------------------------------------

class Topology:
    """Routing interface: a topology enumerates directed links and routes.

    Subclasses implement :meth:`_compute_route` plus the link-property
    lookups; the base class supplies route memoization and the cached
    ``path_metrics`` fast path consumed by the NoC model.
    """

    num_devices: int

    def __init__(self, cache_routing: bool = True):
        self.cache_routing = cache_routing
        self._route_cache: Dict[Tuple[int, int], List[int]] = {}
        # (src, dst) -> sorted de-duplicated link ids (the acquisition set)
        self._links_cache: Dict[Tuple[int, int], List[int]] = {}
        # (src, dst) -> (hops, latency_sum, bottleneck_bw)
        self._metric_cache: Dict[Tuple[int, int], Tuple[int, float, float]] = {}

    # -- to be implemented by subclasses -----------------------------------
    def _compute_route(self, src: int, dst: int) -> List[int]:
        raise NotImplementedError

    def num_links(self) -> int:
        raise NotImplementedError

    def link_bandwidth(self, link_id: int) -> float:
        raise NotImplementedError

    def link_latency(self, link_id: int) -> float:
        raise NotImplementedError

    def coords(self, device: int) -> Tuple[int, int]:
        raise NotImplementedError

    # -- cached routing ----------------------------------------------------
    def route(self, src: int, dst: int) -> List[int]:
        """Link ids traversed from ``src`` to ``dst`` (cached; don't mutate)."""
        if not self.cache_routing:
            return self._compute_route(src, dst)
        key = (src, dst)
        r = self._route_cache.get(key)
        if r is None:
            r = self._compute_route(src, dst)
            self._route_cache[key] = r
        return r

    def route_links(self, src: int, dst: int) -> List[int]:
        """Sorted, de-duplicated link ids of the src->dst route — the
        deadlock-free acquisition order (cached; don't mutate)."""
        if not self.cache_routing:
            return sorted(set(self._compute_route(src, dst)))
        key = (src, dst)
        r = self._links_cache.get(key)
        if r is None:
            r = sorted(set(self.route(src, dst)))
            self._links_cache[key] = r
        return r

    def path_metrics(self, src: int, dst: int) -> Tuple[int, float, float]:
        """(hops, latency_sum, bottleneck_bw) for the src->dst route.

        This is Eq. (2)'s per-path cost in one cached lookup; empty routes
        (src == dst) report infinite bandwidth so ``nbytes / bw`` is 0.
        """
        key = (src, dst)
        m = self._metric_cache.get(key)
        if m is None:
            r = self.route(src, dst)
            if r:
                m = (len(r),
                     sum(self.link_latency(l) for l in r),
                     min(self.link_bandwidth(l) for l in r))
            else:
                m = (0, 0.0, float("inf"))
            if self.cache_routing:
                self._metric_cache[key] = m
        return m

    def hops(self, src: int, dst: int) -> int:
        return self.path_metrics(src, dst)[0]


class Mesh2D(Topology):
    """2-D mesh with X-Y dimension-ordered routing.

    Two-level bandwidth: a hop whose endpoints lie in different *tiles*
    (``tile_shape`` groups of cores) uses ``inter_bw``; hops inside a tile
    use ``intra_bw``. With ``tile_shape=(1,1)`` it degenerates to a flat
    mesh (Grayskull-style single-level). Per-link bandwidth/latency are
    precomputed into arrays at construction.
    """

    def __init__(
        self,
        rows: int,
        cols: int,
        intra_bw: float,
        inter_bw: Optional[float] = None,
        link_latency: float = 5e-8,
        tile_shape: Tuple[int, int] = (1, 1),
        cache_routing: bool = True,
    ):
        super().__init__(cache_routing=cache_routing)
        self.rows, self.cols = rows, cols
        self.num_devices = rows * cols
        self.intra_bw = intra_bw
        self.inter_bw = intra_bw if inter_bw is None else inter_bw
        self._latency = link_latency
        self.tile_shape = tuple(tile_shape)
        # link id layout: horizontal links then vertical links, both directed.
        #   h-link (r, c, dir): between (r,c) and (r,c+1); dir 0 = east, 1 = west
        #   v-link (r, c, dir): between (r,c) and (r+1,c); dir 0 = south, 1 = north
        self._num_h = rows * (cols - 1) * 2
        self._num_v = (rows - 1) * cols * 2
        self._bw: List[float] = [self._endpoint_bw(*self._link_endpoints(l))
                                 for l in range(self.num_links())]

    # -- indexing -----------------------------------------------------------
    def device(self, r: int, c: int) -> int:
        return r * self.cols + c

    def coords(self, device: int) -> Tuple[int, int]:
        return divmod(device, self.cols)

    def _h_link(self, r: int, c: int, westward: bool) -> int:
        return (r * (self.cols - 1) + c) * 2 + int(westward)

    def _v_link(self, r: int, c: int, northward: bool) -> int:
        return self._num_h + (r * self.cols + c) * 2 + int(northward)

    def num_links(self) -> int:
        return self._num_h + self._num_v

    # -- routing --------------------------------------------------------------
    def _compute_route(self, src: int, dst: int) -> List[int]:
        (r0, c0), (r1, c1) = self.coords(src), self.coords(dst)
        links: List[int] = []
        c = c0
        while c < c1:
            links.append(self._h_link(r0, c, westward=False))
            c += 1
        while c > c1:
            links.append(self._h_link(r0, c - 1, westward=True))
            c -= 1
        r = r0
        while r < r1:
            links.append(self._v_link(r, c1, northward=False))
            r += 1
        while r > r1:
            links.append(self._v_link(r - 1, c1, northward=True))
            r -= 1
        return links

    # -- link properties -------------------------------------------------------
    def _link_endpoints(self, link_id: int) -> Tuple[Tuple[int, int], Tuple[int, int]]:
        if link_id < self._num_h:
            base, westward = divmod(link_id, 2)
            r, c = divmod(base, self.cols - 1)
            return (r, c), (r, c + 1)
        base, northward = divmod(link_id - self._num_h, 2)
        r, c = divmod(base, self.cols)
        return (r, c), (r + 1, c)

    def _endpoint_bw(self, a: Tuple[int, int], b: Tuple[int, int]) -> float:
        (r0, c0), (r1, c1) = a, b
        tr, tc = self.tile_shape
        same_tile = (r0 // tr == r1 // tr) and (c0 // tc == c1 // tc)
        return self.intra_bw if same_tile else self.inter_bw

    def link_bandwidth(self, link_id: int) -> float:
        return self._bw[link_id]

    def link_latency(self, link_id: int) -> float:
        return self._latency


class Torus2D(Mesh2D):
    """2-D torus: a mesh plus wraparound links on every row and column.

    Extra link ids, after the mesh's horizontal+vertical blocks:

    * row wrap (r, dir):  ``dir 0`` = east wrap (r, cols-1) -> (r, 0),
      ``dir 1`` = west wrap (r, 0) -> (r, cols-1)
    * col wrap (c, dir):  ``dir 0`` = south wrap (rows-1, c) -> (0, c),
      ``dir 1`` = north wrap (0, c) -> (rows-1, c)

    Routing stays X-Y dimension-ordered but takes the shorter direction
    around each ring (ties go to the non-wrapping mesh direction), so a
    torus route never has more hops than the mesh route between the same
    pair.
    """

    def __init__(self, *args, **kw):
        super().__init__(*args, **kw)
        self._wrap_base = self._num_h + self._num_v
        self._num_wrap = 2 * self.rows + 2 * self.cols
        self._bw = [self._endpoint_bw(*self._link_endpoints(l))
                    for l in range(self.num_links())]

    def num_links(self) -> int:
        return self._num_h + self._num_v + getattr(self, "_num_wrap", 0)

    def _row_wrap(self, r: int, westward: bool) -> int:
        return self._wrap_base + 2 * r + int(westward)

    def _col_wrap(self, c: int, northward: bool) -> int:
        return self._wrap_base + 2 * self.rows + 2 * c + int(northward)

    def _link_endpoints(self, link_id: int) -> Tuple[Tuple[int, int], Tuple[int, int]]:
        wrap_base = getattr(self, "_wrap_base", None)
        if wrap_base is None or link_id < wrap_base:
            return super()._link_endpoints(link_id)
        base = link_id - wrap_base
        if base < 2 * self.rows:
            r = base // 2
            return (r, 0), (r, self.cols - 1)
        c = (base - 2 * self.rows) // 2
        return (0, c), (self.rows - 1, c)

    def _compute_route(self, src: int, dst: int) -> List[int]:
        (r0, c0), (r1, c1) = self.coords(src), self.coords(dst)
        links: List[int] = []
        # X first: shorter way around the row ring (ties: the direct mesh
        # direction, which for c1 >= c0 is east and never wraps)
        d_east = (c1 - c0) % self.cols
        d_west = (c0 - c1) % self.cols
        c = c0
        if d_east < d_west or (d_east == d_west and c1 >= c0):
            for _ in range(d_east):
                links.append(self._row_wrap(r0, westward=False)
                             if c == self.cols - 1
                             else self._h_link(r0, c, westward=False))
                c = (c + 1) % self.cols
        else:
            for _ in range(d_west):
                links.append(self._row_wrap(r0, westward=True)
                             if c == 0
                             else self._h_link(r0, c - 1, westward=True))
                c = (c - 1) % self.cols
        # then Y along column c1 (same tie-break: direct mesh direction)
        d_south = (r1 - r0) % self.rows
        d_north = (r0 - r1) % self.rows
        r = r0
        if d_south < d_north or (d_south == d_north and r1 >= r0):
            for _ in range(d_south):
                links.append(self._col_wrap(c1, northward=False)
                             if r == self.rows - 1
                             else self._v_link(r, c1, northward=False))
                r = (r + 1) % self.rows
        else:
            for _ in range(d_north):
                links.append(self._col_wrap(c1, northward=True)
                             if r == 0
                             else self._v_link(r - 1, c1, northward=True))
                r = (r - 1) % self.rows
        return links


class GPUCluster(Topology):
    """Two-level GPU cluster: node switch (NVLink) + cluster switch (IB).

    Link ids: for each GPU g, links ``2g`` (up to node switch) and ``2g+1``
    (down). For each node n, links ``2G + 2n`` (node up to cluster) and
    ``2G + 2n + 1`` (down). Intra-node routes use only NVLink up/down;
    inter-node routes traverse NVLink up, NIC up, NIC down, NVLink down.
    """

    def __init__(
        self,
        num_gpus: int,
        gpus_per_node: int = 8,
        nvlink_bw: float = 300 * GB,     # A100 NVLink3 per direction
        nic_bw: float = 25 * GB,         # 8x200Gb/s HDR per node / 8 GPUs
        nvlink_latency: float = 2e-6,
        nic_latency: float = 5e-6,
        cache_routing: bool = True,
    ):
        super().__init__(cache_routing=cache_routing)
        self.num_devices = num_gpus
        self.gpus_per_node = gpus_per_node
        self.num_nodes = (num_gpus + gpus_per_node - 1) // gpus_per_node
        self.nvlink_bw, self.nic_bw = nvlink_bw, nic_bw
        self._nv_lat, self._nic_lat = nvlink_latency, nic_latency
        self._nvlink_cutoff = 2 * self.num_devices
        self._node_bw = nic_bw * gpus_per_node  # node NIC aggregate

    def coords(self, device: int) -> Tuple[int, int]:
        return divmod(device, self.gpus_per_node)  # (node, local rank)

    def num_links(self) -> int:
        return 2 * self.num_devices + 2 * self.num_nodes

    def _compute_route(self, src: int, dst: int) -> List[int]:
        if src == dst:
            return []
        n_src, n_dst = src // self.gpus_per_node, dst // self.gpus_per_node
        if n_src == n_dst:
            return [2 * src, 2 * dst + 1]
        base = self._nvlink_cutoff
        return [2 * src, base + 2 * n_src, base + 2 * n_dst + 1, 2 * dst + 1]

    def link_bandwidth(self, link_id: int) -> float:
        return self.nvlink_bw if link_id < self._nvlink_cutoff else self._node_bw

    def link_latency(self, link_id: int) -> float:
        return self._nv_lat if link_id < self._nvlink_cutoff else self._nic_lat


# ---------------------------------------------------------------------------
# Declarative specs
# ---------------------------------------------------------------------------

# kind tag -> spec class, for from_dict dispatch
_SPEC_KINDS: Dict[str, Type["TopologySpec"]] = {}


def _register(kind: str):
    def deco(cls):
        cls.kind = kind
        _SPEC_KINDS[kind] = cls
        return cls
    return deco


class TopologySpec:
    """Base for declarative topology descriptions (pure, JSON-able data)."""

    kind: str = ""

    def compile(self, cache_routing: bool = True) -> Topology:
        raise NotImplementedError

    def to_dict(self) -> Dict[str, Any]:
        d = dataclasses.asdict(self)
        d["kind"] = self.kind
        return d

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "TopologySpec":
        kw = {k: v for k, v in d.items() if k != "kind"}
        return cls(**kw)


@_register("mesh")
@dataclass(frozen=True)
class MeshSpec(TopologySpec):
    """2-D mesh (or torus, with ``torus=True``) of cores.

    ``tile_shape`` groups cores into tiles: hops crossing a tile boundary
    use ``inter_bw`` (defaults to ``intra_bw`` for a flat single-level
    mesh). Prefer :class:`HierarchicalSpec` to express the two levels
    compositionally.
    """

    rows: int
    cols: int
    intra_bw: float
    inter_bw: Optional[float] = None
    link_latency: float = 5e-8
    tile_shape: Tuple[int, int] = (1, 1)
    torus: bool = False

    def __post_init__(self):
        if self.rows < 1 or self.cols < 1:
            raise ValueError(f"mesh shape {self.rows}x{self.cols} must be >= 1x1")
        tr, tc = self.tile_shape
        if self.rows % tr or self.cols % tc:
            raise ValueError(
                f"tile_shape {self.tile_shape} must divide mesh {self.rows}x{self.cols}")
        object.__setattr__(self, "tile_shape", tuple(self.tile_shape))

    @property
    def num_devices(self) -> int:
        return self.rows * self.cols

    def edge_devices(self, edge: str) -> Tuple[int, ...]:
        """Device ids along one mesh edge, in row/column order.

        ``edge`` is ``west`` (column 0), ``east`` (last column), ``north``
        (row 0) or ``south`` (last row) — the placement vocabulary for
        edge-shared DRAM ports (paper §IV-C ❸).
        """
        if edge == "west":
            return tuple(r * self.cols for r in range(self.rows))
        if edge == "east":
            return tuple(r * self.cols + self.cols - 1 for r in range(self.rows))
        if edge == "north":
            return tuple(range(self.cols))
        if edge == "south":
            return tuple((self.rows - 1) * self.cols + c for c in range(self.cols))
        raise ValueError(f"unknown edge {edge!r}; "
                         "expected west/east/north/south")

    def device_edges(self, device: int) -> Tuple[str, ...]:
        """Edges the device lies on (empty for interior devices; corners
        report both of their edges)."""
        r, c = divmod(device, self.cols)
        out = []
        if c == 0:
            out.append("west")
        if c == self.cols - 1:
            out.append("east")
        if r == 0:
            out.append("north")
        if r == self.rows - 1:
            out.append("south")
        return tuple(out)

    def compile(self, cache_routing: bool = True) -> Mesh2D:
        cls = Torus2D if self.torus else Mesh2D
        topo = cls(self.rows, self.cols, intra_bw=self.intra_bw,
                   inter_bw=self.inter_bw, link_latency=self.link_latency,
                   tile_shape=self.tile_shape, cache_routing=cache_routing)
        topo.spec = self
        return topo

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "MeshSpec":
        kw = {k: v for k, v in d.items() if k != "kind"}
        if "tile_shape" in kw and kw["tile_shape"] is not None:
            kw["tile_shape"] = tuple(kw["tile_shape"])
        return cls(**kw)


@_register("gpu_cluster")
@dataclass(frozen=True)
class GPUClusterSpec(TopologySpec):
    """Fat two-level GPU cluster (§V-A2): NVLink inside a node, NIC across."""

    num_gpus: int
    gpus_per_node: int = 8
    nvlink_bw: float = 300 * GB
    nic_bw: float = 25 * GB
    nvlink_latency: float = 2e-6
    nic_latency: float = 5e-6

    def __post_init__(self):
        if self.num_gpus < 1 or self.gpus_per_node < 1:
            raise ValueError("num_gpus and gpus_per_node must be >= 1")

    @property
    def num_devices(self) -> int:
        return self.num_gpus

    def compile(self, cache_routing: bool = True) -> GPUCluster:
        topo = GPUCluster(self.num_gpus, gpus_per_node=self.gpus_per_node,
                          nvlink_bw=self.nvlink_bw, nic_bw=self.nic_bw,
                          nvlink_latency=self.nvlink_latency,
                          nic_latency=self.nic_latency,
                          cache_routing=cache_routing)
        topo.spec = self
        return topo


@_register("hierarchical")
@dataclass(frozen=True)
class HierarchicalSpec(TopologySpec):
    """Two-level tiled accelerator: a tile-level core grid composed over an
    inter-tile grid (paper Table VI; e.g. 5x4 tiles of 4x4 cores).

    ``tile`` describes one tile's internal mesh (``intra_bw`` + latency);
    the outer grid places ``grid_rows x grid_cols`` tiles whose boundary
    hops run at ``inter_bw``. Compiles to the flattened core mesh the
    simulator routes on (uniform X-Y routing, two-level bandwidth).

    .. deprecated::
        For hierarchies *above* one chip (board/node/cluster tiers with
        their own link budgets and collective algorithms) prefer a
        :class:`repro.fabric.FabricSpec` attached to
        ``HardwareSpec.fabric`` — it models the scale-out levels as
        switched links with real collective schedules instead of
        flattening them into one mesh. ``HierarchicalSpec`` remains the
        right tool for the on-die two-level NoC of paper Table VI.
    """

    tile: MeshSpec
    grid_rows: int
    grid_cols: int
    inter_bw: float
    torus: bool = False

    def __post_init__(self):
        if self.grid_rows < 1 or self.grid_cols < 1:
            raise ValueError("grid shape must be >= 1x1")
        if self.tile.torus or self.tile.tile_shape != (1, 1) \
                or self.tile.inter_bw is not None:
            raise ValueError("HierarchicalSpec.tile must be a flat mesh "
                             "(no torus / tile_shape / inter_bw of its own)")

    @property
    def num_devices(self) -> int:
        return self.grid_rows * self.tile.rows * self.grid_cols * self.tile.cols

    def flatten(self) -> MeshSpec:
        """The equivalent single flattened core mesh."""
        return MeshSpec(
            rows=self.grid_rows * self.tile.rows,
            cols=self.grid_cols * self.tile.cols,
            intra_bw=self.tile.intra_bw,
            inter_bw=self.inter_bw,
            link_latency=self.tile.link_latency,
            tile_shape=(self.tile.rows, self.tile.cols),
            torus=self.torus,
        )

    def compile(self, cache_routing: bool = True) -> Mesh2D:
        topo = self.flatten().compile(cache_routing=cache_routing)
        # override the flattened MeshSpec attachment: serialization must
        # round-trip the *hierarchical* description, not its flattening
        topo.spec = self
        return topo

    def to_dict(self) -> Dict[str, Any]:
        d = super().to_dict()
        d["tile"] = self.tile.to_dict()
        return d

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "HierarchicalSpec":
        kw = {k: v for k, v in d.items() if k != "kind"}
        kw["tile"] = MeshSpec.from_dict(kw["tile"])
        return cls(**kw)


def topology_spec_from_dict(d: Dict[str, Any]) -> TopologySpec:
    """Rebuild a spec from its ``to_dict`` form, dispatching on ``kind``."""
    try:
        kind = d["kind"]
    except (TypeError, KeyError):
        raise ValueError(f"topology dict needs a 'kind' tag; got {d!r}") from None
    cls = _SPEC_KINDS.get(kind)
    if cls is None:
        raise ValueError(f"unknown topology kind {kind!r}; "
                         f"known: {sorted(_SPEC_KINDS)}")
    return cls.from_dict(d)


def spec_of(topo: Topology) -> Optional[TopologySpec]:
    """Recover the declarative spec of a compiled topology (None if the
    topology is a custom class the spec schema can't express).

    Topologies built by ``TopologySpec.compile`` carry their originating
    spec (``topo.spec``) and return it verbatim — this is what preserves
    a :class:`HierarchicalSpec` through serialization instead of
    degrading it to its flattened :class:`MeshSpec`. The structural
    fallbacks below handle hand-constructed topologies."""
    attached = getattr(topo, "spec", None)
    if isinstance(attached, TopologySpec):
        return attached
    if isinstance(topo, Mesh2D):          # Torus2D included
        return MeshSpec(rows=topo.rows, cols=topo.cols,
                        intra_bw=topo.intra_bw, inter_bw=topo.inter_bw,
                        link_latency=topo._latency,
                        tile_shape=tuple(topo.tile_shape),
                        torus=isinstance(topo, Torus2D))
    if isinstance(topo, GPUCluster):
        return GPUClusterSpec(num_gpus=topo.num_devices,
                              gpus_per_node=topo.gpus_per_node,
                              nvlink_bw=topo.nvlink_bw, nic_bw=topo.nic_bw,
                              nvlink_latency=topo._nv_lat,
                              nic_latency=topo._nic_lat)
    return None
