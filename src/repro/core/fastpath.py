"""Closed-form fast tier of the two-tier simulator core.

The event kernel (:mod:`repro.core.events`) prices every NoC leg, DRAM
stream and compute phase through the Python heap — the binding cost at
scale-out sizes (ROADMAP; Proteus shows the remedy). This module is the
analytic tier: it *replays* the scheduler's deterministic work lists in
plain arithmetic under the assumption that no resource is ever contended,
then **validates** that assumption against the full set of resource busy
intervals the run would have produced. Only when the optimistic execution
is proven contention-free is its result returned; otherwise the caller
falls back to the generator/heap kernel (the refinement tier).

Why this is exact, not approximate: under zero contention every
``Resource.request`` in the event kernel grants immediately (no time
advance), sequential ``yield``s accumulate durations left-to-right, and
``all_of`` completes at the max of its branches. Both facts commute with
IEEE-754 rounding (``t + max(a, b) == max(t + a, t + b)`` because
rounding is monotone), so evaluating the same float expression tree in
chain form reproduces the event kernel's timestamps bit-for-bit. The
models therefore export ``*_chain`` builders (``NoCModel.transfer_chain``
etc.) that mirror their generator bodies node-for-node rather than
algebraically simplified closed forms.

Chain nodes (plain tuples, struct-of-arrays evaluated):

* ``("dt", x)``          — advance local time by ``x``
* ``("hold", keys, x)``  — record a busy interval ``[t, t+x]`` on every
  packed ``(lane_kind, lane_id)`` key (:func:`repro.core.trace.pack_lane`),
  then advance by ``x``
* ``("par", branches)``  — evaluate every branch from the current time,
  continue at the max end (``all_of`` of concurrently spawned processes)
* ``("bytes", acc, n)``  — bump the ``noc``/``dram``/``fabric`` counter
* ``("spawn", chain)``   — evaluate the chain from the current time
  without advancing (an async ``env.process``); its end time joins the
  stage's pending-DP barrier

Contention validation: the recorded intervals are sorted per lane by
``(start, -duration)``; the run is contention-free iff no interval starts
strictly before its same-lane predecessor ends. Sorting by start makes
the consecutive-pair check complete (if any pair overlaps, a consecutive
pair does), and the ``-duration`` tie-break conservatively flags a
zero-length hold landing at the start of a longer one (whose event-tier
ordering would be heap-order dependent). Touching endpoints are exact:
the queued request is granted at the very release instant, displacing
nothing.
"""

from __future__ import annotations

from typing import Dict, List, NamedTuple, Optional, Tuple

try:
    import numpy as _np
except ImportError:         # pragma: no cover - exercised by CI bench-smoke
    _np = None

from .enums import BoundaryMode
from .parallelism import BD, FD, GU
from .trace import (
    KIND_BD,
    KIND_DRAM,
    KIND_FABRIC,
    KIND_FD,
    KIND_GU,
    TraceRecorder,
)

__all__ = ["FastPathIneligible", "classify", "classify_cached",
           "compile_stage_chains", "replay_chains", "try_fast_run",
           "StageChains", "reason_code"]


class FastPathIneligible(Exception):
    """The mapped graph (or its observed traffic) needs the event tier."""


# machine-readable codes for the prose rejection reasons this module and
# fastbatch produce (substring-matched so wording can carry detail);
# surfaced in RunReport.metrics["host"]["fastpath_rejection"] and as
# host.fastpath.reject.<code> sweep counters
_REASON_CODES = (
    ("interleaved virtual stages", "interleave"),
    ("group-to-group boundary", "strategy_boundary"),
    ("resource contention", "contention"),
    ("replay stalled", "stalled"),
    ("non-finite inference throughput", "nonfinite_throughput"),
    ("batch compilation failed", "batch_compile"),
)


def reason_code(reason: Optional[str]) -> str:
    """Map a prose fast-path rejection reason to its stable code."""
    if not reason:
        return "other"
    for needle, code in _REASON_CODES:
        if needle in reason:
            return code
    return "other"


# ---------------------------------------------------------------------------
# static classification
# ---------------------------------------------------------------------------

def classify(sim) -> Optional[str]:
    """Static contention-detection pass: return the reason this mapped
    graph cannot take the fast path, or ``None`` when it is a candidate.

    Only constructs whose *timing semantics* the chain algebra cannot
    express are rejected here; ordinary resource contention (links, DRAM
    channels, fabric) is detected dynamically by interval validation
    after the optimistic replay.
    """
    if sim.plan.interleave > 1:
        return ("interleaved virtual stages serialize on a shared "
                "PriorityResource (the 1F1B Prior Selector)")
    if (sim.boundary_mode == BoundaryMode.STRATEGY
            and sim.mapped.num_stages > 1
            and any(len(st.devices) > 1 for st in sim.mapped.stages)):
        return "strategy-mode group-to-group boundary hand-off"
    return None


def _classify_key(sim) -> Tuple:
    """Memo key: hardware digest + plan structure summary. Deliberately
    excludes ``global_batch`` (classification is invariant under
    micro-batch truncation), so multi-fidelity search rungs of the same
    (hardware, plan) candidate share one entry. Sound for memos scoped to
    one experiment: within an experiment the mapping *structure* (stage
    count, per-stage device groups) is a function of the hardware and the
    plan's structural fields alone."""
    p = sim.plan
    return (sim.hw.name, str(sim.boundary_mode), p.interleave,
            p.pp, p.dp, p.tp, bool(p.training), str(p.schedule),
            str(p.layout), bool(p.tp_contiguous), p.microbatch)


def classify_cached(sim, memo: Optional[Dict] = None) -> Optional[str]:
    """:func:`classify` through an optional caller-owned memo dict.

    The sweep path keys one memo per experiment (per worker), so the
    static classifier runs once per (hardware digest, plan summary)
    instead of once per job — microbatch-truncated fidelity rungs of the
    same candidate hit the same entry."""
    if memo is None:
        return classify(sim)
    key = _classify_key(sim)
    try:
        return memo[key]
    except KeyError:
        memo[key] = reason = classify(sim)
        return reason


# ---------------------------------------------------------------------------
# chain evaluation (struct-of-arrays interval recording)
# ---------------------------------------------------------------------------

class _ChainEval:
    """Evaluates chains, recording busy intervals + byte counters."""

    __slots__ = ("keys", "starts", "ends", "noc_bytes", "dram_bytes",
                 "fabric_bytes", "level_bytes", "nodes", "spawned")

    def __init__(self):
        self.keys: List[int] = []       # pack_lane(kind, lane) ids
        self.starts: List[float] = []
        self.ends: List[float] = []
        self.noc_bytes = 0.0
        self.dram_bytes = 0.0
        self.fabric_bytes = 0.0
        self.level_bytes: Dict[int, float] = {}   # fabric level -> bytes
        self.nodes = 0          # chain-node evaluations (sim-cost metric)
        self.spawned: List[float] = []

    def run(self, chain, t: float) -> float:
        # hot loop: local bindings + bulk extends; every branch preserves
        # the exact float expression the event kernel would evaluate
        self.nodes += len(chain)
        keys = self.keys
        starts = self.starts
        ends = self.ends
        run = self.run
        for node in chain:
            tag = node[0]
            if tag == "dt":
                t += node[1]
            elif tag == "hold":
                ks = node[1]
                end = t + node[2]
                n = len(ks)
                if n == 1:
                    keys.append(ks[0])
                    starts.append(t)
                    ends.append(end)
                else:
                    keys.extend(ks)
                    starts.extend([t] * n)
                    ends.extend([end] * n)
                t = end
            elif tag == "par":
                branches = node[1]
                if branches:
                    best = run(branches[0], t)
                    for b in branches[1:]:
                        e2 = run(b, t)
                        if e2 > best:
                            best = e2
                    t = best
            elif tag == "bytes":
                acc = node[1]
                if acc == "noc":
                    self.noc_bytes += node[2]
                elif acc == "dram":
                    self.dram_bytes += node[2]
                else:
                    self.fabric_bytes += node[2]
                    if len(node) > 3:
                        # per-level payload metadata, present only when
                        # the fabric compiled with metrics_levels set
                        lb = self.level_bytes
                        for lvl, b in node[3]:
                            lb[lvl] = lb.get(lvl, 0.0) + b
            else:  # "spawn"
                self.spawned.append(run(node[1], t))
        return t


def _validate_and_order(ev: _ChainEval):
    """Contention-check the recorded intervals, and (when clean) return
    them sorted by ``(end, start, key)`` — the order the event tier
    closes busy intervals in, used for timeline rows and the occupancy
    fallback's float accumulation.

    Returns ``(contended, kinds, lanes, starts, ends)``; the four column
    lists are empty when contended.
    """
    n = len(ev.keys)
    if n == 0:
        return False, [], [], [], []
    if _np is not None:
        key = _np.asarray(ev.keys, dtype=_np.int64)     # pack_lane ids
        s = _np.asarray(ev.starts)
        e = _np.asarray(ev.ends)
        if n > 1:
            order = _np.lexsort((s - e, s, key))   # key, start, -duration
            ks, ss, es = key[order], s[order], e[order]
            if bool(_np.any((ks[1:] == ks[:-1]) & (ss[1:] < es[:-1]))):
                return True, [], [], [], []
        order = _np.lexsort((key, s, e))        # end, start, key
        key = key[order]
        return (False, (key >> 32).tolist(), (key & 0xFFFFFFFF).tolist(),
                s[order].tolist(), e[order].tolist())
    rows = sorted(zip(ev.keys, ev.starts, ev.ends),
                  key=lambda r: (r[0], r[1], r[1] - r[2]))
    for a, b in zip(rows, rows[1:]):
        if b[0] == a[0] and b[1] < a[2]:
            return True, [], [], [], []
    rows.sort(key=lambda r: (r[2], r[1], r[0]))
    return (False, [r[0] >> 32 for r in rows],
            [r[0] & 0xFFFFFFFF for r in rows],
            [r[1] for r in rows], [r[2] for r in rows])


# ---------------------------------------------------------------------------
# chain compilation (mirrors PipelineSimulator's FD/BD/GU bodies)
# ---------------------------------------------------------------------------

def _dram_and_compute_chain(sim, stage, act_bytes, weight_bytes,
                            compute_s) -> List:
    if act_bytes + weight_bytes <= 0:
        return [("dt", compute_s)]
    shards = (stage.weight_shards if sim.plan.weight_multicast
              else len(stage.devices))
    dram = sim.dram.group_access_chain(stage.devices, act_bytes,
                                       shared_bytes=weight_bytes,
                                       num_shards=shards)
    if sim.plan.stream_overlap:
        return [("par", (tuple(dram), (("dt", compute_s),)))]
    return dram + [("dt", compute_s)]


def _collectives_chain(sim, stage, comms, phase) -> List:
    branches = []
    precision = sim.hw.precision_bytes
    for task in comms:
        if task.phase != phase:
            continue
        groups = stage.groups.get(task.axis)
        if not groups:
            continue
        per_dev_bytes = task.elems * precision
        for g in groups:
            branches.append(tuple(sim.noc.collective_chain(
                task.kind, g, per_dev_bytes)))
    return [("par", tuple(branches))] if branches else [("dt", 0.0)]


def _boundary_chain(sim, src: int, dst: int) -> List:
    s_from = sim.mapped.stages[src]
    s_to = sim.mapped.stages[dst]
    nbytes = (sim.mapped.boundary_elems(min(src, dst))
              * sim.hw.precision_bytes)
    # strategy mode with multi-device groups was rejected statically;
    # what remains is the pairwise Megatron-style P2P
    n = min(len(s_from.devices), len(s_to.devices))
    per = nbytes / n
    return [("par", tuple(tuple(sim.noc.transfer_chain(
        s_from.devices[i], s_to.devices[i], per)) for i in range(n)))]


def _fd_body_chain(sim, sid: int) -> List:
    stage = sim.mapped.stages[sid]
    chain: List = []
    if sid == 0 and stage.split_ops:
        first = stage.split_ops[0]
        nbytes = first.act_in_elems_tile * sim.hw.precision_bytes
        chain += sim.dram.group_access_chain(stage.devices, nbytes)
    for split, acc in zip(stage.split_ops, sim.access[sid]):
        chain += _dram_and_compute_chain(
            sim, stage, acc.fd_act, acc.fd_weight,
            sim._compute_time(split.fwd_flops_tile, split.matmul_fraction))
        chain += _collectives_chain(sim, stage, split.comms, FD)
    return chain


def _bd_body_chain(sim, sid: int, last_mb: bool) -> List:
    stage = sim.mapped.stages[sid]
    chain: List = []
    for split, acc in zip(reversed(stage.split_ops),
                          reversed(sim.access[sid])):
        compute = sim._compute_time(split.bwd_flops_tile,
                                    split.matmul_fraction)
        if sim.recompute:
            compute += sim._compute_time(split.fwd_flops_tile,
                                         split.matmul_fraction)
        chain += _dram_and_compute_chain(sim, stage, acc.bd_act,
                                         acc.bd_weight, compute)
        chain += _collectives_chain(sim, stage, split.comms, BD)
        if last_mb:
            chain.append(("spawn",
                          tuple(_collectives_chain(sim, stage, split.comms,
                                                   GU))))
    return chain


def _gu_chain(sim, sid: int) -> List:
    stage = sim.mapped.stages[sid]
    gu_bytes = sum(a.gu_bytes for a in sim.access[sid])
    if gu_bytes <= 0:
        return []
    return (sim.dram.group_access_chain(
                stage.devices, 0.0, shared_bytes=gu_bytes / 2,
                num_shards=stage.weight_shards)
            + sim.dram.group_access_chain(
                stage.devices, 0.0, write=True, shared_bytes=gu_bytes / 2,
                num_shards=stage.weight_shards))


# ---------------------------------------------------------------------------
# optimistic replay
# ---------------------------------------------------------------------------

class StageChains(NamedTuple):
    """The compiled per-stage chain set one replay consumes — shared
    between the scalar replay below and the batched evaluator
    (:mod:`repro.core.fastbatch`), which groups jobs by the chains'
    structural signature."""

    fd_body: List[List]
    fd_post: List[Optional[List]]
    bd_body: List[Optional[List]]
    bd_last: List[Optional[List]]
    bd_post: List[Optional[List]]
    gu_body: List[Optional[List]]


def compile_stage_chains(sim) -> StageChains:
    """Compile every FD/BD/GU body and boundary pass of a mapped graph
    into chain form (one walk of the models' ``*_chain`` builders)."""
    S = sim.mapped.num_stages
    training = sim.plan.training
    return StageChains(
        fd_body=[_fd_body_chain(sim, s) for s in range(S)],
        fd_post=[(_boundary_chain(sim, s, s + 1) if s + 1 < S else None)
                 for s in range(S)],
        bd_body=[(_bd_body_chain(sim, s, False) if training else None)
                 for s in range(S)],
        bd_last=[(_bd_body_chain(sim, s, True) if training else None)
                 for s in range(S)],
        bd_post=[(_boundary_chain(sim, s, s - 1) if training and s > 0
                  else None) for s in range(S)],
        gu_body=[(_gu_chain(sim, s) if training else None)
                 for s in range(S)],
    )


def try_fast_run(sim, strict: bool = False):
    """Attempt the analytic tier on a freshly constructed
    :class:`~repro.core.scheduler.PipelineSimulator`.

    Returns the bit-identical :class:`~repro.core.scheduler.SimResult`
    (``engine="fast"``) when the run is provably contention-free, else
    ``None`` — or raises :class:`FastPathIneligible` under ``strict``.
    The simulator instance is left untouched either way, so the caller
    can still run the event tier on it.
    """
    sim.fastpath_reason = None      # clear any stale batch-tier rejection
    reason = classify(sim)
    if reason is None:
        result, reason = _attempt(sim)
        if result is not None:
            return result
    # leave the rejection on the simulator so the metrics layer can
    # attach a machine-readable reason to the event-tier run that follows
    sim.fastpath_reason = reason
    if strict:
        raise FastPathIneligible(reason)
    return None


def _attempt(sim):
    return replay_chains(sim, compile_stage_chains(sim))


def replay_chains(sim, chains: StageChains):
    """Optimistically replay pre-compiled stage chains; returns
    ``(SimResult | None, reason | None)`` exactly like the fast tier —
    the chain-compilation half lives in :func:`compile_stage_chains` so
    the batched evaluator can reuse it."""
    from .scheduler import SimResult

    S = sim.mapped.num_stages
    M = sim.plan.num_microbatches
    training = sim.plan.training

    fd_body, fd_post, bd_body, bd_last, bd_post, gu_body = chains

    ev = _ChainEval()
    rec = TraceRecorder()
    work = [list(sim._work_list(s)) for s in range(S)]
    pos = [0] * S
    cursor = [0.0] * S
    prev_row = [-1] * S
    row_idx: Dict[Tuple[int, int, int], int] = {}
    act = {(0, i): 0.0 for i in range(M)}
    grad: Dict[Tuple[int, int], float] = {}
    fd_done: Dict[Tuple[int, int], float] = {}
    pending: List[List[float]] = [[] for _ in range(S)]
    gu_todo = [training] * S

    progress = True
    while progress:
        progress = False
        for s in range(S):
            while pos[s] < len(work[s]):
                kind, mb = work[s][pos[s]]
                if kind == FD:
                    dep = act.get((s, mb))
                    if dep is None:
                        break
                    t0 = cursor[s]
                    start = max(t0, dep)
                    end = ev.run(fd_body[s], start)
                    fd_done[(s, mb)] = end
                    pred = (row_idx.get((s - 1, KIND_FD, mb), -1)
                            if dep > t0 and s > 0 else prev_row[s])
                    r = rec.compute(s, KIND_FD, mb, start, end, pred)
                    row_idx[(s, KIND_FD, mb)] = r
                    prev_row[s] = r
                    if fd_post[s] is not None:
                        t_post = ev.run(fd_post[s], end)
                        act[(s + 1, mb)] = t_post
                        cursor[s] = t_post
                    else:
                        if training:
                            grad[(s, mb)] = end
                        cursor[s] = end
                else:
                    dep = grad.get((s, mb))
                    if dep is None:
                        break
                    t0 = cursor[s]
                    start = max(t0, dep)
                    n_sp = len(ev.spawned)
                    body = bd_last[s] if mb == M - 1 else bd_body[s]
                    end = ev.run(body, start)
                    pending[s].extend(ev.spawned[n_sp:])
                    if dep > t0:
                        pred = (row_idx.get((s, KIND_FD, mb), -1)
                                if s == S - 1
                                else row_idx.get((s + 1, KIND_BD, mb), -1))
                    else:
                        pred = prev_row[s]
                    r = rec.compute(s, KIND_BD, mb, start, end, pred)
                    row_idx[(s, KIND_BD, mb)] = r
                    prev_row[s] = r
                    if bd_post[s] is not None:
                        t_post = ev.run(bd_post[s], end)
                        grad[(s - 1, mb)] = t_post
                        cursor[s] = t_post
                    else:
                        cursor[s] = end
                pos[s] += 1
                progress = True
            if pos[s] == len(work[s]) and gu_todo[s]:
                t0 = cursor[s]
                start = max([t0] + pending[s])
                pred = (row_idx.get((s, KIND_BD, M - 1), -1)
                        if start > t0 else prev_row[s])
                end = ev.run(gu_body[s], start)
                r = rec.compute(s, KIND_GU, 0, start, end, pred)
                row_idx[(s, KIND_GU, 0)] = r
                prev_row[s] = r
                cursor[s] = end
                gu_todo[s] = False
                progress = True

    if any(pos[s] < len(work[s]) for s in range(S)) or any(gu_todo):
        # a mailbox never filled: the deterministic work lists deadlocked,
        # which the event tier would too — surface instead of mis-pricing
        return None, "work-list replay stalled (mailbox never filled)"

    contended, ikinds, ilanes, istarts, iends = _validate_and_order(ev)
    if contended:
        return None, "resource contention detected by interval validation"

    if ev.level_bytes:
        # successful replay owns the run: publish per-level fabric payload
        # where the event tier would have accumulated it
        sim.noc.level_bytes.update(ev.level_bytes)

    total = max(cursor, default=0.0)
    samples = sim.plan.global_batch
    if training:
        throughput = samples / total if total > 0 else 0.0
    else:
        finishes = sorted(t for (s, i), t in fd_done.items() if s == S - 1)
        mb_size = samples / M
        if len(finishes) > 1:
            throughput = ((len(finishes) - 1) * mb_size
                          / (finishes[-1] - finishes[0]))
        else:
            throughput = samples / total if total > 0 else 0.0

    if sim.collect_timeline:
        # resource lanes: the event tier emits one row per closed busy
        # interval (zero-length intervals suppressed). Raw row order is
        # tier-dependent; use Trace.canonical() for cross-tier comparison.
        for kk, ll, st, en in zip(ikinds, ilanes, istarts, iends):
            if en > st:
                rec.resource(kk, ll, st, en)

    fallback: Dict[int, float] = {}
    if not sim.collect_timeline:
        # mirror SimResult.noc_occupancy_fallback: per-link busy fraction
        # over every touched NoC/fabric link (fabric ids offset past the
        # chips' NoC id ranges, as in FabricModel.occupancy_report);
        # intervals arrive in (end, start) order so the float sums
        # accumulate exactly as the event tier closes them
        fabric_base = (getattr(sim.noc, "num_chips", 0)
                       * getattr(sim.noc, "_noc_stride", 0))
        busy: Dict[int, float] = {}
        for kk, ll, st, en in zip(ikinds, ilanes, istarts, iends):
            if kk == KIND_DRAM:
                continue
            occ = ll + fabric_base if kk == KIND_FABRIC else ll
            busy[occ] = busy.get(occ, 0.0) + (en - st)
        fallback = {occ: (busy[occ] / total if total > 0 else 0.0)
                    for occ in sorted(busy)}

    return SimResult(
        total_time=total,
        throughput=throughput,
        stage_memory=sim.memory,
        recompute=sim.recompute,
        event_count=ev.nodes,
        noc_bytes=ev.noc_bytes + ev.fabric_bytes,
        dram_bytes=ev.dram_bytes,
        engine="fast",
        trace=rec.freeze(total, S),
        noc_occupancy_fallback=fallback,
    ), None
