"""SRAM allocation & DRAM access sizing — PALM Alg. 1 (§IV-C ❶).

Strategies (paper nomenclature):

* ``S_WSG_ACT``  — weights+optimizer+gradients *and* activations resident
  in SRAM: DRAM sees only stage-boundary traffic.
* ``S_WSG`` (``activation_stream``) — WSG resident, activations stream:
  FD access = I + O per op.
* ``S_ACT`` (``weight_stream``)     — activations resident, weights stream:
  FD access = Wt per op (the Cerebras weight-streaming regime [41]).
* ``S_PTY`` (penalty)               — neither fits; weight-stationary vs
  input-stationary chosen by the Φ1/Φ2 comparison; extra DRAM accesses.

Note: as printed, Alg. 1's second guard ``WSG <= S_Cap`` is unreachable
(WSG >= Wt, and the first guard already failed on Wt). The intended guard
is on resident *activations* — we implement ``ACT <= S_Cap`` for the
weight-stream branch and keep the paper's first guard (``Wt`` resident,
extended to WSG when training, since gradients/optimizer state must also
live somewhere during training).

All returned sizes are **bytes**. Weights/activations move at the workload
precision; gradient-update (GU) traffic moves full-precision master
weights + optimizer state (paper: "full-precision weights load from DRAM
and store back").
"""

from __future__ import annotations

import math
from dataclasses import dataclass
from typing import List, Optional

from .enums import Schedule
from .hardware import HardwareSpec
from .parallelism import ParallelPlan, SplitOp, StageMapping

__all__ = [
    "OpAccess",
    "StageMemory",
    "optimizer_state_bytes_per_param",
    "allocate_stage",
    "stage_memory",
]

FP32 = 4


def optimizer_state_bytes_per_param(optimizer: str) -> int:
    """Adam: fp32 master + m + v (12 B); SGD: none (paper §IV-C ❶)."""
    if optimizer == "adam":
        return 12
    if optimizer == "sgd":
        return 0
    raise ValueError(f"unknown optimizer {optimizer!r}")


@dataclass
class OpAccess:
    """Per-op DRAM traffic (bytes) per micro-batch, per phase.

    Weight traffic is tracked separately from activation traffic: weight
    shards are identical across DP replicas, so on edge-shared DRAM one
    stream per *distinct shard* is fetched and multicast over the NoC
    (dataflow weight streaming), while activation traffic is per-tile."""

    strategy: str
    fd_act: float = 0.0
    fd_weight: float = 0.0
    bd_act: float = 0.0
    bd_weight: float = 0.0
    gu_bytes: float = 0.0   # per *mini*-batch (one gradient update); weights

    @property
    def fd_bytes(self) -> float:
        return self.fd_act + self.fd_weight

    @property
    def bd_bytes(self) -> float:
        return self.bd_act + self.bd_weight


@dataclass
class StageMemory:
    """Per-tile memory footprint of a stage (bytes).

    ``offload_bytes`` tracks activations parked outside the device
    (``plan.activation_offload``): they are excluded from :attr:`total`,
    which is what both the simulator's recompute decision and the sweep
    engine's pre-simulation memory-cap estimate compare against — so
    offload-aware pruning stays exact by construction."""

    weights: float
    grads: float
    opt_state: float
    act_per_microbatch: float
    inflight_microbatches: int
    offload_bytes: float = 0.0

    @property
    def activations(self) -> float:
        return self.act_per_microbatch * self.inflight_microbatches

    @property
    def total(self) -> float:
        return self.weights + self.grads + self.opt_state + self.activations


def _wsg_bytes(split: SplitOp, plan: ParallelPlan, precision: int) -> float:
    """Weights + optimizer state + weight gradients per tile (bytes)."""
    w = split.weight_elems_tile
    opt = optimizer_state_bytes_per_param(plan.optimizer) if plan.training else 0
    grads = precision if plan.training else 0
    dp_shard = max(1, plan.dp) if plan.zero >= 1 else 1
    return w * precision + (w * opt) / dp_shard + w * grads / (dp_shard if plan.zero >= 2 else 1)


def allocate_stage(
    stage: StageMapping,
    plan: ParallelPlan,
    hardware: HardwareSpec,
    recompute: bool = False,
    streaming_acts: Optional[bool] = None,
) -> List[OpAccess]:
    """Alg. 1 over one stage's split ops; returns per-op DRAM bytes.

    ``streaming_acts`` (default: inference pipelines) models dataflow
    execution (Grayskull/wafer style): activations move stage-to-stage
    over the NoC (the Act Pass events), never resting in DRAM, so the
    activation-stream branch charges no DRAM activation traffic and the
    penalty branch only streams weights.
    """
    precision = hardware.precision_bytes
    cap = hardware.tile.sram_bytes
    if streaming_acts is None:
        streaming_acts = not plan.training

    wt_total = sum(s.weight_elems_tile for s in stage.split_ops) * precision
    wsg_total = sum(_wsg_bytes(s, plan, precision) for s in stage.split_ops)
    act_total = sum(s.act_in_elems_tile for s in stage.split_ops) * precision

    resident_w = wsg_total if plan.training else wt_total

    out: List[OpAccess] = []
    for split in stage.split_ops:
        wt = split.weight_elems_tile * precision
        act_in = split.act_in_elems_tile * precision
        act_out = split.act_out_elems_tile * precision
        # GU traffic: full-precision weights load + store (+ Adam moments),
        # sharded by DP under ZeRO >= 1.
        opt_factor = 2 * FP32 + (2 * optimizer_state_bytes_per_param(plan.optimizer)
                                 if plan.optimizer == "adam" else 0)
        gu = split.weight_elems_tile * opt_factor
        if plan.zero >= 1:
            gu /= max(1, plan.dp)
        if not plan.training:
            gu = 0.0

        force = None
        if plan.dataflow == "ws":
            force = "weight_stationary"
        elif plan.dataflow == "is":
            force = "input_stationary"

        fd_a = fd_w = bd_a = bd_w = 0.0
        if force is None and resident_w + act_total <= cap:
            strategy = "sram_resident"          # S_WSG_ACT
        elif force is None and resident_w <= cap:
            strategy = "activation_stream"      # S_WSG
            fd_a = 0.0 if streaming_acts else act_in + act_out
            # BD: read saved input act + incoming out-grad, write in-grad
            bd_a = 2 * act_in + act_out
            if recompute:
                bd_a += act_in + act_out        # re-run FD accesses (Fig. 5)
        elif force is None and act_total <= cap:
            strategy = "weight_stream"          # S_ACT
            fd_w = wt
            # BD: stream weights for dgrad + wgrad, write weight grads
            bd_w = 2 * wt + (wt if plan.training else 0.0)
        elif streaming_acts:
            # dataflow pipeline with oversize weights: stream weights per
            # micro-batch while activations flow on the NoC
            strategy = "weight_stream"
            fd_w = wt
            bd_w = 2 * wt + (wt if plan.training else 0.0)
        else:
            # S_PTY: penalty — tiling, choose WS vs IS by Alg. 1's Φ test
            phi1 = math.ceil(max(wt, 1.0) / cap) * act_in   # weight-stationary
            phi2 = math.ceil(max(act_in, 1.0) / cap) * wt   # input-stationary
            if force == "weight_stationary" or (force is None and phi1 < phi2):
                strategy = "weight_stationary"
                fd_w, fd_a = wt, phi1 + act_out
            else:
                strategy = "input_stationary"
                fd_w, fd_a = phi2, act_in + act_out
            bd_a, bd_w = 2 * fd_a, 2 * fd_w
            if recompute:
                bd_a += fd_a
                bd_w += fd_w

        if plan.training and plan.activation_offload and not recompute:
            # offloaded saved activations: store after FD, fetch before BD
            # (with recompute nothing is saved, so offload is a no-op)
            fd_a += act_in
            bd_a += act_in
        if not plan.training:
            bd_a = bd_w = 0.0
        out.append(OpAccess(strategy=strategy, fd_act=fd_a, fd_weight=fd_w,
                            bd_act=bd_a, bd_weight=bd_w, gu_bytes=gu))
    return out


def stage_memory(stage: StageMapping, plan: ParallelPlan, hardware: HardwareSpec) -> StageMemory:
    """Per-tile memory footprint; encodes the paper's GPipe-vs-1F1B
    activation-capacity difference (§IV-B ❶: first stage stores B
    microbatch activations under GPipe but only S under 1F1B)."""
    precision = hardware.precision_bytes
    weights = sum(s.weight_elems_tile for s in stage.split_ops) * precision
    params = sum(s.weight_elems_tile for s in stage.split_ops)
    dp_shard = max(1, plan.dp) if plan.zero >= 1 else 1
    opt = params * optimizer_state_bytes_per_param(plan.optimizer) / dp_shard \
        if plan.training else 0.0
    grads = params * precision / (max(1, plan.dp) if plan.zero >= 2 else 1) \
        if plan.training else 0.0
    act_mb = sum(s.act_in_elems_tile for s in stage.split_ops) * precision

    num_mb = plan.num_microbatches
    S = plan.pp
    if not plan.training:
        inflight = 1
    elif plan.schedule == Schedule.GPIPE:
        inflight = num_mb
    else:  # 1f1b
        inflight = min(max(1, S - stage.stage_id), num_mb)
    offloaded = 0.0
    if plan.training and plan.activation_offload:
        # saved activations live off-device between FD and BD; only the
        # in-flight micro-batch stays resident
        offloaded = act_mb * max(0, inflight - 1)
        inflight = 1
    return StageMemory(weights=weights, grads=grads, opt_state=opt,
                       act_per_microbatch=act_mb, inflight_microbatches=inflight,
                       offload_bytes=offloaded)
