"""PALM top-level entry points (Fig. 2).

``simulate`` runs one training iteration (or an inference pipeline) of a
computation graph on a hardware spec under a parallelism plan and returns
absolute performance. ``sweep_plans`` is the planner loop the paper uses
in §V-B: iterate parallelism strategies directly against simulation
results — the capability the paper says existing simulators lack.

These remain the low-level functional entry points; :mod:`repro.api`
wraps them in the declarative :class:`~repro.api.Experiment` /
:class:`~repro.api.SweepEngine` surface (typed enums, process-pool
sweeps, JSON reports) which is the canonical front door.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Iterable, List, Optional

from .enums import BoundaryMode, NoCMode
from .graph import ComputationGraph
from .hardware import HardwareSpec
from .parallelism import MappedGraph, ParallelPlan, map_graph, plan_sort_key
from .scheduler import (
    PipelineSimulator,
    SimResult,
    ideal_pipeline_time,
    plan_memory,
)

__all__ = ["simulate", "sweep_plans", "PlanResult"]


def simulate(
    graph: ComputationGraph,
    hardware: HardwareSpec,
    plan: ParallelPlan,
    noc_mode: NoCMode = NoCMode.MACRO,
    collect_timeline: bool = False,
    boundary_mode: BoundaryMode = BoundaryMode.PAIRWISE,
    engine: str = "event",
) -> SimResult:
    """Run PALM once. ``graph`` must be built with per-iteration batch
    ``plan.microbatch * plan.dp`` (the DP group's micro-batch).

    The result's columnar ``trace`` always carries the FD/BD/GU compute
    lanes; ``collect_timeline=True`` additionally records NoC-link and
    DRAM-channel busy intervals (resource lanes).

    ``engine`` selects the simulator tier: ``"event"`` (the generator/
    heap kernel), ``"auto"`` (try the bit-identical closed-form fast
    tier, fall back on contention) or ``"fast"`` (fast tier or raise) —
    see :mod:`repro.core.fastpath`."""
    noc_mode = NoCMode(noc_mode)
    boundary_mode = BoundaryMode(boundary_mode)
    mapped = map_graph(graph, hardware, plan)
    sim = PipelineSimulator(mapped, noc_mode=noc_mode,
                            collect_timeline=collect_timeline,
                            boundary_mode=boundary_mode,
                            engine=engine)
    return sim.run()


@dataclass
class PlanResult:
    plan: ParallelPlan
    result: SimResult

    @property
    def throughput(self) -> float:
        return self.result.throughput


def sweep_plans(
    graph_builder: Callable[[ParallelPlan], ComputationGraph],
    hardware: HardwareSpec,
    plans: Iterable[ParallelPlan],
    noc_mode: NoCMode = NoCMode.MACRO,
    memory_cap: Optional[float] = None,
    engine: str = "event",
) -> List[PlanResult]:
    """Evaluate many parallelism strategies; returns results sorted by
    throughput (best first). Plans whose per-tile footprint exceeds
    ``memory_cap`` are dropped (the paper's capacity feasibility check)
    *before* simulation: the footprint is a property of the mapped graph,
    so infeasible plans cost a mapping, not a full event-driven run."""
    noc_mode = NoCMode(noc_mode)
    out: List[PlanResult] = []
    for plan in plans:
        graph = graph_builder(plan)
        mapped = map_graph(graph, hardware, plan)
        mem_plan = None
        if memory_cap is not None:
            mem_plan = plan_memory(mapped)
            if max(m.total for m in mem_plan[0]) > memory_cap:
                continue
        sim = PipelineSimulator(mapped, noc_mode=noc_mode, memory_plan=mem_plan,
                                engine=engine)
        out.append(PlanResult(plan=plan, result=sim.run()))
    # tie-break equal-throughput plans canonically so this ranking and the
    # SweepEngine's (run_rank_key) compare exactly on one hardware spec
    out.sort(key=lambda r: (-r.throughput, plan_sort_key(r.plan)))
    return out
