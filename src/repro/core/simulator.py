"""PALM top-level entry points (Fig. 2).

``simulate`` runs one training iteration (or an inference pipeline) of a
computation graph on a hardware spec under a parallelism plan and returns
absolute performance. ``sweep_plans`` is the planner loop the paper uses
in §V-B: iterate parallelism strategies directly against simulation
results — the capability the paper says existing simulators lack.
"""

from __future__ import annotations

import dataclasses
import itertools
from dataclasses import dataclass
from typing import Callable, Dict, Iterable, List, Optional, Sequence, Tuple

from .graph import ComputationGraph
from .hardware import HardwareSpec
from .parallelism import MappedGraph, ParallelPlan, map_graph
from .scheduler import PipelineSimulator, SimResult, ideal_pipeline_time

__all__ = ["simulate", "sweep_plans", "PlanResult"]


def simulate(
    graph: ComputationGraph,
    hardware: HardwareSpec,
    plan: ParallelPlan,
    noc_mode: str = "macro",
    collect_timeline: bool = False,
    boundary_mode: str = "pairwise",
) -> SimResult:
    """Run PALM once. ``graph`` must be built with per-iteration batch
    ``plan.microbatch * plan.dp`` (the DP group's micro-batch)."""
    mapped = map_graph(graph, hardware, plan)
    sim = PipelineSimulator(mapped, noc_mode=noc_mode,
                            collect_timeline=collect_timeline,
                            boundary_mode=boundary_mode)
    return sim.run()


@dataclass
class PlanResult:
    plan: ParallelPlan
    result: SimResult

    @property
    def throughput(self) -> float:
        return self.result.throughput


def sweep_plans(
    graph_builder: Callable[[ParallelPlan], ComputationGraph],
    hardware: HardwareSpec,
    plans: Iterable[ParallelPlan],
    noc_mode: str = "macro",
    memory_cap: Optional[float] = None,
) -> List[PlanResult]:
    """Evaluate many parallelism strategies; returns results sorted by
    throughput (best first). Plans whose per-tile footprint exceeds
    ``memory_cap`` are dropped (the paper's capacity feasibility check)."""
    out: List[PlanResult] = []
    for plan in plans:
        graph = graph_builder(plan)
        res = simulate(graph, hardware, plan, noc_mode=noc_mode)
        if memory_cap is not None:
            worst = max(m.total for m in res.stage_memory)
            if worst > memory_cap:
                continue
        out.append(PlanResult(plan=plan, result=res))
    out.sort(key=lambda r: -r.throughput)
    return out
