"""PALM core: event-driven performance simulator for tiled accelerators.

Paper: "PALM: A Efficient Performance Simulator for Tiled Accelerators
with Large-scale Model Training" (Fang et al., 2024). See DESIGN.md.
"""

from .enums import BoundaryMode, Layout, NoCMode, Schedule
from .events import AllOf, AnyOf, Environment, Event, PriorityResource, Process, Resource, Timeout
from .graph import (
    Attention,
    ComputationGraph,
    Conv2,
    Embedding,
    Linear,
    MoELayer,
    Norm,
    Op,
    Pool,
    SSMScan,
    TransformerLayer,
    bert_base_graph,
    resnet50_graph,
    transformer_lm_graph,
)
from .hardware import (
    DRAMSpec,
    GPUCluster,
    GPUClusterSpec,
    HARDWARE_PRESETS,
    HardwareSpec,
    HierarchicalSpec,
    Mesh2D,
    MeshSpec,
    TileSpec,
    Topology,
    TopologySpec,
    Torus2D,
    a100_cluster,
    grayskull,
    tpu_v5e_pod,
    wafer_scale,
)
from .topology import spec_of, topology_spec_from_dict
from .trace import (
    COMPUTE_KINDS,
    KIND_BD,
    KIND_DRAM,
    KIND_FD,
    KIND_GU,
    KIND_NOC,
    RESOURCE_KINDS,
    Trace,
    TraceDiff,
    TraceRecorder,
    TraceRow,
    chrome_trace,
)
from .trace import diff as trace_diff
from .noc import NoCModel, collective_steps, ring_time
from .dram import DRAMModel
from .parallelism import (
    BD,
    FD,
    GU,
    CommTask,
    MappedGraph,
    ParallelPlan,
    SplitOp,
    StageMapping,
    line_layout,
    make_groups,
    map_graph,
    s_shape_layout,
    split_op,
)
from .scheduler import PipelineSimulator, SimResult, ideal_pipeline_time
from .fastpath import (
    FastPathIneligible,
    StageChains,
    classify_cached,
    compile_stage_chains,
    replay_chains,
    try_fast_run,
)
from .fastbatch import run_fast_batch
from .simulator import PlanResult, simulate, sweep_plans
from .sram import OpAccess, StageMemory, allocate_stage, optimizer_state_bytes_per_param, stage_memory
