"""DRAM model — PALM §IV-C ❸ (Eq. 4/5).

DRAM bandwidth is a resource occupied during execution, exactly like NoC
links. In tiled accelerators DRAM sits at the array edge (or off-wafer):
an access must traverse the NoC to the nearest DRAM port, so

    DRAM_Time = Access_Time + NoC_Time            (Eq. 5)
    Access_Time = Response_Time + Size / BW_DRAM  (Eq. 4)

Devices with local HBM (GPUs/TPUs: ``hardware.dram_ports == ()``) skip the
NoC leg and contend only on their private channel.
"""

from __future__ import annotations

from typing import Dict, Generator, Optional

from .events import Environment, Resource
from .hardware import HardwareSpec
from .noc import NoCModel
from .trace import KIND_DRAM, TraceRecorder, pack_lane

__all__ = ["DRAMModel"]


class DRAMModel:
    def __init__(self, env: Environment, hardware: HardwareSpec, noc: NoCModel,
                 recorder: Optional[TraceRecorder] = None,
                 resource_base: int = 0):
        self.env = env
        self.hw = hardware
        self.noc = noc
        # when set, every channel records its busy intervals into the
        # trace's DRAM resource lane. ``resource_base`` offsets the
        # recorded/reported channel keys so per-chip DRAM instances of a
        # multi-chip fabric occupy disjoint trace-lane id ranges.
        self.recorder = recorder
        self.resource_base = resource_base
        self._channels: Dict[int, Resource] = {}
        self.bytes_accessed = 0.0

    def _channel(self, key: int) -> Resource:
        res = self._channels.get(key)
        if res is None:
            cb = (self.recorder.interval_cb(KIND_DRAM,
                                            self.resource_base + key)
                  if self.recorder is not None else None)
            res = Resource(self.env, capacity=1, name=f"dram{key}",
                           interval_cb=cb)
            self._channels[key] = res
        return res

    def occupancy_report(self) -> Dict[int, float]:
        """Channel utilizations in sorted key order."""
        return {self.resource_base + key: self._channels[key].utilization()
                for key in sorted(self._channels)}

    def close_open_intervals(self, t: float) -> None:
        """Flush still-busy channels into the recorder at simulation end."""
        if self.recorder is None:
            return
        for key in sorted(self._channels):
            since = self._channels[key].busy_since
            if since is not None and t > since:
                self.recorder.resource(KIND_DRAM, self.resource_base + key,
                                       since, t)

    def access(self, device: int, nbytes: float, priority: int = 0,
               write: bool = False) -> Generator:
        """Process: one DRAM read/write issued by ``device``."""
        if nbytes <= 0:
            yield self.env.timeout(0.0)
            return
        self.bytes_accessed += nbytes
        spec = self.hw.dram
        port = self.hw.nearest_dram_port(device)

        if port is not None and port != device:
            # NoC leg to the edge port (Eq. 5); same exclusive-link semantics
            src, dst = (device, port) if write else (port, device)
            yield self.env.process(self.noc.transfer(src, dst, nbytes, priority))

        # channel contention: shared edge channels, or per-device HBM
        key = port if port is not None else device % max(1, spec.channels)
        chan = self._channel(key)
        req = chan.request(priority)
        yield req
        yield self.env.timeout(spec.response_time + nbytes / spec.bandwidth)  # Eq. (4)
        chan.release(req)

    # -- fast-path pricing (repro.core.fastpath) -------------------------------
    def access_chain(self, device: int, nbytes: float,
                     write: bool = False) -> list:
        """Uncontended price of :meth:`access` as a fast-path chain."""
        if nbytes <= 0:
            return [("dt", 0.0)]
        spec = self.hw.dram
        port = self.hw.nearest_dram_port(device)
        chain: list = [("bytes", "dram", nbytes)]
        if port is not None and port != device:
            src, dst = (device, port) if write else (port, device)
            chain.extend(self.noc.transfer_chain(src, dst, nbytes))
        key = port if port is not None else device % max(1, spec.channels)
        chain.append(("hold", (pack_lane(KIND_DRAM, self.resource_base + key),),
                      spec.response_time + nbytes / spec.bandwidth))
        return chain

    def group_access_chain(self, devices, nbytes_per_device: float,
                           write: bool = False, shared_bytes: float = 0.0,
                           num_shards: int = 1) -> list:
        """Uncontended price of :meth:`group_access` as a fast-path chain."""
        if not self.hw.dram_ports:
            rep = next(iter(devices))
            return self.access_chain(rep, nbytes_per_device + shared_bytes,
                                     write)
        n_dev = len(list(devices))
        per_port: Dict[Optional[int], list] = {}
        for d in devices:
            per_port.setdefault(self.hw.nearest_dram_port(d), []).append(d)
        total_shared = shared_bytes * num_shards
        branches = []
        for port, devs in per_port.items():
            rep = devs[0]
            total = (nbytes_per_device * len(devs)
                     + total_shared * len(devs) / n_dev)
            branches.append(self.access_chain(rep, total, write))
        if not branches:
            return [("dt", 0.0)]
        return [("par", tuple(branches))]

    def group_access(self, devices, nbytes_per_device: float, priority: int = 0,
                     write: bool = False, shared_bytes: float = 0.0,
                     num_shards: int = 1) -> Generator:
        """Process: a tile group's concurrent DRAM accesses (virtual-tile
        aggregation).

        ``nbytes_per_device`` is per-tile-distinct traffic (activations);
        ``shared_bytes`` (x ``num_shards``) is weight traffic whose shards
        are identical across DP replicas — fetched once per shard and
        multicast on the NoC (dataflow weight streaming).

        Edge-shared DRAM (tiled accelerators): one representative request
        per distinct port carrying that port's group-aggregate bytes —
        ports are the shared, contended resource (§IV-C ❸).

        Local HBM (GPUs/TPUs, ``dram_ports == ()``): every device owns a
        private channel; each device fetches its own copy concurrently, so
        the representative request carries per-device bytes.
        """
        if not self.hw.dram_ports:
            rep = next(iter(devices))
            yield self.env.process(self.access(rep, nbytes_per_device + shared_bytes,
                                               priority, write))
            return
        n_dev = len(list(devices))
        per_port: Dict[Optional[int], list] = {}
        for d in devices:
            per_port.setdefault(self.hw.nearest_dram_port(d), []).append(d)
        total_shared = shared_bytes * num_shards
        procs = []
        for port, devs in per_port.items():
            rep = devs[0]
            total = nbytes_per_device * len(devs) + total_shared * len(devs) / n_dev
            procs.append(self.env.process(self.access(rep, total, priority, write)))
        if procs:
            yield self.env.all_of(procs)
        else:
            yield self.env.timeout(0.0)
