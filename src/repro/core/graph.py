"""Workload IR: operator computation graph (PALM §IV-B, Table III).

PALM consumes a computation graph of operators; each operator knows its
FLOPs, parameter count and activation sizes, and (in ``parallelism.py``)
how collective-communication volume scales with its parallelism degrees.

The paper's Table III defines Linear / Conv2 / Pool / Transformer. The
paper treats a transformer as "a combination of a series of linear
operators" — we follow the same decomposition rule to add the operator
types our assigned architectures need: ``Attention`` (GQA, optional
sliding window, decode mode), ``MoE`` (top-k experts), ``SSMScan``
(Mamba2 SSD), ``Embedding`` and ``Norm``.

All sizes are stored in *elements*; byte counts are ``elems *
precision_bytes`` where precision comes from the hardware/parallelism
context. All FLOPs are forward-pass; backward defaults to 2x forward for
weighted (matmul) operators and 1x for unweighted ones, the standard
accounting also used by Megatron.
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

__all__ = [
    "Op",
    "Linear",
    "Conv2",
    "Pool",
    "TransformerLayer",
    "Attention",
    "MoELayer",
    "SSMScan",
    "Embedding",
    "Norm",
    "ComputationGraph",
    "transformer_lm_graph",
    "resnet50_graph",
    "bert_base_graph",
]


@dataclass
class Op:
    """Base operator. Subclasses fill in the cost accounting."""

    name: str

    # -- costs (full, unsplit) ---------------------------------------------
    def fwd_flops(self) -> float:
        raise NotImplementedError

    def bwd_flops(self) -> float:
        return (2.0 if self.param_count() > 0 else 1.0) * self.fwd_flops()

    def param_count(self) -> float:
        return 0.0

    def in_elems(self) -> float:
        """Input activation element count (Alg. 1 ``Op.I``)."""
        raise NotImplementedError

    def out_elems(self) -> float:
        """Output activation element count (Alg. 1 ``Op.O``)."""
        raise NotImplementedError

    @property
    def matmul_fraction(self) -> float:
        """Fraction of FLOPs that run on the matrix unit (vs vector unit)."""
        return 1.0 if self.param_count() > 0 else 0.0

    # -- helpers --------------------------------------------------------------
    def flops_total(self, training: bool = True) -> float:
        return self.fwd_flops() + (self.bwd_flops() if training else 0.0)

    def __repr__(self) -> str:
        return f"<{type(self).__name__} {self.name} {self.fwd_flops():.3g}F {self.param_count():.3g}P>"


# ---------------------------------------------------------------------------
# Table III operators
# ---------------------------------------------------------------------------

@dataclass
class Linear(Op):
    """Y = W X^T with X:(N,K), W:(M,K), Y:(M,N), batched B (Table III row 1)."""

    B: int = 1
    M: int = 1
    N: int = 1
    K: int = 1

    def fwd_flops(self) -> float:
        return 2.0 * self.B * self.M * self.N * self.K

    def param_count(self) -> float:
        return float(self.M * self.K)

    def in_elems(self) -> float:
        return float(self.B * self.N * self.K)

    def out_elems(self) -> float:
        return float(self.B * self.M * self.N)


@dataclass
class Conv2(Op):
    """Conv2D, input (B,C,I,I), weight (R,S,C,K), output (B,K,O,O)."""

    B: int = 1
    H: int = 1
    W: int = 1
    C: int = 1
    R: int = 1
    S: int = 1
    K: int = 1
    stride: int = 1

    @property
    def H_out(self) -> int:
        return max(1, self.H // self.stride)

    @property
    def W_out(self) -> int:
        return max(1, self.W // self.stride)

    def fwd_flops(self) -> float:
        return 2.0 * self.B * self.H_out * self.W_out * self.R * self.S * self.C * self.K

    def param_count(self) -> float:
        return float(self.R * self.S * self.C * self.K)

    def in_elems(self) -> float:
        return float(self.B * self.C * self.H * self.W)

    def out_elems(self) -> float:
        return float(self.B * self.K * self.H_out * self.W_out)


@dataclass
class Pool(Op):
    """Pooling, window RxS (Table III row 3; K == 1)."""

    B: int = 1
    H: int = 1
    W: int = 1
    C: int = 1
    R: int = 2
    S: int = 2
    stride: int = 2

    def fwd_flops(self) -> float:
        return 2.0 * self.B * self.H * self.W * self.R * self.S * self.C / (self.stride ** 2)

    def in_elems(self) -> float:
        return float(self.B * self.C * self.H * self.W)

    def out_elems(self) -> float:
        return float(self.B * self.C * (self.H // self.stride) * (self.W // self.stride))


# ---------------------------------------------------------------------------
# Transformer-family operators (paper row 4 + our extensions)
# ---------------------------------------------------------------------------

@dataclass
class TransformerLayer(Op):
    """One decoder/encoder layer, Megatron accounting (Table III row 4).

    Generalises the paper's [B,S,H] row with GQA (``n_kv < n_heads``),
    gated MLPs, squared-ReLU, and sliding-window attention. With
    ``n_kv == n_heads``, gate off and ``d_ff = 4H`` the FLOPs reduce to the
    paper's ``24BSH^2 + 4BS^2H``.
    """

    B: int = 1
    S: int = 1
    H: int = 1              # d_model
    n_heads: int = 1
    n_kv: int = 1
    d_head: int = 0         # defaults to H / n_heads
    d_ff: int = 1
    gated_mlp: bool = True
    causal: bool = True
    window: Optional[int] = None   # sliding-window attention span

    def __post_init__(self):
        if self.d_head == 0:
            self.d_head = self.H // max(1, self.n_heads)

    # decomposition --------------------------------------------------------
    @property
    def attn_span(self) -> float:
        span = float(self.S if self.window is None else min(self.window, self.S))
        if self.causal and self.window is None:
            span = self.S / 2.0  # causal mask halves the score work
        return span

    def qkv_flops(self) -> float:
        q = self.n_heads * self.d_head
        kv = 2 * self.n_kv * self.d_head
        return 2.0 * self.B * self.S * self.H * (q + kv)

    def score_flops(self) -> float:
        # QK^T and PV, span-limited
        return 4.0 * self.B * self.S * self.attn_span * self.n_heads * self.d_head

    def out_proj_flops(self) -> float:
        return 2.0 * self.B * self.S * (self.n_heads * self.d_head) * self.H

    def mlp_flops(self) -> float:
        mults = 3 if self.gated_mlp else 2
        return 2.0 * self.B * self.S * self.H * self.d_ff * mults

    def fwd_flops(self) -> float:
        return self.qkv_flops() + self.score_flops() + self.out_proj_flops() + self.mlp_flops()

    def param_count(self) -> float:
        attn = self.H * (self.n_heads + 2 * self.n_kv) * self.d_head + (self.n_heads * self.d_head) * self.H
        mlp = (3 if self.gated_mlp else 2) * self.H * self.d_ff
        return float(attn + mlp + 2 * self.H)  # + two norms

    def in_elems(self) -> float:
        return float(self.B * self.S * self.H)

    def out_elems(self) -> float:
        return float(self.B * self.S * self.H)

    @property
    def matmul_fraction(self) -> float:
        f = self.fwd_flops()
        return (f - 0.0) / f if f else 1.0


@dataclass
class Attention(Op):
    """Standalone attention (used for decode: S_q new tokens vs S_kv cache)."""

    B: int = 1
    S_q: int = 1
    S_kv: int = 1
    n_heads: int = 1
    n_kv: int = 1
    d_head: int = 64

    def fwd_flops(self) -> float:
        return 4.0 * self.B * self.S_q * self.S_kv * self.n_heads * self.d_head

    def in_elems(self) -> float:
        # query + cached K/V
        return float(self.B * (self.S_q * self.n_heads + 2 * self.S_kv * self.n_kv) * self.d_head)

    def out_elems(self) -> float:
        return float(self.B * self.S_q * self.n_heads * self.d_head)

    @property
    def matmul_fraction(self) -> float:
        return 0.85


@dataclass
class MoELayer(Op):
    """Mixture-of-experts FFN with top-k routing (DBRX / granite-moe)."""

    B: int = 1
    S: int = 1
    H: int = 1
    n_experts: int = 8
    top_k: int = 2
    d_ff_expert: int = 1
    gated_mlp: bool = True

    def router_flops(self) -> float:
        return 2.0 * self.B * self.S * self.H * self.n_experts

    def expert_flops(self) -> float:
        mults = 3 if self.gated_mlp else 2
        return 2.0 * self.B * self.S * self.top_k * self.H * self.d_ff_expert * mults

    def fwd_flops(self) -> float:
        return self.router_flops() + self.expert_flops()

    def param_count(self) -> float:
        mults = 3 if self.gated_mlp else 2
        return float(self.n_experts * mults * self.H * self.d_ff_expert + self.H * self.n_experts)

    def in_elems(self) -> float:
        return float(self.B * self.S * self.H)

    def out_elems(self) -> float:
        return float(self.B * self.S * self.H)


@dataclass
class SSMScan(Op):
    """Mamba2 SSD block: in/out projections + chunked state-space scan."""

    B: int = 1
    S: int = 1
    H: int = 1              # d_model
    d_inner: int = 0        # typically 2H
    d_state: int = 128
    n_heads: int = 0        # SSD heads; d_inner / headdim
    conv_width: int = 4

    def __post_init__(self):
        if self.d_inner == 0:
            self.d_inner = 2 * self.H
        if self.n_heads == 0:
            self.n_heads = max(1, self.d_inner // 64)

    def proj_flops(self) -> float:
        # in_proj produces x, z, B, C, dt; out_proj back to H
        d_in_proj = 2 * self.d_inner + 2 * self.d_state + self.n_heads
        return 2.0 * self.B * self.S * self.H * d_in_proj + 2.0 * self.B * self.S * self.d_inner * self.H

    def scan_flops(self) -> float:
        # SSD recurrence: state update + output read, ~6 flops per
        # (token, channel, state) plus depthwise conv
        return 6.0 * self.B * self.S * self.d_inner * self.d_state + \
            2.0 * self.B * self.S * self.d_inner * self.conv_width

    def fwd_flops(self) -> float:
        return self.proj_flops() + self.scan_flops()

    def param_count(self) -> float:
        d_in_proj = 2 * self.d_inner + 2 * self.d_state + self.n_heads
        return float(self.H * d_in_proj + self.d_inner * self.H +
                     self.d_inner * self.conv_width + 2 * self.n_heads)

    def in_elems(self) -> float:
        return float(self.B * self.S * self.H)

    def out_elems(self) -> float:
        return float(self.B * self.S * self.H)

    @property
    def matmul_fraction(self) -> float:
        f = self.fwd_flops()
        return self.proj_flops() / f if f else 1.0


@dataclass
class Embedding(Op):
    """Token embedding lookup (DRAM-traffic-dominant for 256k vocabs)."""

    B: int = 1
    S: int = 1
    H: int = 1
    V: int = 1

    def fwd_flops(self) -> float:
        return float(self.B * self.S * self.H)  # gather + scale

    def bwd_flops(self) -> float:
        return float(self.B * self.S * self.H)  # scatter-add

    def param_count(self) -> float:
        return float(self.V * self.H)

    def in_elems(self) -> float:
        return float(self.B * self.S)

    def out_elems(self) -> float:
        return float(self.B * self.S * self.H)

    @property
    def matmul_fraction(self) -> float:
        return 0.0


@dataclass
class Norm(Op):
    """RMSNorm / LayerNorm (vector op)."""

    B: int = 1
    S: int = 1
    H: int = 1

    def fwd_flops(self) -> float:
        return 5.0 * self.B * self.S * self.H

    def param_count(self) -> float:
        return float(self.H)

    def in_elems(self) -> float:
        return float(self.B * self.S * self.H)

    def out_elems(self) -> float:
        return float(self.B * self.S * self.H)

    @property
    def matmul_fraction(self) -> float:
        return 0.0


# ---------------------------------------------------------------------------
# Graph container
# ---------------------------------------------------------------------------

@dataclass
class ComputationGraph:
    """Operator list + dependency edges (indices into ``ops``).

    A linear chain (the common LM case) needs no explicit edges; ops
    without edges depend on their predecessor, matching the paper's
    "pre-order rule" for dependency-free operators.
    """

    ops: List[Op]
    edges: List[Tuple[int, int]] = field(default_factory=list)
    name: str = "graph"

    def __post_init__(self):
        if not self.edges and len(self.ops) > 1:
            self.edges = [(i, i + 1) for i in range(len(self.ops) - 1)]

    def __len__(self) -> int:
        return len(self.ops)

    def total_fwd_flops(self) -> float:
        return sum(op.fwd_flops() for op in self.ops)

    def total_params(self) -> float:
        return sum(op.param_count() for op in self.ops)

    def successors(self, i: int) -> List[int]:
        return [d for (s, d) in self.edges if s == i]

    def predecessors(self, i: int) -> List[int]:
        return [s for (s, d) in self.edges if d == i]

    def partition_stages(self, num_stages: int) -> List[List[int]]:
        """Default stage allocation "based on computing power requirements"
        (paper §IV-B ❶): contiguous split balancing fwd+bwd FLOPs against
        cumulative targets; every stage receives at least one op."""
        if num_stages > len(self.ops):
            raise ValueError(
                f"{num_stages} stages > {len(self.ops)} ops in {self.name!r}")
        flops = [op.flops_total() for op in self.ops]
        total = sum(flops)
        stages: List[List[int]] = [[] for _ in range(num_stages)]
        s, acc = 0, 0.0
        for i, f in enumerate(flops):
            ops_left = len(flops) - i
            stages_left = num_stages - s
            over_target = acc + f / 2 > total * (s + 1) / num_stages
            must_advance = ops_left <= stages_left  # 1 op per remaining stage
            if stages[s] and stages_left > 1 and (over_target or must_advance):
                s += 1
            stages[s].append(i)
            acc += f
        return stages


# ---------------------------------------------------------------------------
# Graph builders for the paper's case studies
# ---------------------------------------------------------------------------

def transformer_lm_graph(
    name: str,
    num_layers: int,
    d_model: int,
    n_heads: int,
    seq_len: int,
    batch: int,
    vocab: int = 51200,
    n_kv: Optional[int] = None,
    d_ff: Optional[int] = None,
    gated_mlp: bool = False,
    include_embedding: bool = True,
) -> ComputationGraph:
    """GPT-style LM as PALM sees it: Embedding + L x TransformerLayer + LMHead."""
    n_kv = n_heads if n_kv is None else n_kv
    d_ff = 4 * d_model if d_ff is None else d_ff
    ops: List[Op] = []
    if include_embedding:
        ops.append(Embedding(name="embed", B=batch, S=seq_len, H=d_model, V=vocab))
    for i in range(num_layers):
        ops.append(TransformerLayer(
            name=f"layer{i}", B=batch, S=seq_len, H=d_model, n_heads=n_heads,
            n_kv=n_kv, d_ff=d_ff, gated_mlp=gated_mlp, causal=True))
    if include_embedding:
        ops.append(Linear(name="lm_head", B=batch, M=vocab, N=seq_len, K=d_model))
    return ComputationGraph(ops=ops, name=name)


def resnet50_graph(batch: int, image: int = 224) -> ComputationGraph:
    """ResNet-50 (He et al. [1]) for the Grayskull Table V benchmark."""
    ops: List[Op] = [Conv2(name="stem", B=batch, H=image, W=image, C=3, R=7, S=7, K=64, stride=2)]
    ops.append(Pool(name="maxpool", B=batch, H=image // 2, W=image // 2, C=64, R=3, S=3, stride=2))

    def bottleneck(idx: int, hw: int, cin: int, cmid: int, cout: int, stride: int):
        ops.append(Conv2(name=f"b{idx}_1x1a", B=batch, H=hw, W=hw, C=cin, R=1, S=1, K=cmid, stride=1))
        ops.append(Conv2(name=f"b{idx}_3x3", B=batch, H=hw, W=hw, C=cmid, R=3, S=3, K=cmid, stride=stride))
        ops.append(Conv2(name=f"b{idx}_1x1b", B=batch, H=hw // stride, W=hw // stride, C=cmid, R=1, S=1, K=cout, stride=1))

    idx = 0
    hw = image // 4
    spec = [(3, 64, 256, 1), (4, 128, 512, 2), (6, 256, 1024, 2), (3, 512, 2048, 2)]
    cin = 64
    for blocks, cmid, cout, first_stride in spec:
        for b in range(blocks):
            stride = first_stride if b == 0 else 1
            bottleneck(idx, hw, cin, cmid, cout, stride)
            hw //= stride
            cin = cout
            idx += 1
    ops.append(Pool(name="avgpool", B=batch, H=hw, W=hw, C=2048, R=hw, S=hw, stride=max(1, hw)))
    ops.append(Linear(name="fc", B=batch, M=1000, N=1, K=2048))
    return ComputationGraph(ops=ops, name="resnet50")


def bert_base_graph(batch: int, seq_len: int = 128) -> ComputationGraph:
    """BERT-base (12L, H=768) for Table V / Fig. 12 benchmarks."""
    ops: List[Op] = [Embedding(name="embed", B=batch, S=seq_len, H=768, V=30522)]
    for i in range(12):
        ops.append(TransformerLayer(
            name=f"layer{i}", B=batch, S=seq_len, H=768, n_heads=12, n_kv=12,
            d_ff=3072, gated_mlp=False, causal=False))
    return ComputationGraph(ops=ops, name="bert_base")
