"""Detailed NoC model — PALM §IV-C ❷ (Eq. 2/3) and collectives (§II-B).

The paper's key modeling decision: *links are exclusive resources during
execution*. A transfer that needs occupied links waits (contention delay);
when granted, a wormhole-pipelined transfer takes Eq. (2):

    Comm_Time = Link_Time x Hops + Comm_Size / BW_link(+ contention wait)

Three fidelity levels expose the paper's complexity story (§IV-A):

* ``detailed``   — every ring/all-to-all step is a set of link-holding
  transfer events: O(P^2) events per collective; used for the small
  validation benches (Fig. 6/7/12).
* ``macro``      — a collective holds its whole link footprint once for its
  closed-form duration; contention *between* collectives and DRAM traffic
  is preserved with O(1) events per collective. This is the
  "analytical model for the NoC" that takes Virtual Tile Aggregation to
  O(M) (§IV-A).
* ``analytical`` — pure closed form, no resources at all (the baseline the
  paper compares against in Fig. 7).

Collective cost closed forms (ring algorithms, P participants, S bytes
per participant): all-reduce 2(P-1)/P * S per link; reduce-scatter and
all-gather (P-1)/P * S; all-to-all (P-1)/P * S bisection-limited.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Dict, Generator, Iterable, List, Optional, Sequence, Tuple

from .enums import NoCMode
from .events import Environment, Resource
from .hardware import HardwareSpec, Topology
from .trace import KIND_NOC, TraceRecorder, pack_lane

__all__ = ["NoCModel", "collective_steps", "ring_time"]


def collective_steps(kind: str, p: int) -> int:
    if p <= 1:
        return 0
    return {"all_reduce": 2 * (p - 1), "reduce_scatter": p - 1, "all_gather": p - 1,
            "all_to_all": p - 1, "broadcast": 1, "reduce": 1}[kind]


def _chunk_bytes(kind: str, nbytes: float, p: int) -> float:
    """Bytes moved per participant per step for ring algorithms."""
    if p <= 1:
        return 0.0
    if kind in ("all_reduce", "reduce_scatter", "all_gather"):
        return nbytes / p
    if kind == "all_to_all":
        return nbytes / p          # one distinct shard per peer per step
    if kind in ("broadcast", "reduce"):
        return nbytes
    raise ValueError(kind)


def ring_time(kind: str, nbytes: float, p: int, bw: float, hop_latency: float,
              hops_per_step: int = 1) -> float:
    """Closed-form ring collective time (used by macro/analytical modes)."""
    steps = collective_steps(kind, p)
    if steps == 0:
        return 0.0
    per_step = hop_latency * hops_per_step + _chunk_bytes(kind, nbytes, p) / bw
    return steps * per_step


class NoCModel:
    """Event-driven NoC with pluggable fidelity."""

    def __init__(self, env: Environment, hardware: HardwareSpec,
                 mode: NoCMode = NoCMode.DETAILED,
                 recorder: Optional[TraceRecorder] = None,
                 resource_base: int = 0):
        self.env = env
        self.hw = hardware
        self.topo: Topology = hardware.topology
        self.mode = NoCMode(mode)
        # when set, every link records its busy intervals into the trace's
        # NOC resource lane (closed on busy->idle transitions).
        # ``resource_base`` offsets the recorded/reported link ids so the
        # per-chip NoC instances of a multi-chip fabric occupy disjoint
        # trace-lane id ranges (0 for the single-chip simulator).
        self.recorder = recorder
        self.resource_base = resource_base
        self._links: Dict[int, Resource] = {}
        # ring-collective link footprints, keyed by the group tuple (macro
        # mode re-runs the same groups every micro-batch)
        self._footprint_cache: Dict[Tuple[int, ...], List[int]] = {}
        # instrumentation
        self.bytes_moved = 0.0
        self.transfer_count = 0

    # -- resources ------------------------------------------------------------
    def link(self, link_id: int) -> Resource:
        res = self._links.get(link_id)
        if res is None:
            cb = (self.recorder.interval_cb(KIND_NOC,
                                            self.resource_base + link_id)
                  if self.recorder is not None else None)
            res = Resource(self.env, capacity=1, name=f"link{link_id}",
                           interval_cb=cb)
            self._links[link_id] = res
        return res

    def occupancy_report(self) -> Dict[int, float]:
        """Link utilizations in sorted link-id order (deterministic JSON /
        equality across pool workers regardless of link touch order)."""
        return {self.resource_base + lid: self._links[lid].utilization()
                for lid in sorted(self._links)}

    def close_open_intervals(self, t: float) -> None:
        """Flush still-busy links into the recorder at simulation end."""
        if self.recorder is None:
            return
        for lid in sorted(self._links):
            since = self._links[lid].busy_since
            if since is not None and t > since:
                self.recorder.resource(KIND_NOC, self.resource_base + lid,
                                       since, t)

    # -- primitive transfer ------------------------------------------------------
    def _path_time(self, route: Sequence[int], nbytes: float) -> float:
        if not route:
            return 0.0
        lat = sum(self.topo.link_latency(l) for l in route)
        bw = min(self.topo.link_bandwidth(l) for l in route)
        return lat + nbytes / bw  # Eq. (2), wormhole-pipelined

    def transfer(self, src: int, dst: int, nbytes: float, priority: int = 0) -> Generator:
        """Process: move ``nbytes`` from src to dst (holds the whole path —
        'treating the link as an exclusive resource during execution')."""
        self.bytes_moved += nbytes
        self.transfer_count += 1
        # Eq. (2) via the topology's cached path metrics (O(1) per pair)
        hops, lat, bw = self.topo.path_metrics(src, dst)
        t = lat + nbytes / bw if hops else 0.0
        if self.mode == NoCMode.ANALYTICAL or not hops:
            yield self.env.timeout(t)
            return
        # deadlock-free acquisition: global link-id order (cached per pair)
        reqs = []
        for lid in self.topo.route_links(src, dst):
            link = self.link(lid)
            req = link.request(priority)
            yield req
            reqs.append((link, req))
        yield self.env.timeout(t)
        for link, req in reqs:
            link.release(req)

    # -- collectives ------------------------------------------------------------
    def collective(self, kind: str, group: Sequence[int], nbytes: float,
                   priority: int = 0, root: Optional[int] = None) -> Generator:
        """Process: run a collective over ``group`` (device ids, ring order =
        list order). ``nbytes`` is the per-participant payload."""
        p = len(group)
        if p <= 1 or nbytes <= 0:
            yield self.env.timeout(0.0)
            return
        if self.mode == NoCMode.DETAILED:
            yield from self._collective_detailed(kind, list(group), nbytes, priority, root)
        elif self.mode == NoCMode.MACRO:
            yield from self._collective_macro(kind, list(group), nbytes, priority, root)
        else:
            yield self.env.timeout(self._collective_closed_form(kind, list(group), nbytes, root))

    # closed form on the actual topology ---------------------------------------
    def _ring_links(self, group: List[int]) -> List[int]:
        links: List[int] = []
        for i, src in enumerate(group):
            dst = group[(i + 1) % len(group)]
            links.extend(self.topo.route(src, dst))
        return links

    def _ring_footprint(self, group: List[int]) -> List[int]:
        """Sorted de-duplicated ring link set (cached per group)."""
        if not getattr(self.topo, "cache_routing", False):
            return sorted(set(self._ring_links(group)))
        key = tuple(group)
        fp = self._footprint_cache.get(key)
        if fp is None:
            fp = sorted(set(self._ring_links(group)))
            self._footprint_cache[key] = fp
        return fp

    def _chain_links(self, group: List[int], root: Optional[int]) -> List[int]:
        """Chain path visiting the group in order, starting at root."""
        order = list(group)
        if root is not None and root in order:
            order.remove(root)
            order = [root] + order
        links: List[int] = []
        for a, b in zip(order, order[1:]):
            links.extend(self.topo.route(a, b))
        return links

    def _collective_closed_form(self, kind: str, group: List[int], nbytes: float,
                                root: Optional[int]) -> float:
        p = len(group)
        if kind == "broadcast":
            # chain-pipelined (wormhole): the payload streams through the
            # member chain once; time = hop latencies + size / bottleneck bw
            links = self._chain_links(group, root)
            return self._path_time(links, nbytes)
        if kind == "reduce":
            # converging transfers: p-1 full-size payloads funnel into the
            # root's <=4 incident links (the §V-C strategy-2 cost driver)
            root = group[0] if root is None else root
            metrics = [self.topo.path_metrics(d, root)
                       for d in group if d != root]
            if not metrics:
                return 0.0
            bw = min((m[2] for m in metrics if m[0]), default=float("inf"))
            fan_in = min(4, len(metrics))
            lat = max(m[1] for m in metrics)
            return lat + len(metrics) * nbytes / (fan_in * bw)
        # ring: pipelined chunks — every chunk crosses every inter-neighbour
        # path, so the slowest path bounds the per-step rate (this is what
        # breaks when the ring has an off-ring member: §V-C)
        chunk = _chunk_bytes(kind, nbytes, p)
        step_times = []
        for i, src in enumerate(group):
            hops, lat, bw = self.topo.path_metrics(src, group[(i + 1) % p])
            step_times.append(lat + chunk / bw if hops else 0.0)
        return collective_steps(kind, p) * max(step_times)

    # macro: closed form + exclusive hold of the link footprint ----------------
    def _collective_macro(self, kind: str, group: List[int], nbytes: float,
                          priority: int, root: Optional[int]) -> Generator:
        self.bytes_moved += nbytes * len(group)
        self.transfer_count += 1
        t = self._collective_closed_form(kind, group, nbytes, root)
        footprint = self._ring_footprint(group)
        reqs = []
        for lid in footprint:
            link = self.link(lid)
            req = link.request(priority)
            yield req
            reqs.append((link, req))
        yield self.env.timeout(t)
        for link, req in reqs:
            link.release(req)

    # detailed: per-step transfers ---------------------------------------------
    def _collective_detailed(self, kind: str, group: List[int], nbytes: float,
                             priority: int, root: Optional[int]) -> Generator:
        env = self.env
        p = len(group)
        if kind == "broadcast":
            # chain-pipelined stream holding the chain's link set once
            self.bytes_moved += nbytes * (p - 1)
            self.transfer_count += 1
            links = self._chain_links(group, root)
            t = self._path_time(links, nbytes)
            reqs = []
            for lid in sorted(set(links)):
                link = self.link(lid)
                req = link.request(priority)
                yield req
                reqs.append((link, req))
            yield env.timeout(t)
            for link, req in reqs:
                link.release(req)
            return
        if kind == "reduce":
            # converging full-size transfers (contend on root's links)
            root = group[0] if root is None else root
            procs = [env.process(self.transfer(d, root, nbytes, priority))
                     for d in group if d != root]
            if procs:
                yield env.all_of(procs)
            return
        steps = collective_steps(kind, p)
        chunk = _chunk_bytes(kind, nbytes, p)
        for _ in range(steps):
            procs = [env.process(self.transfer(group[i], group[(i + 1) % p], chunk, priority))
                     for i in range(p)]
            yield env.all_of(procs)

    # -- fast-path pricing (repro.core.fastpath) -------------------------------
    # Chains are the analytic mirror of the generator bodies above: a flat
    # list of ("dt", x) advances, ("hold", keys, x) resource holds,
    # ("par", branches) concurrent sections and ("bytes", acc, n) counter
    # bumps, composed exactly as the event kernel would accumulate time
    # (sequential yields = additive chain, all_of = max), so evaluating a
    # chain at start time t reproduces the uncontended event timing
    # bit-for-bit. See repro/core/fastpath.py for the evaluator.

    def _link_keys(self, link_ids: Iterable[int]) -> Tuple:
        return tuple(pack_lane(KIND_NOC, self.resource_base + lid)
                     for lid in link_ids)

    def transfer_chain(self, src: int, dst: int, nbytes: float) -> List:
        """Uncontended price of :meth:`transfer` as a fast-path chain."""
        hops, lat, bw = self.topo.path_metrics(src, dst)
        t = lat + nbytes / bw if hops else 0.0
        if self.mode == NoCMode.ANALYTICAL or not hops:
            return [("bytes", "noc", nbytes), ("dt", t)]
        return [("bytes", "noc", nbytes),
                ("hold", self._link_keys(self.topo.route_links(src, dst)), t)]

    def collective_chain(self, kind: str, group: Sequence[int], nbytes: float,
                         root: Optional[int] = None) -> List:
        """Uncontended price of :meth:`collective` as a fast-path chain."""
        p = len(group)
        if p <= 1 or nbytes <= 0:
            return [("dt", 0.0)]
        group = list(group)
        if self.mode == NoCMode.ANALYTICAL:
            return [("dt", self._collective_closed_form(kind, group, nbytes,
                                                        root))]
        if self.mode == NoCMode.MACRO:
            t = self._collective_closed_form(kind, group, nbytes, root)
            return [("bytes", "noc", nbytes * p),
                    ("hold", self._link_keys(self._ring_footprint(group)), t)]
        # detailed: per-step transfer barriers, mirroring _collective_detailed
        if kind == "broadcast":
            links = self._chain_links(group, root)
            t = self._path_time(links, nbytes)
            return [("bytes", "noc", nbytes * (p - 1)),
                    ("hold", self._link_keys(sorted(set(links))), t)]
        if kind == "reduce":
            r = group[0] if root is None else root
            branches = tuple(self.transfer_chain(d, r, nbytes)
                             for d in group if d != r)
            return [("par", branches)] if branches else [("dt", 0.0)]
        steps = collective_steps(kind, p)
        chunk = _chunk_bytes(kind, nbytes, p)
        step = ("par", tuple(self.transfer_chain(group[i], group[(i + 1) % p],
                                                 chunk)
                             for i in range(p)))
        return [step] * steps

    # -- inter-tile-group strategies (paper §V-C, Fig. 11) ----------------------

    def group_to_group(
        self,
        src_group: Sequence[int],
        dst_group: Sequence[int],
        nbytes: float,
        strategy: int = 1,
        num_adapters: int = 1,
        priority: int = 0,
    ) -> Generator:
        """Send a reduced tensor from one tile group to another.

        Strategy 1 (Eq. 7): all-reduce in source -> point-to-point to the
        adapters -> broadcast in destination.
        Strategy 2 (Eq. 8): reduce onto the adapters' peers -> p2p ->
        all-reduce among adapters -> broadcast in destination.
        """
        env = self.env
        src, dst = list(src_group), list(dst_group)
        k = max(1, min(num_adapters, len(src), len(dst)))
        senders, adapters = src[:k], dst[:k]

        if strategy == 1:
            yield env.process(self.collective("all_reduce", src, nbytes, priority))
            shard = nbytes / k
            procs = [env.process(self.transfer(s, a, shard, priority))
                     for s, a in zip(senders, adapters)]
            yield env.all_of(procs)
            yield from self._dest_broadcast(adapters, dst, nbytes, priority)
        elif strategy == 2:
            # reduce within k contiguous source subsets onto the k senders
            m = (len(src) + k - 1) // k
            subsets = [src[i * m:(i + 1) * m] for i in range(k) if src[i * m:(i + 1) * m]]
            procs = [env.process(self.collective("reduce", sub, nbytes, priority, root=sub[0]))
                     for sub in subsets if len(sub) > 1]
            if procs:
                yield env.all_of(procs)
            shard = nbytes  # each adapter receives a partial full-size tensor
            procs = [env.process(self.transfer(sub[0], a, shard, priority))
                     for sub, a in zip(subsets, adapters)]
            yield env.all_of(procs)
            if k > 1:
                yield env.process(self.collective("all_reduce", adapters, nbytes, priority))
            yield from self._dest_broadcast(adapters, dst, nbytes, priority)
        else:
            raise ValueError(f"unknown strategy {strategy}")

    def _dest_broadcast(self, adapters: List[int], dst: List[int], nbytes: float,
                        priority: int) -> Generator:
        env = self.env
        rest = [d for d in dst if d not in adapters]
        if not rest:
            yield env.timeout(0.0)
            return
        # each adapter chain-broadcasts to a contiguous share of the rest
        k = len(adapters)
        m = (len(rest) + k - 1) // k
        procs = []
        for i, a in enumerate(adapters):
            share = rest[i * m:(i + 1) * m]
            if share:
                procs.append(env.process(
                    self.collective("broadcast", [a] + share, nbytes, priority, root=a)))
        if procs:
            yield env.all_of(procs)
