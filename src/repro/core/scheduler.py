"""Pipeline scheduler with Virtual Tile Aggregation — PALM §IV-A, Figs. 3-5.

Every stage's tile group is represented by *one* simulated worker (the
virtual tile): intra-group tiles have identical compute/memory cost by
construction, so one representative carries the group's timing while the
group-aggregate traffic is what hits shared resources (DRAM ports, NoC
links). This is the paper's O(2N^2) -> O(N^2 + M) -> O(M) reduction; with
``noc_mode="macro"`` the per-collective closed form makes the whole
simulation O(M) events per micro-batch.

Event taxonomy (paper Fig. 4/5): per stage and micro-batch we run
``FD`` (forward), ``BD`` (backward: loss + optional re-computation +
gradient), ``GU`` (gradient update: full-precision weight load/store),
plus ``Act/Grad Pass`` NoC messages that *start* the neighbouring stage,
and ``Data Fetch`` for stage 0. The Prior Selector is realised as the
deterministic 1F1B/GPipe work list; DP gradient collectives are launched
asynchronously so they overlap subsequent compute (Fig. 5 note).
"""

from __future__ import annotations

import warnings
from dataclasses import dataclass, field
from typing import Dict, Generator, List, Optional, Tuple

from .dram import DRAMModel
from .enums import BoundaryMode, NoCMode, Schedule
from .events import Environment, Event
from .hardware import HardwareSpec
from .noc import NoCModel
from .parallelism import BD, FD, GU, MappedGraph, ParallelPlan, StageMapping
from .sram import OpAccess, StageMemory, allocate_stage, stage_memory
from .trace import (
    KIND_BD,
    KIND_DRAM,
    KIND_FD,
    KIND_GU,
    KIND_NOC,
    Trace,
    TraceRecorder,
)

__all__ = ["SimResult", "PipelineSimulator", "ideal_pipeline_time",
           "decide_recompute", "estimate_stage_memory", "plan_memory"]


@dataclass
class SimResult:
    total_time: float
    throughput: float                  # samples (sequences) / s
    stage_memory: List[StageMemory]
    recompute: bool
    event_count: int
    noc_bytes: float
    dram_bytes: float
    # which tier produced the result: "event" (generator/heap kernel) or
    # "fast" (closed-form analytic tier, repro.core.fastpath). Timing is
    # bit-identical between tiers whenever the fast tier runs, so the
    # provenance tag is excluded from equality. Note ``event_count`` is
    # tier-dependent (heap pops vs chain-node evaluations).
    engine: str = field(default="event", compare=False)
    # columnar event timeline: compute lanes (FD/BD/GU) are always
    # recorded; NoC/DRAM busy-interval lanes when the simulator ran with
    # ``collect_timeline=True``
    trace: Optional[Trace] = None
    # scalar link-utilization digest for runs without resource lanes
    # (legacy behaviour: the field was always populated). In-process
    # convenience only — the sweep engine clears it so serial and pooled
    # sweeps return identical, lean results
    noc_occupancy_fallback: Dict[int, float] = field(
        default_factory=dict, compare=False, repr=False)
    # ``{"sim": ..., "host": ...}`` observability document (repro.obs),
    # attached by ``PipelineSimulator.run`` when metrics are enabled. The
    # "sim" half is derived only from compare=True data and is therefore
    # itself bit-identical across tiers; the "host" half (engine
    # provenance, rejection reasons) is not, so the whole field stays out
    # of equality.
    metrics: Optional[Dict] = field(default=None, compare=False, repr=False)

    @property
    def timeline(self) -> List[Tuple[int, str, int, float, float]]:
        """Deprecated legacy tuple view of the compute lanes; use
        :attr:`trace` (kept one release for downstream tooling)."""
        warnings.warn("SimResult.timeline is deprecated; use SimResult.trace",
                      DeprecationWarning, stacklevel=2)
        return [] if self.trace is None else self.trace.compute_tuples()

    @property
    def stage_busy(self) -> Dict[int, float]:
        """Per-stage FD+BD busy seconds, derived from the trace."""
        return {} if self.trace is None else self.trace.stage_busy()

    @property
    def noc_occupancy(self) -> Dict[int, float]:
        """Per-link busy fraction, sorted by link id: derived from the
        trace's NOC lane when the run collected resource intervals,
        otherwise the scalar utilization digest recorded at run end."""
        occ = ({} if self.trace is None
               else self.trace.resource_occupancy(KIND_NOC))
        return occ or dict(self.noc_occupancy_fallback)

    @property
    def dram_occupancy(self) -> Dict[int, float]:
        """Per-channel busy fraction from the trace's DRAM lane."""
        return ({} if self.trace is None
                else self.trace.resource_occupancy(KIND_DRAM))

    @property
    def bubble_ratio(self) -> float:
        if self.trace is None:
            return 0.0
        return self.trace.bubble_fraction()


def ideal_pipeline_time(fd_bd_per_stage: List[float], num_microbatches: int,
                        gu_time: float = 0.0) -> float:
    """Paper Eq. (1): (B/b - 1) * max_s(FD+BD) + sum_s(FD+BD) + GU."""
    return ((num_microbatches - 1) * max(fd_bd_per_stage)
            + sum(fd_bd_per_stage) + gu_time)


def decide_recompute(memory: List[StageMemory], plan: ParallelPlan,
                     hardware: HardwareSpec) -> bool:
    """Recompute decision (auto: recompute iff some stage's footprint
    exceeds per-device DRAM capacity without it). Shared by the simulator
    and the sweep engine's pre-simulation memory estimate so early pruning
    sees exactly the memory the simulation would report."""
    if plan.recompute == "always":
        return True
    if plan.recompute == "never":
        return False
    cap = hardware.dram.capacity_bytes
    return any(m.total > cap for m in memory)


def plan_memory(mapped: MappedGraph) -> Tuple[List[StageMemory], bool]:
    """Per-stage memory of a mapped graph *before* simulation, with the
    recompute decision applied — identical to ``SimResult.stage_memory``.
    This is what makes memory-cap feasibility a pre-simulation check; the
    result can be handed to :class:`PipelineSimulator` (``memory_plan``)
    so a capped sweep sizes memory only once per plan."""
    plan, hw = mapped.plan, mapped.hardware
    memory = [stage_memory(st, plan, hw) for st in mapped.stages]
    recompute = decide_recompute(memory, plan, hw)
    if recompute:
        for m in memory:
            m.inflight_microbatches = 1  # only boundary acts retained
            m.offload_bytes = 0.0        # nothing saved => nothing offloaded
    return memory, recompute


def estimate_stage_memory(mapped: MappedGraph) -> List[StageMemory]:
    return plan_memory(mapped)[0]


class PipelineSimulator:
    """Runs one training iteration (or an inference pipeline) of a mapped
    graph and reports absolute time + throughput.

    The FD/BD/GU compute lanes of ``SimResult.trace`` are always recorded
    (they are tiny — O(stages x micro-batches) rows — and feed the scalar
    busy/bubble digests); ``collect_timeline=True`` additionally records
    NoC-link and DRAM-channel busy intervals into the trace's resource
    lanes."""

    def __init__(
        self,
        mapped: MappedGraph,
        noc_mode: NoCMode = NoCMode.MACRO,
        collect_timeline: bool = False,
        boundary_mode: BoundaryMode = BoundaryMode.PAIRWISE,
        memory_plan: Optional[Tuple[List[StageMemory], bool]] = None,
        engine: str = "event",
        metrics: bool = False,
    ):
        if engine not in ("event", "auto", "fast"):
            raise ValueError(f"unknown engine {engine!r} "
                             "(expected 'event', 'auto' or 'fast')")
        self.engine = engine
        self.metrics = bool(metrics)
        self.mapped = mapped
        self.plan: ParallelPlan = mapped.plan
        self.hw: HardwareSpec = mapped.hardware
        self.env = Environment()
        # compute lanes (FD/BD/GU) are always recorded — they are what the
        # scalar stage-busy/bubble digests derive from; ``collect_timeline``
        # additionally records NoC-link / DRAM-channel busy intervals
        self.recorder = TraceRecorder()
        self.collect_timeline = collect_timeline
        res_rec = self.recorder if collect_timeline else None
        if getattr(self.hw, "fabric", None) is not None:
            # multi-chip machine: the fabric facade owns one NoC + DRAM
            # per chip and routes chip-spanning traffic over the scale-out
            # links. Single-chip specs keep the plain models (bit-identical).
            from ..fabric.model import FabricModel

            self.noc = FabricModel(self.env, self.hw, mode=NoCMode(noc_mode),
                                   recorder=res_rec)
            self.dram = self.noc.dram
        else:
            self.noc = NoCModel(self.env, self.hw, mode=NoCMode(noc_mode),
                                recorder=res_rec)
            self.dram = DRAMModel(self.env, self.hw, self.noc,
                                  recorder=res_rec)
        if self.metrics and hasattr(self.noc, "level_bytes"):
            # ask the fabric (both tiers) to attribute payload per level
            self.noc.metrics_levels = True
        self.boundary_mode = BoundaryMode(boundary_mode)

        S = mapped.num_stages
        # Act/Grad Pass mailboxes are event-kernel state: their creation
        # (O(stages x micro-batches) Event objects) is deferred to
        # ``_run_event`` so fast-tier-only runs — the common case in
        # batched sweeps — never pay for them
        self.act_ready: List[List[Event]] = []
        self.grad_ready: List[List[Event]] = []

        # memory + recompute decision (auto: recompute iff footprint exceeds
        # per-device DRAM capacity without it); callers that already sized
        # memory for feasibility pruning pass it in via ``memory_plan``
        self.memory, self.recompute = memory_plan or plan_memory(mapped)

        self.access: List[List[OpAccess]] = [
            allocate_stage(st, self.plan, self.hw, recompute=self.recompute)
            for st in mapped.stages]

        self._fd_done_t: Dict[Tuple[int, int], float] = {}
        # event causality: trace row index per compute event, last row per
        # stage proc, and last releaser row per shared compute resource —
        # what makes ``Trace.critical_path()`` exact under contention
        self._row_idx: Dict[Tuple[int, int, int], int] = {}
        self._prev_row: List[int] = [-1] * S
        self._last_res_row: Dict[Tuple[int, ...], int] = {}
        self._gu_done: List[Event] = []
        # interleaved 1F1B: virtual stages sharing a tile group serialize
        # on the group's compute resource (BD pre-empts queued FD — the
        # Prior Selector, Fig. 4)
        from .events import PriorityResource
        self._compute_res: Dict[Tuple[int, ...], PriorityResource] = {}
        if self.plan.interleave > 1:
            for st in mapped.stages:
                key = tuple(st.devices)
                if key not in self._compute_res:
                    self._compute_res[key] = PriorityResource(
                        self.env, capacity=1, name=f"tiles{st.stage_id % self.plan.pp}")

    def _acquire_compute(self, sid: int, priority: int):
        key = tuple(self.mapped.stages[sid].devices)
        res = self._compute_res.get(key)
        if res is None:
            return None, None
        req = res.request(priority)
        return res, req

    # -- cost primitives -----------------------------------------------------
    def _compute_time(self, flops_tile: float, matmul_fraction: float) -> float:
        tile = self.hw.tile
        mm = flops_tile * matmul_fraction
        vec = flops_tile - mm
        return tile.matmul_time(mm) + (tile.vector_time(vec) if vec > 0 else 0.0)

    def _dram_and_compute(self, stage: StageMapping, act_bytes: float,
                          weight_bytes: float, compute_s: float) -> Generator:
        """One op's DRAM traffic + compute. With ``stream_overlap`` (the
        dataflow double-buffering norm) they run concurrently; otherwise
        sequentially, as Fig. 5's sub-process chain."""
        env = self.env
        if act_bytes + weight_bytes <= 0:
            yield env.timeout(compute_s)
            return
        shards = stage.weight_shards if self.plan.weight_multicast \
            else len(stage.devices)
        dram = env.process(self.dram.group_access(
            stage.devices, act_bytes, priority=1,
            shared_bytes=weight_bytes, num_shards=shards))
        if self.plan.stream_overlap:
            compute = env.timeout(compute_s)
            yield env.all_of([dram, compute])
        else:
            yield dram
            yield env.timeout(compute_s)

    def _stage_collectives(self, stage: StageMapping, comms, phase: str,
                           priority: int) -> Generator:
        """Run one op's intra-stage collectives for ``phase`` (all groups of
        the axis operate concurrently)."""
        env = self.env
        precision = self.hw.precision_bytes
        procs = []
        for task in comms:
            if task.phase != phase:
                continue
            groups = stage.groups.get(task.axis)
            if not groups:
                continue
            # task.elems is already the per-participant payload (Table III)
            per_dev_bytes = task.elems * precision
            for g in groups:
                procs.append(env.process(
                    self.noc.collective(task.kind, g, per_dev_bytes, priority)))
        if procs:
            yield env.all_of(procs)
        else:
            yield env.timeout(0.0)

    # -- FD / BD / GU bodies (Fig. 5) ------------------------------------------
    def _run_fd(self, sid: int, mb: int) -> Generator:
        stage = self.mapped.stages[sid]
        env = self.env
        t_enter = env.now
        yield self.act_ready[sid][mb]
        t_ready = env.now
        res, req = self._acquire_compute(sid, priority=1)   # FD after BD
        if req is not None:
            yield req
        start = env.now
        # causality: what bound this event's start? (priority order:
        # contended compute resource > upstream Act Pass > stage order)
        if res is not None and start > t_ready:
            pred = self._last_res_row.get(tuple(stage.devices), -1)
        elif t_ready > t_enter and sid > 0:
            pred = self._row_idx.get((sid - 1, KIND_FD, mb), -1)
        else:
            pred = self._prev_row[sid]
        if sid == 0 and stage.split_ops:
            # Data Fetch: input micro-batch from DRAM
            first = stage.split_ops[0]
            nbytes = first.act_in_elems_tile * self.hw.precision_bytes
            yield env.process(self.dram.group_access(stage.devices, nbytes))
        for split, acc in zip(stage.split_ops, self.access[sid]):
            yield from self._dram_and_compute(
                stage, acc.fd_act, acc.fd_weight,
                self._compute_time(split.fwd_flops_tile, split.matmul_fraction))
            yield from self._stage_collectives(stage, split.comms, FD, priority=1)
        self._fd_done_t[(sid, mb)] = env.now
        row = self.recorder.compute(sid, KIND_FD, mb, start, env.now, pred)
        self._row_idx[(sid, KIND_FD, mb)] = row
        self._prev_row[sid] = row
        if res is not None:
            self._last_res_row[tuple(stage.devices)] = row
            res.release(req)
        # Act Pass -> next stage (start signal)
        if sid + 1 < self.mapped.num_stages:
            yield from self._boundary_pass(sid, sid + 1, mb, kind="act")
            self.act_ready[sid + 1][mb].succeed()
        elif self.plan.training:
            self.grad_ready[sid][mb].succeed()  # loss is computed locally

    def _run_bd(self, sid: int, mb: int, pending_dp: List) -> Generator:
        stage = self.mapped.stages[sid]
        env = self.env
        t_enter = env.now
        yield self.grad_ready[sid][mb]
        t_ready = env.now
        res, req = self._acquire_compute(sid, priority=0)   # BD first (1F1B)
        if req is not None:
            yield req
        start = env.now
        if res is not None and start > t_ready:
            pred = self._last_res_row.get(tuple(stage.devices), -1)
        elif t_ready > t_enter:
            pred = (self._row_idx.get((sid, KIND_FD, mb), -1)
                    if sid == self.mapped.num_stages - 1
                    else self._row_idx.get((sid + 1, KIND_BD, mb), -1))
        else:
            pred = self._prev_row[sid]
        for split, acc in zip(reversed(stage.split_ops), reversed(self.access[sid])):
            compute = self._compute_time(split.bwd_flops_tile, split.matmul_fraction)
            if self.recompute:  # Fig. 5 Recompute sub-process
                compute += self._compute_time(split.fwd_flops_tile,
                                              split.matmul_fraction)
            yield from self._dram_and_compute(stage, acc.bd_act, acc.bd_weight,
                                              compute)
            yield from self._stage_collectives(stage, split.comms, BD, priority=1)
            if mb == self.plan.num_microbatches - 1:
                # DP gradient sync: async, overlaps later compute (Fig. 5)
                pending_dp.append(env.process(
                    self._stage_collectives(stage, split.comms, GU, priority=2)))
        row = self.recorder.compute(sid, KIND_BD, mb, start, env.now, pred)
        self._row_idx[(sid, KIND_BD, mb)] = row
        self._prev_row[sid] = row
        if res is not None:
            self._last_res_row[tuple(stage.devices)] = row
            res.release(req)
        if sid > 0:
            yield from self._boundary_pass(sid, sid - 1, mb, kind="grad")
            self.grad_ready[sid - 1][mb].succeed()

    def _run_gu(self, sid: int, pending_dp: List) -> Generator:
        stage = self.mapped.stages[sid]
        env = self.env
        t_enter = env.now
        if pending_dp:
            yield env.all_of(pending_dp)
        start = env.now
        pred = (self._row_idx.get(
                    (sid, KIND_BD, self.plan.num_microbatches - 1), -1)
                if start > t_enter else self._prev_row[sid])
        gu_bytes = sum(a.gu_bytes for a in self.access[sid])
        if gu_bytes > 0:
            # full-precision weight load from DRAM and store back (§IV-A);
            # optimizer state is per-shard (not replicated across DP)
            yield env.process(self.dram.group_access(
                stage.devices, 0.0, shared_bytes=gu_bytes / 2,
                num_shards=stage.weight_shards))
            yield env.process(self.dram.group_access(
                stage.devices, 0.0, write=True, shared_bytes=gu_bytes / 2,
                num_shards=stage.weight_shards))
        row = self.recorder.compute(sid, KIND_GU, 0, start, env.now, pred)
        self._row_idx[(sid, KIND_GU, 0)] = row
        self._prev_row[sid] = row
        self._gu_done[sid].succeed()

    def _boundary_pass(self, src: int, dst: int, mb: int, kind: str) -> Generator:
        """Act/Grad Pass between adjacent stages (NoC communication event)."""
        env = self.env
        s_from = self.mapped.stages[src]
        s_to = self.mapped.stages[dst]
        nbytes = self.mapped.boundary_elems(min(src, dst)) * self.hw.precision_bytes
        if self.boundary_mode == BoundaryMode.STRATEGY and len(s_from.devices) > 1:
            yield from self.noc.group_to_group(
                s_from.devices, s_to.devices, nbytes,
                strategy=self.plan.comm_strategy,
                num_adapters=max(1, len(s_to.devices) // 4))
            return
        # pairwise: rank i -> rank i (Megatron-style P2P), concurrent
        n = min(len(s_from.devices), len(s_to.devices))
        per = nbytes / n
        procs = [env.process(self.noc.transfer(s_from.devices[i], s_to.devices[i],
                                               per, priority=0))
                 for i in range(n)]
        yield env.all_of(procs)

    # -- per-stage worker (Prior Selector as deterministic work list) --------
    def _work_list(self, sid: int) -> List[Tuple[str, int]]:
        S, M = self.mapped.num_stages, self.plan.num_microbatches
        if not self.plan.training:
            return [(FD, i) for i in range(M)]
        if self.plan.schedule == Schedule.GPIPE:
            return [(FD, i) for i in range(M)] + [(BD, i) for i in range(M)]
        # 1F1B: warmup forwards, then strict BD-before-FD alternation
        w = min(S - sid, M)
        order: List[Tuple[str, int]] = [(FD, i) for i in range(w)]
        bd, fd = 0, w
        while bd < M:
            order.append((BD, bd)); bd += 1
            if fd < M:
                order.append((FD, fd)); fd += 1
        return order

    def _stage_proc(self, sid: int) -> Generator:
        pending_dp: List = []
        for kind, mb in self._work_list(sid):
            if kind == FD:
                yield from self._run_fd(sid, mb)
            else:
                yield from self._run_bd(sid, mb, pending_dp)
        if self.plan.training:
            yield from self._run_gu(sid, pending_dp)

    # -- entry ----------------------------------------------------------------
    def run(self) -> SimResult:
        """Simulate per the configured engine.

        ``event`` always runs the generator/heap kernel; ``auto`` tries the
        closed-form fast tier first (bit-identical when it applies) and
        silently falls back on static ineligibility or detected resource
        contention; ``fast`` demands the fast tier and raises
        :class:`~repro.core.fastpath.FastPathIneligible` otherwise."""
        if self.engine != "event":
            from .fastpath import try_fast_run

            result = try_fast_run(self, strict=(self.engine == "fast"))
            if result is not None:
                return self._attach_metrics(result)
        return self._attach_metrics(self._run_event())

    def _attach_metrics(self, result: SimResult) -> SimResult:
        """Attach the repro.obs metrics document when enabled (no-op —
        and no import — otherwise, so disabled runs pay nothing)."""
        if self.metrics:
            from ..obs.simmetrics import run_metrics

            result.metrics = run_metrics(self, result)
        return result

    def _setup_events(self) -> None:
        """Create the Act/Grad Pass mailboxes and GU-done latches the
        event kernel synchronizes on (deferred from ``__init__`` so
        fast-tier runs skip the O(S x M) Event construction)."""
        S = self.mapped.num_stages
        M = self.plan.num_microbatches
        self.act_ready = [
            [self.env.event(f"act[{s}][{i}]") for i in range(M)]
            for s in range(S)]
        self.grad_ready = [
            [self.env.event(f"grad[{s}][{i}]") for i in range(M)]
            for s in range(S)]
        for i in range(M):
            self.act_ready[0][i].succeed()  # stage 0 fetches its own data
        self._gu_done = [self.env.event(f"gu[{s}]") for s in range(S)]

    def _run_event(self) -> SimResult:
        env = self.env
        self._setup_events()
        procs = [env.process(self._stage_proc(s), name=f"stage{s}")
                 for s in range(self.mapped.num_stages)]
        env.run(until_event=env.all_of(procs))
        total = env.now
        # flush any still-open resource busy intervals into the trace
        self.noc.close_open_intervals(total)
        self.dram.close_open_intervals(total)

        M = self.plan.num_microbatches
        samples = self.plan.global_batch
        if self.plan.training:
            throughput = samples / total if total > 0 else 0.0
        else:
            # steady-state pipeline rate, drain/setup excluded (§V-A3)
            finishes = sorted(t for (s, i), t in self._fd_done_t.items()
                              if s == self.mapped.num_stages - 1)
            mb_size = samples / M
            if len(finishes) > 1:
                throughput = (len(finishes) - 1) * mb_size / (finishes[-1] - finishes[0])
            else:
                throughput = samples / total if total > 0 else 0.0
        return SimResult(
            total_time=total,
            throughput=throughput,
            stage_memory=self.memory,
            recompute=self.recompute,
            event_count=env.event_count,
            noc_bytes=self.noc.bytes_moved,
            dram_bytes=self.dram.bytes_accessed,
            trace=self.recorder.freeze(total, self.mapped.num_stages),
            noc_occupancy_fallback=(self.noc.occupancy_report()
                                    if not self.collect_timeline
                                    and self.noc._links else {}),
        )
