"""Typed configuration enums for the simulator front door.

Historically ``simulate`` / ``PipelineSimulator`` / ``ParallelPlan`` took
bare strings (``noc_mode="macro"``, ``schedule="1f1b"``, ...), which made
large sweeps error-prone: a typo silently fell through to a ``ValueError``
deep inside the scheduler, or — worse — matched nothing and picked a
default branch. These enums are the canonical spelling; every entry point
still accepts the legacy strings via :func:`coerce` for one release,
emitting a :class:`DeprecationWarning`.

All enums subclass ``str`` so existing comparisons (``plan.schedule ==
"gpipe"``) and string formatting keep working during the migration.
"""

from __future__ import annotations

import enum
import warnings
from typing import Type, TypeVar, Union

__all__ = ["NoCMode", "BoundaryMode", "Schedule", "Layout", "coerce"]

E = TypeVar("E", bound="_StrEnum")


class _StrEnum(str, enum.Enum):
    def __str__(self) -> str:  # argparse/json print the bare value
        return self.value


def coerce(cls: Type[E], value: Union[str, E], param: str = "",
           warn: bool = True) -> E:
    """Return ``value`` as a member of ``cls``.

    Enum members pass through; legacy strings are matched case-insensitively
    against member values and (when ``warn``) trigger a DeprecationWarning
    naming the typed replacement. Unknown strings raise ``ValueError`` with
    the full list of accepted values.
    """
    if isinstance(value, cls):
        return value
    if isinstance(value, str):
        try:
            member = cls(value.lower())
        except ValueError:
            valid = ", ".join(repr(m.value) for m in cls)
            raise ValueError(
                f"unknown {param or cls.__name__} {value!r}; expected one of {valid}"
            ) from None
        if warn:
            warnings.warn(
                f"passing {param or cls.__name__} as a string is deprecated; "
                f"use {cls.__name__}.{member.name}",
                DeprecationWarning, stacklevel=3)
        return member
    raise TypeError(f"{param or cls.__name__} must be {cls.__name__} or str, "
                    f"got {type(value).__name__}")


class NoCMode(_StrEnum):
    """NoC model fidelity (§IV-C ❷): per-link event-driven, per-collective
    closed form on the real topology, or pure analytical ring model."""

    DETAILED = "detailed"
    MACRO = "macro"
    ANALYTICAL = "analytical"


class BoundaryMode(_StrEnum):
    """Stage-boundary Act/Grad Pass model: rank-i -> rank-i P2P pairs, or
    the inter-tile-group strategies of Fig. 11."""

    PAIRWISE = "pairwise"
    STRATEGY = "strategy"


class Schedule(_StrEnum):
    """Pipeline schedule (Table II)."""

    GPIPE = "gpipe"
    ONE_F_ONE_B = "1f1b"


class Layout(_StrEnum):
    """Spatial stage layout on the 2-D mesh (Fig. 8)."""

    LINE = "line"
    S_SHAPE = "s_shape"
