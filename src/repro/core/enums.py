"""Typed configuration enums for the simulator front door.

These are the canonical spelling for every mode/schedule/layout kwarg.
All enums subclass ``str``, so the canonical value strings construct the
member directly (``NoCMode("macro") is NoCMode.MACRO``) and comparisons
like ``plan.schedule == "gpipe"`` keep working; anything else raises
``ValueError`` listing the accepted values. The legacy case-insensitive
string-coercion path (and its DeprecationWarnings) was removed one
release after the enums landed — pass the enum member or its exact
value.
"""

from __future__ import annotations

import enum

__all__ = ["NoCMode", "BoundaryMode", "Schedule", "Layout"]


class _StrEnum(str, enum.Enum):
    def __str__(self) -> str:  # argparse/json print the bare value
        return self.value

    @classmethod
    def _missing_(cls, value):
        valid = ", ".join(repr(m.value) for m in cls)
        raise ValueError(
            f"unknown {cls.__name__} {value!r}; expected one of {valid}")


class NoCMode(_StrEnum):
    """NoC model fidelity (§IV-C ❷): per-link event-driven, per-collective
    closed form on the real topology, or pure analytical ring model."""

    DETAILED = "detailed"
    MACRO = "macro"
    ANALYTICAL = "analytical"


class BoundaryMode(_StrEnum):
    """Stage-boundary Act/Grad Pass model: rank-i -> rank-i P2P pairs, or
    the inter-tile-group strategies of Fig. 11."""

    PAIRWISE = "pairwise"
    STRATEGY = "strategy"


class Schedule(_StrEnum):
    """Pipeline schedule (Table II)."""

    GPIPE = "gpipe"
    ONE_F_ONE_B = "1f1b"


class Layout(_StrEnum):
    """Spatial stage layout on the 2-D mesh (Fig. 8)."""

    LINE = "line"
    S_SHAPE = "s_shape"
