"""Columnar event trace — the simulator's first-class execution artifact.

PALM's value is event-driven visibility into FD/BD/GU and NoC/DRAM
interactions; this module stores that timeline as a struct-of-arrays
:class:`Trace` instead of a Python ``List[Tuple]``:

* five core columns — ``stage`` (int32), ``kind`` (int8 event-kind code),
  ``micro`` (int32 micro-batch), ``start``/``end`` (float64 seconds) —
  plus a ``resource`` column (int32) carrying the NoC link id / DRAM
  channel id for resource busy-interval rows (``-1`` on compute rows);
* numpy-backed when numpy is importable, ``array.array``-backed otherwise
  (the simulator core stays dependency-free, matching pyproject);
* compact, *lossless* wire form: pickling a Trace serializes the columns
  through :meth:`to_bytes` (zlib over byte-shuffled, xor-delta'd column
  buffers), which is what makes ``return_timelines=True`` sweeps cheap
  across the process pool (see ``benchmarks/bench_sweep_engine.py``);
* ``to_npz``/``from_npz`` (numpy), JSON-safe ``to_dict``/``from_dict``,
  ``concat``/``filter``/``slice_time`` views;
* derived analytics: :meth:`stage_utilization`, :meth:`bubble_fraction`,
  :meth:`critical_path`, :meth:`resource_occupancy` — the scalar
  ``stage_busy``/``noc_occupancy`` dicts of the legacy ``SimResult`` are
  now views over this data;
* :func:`chrome_trace` renders the Chrome/Perfetto ``traceEvents`` JSON
  (one lane per pipeline stage, separate NoC/DRAM process groups) so
  training and serving timelines are directly comparable in one viewer.

:class:`TraceRecorder` is the write-side half: the scheduler appends
compute events, and NoC links / DRAM channels close busy intervals into
it through :meth:`TraceRecorder.interval_cb`.
"""

from __future__ import annotations

import array
import json
import struct
import sys
import zlib
from typing import Any, Callable, Dict, Iterator, List, NamedTuple, Optional, Sequence, Tuple

try:
    import numpy as _np
except ImportError:         # pragma: no cover - exercised by CI bench-smoke
    _np = None

__all__ = [
    "KIND_FD", "KIND_BD", "KIND_GU", "KIND_NOC", "KIND_DRAM",
    "KIND_PREFILL", "KIND_DECODE", "KIND_QUEUE", "KIND_FABRIC",
    "KIND_NAMES", "KIND_CODES", "COMPUTE_KINDS", "RESOURCE_KINDS",
    "REQUEST_KINDS", "pack_lane",
    "TraceRow", "Trace", "TraceRecorder", "TraceDiff", "chrome_trace",
    "diff",
]

# event-kind enum codes (paper Fig. 4/5 taxonomy + resource lanes)
KIND_FD, KIND_BD, KIND_GU = 0, 1, 2        # compute lanes (per stage)
KIND_NOC, KIND_DRAM = 3, 4                 # resource busy-interval lanes
# per-request serving lanes (repro.serving.system): the `resource` column
# carries the request id, `micro` the batching episode (bumped on each
# eviction/resume), `stage` stays -1
KIND_PREFILL, KIND_DECODE, KIND_QUEUE = 5, 6, 7
# scale-out fabric link busy intervals (repro.fabric): the `resource`
# column carries the fabric link id
KIND_FABRIC = 8


def pack_lane(kind: int, lane: int) -> int:
    """Pack a ``(kind, lane)`` resource identity into one int.

    The fast tier (:mod:`repro.core.fastpath`) records busy intervals on
    packed lanes so validation can lexsort a flat int column; the packing
    is order-preserving (kind major, lane minor — both non-negative and
    lane < 2**32), so sorting packed ints equals sorting the tuples.
    """
    return (kind << 32) | lane


KIND_NAMES: Tuple[str, ...] = ("FD", "BD", "GU", "NOC", "DRAM",
                               "PREFILL", "DECODE", "QUEUE", "FABRIC")
KIND_CODES: Dict[str, int] = {name: code for code, name in enumerate(KIND_NAMES)}
COMPUTE_KINDS: Tuple[int, ...] = (KIND_FD, KIND_BD, KIND_GU)
RESOURCE_KINDS: Tuple[int, ...] = (KIND_NOC, KIND_DRAM, KIND_FABRIC)
REQUEST_KINDS: Tuple[int, ...] = (KIND_PREFILL, KIND_DECODE, KIND_QUEUE)

_SCHEMA = 2          # v2 adds the per-row `pred` causality column
_MAGIC = b"PTRC"

# array.array typecodes with guaranteed widths (int is 4 bytes on every
# CPython platform we target; guard anyway so to_bytes stays portable)
_I32 = "i" if array.array("i").itemsize == 4 else "l"
assert array.array(_I32).itemsize == 4, "no 4-byte int array typecode"


# ---------------------------------------------------------------------------
# column backends
# ---------------------------------------------------------------------------

def _col(typecode: str, values: Sequence) -> "array.array | _np.ndarray":
    """Build one column; numpy when available, array.array otherwise."""
    if _np is not None:
        dtype = {"b": _np.int8, _I32: _np.int32, "d": _np.float64}[typecode]
        return _np.asarray(values, dtype=dtype)
    if isinstance(values, array.array) and values.typecode == typecode:
        return values
    return array.array(typecode, values)


def _col_bytes(col) -> bytes:
    b = col.tobytes()
    if sys.byteorder != "little":       # pragma: no cover - big-endian host
        a = array.array(_typecode_of(col), b)
        a.byteswap()
        b = a.tobytes()
    return b


def _col_from_bytes(typecode: str, b: bytes):
    a = array.array(typecode)
    a.frombytes(b)
    if sys.byteorder != "little":       # pragma: no cover - big-endian host
        a.byteswap()
    return _col(typecode, a)


def _typecode_of(col) -> str:
    if _np is not None and isinstance(col, _np.ndarray):
        return {"int8": "b", "int32": _I32, "float64": "d"}[col.dtype.name]
    return col.typecode


def _col_eq(a, b) -> bool:
    if len(a) != len(b):
        return False
    if _np is not None and isinstance(a, _np.ndarray) and isinstance(b, _np.ndarray):
        return bool(_np.array_equal(a, b))
    return list(a) == list(b)


# ---------------------------------------------------------------------------
# lossless byte transforms for the compressed wire form
# ---------------------------------------------------------------------------

def _shuffle(b: bytes, width: int) -> bytes:
    """Byte-transpose a ``width``-byte-item buffer (Blosc-style shuffle):
    groups the slowly-varying high-order bytes so zlib sees long runs."""
    return b"".join(b[i::width] for i in range(width))


def _unshuffle(b: bytes, width: int) -> bytes:
    n = len(b) // width
    out = bytearray(len(b))
    for i in range(width):
        out[i::width] = b[i * n:(i + 1) * n]
    return bytes(out)


def _xor_delta(b: bytes) -> bytes:
    """out[i] = x[i] ^ x[i-1] over the u64 bit patterns (lossless; event
    times are near-monotone, so consecutive words share high bits)."""
    if _np is not None:
        x = _np.frombuffer(b, dtype="<u8")
        out = x.copy()
        out[1:] = x[1:] ^ x[:-1]
        return out.tobytes()
    a = array.array("Q")
    a.frombytes(b)
    prev = 0
    for i, cur in enumerate(a):
        a[i] = cur ^ prev
        prev = cur
    return a.tobytes()


def _xor_undelta(b: bytes) -> bytes:
    if _np is not None:
        x = _np.frombuffer(b, dtype="<u8")
        return _np.bitwise_xor.accumulate(x).tobytes()
    a = array.array("Q")
    a.frombytes(b)
    acc = 0
    for i, cur in enumerate(a):
        acc ^= cur
        a[i] = acc
    return a.tobytes()


class TraceRow(NamedTuple):
    """One materialized trace event (row view over the columns)."""

    stage: int
    kind: int
    micro: int
    resource: int
    start: float
    end: float

    @property
    def kind_name(self) -> str:
        return KIND_NAMES[self.kind]

    @property
    def duration(self) -> float:
        return self.end - self.start


# ---------------------------------------------------------------------------
# Trace
# ---------------------------------------------------------------------------

class Trace:
    """Struct-of-arrays event timeline.

    Rows appear in record order (the scheduler appends compute events at
    completion time, so the compute lanes replay the legacy tuple list
    exactly). ``total_time`` is the simulation horizon analytics divide
    by; ``num_stages`` fixes the utilization denominator even for stages
    that never ran.
    """

    __slots__ = ("stage", "kind", "micro", "resource", "start", "end",
                 "pred", "total_time", "num_stages")

    def __init__(self, stage: Sequence[int] = (), kind: Sequence[int] = (),
                 micro: Sequence[int] = (), resource: Sequence[int] = (),
                 start: Sequence[float] = (), end: Sequence[float] = (),
                 pred: Optional[Sequence[int]] = None,
                 total_time: float = 0.0, num_stages: int = 0):
        n = len(stage)
        if not (len(kind) == len(micro) == len(resource) == len(start)
                == len(end) == n):
            raise ValueError("trace columns must have equal length")
        if pred is None:
            pred = [-1] * n
        elif len(pred) != n:
            raise ValueError("trace columns must have equal length")
        self.stage = _col(_I32, stage)
        self.kind = _col("b", kind)
        self.micro = _col(_I32, micro)
        self.resource = _col(_I32, resource)
        self.start = _col("d", start)
        self.end = _col("d", end)
        # `pred[i]` is the row index of the event whose completion bound
        # row i's start (-1 = unknown / no predecessor): explicit event
        # causality recorded by the scheduler, making critical_path()
        # exact even when resource contention delays an event past its
        # structural dependencies
        self.pred = _col(_I32, pred)
        self.total_time = float(total_time)
        self.num_stages = int(num_stages)

    # -- basics -------------------------------------------------------------
    def __len__(self) -> int:
        return len(self.stage)

    def __repr__(self) -> str:
        return (f"Trace({len(self)} events, {self.num_stages} stages, "
                f"total_time={self.total_time:.6g})")

    def __eq__(self, other: object) -> bool:
        if not isinstance(other, Trace):
            return NotImplemented
        return (self.total_time == other.total_time
                and self.num_stages == other.num_stages
                and all(_col_eq(getattr(self, c), getattr(other, c))
                        for c in ("stage", "kind", "micro", "resource",
                                  "start", "end", "pred")))

    def __ne__(self, other: object) -> bool:
        eq = self.__eq__(other)
        return eq if eq is NotImplemented else not eq

    def rows(self) -> Iterator[TraceRow]:
        for i in range(len(self)):
            yield TraceRow(int(self.stage[i]), int(self.kind[i]),
                           int(self.micro[i]), int(self.resource[i]),
                           float(self.start[i]), float(self.end[i]))

    def __getitem__(self, i: int) -> TraceRow:
        if not -len(self) <= i < len(self):
            raise IndexError(i)
        i %= max(1, len(self))
        return TraceRow(int(self.stage[i]), int(self.kind[i]),
                        int(self.micro[i]), int(self.resource[i]),
                        float(self.start[i]), float(self.end[i]))

    @property
    def nbytes(self) -> int:
        """In-memory column payload size (bytes)."""
        return sum(len(getattr(self, c)) * _itemsize(getattr(self, c))
                   for c in ("stage", "kind", "micro", "resource", "start",
                             "end", "pred"))

    # -- legacy view ---------------------------------------------------------
    def compute_tuples(self) -> List[Tuple[int, str, int, float, float]]:
        """The legacy ``SimResult.timeline`` tuple list: compute lanes only,
        in record order, kind as its string name."""
        return [(r.stage, KIND_NAMES[r.kind], r.micro, r.start, r.end)
                for r in self.rows() if r.kind in COMPUTE_KINDS]

    # -- views ---------------------------------------------------------------
    def filter(self, stages: Optional[Sequence[int]] = None,
               kinds: Optional[Sequence[int]] = None,
               micro: Optional[Sequence[int]] = None) -> "Trace":
        """Row-subset copy matching every provided criterion."""
        stages = None if stages is None else set(stages)
        kinds = None if kinds is None else set(kinds)
        micro = None if micro is None else set(micro)
        idx = [i for i in range(len(self))
               if (stages is None or int(self.stage[i]) in stages)
               and (kinds is None or int(self.kind[i]) in kinds)
               and (micro is None or int(self.micro[i]) in micro)]
        return self._take(idx)

    def slice_time(self, t0: float, t1: float) -> "Trace":
        """Rows whose [start, end) interval intersects [t0, t1) (intervals
        are kept whole, not clipped)."""
        idx = [i for i in range(len(self))
               if float(self.end[i]) > t0 and float(self.start[i]) < t1]
        return self._take(idx)

    def _take(self, idx: List[int]) -> "Trace":
        # pred indices are row positions: remap through the selection,
        # dropping edges whose predecessor was filtered out
        remap = {old: new for new, old in enumerate(idx)}
        return Trace(stage=[int(self.stage[i]) for i in idx],
                     kind=[int(self.kind[i]) for i in idx],
                     micro=[int(self.micro[i]) for i in idx],
                     resource=[int(self.resource[i]) for i in idx],
                     start=[float(self.start[i]) for i in idx],
                     end=[float(self.end[i]) for i in idx],
                     pred=[remap.get(int(self.pred[i]), -1) for i in idx],
                     total_time=self.total_time, num_stages=self.num_stages)

    @classmethod
    def concat(cls, traces: Sequence["Trace"]) -> "Trace":
        """Row-wise concatenation; total_time is the max horizon and
        num_stages the max stage count of the parts. pred indices are
        offset so each part's causality edges stay internally valid."""
        traces = list(traces)
        if not traces:
            return cls()
        pred: List[int] = []
        base = 0
        for t in traces:
            pred.extend(int(p) + base if int(p) >= 0 else -1 for p in t.pred)
            base += len(t)
        return cls(
            stage=[s for t in traces for s in t.stage],
            kind=[k for t in traces for k in t.kind],
            micro=[m for t in traces for m in t.micro],
            resource=[r for t in traces for r in t.resource],
            start=[x for t in traces for x in t.start],
            end=[x for t in traces for x in t.end],
            pred=pred,
            total_time=max(t.total_time for t in traces),
            num_stages=max(t.num_stages for t in traces))

    def canonical(self) -> "Trace":
        """Deterministically ordered copy: rows sorted by
        ``(end, start, kind, stage, micro, resource)`` with pred edges
        remapped through the permutation. Event-tier and fast-tier runs
        of the same workload record identical row *sets* but may differ
        in append order (completion order vs. analytic replay order) —
        compare their ``canonical()`` forms."""
        idx = sorted(range(len(self)),
                     key=lambda i: (float(self.end[i]), float(self.start[i]),
                                    int(self.kind[i]), int(self.stage[i]),
                                    int(self.micro[i]), int(self.resource[i])))
        return self._take(idx)

    # -- analytics -----------------------------------------------------------
    def stage_busy(self, kinds: Sequence[int] = (KIND_FD, KIND_BD)) -> Dict[int, float]:
        """Per-stage busy seconds over the given compute kinds (default
        FD+BD, the legacy ``SimResult.stage_busy`` definition — GU overlaps
        the async DP collectives and counts as pipeline tail, not busy)."""
        kinds = set(kinds)
        busy = {s: 0.0 for s in range(self.num_stages)}
        for i in range(len(self)):
            if int(self.kind[i]) in kinds:
                s = int(self.stage[i])
                busy[s] = busy.get(s, 0.0) + float(self.end[i]) - float(self.start[i])
        return busy

    def stage_utilization(self, kinds: Sequence[int] = COMPUTE_KINDS) -> Dict[int, float]:
        """Busy fraction per stage (all compute kinds by default)."""
        if self.total_time <= 0:
            return {s: 0.0 for s in range(self.num_stages)}
        return {s: b / self.total_time
                for s, b in self.stage_busy(kinds).items()}

    def bubble_fraction(self, kinds: Sequence[int] = (KIND_FD, KIND_BD)) -> float:
        """1 - mean stage busy fraction (the legacy ``bubble_ratio``)."""
        busy = self.stage_busy(kinds)
        if not busy or self.total_time <= 0:
            return 0.0
        return 1.0 - sum(busy.values()) / len(busy) / self.total_time

    def resource_occupancy(self, kind: int = KIND_NOC) -> Dict[int, float]:
        """Busy fraction per resource id for one resource lane, in sorted
        key order (deterministic across pool workers)."""
        busy: Dict[int, float] = {}
        for i in range(len(self)):
            if int(self.kind[i]) == kind:
                rid = int(self.resource[i])
                busy[rid] = busy.get(rid, 0.0) + float(self.end[i]) - float(self.start[i])
        if self.total_time <= 0:
            return {rid: 0.0 for rid in sorted(busy)}
        return {rid: busy[rid] / self.total_time for rid in sorted(busy)}

    def critical_path(self) -> List[TraceRow]:
        """Binding-dependency chain through the compute lanes, in
        chronological order.

        When the trace carries recorded causality (``pred`` column, any
        entry >= 0) the path is *exact*: it follows the scheduler's
        per-event binding-predecessor edges, which account for resource
        contention (a compute event delayed by a shared tile group points
        at the event that released the resource, not at a structural
        neighbour). Traces without recorded causality (schema-1 files,
        serving timelines) fall back to the structural heuristic: walking
        back from the last-finishing compute event, the predecessor is
        the latest-ending candidate among the event's structural
        dependencies (previous event on the same stage; the upstream FD
        for an FD; the downstream BD — or the local loss FD — for a BD;
        the stage's last BD for a GU)."""
        if self._has_pred():
            comp_idx = [i for i in range(len(self))
                        if int(self.kind[i]) in COMPUTE_KINDS]
            if not comp_idx:
                return []
            cur = max(comp_idx, key=lambda i: (float(self.end[i]), i))
            path: List[TraceRow] = []
            seen = set()
            while 0 <= cur < len(self) and cur not in seen:
                seen.add(cur)
                path.append(self[cur])
                cur = int(self.pred[cur])
            path.reverse()
            return path
        comp = [(i, TraceRow(int(self.stage[i]), int(self.kind[i]),
                             int(self.micro[i]), int(self.resource[i]),
                             float(self.start[i]), float(self.end[i])))
                for i in range(len(self))
                if int(self.kind[i]) in COMPUTE_KINDS]
        if not comp:
            return []
        by_key = {(r.stage, r.kind, r.micro): r for _, r in comp}
        prev_on_stage: Dict[int, Dict[Tuple[int, int, int], Optional[TraceRow]]] = {}
        last: Dict[int, Optional[TraceRow]] = {}
        last_bd: Dict[int, TraceRow] = {}
        for _, r in comp:                       # record order == per-stage order
            prev_on_stage.setdefault(r.stage, {})[(r.stage, r.kind, r.micro)] = \
                last.get(r.stage)
            last[r.stage] = r
            if r.kind == KIND_BD:
                last_bd[r.stage] = r
        max_stage = max(r.stage for _, r in comp)

        cur = max(comp, key=lambda ir: (ir[1].end, ir[0]))[1]
        path = [cur]
        for _ in range(len(comp)):              # bounded walk (no cycles)
            cands: List[Optional[TraceRow]] = [
                prev_on_stage[cur.stage].get((cur.stage, cur.kind, cur.micro))]
            if cur.kind == KIND_FD and cur.stage > 0:
                cands.append(by_key.get((cur.stage - 1, KIND_FD, cur.micro)))
            elif cur.kind == KIND_BD:
                if cur.stage < max_stage:
                    cands.append(by_key.get((cur.stage + 1, KIND_BD, cur.micro)))
                else:                           # loss computed locally after FD
                    cands.append(by_key.get((cur.stage, KIND_FD, cur.micro)))
            elif cur.kind == KIND_GU:
                cands.append(last_bd.get(cur.stage))
            cands = [c for c in cands if c is not None and c is not cur
                     and c.end <= cur.start + 1e-12]
            if not cands:
                break
            cur = max(cands, key=lambda r: r.end)
            path.append(cur)
        path.reverse()
        return path

    def _has_pred(self) -> bool:
        """True when any row carries a recorded causality edge."""
        if _np is not None and isinstance(self.pred, _np.ndarray):
            return bool((self.pred >= 0).any())
        return any(p >= 0 for p in self.pred)

    def summary(self) -> Dict[str, Any]:
        """JSON-safe analytics digest (what reports embed)."""
        path = self.critical_path()
        return {
            "events": len(self),
            "compute_events": sum(1 for i in range(len(self))
                                  if int(self.kind[i]) in COMPUTE_KINDS),
            "total_time": self.total_time,
            "num_stages": self.num_stages,
            "stage_utilization": {str(s): u
                                  for s, u in self.stage_utilization().items()},
            "bubble_fraction": self.bubble_fraction(),
            "critical_path": {
                "length": len(path),
                "busy_time": sum(r.duration for r in path),
                "exact": self._has_pred(),
            },
            "noc_occupancy": {str(k): v
                              for k, v in self.resource_occupancy(KIND_NOC).items()},
            "dram_occupancy": {str(k): v
                               for k, v in self.resource_occupancy(KIND_DRAM).items()},
            "fabric_occupancy": {str(k): v
                                 for k, v in self.resource_occupancy(KIND_FABRIC).items()},
        }

    # -- serialization -------------------------------------------------------
    def to_dict(self) -> Dict[str, Any]:
        """Compact JSON-safe dict (plain lists, kinds as enum codes)."""
        return {
            "schema": _SCHEMA,
            "total_time": self.total_time,
            "num_stages": self.num_stages,
            "stage": [int(v) for v in self.stage],
            "kind": [int(v) for v in self.kind],
            "micro": [int(v) for v in self.micro],
            "resource": [int(v) for v in self.resource],
            "start": [float(v) for v in self.start],
            "end": [float(v) for v in self.end],
            "pred": [int(v) for v in self.pred],
        }

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "Trace":
        # schema 1 lacks the pred column; it reads back as all -1
        if d.get("schema", _SCHEMA) not in (1, _SCHEMA):
            raise ValueError(f"unknown trace schema {d.get('schema')!r}")
        return cls(stage=d["stage"], kind=d["kind"], micro=d["micro"],
                   resource=d["resource"], start=d["start"], end=d["end"],
                   pred=d.get("pred"),
                   total_time=d["total_time"], num_stages=d["num_stages"])

    def to_bytes(self) -> bytes:
        """Lossless compressed wire form (also the pickle payload).

        Events are recorded at completion time, so the ``end`` column is
        near-monotone: xor-delta over its u64 bit patterns leaves mostly
        shared high bits. ``start`` is stored as the duration
        ``end - start`` — event-driven timelines repeat a handful of
        distinct durations thousands of times — with an explicit fixup
        list for the (rare) rows where ``end - dur`` does not reproduce
        ``start`` bit-exactly. Float payloads are byte-shuffled, then the
        whole body is zlib-compressed."""
        # pred is near-monotone (mostly "the previous row on this stage"),
        # so it ships as the small, highly repetitive offset `i - pred[i]`;
        # the no-predecessor rows (-1) ship as 0, which is unambiguous
        # (a real pred is always an earlier row, so i - pred >= 1)
        start = [float(v) for v in self.start] if _np is None else None
        if _np is None:
            pred_b = _col_bytes(_col(_I32, [0 if p < 0 else i - int(p)
                                            for i, p
                                            in enumerate(self.pred)]))
            end = [float(v) for v in self.end]
            dur = [e - s for s, e in zip(start, end)]
            fix_idx = [i for i in range(len(self))
                       if end[i] - dur[i] != start[i]]
            dur_b = _col_bytes(_col("d", dur))
            fix_idx_b = _col_bytes(_col(_I32, fix_idx))
            fix_val_b = _col_bytes(_col("d", [start[i] for i in fix_idx]))
        else:
            rel = (_np.arange(len(self), dtype=_np.int64)
                   - self.pred).astype(_np.int32)
            rel[self.pred < 0] = 0
            pred_b = _col_bytes(rel)
            dur = self.end - self.start
            bad = (self.end - dur) != self.start
            idx = _np.nonzero(bad)[0].astype(_np.int32)
            dur_b = _col_bytes(dur)
            fix_idx_b = _col_bytes(idx)
            fix_val_b = _col_bytes(self.start[bad])
            fix_idx = idx
        body = (_col_bytes(self.stage) + _col_bytes(self.kind)
                + _col_bytes(self.micro) + _col_bytes(self.resource)
                + pred_b
                + _shuffle(_xor_delta(_col_bytes(self.end)), 8)
                + _shuffle(dur_b, 8) + fix_idx_b + fix_val_b)
        header = json.dumps({"v": _SCHEMA, "n": len(self),
                             "nfix": len(fix_idx),
                             "total_time": self.total_time,
                             "num_stages": self.num_stages}).encode()
        return (_MAGIC + struct.pack("<I", len(header)) + header
                + zlib.compress(body, 6))

    @classmethod
    def from_bytes(cls, blob: bytes) -> "Trace":
        if blob[:4] != _MAGIC:
            raise ValueError("not a Trace byte stream")
        (hlen,) = struct.unpack("<I", blob[4:8])
        meta = json.loads(blob[8:8 + hlen].decode())
        if meta["v"] not in (1, _SCHEMA):
            raise ValueError(f"unknown trace schema {meta['v']!r}")
        has_pred = meta["v"] >= 2       # schema-1 blobs lack the pred column
        n, nfix = meta["n"], meta["nfix"]
        body = zlib.decompress(blob[8 + hlen:])
        sizes = [4 * n, n, 4 * n, 4 * n]
        if has_pred:
            sizes.append(4 * n)
        sizes += [8 * n, 8 * n, 4 * nfix, 8 * nfix]
        if len(body) != sum(sizes):
            raise ValueError("corrupt trace payload")
        parts, off = [], 0
        for sz in sizes:
            parts.append(body[off:off + sz])
            off += sz
        pred_b = parts.pop(4) if has_pred else None
        end_b = _xor_undelta(_unshuffle(parts[4], 8))
        end = _col_from_bytes("d", end_b)
        dur = _col_from_bytes("d", _unshuffle(parts[5], 8))
        fix_idx = _col_from_bytes(_I32, parts[6])
        fix_val = _col_from_bytes("d", parts[7])
        if _np is not None:
            start = end - dur
            start[_np.asarray(fix_idx, dtype=_np.int64)] = fix_val
        else:
            start = array.array("d", (e - d for e, d in zip(end, dur)))
            for i, v in zip(fix_idx, fix_val):
                start[i] = v
        out = cls.__new__(cls)
        out.stage = _col_from_bytes(_I32, parts[0])
        out.kind = _col_from_bytes("b", parts[1])
        out.micro = _col_from_bytes(_I32, parts[2])
        out.resource = _col_from_bytes(_I32, parts[3])
        if pred_b is None:
            out.pred = _col(_I32, [-1] * n)
        else:
            rel = _col_from_bytes(_I32, pred_b)
            if _np is not None:
                pred = (_np.arange(n, dtype=_np.int64)
                        - rel).astype(_np.int32)
                pred[_np.asarray(rel) == 0] = -1
                out.pred = pred
            else:
                out.pred = _col(_I32, [-1 if r == 0 else i - int(r)
                                       for i, r in enumerate(rel)])
        out.start = _col("d", start)
        out.end = end
        out.total_time = float(meta["total_time"])
        out.num_stages = int(meta["num_stages"])
        return out

    def __reduce__(self):
        # columnar + compressed on the wire: this is what cuts sweep IPC
        return (Trace.from_bytes, (self.to_bytes(),))

    def to_npz(self, path) -> None:
        """Write the columns as a compressed ``.npz`` archive (numpy only)."""
        if _np is None:
            raise RuntimeError("to_npz needs numpy; use to_bytes/to_dict "
                               "in numpy-free environments")
        _np.savez_compressed(
            path,
            stage=_np.asarray(self.stage, dtype=_np.int32),
            kind=_np.asarray(self.kind, dtype=_np.int8),
            micro=_np.asarray(self.micro, dtype=_np.int32),
            resource=_np.asarray(self.resource, dtype=_np.int32),
            start=_np.asarray(self.start, dtype=_np.float64),
            end=_np.asarray(self.end, dtype=_np.float64),
            pred=_np.asarray(self.pred, dtype=_np.int32),
            meta=_np.array([self.total_time, float(self.num_stages),
                            float(_SCHEMA)]))

    @classmethod
    def from_npz(cls, path) -> "Trace":
        if _np is None:
            raise RuntimeError("from_npz needs numpy")
        with _np.load(path) as z:
            meta = z["meta"]
            if int(meta[2]) not in (1, _SCHEMA):
                raise ValueError(f"unknown trace schema {int(meta[2])}")
            return cls(stage=z["stage"], kind=z["kind"], micro=z["micro"],
                       resource=z["resource"], start=z["start"], end=z["end"],
                       pred=z["pred"] if "pred" in z.files else None,
                       total_time=float(meta[0]), num_stages=int(meta[1]))


def _itemsize(col) -> int:
    return col.itemsize     # same attribute on ndarray and array.array


# ---------------------------------------------------------------------------
# TraceRecorder (write side)
# ---------------------------------------------------------------------------

class TraceRecorder:
    """Append-only builder the simulator records into; ``freeze`` produces
    the immutable columnar :class:`Trace`."""

    def __init__(self):
        self._stage: List[int] = []
        self._kind: List[int] = []
        self._micro: List[int] = []
        self._resource: List[int] = []
        self._start: List[float] = []
        self._end: List[float] = []
        self._pred: List[int] = []

    def __len__(self) -> int:
        return len(self._stage)

    def compute(self, stage: int, kind: int, micro: int,
                start: float, end: float, pred: int = -1) -> int:
        """One FD/BD/GU event on a pipeline stage. ``pred`` is the row
        index of the event whose completion bound this event's start
        (-1 = none known). Returns this row's index so callers can wire
        later events' causality to it."""
        self._stage.append(stage)
        self._kind.append(kind)
        self._micro.append(micro)
        self._resource.append(-1)
        self._start.append(start)
        self._end.append(end)
        self._pred.append(pred)
        return len(self._stage) - 1

    def resource(self, kind: int, resource_id: int,
                 start: float, end: float) -> None:
        """One busy interval on a NoC link / DRAM channel."""
        self._stage.append(-1)
        self._kind.append(kind)
        self._micro.append(-1)
        self._resource.append(resource_id)
        self._start.append(start)
        self._end.append(end)
        self._pred.append(-1)

    def request(self, kind: int, request_id: int, episode: int,
                start: float, end: float) -> None:
        """One per-request serving span (PREFILL/DECODE/QUEUE): the
        ``resource`` column carries the request id and ``micro`` the
        batching episode (bumped each time a preempted request resumes)."""
        self._stage.append(-1)
        self._kind.append(kind)
        self._micro.append(episode)
        self._resource.append(request_id)
        self._start.append(start)
        self._end.append(end)
        self._pred.append(-1)

    def interval_cb(self, kind: int, resource_id: int) -> Callable[[float, float], None]:
        """Busy-interval callback for one resource (what
        :class:`~repro.core.events.Resource` calls on busy->idle)."""
        def cb(start: float, end: float) -> None:
            self.resource(kind, resource_id, start, end)
        return cb

    def freeze(self, total_time: float, num_stages: int) -> Trace:
        return Trace(stage=self._stage, kind=self._kind, micro=self._micro,
                     resource=self._resource, start=self._start,
                     end=self._end, pred=self._pred, total_time=total_time,
                     num_stages=num_stages)


# ---------------------------------------------------------------------------
# Chrome / Perfetto export
# ---------------------------------------------------------------------------

_PID_STAGES, _PID_NOC, _PID_DRAM, _PID_REQUESTS, _PID_FABRIC = 0, 1, 2, 3, 4
_PID_COUNTERS = 5


def chrome_trace(trace: Trace, label: str = "palm",
                 counters: Optional[Dict[str, List[List[float]]]] = None,
                 ) -> Dict[str, Any]:
    """Render a Trace as the Chrome/Perfetto ``traceEvents`` JSON dict
    (load via chrome://tracing or https://ui.perfetto.dev).

    Pipeline stages are threads of process 0 (one row per stage); NoC link
    and DRAM channel busy intervals are threads of processes 1 and 2;
    serving per-request lanes (PREFILL/DECODE/QUEUE spans, one thread per
    request id) are threads of process 3; scale-out fabric link busy
    intervals are threads of process 4. Timestamps are microseconds (the
    format's unit); durations are complete events (``ph: "X"``).

    ``counters`` maps series names to ``[t_seconds, value]`` samples
    (see :mod:`repro.obs.tracks`); each series becomes a Perfetto counter
    track (``ph: "C"``) on process 5."""
    events: List[Dict[str, Any]] = []
    for pid, name in ((_PID_STAGES, f"{label}: pipeline stages"),
                      (_PID_NOC, f"{label}: NoC links"),
                      (_PID_DRAM, f"{label}: DRAM channels"),
                      (_PID_REQUESTS, f"{label}: requests"),
                      (_PID_FABRIC, f"{label}: fabric links")):
        events.append({"ph": "M", "pid": pid, "name": "process_name",
                       "args": {"name": name}})
    if counters:
        events.append({"ph": "M", "pid": _PID_COUNTERS,
                       "name": "process_name",
                       "args": {"name": f"{label}: counters"}})
        for series_name in sorted(counters):
            for t, v in counters[series_name]:
                events.append({"ph": "C", "pid": _PID_COUNTERS, "tid": 0,
                               "name": series_name, "ts": t * 1e6,
                               "args": {"value": v}})
    seen_tids = set()
    for r in trace.rows():
        if r.kind in COMPUTE_KINDS:
            pid, tid = _PID_STAGES, r.stage
            name = f"{KIND_NAMES[r.kind]} mb{r.micro}"
            args: Dict[str, Any] = {"micro": r.micro}
            tname = f"stage {r.stage}"
        elif r.kind in REQUEST_KINDS:
            pid, tid = _PID_REQUESTS, r.resource
            name = f"{KIND_NAMES[r.kind]} ep{r.micro}"
            args = {"episode": r.micro}
            tname = f"req {r.resource}"
        elif r.kind == KIND_FABRIC:
            pid, tid = _PID_FABRIC, r.resource
            name = "busy"
            args = {}
            tname = f"flink {r.resource}"
        else:
            pid = _PID_NOC if r.kind == KIND_NOC else _PID_DRAM
            tid = r.resource
            name = "busy"
            args = {}
            tname = (f"link {r.resource}" if r.kind == KIND_NOC
                     else f"channel {r.resource}")
        if (pid, tid) not in seen_tids:
            seen_tids.add((pid, tid))
            events.append({"ph": "M", "pid": pid, "tid": tid,
                           "name": "thread_name", "args": {"name": tname}})
        events.append({"ph": "X", "pid": pid, "tid": tid, "name": name,
                       "cat": KIND_NAMES[r.kind], "ts": r.start * 1e6,
                       "dur": (r.end - r.start) * 1e6, "args": args})
    return {"displayTimeUnit": "ms", "traceEvents": events}


# ---------------------------------------------------------------------------
# Trace diff (hardware / plan A/B studies)
# ---------------------------------------------------------------------------

def _paired(a: Dict[int, float], b: Dict[int, float]) -> Dict[int, Tuple[float, float]]:
    """Union the key sets; missing entries read as 0.0."""
    return {k: (a.get(k, 0.0), b.get(k, 0.0))
            for k in sorted(set(a) | set(b))}


class TraceDiff:
    """Structural comparison of two timelines (A vs B).

    Every per-key dict maps to an ``(a, b)`` value pair over the union of
    the two traces' keys (a stage / resource present in only one trace
    reads as 0.0 in the other), so a hardware variant that adds NoC links
    or drops a pipeline stage still diffs cleanly. Deltas are ``b - a``.
    """

    def __init__(self, a: Trace, b: Trace):
        self.total_time = (a.total_time, b.total_time)
        self.events = (len(a), len(b))
        self.bubble_fraction = (a.bubble_fraction(), b.bubble_fraction())
        self.stage_busy = _paired(a.stage_busy(), b.stage_busy())
        self.stage_utilization = _paired(a.stage_utilization(),
                                         b.stage_utilization())
        self.noc_occupancy = _paired(a.resource_occupancy(KIND_NOC),
                                     b.resource_occupancy(KIND_NOC))
        self.dram_occupancy = _paired(a.resource_occupancy(KIND_DRAM),
                                      b.resource_occupancy(KIND_DRAM))
        self.fabric_occupancy = _paired(a.resource_occupancy(KIND_FABRIC),
                                        b.resource_occupancy(KIND_FABRIC))

    # -- deltas (b - a) ------------------------------------------------------
    @property
    def total_time_delta(self) -> float:
        return self.total_time[1] - self.total_time[0]

    @property
    def bubble_delta(self) -> float:
        return self.bubble_fraction[1] - self.bubble_fraction[0]

    def stage_busy_delta(self) -> Dict[int, float]:
        return {s: b - a for s, (a, b) in self.stage_busy.items()}

    def stage_utilization_delta(self) -> Dict[int, float]:
        return {s: b - a for s, (a, b) in self.stage_utilization.items()}

    def noc_occupancy_delta(self) -> Dict[int, float]:
        return {r: b - a for r, (a, b) in self.noc_occupancy.items()}

    def dram_occupancy_delta(self) -> Dict[int, float]:
        return {r: b - a for r, (a, b) in self.dram_occupancy.items()}

    def fabric_occupancy_delta(self) -> Dict[int, float]:
        return {r: b - a for r, (a, b) in self.fabric_occupancy.items()}

    # -- serialization -------------------------------------------------------
    def to_dict(self) -> Dict[str, Any]:
        def pairs(d: Dict[int, Tuple[float, float]]) -> Dict[str, Any]:
            return {str(k): {"a": a, "b": b, "delta": b - a}
                    for k, (a, b) in d.items()}
        return {
            "total_time": {"a": self.total_time[0], "b": self.total_time[1],
                           "delta": self.total_time_delta},
            "events": {"a": self.events[0], "b": self.events[1],
                       "delta": self.events[1] - self.events[0]},
            "bubble_fraction": {"a": self.bubble_fraction[0],
                                "b": self.bubble_fraction[1],
                                "delta": self.bubble_delta},
            "stage_busy": pairs(self.stage_busy),
            "stage_utilization": pairs(self.stage_utilization),
            "noc_occupancy": pairs(self.noc_occupancy),
            "dram_occupancy": pairs(self.dram_occupancy),
            "fabric_occupancy": pairs(self.fabric_occupancy),
        }

    def to_json(self, **kw: Any) -> str:
        return json.dumps(self.to_dict(), **kw)

    def table(self, top: int = 10) -> str:
        """Human-readable digest: scalar deltas plus the per-stage table
        and the ``top`` NoC/DRAM lanes by absolute occupancy delta."""
        ta, tb = self.total_time
        rel = f" ({(tb - ta) / ta:+.1%})" if ta > 0 else ""
        lines = [
            f"total_time: {ta:.6g}s -> {tb:.6g}s"
            f" (delta {self.total_time_delta:+.6g}s{rel})",
            f"bubble:     {self.bubble_fraction[0]:.1%} -> "
            f"{self.bubble_fraction[1]:.1%} (delta {self.bubble_delta:+.1%})",
            f"events:     {self.events[0]} -> {self.events[1]}",
            "",
            f"{'stage':>5s} {'busy_a (s)':>12s} {'busy_b (s)':>12s} "
            f"{'delta (s)':>12s} {'util delta':>10s}",
        ]
        util_delta = self.stage_utilization_delta()
        for s, (a, b) in self.stage_busy.items():
            lines.append(f"{s:5d} {a:12.6g} {b:12.6g} {b - a:+12.6g} "
                         f"{util_delta.get(s, 0.0):+10.1%}")
        for label, paired in (("NoC link", self.noc_occupancy),
                              ("DRAM channel", self.dram_occupancy),
                              ("Fabric link", self.fabric_occupancy)):
            if not paired:
                continue
            ranked = sorted(paired.items(),
                            key=lambda kv: -abs(kv[1][1] - kv[1][0]))[:top]
            lines.append("")
            lines.append(f"{label:>12s} {'occ_a':>8s} {'occ_b':>8s} "
                         f"{'delta':>8s}   (top {len(ranked)} by |delta|)")
            for rid, (a, b) in ranked:
                lines.append(f"{rid:12d} {a:8.1%} {b:8.1%} {b - a:+8.1%}")
        return "\n".join(lines)


def diff(a: Trace, b: Trace) -> TraceDiff:
    """Per-stage / per-lane busy & bubble deltas between two timelines
    (e.g. the same workload on two hardware variants)."""
    return TraceDiff(a, b)
