"""ArchConfig -> PALM workload IR (ComputationGraph).

This is the bridge that makes the paper's technique first-class for every
assigned architecture: the planner simulates the same arch configs the
JAX launchers execute. Decomposition follows the paper's rule for
transformers ("a combination of a series of linear operators"), extended
per DESIGN.md §4 for MoE / SSM / hybrid blocks.
"""

from __future__ import annotations

from typing import List, Optional

from ..configs.base import ArchConfig
from .graph import (
    Attention,
    ComputationGraph,
    Embedding,
    Linear,
    MoELayer,
    Op,
    SSMScan,
    TransformerLayer,
)

__all__ = ["arch_to_graph"]


def _mlp_op(name: str, arch: ArchConfig, batch: int, seq: int) -> Op:
    """Standalone MLP as a Linear op (fused gate+up+down accounting)."""
    mults = 3 if arch.mlp == "gated_silu" else 2
    return Linear(name=name, B=1, M=mults * arch.d_ff, N=batch * seq, K=arch.d_model)


def arch_to_graph(
    arch: ArchConfig,
    seq_len: int,
    batch: int,
    training: bool = True,
    decode: bool = False,
) -> ComputationGraph:
    """Build the operator graph for one iteration (train fwd+bwd handled by
    the scheduler; ``decode=True`` builds the 1-token serve step against a
    ``seq_len`` KV cache)."""
    ops: List[Op] = []
    S = 1 if decode else seq_len
    if not arch.embeds_input:
        ops.append(Embedding(name="embed", B=batch, S=S, H=arch.d_model, V=arch.vocab))

    for i in range(arch.num_layers):
        if arch.block == "attn":
            if decode:
                ops.extend(_decode_layer(arch, batch, seq_len, i))
            else:
                ops.append(TransformerLayer(
                    name=f"layer{i}", B=batch, S=S, H=arch.d_model,
                    n_heads=arch.n_heads, n_kv=arch.n_kv, d_head=arch.head_dim,
                    d_ff=arch.d_ff if not arch.n_experts else 0,
                    gated_mlp=arch.mlp == "gated_silu",
                    causal=arch.causal,
                    window=arch.window or None))
            if arch.n_experts:
                ops.append(MoELayer(
                    name=f"moe{i}", B=batch, S=S, H=arch.d_model,
                    n_experts=arch.n_experts, top_k=arch.top_k,
                    d_ff_expert=arch.d_ff_expert))
        elif arch.block == "ssm":
            ops.append(SSMScan(
                name=f"ssm{i}", B=batch, S=S, H=arch.d_model,
                d_inner=arch.d_inner, d_state=arch.ssm_state,
                n_heads=arch.ssm_n_heads, conv_width=arch.conv_width))
        elif arch.block == "hymba":
            # parallel attn + mamba heads sharing the block, then MLP.
            # Reference hymba keeps 3 global-attention layers; the workload
            # IR models them; window elsewhere (DESIGN.md §4).
            is_global = i in (0, arch.num_layers // 2, arch.num_layers - 1)
            window = None if is_global else (arch.window or None)
            if decode:
                span = seq_len if is_global else min(arch.window or seq_len, seq_len)
                ops.append(Attention(
                    name=f"attn{i}", B=batch, S_q=1, S_kv=span,
                    n_heads=arch.n_heads, n_kv=arch.n_kv, d_head=arch.head_dim))
            else:
                ops.append(TransformerLayer(
                    name=f"attn{i}", B=batch, S=S, H=arch.d_model,
                    n_heads=arch.n_heads, n_kv=arch.n_kv, d_head=arch.head_dim,
                    d_ff=0, gated_mlp=False, causal=True, window=window))
            ops.append(SSMScan(
                name=f"ssm{i}", B=batch, S=S, H=arch.d_model,
                d_inner=arch.d_inner, d_state=arch.ssm_state,
                n_heads=arch.ssm_n_heads, conv_width=arch.conv_width))
            if arch.d_ff:
                ops.append(_mlp_op(f"mlp{i}", arch, batch, S))
        else:
            raise ValueError(f"unknown block {arch.block}")

    if not arch.is_encoder_only or arch.vocab:
        ops.append(Linear(name="lm_head", B=1, M=arch.vocab, N=batch * S, K=arch.d_model))
    return ComputationGraph(ops=ops, name=arch.name)


def _decode_layer(arch: ArchConfig, batch: int, cache_len: int, i: int) -> List[Op]:
    """Decode-mode transformer layer: S=1 projections/MLP + cache attention
    against the full ``cache_len`` span (a separate Attention op so the
    span is not clipped by S=1)."""
    span = min(arch.window or cache_len, cache_len)
    proj = TransformerLayer(
        name=f"layer{i}", B=batch, S=1, H=arch.d_model,
        n_heads=arch.n_heads, n_kv=arch.n_kv, d_head=arch.head_dim,
        d_ff=arch.d_ff if not arch.n_experts else 0,
        gated_mlp=arch.mlp == "gated_silu", causal=False, window=1)
    attn = Attention(
        name=f"cache_attn{i}", B=batch, S_q=1, S_kv=span,
        n_heads=arch.n_heads, n_kv=arch.n_kv, d_head=arch.head_dim)
    return [proj, attn]
