"""Discrete event-driven simulation kernel (PALM §II-D2, §IV).

PALM is built on a discrete event-driven framework — the paper uses SimPy
[49]; SimPy is not available in this environment, so this module provides an
equivalent, deterministic, generator-based process/resource kernel.

Semantics mirror the SimPy subset PALM needs:

* ``Environment``   — event heap + virtual clock.
* ``Event``         — one-shot triggerable value carrier.
* ``Timeout``       — event that fires after a virtual delay.
* ``Process``       — generator coroutine; ``yield`` an event to wait on it.
* ``Resource``      — capacity-limited FIFO resource (NoC links, DRAM ports).
* ``PriorityResource`` — resource whose queue is ordered by priority
  (used by the 1F1B Prior Selector: BD requests pre-empt queued FD ones).
* ``AllOf/AnyOf``   — condition events.

Determinism: the heap is keyed ``(time, priority, seq)`` where ``seq`` is a
monotone counter, so identical-time events always replay in schedule order.
"""

from __future__ import annotations

import heapq
import itertools
from typing import Any, Callable, Generator, Iterable, List, Optional

__all__ = [
    "Environment",
    "Event",
    "Timeout",
    "Process",
    "Resource",
    "PriorityResource",
    "AllOf",
    "AnyOf",
    "Interrupt",
]


class Interrupt(Exception):
    """Raised inside a process that has been interrupted."""

    def __init__(self, cause: Any = None):
        super().__init__(cause)
        self.cause = cause


class Event:
    """A one-shot event that processes can wait on."""

    __slots__ = ("env", "callbacks", "_value", "_ok", "_triggered", "_processed", "name")

    def __init__(self, env: "Environment", name: str = ""):
        self.env = env
        self.callbacks: List[Callable[["Event"], None]] = []
        self._value: Any = None
        self._ok = True
        self._triggered = False
        self._processed = False
        self.name = name

    # -- state ------------------------------------------------------------
    @property
    def triggered(self) -> bool:
        return self._triggered

    @property
    def processed(self) -> bool:
        return self._processed

    @property
    def ok(self) -> bool:
        return self._ok

    @property
    def value(self) -> Any:
        return self._value

    # -- triggering -------------------------------------------------------
    def succeed(self, value: Any = None, priority: int = 0) -> "Event":
        if self._triggered:
            raise RuntimeError(f"event {self.name!r} already triggered")
        self._triggered = True
        self._value = value
        self.env._schedule(self, delay=0.0, priority=priority)
        return self

    def fail(self, exc: BaseException, priority: int = 0) -> "Event":
        if self._triggered:
            raise RuntimeError(f"event {self.name!r} already triggered")
        self._triggered = True
        self._ok = False
        self._value = exc
        self.env._schedule(self, delay=0.0, priority=priority)
        return self

    def __repr__(self) -> str:  # pragma: no cover - debug aid
        state = "processed" if self._processed else ("triggered" if self._triggered else "pending")
        return f"<{type(self).__name__} {self.name!r} {state} @{self.env.now:.6g}>"


class Timeout(Event):
    """Event that fires ``delay`` virtual seconds after creation."""

    def __init__(self, env: "Environment", delay: float, value: Any = None, name: str = ""):
        if delay < 0:
            raise ValueError(f"negative delay {delay}")
        super().__init__(env, name=name or f"timeout({delay:.3g})")
        self._triggered = True
        self._value = value
        env._schedule(self, delay=delay)


class Process(Event):
    """Runs a generator; the process event triggers when the generator ends.

    The generator may ``yield`` any :class:`Event`; it is resumed with the
    event's value (or the event's exception is thrown into it).
    """

    __slots__ = ("_gen", "_target")

    def __init__(self, env: "Environment", gen: Generator, name: str = ""):
        super().__init__(env, name=name or getattr(gen, "__name__", "process"))
        self._gen = gen
        self._target: Optional[Event] = None
        # bootstrap: resume on the next scheduling round at the current time
        init = Event(env, name=f"{self.name}.init")
        init.callbacks.append(self._resume)
        init._triggered = True
        env._schedule(init, delay=0.0)

    def interrupt(self, cause: Any = None) -> None:
        """Throw :class:`Interrupt` into the process at the current time."""
        if self._triggered:
            return
        evt = Event(self.env, name=f"{self.name}.interrupt")
        evt._ok = False
        evt._value = Interrupt(cause)
        evt.callbacks.append(self._resume)
        evt._triggered = True
        # detach from whatever we were waiting on
        target, self._target = self._target, None
        if target is not None and self._resume in target.callbacks:
            target.callbacks.remove(self._resume)
        self.env._schedule(evt, delay=0.0, priority=-1)

    # -- engine -----------------------------------------------------------
    def _resume(self, event: Event) -> None:
        self._target = None
        try:
            if event.ok:
                nxt = self._gen.send(event.value)
            else:
                nxt = self._gen.throw(event.value)
        except StopIteration as stop:
            self.succeed(getattr(stop, "value", None))
            return
        except BaseException as exc:  # propagate failures to waiters
            if self.callbacks:
                self.fail(exc)
                return
            raise
        if not isinstance(nxt, Event):
            raise TypeError(
                f"process {self.name!r} yielded {nxt!r}; processes must yield Event instances"
            )
        self._target = nxt
        if nxt._processed:
            # already fired: resume immediately at current time
            relay = Event(self.env, name=f"{self.name}.relay")
            relay._ok = nxt._ok
            relay._value = nxt._value
            relay.callbacks.append(self._resume)
            relay._triggered = True
            self.env._schedule(relay, delay=0.0)
        else:
            nxt.callbacks.append(self._resume)


class _Condition(Event):
    """Base for AllOf/AnyOf."""

    __slots__ = ("_events", "_count")

    def __init__(self, env: "Environment", events: Iterable[Event], name: str):
        super().__init__(env, name=name)
        self._events = list(events)
        self._count = 0
        if not self._events:
            self.succeed({})
            return
        for evt in self._events:
            if evt._processed:
                self._on_fire(evt)
            else:
                evt.callbacks.append(self._on_fire)

    def _on_fire(self, event: Event) -> None:
        raise NotImplementedError


class AllOf(_Condition):
    """Triggers when every child event has fired. Value: dict event->value."""

    def __init__(self, env: "Environment", events: Iterable[Event], name: str = "all_of"):
        super().__init__(env, events, name)

    def _on_fire(self, event: Event) -> None:
        if self._triggered:
            return
        if not event.ok:
            self.fail(event.value if isinstance(event.value, BaseException) else RuntimeError(event.value))
            return
        self._count += 1
        if self._count == len(self._events):
            self.succeed({e: e.value for e in self._events})


class AnyOf(_Condition):
    """Triggers when the first child event fires. Value: dict event->value."""

    def __init__(self, env: "Environment", events: Iterable[Event], name: str = "any_of"):
        super().__init__(env, events, name)

    def _on_fire(self, event: Event) -> None:
        if self._triggered:
            return
        if not event.ok:
            self.fail(event.value if isinstance(event.value, BaseException) else RuntimeError(event.value))
            return
        self.succeed({event: event.value})


class Environment:
    """Virtual-time event loop."""

    def __init__(self, initial_time: float = 0.0):
        self.now: float = float(initial_time)
        self._heap: List[tuple] = []
        self._seq = itertools.count()
        self.event_count = 0  # total processed events (sim-cost metric)

    # -- scheduling ---------------------------------------------------------
    def _schedule(self, event: Event, delay: float = 0.0, priority: int = 0) -> None:
        heapq.heappush(self._heap, (self.now + delay, priority, next(self._seq), event))

    def event(self, name: str = "") -> Event:
        return Event(self, name=name)

    def timeout(self, delay: float, value: Any = None, name: str = "") -> Timeout:
        return Timeout(self, delay, value=value, name=name)

    def process(self, gen: Generator, name: str = "") -> Process:
        return Process(self, gen, name=name)

    def all_of(self, events: Iterable[Event]) -> AllOf:
        return AllOf(self, events)

    def any_of(self, events: Iterable[Event]) -> AnyOf:
        return AnyOf(self, events)

    # -- execution ------------------------------------------------------------
    def step(self) -> None:
        time, _prio, _seq, event = heapq.heappop(self._heap)
        if time < self.now - 1e-12:
            raise RuntimeError("time went backwards")
        self.now = max(self.now, time)
        event._processed = True
        callbacks, event.callbacks = event.callbacks, []
        self.event_count += 1
        for cb in callbacks:
            cb(event)

    def run(self, until: Optional[float] = None, until_event: Optional[Event] = None) -> Any:
        """Run until the heap drains, ``until`` time passes, or event fires.

        The ``until`` horizon only *peeks* at the heap head — the first
        event past the horizon is never popped, so a resumed
        ``run(until=later)`` (or a final ``run()``) replays it exactly
        once at its own timestamp. The clock never rewinds: a horizon
        earlier than ``now`` is a no-op, and a fired ``until_event`` is
        reported even when the next head already lies past ``until``.
        """
        while self._heap:
            if until_event is not None and until_event._processed:
                return until_event.value
            if until is not None and self._heap[0][0] > until:
                self.now = max(self.now, until)
                return None
            self.step()
        if until_event is not None and until_event._processed:
            return until_event.value
        if until is not None:
            self.now = max(self.now, until)
        return None


class Resource:
    """Capacity-limited resource with a FIFO wait queue.

    ``request()`` returns an Event that fires once a slot is granted; pass the
    same request object to ``release``. PALM models each NoC link and each
    DRAM channel as a ``Resource(capacity=1)`` — "treating the link as an
    exclusive resource during execution" (§IV-C).
    """

    def __init__(self, env: Environment, capacity: int = 1, name: str = "",
                 interval_cb: Optional[Callable[[float, float], None]] = None):
        if capacity < 1:
            raise ValueError("capacity must be >= 1")
        self.env = env
        self.capacity = capacity
        self.name = name
        self._users: List[Event] = []
        self._queue: List[tuple] = []
        self._qseq = itertools.count()
        # instrumentation: busy-time integral for utilisation reporting;
        # interval_cb additionally receives each closed (start, end) busy
        # interval (the trace recorder's resource lanes)
        self._busy_since: Optional[float] = None
        self.busy_time: float = 0.0
        self.grant_count: int = 0
        self._interval_cb = interval_cb

    # -- API ----------------------------------------------------------------
    def request(self, priority: int = 0) -> Event:
        req = Event(self.env, name=f"{self.name}.req")
        if len(self._users) < self.capacity:
            self._grant(req)
        else:
            heapq.heappush(self._queue, (priority, next(self._qseq), req))
        return req

    def release(self, req: Event) -> None:
        try:
            self._users.remove(req)
        except ValueError:
            raise RuntimeError(f"release of non-user request on {self.name!r}")
        if not self._users and self._busy_since is not None:
            self.busy_time += self.env.now - self._busy_since
            if self._interval_cb is not None and self.env.now > self._busy_since:
                self._interval_cb(self._busy_since, self.env.now)
            self._busy_since = None
        while self._queue and len(self._users) < self.capacity:
            _, _, nxt = heapq.heappop(self._queue)
            self._grant(nxt)

    @property
    def queue_len(self) -> int:
        return len(self._queue)

    @property
    def busy_since(self) -> Optional[float]:
        """Start of the currently open busy interval (None when idle)."""
        return self._busy_since

    @property
    def in_use(self) -> int:
        return len(self._users)

    def utilization(self, horizon: Optional[float] = None) -> float:
        horizon = self.env.now if horizon is None else horizon
        busy = self.busy_time
        if self._busy_since is not None:
            busy += self.env.now - self._busy_since
        return busy / horizon if horizon > 0 else 0.0

    # -- internals ------------------------------------------------------------
    def _grant(self, req: Event) -> None:
        self._users.append(req)
        if self._busy_since is None:
            self._busy_since = self.env.now
        self.grant_count += 1
        req.succeed(self)


class PriorityResource(Resource):
    """Resource whose waiters are served lowest-priority-value-first.

    The 1F1B "Prior Selector" (PALM Fig. 4) grants backward (priority 0)
    before forward (priority 1) work when both are queued on a stage's
    virtual tile.
    """

    pass  # behaviour comes from the priority heap in Resource.request
