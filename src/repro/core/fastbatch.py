"""Batched analytic tier: vectorize the fast path across configurations.

PR 8's fast tier (:mod:`repro.core.fastpath`) made *one* run cheap; the
binding cost in a sweep is now the per-job Python dispatch — every
(hardware, plan) point walks its own chains node-by-node through
``_ChainEval.run``. But a co-design sweep is dominated by configurations
that share the *structure* of their chains (same mesh topology, same
mapped graph shape, same schedule) and differ only in the float leaves
(compute times, transfer times, byte counts) that the hardware axes
scale. This module exploits that:

1. every fast-path-eligible job's compiled :class:`~repro.core.fastpath.
   StageChains` is *skeletonized* — float leaves stripped into a flat
   per-job leaf vector, structure hashed into a chain **shape
   signature** (stage count, microbatch count, work lists, hold lanes,
   par/spawn nesting);
2. jobs are grouped by signature and each group's leaf vectors are
   packed into one ``(num_leaves, num_configs)`` float64 matrix;
3. one structural replay evaluates the whole group: chain segments
   become prefix sums (``np.add.accumulate``) over the config axis, par
   joins become elementwise ``np.maximum`` folds, and the scheduler's
   mailbox replay runs *once* with ``(num_configs,)`` time vectors
   instead of once per job.

Why grouping is sound: the optimistic replay's control flow is purely
structural — which mailbox fills at which step, which chain body runs
next, when the work lists drain — none of it depends on the float
values, only on the (shared) structure. And why the numbers are
bit-identical: ``np.add.accumulate`` is a strict sequential left fold
(so a segment's prefix sums reproduce ``((t + x1) + x2)...`` exactly),
elementwise float64 ops equal their scalar counterparts per element,
and every fold (par joins, totals, byte counters) runs in the same
fixed node order as the scalar tier, so IEEE-754 never reassociates.

Per-job semantics are preserved: interval validation runs per config
(one flat lexsort over the config-major interval matrix), contended or
otherwise ineligible configs fall back individually, and groups too
small to amortize the vector overhead take the scalar replay.

Known divergence: batched results leave ``noc_occupancy_fallback``
empty (its float accumulation order cannot be cheaply vectorized); the
field is compare-excluded and the sweep layer clears it anyway.
"""

from __future__ import annotations

from time import perf_counter
from typing import Dict, List, Optional, Tuple

try:
    import numpy as _np
except ImportError:         # pragma: no cover - exercised by CI bench-smoke
    _np = None

from .fastpath import (
    StageChains,
    classify_cached,
    compile_stage_chains,
    replay_chains,
)
from .parallelism import FD
from .trace import KIND_BD, KIND_FD, KIND_GU, Trace

__all__ = ["available", "run_fast_batch"]

_CONTENDED = "resource contention detected by interval validation"
_STALLED = "work-list replay stalled (mailbox never filled)"


def available() -> bool:
    """True when the vectorized group evaluator can run (numpy present).

    Without numpy :func:`run_fast_batch` still works — it degrades to
    the scalar fast tier per job — so callers never need to branch."""
    return _np is not None


def _padd(profile: Optional[Dict], key: str, val) -> None:
    if profile is not None:
        profile[key] = profile.get(key, 0) + val


# ---------------------------------------------------------------------------
# skeletonization: split a chain into structure (hashable) + float leaves
# ---------------------------------------------------------------------------

def _skeletonize(chain, leaves: List[float]) -> Tuple:
    """Strip a chain's float leaves (appended to ``leaves`` in walk
    order) and return its hashable structure. The walk order — node
    order, par branches left-to-right, spawn bodies inline — is the
    contract :class:`_Compiler` assigns leaf rows by."""
    out = []
    for node in chain:
        tag = node[0]
        if tag == "dt":
            leaves.append(node[1])
            out.append(("dt",))
        elif tag == "hold":
            leaves.append(node[2])
            out.append(("hold", tuple(node[1])))
        elif tag == "par":
            out.append(("par", tuple(_skeletonize(b, leaves)
                                     for b in node[1])))
        elif tag == "bytes":
            leaves.append(node[2])
            out.append(("bytes", node[1]))
        else:  # "spawn"
            out.append(("spawn", _skeletonize(node[1], leaves)))
    return tuple(out)


def _signature(sim, chains: StageChains):
    """Chain shape signature + this job's leaf vector.

    Two jobs with equal signatures replay identically modulo leaf
    values: same stages, same microbatch count and work lists, same
    hold lanes, same par/spawn nesting — so one structural replay
    serves the whole group."""
    S = sim.mapped.num_stages
    leaves: List[float] = []
    skels = []
    for slot in chains:         # NamedTuple order == _Compiler walk order
        skels.append(tuple(None if ch is None else _skeletonize(ch, leaves)
                           for ch in slot))
    work = tuple(tuple(sim._work_list(s)) for s in range(S))
    sig = (S, sim.plan.num_microbatches, bool(sim.plan.training),
           bool(sim.collect_timeline), work, tuple(skels))
    return sig, leaves


# ---------------------------------------------------------------------------
# program compilation: skeleton -> vector ops
# ---------------------------------------------------------------------------

class _Seg:
    """A maximal run of time-advancing nodes (dt/hold) plus the byte
    counters interleaved with them. Evaluated as one prefix sum over
    the leaf matrix: ``P[0] = t``, ``P[i] = P[i-1] + V[adv[i-1]]`` —
    the exact left-fold the scalar tier performs. Byte counters are
    pre-grouped per accumulator (their walk order within one
    accumulator preserved) so a segment's contribution is one more
    ``np.add.accumulate`` seeded with the running total — the same
    strict left fold, not a reassociating ``sum``."""

    __slots__ = ("adv", "hold_pos", "hold_keys", "bytes_ops",
                 "hold_idx", "v_adv", "v_bytes")

    def __init__(self, adv, hold_pos, hold_keys, bytes_ops):
        self.adv = adv              # (k,) leaf rows, walk order
        self.hold_pos = hold_pos    # (h,) prefix positions, one per lane key
        self.hold_keys = hold_keys  # (h,) packed lane ids
        self.bytes_ops = bytes_ops  # ((acc_idx, (k,) leaf rows), ...)
        # interval bounds in one gather: P[hold_idx][:h] are the starts,
        # [h:] the ends
        self.hold_idx = _np.concatenate((hold_pos, hold_pos + 1))
        self.v_adv = None           # (k, G) leaf slice, bound per group
        self.v_bytes = None         # ((acc_idx, (k, G)), ...), ditto


class _Par:
    __slots__ = ("branches",)

    def __init__(self, branches):
        self.branches = branches    # tuple of _Prog


class _Spawn:
    __slots__ = ("body",)

    def __init__(self, body):
        self.body = body            # _Prog


class _Prog:
    """One compiled chain. ``nodes`` is the total chain-node count this
    program contributes per evaluation, *including* par branches and
    spawn bodies — the scalar ``_ChainEval`` adds ``len(chain)`` on
    every (recursive) ``run`` call, and it never skips a branch, so
    the per-run total is static."""

    __slots__ = ("ops", "nodes")

    def __init__(self, ops, nodes):
        self.ops = ops
        self.nodes = nodes


_ACC_IDX = {"noc": 0, "dram": 1}        # anything else is fabric (2)


class _Compiler:
    """Compiles a signature's skeletons into programs, assigning every
    float leaf a row in the group's leaf matrix. One compiler walks all
    chain slots in :class:`StageChains` order, so the row assignment
    matches :func:`_skeletonize`'s leaf collection order exactly."""

    def __init__(self):
        self.row = 0

    def prog(self, skel) -> _Prog:
        ops: List = []
        nodes = len(skel)
        adv: List[int] = []
        hold_pos: List[int] = []
        hold_keys: List[int] = []
        bytes_rows: Dict[int, List[int]] = {}

        def flush():
            nonlocal adv, hold_pos, hold_keys, bytes_rows
            if adv or bytes_rows:
                ops.append(_Seg(
                    _np.asarray(adv, dtype=_np.intp),
                    _np.asarray(hold_pos, dtype=_np.intp),
                    _np.asarray(hold_keys, dtype=_np.int64),
                    tuple((acc, _np.asarray(rows, dtype=_np.intp))
                          for acc, rows in bytes_rows.items())))
                adv, hold_pos, hold_keys, bytes_rows = [], [], [], {}

        for node in skel:
            tag = node[0]
            if tag == "dt":
                adv.append(self.row)
                self.row += 1
            elif tag == "hold":
                j = len(adv)        # interval = [P[j], P[j+1]] per key
                adv.append(self.row)
                self.row += 1
                for k in node[1]:
                    hold_pos.append(j)
                    hold_keys.append(k)
            elif tag == "bytes":
                bytes_rows.setdefault(_ACC_IDX.get(node[1], 2),
                                      []).append(self.row)
                self.row += 1
            elif tag == "par":
                flush()
                branches = tuple(self.prog(b) for b in node[1])
                nodes += sum(b.nodes for b in branches)
                ops.append(_Par(branches))
            else:  # "spawn"
                flush()
                body = self.prog(node[1])
                nodes += body.nodes
                ops.append(_Spawn(body))
        flush()
        return _Prog(tuple(ops), nodes)


def _compile_group(skels) -> Tuple[StageChains, int]:
    comp = _Compiler()
    slots = [[None if sk is None else comp.prog(sk) for sk in slot]
             for slot in skels]
    return StageChains(*slots), comp.row


def _bind_leaves(progs: StageChains, V) -> None:
    """Materialize every segment's leaf-matrix slices once per group.
    The segments are replayed M x S times; gathering ``V[adv]`` on
    every call would dominate the vector replay, and the slices are
    call-invariant (only the running time vector changes)."""
    def walk(prog):
        for op in prog.ops:
            cls = op.__class__
            if cls is _Seg:
                op.v_adv = V[op.adv] if len(op.adv) else None
                op.v_bytes = tuple((acc, V[rows])
                                   for acc, rows in op.bytes_ops)
            elif cls is _Par:
                for b in op.branches:
                    walk(b)
            else:
                walk(op.body)
    for slot in progs:
        for prog in slot:
            if prog is not None:
                walk(prog)


# ---------------------------------------------------------------------------
# vector chain evaluation
# ---------------------------------------------------------------------------

class _BatchEval:
    """The vector counterpart of ``_ChainEval``: time is a
    ``(num_configs,)`` float64 vector, intervals are recorded as
    ``(keys, (n, G) start/end)`` chunks, byte counters are per-config
    vectors accumulated in walk order."""

    __slots__ = ("V", "G", "key_chunks", "start_chunks", "end_chunks",
                 "accs", "nodes", "spawned")

    def __init__(self, V, G: int):
        self.V = V                      # (num_leaves, G) leaf matrix
        self.G = G
        self.key_chunks: List = []
        self.start_chunks: List = []
        self.end_chunks: List = []
        self.accs = [_np.zeros(G), _np.zeros(G), _np.zeros(G)]
        self.nodes = 0
        self.spawned: List = []

    def run(self, prog: _Prog, t):
        self.nodes += prog.nodes
        return self._eval(prog.ops, t)

    def _eval(self, ops, t):
        for op in ops:
            cls = op.__class__
            if cls is _Seg:
                k = len(op.adv)
                if k:
                    # strict sequential left fold: P[i+1] = P[i] + x_i,
                    # bit-identical to the scalar t += x chain
                    stack = _np.empty((k + 1, self.G))
                    stack[0] = t
                    stack[1:] = op.v_adv
                    P = _np.add.accumulate(stack, axis=0, out=stack)
                    h = len(op.hold_pos)
                    if h:
                        self.key_chunks.append(op.hold_keys)
                        bounds = P[op.hold_idx]
                        self.start_chunks.append(bounds[:h])
                        self.end_chunks.append(bounds[h:])
                    t = P[k]
                for acc, rows in op.v_bytes:
                    if len(rows) == 1:
                        self.accs[acc] = self.accs[acc] + rows[0]
                    else:
                        bstack = _np.empty((len(rows) + 1, self.G))
                        bstack[0] = self.accs[acc]
                        bstack[1:] = rows
                        _np.add.accumulate(bstack, axis=0, out=bstack)
                        self.accs[acc] = bstack[len(rows)]
            elif cls is _Par:
                branches = op.branches
                if branches:
                    best = self._eval(branches[0].ops, t)
                    for b in branches[1:]:
                        best = _np.maximum(best, self._eval(b.ops, t))
                    t = best
            else:  # _Spawn
                self.spawned.append(self._eval(op.body.ops, t))
        return t


# ---------------------------------------------------------------------------
# group replay (the vectorized mirror of fastpath.replay_chains)
# ---------------------------------------------------------------------------

def _replay_group(sims, progs: StageChains, V, profile: Optional[Dict]):
    """Replay one signature group; returns the per-sim outcome list
    (``(SimResult | None, reason | None)`` in ``sims`` order).

    Structurally this is ``fastpath.replay_chains`` with every float
    replaced by a ``(G,)`` vector; every branch the scalar replay takes
    on float *presence* (mailbox filled or not) is structural, so one
    pass serves the whole group."""
    from .scheduler import SimResult

    sim0 = sims[0]
    G = len(sims)
    S = sim0.mapped.num_stages
    M = sim0.plan.num_microbatches
    training = sim0.plan.training
    collect_timeline = sim0.collect_timeline

    fd_body, fd_post, bd_body, bd_last, bd_post, gu_body = progs

    ev = _BatchEval(V, G)
    work = [list(sim0._work_list(s)) for s in range(S)]
    pos = [0] * S
    zero = _np.zeros(G)
    cursor = [zero] * S                 # entries replaced, never mutated
    prev_row = [-1] * S                 # structural (same row for all configs)
    row_idx: Dict[Tuple[int, int, int], int] = {}
    act = {(0, i): zero for i in range(M)}
    grad: Dict[Tuple[int, int], object] = {}
    fd_done: Dict[Tuple[int, int], object] = {}
    pending: List[List] = [[] for _ in range(S)]
    gu_todo = [training] * S

    # trace rows: structural columns + per-config float/pred columns
    tr_stage: List[int] = []
    tr_kind: List[int] = []
    tr_micro: List[int] = []
    tr_start: List = []
    tr_end: List = []
    tr_pred: List = []                  # scalar int or (G,) int vector

    def rec(s, kind, mb, start, end, pred) -> int:
        tr_stage.append(s)
        tr_kind.append(kind)
        tr_micro.append(mb)
        tr_start.append(start)
        tr_end.append(end)
        tr_pred.append(pred)
        return len(tr_stage) - 1

    progress = True
    while progress:
        progress = False
        for s in range(S):
            while pos[s] < len(work[s]):
                kind, mb = work[s][pos[s]]
                if kind == FD:
                    dep = act.get((s, mb))
                    if dep is None:
                        break
                    t0 = cursor[s]
                    start = _np.maximum(t0, dep)
                    end = ev.run(fd_body[s], start)
                    fd_done[(s, mb)] = end
                    if s > 0:
                        pred = _np.where(dep > t0,
                                         row_idx.get((s - 1, KIND_FD, mb),
                                                     -1),
                                         prev_row[s])
                    else:
                        pred = prev_row[s]
                    r = rec(s, KIND_FD, mb, start, end, pred)
                    row_idx[(s, KIND_FD, mb)] = r
                    prev_row[s] = r
                    if fd_post[s] is not None:
                        t_post = ev.run(fd_post[s], end)
                        act[(s + 1, mb)] = t_post
                        cursor[s] = t_post
                    else:
                        if training:
                            grad[(s, mb)] = end
                        cursor[s] = end
                else:
                    dep = grad.get((s, mb))
                    if dep is None:
                        break
                    t0 = cursor[s]
                    start = _np.maximum(t0, dep)
                    n_sp = len(ev.spawned)
                    body = bd_last[s] if mb == M - 1 else bd_body[s]
                    end = ev.run(body, start)
                    pending[s].extend(ev.spawned[n_sp:])
                    row = (row_idx.get((s, KIND_FD, mb), -1) if s == S - 1
                           else row_idx.get((s + 1, KIND_BD, mb), -1))
                    pred = _np.where(dep > t0, row, prev_row[s])
                    r = rec(s, KIND_BD, mb, start, end, pred)
                    row_idx[(s, KIND_BD, mb)] = r
                    prev_row[s] = r
                    if bd_post[s] is not None:
                        t_post = ev.run(bd_post[s], end)
                        grad[(s - 1, mb)] = t_post
                        cursor[s] = t_post
                    else:
                        cursor[s] = end
                pos[s] += 1
                progress = True
            if pos[s] == len(work[s]) and gu_todo[s]:
                t0 = cursor[s]
                start = t0
                for p in pending[s]:
                    start = _np.maximum(start, p)
                pred = _np.where(start > t0,
                                 row_idx.get((s, KIND_BD, M - 1), -1),
                                 prev_row[s])
                end = ev.run(gu_body[s], start)
                r = rec(s, KIND_GU, 0, start, end, pred)
                row_idx[(s, KIND_GU, 0)] = r
                prev_row[s] = r
                cursor[s] = end
                gu_todo[s] = False
                progress = True

    if any(pos[s] < len(work[s]) for s in range(S)) or any(gu_todo):
        # deadlock is structural: the whole group stalls identically
        return [(None, _STALLED)] * G

    # -- per-config interval validation -------------------------------------
    t_val = perf_counter()
    contended = _np.zeros(G, dtype=bool)
    N = 0
    if ev.key_chunks:
        keys = _np.concatenate(ev.key_chunks)           # (N,) packed lanes
        starts = _np.vstack(ev.start_chunks)            # (N, G)
        ends = _np.vstack(ev.end_chunks)
        N = len(keys)
        if collect_timeline:
            # timeline runs need the full per-config resource rows anyway,
            # so validate off the same flat config-major lexsort that will
            # order the emission: primary key is the config, so rows
            # g*N:(g+1)*N are config g's sorted slice
            cfg = _np.repeat(_np.arange(G), N)
            k_f = _np.tile(keys, G)
            s_f = starts.T.ravel()
            e_f = ends.T.ravel()
            order = _np.lexsort((s_f - e_f, s_f, k_f, cfg))
            cs, ks = cfg[order], k_f[order]
            ss, es = s_f[order], e_f[order]
            bad = ((cs[1:] == cs[:-1]) & (ks[1:] == ks[:-1])
                   & (ss[1:] < es[:-1]))
            contended[cs[1:][bad]] = True
            order2 = _np.lexsort((k_f, s_f, e_f, cfg))
        else:
            # scalar-only runs: per-lane column-wise validation, no (N*G,)
            # scratch arrays. Two stacked *stable* axis-0 argsorts — by
            # (s - e), then by s — reproduce the lexsort's per-config
            # (s, s-e, emission-order) ordering exactly, so the contended
            # verdict is bit-identical to the flat path (and the scalar
            # tier). Rows within a lane block keep emission order because
            # the lane grouping itself is a stable structural sort.
            lane_order = _np.argsort(keys, kind="stable")
            ks = keys[lane_order]
            bounds = _np.flatnonzero(ks[1:] != ks[:-1]) + 1
            blocks = _np.split(lane_order, bounds)
            for rows in blocks:
                if len(rows) < 2:
                    continue
                A = starts[rows]
                B = ends[rows]
                o1 = _np.argsort(A - B, axis=0, kind="stable")
                A1 = _np.take_along_axis(A, o1, axis=0)
                B1 = _np.take_along_axis(B, o1, axis=0)
                o2 = _np.argsort(A1, axis=0, kind="stable")
                A2 = _np.take_along_axis(A1, o2, axis=0)
                B2 = _np.take_along_axis(B1, o2, axis=0)
                contended |= (A2[1:] < B2[:-1]).any(axis=0)
    _padd(profile, "validate_us", (perf_counter() - t_val) * 1e6)

    # -- totals & throughput -------------------------------------------------
    total = cursor[0]
    for s in range(1, S):
        total = _np.maximum(total, cursor[s])
    samples = _np.asarray([sim.plan.global_batch for sim in sims],
                          dtype=_np.float64)
    bad_thpt = _np.zeros(G, dtype=bool)
    with _np.errstate(divide="ignore", invalid="ignore"):
        if training or M <= 1:
            throughput = _np.where(total > 0, samples / total, 0.0)
        else:
            first = fd_done[(S - 1, 0)]
            last = first
            for i in range(1, M):
                v = fd_done[(S - 1, i)]
                first = _np.minimum(first, v)
                last = _np.maximum(last, v)
            throughput = (M - 1) * (samples / M) / (last - first)
            bad_thpt = ~_np.isfinite(throughput)

    # -- per-config SimResults ----------------------------------------------
    R = len(tr_stage)
    stage_col = _np.asarray(tr_stage, dtype=_np.int32)
    kind_col = _np.asarray(tr_kind, dtype=_np.int8)
    micro_col = _np.asarray(tr_micro, dtype=_np.int32)
    res_col = _np.full(R, -1, dtype=_np.int32)
    start_mat = _np.vstack(tr_start) if R else _np.empty((0, G))
    end_mat = _np.vstack(tr_end) if R else _np.empty((0, G))
    pred_mat = (_np.vstack([_np.broadcast_to(
                    _np.asarray(p, dtype=_np.int32), (G,))
                for p in tr_pred])
                if R else _np.empty((0, G), dtype=_np.int32))

    out = []
    for g, sim in enumerate(sims):
        if contended[g]:
            out.append((None, _CONTENDED))
            _padd(profile, "contended_jobs", 1)
            continue
        if bad_thpt[g]:
            out.append((None, "non-finite inference throughput"))
            continue
        st_g = _np.ascontiguousarray(start_mat[:, g])
        en_g = _np.ascontiguousarray(end_mat[:, g])
        pr_g = _np.ascontiguousarray(pred_mat[:, g])
        if collect_timeline and N:
            idx = order2[g * N:(g + 1) * N]
            stv = s_f[idx]
            env = e_f[idx]
            keep = env > stv            # zero-length intervals suppressed
            kk = k_f[idx][keep]
            n_res = len(kk)
            trace = Trace(
                stage=_np.concatenate(
                    [stage_col, _np.full(n_res, -1, dtype=_np.int32)]),
                kind=_np.concatenate(
                    [kind_col, (kk >> 32).astype(_np.int8)]),
                micro=_np.concatenate(
                    [micro_col, _np.full(n_res, -1, dtype=_np.int32)]),
                resource=_np.concatenate(
                    [res_col, (kk & 0xFFFFFFFF).astype(_np.int32)]),
                start=_np.concatenate([st_g, stv[keep]]),
                end=_np.concatenate([en_g, env[keep]]),
                pred=_np.concatenate(
                    [pr_g, _np.full(n_res, -1, dtype=_np.int32)]),
                total_time=float(total[g]), num_stages=S)
        else:
            trace = Trace(stage=stage_col, kind=kind_col, micro=micro_col,
                          resource=res_col, start=st_g, end=en_g,
                          pred=pr_g, total_time=float(total[g]),
                          num_stages=S)
        out.append((SimResult(
            total_time=float(total[g]),
            throughput=float(throughput[g]),
            stage_memory=sim.memory,
            recompute=sim.recompute,
            event_count=ev.nodes,
            noc_bytes=float(ev.accs[0][g] + ev.accs[2][g]),
            dram_bytes=float(ev.accs[1][g]),
            engine="fast",
            trace=trace,
            noc_occupancy_fallback={},
        ), None))
    return out


# ---------------------------------------------------------------------------
# entry point
# ---------------------------------------------------------------------------

def run_fast_batch(sims, *, min_group: int = 2,
                   classify_memo: Optional[Dict] = None,
                   profile: Optional[Dict] = None):
    """Evaluate many simulators through the fast tier, vectorizing
    across configurations that share a chain shape signature.

    Returns one ``(SimResult | None, reason | None)`` pair per input
    sim, in order — exactly the contract of a ``try_fast_run`` per job
    (``None`` result means the caller should fall back to the event
    tier for that job; the reason says why). Results are bit-identical
    to the scalar fast tier. ``min_group`` is the smallest signature
    group worth the vector overhead; smaller groups take the scalar
    replay on their already-compiled chains. ``classify_memo`` and
    ``profile`` are optional caller-owned dicts (classifier cache and
    per-phase timing/count accumulator)."""
    out: List = [None] * len(sims)
    _padd(profile, "jobs", len(sims))

    if _np is None:
        # dependency-free degradation: scalar fast tier per job
        for i, sim in enumerate(sims):
            reason = classify_cached(sim, classify_memo)
            if reason is None:
                result, reason = replay_chains(sim,
                                               compile_stage_chains(sim))
                out[i] = (result, reason)
            else:
                out[i] = (None, reason)
        return out

    t0 = perf_counter()
    groups: Dict[Tuple, List[int]] = {}
    per: List = [None] * len(sims)      # (chains, leaves) for eligible jobs
    for i, sim in enumerate(sims):
        reason = classify_cached(sim, classify_memo)
        if reason is not None:
            out[i] = (None, reason)
            _padd(profile, "ineligible_jobs", 1)
            continue
        chains = compile_stage_chains(sim)
        if getattr(sim.noc, "metrics_levels", False):
            # per-level payload metadata rides as extra chain-node fields
            # the group skeletonizer does not model — the scalar replay
            # preserves it, so fabric jobs with metrics enabled skip
            # signature grouping
            out[i] = replay_chains(sim, chains)
            _padd(profile, "scalar_jobs", 1)
            continue
        sig, leaves = _signature(sim, chains)
        per[i] = (chains, leaves)
        groups.setdefault(sig, []).append(i)
    _padd(profile, "compile_us", (perf_counter() - t0) * 1e6)

    for sig, idxs in groups.items():
        if len(idxs) < min_group:
            _padd(profile, "scalar_jobs", len(idxs))
            for i in idxs:
                out[i] = replay_chains(sims[i], per[i][0])
            continue
        t1 = perf_counter()
        v0 = profile.get("validate_us", 0) if profile is not None else 0
        progs, n_rows = _compile_group(sig[5])
        if n_rows != len(per[idxs[0]][1]):      # pragma: no cover - invariant
            raise AssertionError("leaf row assignment out of sync with "
                                 "skeleton walk")
        V = _np.ascontiguousarray(_np.asarray(
            [per[i][1] for i in idxs], dtype=_np.float64).T)
        _bind_leaves(progs, V)
        results = _replay_group([sims[i] for i in idxs], progs, V, profile)
        for j, i in enumerate(idxs):
            out[i] = results[j]
        dv = ((profile.get("validate_us", 0) - v0)
              if profile is not None else 0)
        _padd(profile, "eval_us", (perf_counter() - t1) * 1e6 - dv)
        _padd(profile, "groups", 1)
        _padd(profile, "batched_jobs", len(idxs))
    return out
