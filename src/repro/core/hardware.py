"""Hardware descriptions for PALM (paper §II-C, §III-C, Tables I & VI).

A :class:`HardwareSpec` is pure data: tile compute/SRAM, NoC topology +
bandwidths, and DRAM channel placement. PALM models a *two-level* tiled
accelerator (tiles composed of cores); the declarative topology specs in
:mod:`repro.core.topology` express both levels (``HierarchicalSpec``) and
compile them into one flattened 2-D core grid whose link bandwidth
depends on whether a hop crosses a tile boundary — faithful to Table VI
while keeping routing uniform.

The hardware layer is declarative end to end: every preset below is
built from a :class:`~repro.core.topology.TopologySpec`, and a
``HardwareSpec`` round-trips losslessly through ``to_dict``/``from_dict``
(and ``to_json``/``from_json``), so machines are data users can dump,
tweak, diff, and sweep (:class:`repro.api.HardwareSearchSpace`).

Presets reproduce the hardware used in the paper's case studies plus the
TPU v5e pod used for the roofline cross-check; ``HARDWARE_PRESETS`` maps
names to builders (parameterized ``a100x<N>`` / ``tpu_v5e_<R>x<C>`` names
are resolved by :func:`repro.api.resolve_hardware`).
"""

from __future__ import annotations

import dataclasses
import json
import math
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence, Tuple

from .topology import (
    GPUCluster,
    GPUClusterSpec,
    HierarchicalSpec,
    Mesh2D,
    MeshSpec,
    Topology,
    TopologySpec,
    Torus2D,
    spec_of,
    topology_spec_from_dict,
)

__all__ = [
    "TileSpec",
    "DRAMSpec",
    "Topology",
    "Mesh2D",
    "Torus2D",
    "GPUCluster",
    "TopologySpec",
    "MeshSpec",
    "GPUClusterSpec",
    "HierarchicalSpec",
    "HardwareSpec",
    "HARDWARE_PRESETS",
    "grayskull",
    "wafer_scale",
    "a100_cluster",
    "tpu_v5e_pod",
    "tiled_cluster",
]

GB = 1e9
MB = 1e6
TFLOPS = 1e12


@dataclass(frozen=True)
class TileSpec:
    """Per-tile (per-core after flattening) compute + SRAM."""

    flops: float                  # peak FLOP/s at the workload precision
    sram_bytes: float             # local SRAM capacity
    compute_efficiency: float = 0.50   # sustained fraction of peak on dense GEMM
    vector_efficiency: float = 0.15    # sustained fraction for memory-bound ops

    def matmul_time(self, flop: float) -> float:
        return flop / (self.flops * self.compute_efficiency)

    def vector_time(self, flop: float) -> float:
        return flop / (self.flops * self.vector_efficiency)

    def to_dict(self) -> Dict[str, Any]:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "TileSpec":
        return cls(**d)


@dataclass(frozen=True)
class DRAMSpec:
    """Edge-shared DRAM (paper §IV-C ❸)."""

    bandwidth: float              # bytes/s per channel
    response_time: float = 1e-7   # seconds, Eq. (4) Response_Time
    channels: int = 1             # number of shared channels (edges)
    capacity_bytes: float = float("inf")  # per-device DRAM capacity (recompute trigger)

    def to_dict(self) -> Dict[str, Any]:
        d = dataclasses.asdict(self)
        # JSON has no Infinity: unbounded capacity serializes as null
        if math.isinf(d["capacity_bytes"]):
            d["capacity_bytes"] = None
        return d

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "DRAMSpec":
        kw = dict(d)
        if kw.get("capacity_bytes") is None:
            kw["capacity_bytes"] = float("inf")
        return cls(**kw)


@dataclass
class HardwareSpec:
    """Complete machine description consumed by the simulator.

    ``topology`` accepts either a compiled :class:`Topology` or a
    declarative :class:`TopologySpec` (which is compiled on construction
    and kept in ``topology_spec`` for serialization). Specs built from a
    spec — including every preset — round-trip through JSON losslessly.
    """

    name: str
    topology: Topology
    tile: TileSpec
    dram: DRAMSpec
    # device ids (after flattening) that host a DRAM port; empty = every
    # device has local HBM (GPU/TPU style, no NoC traversal to reach DRAM).
    dram_ports: Tuple[int, ...] = ()
    precision_bytes: int = 2
    # scale-out fabric (repro.fabric.FabricSpec) replicating the chip
    # described above into a board/node/cluster hierarchy; None = single
    # chip (every existing preset, bit-identical behaviour).
    fabric: Optional[Any] = None
    topology_spec: Optional[TopologySpec] = None
    _port_cache: Dict[int, Optional[int]] = field(
        default_factory=dict, init=False, repr=False, compare=False)

    def __post_init__(self):
        if isinstance(self.topology, TopologySpec):
            self.topology_spec = self.topology
            self.topology = self.topology.compile()
        elif self.topology_spec is None:
            # best effort: recover the spec from known compiled classes so
            # hand-built HardwareSpecs still serialize
            self.topology_spec = spec_of(self.topology)
        self.dram_ports = tuple(self.dram_ports)

    @property
    def num_chips(self) -> int:
        """Chips in the cluster (1 when no fabric is attached)."""
        return self.fabric.num_chips if self.fabric is not None else 1

    @property
    def chip_devices(self) -> int:
        """Devices on one chip (the compiled topology's grid)."""
        return self.topology.num_devices

    @property
    def num_devices(self) -> int:
        """Total devices across the cluster; global device ids are
        ``chip * chip_devices + local``."""
        return self.topology.num_devices * self.num_chips

    def nearest_dram_port(self, device: int) -> Optional[int]:
        if not self.dram_ports:
            return None
        port = self._port_cache.get(device)
        if port is None:
            port = min(self.dram_ports,
                       key=lambda p: self.topology.hops(device, p))
            self._port_cache[device] = port
        return port

    def with_(self, **kw) -> "HardwareSpec":
        if "topology" in kw and "topology_spec" not in kw:
            kw["topology_spec"] = None   # don't carry a stale spec
        return dataclasses.replace(self, **kw)

    # -- serialization -------------------------------------------------------
    def to_dict(self) -> Dict[str, Any]:
        if self.topology_spec is None:
            raise ValueError(
                f"hardware {self.name!r} has a custom {type(self.topology).__name__} "
                "topology with no declarative spec; build it from a TopologySpec "
                "to serialize")
        d = {
            "name": self.name,
            "topology": self.topology_spec.to_dict(),
            "tile": self.tile.to_dict(),
            "dram": self.dram.to_dict(),
            "dram_ports": list(self.dram_ports),
            "precision_bytes": self.precision_bytes,
        }
        if self.fabric is not None:
            d["fabric"] = self.fabric.to_dict()
        return d

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "HardwareSpec":
        fabric = None
        if d.get("fabric") is not None:
            from ..fabric.spec import FabricSpec  # pure data, no cycle

            fabric = FabricSpec.from_dict(d["fabric"])
        try:
            return cls(
                name=d["name"],
                topology=topology_spec_from_dict(d["topology"]),
                tile=TileSpec.from_dict(d["tile"]),
                dram=DRAMSpec.from_dict(d["dram"]),
                dram_ports=tuple(d.get("dram_ports", ())),
                precision_bytes=d.get("precision_bytes", 2),
                fabric=fabric,
            )
        except (KeyError, TypeError) as e:
            raise ValueError(f"bad hardware dict: {e}") from None

    def to_json(self, **kw: Any) -> str:
        return json.dumps(self.to_dict(), **kw)

    @classmethod
    def from_json(cls, s: str) -> "HardwareSpec":
        return cls.from_dict(json.loads(s))


# --------------------------------------------------------------------------
# Presets used by the paper's case studies (all built from declarative
# topology specs, so `HardwareSpec.to_json()` works on every one of them)
# --------------------------------------------------------------------------

def grayskull() -> HardwareSpec:
    """Tenstorrent Grayskull e150 (paper Table I / §V-A3, [40]).

    120 Tensix cores in a 10x12 grid, ~368 int8 TOPS => ~3 TOPS/core,
    ~1 MB SRAM/core (120 MB total), 8 channels LPDDR4 ~100 GB/s aggregate,
    NoC ~192 GB/s per link direction.
    """
    spec = MeshSpec(rows=10, cols=12, intra_bw=192 * GB, link_latency=5e-8)
    # DRAM ports on the top edge (row 0), matching the board's 8 channels.
    ports = tuple(range(0, 12, 2))[:8]
    return HardwareSpec(
        name="grayskull",
        topology=spec,
        tile=TileSpec(flops=3.07 * TFLOPS, sram_bytes=1.0 * MB,
                      compute_efficiency=0.65, vector_efficiency=0.20),
        dram=DRAMSpec(bandwidth=100 * GB / 8, response_time=2e-7, channels=8),
        dram_ports=ports,
        precision_bytes=1,  # published numbers are int8
    )


def wafer_scale() -> HardwareSpec:
    """Paper Table VI wafer-scale config: 5x4 tiles of 4x4 cores.

    256 TFLOPS fp16 + 60 MB SRAM per *tile* => 16 TFLOPS + 3.75 MB per core.
    intra-tile NoC 1024 GB/s, inter-tile 256 GB/s, edge DRAM 256 GB/s/tile.
    """
    spec = HierarchicalSpec(
        tile=MeshSpec(rows=4, cols=4, intra_bw=1024 * GB, link_latency=2e-8),
        grid_rows=5, grid_cols=4, inter_bw=256 * GB)
    mesh = spec.flatten()
    # Edge-shared DRAM: one port per tile-row on both vertical edges.
    dev = lambda r, c: r * mesh.cols + c
    ports = tuple(dev(r, 0) for r in range(0, mesh.rows, 4)) + tuple(
        dev(r, mesh.cols - 1) for r in range(0, mesh.rows, 4))
    return HardwareSpec(
        name="wafer_scale",
        topology=spec,
        tile=TileSpec(flops=16 * TFLOPS, sram_bytes=3.75 * MB,
                      compute_efficiency=0.55, vector_efficiency=0.15),
        dram=DRAMSpec(bandwidth=256 * GB, response_time=3e-7, channels=10),
        dram_ports=ports,
        precision_bytes=2,
    )


def a100_cluster(num_gpus: int, d_model: Optional[int] = None) -> HardwareSpec:
    """Selene-style A100 cluster used for Table IV (Megatron published data).

    312 TFLOP/s bf16 peak. Sustained GEMM efficiency on A100 grows with
    matrix size (cuBLAS: ~52% at K~6k up to ~63% at K~20k — visible in
    Megatron's own per-GPU numbers, 135 TF/s @18B vs 163 TF/s @530B);
    ``d_model`` selects the point on that curve (also reachable from the
    CLI: ``--hardware a100x64 --d-model 12288``). 40 MB L2 as the "SRAM"
    level, 1.94 TB/s HBM2e local to each GPU (no NoC traversal =>
    dram_ports=()).
    """
    if d_model is None:
        eff = 0.52
    else:
        eff = min(0.65, max(0.45, 0.475 + 7.3e-6 * d_model))
    return HardwareSpec(
        name=f"a100x{num_gpus}",
        topology=GPUClusterSpec(num_gpus=num_gpus),
        tile=TileSpec(flops=312 * TFLOPS, sram_bytes=40 * MB,
                      compute_efficiency=eff, vector_efficiency=0.10),
        dram=DRAMSpec(bandwidth=1.94e12, response_time=1e-7, channels=num_gpus,
                      capacity_bytes=80e9),
        dram_ports=(),
        precision_bytes=2,
    )


def tpu_v5e_pod(rows: int = 16, cols: int = 16,
                torus: bool = False) -> HardwareSpec:
    """TPU v5e pod slice for the roofline cross-check (see DESIGN.md §3).

    197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s per ICI link. The real pod
    ICI is a 2-D torus; the default models it as a mesh (simulator routes
    are upper bounds on torus), ``torus=True`` adds the wraparound links
    (preset name ``tpu_v5e_torus`` / ``tpu_v5e_torus_<R>x<C>``).
    """
    spec = MeshSpec(rows=rows, cols=cols, intra_bw=50 * GB, link_latency=1e-6,
                    torus=torus)
    return HardwareSpec(
        name=f"tpu_v5e{'_torus' if torus else ''}_{rows}x{cols}",
        topology=spec,
        tile=TileSpec(flops=197 * TFLOPS, sram_bytes=128 * MB,
                      compute_efficiency=0.55, vector_efficiency=0.12),
        dram=DRAMSpec(bandwidth=819 * GB, response_time=1e-7, channels=rows * cols),
        dram_ports=(),
        precision_bytes=2,
    )


def tiled_cluster() -> HardwareSpec:
    """Four-chip cluster: 2 boards x 2 chips, each chip a 4x4 tiled
    accelerator with local HBM-style DRAM. The acceptance machine for the
    fabric subsystem — dp gradient all-reduces span chips and decompose
    into NoC legs + board/node fabric legs (hierarchical by default)."""
    from ..fabric.spec import cluster_2x2  # pure data, no cycle

    spec = MeshSpec(rows=4, cols=4, intra_bw=512 * GB, link_latency=2e-8)
    return HardwareSpec(
        name="tiled_cluster",
        topology=spec,
        tile=TileSpec(flops=16 * TFLOPS, sram_bytes=3.75 * MB,
                      compute_efficiency=0.55, vector_efficiency=0.15),
        dram=DRAMSpec(bandwidth=256 * GB, response_time=2e-7, channels=16),
        dram_ports=(),
        precision_bytes=2,
        fabric=cluster_2x2(),
    )


def tpu_v5e_torus_pod(rows: int = 16, cols: int = 16) -> HardwareSpec:
    """The tpu_v5e pod on the wraparound-ICI topology (MeshSpec torus)."""
    return tpu_v5e_pod(rows, cols, torus=True)


# name -> zero-arg builder; parameterized families (a100x<N>,
# tpu_v5e_<R>x<C>, tpu_v5e_torus_<R>x<C>) are parsed by
# repro.api.resolve_hardware on top of this registry.
HARDWARE_PRESETS = {
    "grayskull": grayskull,
    "wafer_scale": wafer_scale,
    "tpu_v5e": tpu_v5e_pod,
    "tpu_v5e_torus": tpu_v5e_torus_pod,
    "tiled_cluster": tiled_cluster,
}
