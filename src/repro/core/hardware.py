"""Hardware descriptions for PALM (paper §II-C, §III-C, Tables I & VI).

A :class:`HardwareSpec` is pure data: tile compute/SRAM, NoC topology +
bandwidths, and DRAM channel placement. PALM models a *two-level* tiled
accelerator (tiles composed of cores); we flatten both levels into one 2-D
grid of *cores* whose link bandwidth depends on whether a hop crosses a tile
boundary — faithful to Table VI while keeping routing uniform.

Topologies are pluggable because the paper validates against a GPU cluster
("we replace the underlying 2D topology of PALM with GPU topology", §V-A2):

* :class:`Mesh2D`       — X-Y dimension-ordered routing on a 2-D mesh.
* :class:`GPUCluster`   — two-level fat topology: GPUs under a node switch
  (NVLink/NVSwitch), nodes under a cluster switch (IB NICs).

Presets at the bottom reproduce the hardware used in the paper's case
studies plus the TPU v5e pod used for the roofline cross-check.
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

__all__ = [
    "TileSpec",
    "DRAMSpec",
    "Topology",
    "Mesh2D",
    "GPUCluster",
    "HardwareSpec",
    "grayskull",
    "wafer_scale",
    "a100_cluster",
    "tpu_v5e_pod",
]

GB = 1e9
MB = 1e6
TFLOPS = 1e12


@dataclass(frozen=True)
class TileSpec:
    """Per-tile (per-core after flattening) compute + SRAM."""

    flops: float                  # peak FLOP/s at the workload precision
    sram_bytes: float             # local SRAM capacity
    compute_efficiency: float = 0.50   # sustained fraction of peak on dense GEMM
    vector_efficiency: float = 0.15    # sustained fraction for memory-bound ops

    def matmul_time(self, flop: float) -> float:
        return flop / (self.flops * self.compute_efficiency)

    def vector_time(self, flop: float) -> float:
        return flop / (self.flops * self.vector_efficiency)


@dataclass(frozen=True)
class DRAMSpec:
    """Edge-shared DRAM (paper §IV-C ❸)."""

    bandwidth: float              # bytes/s per channel
    response_time: float = 1e-7   # seconds, Eq. (4) Response_Time
    channels: int = 1             # number of shared channels (edges)
    capacity_bytes: float = float("inf")  # per-device DRAM capacity (recompute trigger)


class Topology:
    """Routing interface: a topology enumerates directed links and routes."""

    num_devices: int

    def route(self, src: int, dst: int) -> List[int]:
        """Return the list of link ids traversed from ``src`` to ``dst``."""
        raise NotImplementedError

    def num_links(self) -> int:
        raise NotImplementedError

    def link_bandwidth(self, link_id: int) -> float:
        raise NotImplementedError

    def link_latency(self, link_id: int) -> float:
        raise NotImplementedError

    def hops(self, src: int, dst: int) -> int:
        return len(self.route(src, dst))

    def coords(self, device: int) -> Tuple[int, int]:
        raise NotImplementedError


class Mesh2D(Topology):
    """2-D mesh with X-Y dimension-ordered routing.

    Two-level bandwidth: a hop whose endpoints lie in different *tiles*
    (``tile_shape`` groups of cores) uses ``inter_bw``; hops inside a tile
    use ``intra_bw``. With ``tile_shape=(1,1)`` it degenerates to a flat
    mesh (Grayskull-style single-level).
    """

    def __init__(
        self,
        rows: int,
        cols: int,
        intra_bw: float,
        inter_bw: Optional[float] = None,
        link_latency: float = 5e-8,
        tile_shape: Tuple[int, int] = (1, 1),
    ):
        self.rows, self.cols = rows, cols
        self.num_devices = rows * cols
        self.intra_bw = intra_bw
        self.inter_bw = intra_bw if inter_bw is None else inter_bw
        self._latency = link_latency
        self.tile_shape = tile_shape
        # link id layout: horizontal links then vertical links, both directed.
        #   h-link (r, c, dir): between (r,c) and (r,c+1); dir 0 = east, 1 = west
        #   v-link (r, c, dir): between (r,c) and (r+1,c); dir 0 = south, 1 = north
        self._num_h = rows * (cols - 1) * 2
        self._num_v = (rows - 1) * cols * 2

    # -- indexing -----------------------------------------------------------
    def device(self, r: int, c: int) -> int:
        return r * self.cols + c

    def coords(self, device: int) -> Tuple[int, int]:
        return divmod(device, self.cols)

    def _h_link(self, r: int, c: int, westward: bool) -> int:
        return (r * (self.cols - 1) + c) * 2 + int(westward)

    def _v_link(self, r: int, c: int, northward: bool) -> int:
        return self._num_h + (r * self.cols + c) * 2 + int(northward)

    def num_links(self) -> int:
        return self._num_h + self._num_v

    # -- routing --------------------------------------------------------------
    def route(self, src: int, dst: int) -> List[int]:
        (r0, c0), (r1, c1) = self.coords(src), self.coords(dst)
        links: List[int] = []
        c = c0
        while c < c1:
            links.append(self._h_link(r0, c, westward=False))
            c += 1
        while c > c1:
            links.append(self._h_link(r0, c - 1, westward=True))
            c -= 1
        r = r0
        while r < r1:
            links.append(self._v_link(r, c1, northward=False))
            r += 1
        while r > r1:
            links.append(self._v_link(r - 1, c1, northward=True))
            r -= 1
        return links

    # -- link properties -------------------------------------------------------
    def _link_endpoints(self, link_id: int) -> Tuple[Tuple[int, int], Tuple[int, int]]:
        if link_id < self._num_h:
            base, westward = divmod(link_id, 2)
            r, c = divmod(base, self.cols - 1)
            return (r, c), (r, c + 1)
        base, northward = divmod(link_id - self._num_h, 2)
        r, c = divmod(base, self.cols)
        return (r, c), (r + 1, c)

    def link_bandwidth(self, link_id: int) -> float:
        (r0, c0), (r1, c1) = self._link_endpoints(link_id)
        tr, tc = self.tile_shape
        same_tile = (r0 // tr == r1 // tr) and (c0 // tc == c1 // tc)
        return self.intra_bw if same_tile else self.inter_bw

    def link_latency(self, link_id: int) -> float:
        return self._latency


class GPUCluster(Topology):
    """Two-level GPU cluster: node switch (NVLink) + cluster switch (IB).

    Link ids: for each GPU g, links ``2g`` (up to node switch) and ``2g+1``
    (down). For each node n, links ``2G + 2n`` (node up to cluster) and
    ``2G + 2n + 1`` (down). Intra-node routes use only NVLink up/down;
    inter-node routes traverse NVLink up, NIC up, NIC down, NVLink down.
    """

    def __init__(
        self,
        num_gpus: int,
        gpus_per_node: int = 8,
        nvlink_bw: float = 300 * GB,     # A100 NVLink3 per direction
        nic_bw: float = 25 * GB,         # 8x200Gb/s HDR per node / 8 GPUs
        nvlink_latency: float = 2e-6,
        nic_latency: float = 5e-6,
    ):
        self.num_devices = num_gpus
        self.gpus_per_node = gpus_per_node
        self.num_nodes = (num_gpus + gpus_per_node - 1) // gpus_per_node
        self.nvlink_bw, self.nic_bw = nvlink_bw, nic_bw
        self._nv_lat, self._nic_lat = nvlink_latency, nic_latency

    def coords(self, device: int) -> Tuple[int, int]:
        return divmod(device, self.gpus_per_node)  # (node, local rank)

    def num_links(self) -> int:
        return 2 * self.num_devices + 2 * self.num_nodes

    def route(self, src: int, dst: int) -> List[int]:
        if src == dst:
            return []
        n_src, n_dst = src // self.gpus_per_node, dst // self.gpus_per_node
        if n_src == n_dst:
            return [2 * src, 2 * dst + 1]
        base = 2 * self.num_devices
        return [2 * src, base + 2 * n_src, base + 2 * n_dst + 1, 2 * dst + 1]

    def link_bandwidth(self, link_id: int) -> float:
        if link_id < 2 * self.num_devices:
            return self.nvlink_bw
        return self.nic_bw * self.gpus_per_node  # node NIC aggregate

    def link_latency(self, link_id: int) -> float:
        return self._nv_lat if link_id < 2 * self.num_devices else self._nic_lat


@dataclass
class HardwareSpec:
    """Complete machine description consumed by the simulator."""

    name: str
    topology: Topology
    tile: TileSpec
    dram: DRAMSpec
    # device ids (after flattening) that host a DRAM port; empty = every
    # device has local HBM (GPU/TPU style, no NoC traversal to reach DRAM).
    dram_ports: Tuple[int, ...] = ()
    precision_bytes: int = 2

    @property
    def num_devices(self) -> int:
        return self.topology.num_devices

    def nearest_dram_port(self, device: int) -> Optional[int]:
        if not self.dram_ports:
            return None
        return min(self.dram_ports, key=lambda p: self.topology.hops(device, p))

    def with_(self, **kw) -> "HardwareSpec":
        return dataclasses.replace(self, **kw)


# --------------------------------------------------------------------------
# Presets used by the paper's case studies
# --------------------------------------------------------------------------

def grayskull() -> HardwareSpec:
    """Tenstorrent Grayskull e150 (paper Table I / §V-A3, [40]).

    120 Tensix cores in a 10x12 grid, ~368 int8 TOPS => ~3 TOPS/core,
    ~1 MB SRAM/core (120 MB total), 8 channels LPDDR4 ~100 GB/s aggregate,
    NoC ~192 GB/s per link direction.
    """
    topo = Mesh2D(10, 12, intra_bw=192 * GB, link_latency=5e-8)
    # DRAM ports on the top edge (row 0), matching the board's 8 channels.
    ports = tuple(range(0, 12, 2))[:8]
    return HardwareSpec(
        name="grayskull",
        topology=topo,
        tile=TileSpec(flops=3.07 * TFLOPS, sram_bytes=1.0 * MB,
                      compute_efficiency=0.65, vector_efficiency=0.20),
        dram=DRAMSpec(bandwidth=100 * GB / 8, response_time=2e-7, channels=8),
        dram_ports=ports,
        precision_bytes=1,  # published numbers are int8
    )


def wafer_scale() -> HardwareSpec:
    """Paper Table VI wafer-scale config: 5x4 tiles of 4x4 cores.

    256 TFLOPS fp16 + 60 MB SRAM per *tile* => 16 TFLOPS + 3.75 MB per core.
    intra-tile NoC 1024 GB/s, inter-tile 256 GB/s, edge DRAM 256 GB/s/tile.
    """
    topo = Mesh2D(5 * 4, 4 * 4, intra_bw=1024 * GB, inter_bw=256 * GB,
                  link_latency=2e-8, tile_shape=(4, 4))
    # Edge-shared DRAM: one port per tile-row on both vertical edges.
    ports = tuple(topo.device(r, 0) for r in range(0, 20, 4)) + tuple(
        topo.device(r, 15) for r in range(0, 20, 4))
    return HardwareSpec(
        name="wafer_scale",
        topology=topo,
        tile=TileSpec(flops=16 * TFLOPS, sram_bytes=3.75 * MB,
                      compute_efficiency=0.55, vector_efficiency=0.15),
        dram=DRAMSpec(bandwidth=256 * GB, response_time=3e-7, channels=10),
        dram_ports=ports,
        precision_bytes=2,
    )


def a100_cluster(num_gpus: int, d_model: Optional[int] = None) -> HardwareSpec:
    """Selene-style A100 cluster used for Table IV (Megatron published data).

    312 TFLOP/s bf16 peak. Sustained GEMM efficiency on A100 grows with
    matrix size (cuBLAS: ~52% at K~6k up to ~63% at K~20k — visible in
    Megatron's own per-GPU numbers, 135 TF/s @18B vs 163 TF/s @530B);
    ``d_model`` selects the point on that curve. 40 MB L2 as the "SRAM"
    level, 1.94 TB/s HBM2e local to each GPU (no NoC traversal =>
    dram_ports=()).
    """
    if d_model is None:
        eff = 0.52
    else:
        eff = min(0.65, max(0.45, 0.475 + 7.3e-6 * d_model))
    return HardwareSpec(
        name=f"a100x{num_gpus}",
        topology=GPUCluster(num_gpus),
        tile=TileSpec(flops=312 * TFLOPS, sram_bytes=40 * MB,
                      compute_efficiency=eff, vector_efficiency=0.10),
        dram=DRAMSpec(bandwidth=1.94e12, response_time=1e-7, channels=num_gpus,
                      capacity_bytes=80e9),
        dram_ports=(),
        precision_bytes=2,
    )


def tpu_v5e_pod(rows: int = 16, cols: int = 16) -> HardwareSpec:
    """TPU v5e pod slice for the roofline cross-check (see DESIGN.md §3).

    197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s per ICI link, 2-D torus
    (modelled as a mesh — simulator routes are upper bounds on torus).
    """
    topo = Mesh2D(rows, cols, intra_bw=50 * GB, link_latency=1e-6)
    return HardwareSpec(
        name=f"tpu_v5e_{rows}x{cols}",
        topology=topo,
        tile=TileSpec(flops=197 * TFLOPS, sram_bytes=128 * MB,
                      compute_efficiency=0.55, vector_efficiency=0.12),
        dram=DRAMSpec(bandwidth=819 * GB, response_time=1e-7, channels=rows * cols),
        dram_ports=(),
        precision_bytes=2,
    )
