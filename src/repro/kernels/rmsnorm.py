"""Fused RMSNorm Pallas kernel (row-tiled, fp32 statistics in-register).

Small but ubiquitous: every block runs 2-4 norms; fusing the square-mean,
rsqrt and scale into one VMEM pass removes two HBM round-trips per call.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rmsnorm_kernel(x_ref, w_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)               # [bt, H]
    w = w_ref[...].astype(jnp.float32)               # [H]
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    o_ref[...] = (x * jax.lax.rsqrt(var + eps) * w[None, :]).astype(o_ref.dtype)


def rmsnorm_pallas(
    x: jax.Array,      # [T, H]
    w: jax.Array,      # [H]
    *,
    eps: float = 1e-5,
    block_rows: int = 256,
    interpret: bool = False,
) -> jax.Array:
    T, H = x.shape
    T_pad = math.ceil(T / block_rows) * block_rows
    if T_pad != T:
        x = jnp.pad(x, ((0, T_pad - T), (0, 0)))
    out = pl.pallas_call(
        functools.partial(_rmsnorm_kernel, eps=eps),
        grid=(T_pad // block_rows,),
        in_specs=[
            pl.BlockSpec((block_rows, H), lambda i: (i, 0)),
            pl.BlockSpec((H,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_rows, H), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((T_pad, H), x.dtype),
        interpret=interpret,
    )(x, w)
    return out[:T]
