"""Pallas TPU kernels for the model zoo's compute hot spots.

Each kernel ships three artifacts (per the repo convention):
``<name>.py`` (pl.pallas_call + BlockSpec), ``ops.py`` (jit wrapper),
``ref.py`` (pure-jnp oracle used by the allclose sweeps in tests/).
"""

from .ops import flash_attention, rmsnorm, ssd_scan
from . import ref

__all__ = ["flash_attention", "rmsnorm", "ssd_scan", "ref"]
