"""jit'd public wrappers around the Pallas kernels.

``interpret`` defaults to True off-TPU (this container is CPU-only; the
kernels target TPU — see DESIGN.md §3). On TPU backends the flag drops to
False automatically and the same call sites run the compiled kernels.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .flash_attention import flash_attention as _flash
from .rmsnorm import rmsnorm_pallas as _rmsnorm
from .ssd_scan import ssd_scan_pallas as _ssd


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


@partial(jax.jit, static_argnames=("causal", "window", "block_q", "block_k", "interpret"))
def flash_attention(q, k, v, *, causal=True, window=0, block_q=128, block_k=128,
                    interpret=None):
    interpret = _default_interpret() if interpret is None else interpret
    return _flash(q, k, v, causal=causal, window=window,
                  block_q=block_q, block_k=block_k, interpret=interpret)


@partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan(x, dt, A, Bm, Cm, *, chunk=256, interpret=None):
    interpret = _default_interpret() if interpret is None else interpret
    return _ssd(x, dt, A, Bm, Cm, chunk=chunk, interpret=interpret)


@partial(jax.jit, static_argnames=("eps", "block_rows", "interpret"))
def rmsnorm(x, w, *, eps=1e-5, block_rows=256, interpret=None):
    interpret = _default_interpret() if interpret is None else interpret
    return _rmsnorm(x, w, eps=eps, block_rows=block_rows, interpret=interpret)
