"""Mamba2 SSD (state-space duality) chunked scan as a Pallas TPU kernel.

TPU-native adaptation (DESIGN.md §3): the SSD block decomposition maps
naturally onto the MXU — the intra-chunk term is a [Q,Q]x[Q,hp] masked
matmul and the inter-chunk term a rank-N state contraction. The grid is
(batch, head, chunk) with the chunk axis innermost-sequential; the
[hp, N] fp32 running state lives in VMEM scratch across grid steps (the
same carry pattern as flash attention's (m, l, acc)).

Padding note: S is padded to a chunk multiple with dt = 0, which makes
padded tokens exact no-ops in the recurrence (decay 1, update 0), so no
tail masking is needed.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(a_ref, x_ref, dt_ref, b_ref, c_ref, y_ref, state_scr, *,
                chunk: int):
    c_idx = pl.program_id(2)

    @pl.when(c_idx == 0)
    def _init():
        state_scr[...] = jnp.zeros_like(state_scr)

    A = a_ref[0]                                     # scalar decay rate (f32)
    x = x_ref[0, 0].astype(jnp.float32)              # [Q, hp]
    dt = dt_ref[0, 0].astype(jnp.float32)            # [Q]
    Bm = b_ref[0].astype(jnp.float32)                # [Q, N]
    Cm = c_ref[0].astype(jnp.float32)                # [Q, N]

    a = dt * A                                       # [Q] log decay
    a_cs = jnp.cumsum(a)                             # [Q]

    # intra-chunk (attention form): scores[i,j] = C_i.B_j exp(acs_i-acs_j) dt_j, j<=i
    diff = a_cs[:, None] - a_cs[None, :]
    tri = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0) >= \
        jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    L = jnp.where(tri, jnp.exp(diff), 0.0)           # [Q, Q]
    cb = jax.lax.dot_general(Cm, Bm, (((1,), (1,)), ((), ())))  # [Q, Q]
    scores = cb * L * dt[None, :]
    y = jax.lax.dot(scores, x)                       # [Q, hp]

    # inter-chunk: y_i += (C_i . h_prev) * exp(acs_i)
    state = state_scr[...]                           # [hp, N]
    y += jax.lax.dot_general(Cm, state, (((1,), (1,)), ((), ()))) * \
        jnp.exp(a_cs)[:, None]

    # state update: h <- exp(sum a) h + sum_j exp(acs_last-acs_j) dt_j x_j B_j^T
    w = jnp.exp(a_cs[-1] - a_cs) * dt                # [Q]
    upd = jax.lax.dot_general(x, Bm * w[:, None], (((0,), (0,)), ((), ())))  # [hp,N]
    state_scr[...] = state * jnp.exp(a_cs[-1]) + upd

    y_ref[0, 0] = y.astype(y_ref.dtype)


def ssd_scan_pallas(
    x: jax.Array,      # [B, nh, S, hp]
    dt: jax.Array,     # [B, nh, S]   (already softplus-ed)
    A: jax.Array,      # [nh]         (negative)
    Bm: jax.Array,     # [B, S, N]    (shared across heads)
    Cm: jax.Array,     # [B, S, N]
    *,
    chunk: int = 256,
    interpret: bool = False,
) -> jax.Array:
    B, nh, S, hp = x.shape
    N = Bm.shape[-1]
    S_pad = math.ceil(S / chunk) * chunk
    if S_pad != S:
        x = jnp.pad(x, ((0, 0), (0, 0), (0, S_pad - S), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, 0), (0, S_pad - S)))   # dt=0 => exact no-op
        Bm = jnp.pad(Bm, ((0, 0), (0, S_pad - S), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, S_pad - S), (0, 0)))
    nc = S_pad // chunk

    out = pl.pallas_call(
        functools.partial(_ssd_kernel, chunk=chunk),
        grid=(B, nh, nc),
        in_specs=[
            pl.BlockSpec((1,), lambda b, h, c: (h,)),
            pl.BlockSpec((1, 1, chunk, hp), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, 1, chunk), lambda b, h, c: (b, h, c)),
            pl.BlockSpec((1, chunk, N), lambda b, h, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, N), lambda b, h, c: (b, c, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, chunk, hp), lambda b, h, c: (b, h, c, 0)),
        out_shape=jax.ShapeDtypeStruct((B, nh, S_pad, hp), x.dtype),
        scratch_shapes=[pltpu.VMEM((hp, N), jnp.float32)],
        interpret=interpret,
    )(A.astype(jnp.float32), x, dt, Bm, Cm)
    return out[:, :, :S]
