"""Pure-jnp oracles for every Pallas kernel (the per-kernel allclose
reference demanded by the test suite). Layouts match the kernel entry
points exactly (head-major attention, [T,H] rmsnorm)."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def flash_attention_ref(q, k, v, *, causal=True, window=0):
    """q: [B,nh,S,hd]; k,v: [B,nkv,S,hd] -> [B,nh,S,hd]. Naive softmax."""
    B, nh, S, hd = q.shape
    nkv = k.shape[1]
    g = nh // nkv
    qg = q.reshape(B, nkv, g, S, hd).astype(jnp.float32)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    scores = jnp.einsum("bkgqh,bksh->bkgqs", qg, kf) * hd ** -0.5
    rows = jnp.arange(S)[:, None]
    cols = jnp.arange(S)[None, :]
    mask = jnp.ones((S, S), dtype=bool)
    if causal:
        mask &= cols <= rows
    if window > 0:
        mask &= cols > rows - window
    scores = jnp.where(mask, scores, -jnp.inf)
    probs = jax.nn.softmax(scores, axis=-1)
    probs = jnp.where(jnp.isnan(probs), 0.0, probs)
    out = jnp.einsum("bkgqs,bksh->bkgqh", probs, vf)
    return out.reshape(B, nh, S, hd).astype(q.dtype)


def ssd_scan_ref(x, dt, A, Bm, Cm):
    """Sequential SSD recurrence. x: [B,nh,S,hp]; dt: [B,nh,S]; A: [nh];
    Bm/Cm: [B,S,N] -> [B,nh,S,hp]. O(S) scan, fp32 state."""
    B, nh, S, hp = x.shape
    N = Bm.shape[-1]
    f32 = jnp.float32

    def step(state, inp):
        x_t, dt_t, b_t, c_t = inp                     # [B,nh,hp],[B,nh],[B,N],[B,N]
        dec = jnp.exp(dt_t.astype(f32) * A.astype(f32))
        upd = jnp.einsum("bh,bhp,bn->bhpn", dt_t.astype(f32), x_t.astype(f32),
                         b_t.astype(f32))
        state = state * dec[..., None, None] + upd
        y = jnp.einsum("bn,bhpn->bhp", c_t.astype(f32), state)
        return state, y

    init = jnp.zeros((B, nh, hp, N), f32)
    xs = (jnp.moveaxis(x, 2, 0), jnp.moveaxis(dt, 2, 0),
          jnp.moveaxis(Bm, 1, 0), jnp.moveaxis(Cm, 1, 0))
    _, ys = jax.lax.scan(step, init, xs)
    return jnp.moveaxis(ys, 0, 2).astype(x.dtype)


def rmsnorm_ref(x, w, eps=1e-5):
    """x: [T,H]; w: [H]."""
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * w.astype(jnp.float32)[None, :]).astype(x.dtype)
