"""Flash attention (causal GQA, optional sliding window) as a Pallas TPU
kernel.

TPU-native adaptation (DESIGN.md §3): online-softmax accumulation in fp32
VMEM scratch, MXU-aligned tiles (block_q x block_k multiples of 128 on
the lane dim), grid (batch, q_head, q_block, kv_block) with the kv_block
axis innermost-sequential so the (m, l, acc) carry lives in scratch
across grid steps. GQA is expressed in the K/V index_map (q head ->
kv head = h * n_kv // n_heads) so KV tiles are fetched once per group —
no repeated-KV materialisation in HBM.

Fully-masked tiles are skipped via ``pl.when`` (causal upper triangle and
tiles beyond the sliding window), which is where the sub-quadratic win
for window archs (hymba) comes from.
"""

from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  block_q: int, block_k: int, seq_len: int, causal: bool,
                  window: int, num_k_blocks: int):
    iq = pl.program_id(2)
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_start = iq * block_q
    k_start = ik * block_k

    # tile-level skip: strictly-future tiles (causal) / expired tiles (window)
    live = True
    if causal:
        live = k_start <= q_start + block_q - 1
    if window > 0:
        live = jnp.logical_and(live, k_start + block_k - 1 > q_start - window)

    @pl.when(live)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)          # [bq, hd]
        k = k_ref[0, 0].astype(jnp.float32)          # [bk, hd]
        v = v_ref[0, 0].astype(jnp.float32)          # [bk, hd]
        scale = q.shape[-1] ** -0.5
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ()))) * scale  # [bq,bk]

        rows = q_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
        cols = k_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
        mask = jnp.ones((block_q, block_k), dtype=bool)
        if causal:
            mask &= cols <= rows
        if window > 0:
            mask &= cols > rows - window
        mask &= cols < seq_len
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_scr[...]                          # [bq, 1]
        m_cur = jnp.max(s, axis=-1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)                       # [bq, bk]
        correction = jnp.exp(m_prev - m_new)         # [bq, 1]
        l_scr[...] = l_scr[...] * correction + jnp.sum(p, axis=-1, keepdims=True)
        acc_scr[...] = acc_scr[...] * correction + jax.lax.dot(p, v)
        m_scr[...] = m_new

    @pl.when(ik == num_k_blocks - 1)
    def _finalize():
        l = l_scr[...]
        safe = jnp.where(l == 0.0, 1.0, l)           # fully-masked rows -> 0
        o_ref[0, 0] = (acc_scr[...] / safe).astype(o_ref.dtype)


def flash_attention(
    q: jax.Array,              # [B, nh, S, hd]
    k: jax.Array,              # [B, nkv, S, hd]
    v: jax.Array,              # [B, nkv, S, hd]
    *,
    causal: bool = True,
    window: int = 0,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = False,
) -> jax.Array:
    B, nh, S, hd = q.shape
    nkv = k.shape[1]
    assert nh % nkv == 0, (nh, nkv)

    # pad S to tile multiples (mask handles the tail)
    blk = max(block_q, block_k)
    S_pad = math.ceil(S / blk) * blk
    if S_pad != S:
        pad = ((0, 0), (0, 0), (0, S_pad - S), (0, 0))
        q, k, v = (jnp.pad(t, pad) for t in (q, k, v))

    nq = S_pad // block_q
    nk = S_pad // block_k

    kernel = functools.partial(
        _flash_kernel, block_q=block_q, block_k=block_k, seq_len=S,
        causal=causal, window=window, num_k_blocks=nk)

    out = pl.pallas_call(
        kernel,
        grid=(B, nh, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, hd), lambda b, h, iq, ik: (b, h, iq, 0)),
            pl.BlockSpec((1, 1, block_k, hd),
                         lambda b, h, iq, ik: (b, h * nkv // nh, ik, 0)),
            pl.BlockSpec((1, 1, block_k, hd),
                         lambda b, h, iq, ik: (b, h * nkv // nh, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, hd), lambda b, h, iq, ik: (b, h, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((B, nh, S_pad, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),   # running max m
            pltpu.VMEM((block_q, 1), jnp.float32),   # running denom l
            pltpu.VMEM((block_q, hd), jnp.float32),  # output accumulator
        ],
        interpret=interpret,
    )(q, k, v)
    return out[:, :, :S]
