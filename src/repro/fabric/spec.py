"""Declarative multi-level scale-out fabric — the chip→board→node→cluster
hierarchy PALM's single-chip model plugs into.

A :class:`FabricSpec` stacks :class:`FabricLevel` entries innermost-first
(board, then node, then cluster, ...). Each level is a switch tier: every
child instance at that level owns one up-link and one down-link to its
parent switch with the level's bandwidth/latency (GPUCluster-style
switched links, see ``repro.core.topology.GPUCluster``). Chips are the
leaves; a chip's id decomposes in mixed radix over the level degrees, so
routing between two chips is "climb to the lowest common ancestor level,
descend" and the traversed link ids are pure arithmetic.

Like :class:`~repro.core.hardware.HardwareSpec`, a fabric is *data*: it
round-trips losslessly through ``to_dict``/``from_dict`` (and
``to_json``/``from_json``), so cluster designs can be dumped, tweaked,
diffed, and swept (``HardwareSearchSpace.fabric_bw``).

This module is import-cycle-free by construction: it depends on nothing
from ``repro.core`` (the event-compiling half lives in
``repro.fabric.model``).
"""

from __future__ import annotations

import dataclasses
import json
import math
from dataclasses import dataclass
from typing import Any, Dict, List, Tuple

__all__ = [
    "FabricLevel",
    "FabricSpec",
    "fabric_spec_from_dict",
    "FABRIC_PRESETS",
    "board_pair",
    "cluster_2x2",
    "rack_2x2x2",
]

GB = 1e9

# per-level leg algorithms (reduce-scatter/all-gather flavors) and
# cross-chip all-reduce families understood by repro.fabric.model
LEVEL_ALGORITHMS = ("ring", "tree", "hd")
COLLECTIVE_FAMILIES = ("hierarchical", "ring", "tree", "hd")


@dataclass(frozen=True)
class FabricLevel:
    """One switch tier of the scale-out hierarchy.

    ``degree`` children hang off each switch at this level; every child
    has one up-link and one down-link of ``bandwidth`` bytes/s and
    ``latency`` seconds. ``algorithm`` picks the reduce-scatter /
    all-gather flavor hierarchical collectives use *at this level*
    (``ring`` | ``tree`` | ``hd`` halving-doubling).
    """

    name: str
    degree: int
    bandwidth: float          # bytes/s per up/down link
    latency: float = 1e-6     # seconds per link traversal
    algorithm: str = "ring"

    def __post_init__(self):
        if self.degree < 1:
            raise ValueError(f"level {self.name!r}: degree must be >= 1")
        if self.bandwidth <= 0:
            raise ValueError(f"level {self.name!r}: bandwidth must be > 0")
        if self.latency < 0:
            raise ValueError(f"level {self.name!r}: latency must be >= 0")
        if self.algorithm not in LEVEL_ALGORITHMS:
            raise ValueError(
                f"level {self.name!r}: unknown algorithm "
                f"{self.algorithm!r}; known: {', '.join(LEVEL_ALGORITHMS)}")

    def to_dict(self) -> Dict[str, Any]:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "FabricLevel":
        try:
            return cls(**d)
        except TypeError as e:
            raise ValueError(f"bad fabric level dict: {e}") from None


@dataclass(frozen=True)
class FabricSpec:
    """Multi-level fabric: levels innermost-first, chips as leaves.

    ``collective`` is the cross-chip all-reduce family: ``hierarchical``
    (per-level reduce-scatter up / all-gather down, the payload shrinking
    by the participant count at every level) or a flat ``ring`` / ``tree``
    / ``hd`` over all chips.
    """

    levels: Tuple[FabricLevel, ...]
    collective: str = "hierarchical"
    name: str = "fabric"

    def __post_init__(self):
        object.__setattr__(self, "levels", tuple(self.levels))
        if not self.levels:
            raise ValueError("a FabricSpec needs at least one level")
        if self.collective not in COLLECTIVE_FAMILIES:
            raise ValueError(
                f"unknown collective family {self.collective!r}; known: "
                f"{', '.join(COLLECTIVE_FAMILIES)}")

    # -- shape arithmetic ----------------------------------------------------
    @property
    def num_levels(self) -> int:
        return len(self.levels)

    @property
    def degrees(self) -> Tuple[int, ...]:
        return tuple(l.degree for l in self.levels)

    @property
    def num_chips(self) -> int:
        return math.prod(self.degrees)

    def chips_per_child(self, level: int) -> int:
        """Chips under one *child instance* at ``level`` (the endpoint a
        level-``level`` up/down link pair serves). Level 0 children are
        single chips."""
        return math.prod(self.degrees[:level])

    def chips_per_group(self, level: int) -> int:
        """Chips under one *switch* at ``level``."""
        return math.prod(self.degrees[:level + 1])

    def instances(self, level: int) -> int:
        """Number of child instances at ``level`` (each owns an up/down
        link pair)."""
        return self.num_chips // self.chips_per_child(level)

    # -- link id layout ------------------------------------------------------
    # Level 0 pairs come first, then level 1, ... Within a level, child
    # instance ``i`` owns up-link ``offset + 2*i`` and down-link
    # ``offset + 2*i + 1``.
    def link_offset(self, level: int) -> int:
        return sum(2 * self.instances(l) for l in range(level))

    def num_links(self) -> int:
        return sum(2 * self.instances(l) for l in range(self.num_levels))

    def up_link(self, level: int, chip: int) -> int:
        return self.link_offset(level) + 2 * (chip // self.chips_per_child(level))

    def down_link(self, level: int, chip: int) -> int:
        return self.up_link(level, chip) + 1

    def link_level(self, link_id: int) -> int:
        for level in range(self.num_levels):
            if link_id < self.link_offset(level) + 2 * self.instances(level):
                return level
        raise ValueError(f"link id {link_id} out of range")

    def link_bandwidth(self, link_id: int) -> float:
        return self.levels[self.link_level(link_id)].bandwidth

    def link_latency(self, link_id: int) -> float:
        return self.levels[self.link_level(link_id)].latency

    def ancestor_level(self, a: int, b: int) -> int:
        """Lowest level whose switch covers both chips."""
        for level in range(self.num_levels):
            g = self.chips_per_group(level)
            if a // g == b // g:
                return level
        raise ValueError(f"chips {a} and {b} share no switch "
                         f"(num_chips={self.num_chips})")

    def route(self, src: int, dst: int) -> List[int]:
        """Directed link ids traversed src -> dst: climb through the
        up-links of every level below the common ancestor, then descend
        through the matching down-links."""
        if src == dst:
            return []
        top = self.ancestor_level(src, dst)
        up = [self.up_link(l, src) for l in range(top + 1)]
        down = [self.down_link(l, dst) for l in range(top, -1, -1)]
        return up + down

    # -- derivation ----------------------------------------------------------
    def with_level(self, level: int, **kw: Any) -> "FabricSpec":
        """Copy with one level's fields replaced (search-axis helper)."""
        levels = list(self.levels)
        levels[level] = dataclasses.replace(levels[level], **kw)
        return dataclasses.replace(self, levels=tuple(levels))

    # -- serialization -------------------------------------------------------
    def to_dict(self) -> Dict[str, Any]:
        return {
            "name": self.name,
            "collective": self.collective,
            "levels": [l.to_dict() for l in self.levels],
        }

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "FabricSpec":
        try:
            return cls(
                levels=tuple(FabricLevel.from_dict(l) for l in d["levels"]),
                collective=d.get("collective", "hierarchical"),
                name=d.get("name", "fabric"),
            )
        except (KeyError, TypeError) as e:
            raise ValueError(f"bad fabric dict: {e}") from None

    def to_json(self, **kw: Any) -> str:
        return json.dumps(self.to_dict(), **kw)

    @classmethod
    def from_json(cls, s: str) -> "FabricSpec":
        return cls.from_dict(json.loads(s))


def fabric_spec_from_dict(d: Dict[str, Any]) -> FabricSpec:
    return FabricSpec.from_dict(d)


# ---------------------------------------------------------------------------
# presets
# ---------------------------------------------------------------------------

def board_pair() -> FabricSpec:
    """Two chips on one board (smallest multi-chip fabric)."""
    return FabricSpec(
        name="board_pair",
        levels=(FabricLevel("board", degree=2, bandwidth=100 * GB,
                            latency=5e-7),),
    )


def cluster_2x2() -> FabricSpec:
    """2 boards x 2 chips (the 4-chip cluster the docs walk through):
    fast board-level links, slower node-level links."""
    return FabricSpec(
        name="cluster_2x2",
        levels=(
            FabricLevel("board", degree=2, bandwidth=100 * GB, latency=5e-7),
            FabricLevel("node", degree=2, bandwidth=25 * GB, latency=2e-6),
        ),
    )


def rack_2x2x2() -> FabricSpec:
    """Three-tier 8-chip example: 2 chips/board, 2 boards/node, 2 nodes."""
    return FabricSpec(
        name="rack_2x2x2",
        levels=(
            FabricLevel("board", degree=2, bandwidth=100 * GB, latency=5e-7),
            FabricLevel("node", degree=2, bandwidth=25 * GB, latency=2e-6),
            FabricLevel("rack", degree=2, bandwidth=12.5 * GB, latency=5e-6),
        ),
    )


FABRIC_PRESETS = {
    "board_pair": board_pair,
    "cluster_2x2": cluster_2x2,
    "rack_2x2x2": rack_2x2x2,
}
