"""Collective *algorithm* schedules for the scale-out fabric.

Each algorithm compiles to a list of rounds; a round is a list of
``(src, dst, nbytes)`` point-to-point messages that run concurrently.
Members are abstract participant ids (the fabric model passes chip ids),
so the schedules are topology-agnostic — the model prices each message
over the fabric route and executes rounds as barriers of link-holding
transfer events (or closed forms, per fidelity mode).

Algorithms (ASTRA-sim-style menu):

* ``ring``       — all kinds; ``2(p-1)`` steps of ``n/p`` for all-reduce,
  ``p-1`` steps for reduce-scatter / all-gather.
* ``tree``       — binomial reduce + broadcast; ``2*ceil(log2 p)`` rounds
  of full-size messages for all-reduce (latency-optimal: wins for small
  messages at high participant counts).
* ``hd``         — recursive halving-doubling reduce-scatter /
  all-gather (``log2 p`` rounds, payload halving/doubling); non-power-of-2
  groups fall back to ring.
* ``pairwise``   — all-to-all: ``p-1`` rounds, each member exchanging an
  ``n/p`` shard with one distinct peer (MoE dispatch).

``alpha_beta_lower_bound`` gives the bandwidth-term lower bound the tests
cross-check simulated costs against (ring all-reduce: ``2(p-1)/p * n/bw``).
"""

from __future__ import annotations

import math
from typing import List, Optional, Sequence, Tuple

__all__ = [
    "ring_rounds",
    "tree_rounds",
    "hd_rounds",
    "pairwise_rounds",
    "rounds_for",
    "alpha_beta_lower_bound",
]

# (src, dst, nbytes) messages; one round's messages run concurrently
Message = Tuple[int, int, float]
Rounds = List[List[Message]]


def _steps(kind: str, p: int) -> int:
    return {"all_reduce": 2 * (p - 1), "reduce_scatter": p - 1,
            "all_gather": p - 1, "all_to_all": p - 1}[kind]


def ring_rounds(members: Sequence[int], kind: str, nbytes: float) -> Rounds:
    """Ring schedule: every step, member i sends an ``n/p`` chunk to its
    ring successor (all-reduce = reduce-scatter pass + all-gather pass)."""
    m = list(members)
    p = len(m)
    if p <= 1 or nbytes <= 0:
        return []
    chunk = nbytes / p
    return [[(m[i], m[(i + 1) % p], chunk) for i in range(p)]
            for _ in range(_steps(kind, p))]


def tree_rounds(members: Sequence[int], kind: str, nbytes: float,
                root: Optional[int] = None) -> Rounds:
    """Binomial-tree schedule: ``ceil(log2 p)`` rounds of full-size
    messages for reduce or broadcast, both passes for all-reduce."""
    m = list(members)
    p = len(m)
    if p <= 1 or nbytes <= 0:
        return []
    if root is not None and root in m:
        m.remove(root)
        m = [root] + m
    depth = (p - 1).bit_length()

    def reduce_pass() -> Rounds:
        rounds: Rounds = []
        for r in range(depth):
            step = [(m[i], m[i - (1 << r)], nbytes)
                    for i in range(p) if i % (1 << (r + 1)) == (1 << r)]
            if step:
                rounds.append(step)
        return rounds

    def broadcast_pass() -> Rounds:
        return [[(dst, src, b) for src, dst, b in step]
                for step in reversed(reduce_pass())]

    if kind == "reduce":
        return reduce_pass()
    if kind == "broadcast":
        return broadcast_pass()
    if kind == "all_reduce":
        return reduce_pass() + broadcast_pass()
    # tree reduce-scatter / all-gather degenerate to the hd recursion
    return hd_rounds(m, kind, nbytes)


def hd_rounds(members: Sequence[int], kind: str, nbytes: float) -> Rounds:
    """Recursive halving (reduce-scatter) / doubling (all-gather):
    ``log2 p`` pairwise-exchange rounds with geometric payloads. Falls
    back to ring when ``p`` is not a power of two."""
    m = list(members)
    p = len(m)
    if p <= 1 or nbytes <= 0:
        return []
    if p & (p - 1):
        return ring_rounds(m, kind, nbytes)
    depth = p.bit_length() - 1
    rounds: Rounds = []
    if kind == "reduce_scatter":
        for r in range(depth):
            dist = p >> (r + 1)
            size = nbytes / (1 << (r + 1))
            rounds.append([(m[i], m[i ^ dist], size) for i in range(p)])
        return rounds
    if kind == "all_gather":
        for r in range(depth):
            dist = 1 << r
            size = nbytes * (1 << r) / p
            rounds.append([(m[i], m[i ^ dist], size) for i in range(p)])
        return rounds
    if kind == "all_reduce":
        return (hd_rounds(m, "reduce_scatter", nbytes)
                + hd_rounds(m, "all_gather", nbytes))
    raise ValueError(f"hd_rounds does not implement {kind!r}")


def pairwise_rounds(members: Sequence[int], nbytes: float) -> Rounds:
    """Pairwise-exchange all-to-all: round r, member i sends its ``n/p``
    shard to member ``(i + r) mod p``."""
    m = list(members)
    p = len(m)
    if p <= 1 or nbytes <= 0:
        return []
    shard = nbytes / p
    return [[(m[i], m[(i + r) % p], shard) for i in range(p)]
            for r in range(1, p)]


def rounds_for(algorithm: str, kind: str, members: Sequence[int],
               nbytes: float, root: Optional[int] = None) -> Rounds:
    """Schedule ``kind`` over ``members`` with the named algorithm.
    Broadcast/reduce always use the binomial tree; all-to-all always the
    pairwise exchange (the algorithm knob selects among the bulk kinds)."""
    if kind in ("broadcast", "reduce"):
        return tree_rounds(members, kind, nbytes, root=root)
    if kind == "all_to_all":
        return pairwise_rounds(members, nbytes)
    if algorithm == "ring":
        return ring_rounds(members, kind, nbytes)
    if algorithm == "tree":
        return tree_rounds(members, kind, nbytes, root=root)
    if algorithm == "hd":
        return hd_rounds(members, kind, nbytes)
    raise ValueError(f"unknown fabric algorithm {algorithm!r}")


def alpha_beta_lower_bound(kind: str, p: int, nbytes: float,
                           bw: float) -> float:
    """Bandwidth-term lower bound (alpha-beta model, latency dropped):
    no algorithm moves the payload in less link time than this."""
    if p <= 1 or nbytes <= 0:
        return 0.0
    if kind == "all_reduce":
        return 2 * (p - 1) / p * nbytes / bw
    if kind in ("reduce_scatter", "all_gather", "all_to_all"):
        return (p - 1) / p * nbytes / bw
    if kind in ("broadcast", "reduce"):
        return nbytes / bw
    raise ValueError(kind)
