"""Scale-out fabric: multi-level interconnect specs, collective
algorithm schedules, and the event-compiling cluster model.

``spec`` and ``collectives`` are pure data/math (no core imports) and
load eagerly; :class:`FabricModel` / :class:`ClusterDRAM` pull in the
event core and load lazily on first attribute access so that
``repro.core.hardware`` can import :class:`FabricSpec` without a cycle.
"""

from .collectives import (
    alpha_beta_lower_bound,
    hd_rounds,
    pairwise_rounds,
    ring_rounds,
    rounds_for,
    tree_rounds,
)
from .spec import (
    COLLECTIVE_FAMILIES,
    FABRIC_PRESETS,
    LEVEL_ALGORITHMS,
    FabricLevel,
    FabricSpec,
    board_pair,
    cluster_2x2,
    fabric_spec_from_dict,
    rack_2x2x2,
)

__all__ = [
    "FabricLevel",
    "FabricSpec",
    "FabricModel",
    "ClusterDRAM",
    "FABRIC_PRESETS",
    "COLLECTIVE_FAMILIES",
    "LEVEL_ALGORITHMS",
    "board_pair",
    "cluster_2x2",
    "rack_2x2x2",
    "fabric_spec_from_dict",
    "ring_rounds",
    "tree_rounds",
    "hd_rounds",
    "pairwise_rounds",
    "rounds_for",
    "alpha_beta_lower_bound",
]

_LAZY = {"FabricModel", "ClusterDRAM"}


def __getattr__(name):
    if name in _LAZY:
        from . import model

        return getattr(model, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
