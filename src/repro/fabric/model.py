"""Event-driven scale-out fabric model (the multi-chip interconnect).

:class:`FabricModel` is a drop-in replacement for the scheduler's
:class:`~repro.core.noc.NoCModel`: it owns one NoC instance *per chip*
(device ids are global — ``chip * chip_size + local``) plus the fabric's
switched up/down links as first-class exclusive
:class:`~repro.core.events.Resource` objects, so cross-chip collectives
compile into sequences of link-holding transfer events that contend with
each other and appear as FABRIC lanes in the trace. :class:`ClusterDRAM`
is the matching drop-in for :class:`~repro.core.dram.DRAMModel` (one DRAM
instance per chip).

A collective whose group sits on one chip delegates to that chip's NoC
untouched; a chip-spanning group decomposes into

1. an intra-chip NoC leg (reduce onto each chip's gateway leader),
2. per-level fabric legs among the chip leaders — the algorithm schedules
   from :mod:`repro.fabric.collectives`, priced over the fabric route and
   executed per the fidelity mode, and
3. an intra-chip broadcast leg from each leader.

Fidelity mirrors :class:`~repro.core.enums.NoCMode`:

* ``detailed``   — every schedule round is a barrier of concurrent
  link-holding chip-to-chip transfers;
* ``macro``      — one closed-form hold of the schedule's whole link
  footprint (contention between collectives preserved, O(1) events);
* ``analytical`` — pure closed form, no resources.
"""

from __future__ import annotations

from typing import Dict, Generator, List, Optional, Sequence, Tuple

from ..core.dram import DRAMModel
from ..core.enums import NoCMode
from ..core.events import Environment, Resource
from ..core.hardware import HardwareSpec
from ..core.noc import NoCModel
from ..core.trace import KIND_FABRIC, TraceRecorder, pack_lane
from .collectives import Rounds, rounds_for
from .spec import FabricSpec

__all__ = ["FabricModel", "ClusterDRAM"]

# local device that fronts each chip's fabric port (data enters/leaves
# the chip NoC here)
GATEWAY = 0


class FabricModel:
    """Cluster interconnect: per-chip NoCs + multi-level fabric links."""

    def __init__(self, env: Environment, hardware: HardwareSpec,
                 mode: NoCMode = NoCMode.MACRO,
                 recorder: Optional[TraceRecorder] = None):
        spec = getattr(hardware, "fabric", None)
        if spec is None:
            raise ValueError(f"hardware {hardware.name!r} has no fabric spec")
        self.env = env
        self.hw = hardware
        self.spec: FabricSpec = spec
        self.mode = NoCMode(mode)
        self.recorder = recorder
        self.chip_size = hardware.topology.num_devices
        self.num_chips = spec.num_chips
        noc_stride = hardware.topology.num_links()
        self.nocs: List[NoCModel] = [
            NoCModel(env, hardware, self.mode, recorder=recorder,
                     resource_base=c * noc_stride)
            for c in range(self.num_chips)]
        self._noc_stride = noc_stride
        self._flinks: Dict[int, Resource] = {}
        self.fabric_bytes = 0.0
        self.fabric_transfers = 0
        # per-hierarchy-level payload accounting (sim-domain metric):
        # populated only when metrics_levels is set by the simulator.
        # Byte counts are integral-valued floats, so sums are exact in
        # any accumulation order — both tiers agree bit-for-bit.
        self.level_bytes: Dict[int, float] = {}
        self.metrics_levels = False
        self.dram = ClusterDRAM(self)

    # -- device arithmetic ---------------------------------------------------
    def chip_of(self, device: int) -> int:
        return device // self.chip_size

    def local(self, device: int) -> int:
        return device % self.chip_size

    def _gateway(self, chip: int) -> int:
        """Global id of a chip's fabric gateway device."""
        return chip * self.chip_size + GATEWAY

    # -- fabric link resources -----------------------------------------------
    def _flink(self, fid: int) -> Resource:
        res = self._flinks.get(fid)
        if res is None:
            cb = (self.recorder.interval_cb(KIND_FABRIC, fid)
                  if self.recorder is not None else None)
            res = Resource(self.env, capacity=1, name=f"flink{fid}",
                           interval_cb=cb)
            self._flinks[fid] = res
        return res

    def _path_time(self, route: Sequence[int], nbytes: float) -> float:
        """Wormhole-pipelined fabric path cost (Eq. 2 analogue)."""
        if not route:
            return 0.0
        lat = sum(self.spec.link_latency(f) for f in route)
        bw = min(self.spec.link_bandwidth(f) for f in route)
        return lat + nbytes / bw

    def _pair_time(self, src_chip: int, dst_chip: int, nbytes: float) -> float:
        return self._path_time(self.spec.route(src_chip, dst_chip), nbytes)

    def _hold(self, link_ids: Sequence[int], t: float,
              priority: int) -> Generator:
        """Acquire fabric links in sorted-id order (deadlock-free), hold
        for ``t``, release."""
        reqs = []
        for fid in sorted(set(link_ids)):
            link = self._flink(fid)
            req = link.request(priority)
            yield req
            reqs.append((link, req))
        yield self.env.timeout(t)
        for link, req in reqs:
            link.release(req)

    def _accum_levels(self, legs) -> None:
        """Attribute ``(route, nbytes)`` legs to the hierarchy levels
        they cross: every traversed link at level L carries ``nbytes``.
        Pre-aggregates per call before folding into ``level_bytes`` —
        the same float association the fast tier applies when replaying
        the per-node ``_level_item`` metadata, so both tiers produce
        bit-identical level sums."""
        lb = self.level_bytes
        for lvl, b in self._level_item(legs):
            lb[lvl] = lb.get(lvl, 0.0) + b

    def _level_item(self, legs) -> Tuple:
        """Chain-node metadata form of :meth:`_accum_levels` over
        ``(route, nbytes)`` legs: sorted ``(level, bytes)`` pairs."""
        acc: Dict[int, float] = {}
        for route, nbytes in legs:
            for fid in route:
                lvl = self.spec.link_level(fid)
                acc[lvl] = acc.get(lvl, 0.0) + nbytes
        return tuple(sorted(acc.items()))

    def _fabric_leg(self, src_chip: int, dst_chip: int, nbytes: float,
                    priority: int) -> Generator:
        """One chip-to-chip fabric transfer (gateway to gateway)."""
        self.fabric_bytes += nbytes
        self.fabric_transfers += 1
        route = self.spec.route(src_chip, dst_chip)
        if self.metrics_levels and route:
            self._accum_levels([(route, nbytes)])
        t = self._path_time(route, nbytes)
        if self.mode == NoCMode.ANALYTICAL or not route:
            yield self.env.timeout(t)
            return
        yield from self._hold(route, t, priority)

    # -- schedule execution ----------------------------------------------------
    def _rounds_time(self, rounds: Rounds) -> float:
        return sum(max((self._pair_time(s, d, b) for s, d, b in rnd),
                       default=0.0) for rnd in rounds)

    def _rounds_footprint(self, rounds: Rounds) -> List[int]:
        fp = set()
        for rnd in rounds:
            for s, d, _ in rnd:
                fp.update(self.spec.route(s, d))
        return sorted(fp)

    def _exec_rounds(self, rounds: Rounds, priority: int) -> Generator:
        """Run a collective schedule per the fidelity mode."""
        env = self.env
        if not rounds:
            yield env.timeout(0.0)
            return
        if self.mode == NoCMode.DETAILED:
            for rnd in rounds:
                procs = [env.process(self._fabric_leg(s, d, b, priority))
                         for s, d, b in rnd]
                yield env.all_of(procs)
            return
        total_bytes = sum(b for rnd in rounds for _, _, b in rnd)
        self.fabric_bytes += total_bytes
        self.fabric_transfers += 1
        if self.metrics_levels:
            self._accum_levels((self.spec.route(s, d), b)
                               for rnd in rounds for s, d, b in rnd)
        t = self._rounds_time(rounds)
        if self.mode == NoCMode.ANALYTICAL:
            yield env.timeout(t)
            return
        yield from self._hold(self._rounds_footprint(rounds), t, priority)

    # -- hierarchical all-reduce ------------------------------------------------
    def _hier_allreduce_rounds(self, chips: List[int], nbytes: float) -> Rounds:
        """Per-level reduce-scatter up / all-gather down among chip
        leaders; the payload entering level L shrinks by the sibling count
        at every level below (this is what makes hierarchical all-reduce
        cheap on thin upper tiers)."""
        spec = self.spec
        reps = sorted(chips)
        payload: Dict[int, float] = {c: nbytes for c in reps}
        up: Rounds = []
        stack: List[Tuple[int, List[List[int]], Dict[int, float]]] = []
        for lvl in range(spec.num_levels):
            if len(reps) <= 1:
                break
            groups: Dict[int, List[int]] = {}
            for c in reps:
                groups.setdefault(c // spec.chips_per_group(lvl), []).append(c)
            group_list = [sorted(g) for _, g in sorted(groups.items())]
            entering = dict(payload)
            per_group = [
                rounds_for(spec.levels[lvl].algorithm, "reduce_scatter",
                           members, max(payload[m] for m in members))
                for members in group_list if len(members) > 1]
            up.extend(_merge_rounds(per_group))
            stack.append((lvl, group_list, entering))
            reps = []
            for members in group_list:
                rep = members[0]
                if len(members) > 1:
                    payload[rep] = max(payload[m] for m in members) / len(members)
                reps.append(rep)
        down: Rounds = []
        for lvl, group_list, entering in reversed(stack):
            per_group = [
                rounds_for(spec.levels[lvl].algorithm, "all_gather",
                           members, max(entering[m] for m in members))
                for members in group_list if len(members) > 1]
            down.extend(_merge_rounds(per_group))
        return up + down

    def _cross_rounds(self, kind: str, chips: List[int], nbytes: float,
                      root_chip: Optional[int] = None) -> Rounds:
        """Cross-chip schedule among the chip leaders."""
        family = self.spec.collective
        if kind == "all_reduce" and family == "hierarchical":
            return self._hier_allreduce_rounds(chips, nbytes)
        if kind in ("reduce_scatter", "all_gather") and family == "hierarchical":
            # per-level recursion for RS/AG alone approximates to the
            # halving-doubling schedule over the flat chip set
            return rounds_for("hd", kind, sorted(chips), nbytes)
        return rounds_for(family if family != "hierarchical" else "ring",
                          kind, sorted(chips), nbytes, root=root_chip)

    # -- NoCModel-compatible surface --------------------------------------------
    @property
    def bytes_moved(self) -> float:
        return self.fabric_bytes + sum(n.bytes_moved for n in self.nocs)

    @property
    def transfer_count(self) -> int:
        return self.fabric_transfers + sum(n.transfer_count for n in self.nocs)

    @property
    def _links(self) -> Dict[int, Resource]:
        """Merged resource view (truthy iff any link was touched)."""
        merged: Dict[int, Resource] = {}
        for c, noc in enumerate(self.nocs):
            for lid, res in noc._links.items():
                merged[c * self._noc_stride + lid] = res
        base = self.num_chips * self._noc_stride
        for fid, res in self._flinks.items():
            merged[base + fid] = res
        return merged

    def occupancy_report(self) -> Dict[int, float]:
        """Chip NoC link utilizations (chip-offset ids) followed by fabric
        link utilizations (offset past every chip's id range)."""
        out: Dict[int, float] = {}
        for noc in self.nocs:
            out.update(noc.occupancy_report())
        base = self.num_chips * self._noc_stride
        for fid in sorted(self._flinks):
            out[base + fid] = self._flinks[fid].utilization()
        return out

    def close_open_intervals(self, t: float) -> None:
        for noc in self.nocs:
            noc.close_open_intervals(t)
        if self.recorder is None:
            return
        for fid in sorted(self._flinks):
            since = self._flinks[fid].busy_since
            if since is not None and t > since:
                self.recorder.resource(KIND_FABRIC, fid, since, t)

    def transfer(self, src: int, dst: int, nbytes: float,
                 priority: int = 0) -> Generator:
        """Process: move ``nbytes`` between two global devices. Same-chip
        transfers delegate to the chip NoC; cross-chip transfers take a
        NoC leg to the source gateway, the fabric route, and a NoC leg
        from the destination gateway."""
        env = self.env
        cs, cd = self.chip_of(src), self.chip_of(dst)
        if cs == cd:
            yield env.process(self.nocs[cs].transfer(
                self.local(src), self.local(dst), nbytes, priority))
            return
        if self.local(src) != GATEWAY:
            yield env.process(self.nocs[cs].transfer(
                self.local(src), GATEWAY, nbytes, priority))
        yield from self._fabric_leg(cs, cd, nbytes, priority)
        if self.local(dst) != GATEWAY:
            yield env.process(self.nocs[cd].transfer(
                GATEWAY, self.local(dst), nbytes, priority))

    def collective(self, kind: str, group: Sequence[int], nbytes: float,
                   priority: int = 0, root: Optional[int] = None) -> Generator:
        """Process: run a collective over global device ids. Groups on a
        single chip go straight to that chip's NoC; chip-spanning groups
        decompose into intra-chip legs + per-level fabric legs."""
        env = self.env
        if len(group) <= 1 or nbytes <= 0:
            yield env.timeout(0.0)
            return
        by_chip: Dict[int, List[int]] = {}
        for d in group:
            by_chip.setdefault(self.chip_of(d), []).append(self.local(d))
        if len(by_chip) == 1:
            chip, locs = next(iter(by_chip.items()))
            local_root = (self.local(root)
                          if root is not None and self.chip_of(root) == chip
                          else None)
            yield env.process(self.nocs[chip].collective(
                kind, locs, nbytes, priority, root=local_root))
            return
        yield from self._cross_chip(kind, by_chip, nbytes, priority, root)

    def _intra(self, by_chip: Dict[int, List[int]], kind: str, nbytes: float,
               priority: int, roots: Optional[Dict[int, int]] = None) -> Generator:
        """Concurrent per-chip NoC collectives (chips with one member
        skip theirs)."""
        env = self.env
        procs = []
        for chip in sorted(by_chip):
            locs = by_chip[chip]
            if len(locs) > 1:
                root = roots.get(chip) if roots is not None else None
                procs.append(env.process(self.nocs[chip].collective(
                    kind, locs, nbytes, priority, root=root)))
        if procs:
            yield env.all_of(procs)
        else:
            yield env.timeout(0.0)

    def _cross_chip(self, kind: str, by_chip: Dict[int, List[int]],
                    nbytes: float, priority: int,
                    root: Optional[int]) -> Generator:
        env = self.env
        chips = sorted(by_chip)
        leaders = {chip: min(locs) for chip, locs in by_chip.items()}
        root_chip = self.chip_of(root) if root is not None else chips[0]

        if kind == "all_reduce":
            yield from self._intra(by_chip, "reduce", nbytes, priority,
                                   roots=leaders)
            yield from self._exec_rounds(
                self._cross_rounds("all_reduce", chips, nbytes), priority)
            yield from self._intra(by_chip, "broadcast", nbytes, priority,
                                   roots=leaders)
        elif kind in ("reduce_scatter", "all_gather"):
            if kind == "reduce_scatter":
                yield from self._intra(by_chip, kind, nbytes, priority)
                yield from self._exec_rounds(
                    self._cross_rounds(kind, chips, nbytes), priority)
            else:
                yield from self._exec_rounds(
                    self._cross_rounds(kind, chips, nbytes), priority)
                yield from self._intra(by_chip, kind, nbytes, priority)
        elif kind == "all_to_all":
            yield from self._intra(by_chip, kind, nbytes, priority)
            yield from self._exec_rounds(
                self._cross_rounds(kind, chips, nbytes), priority)
        elif kind == "broadcast":
            yield from self._exec_rounds(
                rounds_for("tree", "broadcast", chips, nbytes,
                           root=root_chip), priority)
            yield from self._intra(by_chip, "broadcast", nbytes, priority,
                                   roots=leaders)
        elif kind == "reduce":
            yield from self._intra(by_chip, "reduce", nbytes, priority,
                                   roots=leaders)
            yield from self._exec_rounds(
                rounds_for("tree", "reduce", chips, nbytes,
                           root=root_chip), priority)
        else:
            raise ValueError(f"unknown collective kind {kind!r}")

    # -- fast-path pricing (repro.core.fastpath) -------------------------------
    def _fabric_leg_chain(self, src_chip: int, dst_chip: int,
                          nbytes: float) -> List:
        """Uncontended price of :meth:`_fabric_leg` as a fast-path chain."""
        route = self.spec.route(src_chip, dst_chip)
        t = self._path_time(route, nbytes)
        bnode = ("bytes", "fabric", nbytes)
        if self.metrics_levels and route:
            bnode = bnode + (self._level_item([(route, nbytes)]),)
        if self.mode == NoCMode.ANALYTICAL or not route:
            return [bnode, ("dt", t)]
        return [bnode,
                ("hold", tuple(pack_lane(KIND_FABRIC, fid)
                               for fid in sorted(set(route))), t)]

    def transfer_chain(self, src: int, dst: int, nbytes: float) -> List:
        """Uncontended price of :meth:`transfer` as a fast-path chain."""
        cs, cd = self.chip_of(src), self.chip_of(dst)
        if cs == cd:
            return self.nocs[cs].transfer_chain(self.local(src),
                                                self.local(dst), nbytes)
        chain: List = []
        if self.local(src) != GATEWAY:
            chain.extend(self.nocs[cs].transfer_chain(self.local(src),
                                                      GATEWAY, nbytes))
        chain.extend(self._fabric_leg_chain(cs, cd, nbytes))
        if self.local(dst) != GATEWAY:
            chain.extend(self.nocs[cd].transfer_chain(GATEWAY,
                                                      self.local(dst), nbytes))
        return chain

    def _exec_rounds_chain(self, rounds: Rounds) -> List:
        """Uncontended price of :meth:`_exec_rounds` as a fast-path chain."""
        if not rounds:
            return [("dt", 0.0)]
        if self.mode == NoCMode.DETAILED:
            return [("par", tuple(self._fabric_leg_chain(s, d, b)
                                  for s, d, b in rnd))
                    for rnd in rounds]
        total_bytes = sum(b for rnd in rounds for _, _, b in rnd)
        t = self._rounds_time(rounds)
        bnode = ("bytes", "fabric", total_bytes)
        if self.metrics_levels:
            bnode = bnode + (self._level_item(
                (self.spec.route(s, d), b)
                for rnd in rounds for s, d, b in rnd),)
        if self.mode == NoCMode.ANALYTICAL:
            return [bnode, ("dt", t)]
        return [bnode,
                ("hold", tuple(pack_lane(KIND_FABRIC, fid)
                               for fid in self._rounds_footprint(rounds)), t)]

    def _intra_chain(self, by_chip: Dict[int, List[int]], kind: str,
                     nbytes: float,
                     roots: Optional[Dict[int, int]] = None) -> List:
        """Uncontended price of :meth:`_intra` as a fast-path chain."""
        branches = []
        for chip in sorted(by_chip):
            locs = by_chip[chip]
            if len(locs) > 1:
                root = roots.get(chip) if roots is not None else None
                branches.append(self.nocs[chip].collective_chain(
                    kind, locs, nbytes, root=root))
        return [("par", tuple(branches))] if branches else [("dt", 0.0)]

    def collective_chain(self, kind: str, group: Sequence[int], nbytes: float,
                         root: Optional[int] = None) -> List:
        """Uncontended price of :meth:`collective` as a fast-path chain."""
        if len(group) <= 1 or nbytes <= 0:
            return [("dt", 0.0)]
        by_chip: Dict[int, List[int]] = {}
        for d in group:
            by_chip.setdefault(self.chip_of(d), []).append(self.local(d))
        if len(by_chip) == 1:
            chip, locs = next(iter(by_chip.items()))
            local_root = (self.local(root)
                          if root is not None and self.chip_of(root) == chip
                          else None)
            return self.nocs[chip].collective_chain(kind, locs, nbytes,
                                                    root=local_root)
        chips = sorted(by_chip)
        leaders = {chip: min(locs) for chip, locs in by_chip.items()}
        root_chip = self.chip_of(root) if root is not None else chips[0]
        chain: List = []
        if kind == "all_reduce":
            chain += self._intra_chain(by_chip, "reduce", nbytes,
                                       roots=leaders)
            chain += self._exec_rounds_chain(
                self._cross_rounds("all_reduce", chips, nbytes))
            chain += self._intra_chain(by_chip, "broadcast", nbytes,
                                       roots=leaders)
        elif kind in ("reduce_scatter", "all_gather"):
            if kind == "reduce_scatter":
                chain += self._intra_chain(by_chip, kind, nbytes)
                chain += self._exec_rounds_chain(
                    self._cross_rounds(kind, chips, nbytes))
            else:
                chain += self._exec_rounds_chain(
                    self._cross_rounds(kind, chips, nbytes))
                chain += self._intra_chain(by_chip, kind, nbytes)
        elif kind == "all_to_all":
            chain += self._intra_chain(by_chip, kind, nbytes)
            chain += self._exec_rounds_chain(
                self._cross_rounds(kind, chips, nbytes))
        elif kind == "broadcast":
            chain += self._exec_rounds_chain(
                rounds_for("tree", "broadcast", chips, nbytes,
                           root=root_chip))
            chain += self._intra_chain(by_chip, "broadcast", nbytes,
                                       roots=leaders)
        elif kind == "reduce":
            chain += self._intra_chain(by_chip, "reduce", nbytes,
                                       roots=leaders)
            chain += self._exec_rounds_chain(
                rounds_for("tree", "reduce", chips, nbytes, root=root_chip))
        else:
            raise ValueError(f"unknown collective kind {kind!r}")
        return chain

    def group_to_group(self, src_group: Sequence[int],
                       dst_group: Sequence[int], nbytes: float,
                       strategy: int = 1, num_adapters: int = 1,
                       priority: int = 0) -> Generator:
        """Inter-stage tensor hand-off across global device groups. When
        both groups sit on one chip the chip NoC's §V-C strategies apply
        verbatim; otherwise: reduce in the source group, one fabric
        transfer leader-to-leader, broadcast in the destination group."""
        env = self.env
        src, dst = list(src_group), list(dst_group)
        src_chips = {self.chip_of(d) for d in src}
        dst_chips = {self.chip_of(d) for d in dst}
        if len(src_chips | dst_chips) == 1:
            chip = next(iter(src_chips))
            yield env.process(self.nocs[chip].group_to_group(
                [self.local(d) for d in src], [self.local(d) for d in dst],
                nbytes, strategy=strategy, num_adapters=num_adapters,
                priority=priority))
            return
        src_leader, dst_leader = min(src), min(dst)
        if len(src) > 1:
            yield env.process(self.collective("reduce", src, nbytes, priority,
                                              root=src_leader))
        yield env.process(self.transfer(src_leader, dst_leader, nbytes,
                                        priority))
        if len(dst) > 1:
            yield env.process(self.collective("broadcast", dst, nbytes,
                                              priority, root=dst_leader))


def _merge_rounds(per_group: List[Rounds]) -> Rounds:
    """Zip concurrent per-group schedules round-by-round (sibling groups
    at one level run in parallel)."""
    if not per_group:
        return []
    depth = max(len(r) for r in per_group)
    return [[msg for rounds in per_group if i < len(rounds)
             for msg in rounds[i]]
            for i in range(depth)]


class ClusterDRAM:
    """DRAMModel-compatible facade: one DRAM instance per chip, device
    ids global. Weight-stream traffic (``shared_bytes``) is split across
    chips in proportion to each chip's share of the group."""

    def __init__(self, fabric: FabricModel):
        self.fabric = fabric
        self.env = fabric.env
        hw = fabric.hw
        stride = max(fabric.chip_size, hw.dram.channels)
        self.drams: List[DRAMModel] = [
            DRAMModel(fabric.env, hw, fabric.nocs[c],
                      recorder=fabric.recorder, resource_base=c * stride)
            for c in range(fabric.num_chips)]

    @property
    def bytes_accessed(self) -> float:
        return sum(d.bytes_accessed for d in self.drams)

    def occupancy_report(self) -> Dict[int, float]:
        out: Dict[int, float] = {}
        for d in self.drams:
            out.update(d.occupancy_report())
        return out

    def close_open_intervals(self, t: float) -> None:
        for d in self.drams:
            d.close_open_intervals(t)

    def access(self, device: int, nbytes: float, priority: int = 0,
               write: bool = False) -> Generator:
        chip = self.fabric.chip_of(device)
        yield self.env.process(self.drams[chip].access(
            self.fabric.local(device), nbytes, priority, write))

    # -- fast-path pricing (repro.core.fastpath) -------------------------------
    def access_chain(self, device: int, nbytes: float,
                     write: bool = False) -> List:
        chip = self.fabric.chip_of(device)
        return self.drams[chip].access_chain(self.fabric.local(device),
                                             nbytes, write)

    def group_access_chain(self, devices, nbytes_per_device: float,
                           write: bool = False, shared_bytes: float = 0.0,
                           num_shards: int = 1) -> List:
        devs = list(devices)
        by_chip: Dict[int, List[int]] = {}
        for d in devs:
            by_chip.setdefault(self.fabric.chip_of(d), []).append(
                self.fabric.local(d))
        n_total = max(1, len(devs))
        branches = [self.drams[chip].group_access_chain(
                        by_chip[chip], nbytes_per_device, write,
                        shared_bytes * len(by_chip[chip]) / n_total,
                        num_shards)
                    for chip in sorted(by_chip)]
        return [("par", tuple(branches))] if branches else [("dt", 0.0)]

    def group_access(self, devices, nbytes_per_device: float,
                     priority: int = 0, write: bool = False,
                     shared_bytes: float = 0.0,
                     num_shards: int = 1) -> Generator:
        devs = list(devices)
        by_chip: Dict[int, List[int]] = {}
        for d in devs:
            by_chip.setdefault(self.fabric.chip_of(d), []).append(
                self.fabric.local(d))
        n_total = max(1, len(devs))
        procs = []
        for chip in sorted(by_chip):
            locs = by_chip[chip]
            procs.append(self.env.process(self.drams[chip].group_access(
                locs, nbytes_per_device, priority, write,
                shared_bytes * len(locs) / n_total, num_shards)))
        if procs:
            yield self.env.all_of(procs)
        else:
            yield self.env.timeout(0.0)
