"""Request-level serving simulator on the PALM event core.

``repro.serving.system`` answers "what does this (hardware, plan) pair do
under real traffic" instead of "how fast is one step": a seeded
:class:`~.workload.WorkloadSpec` drives arrivals, a
:class:`~.batcher.ContinuousBatcher` schedules iteration-level admission
and KV-cache eviction, and every engine iteration advances a
deterministic :class:`~repro.core.events.Environment` by the *simulated*
cost of that prefill/decode step.

Step costs come from the existing PALM graph simulation: a
:class:`StepCostModel` builds the decode (1-token against a KV span) or
prefill graph for the iteration's batch/context, maps it onto the
hardware with the serving plan, and runs the event-driven
:class:`~repro.core.scheduler.PipelineSimulator` — memoized per
(batch-bucket, context-bucket), so a 10k-request run costs a handful of
graph simulations, not ten thousand (the two-tier fast-path/detailed
split Proteus uses).

The result is a :class:`ServingReport`: TTFT/TPOT/e2e percentiles,
goodput, SLO-attainment curves, queue depth and KV occupancy over time —
JSON-round-trippable like every other report — plus (optionally) a
columnar :class:`~repro.core.trace.Trace` with per-request
PREFILL/DECODE/QUEUE lanes that renders through the same npz/Chrome
exporters as training timelines.

Everything here is deterministic by construction (seeded workload, the
``(time, priority, seq)``-keyed event heap, FIFO/LIFO batcher ordering):
identical specs produce bit-identical reports, in-process or in a pool
worker.
"""

from __future__ import annotations

import dataclasses
import json
import math
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence, Tuple, Union

from ..configs.base import ArchConfig
from ..core.enums import BoundaryMode, NoCMode, Schedule
from ..core.events import Environment, Event
from ..core.hardware import HardwareSpec
from ..core.parallelism import ParallelPlan, map_graph
from ..core.scheduler import PipelineSimulator, plan_memory
from ..core.trace import (
    KIND_DECODE,
    KIND_PREFILL,
    KIND_QUEUE,
    Trace,
    TraceRecorder,
)
from ..core.workload import arch_to_graph
from .batcher import ActiveRequest, ContinuousBatcher, KVCacheModel
from .workload import WorkloadSpec

__all__ = ["ServingSpec", "StepCostModel", "ServingSimulator",
           "ServingReport", "simulate_serving"]


@dataclass
class ServingSpec:
    """Declarative serving-scenario description (what to simulate).

    ``kv_budget_bytes=None`` derives the cluster KV budget from the
    hardware: per-tile DRAM capacity minus the plan's resident footprint
    (the same :func:`~repro.core.scheduler.plan_memory` accounting the
    training simulator prunes on), summed over the tiles the plan uses.
    SLO targets are milliseconds; goodput counts requests meeting both.
    """

    workload: WorkloadSpec = field(default_factory=WorkloadSpec)
    slo_ttft_ms: float = 2000.0
    slo_tpot_ms: float = 200.0
    max_batch: int = 32
    kv_budget_bytes: Optional[float] = None
    policy: str = "continuous"              # or "static"
    ctx_bucket: int = 512                   # step-cost context rounding
    slo_scales: Tuple[float, ...] = (0.25, 0.5, 1.0, 2.0, 4.0)
    sample_limit: int = 256                 # time-series points kept in report

    def to_dict(self) -> Dict[str, Any]:
        d = dataclasses.asdict(self)
        d["workload"] = self.workload.to_dict()
        d["slo_scales"] = list(self.slo_scales)
        return d

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "ServingSpec":
        kw = dict(d)
        kw["workload"] = WorkloadSpec.from_dict(kw["workload"])
        kw["slo_scales"] = tuple(kw.get("slo_scales", (0.25, 0.5, 1.0, 2.0, 4.0)))
        return cls(**kw)


class StepCostModel:
    """Memoized per-iteration step costs from the PALM graph simulation.

    One engine iteration is either a prefill over the admitted requests'
    contexts or a single decode step for the running batch. Its cost is
    the event-driven simulated ``total_time`` of the corresponding graph
    (``arch_to_graph(..., decode=True)`` for decode) mapped with the
    serving plan — with the iteration batch rounded up to a
    ``dp * 2^k`` bucket and the context to a ``ctx_bucket`` multiple, so
    runs over thousands of requests reuse a handful of simulations.
    Bucketing rounds *up*: costs are conservative, never optimistic.
    """

    def __init__(self, arch: ArchConfig, hardware: HardwareSpec,
                 plan: ParallelPlan, *,
                 noc_mode: NoCMode = NoCMode.MACRO,
                 boundary_mode: BoundaryMode = BoundaryMode.PAIRWISE,
                 ctx_bucket: int = 512):
        if ctx_bucket < 1:
            raise ValueError("ctx_bucket must be >= 1")
        self.arch = arch
        self.hardware = hardware
        self.plan = plan
        self.noc_mode = NoCMode(noc_mode)
        self.boundary_mode = BoundaryMode(boundary_mode)
        self.ctx_bucket = int(ctx_bucket)
        self._memo: Dict[Tuple[str, int, int], float] = {}
        self.sims = 0           # distinct graph simulations run

    # -- bucketing -----------------------------------------------------------
    def bucket_batch(self, batch: int) -> int:
        dp = max(1, self.plan.dp)
        per_replica = max(1, math.ceil(batch / dp))
        return dp * (1 << (per_replica - 1).bit_length())

    def bucket_ctx(self, ctx: int) -> int:
        return self.ctx_bucket * max(1, math.ceil(ctx / self.ctx_bucket))

    # -- costs ---------------------------------------------------------------
    def prefill_cost(self, batch: int, ctx: int) -> float:
        return self._cost("prefill", batch, ctx)

    def decode_cost(self, batch: int, ctx: int) -> float:
        return self._cost("decode", batch, ctx)

    def _cost(self, kind: str, batch: int, ctx: int) -> float:
        key = (kind, self.bucket_batch(batch), self.bucket_ctx(ctx))
        cost = self._memo.get(key)
        if cost is None:
            cost = self._simulate(*key)
            self._memo[key] = cost
            self.sims += 1
        return cost

    def _plan_for(self, batch: int) -> ParallelPlan:
        """The serving plan resized so one iteration is one micro-batch
        (``microbatch * dp == global_batch == batch``)."""
        dp = max(1, self.plan.dp)
        return dataclasses.replace(
            self.plan, microbatch=batch // dp, global_batch=batch,
            training=False, schedule=Schedule.GPIPE,
            activation_offload=False)

    def _simulate(self, kind: str, batch: int, ctx: int) -> float:
        plan = self._plan_for(batch)
        graph = arch_to_graph(self.arch, ctx, batch, training=False,
                              decode=(kind == "decode"))
        mapped = map_graph(graph, self.hardware, plan)
        sim = PipelineSimulator(mapped, noc_mode=self.noc_mode,
                                boundary_mode=self.boundary_mode)
        return sim.run().total_time

    # -- KV budget -----------------------------------------------------------
    def derive_kv_budget(self) -> float:
        """Cluster-aggregate KV byte budget: per-tile DRAM capacity minus
        the plan's resident per-tile footprint (weights/state via
        :func:`plan_memory` on the smallest decode mapping), summed over
        every tile the plan uses. ``inf``-capacity hardware (abstract
        meshes) yields an unbounded budget."""
        cap = self.hardware.dram.capacity_bytes
        if math.isinf(cap):
            return math.inf
        dp = max(1, self.plan.dp)
        plan = self._plan_for(dp)
        graph = arch_to_graph(self.arch, self.ctx_bucket, dp,
                              training=False, decode=True)
        mapped = map_graph(graph, self.hardware, plan)
        memory, _ = plan_memory(mapped)
        tiles_per_stage = self.plan.dp * self.plan.tp
        budget = sum(max(0.0, cap - m.total) * tiles_per_stage
                     for m in memory)
        if budget <= 0:
            raise ValueError(
                f"no KV-cache headroom: plan resident footprint "
                f"{max(m.total for m in memory):.3g} B/tile >= DRAM "
                f"capacity {cap:.3g} B/tile on {self.hardware.name}")
        return budget


# ---------------------------------------------------------------------------
# metrics helpers
# ---------------------------------------------------------------------------

def _pctl(sorted_vals: Sequence[float], q: float) -> float:
    """Nearest-rank percentile over pre-sorted values."""
    if not sorted_vals:
        return 0.0
    idx = max(0, math.ceil(q / 100.0 * len(sorted_vals)) - 1)
    return float(sorted_vals[idx])


def _stats(vals: Sequence[float]) -> Dict[str, float]:
    s = sorted(vals)
    return {
        "p50": _pctl(s, 50), "p90": _pctl(s, 90), "p99": _pctl(s, 99),
        "mean": sum(s) / len(s) if s else 0.0,
        "max": s[-1] if s else 0.0,
    }


def _thin(series: List[List[float]], limit: int) -> List[List[float]]:
    """Deterministic stride downsampling that always keeps the last point."""
    if limit <= 0 or len(series) <= limit:
        return series
    stride = math.ceil(len(series) / limit)
    out = series[::stride]
    if out[-1] is not series[-1]:
        out.append(series[-1])
    return out


@dataclass
class ServingReport:
    """Digest of one traffic-driven serving simulation.

    Latency stats are seconds (keys p50/p90/p99/mean/max) over *completed*
    requests; SLO attainment fractions count rejected requests as misses.
    ``goodput_rps`` is completed-requests-meeting-both-SLOs per second of
    simulated time. ``queue_depth`` / ``kv_occupancy_bytes`` are
    ``[t, value]`` samples taken after every engine iteration
    (downsampled to the spec's ``sample_limit``).
    JSON-round-trips via ``to_json``/``from_json``; the optional
    per-request :class:`Trace` is excluded from JSON and equality, like
    ``RunReport``.
    """

    arch: str
    hardware: str
    plan: ParallelPlan
    num_requests: int
    completed: int
    rejected: int
    preemptions: int
    sim_time: float
    offered_rate: float
    throughput_rps: float
    goodput_rps: float
    tokens_per_s: float
    ttft: Dict[str, float]
    tpot: Dict[str, float]
    e2e: Dict[str, float]
    slo: Dict[str, float]
    slo_curve: List[Dict[str, float]]
    queue_depth: List[List[float]]
    kv_occupancy_bytes: List[List[float]]
    kv_peak_bytes: float
    kv_budget_bytes: Optional[float]        # None = unbounded
    steps: Dict[str, int]
    extra: Dict[str, Any] = field(default_factory=dict)
    trace: Optional[Trace] = field(default=None, compare=False, repr=False)
    # repro.obs metrics document ({"sim": ..., "host": ...}) when the
    # simulator ran with metrics=True; the host half is wall-clock so the
    # field stays out of equality (JSON keeps it when present)
    metrics: Optional[Dict[str, Any]] = field(default=None, compare=False,
                                              repr=False)

    @property
    def slo_attainment(self) -> float:
        return self.slo.get("attainment", 0.0)

    def to_dict(self, include_trace: bool = False) -> Dict[str, Any]:
        from ..api.report import plan_to_dict      # api builds on core
        src = dataclasses.replace(self, trace=None) if self.trace is not None \
            else self
        d = dataclasses.asdict(src)
        d["plan"] = plan_to_dict(self.plan)
        d.pop("trace", None)
        if d.get("metrics") is None:
            d.pop("metrics", None)
        if include_trace and self.trace is not None:
            d["trace"] = self.trace.to_dict()
        return d

    def to_json(self, **kw: Any) -> str:
        return json.dumps(self.to_dict(), **kw)

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "ServingReport":
        from ..api.report import plan_from_dict
        d = dict(d)
        d["plan"] = plan_from_dict(d["plan"])
        trace = d.pop("trace", None)
        if trace is not None:
            d["trace"] = Trace.from_dict(trace)
        return cls(**d)

    @classmethod
    def from_json(cls, s: str) -> "ServingReport":
        return cls.from_dict(json.loads(s))

    def summary(self) -> str:
        budget = ("unbounded" if self.kv_budget_bytes is None
                  else f"{self.kv_budget_bytes / 1e9:.2f} GB")
        return "\n".join([
            f"{self.arch} on {self.hardware} "
            f"(pp={self.plan.pp} dp={self.plan.dp} tp={self.plan.tp}, "
            f"{self.steps.get('policy', 'continuous')} batching)",
            f"requests:  {self.completed}/{self.num_requests} completed, "
            f"{self.rejected} rejected, {self.preemptions} preemptions",
            f"offered:   {self.offered_rate:.3g} req/s over "
            f"{self.sim_time:.3g} s simulated",
            f"TTFT (s):  p50 {self.ttft['p50']:.4g}  p90 {self.ttft['p90']:.4g}"
            f"  p99 {self.ttft['p99']:.4g}",
            f"TPOT (s):  p50 {self.tpot['p50']:.4g}  p90 {self.tpot['p90']:.4g}"
            f"  p99 {self.tpot['p99']:.4g}",
            f"e2e  (s):  p50 {self.e2e['p50']:.4g}  p90 {self.e2e['p90']:.4g}"
            f"  p99 {self.e2e['p99']:.4g}",
            f"goodput:   {self.goodput_rps:.4g} req/s "
            f"(throughput {self.throughput_rps:.4g} req/s, "
            f"{self.tokens_per_s:.4g} tok/s)",
            f"SLO:       ttft <= {self.slo['ttft_ms']:.4g} ms & tpot <= "
            f"{self.slo['tpot_ms']:.4g} ms -> "
            f"{self.slo['attainment']:.1%} attainment",
            f"KV cache:  peak {self.kv_peak_bytes / 1e9:.3g} GB of {budget}",
        ])


class ServingSimulator:
    """Drives a workload through a continuous batcher on the event core.

    One generator process owns the engine loop (admission -> prefill or
    decode iteration, each advanced by its simulated step cost); a second
    process feeds arrivals and wakes the engine when it is drained. All
    scheduling runs on the deterministic ``(time, priority, seq)`` event
    heap, so identical inputs replay identically.
    """

    def __init__(self, arch: ArchConfig, hardware: HardwareSpec,
                 plan: ParallelPlan, spec: ServingSpec, *,
                 noc_mode: NoCMode = NoCMode.MACRO,
                 boundary_mode: BoundaryMode = BoundaryMode.PAIRWISE,
                 collect_trace: bool = False,
                 metrics: bool = False,
                 cost_model: Optional[StepCostModel] = None):
        self.arch = arch
        self.hardware = hardware
        self.plan = plan
        self.spec = spec
        self.collect_trace = collect_trace
        self.metrics = bool(metrics)
        self.cost = cost_model or StepCostModel(
            arch, hardware, plan, noc_mode=noc_mode,
            boundary_mode=boundary_mode, ctx_bucket=spec.ctx_bucket)

    # -- engine --------------------------------------------------------------
    def run(self) -> ServingReport:
        spec = self.spec
        requests = spec.workload.generate()
        kv = KVCacheModel.from_arch(self.arch, self.hardware.precision_bytes)
        budget = (spec.kv_budget_bytes if spec.kv_budget_bytes is not None
                  else self.cost.derive_kv_budget())
        batcher = ContinuousBatcher(kv, budget, max_batch=spec.max_batch,
                                    policy=spec.policy)
        env = Environment()
        rec = TraceRecorder() if self.collect_trace else None
        samples: List[List[float]] = []     # [t, queue_depth, kv_bytes]
        counts = {"prefill": 0, "decode": 0}
        kv_peak = [0.0]
        wake: List[Optional[Event]] = [None]

        def _wake_engine() -> None:
            evt = wake[0]
            if evt is not None and not evt.triggered:
                evt.succeed()

        def arrivals():
            for req in requests:
                if req.arrival > env.now:
                    yield env.timeout(req.arrival - env.now)
                batcher.add(req, env.now)
                _wake_engine()

        def _sample() -> None:
            used = batcher.kv_used_bytes
            kv_peak[0] = max(kv_peak[0], used)
            samples.append([env.now, float(batcher.queue_depth), used])

        def engine():
            total = len(requests)
            while len(batcher.finished) + len(batcher.rejected) < total:
                if not batcher.running and not batcher.waiting:
                    wake[0] = env.event("serve.wake")
                    yield wake[0]
                    wake[0] = None
                    continue
                admitted = batcher.admit(env.now)
                if admitted:
                    start = env.now
                    ctx = max(a.resume_context for a in admitted)
                    yield env.timeout(
                        self.cost.prefill_cost(len(admitted), ctx))
                    counts["prefill"] += 1
                    batcher.finish_prefill(admitted, env.now)
                    if rec is not None:
                        for a in admitted:
                            if start > a.enqueued_at:
                                rec.request(KIND_QUEUE, a.rid, a.episode,
                                            a.enqueued_at, start)
                            rec.request(KIND_PREFILL, a.rid, a.episode,
                                        start, env.now)
                elif batcher.running:
                    batch = batcher.decode_batch()
                    ctx = max(a.context for a in batch)
                    yield env.timeout(self.cost.decode_cost(len(batch), ctx))
                    counts["decode"] += 1
                    retired, evicted = batcher.finish_decode(env.now)
                    if rec is not None:
                        for a in retired:
                            rec.request(KIND_DECODE, a.rid, a.episode,
                                        a.decode_started_at, env.now)
                        for a in evicted:
                            rec.request(KIND_DECODE, a.rid, a.episode - 1,
                                        a.decode_started_at, env.now)
                _sample()

        from ..obs.registry import make_registry
        registry = make_registry(self.metrics)
        with registry.span("host.serving.run"):
            env.process(arrivals(), name="serve.arrivals")
            done = env.process(engine(), name="serve.engine")
            env.run(until_event=done)

        report = self._report(batcher, env, samples, counts, kv_peak[0],
                              budget, rec)
        if registry:
            registry.counter("host.serving.cost_sims").inc(self.cost.sims)
            registry.counter("host.serving.iterations").inc(
                counts["prefill"] + counts["decode"])
            from ..obs.simmetrics import serving_sim_metrics
            report.metrics = {"sim": serving_sim_metrics(report),
                              "host": registry.to_dict()}
        return report

    # -- report assembly -----------------------------------------------------
    def _report(self, batcher: ContinuousBatcher, env: Environment,
                samples: List[List[float]], counts: Dict[str, int],
                kv_peak: float, budget: float,
                rec: Optional[TraceRecorder]) -> ServingReport:
        spec = self.spec
        finished: List[ActiveRequest] = sorted(batcher.finished,
                                               key=lambda a: a.rid)
        total = len(finished) + len(batcher.rejected)
        sim_time = env.now

        ttfts, tpots, e2es = [], [], []
        per_req: List[Tuple[float, float]] = []     # (ttft, tpot) for SLO
        for a in finished:
            ttft = a.first_token_at - a.req.arrival
            e2e = a.finished_at - a.req.arrival
            n_out = a.req.decode_len
            tpot = ((a.finished_at - a.first_token_at) / (n_out - 1)
                    if n_out > 1 else 0.0)
            ttfts.append(ttft)
            tpots.append(tpot)
            e2es.append(e2e)
            per_req.append((ttft, tpot))

        def attainment(scale: float) -> float:
            if total == 0:
                return 0.0
            t_cap = spec.slo_ttft_ms * scale / 1e3
            p_cap = spec.slo_tpot_ms * scale / 1e3
            ok = sum(1 for t, p in per_req if t <= t_cap and p <= p_cap)
            return ok / total               # rejected requests count as misses

        n_ok = round(attainment(1.0) * total)
        out_tokens = sum(a.req.decode_len for a in finished)
        curve = [{"scale": s, "ttft_ms": spec.slo_ttft_ms * s,
                  "tpot_ms": spec.slo_tpot_ms * s, "attainment": attainment(s)}
                 for s in spec.slo_scales]

        trace = None
        if rec is not None:
            trace = rec.freeze(total_time=sim_time, num_stages=0)

        return ServingReport(
            arch=self.arch.name,
            hardware=self.hardware.name,
            plan=self.plan,
            num_requests=total,
            completed=len(finished),
            rejected=len(batcher.rejected),
            preemptions=batcher.preemptions,
            sim_time=sim_time,
            offered_rate=spec.workload.offered_rate,
            throughput_rps=len(finished) / sim_time if sim_time > 0 else 0.0,
            goodput_rps=n_ok / sim_time if sim_time > 0 else 0.0,
            tokens_per_s=out_tokens / sim_time if sim_time > 0 else 0.0,
            ttft=_stats(ttfts),
            tpot=_stats(tpots),
            e2e=_stats(e2es),
            slo={"ttft_ms": spec.slo_ttft_ms, "tpot_ms": spec.slo_tpot_ms,
                 "attainment": attainment(1.0)},
            slo_curve=curve,
            queue_depth=_thin([[t, q] for t, q, _ in samples],
                              spec.sample_limit),
            kv_occupancy_bytes=_thin([[t, b] for t, _, b in samples],
                                     spec.sample_limit),
            kv_peak_bytes=kv_peak,
            kv_budget_bytes=None if math.isinf(budget) else budget,
            steps={"prefill": counts["prefill"], "decode": counts["decode"],
                   "cost_sims": self.cost.sims, "events": env.event_count,
                   "policy": spec.policy},
            trace=trace,
        )


def simulate_serving(arch: Union[str, ArchConfig],
                     hardware: Union[str, HardwareSpec],
                     plan: Optional[ParallelPlan],
                     spec: ServingSpec, *,
                     noc_mode: NoCMode = NoCMode.MACRO,
                     boundary_mode: BoundaryMode = BoundaryMode.PAIRWISE,
                     collect_trace: bool = False,
                     metrics: bool = False,
                     cost_model: Optional[StepCostModel] = None) -> ServingReport:
    """One traffic-driven serving simulation (resolves registry names).
    ``plan=None`` serves on a single device (pp = dp = tp = 1)."""
    from ..api.experiment import resolve_hardware   # api builds on core
    from ..configs import get_config

    arch = get_config(arch) if isinstance(arch, str) else arch
    hw = resolve_hardware(hardware)
    if plan is None:
        plan = ParallelPlan(pp=1, dp=1, tp=1, microbatch=1, global_batch=1,
                            schedule=Schedule.GPIPE, training=False)
    sim = ServingSimulator(arch, hw, plan, spec, noc_mode=noc_mode,
                           boundary_mode=boundary_mode,
                           collect_trace=collect_trace,
                           metrics=metrics,
                           cost_model=cost_model)
    return sim.run()
