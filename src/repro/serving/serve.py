"""serve_step / prefill factories.

Decode sharding (DESIGN.md §5): KV cache batch over ``data``, sequence
over ``model`` (decode-time context parallelism — softmax over the
sharded KV span turns into small partial-stat collectives); SSM states
shard their head dim over ``model``. Parameters keep the FSDP x TP
layout: for 340B-class serving this is weight-streaming (per-layer
all-gather inside the scan), the Cerebras-style regime PALM cites [41],
mapped to TPU.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from ..configs.base import ArchConfig
from ..models.lm import RunCfg, decode_step, forward, init_cache
from ..parallel.sharding import ShardingPlanner
from .planner import plan_serving

__all__ = ["make_serve_step", "make_prefill_step", "greedy_generate",
           "plan_serving"]


def _mesh_cfg(cfg: RunCfg, mesh: Optional[Mesh]) -> RunCfg:
    if mesh is None:
        return cfg
    axes = ("pod", "data") if "pod" in mesh.axis_names else ("data",)
    return dataclasses.replace(cfg, mesh=mesh, batch_axes=axes)


def make_serve_step(arch: ArchConfig, cfg: RunCfg, mesh: Optional[Mesh] = None):
    """One greedy decode step: (params, cache, tokens|embeds, pos) ->
    (next_tokens [B], logits [B,V], new_cache)."""
    cfg = _mesh_cfg(cfg, mesh)

    def serve_step(params, cache, tokens, pos):
        kwargs = {"embeds": tokens} if arch.embeds_input else {"tokens": tokens}
        logits, new_cache = decode_step(arch, params, cache, pos=pos, cfg=cfg, **kwargs)
        next_tokens = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return next_tokens, logits, new_cache

    if mesh is None:
        return jax.jit(serve_step, donate_argnums=(1,))

    planner = ShardingPlanner(mesh, arch)

    def jit_with(params_shapes, cache_shapes, batch_size: int = 0):
        from ..parallel.sharding import fit_first
        p_sh = planner.params(params_shapes)
        c_sh = planner.cache(cache_shapes)
        b = batch_size or next(iter(jax.tree.leaves(cache_shapes))).shape[1]
        t_spec = fit_first([P(("data",))], (b,), mesh)  # replicate if B=1
        t_sh = planner.named(t_spec)
        return jax.jit(serve_step,
                       in_shardings=(p_sh, c_sh, t_sh, planner.named(P())),
                       out_shardings=(t_sh, None, c_sh),
                       donate_argnums=(1,))

    serve_step.jit_with = jit_with
    serve_step.planner = planner
    return serve_step


def make_prefill_step(arch: ArchConfig, cfg: RunCfg, mesh: Optional[Mesh] = None):
    """Batched prefill: full forward over the prompt (logits only —
    the dry-run's inference-prefill cell)."""
    cfg = _mesh_cfg(cfg, mesh)

    def prefill(params, batch):
        # causal archs: next-token logits only (a full [B,S,V] would be
        # petabyte-scale for 256k vocabs at 32k context)
        positions = "last" if arch.causal else "all"
        logits, _ = forward(arch, params,
                            tokens=batch.get("tokens"),
                            embeds=batch.get("embeds"), cfg=cfg,
                            logits_positions=positions)
        return logits

    if mesh is None:
        return jax.jit(prefill)

    planner = ShardingPlanner(mesh, arch)

    def jit_with(params_shapes, batch_shapes):
        p_sh = planner.params(params_shapes)
        b_sh = jax.tree.map(
            lambda leaf: planner.batch(example_shape=leaf.shape), batch_shapes)
        return jax.jit(prefill, in_shardings=(p_sh, b_sh), out_shardings=None)

    prefill.jit_with = jit_with
    return prefill


def greedy_generate(arch: ArchConfig, params, prompt_tokens: jax.Array,
                    max_new: int, cfg: RunCfg = RunCfg(),
                    mesh: Optional[Mesh] = None):
    """Reference end-to-end generation loop (CPU-scale; used by examples
    and tests): prefill token-by-token then decode ``max_new`` tokens.

    With a ``mesh`` — typically the ``(data, model)`` split
    :func:`plan_serving` suggests, built via
    :func:`repro.launch.mesh.make_serving_mesh` — the loop runs through
    :func:`make_serve_step` with the ShardingPlanner's KV-cache/parameter
    shardings instead of the single-device jit.
    """
    B, S0 = prompt_tokens.shape
    cache = init_cache(arch, B, S0 + max_new, cfg)
    if mesh is None:
        dstep = jax.jit(lambda p, c, t, i: decode_step(arch, p, c, tokens=t,
                                                       pos=i, cfg=cfg))

        def step(p, c, t, i):
            logits, c2 = dstep(p, c, t, i)
            return jnp.argmax(logits, axis=-1).astype(jnp.int32), c2
    else:
        serve = make_serve_step(arch, cfg, mesh)
        shapes = lambda tree: jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree)
        jitted = serve.jit_with(shapes(params), shapes(cache), batch_size=B)

        def step(p, c, t, i):
            nxt, _, c2 = jitted(p, c, t, i)
            return nxt, c2
    tok = prompt_tokens[:, 0]
    out = []
    for i in range(S0 + max_new - 1):
        nxt, cache = step(params, cache, tok, jnp.int32(i))
        if i + 1 < S0:
            tok = prompt_tokens[:, i + 1]
        else:
            tok = nxt
            out.append(tok)
    return jnp.stack(out, axis=1)
