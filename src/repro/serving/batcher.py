"""Continuous batcher: iteration-level admission + KV-cache pressure.

The batcher is the serving policy half of ``repro.serving.system`` — pure
bookkeeping, no event loop. The :class:`~.system.ServingSimulator` asks it
what the next engine iteration should do; it tracks the waiting queue, the
running batch, and KV-cache occupancy against a byte budget derived from
the same SRAM/DRAM :class:`~repro.core.sram.StageMemory` accounting the
training simulator uses.

Two policies:

* ``"continuous"`` — Orca/vLLM-style iteration-level scheduling: waiting
  requests are admitted into the running batch between decode iterations
  (prefill-prioritizing), and requests retire individually the moment
  their last token is emitted.
* ``"static"`` — classic batch serving: a batch is formed only when the
  previous one has fully drained, so short requests wait for the longest
  request in their batch (the baseline the goodput benchmark rigs
  against).

KV pressure: every decode iteration grows each running request's cache by
one token. When occupancy exceeds the budget the batcher preempts
most-recently-admitted requests (LIFO, the vLLM recompute policy):
their cache is dropped, they re-queue at the *front* of the waiting
queue, and on re-admission the whole context (prompt + tokens generated
so far) is re-prefilled — recompute-on-resume.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional, Tuple

from ..configs.base import ArchConfig
from .workload import Request

__all__ = ["KVCacheModel", "ActiveRequest", "ContinuousBatcher"]

CONTINUOUS, STATIC = "continuous", "static"
_POLICIES = (CONTINUOUS, STATIC)


@dataclass(frozen=True)
class KVCacheModel:
    """Per-request decode-cache footprint of an architecture.

    ``per_token_bytes`` covers the attention KV cache (2 x n_kv x head_dim
    per layer per token, capped at ``window`` tokens for sliding-window
    attention); ``fixed_bytes`` the per-request constant state (Mamba2 SSD
    state + conv buffer for ssm/hymba blocks).
    """

    per_token_bytes: float
    fixed_bytes: float
    window: int = 0     # 0 = full attention (cache grows with context)

    @classmethod
    def from_arch(cls, arch: ArchConfig, precision_bytes: int = 2) -> "KVCacheModel":
        per_tok = 0.0
        fixed = 0.0
        if arch.has_attention:
            per_tok = 2.0 * arch.n_kv * arch.head_dim * precision_bytes \
                * arch.num_layers
        if arch.block in ("ssm", "hymba"):
            # SSD state (n_heads x headdim x d_state == d_inner x d_state)
            # plus the depthwise-conv ring buffer
            fixed = float(arch.num_layers * precision_bytes
                          * (arch.d_inner * arch.ssm_state
                             + arch.d_inner * arch.conv_width))
        return cls(per_token_bytes=per_tok, fixed_bytes=fixed,
                   window=arch.window)

    def request_bytes(self, context_len: int) -> float:
        """Cache bytes for one request holding ``context_len`` tokens."""
        tokens = min(context_len, self.window) if self.window else context_len
        return self.fixed_bytes + tokens * self.per_token_bytes


@dataclass
class ActiveRequest:
    """Mutable serving state of one request across its lifetime."""

    req: Request
    enqueued_at: float          # last (re-)queue time, for the QUEUE lane
    episode: int = 0            # bumped on every eviction/resume
    generated: int = 0          # output tokens emitted so far
    context: int = 0            # tokens resident in the KV cache
    admitted_at: Optional[float] = None
    first_token_at: Optional[float] = None
    decode_started_at: Optional[float] = None   # this episode's decode start
    finished_at: Optional[float] = None
    preemptions: int = 0

    @property
    def rid(self) -> int:
        return self.req.rid

    @property
    def resume_context(self) -> int:
        """Tokens to (re-)prefill on admission: the prompt plus whatever
        was already generated before an eviction dropped the cache."""
        return self.req.prompt_len + self.generated

    @property
    def done(self) -> bool:
        return self.generated >= self.req.decode_len


class ContinuousBatcher:
    """Admission / retirement / preemption policy over a KV byte budget.

    The simulator owns time; every method takes ``now`` and returns what
    changed so the caller can record trace lanes. Determinism: all
    ordering is by explicit FIFO/LIFO position — no hashing, no clocks.
    """

    def __init__(self, kv: KVCacheModel, kv_budget_bytes: float,
                 max_batch: int = 32, policy: str = CONTINUOUS):
        if policy not in _POLICIES:
            raise ValueError(f"unknown batching policy {policy!r}; "
                             f"known: {', '.join(_POLICIES)}")
        if max_batch < 1:
            raise ValueError("max_batch must be >= 1")
        self.kv = kv
        self.kv_budget_bytes = float(kv_budget_bytes)
        self.max_batch = int(max_batch)
        self.policy = policy
        self.waiting: List[ActiveRequest] = []      # FIFO; resumes at front
        self.running: List[ActiveRequest] = []      # admission order (LIFO evict)
        self.finished: List[ActiveRequest] = []
        self.rejected: List[ActiveRequest] = []
        self.preemptions = 0

    # -- state ----------------------------------------------------------------
    @property
    def kv_used_bytes(self) -> float:
        return sum(self.kv.request_bytes(a.context) for a in self.running)

    @property
    def queue_depth(self) -> int:
        return len(self.waiting)

    @property
    def num_outstanding(self) -> int:
        return len(self.waiting) + len(self.running)

    # -- arrivals --------------------------------------------------------------
    def add(self, req: Request, now: float) -> Optional[ActiveRequest]:
        """New arrival. Requests whose full context (prompt + all decode
        tokens) can never fit the budget alone are rejected up front —
        the deadlock guard that keeps eviction from thrashing forever."""
        act = ActiveRequest(req=req, enqueued_at=now)
        if self.kv.request_bytes(req.total_tokens) > self.kv_budget_bytes:
            act.finished_at = now
            self.rejected.append(act)
            return None
        self.waiting.append(act)
        return act

    # -- admission -------------------------------------------------------------
    def admit(self, now: float) -> List[ActiveRequest]:
        """Move waiting requests into the running batch (front-of-queue
        first). Continuous policy admits between any two iterations;
        static policy only forms a new batch once the previous one has
        fully drained. Admitted requests still need their prefill —
        the caller runs it and then calls :meth:`finish_prefill`."""
        if self.policy == STATIC and self.running:
            return []
        admitted: List[ActiveRequest] = []
        used = self.kv_used_bytes
        while (self.waiting and
               len(self.running) + len(admitted) < self.max_batch):
            cand = self.waiting[0]
            need = self.kv.request_bytes(cand.resume_context)
            if used + need > self.kv_budget_bytes:
                break           # head-of-line blocking keeps FIFO fairness
            self.waiting.pop(0)
            cand.admitted_at = now
            used += need
            admitted.append(cand)
        self.running.extend(admitted)
        return admitted

    def finish_prefill(self, admitted: List[ActiveRequest],
                       now: float) -> List[ActiveRequest]:
        """Prefill done: contexts become resident and each admitted
        request's first *new* token of this episode is out (for episode 0
        that is the request's first token — TTFT stops here). Requests
        whose last token that was (``decode_len`` reached, e.g. single-
        token completions or a resume that recomputed to the end) retire
        immediately and are returned."""
        retired: List[ActiveRequest] = []
        for act in admitted:
            act.context = act.resume_context + 1    # prefill emits one token
            act.generated += 1
            act.decode_started_at = now
            if act.first_token_at is None:
                act.first_token_at = now
            if act.done:
                act.finished_at = now
                act.context = 0
                self.running.remove(act)
                self.finished.append(act)
                retired.append(act)
        return retired

    # -- decode ----------------------------------------------------------------
    def decode_batch(self) -> List[ActiveRequest]:
        return list(self.running)

    def finish_decode(self, now: float) -> Tuple[List[ActiveRequest],
                                                 List[ActiveRequest]]:
        """One decode iteration done: every running request emitted one
        token and its cache grew by one. Returns ``(retired, evicted)``:
        requests that emitted their last token retire; then, if the grown
        occupancy exceeds the budget, most-recently-admitted requests are
        preempted (cache dropped, re-queued at the front, episode += 1)
        until the rest fit. The longest-running request is never evicted
        (the deadlock guard in :meth:`add` guarantees it fits alone)."""
        retired: List[ActiveRequest] = []
        for act in self.running:
            act.generated += 1
            act.context += 1
        still: List[ActiveRequest] = []
        for act in self.running:
            if act.done:
                act.finished_at = now
                act.context = 0
                retired.append(act)
                self.finished.append(act)
            else:
                still.append(act)
        self.running = still
        evicted: List[ActiveRequest] = []
        while len(self.running) > 1 and self.kv_used_bytes > self.kv_budget_bytes:
            victim = self.running.pop()             # LIFO: newest admission
            victim.context = 0                      # recompute-on-resume
            victim.episode += 1
            victim.preemptions += 1
            victim.admitted_at = None
            victim.enqueued_at = now
            self.preemptions += 1
            evicted.append(victim)
        # resumes go to the *front*, oldest-first, so preempted requests
        # are not starved by fresh arrivals
        for victim in reversed(evicted):
            self.waiting.insert(0, victim)
        return retired, evicted
