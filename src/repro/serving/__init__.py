"""Serving runtime: batched prefill + decode with sharded KV/SSM caches."""

from .serve import make_prefill_step, make_serve_step, greedy_generate, plan_serving

__all__ = ["make_prefill_step", "make_serve_step", "greedy_generate",
           "plan_serving"]
