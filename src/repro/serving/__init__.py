"""Serving: batched prefill/decode runtime + traffic-driven system simulator.

Two halves:

* runtime (``serve``) — jax prefill/decode step factories with sharded
  KV/SSM caches (:func:`make_serve_step`, :func:`greedy_generate`);
* simulation (``workload`` / ``batcher`` / ``system`` / ``planner``) —
  dependency-free request-level serving simulator (continuous batching,
  KV-cache pressure, SLO metrics) built on the PALM event core.

The jax runtime is imported lazily so the simulation half (and
``python -m repro serve-sim`` / ``serve-plan``) works in jax-free
environments.
"""

from typing import TYPE_CHECKING

from .batcher import ActiveRequest, ContinuousBatcher, KVCacheModel
from .planner import plan_serving
from .system import (
    ServingReport,
    ServingSimulator,
    ServingSpec,
    StepCostModel,
    simulate_serving,
)
from .workload import Request, WorkloadSpec, workload_from_json, workload_to_json

if TYPE_CHECKING:                       # jax runtime half (lazy at runtime)
    from .serve import greedy_generate, make_prefill_step, make_serve_step

__all__ = [
    "ActiveRequest",
    "ContinuousBatcher",
    "KVCacheModel",
    "Request",
    "ServingReport",
    "ServingSimulator",
    "ServingSpec",
    "StepCostModel",
    "WorkloadSpec",
    "greedy_generate",
    "make_prefill_step",
    "make_serve_step",
    "plan_serving",
    "simulate_serving",
    "workload_from_json",
    "workload_to_json",
]

_JAX_EXPORTS = ("make_prefill_step", "make_serve_step", "greedy_generate")


def __getattr__(name: str):
    if name in _JAX_EXPORTS:
        from . import serve
        return getattr(serve, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
