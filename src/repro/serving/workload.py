"""Request workloads for the serving simulator (traffic generators).

A :class:`WorkloadSpec` describes an inference request stream the way the
TCO-survey pipeline frames it (workload -> simulator -> cost): a seeded
arrival process (Poisson or bursty Markov-modulated Poisson), prompt and
decode length distributions (fixed or discretized lognormal), or a
replayable request trace. :meth:`WorkloadSpec.generate` materializes the
deterministic request list — same spec, same seed, bit-identical
requests, in this process or a pool worker — and the JSON trace form
(:func:`workload_to_json` / :func:`workload_from_json`) makes any
generated stream replayable and shareable.

Everything here is dependency-free (``random.Random`` only) so the
serving simulator runs in the same environments as the event core.
"""

from __future__ import annotations

import json
import math
import random
from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Sequence

__all__ = ["Request", "WorkloadSpec", "workload_to_json", "workload_from_json"]

_SCHEMA = 1

# arrival-process kinds
POISSON, BURSTY, REPLAY = "poisson", "bursty", "replay"
_KINDS = (POISSON, BURSTY, REPLAY)


@dataclass(frozen=True)
class Request:
    """One inference request: arrival time (s), prompt length (tokens to
    prefill) and decode length (tokens to generate, >= 1 — the first
    output token comes out of the prefill)."""

    rid: int
    arrival: float
    prompt_len: int
    decode_len: int

    @property
    def total_tokens(self) -> int:
        return self.prompt_len + self.decode_len

    def to_row(self) -> List:
        return [self.arrival, self.prompt_len, self.decode_len]


def _lognormal_int(rng: random.Random, mean: float, cv: float,
                   lo: int, hi: Optional[int]) -> int:
    """Discretized lognormal with the given mean and coefficient of
    variation; ``cv=0`` degenerates to the (rounded) mean."""
    if cv <= 0:
        v = mean
    else:
        sigma2 = math.log(1.0 + cv * cv)
        mu = math.log(mean) - sigma2 / 2.0
        v = rng.lognormvariate(mu, math.sqrt(sigma2))
    out = max(lo, int(round(v)))
    return min(out, hi) if hi is not None else out


@dataclass
class WorkloadSpec:
    """Seeded request-stream description.

    ``kind`` selects the arrival process:

    * ``"poisson"`` — stationary Poisson arrivals at ``rate`` req/s.
    * ``"bursty"``  — two-state Markov-modulated Poisson: the rate
      alternates between ``rate * burst_factor`` (burst) and
      ``rate / burst_factor`` (lull), with exponentially distributed
      state dwell times of mean ``burst_dwell_s`` seconds. Exponential
      memorylessness makes the advance-to-switch-and-redraw simulation
      exact.
    * ``"replay"``  — play back an explicit request list (``requests``,
      e.g. loaded via :func:`workload_from_json`).

    Prompt/decode lengths draw from discretized lognormals with the given
    mean and coefficient of variation (``cv = 0`` means fixed lengths);
    decode lengths are always >= 1 (the prefill emits the first token).
    """

    kind: str = POISSON
    rate: float = 4.0                     # mean arrival rate (requests/s)
    num_requests: int = 64
    seed: int = 0
    prompt_mean: float = 512.0
    prompt_cv: float = 0.0
    prompt_max: Optional[int] = None
    decode_mean: float = 64.0
    decode_cv: float = 0.0
    decode_max: Optional[int] = None
    burst_factor: float = 4.0             # bursty: hi = rate*f, lo = rate/f
    burst_dwell_s: float = 2.0            # mean dwell per MMPP state
    # replay payload (kind == "replay"); rows are [arrival, prompt, decode]
    requests: Optional[List[List]] = None

    def __post_init__(self):
        if self.kind not in _KINDS:
            raise ValueError(f"unknown workload kind {self.kind!r}; "
                             f"known: {', '.join(_KINDS)}")
        if self.kind == REPLAY:
            if not self.requests:
                raise ValueError("replay workload needs a `requests` list")
        else:
            if self.rate <= 0:
                raise ValueError("arrival rate must be > 0")
            if self.num_requests < 1:
                raise ValueError("num_requests must be >= 1")
        if self.kind == BURSTY and self.burst_factor < 1:
            raise ValueError("burst_factor must be >= 1")

    # -- generation ----------------------------------------------------------
    def _arrivals(self, rng: random.Random) -> List[float]:
        if self.kind == POISSON:
            t, out = 0.0, []
            for _ in range(self.num_requests):
                t += rng.expovariate(self.rate)
                out.append(t)
            return out
        # bursty MMPP: start in the burst state (deterministic), draw the
        # next state-switch time, advance gap-by-gap
        hi, lo = self.rate * self.burst_factor, self.rate / self.burst_factor
        state_rate = hi
        t = 0.0
        t_switch = rng.expovariate(1.0 / self.burst_dwell_s)
        out: List[float] = []
        while len(out) < self.num_requests:
            gap = rng.expovariate(state_rate)
            if t + gap >= t_switch:
                # memoryless: jump to the switch point and redraw at the
                # new rate — an exact MMPP simulation, not an approximation
                t = t_switch
                state_rate = lo if state_rate == hi else hi
                t_switch = t + rng.expovariate(1.0 / self.burst_dwell_s)
                continue
            t += gap
            out.append(t)
        return out

    def generate(self) -> List[Request]:
        """The deterministic request list for this spec (seeded)."""
        if self.kind == REPLAY:
            reqs = [Request(rid=i, arrival=float(a), prompt_len=int(p),
                            decode_len=max(1, int(d)))
                    for i, (a, p, d) in enumerate(self.requests)]
            return sorted(reqs, key=lambda r: (r.arrival, r.rid))
        rng = random.Random(self.seed)
        arrivals = self._arrivals(rng)
        out = []
        for i, t in enumerate(arrivals):
            prompt = _lognormal_int(rng, self.prompt_mean, self.prompt_cv,
                                    lo=1, hi=self.prompt_max)
            decode = _lognormal_int(rng, self.decode_mean, self.decode_cv,
                                    lo=1, hi=self.decode_max)
            out.append(Request(rid=i, arrival=t, prompt_len=prompt,
                               decode_len=decode))
        return out

    @property
    def offered_rate(self) -> float:
        """Mean offered arrival rate (requests/s)."""
        if self.kind != REPLAY:
            return self.rate
        rows = self.requests or []
        if len(rows) < 2:
            return 0.0
        span = max(r[0] for r in rows) - min(r[0] for r in rows)
        return (len(rows) - 1) / span if span > 0 else 0.0

    # -- serialization -------------------------------------------------------
    def to_dict(self) -> Dict[str, Any]:
        d = {
            "kind": self.kind, "rate": self.rate,
            "num_requests": self.num_requests, "seed": self.seed,
            "prompt_mean": self.prompt_mean, "prompt_cv": self.prompt_cv,
            "prompt_max": self.prompt_max,
            "decode_mean": self.decode_mean, "decode_cv": self.decode_cv,
            "decode_max": self.decode_max,
            "burst_factor": self.burst_factor,
            "burst_dwell_s": self.burst_dwell_s,
        }
        if self.requests is not None:
            d["requests"] = [list(r) for r in self.requests]
        return d

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "WorkloadSpec":
        return cls(**dict(d))


def workload_to_json(requests: Sequence[Request], **kw: Any) -> str:
    """Replayable JSON trace of a concrete request list."""
    return json.dumps({"schema": _SCHEMA,
                       "requests": [r.to_row() for r in requests]}, **kw)


def workload_from_json(text: str) -> WorkloadSpec:
    """Parse a request-trace JSON document into a replay WorkloadSpec."""
    doc = json.loads(text)
    if doc.get("schema", _SCHEMA) != _SCHEMA:
        raise ValueError(f"unknown workload schema {doc.get('schema')!r}")
    rows = doc.get("requests")
    if not isinstance(rows, list) or not rows:
        raise ValueError("workload trace needs a non-empty `requests` list")
    return WorkloadSpec(kind=REPLAY, requests=[list(r) for r in rows])
