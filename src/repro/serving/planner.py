"""Serving-split planner: pick a ``(data, model)`` mesh for decode.

jax-free half of the serving planner (``repro.serving.serve`` re-exports
:func:`plan_serving` for the runtime side). Sweeps the 1-token decode
graph over ``dp x tp`` splits of the device count through the PALM
simulator — the same two axes the runtime's ShardingPlanner shards over
(KV-cache batch on ``data``, heads/features on ``model``).

All ``repro.api`` imports happen at call time: ``repro.api.experiment``
imports ``repro.serving`` at module level (for the ``Experiment.serving``
field), so importing api from here at import time would cycle.
"""

from __future__ import annotations

from typing import TYPE_CHECKING, Optional

if TYPE_CHECKING:
    from ..api.report import SweepReport
    from ..api.sweep import SweepEngine
    from ..configs.base import ArchConfig

__all__ = ["plan_serving"]


def _fmt_bytes(b: float) -> str:
    for scale, suffix in ((1e9, "GB"), (1e6, "MB"), (1e3, "KB")):
        if abs(b) >= scale:
            return f"{b / scale:.2f} {suffix}"
    return f"{b:.0f} B"


def _infeasibility_message(arch_name: str, hw_name: str,
                           report: "SweepReport") -> str:
    """Explain *why* no serving split was feasible from the sweep's
    pruned/failed diagnostic records instead of a bare 'nothing fit'."""
    lines = [f"no feasible serving split for {arch_name} on {hw_name}: "
             f"{report.num_candidates} candidate(s), "
             f"{report.num_pruned_memory} memory-pruned, "
             f"{report.num_failed} failed"]
    for rec in report.pruned_records:
        p = rec.get("plan", {})
        split = f"(dp={p.get('dp', '?')}, tp={p.get('tp', '?')})"
        if "deficit_bytes" in rec:
            lines.append(
                f"  {split}: peak {_fmt_bytes(rec['peak_bytes'])} over the "
                f"{_fmt_bytes(rec['cap_bytes'])} per-tile cap by "
                f"{_fmt_bytes(rec['deficit_bytes'])}")
        else:
            lines.append(f"  {split}: memory-pruned")
    for rec in report.failed_records:
        p = rec.get("plan", {})
        lines.append(f"  (dp={p.get('dp', '?')}, tp={p.get('tp', '?')}): "
                     f"{rec.get('reason', 'failed')}")
    return "\n".join(lines)


def plan_serving(arch: "ArchConfig | str", hardware="tpu_v5e", batch: int = 8,
                 context_len: int = 4096, workers: int = 0,
                 collect_timeline: bool = False,
                 memory_cap: Optional[float] = None,
                 engine: Optional["SweepEngine"] = None):
    """Pick a ``(data, model)`` mesh split for serving by sweeping
    decode-step parallelism through the PALM simulator.

    The decode graph (1-token step against a ``context_len`` KV cache) is
    swept over ``dp x tp`` splits of the device count. Returns
    ``(mesh_axes, SweepReport)`` where ``mesh_axes`` is ``{"data": dp,
    "model": tp}`` for the highest simulated decode throughput.

    ``collect_timeline=True`` attaches each candidate's columnar event
    timeline to ``RunReport.trace`` — the *same*
    :class:`~repro.core.trace.Trace` schema training simulations emit, so
    serving and training timelines can be compared (or rendered through
    :func:`repro.core.trace.chrome_trace`) side by side.

    ``memory_cap`` (bytes per tile) prunes splits whose mapped decode
    graph cannot fit before simulating them; when every split is
    infeasible the raised ``RuntimeError`` lists each pruned split's
    per-tile deficit (from ``SweepReport.pruned_records``) so the caller
    can see *how far* over budget the model is on this machine.

    ``engine`` lends an open persistent :class:`SweepEngine` (its warm
    process pool is reused and never closed here); defaults to the
    module-level :func:`repro.api.sweep.shared_engine` pool so repeated
    planning calls reuse one warm engine.
    """
    from ..api import Experiment, Layout, SearchSpace, resolve_hardware
    from ..api.sweep import shared_engine
    from ..configs import get_config

    if engine is None:
        engine = shared_engine(workers=workers,
                               return_timelines=collect_timeline,
                               trace_resources=collect_timeline)
    arch = get_config(arch) if isinstance(arch, str) else arch
    hw = resolve_hardware(hardware)
    n = hw.num_devices
    degrees = [(1, dp, n // dp) for dp in range(1, n + 1)
               if n % dp == 0 and batch % dp == 0]
    # one layout and max_plans == len(degrees): every split is simulated
    # (the diversity budget would otherwise keep layout duplicates of
    # low-dp splits and drop the high-dp ones)
    report = Experiment(
        arch=arch,
        hardware=hw,
        search=SearchSpace(degrees=degrees, microbatch_sizes=(1,),
                           layouts=(Layout.S_SHAPE,),
                           max_plans=len(degrees) or 1),
        seq_len=context_len,
        global_batch=batch,
        training=False,
        decode=True,
        memory_cap=memory_cap,
        collect_timeline=collect_timeline,   # full NoC/DRAM lanes in traces
    ).sweep(workers=workers, engine=engine)
    if report.best is None:
        raise RuntimeError(_infeasibility_message(arch.name, hw.name, report))
    best = report.best.plan
    return {"data": best.dp, "model": best.tp}, report
