"""Typed, seedable encoding of the joint hardware x parallelism space.

An :class:`EncodedSpace` materializes the same candidate universe the
exhaustive sweep enumerates — every ``(hardware variant, parallel plan)``
pair derived from an Experiment's :class:`SearchSpace` and optional
:class:`HardwareSearchSpace` — behind an index-based interface search
strategies can sample and mutate:

* a :class:`Candidate` is ``(variant index, plan index)``; the flat
  candidate order matches the exhaustive job stream exactly, which is
  what makes ``--search exhaustive`` bit-identical to the legacy path
  and keeps fixed-seed runs reproducible across serial/pool executors;
* hardware variants keep their *factored* axis structure (the
  mixed-radix digits of :meth:`HardwareSearchSpace.enumerate_specs`'s
  cartesian product), so :meth:`mutate` can take single-axis steps
  through the hardware space instead of teleporting;
* plan lists are enumeration-ordered (nested loops over the SearchSpace
  axes), so small plan-index steps are local moves in plan space.

Enumeration is cheap — no simulation happens here; the simulator budget
is what the strategies in :mod:`repro.search.strategies` manage.
"""

from __future__ import annotations

import itertools
import random
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple, TYPE_CHECKING

from ..core.hardware import HardwareSpec
from ..core.parallelism import ParallelPlan

if TYPE_CHECKING:                       # avoid importing api at module load
    from ..api.experiment import Experiment

__all__ = ["Candidate", "EncodedSpace"]


@dataclass(frozen=True)
class Candidate:
    """One point of the encoded space: a kept hardware-variant index plus
    a plan index within that variant's enumeration-ordered plan list."""

    variant: int
    plan_index: int

    @property
    def key(self) -> Tuple[int, int]:
        return (self.variant, self.plan_index)


class EncodedSpace:
    """Candidate universe for guided search (see module docstring)."""

    def __init__(self, specs: Sequence[HardwareSpec],
                 plans: Sequence[Sequence[ParallelPlan]],
                 digits: Optional[Sequence[Tuple[int, ...]]] = None,
                 radices: Sequence[Tuple[str, int]] = (),
                 num_enumerated: Optional[int] = None,
                 extra_failed: int = 0):
        if len(specs) != len(plans):
            raise ValueError("one plan list per hardware variant required")
        self.specs = list(specs)
        self.plans = [list(p) for p in plans]
        self.radices = list(radices)        # (hardware axis name, size)
        self.extra_failed = int(extra_failed)
        self.num_enumerated = (len(self.specs) + self.extra_failed
                               if num_enumerated is None else num_enumerated)
        self._digits = list(digits) if digits is not None else \
            [(i,) for i in range(len(self.specs))]
        self._by_digits: Dict[Tuple[int, ...], int] = {
            d: v for v, d in enumerate(self._digits)}
        # flat-index offsets (variant-major, exhaustive job-stream order)
        self._starts: List[int] = []
        total = 0
        for p in self.plans:
            self._starts.append(total)
            total += len(p)
        self._total = total

    # -- construction --------------------------------------------------------
    @classmethod
    def from_experiment(cls, exp: "Experiment") -> "EncodedSpace":
        """Encode an Experiment's joint search space. Variants that cannot
        host any plan (too few devices for explicit degrees / the fixed
        plan) are dropped and counted, mirroring the exhaustive sweep."""
        base = exp.hardware_spec
        hs = exp.hardware_search
        if hs is not None:
            enumerated = hs.enumerate_specs(base)
            radices = [(name, max(1, len(tuple(vals))))
                       for name, vals, _, _ in hs._axes()]
            digit_iter = itertools.product(*(range(r) for _, r in radices))
            all_digits = list(itertools.islice(digit_iter, len(enumerated)))
        else:
            enumerated = [base]
            radices = []
            all_digits = [()]
        specs: List[HardwareSpec] = []
        plans: List[List[ParallelPlan]] = []
        digits: List[Tuple[int, ...]] = []
        failed = 0
        for spec, dg in zip(enumerated, all_digits):
            try:
                plan_list = exp._plans_for(spec)
            except ValueError:
                failed += 1
                continue
            specs.append(spec)
            plans.append(plan_list)
            digits.append(dg)
        return cls(specs, plans, digits=digits, radices=radices,
                   num_enumerated=len(enumerated), extra_failed=failed)

    # -- basics --------------------------------------------------------------
    def __len__(self) -> int:
        return self._total

    def __repr__(self) -> str:
        return (f"EncodedSpace({self._total} candidates, "
                f"{len(self.specs)} hardware variants)")

    def describe(self) -> Dict[str, object]:
        """Axis sizes (introspection / docs)."""
        return {
            "candidates": self._total,
            "hardware_variants": len(self.specs),
            "hardware_axes": {name: size for name, size in self.radices
                              if size > 1},
            "plans_per_variant": [len(p) for p in self.plans],
        }

    def job(self, cand: Candidate) -> Tuple[int, ParallelPlan]:
        """The sweep-engine job for a candidate."""
        return (cand.variant, self.plans[cand.variant][cand.plan_index])

    def jobs(self) -> List[Tuple[int, ParallelPlan]]:
        """Every job in exhaustive enumeration order (variant-major)."""
        return [(v, p) for v, plist in enumerate(self.plans) for p in plist]

    def flat_index(self, cand: Candidate) -> int:
        return self._starts[cand.variant] + cand.plan_index

    def from_flat(self, i: int) -> Candidate:
        if not 0 <= i < self._total:
            raise IndexError(i)
        # starts is sorted; linear scan is fine at these sizes
        v = max(vi for vi, s in enumerate(self._starts) if s <= i
                and self.plans[vi])
        return Candidate(v, i - self._starts[v])

    # -- sampling ------------------------------------------------------------
    def sample(self, rng: random.Random) -> Candidate:
        """One uniform candidate."""
        return self.from_flat(rng.randrange(self._total))

    def sample_many(self, rng: random.Random, k: int) -> List[Candidate]:
        """``k`` distinct candidates (all of them when ``k >= len``),
        returned in flat order for deterministic evaluation batches."""
        k = min(k, self._total)
        if k == self._total:
            ids: Sequence[int] = range(self._total)
        else:
            ids = sorted(rng.sample(range(self._total), k))
        return [self.from_flat(i) for i in ids]

    # -- local moves ---------------------------------------------------------
    def mutate(self, cand: Candidate, rng: random.Random,
               attempts: int = 16) -> Candidate:
        """One local move: step a single hardware axis (mixed-radix digit
        +-1, wrapping) keeping the plan position, or move the plan index
        within the variant (small step, occasionally a uniform re-draw).
        Falls back to a uniform sample when no valid neighbour is found
        (e.g. truncated/failed variants)."""
        for _ in range(attempts):
            hw_axes = [i for i, (_, r) in enumerate(self.radices) if r > 1]
            move_hw = bool(hw_axes) and len(self.specs) > 1 and (
                len(self.plans[cand.variant]) <= 1 or rng.random() < 0.5)
            if move_hw:
                ax = rng.choice(hw_axes)
                step = rng.choice((-1, 1))
                digits = list(self._digits[cand.variant])
                digits[ax] = (digits[ax] + step) % self.radices[ax][1]
                v = self._by_digits.get(tuple(digits))
                if v is None or not self.plans[v]:
                    continue            # truncated by max_specs, or failed
                pi = min(cand.plan_index, len(self.plans[v]) - 1)
                if (v, pi) != cand.key:
                    return Candidate(v, pi)
                continue
            n = len(self.plans[cand.variant])
            if n <= 1:
                continue
            if rng.random() < 0.3:      # occasional uniform re-draw
                pi = rng.randrange(n - 1)
                if pi >= cand.plan_index:
                    pi += 1
            else:                       # local step
                pi = (cand.plan_index + rng.choice((-2, -1, 1, 2))) % n
            if pi != cand.plan_index:
                return Candidate(cand.variant, pi)
        return self.sample(rng)
