"""Ask/tell search strategies over an :class:`EncodedSpace`.

Every strategy implements the same protocol: :meth:`ask` returns a batch
of ``(candidate, fidelity)`` pairs to evaluate (one *generation* — the
controller dispatches the whole batch through the shared-pool sweep
engine, so workers stay warm across generations), :meth:`tell` receives
the outcomes in ask order, and an empty ask ends the search. All
randomness flows through one ``random.Random(seed)``, and candidates
inside a generation are ordered by flat index, so fixed-seed runs are
bit-reproducible regardless of the executor (serial vs process pool).

Budget semantics (shared by every strategy and the CLI ``--search-budget``
flag): the budget counts **full-fidelity simulations** — the expensive
evaluations an exhaustive sweep would spend one per candidate. Reduced
rungs (coarser NoC model, truncated microbatch count) are the cheap
currency multi-fidelity strategies trade in; they are accounted in
``SearchReport.sims_per_fidelity`` but not budget-capped.

* :class:`RandomSearch` — the baseline: ``budget`` uniform candidates,
  all at full fidelity.
* :class:`SuccessiveHalving` — evaluates a large cohort at the cheapest
  rung and halves it (keep the top ``1/eta``) while climbing the
  fidelity ladder; the final (full-fidelity) rung is sized so it can
  never exceed the budget.
* :class:`Evolutionary` — (mu + lambda) local search: tournament-selected
  parents produce single-axis mutants (one hardware-axis step or a local
  plan move); meant for large factored hardware spaces where good
  variants cluster along axes.
"""

from __future__ import annotations

import random
from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Protocol, Sequence, Tuple

from .fidelity import FULL, Fidelity
from .report import RungRecord
from .space import Candidate, EncodedSpace

__all__ = ["EvalOutcome", "Optimizer", "RandomSearch", "SuccessiveHalving",
           "Evolutionary", "STRATEGIES", "make_strategy"]

Ask = List[Tuple[Candidate, Fidelity]]


@dataclass
class EvalOutcome:
    """One evaluation result handed back to :meth:`Optimizer.tell`."""

    candidate: Candidate
    fidelity: Fidelity
    ok: bool                    # simulated successfully (not pruned/failed)
    throughput: float = 0.0     # 0.0 when not ok
    cached: bool = False        # reused a previous evaluation (cost nothing)
    report: Optional[Any] = None    # the RunReport when ok


class Optimizer(Protocol):
    """Ask/tell search driver over an EncodedSpace."""

    def ask(self) -> Ask:
        """Next generation to evaluate; empty list ends the search."""
        ...

    def tell(self, outcomes: List[EvalOutcome]) -> None:
        """Outcomes for the last ask, in ask order."""
        ...

    def rung_records(self) -> List[RungRecord]:
        """Per-generation history for the SearchReport."""
        ...


def _ranked(outcomes: Sequence[EvalOutcome],
            space: EncodedSpace) -> List[EvalOutcome]:
    """Successful outcomes best-first; ties break on flat index so the
    ordering is independent of executor and dict iteration order."""
    return sorted((o for o in outcomes if o.ok),
                  key=lambda o: (-o.throughput,
                                 space.flat_index(o.candidate)))


class RandomSearch:
    """Uniform sampling without replacement at full fidelity."""

    def __init__(self, space: EncodedSpace, budget: int, seed: int = 0):
        self.space = space
        self.budget = max(1, budget)
        self._rng = random.Random(seed)
        self._pending = space.sample_many(self._rng, self.budget)
        self._records: List[RungRecord] = []

    def ask(self) -> Ask:
        batch, self._pending = self._pending, []
        return [(c, FULL) for c in batch]

    def tell(self, outcomes: List[EvalOutcome]) -> None:
        self._records.append(RungRecord(
            rung=len(self._records), fidelity=FULL.name,
            evaluated=len(outcomes), promoted=0))

    def rung_records(self) -> List[RungRecord]:
        return list(self._records)


class SuccessiveHalving:
    """Fidelity-climbing successive halving (Hyperband's inner loop).

    With ladder rungs ``f_0 .. f_{R-1}`` (cheapest first, ``f_{R-1}`` =
    full) and reduction factor ``eta``, the initial cohort holds
    ``min(space, budget * eta^(R-1))`` candidates; rung ``r`` keeps the
    top ``n_0 / eta^r``. The final rung size is additionally clamped to
    ``budget``, so the strategy can never promote past its full-fidelity
    budget.
    """

    def __init__(self, space: EncodedSpace, budget: int, seed: int = 0,
                 ladder: Optional[Sequence[Fidelity]] = None, eta: int = 2):
        if eta < 2:
            raise ValueError("eta must be >= 2")
        self.space = space
        self.budget = max(1, budget)
        self.eta = eta
        self.ladder = list(ladder) if ladder is not None else [FULL]
        if not self.ladder or not self.ladder[-1].is_full:
            raise ValueError("fidelity ladder must end at full fidelity")
        self._rng = random.Random(seed)
        R = len(self.ladder)
        n0 = min(len(space), self.budget * eta ** (R - 1))
        # per-rung cohort budgets; the last is the full-fidelity budget
        self._rung_sizes = [max(1, n0 // eta ** r) for r in range(R)]
        self._rung_sizes[-1] = min(self._rung_sizes[-1], self.budget)
        self._cohort = space.sample_many(self._rng, n0)
        self._rung = 0
        self._records: List[RungRecord] = []

    def ask(self) -> Ask:
        if self._rung >= len(self.ladder) or not self._cohort:
            return []
        fid = self.ladder[self._rung]
        return [(c, fid) for c in self._cohort]

    def tell(self, outcomes: List[EvalOutcome]) -> None:
        nxt = self._rung + 1
        if nxt < len(self.ladder):
            keep = _ranked(outcomes, self.space)[:self._rung_sizes[nxt]]
            cohort = sorted((o.candidate for o in keep),
                            key=self.space.flat_index)
        else:
            cohort = []
        self._records.append(RungRecord(
            rung=self._rung, fidelity=self.ladder[self._rung].name,
            evaluated=len(outcomes), promoted=len(cohort)))
        self._cohort = cohort
        self._rung = nxt

    def rung_records(self) -> List[RungRecord]:
        return list(self._records)


class Evolutionary:
    """(mu + lambda) evolution with tournament selection and the space's
    single-axis mutation operator, at full fidelity throughout."""

    def __init__(self, space: EncodedSpace, budget: int, seed: int = 0,
                 population: Optional[int] = None, tournament: int = 2,
                 max_stalls: int = 3):
        self.space = space
        self.budget = max(1, budget)
        self._rng = random.Random(seed)
        self.population = min(len(space),
                              population or max(4, self.budget // 4))
        self.tournament = max(1, tournament)
        self._pop: List[EvalOutcome] = []
        self._spent = 0                  # unique full-fidelity evaluations
        self._stalls = 0                 # generations that added no new sims
        self.max_stalls = max_stalls
        self._pending = space.sample_many(
            self._rng, min(self.population, self.budget))
        self._records: List[RungRecord] = []

    def _parent(self) -> Candidate:
        k = max(1, min(len(self._pop), self.tournament))
        contenders = [self._pop[self._rng.randrange(len(self._pop))]
                      for _ in range(k)]
        best = max(contenders,
                   key=lambda o: (o.throughput,
                                  -self.space.flat_index(o.candidate)))
        return best.candidate

    def ask(self) -> Ask:
        if self._pending:
            batch, self._pending = self._pending, []
            return [(c, FULL) for c in batch]
        if (self._spent >= self.budget or not self._pop
                or self._stalls >= self.max_stalls):
            return []
        lam = min(self.population, self.budget - self._spent)
        seen = set()
        children: List[Candidate] = []
        for _ in range(lam):
            child = self.space.mutate(self._parent(), self._rng)
            if child.key not in seen:
                seen.add(child.key)
                children.append(child)
        children.sort(key=self.space.flat_index)
        return [(c, FULL) for c in children]

    def tell(self, outcomes: List[EvalOutcome]) -> None:
        fresh = sum(1 for o in outcomes if not o.cached)
        self._spent += fresh
        self._stalls = 0 if fresh else self._stalls + 1
        survivors = _ranked(list(self._pop) + [o for o in outcomes if o.ok],
                            self.space)
        # dedup by candidate (an outcome may re-enter via the cache)
        seen: Dict[Tuple[int, int], None] = {}
        pop: List[EvalOutcome] = []
        for o in survivors:
            if o.candidate.key not in seen:
                seen[o.candidate.key] = None
                pop.append(o)
            if len(pop) >= self.population:
                break
        entered = sum(1 for o in outcomes
                      if o.ok and any(p.candidate.key == o.candidate.key
                                      for p in pop))
        self._records.append(RungRecord(
            rung=len(self._records), fidelity=FULL.name,
            evaluated=len(outcomes), promoted=entered))
        self._pop = pop

    def rung_records(self) -> List[RungRecord]:
        return list(self._records)


STRATEGIES = {
    "random": RandomSearch,
    "sh": SuccessiveHalving,
    "evolve": Evolutionary,
}


def make_strategy(name: str, space: EncodedSpace, budget: int, seed: int = 0,
                  ladder: Optional[Sequence[Fidelity]] = None, **kw):
    """Instantiate a registered strategy by CLI name (``random`` / ``sh``
    / ``evolve``; ``exhaustive`` is the legacy sweep path, not a
    strategy)."""
    if name not in STRATEGIES:
        known = ", ".join(sorted(STRATEGIES) + ["exhaustive"])
        raise ValueError(f"unknown search strategy {name!r}; known: {known}")
    if name == "sh":
        return SuccessiveHalving(space, budget, seed=seed, ladder=ladder, **kw)
    return STRATEGIES[name](space, budget, seed=seed, **kw)
