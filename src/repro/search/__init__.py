"""Guided multi-fidelity search over joint hardware x parallelism spaces.

The package behind ``Experiment.sweep(strategy=...)``, ``plan_codesign``
co-design search, and ``python -m repro {sweep,plan} --search ...``:

* :class:`EncodedSpace` / :class:`Candidate` — typed, seedable encoding
  of the joint space (discrete plan axes + the factored hardware axes of
  :class:`~repro.api.HardwareSearchSpace`);
* :class:`Fidelity` / :func:`default_ladder` — the simulation-fidelity
  rung model (NoC-model coarsening + microbatch truncation);
* :class:`RandomSearch`, :class:`SuccessiveHalving`,
  :class:`Evolutionary` — ask/tell strategies (:class:`Optimizer`);
* :func:`run_search` — the generation loop over one persistent
  shared-pool :class:`~repro.api.SweepEngine`;
* :class:`SearchReport` — spend/convergence accounting nested into
  :class:`~repro.api.SweepReport`.

See ``docs/search.md`` for the model and budget semantics.
"""

from .fidelity import FULL, Fidelity, default_ladder
from .space import Candidate, EncodedSpace
from .strategies import (
    STRATEGIES,
    EvalOutcome,
    Evolutionary,
    Optimizer,
    RandomSearch,
    SuccessiveHalving,
    make_strategy,
)
from .report import RungRecord, SearchReport
from .engine import run_search

__all__ = [
    "Candidate",
    "EncodedSpace",
    "EvalOutcome",
    "Evolutionary",
    "FULL",
    "Fidelity",
    "Optimizer",
    "RandomSearch",
    "RungRecord",
    "STRATEGIES",
    "SearchReport",
    "SuccessiveHalving",
    "default_ladder",
    "make_strategy",
    "run_search",
]
