"""Simulation-fidelity rungs for multi-fidelity search.

The PALM simulator exposes two natural cost knobs, and both preserve
the *relative* ordering of candidates well enough to steer a search:

* **NoC model fidelity** (:class:`~repro.core.enums.NoCMode`): the pure
  analytical ring model and the per-collective macro model are orders of
  magnitude cheaper than per-link event-driven simulation;
* **microbatch count**: event count is O(M) in the number of pipeline
  microbatches, and a run truncated to a few microbatches already prices
  the steady-state stage times, collectives and DRAM streams — only the
  ramp-up/ramp-down amortization shifts.

A :class:`Fidelity` bundles both knobs. ``Fidelity()`` (no overrides) is
*full* fidelity: evaluating a candidate under it is exactly the
evaluation the exhaustive sweep performs, which is why final rungs and
final reports are comparable across search strategies.

Reducing the microbatch count only ever *lowers* the per-tile memory
footprint (fewer in-flight microbatches), so a low-fidelity rung never
memory-prunes a candidate the full-fidelity evaluation would keep.
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional

from ..core.enums import NoCMode
from ..core.parallelism import ParallelPlan

__all__ = ["Fidelity", "FULL", "default_ladder"]


@dataclasses.dataclass(frozen=True)
class Fidelity:
    """One simulation-fidelity point; picklable, ships inside pool jobs."""

    name: str = "full"
    noc_mode: Optional[NoCMode] = None       # None = the experiment's mode
    max_microbatches: Optional[int] = None   # None = the plan's full count
    max_requests: Optional[int] = None       # None = the workload's full count
    # simulator tier (repro.core.fastpath): None = the experiment's engine.
    # "auto" is result-preserving (the fast tier is bit-identical when it
    # fires), so it does NOT reduce fidelity — it's a pure cost knob and
    # the natural floor of every ladder.
    engine: Optional[str] = None

    def __post_init__(self):
        if self.noc_mode is not None:
            object.__setattr__(self, "noc_mode", NoCMode(self.noc_mode))
        if self.engine is not None and self.engine not in ("event", "auto",
                                                           "fast"):
            raise ValueError(f"unknown engine {self.engine!r}")
        if self.max_microbatches is not None and self.max_microbatches < 1:
            raise ValueError("max_microbatches must be >= 1")
        if self.max_requests is not None and self.max_requests < 1:
            raise ValueError("max_requests must be >= 1")
        if self.name == "full" and not self.is_full:
            # a reduced rung must never masquerade as "full" in the
            # accounting — derive a descriptive name instead
            noc = str(self.noc_mode) if self.noc_mode is not None else "noc"
            mb = (f"mb{self.max_microbatches}"
                  if self.max_microbatches is not None else "mball")
            object.__setattr__(self, "name", f"{noc}-{mb}")

    @property
    def is_full(self) -> bool:
        return (self.noc_mode is None and self.max_microbatches is None
                and self.max_requests is None)

    def resolve(self, plan: ParallelPlan, noc_mode: NoCMode,
                engine: str) -> tuple:
        """Apply every knob of this rung to a job's effective
        ``(plan, noc_mode, engine)`` triple (the sweep engine's
        :func:`~repro.api.sweep._prepare` calls this per job). The
        returned engine also decides *batching*: ``"auto"``/``"fast"``
        jobs are grouped by chain shape and priced through the vectorized
        batched fast tier (:mod:`repro.core.fastbatch`), so cheap rungs
        of a ladder evaluate whole generations in a few numpy passes."""
        plan = self.apply(plan)
        if self.noc_mode is not None:
            noc_mode = NoCMode(self.noc_mode)
        if self.engine is not None:
            engine = self.engine
        return plan, noc_mode, engine

    def apply(self, plan: ParallelPlan) -> ParallelPlan:
        """Truncate the plan's microbatch count (the per-iteration batch
        ``microbatch * dp`` — and thus the workload graph — is
        unchanged, so sweep-engine graph memos stay shared)."""
        if self.max_microbatches is None:
            return plan
        if plan.num_microbatches <= self.max_microbatches:
            return plan
        return dataclasses.replace(
            plan,
            global_batch=plan.microbatch * plan.dp * self.max_microbatches)

    def apply_serving(self, serving):
        """Truncate a :class:`~repro.serving.system.ServingSpec`'s request
        count — the serving analogue of :meth:`apply`: a short prefix of
        the arrival stream already prices steady-state batching, KV
        pressure and SLO attainment, so reduced rungs stop simulating the
        whole workload (the gap that previously made ``objective="slo"``
        searches pay full price at every rung)."""
        if self.max_requests is None or serving is None:
            return serving
        wl = serving.workload
        reqs = getattr(wl, "requests", None)
        count = len(reqs) if reqs else wl.num_requests
        if count <= self.max_requests:
            return serving
        kw = {"num_requests": self.max_requests}
        if reqs:
            kw["requests"] = list(reqs)[: self.max_requests]
        return dataclasses.replace(
            serving, workload=dataclasses.replace(wl, **kw))


FULL = Fidelity()


def default_ladder(noc_mode: NoCMode = NoCMode.MACRO,
                   num_rungs: int = 3) -> List[Fidelity]:
    """Cheapest-first fidelity ladder ending at full fidelity.

    ``noc_mode`` is the experiment's own (full-fidelity) NoC model; the
    middle rung steps down event-driven runs to the macro model and
    leaves cheaper modes untouched.
    """
    if not 1 <= num_rungs <= 3:
        raise ValueError("num_rungs must be 1, 2 or 3")
    noc_mode = NoCMode(noc_mode)
    mid_noc = NoCMode.MACRO if noc_mode == NoCMode.DETAILED else noc_mode
    ladder = [
        Fidelity("analytical-mb2", NoCMode.ANALYTICAL, 2, 8, engine="auto"),
        Fidelity(f"{mid_noc}-mb4", mid_noc, 4, 32, engine="auto"),
        FULL,
    ]
    return ladder[3 - num_rungs:]
