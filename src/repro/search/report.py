"""Search accounting that nests into :class:`repro.api.SweepReport`.

A :class:`SearchReport` records what a guided search *spent* and how it
converged: the per-rung promotion history, evaluation counts per
fidelity, and the best-so-far throughput curve indexed by full-fidelity
simulation count (the axis guided search optimizes). It round-trips
through JSON alongside the SweepReport it rides in.
"""

from __future__ import annotations

import dataclasses
import json
from dataclasses import dataclass, field
from typing import Any, Dict, List

__all__ = ["RungRecord", "SearchReport"]


@dataclass
class RungRecord:
    """One generation / successive-halving rung."""

    rung: int
    fidelity: str           # Fidelity.name the cohort was evaluated at
    evaluated: int          # candidates asked at this rung
    promoted: int           # candidates advanced to the next rung

    def to_dict(self) -> Dict[str, Any]:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "RungRecord":
        return cls(**d)


@dataclass
class SearchReport:
    """Guided-search accounting (see module docstring).

    ``budget`` is the full-fidelity simulation budget the strategy was
    given; ``full_fidelity_sims`` what it actually dispatched (cached
    re-asks are free and not counted). ``best_curve`` rows are
    ``[full_fidelity_sims_so_far, best_throughput_so_far]``.
    """

    strategy: str
    seed: int
    budget: int
    space_size: int
    evaluations: int = 0                 # dispatched at any fidelity
    full_fidelity_sims: int = 0
    sims_per_fidelity: Dict[str, int] = field(default_factory=dict)
    rungs: List[RungRecord] = field(default_factory=list)
    best_curve: List[List[float]] = field(default_factory=list)

    def __post_init__(self):
        # normalize to the JSON-native shapes so round-trips compare equal
        self.best_curve = [list(row) for row in self.best_curve]
        self.rungs = [r if isinstance(r, RungRecord) else RungRecord(**r)
                      for r in self.rungs]

    def to_dict(self) -> Dict[str, Any]:
        d = dataclasses.asdict(dataclasses.replace(self, rungs=[]))
        d["rungs"] = [r.to_dict() for r in self.rungs]
        return d

    def to_json(self, **kw: Any) -> str:
        return json.dumps(self.to_dict(), **kw)

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "SearchReport":
        d = dict(d)
        d["rungs"] = [RungRecord.from_dict(r) for r in d.get("rungs", [])]
        return cls(**d)

    @classmethod
    def from_json(cls, s: str) -> "SearchReport":
        return cls.from_dict(json.loads(s))

    def summary(self) -> str:
        fid = ", ".join(f"{k}: {v}"
                        for k, v in sorted(self.sims_per_fidelity.items()))
        return (f"{self.strategy} (seed {self.seed}): "
                f"{self.full_fidelity_sims}/{self.space_size} full-fidelity "
                f"sims (budget {self.budget}); evaluations by fidelity: "
                f"{fid or 'none'}")
