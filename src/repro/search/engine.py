"""Guided-search controller: strategy x EncodedSpace x shared-pool engine.

:func:`run_search` is the generation loop behind
``Experiment.sweep(strategy=...)`` and ``python -m repro sweep/plan
--search ...``: it encodes the Experiment's joint space, instantiates an
ask/tell strategy, and dispatches each generation as one job batch
through a *persistent* :class:`~repro.api.SweepEngine` pool (workers are
initialized once with the pickled experiment + every variant spec and
stay warm across generations — the same execution substrate the
exhaustive sweep uses, so full-fidelity evaluations are identical).

Evaluations are cached by ``(candidate, fidelity)``: a strategy re-asking
a point (e.g. an evolutionary mutation that lands on a known candidate)
costs nothing and is handed the cached outcome with ``cached=True``.

The result is an ordinary ranked :class:`~repro.api.SweepReport` whose
``runs`` are the full-fidelity evaluations, with a nested
:class:`SearchReport` accounting for what the search spent.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Sequence, Tuple, TYPE_CHECKING

from .fidelity import Fidelity, default_ladder
from .report import SearchReport
from .space import EncodedSpace
from .strategies import EvalOutcome, make_strategy

if TYPE_CHECKING:
    from ..api.experiment import Experiment
    from ..api.report import SweepReport
    from ..api.sweep import SweepEngine

__all__ = ["run_search"]


def run_search(exp: "Experiment", strategy: str = "sh",
               budget: Optional[int] = None, seed: int = 0,
               workers: Optional[int] = 0,
               return_timelines: bool = False,
               ladder: Optional[Sequence[Fidelity]] = None,
               engine: Optional["SweepEngine"] = None,
               profile: bool = False,
               **strategy_kw) -> "SweepReport":
    """Run a guided search over an Experiment's joint (hardware x plan)
    space and return the ranked SweepReport (full-fidelity runs only)
    with a nested :class:`SearchReport`.

    ``budget`` caps *full-fidelity* simulations and defaults to a fifth
    of the space (the multi-fidelity savings target); ``ladder``
    overrides the default fidelity rungs (cheapest first, ending at full
    fidelity). A caller-provided ``engine`` is used as-is (and not
    closed); otherwise one persistent engine spans all generations.

    ``profile=True`` attaches the fast-tier phase accounting to
    ``SweepReport.profile`` — cumulative totals plus a ``generations``
    list with one per-rung delta per engine call. When the Experiment
    has ``metrics=True`` the report also carries the repro.obs metrics
    document: engine host metrics merged across generations under
    ``host.search.generation`` spans, and a sim-domain aggregate of the
    ranked full-fidelity runs.
    """
    # api imports stay call-time: repro.api imports repro.search lazily too
    from ..api.report import SweepReport
    from ..api.sweep import (_FAILED, _OK, _PRUNED, SweepEngine,
                             _merge_profile)
    from ..obs.registry import make_registry

    space = EncodedSpace.from_experiment(exp)
    if budget is None:
        budget = max(1, math.ceil(len(space) / 5))
    if ladder is None:
        ladder = default_ladder(exp.noc_mode)
    strat = make_strategy(strategy, space, budget=budget, seed=seed,
                          ladder=ladder, **strategy_kw)

    own_engine = engine is None
    if own_engine:
        engine = SweepEngine(
            workers=workers,
            return_timelines=return_timelines or exp.collect_timeline,
            trace_resources=exp.collect_timeline,
            profile=profile)
        engine.__enter__()              # keep one pool across generations

    registry = make_registry(bool(getattr(exp, "metrics", False)))
    profile_totals: Dict[str, int] = {}
    generations: List[Dict[str, int]] = []
    cache: Dict[Tuple[Tuple[int, int], Fidelity], EvalOutcome] = {}
    reports: Dict[Tuple[int, int], object] = {}   # full-fidelity RunReports
    sims_per_fidelity: Dict[str, int] = {}
    evaluations = full_sims = pruned = failed = 0
    best = -math.inf
    best_curve: List[List[float]] = []
    executor: Optional[str] = None
    try:
        while True:
            asks = strat.ask()
            if not asks:
                break
            fresh = [(c, f) for c, f in asks if (c.key, f) not in cache]
            if fresh:
                jobs = []
                for cand, fid in fresh:
                    variant, plan = space.job(cand)
                    jobs.append((variant, plan) if fid.is_full
                                else (variant, plan, fid))
                with registry.span("host.search.generation"):
                    outcomes, label = engine.evaluate_jobs(
                        exp, space.specs, jobs)
                _merge_profile(profile_totals, engine.last_profile)
                generations.append(
                    {"jobs": len(jobs), **engine.last_profile})
                if registry:
                    registry.counter("host.search.evaluations").inc(len(jobs))
                    registry.merge_dict(engine.last_metrics or {})
                if executor is None:    # rung 0 is the largest batch
                    executor = label
                for (cand, fid), (tag, payload) in zip(fresh, outcomes):
                    evaluations += 1
                    sims_per_fidelity[fid.name] = \
                        sims_per_fidelity.get(fid.name, 0) + 1
                    ok = tag == _OK
                    out = EvalOutcome(
                        candidate=cand, fidelity=fid, ok=ok,
                        throughput=payload.throughput if ok else 0.0,
                        report=payload if ok else None)
                    cache[(cand.key, fid)] = out
                    if fid.is_full:
                        full_sims += 1
                        if tag == _PRUNED:
                            pruned += 1
                        elif tag == _FAILED:
                            failed += 1
                        if ok:
                            reports[cand.key] = payload
                            best = max(best, out.throughput)
                            best_curve.append([full_sims, best])
            fresh_keys = {(c.key, f) for c, f in fresh}
            strat.tell([
                cache[(c.key, f)] if (c.key, f) in fresh_keys
                else dataclasses.replace(cache[(c.key, f)], cached=True)
                for c, f in asks])
    finally:
        if own_engine:
            engine.__exit__(None, None, None)

    return _assemble(exp, space, strategy, seed, budget,
                     reports=reports, pruned=pruned, failed=failed,
                     executor=executor or "serial",
                     evaluations=evaluations, full_sims=full_sims,
                     sims_per_fidelity=sims_per_fidelity,
                     rungs=strat.rung_records(), best_curve=best_curve,
                     profile=({**profile_totals, "generations": generations}
                              if profile else None),
                     host_metrics=registry.to_dict() if registry else None)


def _assemble(exp, space: EncodedSpace, strategy: str, seed: int,
              budget: int, *, reports, pruned: int, failed: int,
              executor: str, evaluations: int, full_sims: int,
              sims_per_fidelity, rungs, best_curve,
              profile=None, host_metrics=None) -> "SweepReport":
    """Rank the full-fidelity runs into a SweepReport with the nested
    SearchReport, reusing the Experiment's report-assembly helpers so
    guided and exhaustive reports stay structurally identical."""
    from ..api.report import SweepReport, run_rank_key

    runs = sorted(reports.values(), key=run_rank_key)
    report = SweepReport(
        arch=exp.arch_name,
        hardware=exp._hardware_label(space.num_enumerated),
        runs=runs,
        num_candidates=len(space),
        num_pruned_memory=pruned,
        num_failed=failed + space.extra_failed,
        executor=executor,
        num_hardware=space.num_enumerated,
        search=SearchReport(
            strategy=strategy, seed=seed, budget=budget,
            space_size=len(space), evaluations=evaluations,
            full_fidelity_sims=full_sims,
            sims_per_fidelity=dict(sorted(sims_per_fidelity.items())),
            rungs=rungs, best_curve=best_curve),
        profile=profile)
    if getattr(exp, "metrics", False):
        from ..api.sweep import _OK
        from ..obs.simmetrics import aggregate_run_metrics
        # aggregate the ranked full-fidelity runs (rank order is total and
        # executor-independent, so the sim half stays deterministic);
        # pruned/failed counts come from the search loop, not the fold
        agg = aggregate_run_metrics([(_OK, r) for r in runs])
        agg["pruned"] = pruned
        agg["failed"] = failed + space.extra_failed
        report.metrics = {"sim": agg, "host": host_metrics or {}}
    if exp.hardware_search is not None:
        exp._record_hardware_specs(report, space.specs)
    return report
