"""Fault tolerance: straggler detection, fault-injected retry, elastic
mesh resharding.

At 1000+ nodes the failure model is: (i) slow nodes (stragglers) that
stretch every synchronous step, (ii) hard node loss (restart from
checkpoint, possibly on fewer nodes). This module provides the three
runtime pieces, each unit-tested on CPU:

* :class:`StragglerMonitor` — per-step wall-time ring buffer; flags steps
  exceeding ``threshold x`` the running median and recommends an action
  (the real-pod hook would re-dispatch that host's shard or evict it).
* :func:`run_with_restart` — drives a step function under a fault
  injector; on failure restores the latest checkpoint and replays
  (exactly-once semantics come from the counter-based data pipeline).
* :func:`elastic_reshard` — moves a checkpointed state onto a different
  mesh (e.g. 256 -> 128 chips after losing a pod slice): because every
  leaf's sharding is derived from its tree path (parallel.sharding),
  resharding is a device_put with the new mesh's NamedShardings.
"""

from __future__ import annotations

import collections
import statistics
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import numpy as np

from ..parallel.sharding import ShardingPlanner

__all__ = ["StragglerMonitor", "run_with_restart", "elastic_reshard"]


@dataclass
class StragglerMonitor:
    window: int = 50
    threshold: float = 2.0
    grace_steps: int = 5                 # ignore warmup/compile steps
    _times: collections.deque = field(default_factory=lambda: collections.deque(maxlen=256))
    events: List[Dict] = field(default_factory=list)

    def record(self, step: int, seconds: float) -> Optional[Dict]:
        self._times.append(seconds)
        if len(self._times) < self.grace_steps + 3:
            return None
        window = list(self._times)[-self.window:-1]
        med = statistics.median(window)
        if med > 0 and seconds > self.threshold * med:
            event = {"step": step, "seconds": seconds, "median": med,
                     "ratio": seconds / med,
                     "action": "re-dispatch shard / evict host if recurrent"}
            self.events.append(event)
            return event
        return None

    @property
    def median_step_time(self) -> float:
        return statistics.median(self._times) if self._times else 0.0


def run_with_restart(
    step_fn: Callable[[int, Any], Any],
    init_state: Any,
    num_steps: int,
    save_fn: Callable[[int, Any], None],
    restore_fn: Callable[[], Tuple[Optional[int], Any]],
    fault_injector: Optional[Callable[[int], bool]] = None,
    max_restarts: int = 10,
) -> Tuple[Any, Dict]:
    """Checkpoint/restart driver. ``step_fn(step, state) -> state``;
    ``restore_fn() -> (last_step, state)``. A 'fault' raises inside the
    loop; recovery restores and replays from the checkpoint."""
    state = init_state
    step = 0
    restarts = 0
    while step < num_steps:
        try:
            if fault_injector is not None and fault_injector(step):
                raise RuntimeError(f"injected node failure at step {step}")
            state = step_fn(step, state)
            step += 1
            save_fn(step, state)
        except RuntimeError:
            restarts += 1
            if restarts > max_restarts:
                raise
            last, restored = restore_fn()
            if last is None:
                state, step = init_state, 0
            else:
                state, step = restored, last
    return state, {"restarts": restarts, "final_step": step}


def elastic_reshard(state: Dict[str, Any], arch, new_mesh) -> Dict[str, Any]:
    """Re-place a {'params':..., 'opt_state':...} state dict onto a new
    mesh (grown or shrunk). Host-side gather then device_put with the new
    NamedShardings — the path-derived sharding rules make this mesh-shape
    agnostic."""
    planner = ShardingPlanner(new_mesh, arch)
    host = jax.tree.map(lambda x: np.asarray(x), state)
    out: Dict[str, Any] = {}
    if "params" in host:
        sh = planner.params(host["params"])
        out["params"] = jax.tree.map(jax.device_put, host["params"], sh)
    if "opt_state" in host:
        sh = planner.opt_state(host["params" if "params" in host else "opt_state"])
        out["opt_state"] = {
            "m": jax.tree.map(jax.device_put, host["opt_state"]["m"], sh["m"]),
            "v": jax.tree.map(jax.device_put, host["opt_state"]["v"], sh["v"]),
            "step": jax.device_put(host["opt_state"]["step"], sh["step"]),
        }
    for k in host:
        if k not in out:
            out[k] = jax.tree.map(jax.device_put, host[k])
    return out
