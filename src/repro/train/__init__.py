"""Training substrate: optimizer, train step, data pipeline, checkpointing,
fault tolerance."""

from .optim import OptimizerCfg, apply_optimizer, init_opt_state, lr_at
from .step import TrainCfg, init_train_state, make_eval_step, make_train_step

__all__ = ["OptimizerCfg", "apply_optimizer", "init_opt_state", "lr_at",
           "TrainCfg", "init_train_state", "make_eval_step", "make_train_step"]
