"""Step-addressed checkpointing with atomic writes and restart semantics.

Layout: ``<dir>/step_<N>/arrays.npz`` + ``manifest.json`` (tree structure,
step, data-pipeline cursor). Writes go to ``step_<N>.tmp`` then rename —
a crash mid-save never corrupts the latest checkpoint. ``keep_last``
prunes old steps. ``restore_latest`` is what a restarted worker calls.

On a real pod each host writes its process-local shards
(``jax.experimental.multihost_utils``); on this single-process container
arrays are saved whole. ``elastic.py`` reshards a checkpoint onto a
different mesh shape.
"""

from __future__ import annotations

import json
import shutil
import threading
from pathlib import Path
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np

__all__ = ["save_checkpoint", "restore_checkpoint", "restore_latest",
           "latest_step", "CheckpointManager"]

_SEP = "/"


def _flatten(tree) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = _SEP.join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)
        flat[key] = np.asarray(leaf)
    return flat


def save_checkpoint(ckpt_dir, step: int, state: Dict[str, Any],
                    extra: Optional[Dict] = None, keep_last: int = 3) -> Path:
    """state: dict of pytrees (e.g. {"params": ..., "opt_state": ...})."""
    ckpt_dir = Path(ckpt_dir)
    ckpt_dir.mkdir(parents=True, exist_ok=True)
    final = ckpt_dir / f"step_{step:08d}"
    tmp = ckpt_dir / f"step_{step:08d}.tmp"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir()
    arrays = {}
    manifest = {"step": step, "trees": {}, "extra": extra or {}}
    for name, tree in state.items():
        flat = _flatten(tree)
        manifest["trees"][name] = {
            k: {"shape": list(v.shape), "dtype": str(v.dtype)} for k, v in flat.items()}
        for k, v in flat.items():
            arrays[f"{name}::{k}"] = v
    np.savez(tmp / "arrays.npz", **arrays)
    (tmp / "manifest.json").write_text(json.dumps(manifest))
    if final.exists():
        shutil.rmtree(final)
    tmp.rename(final)                      # atomic publish
    # prune
    steps = sorted(p for p in ckpt_dir.glob("step_????????") if p.is_dir())
    for old in steps[:-keep_last]:
        shutil.rmtree(old)
    return final


def latest_step(ckpt_dir) -> Optional[int]:
    ckpt_dir = Path(ckpt_dir)
    if not ckpt_dir.exists():
        return None
    steps = sorted(int(p.name.split("_")[1]) for p in ckpt_dir.glob("step_????????"))
    return steps[-1] if steps else None


def restore_checkpoint(ckpt_dir, step: int, like: Dict[str, Any],
                       shardings: Optional[Dict[str, Any]] = None
                       ) -> Tuple[Dict[str, Any], Dict]:
    """Restore into the structure of ``like`` (a dict of pytrees of arrays
    or ShapeDtypeStructs). ``shardings`` optionally maps tree names to
    sharding pytrees for device placement on a mesh."""
    path = Path(ckpt_dir) / f"step_{step:08d}"
    manifest = json.loads((path / "manifest.json").read_text())
    data = np.load(path / "arrays.npz")
    state = {}
    for name, tree in like.items():
        leaves_paths, treedef = jax.tree_util.tree_flatten_with_path(tree)
        new_leaves = []
        for kp, leaf in leaves_paths:
            key = _SEP.join(str(getattr(k, "key", getattr(k, "idx", k))) for k in kp)
            arr = data[f"{name}::{key}"]
            if shardings is not None and name in shardings:
                sh_leaf = jax.tree_util.tree_flatten(shardings[name])[0][len(new_leaves)]
                arr = jax.device_put(arr, sh_leaf)
            new_leaves.append(arr)
        state[name] = jax.tree_util.tree_unflatten(treedef, new_leaves)
    return state, manifest["extra"]


def restore_latest(ckpt_dir, like, shardings=None):
    step = latest_step(ckpt_dir)
    if step is None:
        return None, None, None
    state, extra = restore_checkpoint(ckpt_dir, step, like, shardings)
    return step, state, extra


class CheckpointManager:
    """Periodic async checkpointing: the save runs on a background thread
    so the train loop is not blocked (fault-tolerance requirement)."""

    def __init__(self, ckpt_dir, every_steps: int = 100, keep_last: int = 3):
        self.dir = Path(ckpt_dir)
        self.every = every_steps
        self.keep_last = keep_last
        self._pending: Optional[threading.Thread] = None

    def maybe_save(self, step: int, state: Dict[str, Any], extra=None,
                   block: bool = False):
        if step % self.every != 0:
            return False
        self.wait()
        # materialise on host before handing to the thread
        host_state = jax.tree.map(lambda x: np.asarray(x), state)
        self._pending = threading.Thread(
            target=save_checkpoint,
            args=(self.dir, step, host_state),
            kwargs={"extra": extra, "keep_last": self.keep_last})
        self._pending.start()
        if block:
            self.wait()
        return True

    def wait(self):
        if self._pending is not None:
            self._pending.join()
            self._pending = None
