"""Distributed train_step / eval_step factories.

``make_train_step`` returns a jit-compiled function with full sharding
annotations: FSDP x TP parameter/optimizer shardings, batch over
(pod?, data), microbatch gradient accumulation via ``lax.scan`` (bounds
activation memory — the executable analogue of PALM's micro-batching,
Fig. 3), donated params/opt buffers, and optional cross-pod gradient
compression.
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..configs.base import ArchConfig
from ..models.lm import RunCfg, forward, init_params, loss_fn
from ..parallel.sharding import ShardingPlanner, param_pspecs
from .optim import OptimizerCfg, apply_optimizer, init_opt_state

__all__ = ["TrainCfg", "make_train_step", "make_eval_step", "init_train_state"]


@dataclass(frozen=True)
class TrainCfg:
    run: RunCfg = RunCfg()
    opt: OptimizerCfg = OptimizerCfg()
    num_microbatches: int = 1
    grad_accum_dtype: Any = jnp.float32    # bf16 = 340B memory policy


def _with_mesh_cfg(cfg: TrainCfg, mesh: Optional[Mesh]) -> TrainCfg:
    if mesh is None:
        return cfg
    axes = ("pod", "data") if "pod" in mesh.axis_names else ("data",)
    return dataclasses.replace(cfg, run=dataclasses.replace(
        cfg.run, mesh=mesh, batch_axes=axes))


def init_train_state(arch: ArchConfig, cfg: TrainCfg, key) -> Tuple[Any, Any]:
    params = init_params(arch, key, cfg.run)
    opt_state = init_opt_state(cfg.opt, params)
    return params, opt_state


def make_train_step(
    arch: ArchConfig,
    cfg: TrainCfg,
    mesh: Optional[Mesh] = None,
) -> Callable:
    """Build the jitted train step.

    Signature: ``train_step(params, opt_state, batch) ->
    (params, opt_state, metrics)`` where batch leaves carry a leading
    microbatch axis [G, B_mb, ...] (G == cfg.num_microbatches).
    """
    cfg = _with_mesh_cfg(cfg, mesh)
    G = cfg.num_microbatches

    def train_step(params, opt_state, batch):
        def mb_loss(p, mb):
            return loss_fn(arch, p, mb, cfg.run)

        grad_fn = jax.value_and_grad(mb_loss, has_aux=True)

        def shard_like_params(g):
            # per-microbatch ZeRO-2: pin each microbatch's grads to the
            # parameter shardings so XLA emits reduce-scatters, not
            # all-reduces (EXPERIMENTS.md §Perf iteration 3)
            if mesh is None:
                return g
            specs = param_pspecs(params, mesh)
            return jax.tree.map(
                lambda t, s: lax.with_sharding_constraint(t, NamedSharding(mesh, s)),
                g, specs, is_leaf=lambda x: isinstance(x, P))

        if G == 1:
            mb = jax.tree.map(lambda t: t[0], batch)
            (loss, metrics), grads = grad_fn(params, mb)
        else:
            def acc(carry, mb):
                g_acc, l_acc = carry
                (l, m), g = grad_fn(params, mb)
                g = shard_like_params(g)
                g_acc = jax.tree.map(
                    lambda a, b: a + b.astype(cfg.grad_accum_dtype), g_acc, g)
                return (g_acc, l_acc + l / G), m

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, cfg.grad_accum_dtype), params)
            zeros = shard_like_params(zeros)
            (grads, loss), metrics = lax.scan(acc, (zeros, 0.0), batch)
            grads = jax.tree.map(lambda g: g / G, grads)
            metrics = jax.tree.map(lambda m: m.mean(), metrics)

        if mesh is not None:  # keep grads on the param shardings (ZeRO-2)
            specs = param_pspecs(params, mesh)
            grads = jax.tree.map(
                lambda g, s: lax.with_sharding_constraint(g, NamedSharding(mesh, s)),
                grads, specs, is_leaf=lambda x: isinstance(x, P))

        new_params, new_opt, om = apply_optimizer(cfg.opt, params, grads, opt_state)
        metrics = {**metrics, **om, "loss": loss if G > 1 else metrics["loss"]}
        return new_params, new_opt, metrics

    if mesh is None:
        return jax.jit(train_step, donate_argnums=(0, 1))

    planner = ShardingPlanner(mesh, arch)

    def jit_with(params_shapes, batch_shapes):
        p_sh = planner.params(params_shapes)
        o_sh = planner.opt_state(params_shapes)
        batch_sh = jax.tree.map(
            lambda leaf: planner.batch(leading_scan_dim=True,
                                       example_shape=leaf.shape), batch_shapes)
        return jax.jit(
            train_step,
            in_shardings=(p_sh, o_sh, batch_sh),
            out_shardings=(p_sh, o_sh, None),
            donate_argnums=(0, 1),
        )

    train_step.jit_with = jit_with        # attach builder for launchers
    train_step.planner = planner
    return train_step


def make_eval_step(arch: ArchConfig, cfg: TrainCfg, mesh: Optional[Mesh] = None):
    cfg = _with_mesh_cfg(cfg, mesh)

    def eval_step(params, batch):
        loss, metrics = loss_fn(arch, params, batch, cfg.run)
        return metrics

    return jax.jit(eval_step)
