"""Deterministic, resumable synthetic data pipeline.

Counter-based RNG (numpy Philox keyed on (seed, step)) makes every batch
a pure function of the step index: checkpoint-restart resumes the stream
exactly (no state files), and any worker can regenerate any shard —
the property a 1000-node data pipeline needs for fault tolerance.

A background prefetch thread overlaps host batch synthesis with device
compute (the CPU-scale stand-in for a real input pipeline).
"""

from __future__ import annotations

import queue
import threading
from dataclasses import dataclass
from typing import Dict, Iterator, Optional

import numpy as np

from ..configs.base import ArchConfig

__all__ = ["DataCfg", "SyntheticDataset", "PrefetchIterator"]


@dataclass(frozen=True)
class DataCfg:
    seq_len: int
    global_batch: int
    num_microbatches: int = 1
    seed: int = 0


class SyntheticDataset:
    """Markov-ish token stream with a learnable structure (so tiny models
    show decreasing loss): token_{t+1} = (a * token_t + noise) % vocab."""

    def __init__(self, arch: ArchConfig, cfg: DataCfg):
        self.arch = arch
        self.cfg = cfg

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        cfg = self.cfg
        rng = np.random.Generator(np.random.Philox(key=cfg.seed, counter=step))
        G = cfg.num_microbatches
        B = cfg.global_batch // G
        S = cfg.seq_len
        if self.arch.embeds_input:
            embeds = rng.normal(size=(G, B, S, self.arch.d_model)).astype(np.float32)
            labels = rng.integers(0, self.arch.vocab, size=(G, B, S)).astype(np.int32)
            return {"embeds": embeds, "labels": labels}
        V = self.arch.vocab
        start = rng.integers(0, V, size=(G, B, 1))
        mult = 31
        noise = (rng.random(size=(G, B, S)) < 0.1).astype(np.int64)
        toks = np.zeros((G, B, S), dtype=np.int64)
        toks[..., 0] = start[..., 0]
        for t in range(1, S):
            toks[..., t] = (toks[..., t - 1] * mult + 7 + noise[..., t]) % V
        tokens = toks[..., :].astype(np.int32)
        labels = np.roll(toks, -1, axis=-1).astype(np.int32)
        labels[..., -1] = 0
        return {"tokens": tokens, "labels": labels}

    def iterate(self, start_step: int = 0) -> Iterator[Dict[str, np.ndarray]]:
        step = start_step
        while True:
            yield self.batch_at(step)
            step += 1


class PrefetchIterator:
    """Background-thread prefetch with bounded queue; ``close()`` joins."""

    def __init__(self, dataset: SyntheticDataset, start_step: int = 0, depth: int = 2):
        self._q: "queue.Queue" = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self._step = start_step

        def worker():
            s = start_step
            while not self._stop.is_set():
                batch = dataset.batch_at(s)
                while not self._stop.is_set():
                    try:
                        self._q.put((s, batch), timeout=0.1)
                        break
                    except queue.Full:
                        continue
                s += 1

        self._thread = threading.Thread(target=worker, daemon=True)
        self._thread.start()

    def __next__(self):
        step, batch = self._q.get()
        self._step = step
        return batch

    def __iter__(self):
        return self

    @property
    def step(self) -> int:
        return self._step

    def close(self):
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass
        self._thread.join(timeout=2)
