"""Optimizers (Adam / SGD, PALM Table II row 'Optimizer') with ZeRO-style
sharded state and configurable moment dtype.

The memory policy lever for nemotron-4-340b (DESIGN.md §6): moments can
be stored in bf16 (``moment_dtype``) while the update math runs in fp32
— params fp32 5.3 GB + m,v bf16 2x2.7 GB per chip at 256-way sharding.
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

__all__ = ["OptimizerCfg", "init_opt_state", "apply_optimizer", "lr_at"]


@dataclass(frozen=True)
class OptimizerCfg:
    name: str = "adam"                # "adam" | "sgd" (paper Table II)
    peak_lr: float = 3e-4
    warmup_steps: int = 100
    decay_steps: int = 10_000
    min_lr_ratio: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    moment_dtype: Any = jnp.float32   # bf16 = the 340B memory policy


def lr_at(cfg: OptimizerCfg, step: jax.Array) -> jax.Array:
    """Linear warmup + cosine decay to min_lr_ratio."""
    step = step.astype(jnp.float32)
    warm = cfg.peak_lr * step / max(1, cfg.warmup_steps)
    prog = jnp.clip((step - cfg.warmup_steps) /
                    max(1, cfg.decay_steps - cfg.warmup_steps), 0.0, 1.0)
    cos = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return jnp.where(step < cfg.warmup_steps, warm, cfg.peak_lr * cos)


def init_opt_state(cfg: OptimizerCfg, params) -> Dict:
    if cfg.name == "sgd":
        return {"m": jax.tree.map(lambda p: jnp.zeros((), p.dtype), params),  # stubs
                "v": jax.tree.map(lambda p: jnp.zeros((), p.dtype), params),
                "step": jnp.zeros((), jnp.int32)}
    zeros = lambda p: jnp.zeros(p.shape, cfg.moment_dtype)
    return {"m": jax.tree.map(zeros, params),
            "v": jax.tree.map(zeros, params),
            "step": jnp.zeros((), jnp.int32)}


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree)]
    return jnp.sqrt(sum(leaves))


def apply_optimizer(
    cfg: OptimizerCfg,
    params,
    grads,
    state: Dict,
) -> Tuple[Any, Dict, Dict]:
    """Returns (new_params, new_state, metrics). Math in fp32, storage at
    param/moment dtypes."""
    step = state["step"] + 1
    lr = lr_at(cfg, step)

    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9)) if cfg.grad_clip > 0 else 1.0

    if cfg.name == "sgd":
        def upd(p, g):
            g32 = g.astype(jnp.float32) * scale
            return (p.astype(jnp.float32) - lr * g32).astype(p.dtype)
        new_params = jax.tree.map(upd, params, grads)
        new_state = {**state, "step": step}
        return new_params, new_state, {"lr": lr, "grad_norm": gnorm}

    b1, b2 = cfg.b1, cfg.b2
    c1 = 1.0 - b1 ** step.astype(jnp.float32)
    c2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g32 = g.astype(jnp.float32) * scale
        m32 = b1 * m.astype(jnp.float32) + (1 - b1) * g32
        v32 = b2 * v.astype(jnp.float32) + (1 - b2) * g32 * g32
        mhat = m32 / c1
        vhat = v32 / c2
        p32 = p.astype(jnp.float32)
        step_dir = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if cfg.weight_decay > 0 and p.ndim >= 2:   # decay matrices only
            step_dir = step_dir + cfg.weight_decay * p32
        return ((p32 - lr * step_dir).astype(p.dtype),
                m32.astype(cfg.moment_dtype), v32.astype(cfg.moment_dtype))

    out = jax.tree.map(upd, params, grads, state["m"], state["v"])
    new_params = jax.tree.map(lambda t: t[0], out, is_leaf=lambda x: isinstance(x, tuple))
    new_m = jax.tree.map(lambda t: t[1], out, is_leaf=lambda x: isinstance(x, tuple))
    new_v = jax.tree.map(lambda t: t[2], out, is_leaf=lambda x: isinstance(x, tuple))
    new_state = {"m": new_m, "v": new_v, "step": step}
    return new_params, new_state, {"lr": lr, "grad_norm": gnorm}
