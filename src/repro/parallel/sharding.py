"""Sharding rules: FSDP(data) x TP(model) x optional DP(pod), per leaf.

The scheme (DESIGN.md §5):

* every weight matrix is sharded on one dim by ``model`` (Megatron TP:
  head/ffn/expert dims) and on another by ``data`` (ZeRO-3/FSDP; XLA
  GSPMD inserts the per-layer all-gathers inside the layer scan and
  reduce-scatters the gradients),
* optimizer state mirrors the parameter shardings (ZeRO-1/2 for free),
* activations: batch over ``(pod, data)``; with sequence parallelism the
  residual stream is additionally sharded over ``model`` on the sequence
  dim between blocks (knob: ``seq_shard`` — the nemotron-340B memory-fit
  lever),
* KV caches: batch over ``data``, sequence over ``model`` (decode-time
  context parallelism); SSM states: head dim over ``model``.

pjit *argument* shardings must divide evenly, so every rule is a
fallback chain evaluated against the actual leaf shape + mesh: e.g.
granite-moe's 40 experts don't divide the 16-way model axis, so expert
weights fall back to intra-expert TP (F-dim over model); 49155-token
vocabs fall back to replicated-vocab embeddings; batch-1 decode drops
the data axis. Chosen fallbacks are deterministic and recorded in
EXPERIMENTS.md §Dry-run.
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Any, Dict, Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..configs.base import ArchConfig

__all__ = ["param_pspecs", "batch_pspec", "cache_pspecs", "ShardingPlanner"]

FSDP = "data"
TP = "model"


def _axis_size(mesh: Mesh, axis) -> int:
    if axis is None:
        return 1
    if isinstance(axis, (tuple, list)):
        out = 1
        for a in axis:
            out *= mesh.shape[a]
        return out
    return mesh.shape[axis]


def fit_spec(spec: P, shape: Tuple[int, ...], mesh: Mesh) -> Optional[P]:
    """Return the spec if every sharded dim divides evenly, else None."""
    dims = list(spec) + [None] * (len(shape) - len(spec))
    for d, axis in zip(shape, dims):
        if axis is not None and d % _axis_size(mesh, axis) != 0:
            return None
    return P(*dims)


def fit_first(candidates, shape: Tuple[int, ...], mesh: Mesh) -> P:
    """First candidate that divides; last resort drops offending axes."""
    for cand in candidates:
        ok = fit_spec(cand, shape, mesh)
        if ok is not None:
            return ok
    base = list(candidates[0]) + [None] * (len(shape) - len(candidates[0]))
    out = [a if a is not None and d % _axis_size(mesh, a) == 0 else None
           for d, a in zip(shape, base)]
    return P(*out)


def _leaf_candidates(path: Tuple[str, ...], ndim: int):
    """Ordered sharding rules by (parent, name) — see module docstring."""
    name = path[-1]
    parent = path[-2] if len(path) > 1 else ""

    if name == "embed":      # [V, H]
        return [P(TP, FSDP), P(None, FSDP)]
    if name == "lm_head":    # [H, V]
        return [P(FSDP, TP), P(FSDP, None)]
    if name == "final_norm":
        return [P(None)]
    if name in ("norm1", "norm2"):
        return [P(None, None)]

    if parent == "attn":
        if name in ("wq", "wk", "wv"):   # [L, H, heads*hd]
            return [P(None, FSDP, TP), P(None, FSDP, None)]
        if name == "wo":                 # [L, heads*hd, H]
            return [P(None, TP, FSDP), P(None, None, FSDP)]
    if parent == "mlp":
        if name in ("wi", "wg"):         # [L, H, F]
            return [P(None, FSDP, TP), P(None, FSDP, None)]
        if name == "wo":                 # [L, F, H]
            return [P(None, TP, FSDP), P(None, None, FSDP)]
    if parent == "moe":
        if name == "router":             # [L, H, E]
            return [P(None, FSDP, None)]
        if name in ("wi", "wg"):         # [L, E, H, F]: EP, else intra-expert TP
            return [P(None, TP, FSDP, None), P(None, None, FSDP, TP),
                    P(None, None, FSDP, None)]
        if name == "wo":                 # [L, E, F, H]
            return [P(None, TP, None, FSDP), P(None, None, TP, FSDP),
                    P(None, None, None, FSDP)]
    if parent == "ssm":
        if name == "in_proj":            # [L, H, d_in_proj]
            return [P(None, FSDP, TP), P(None, FSDP, None)]
        if name == "out_proj":           # [L, d_inner, H]
            return [P(None, TP, FSDP), P(None, None, FSDP)]
        if name == "conv_w":             # [L, K, conv_dim]
            return [P(None, None, TP), P(None, None, None)]
        if name in ("conv_b", "ssm_norm"):
            return [P(None, TP), P(None, None)]
        if name in ("A_log", "D", "dt_bias"):
            return [P(None, None)]
    return [P(*([None] * ndim))]


def param_pspecs(params_or_shapes, mesh: Mesh) -> Any:
    """PartitionSpec pytree matching a params pytree (works on shapes);
    every spec is divisibility-checked against the mesh."""
    def rule(kp, leaf):
        path = tuple(getattr(k, "key", str(k)) for k in kp)
        cands = _leaf_candidates(path, len(leaf.shape))
        return fit_first(cands, tuple(leaf.shape), mesh)
    return jax.tree_util.tree_map_with_path(rule, params_or_shapes)


def batch_pspec(mesh: Mesh, leading_scan_dim: bool = False) -> P:
    """Batch sharding: batch dim over (pod?, data)."""
    axes = ("pod", "data") if "pod" in mesh.axis_names else ("data",)
    if leading_scan_dim:                      # [n_microbatch, B, S]
        return P(None, axes)
    return P(axes)


def cache_pspecs(arch: ArchConfig, cache, mesh: Mesh) -> Any:
    """Decode-cache shardings: KV [L,B,S,nkv,hd] -> batch over data,
    sequence over model (context-parallel decode); SSM state
    [L,B,nh,hp,N] -> heads (or head-dim) over model. Batch-1 decode
    (long_500k) drops the data axis via the fallback chains."""
    cands = {
        "k": [P(None, FSDP, TP, None, None), P(None, None, TP, None, None),
              P(None, None, None, None, None)],
        "v": [P(None, FSDP, TP, None, None), P(None, None, TP, None, None),
              P(None, None, None, None, None)],
        "conv": [P(None, FSDP, None, TP), P(None, None, None, TP),
                 P(None, None, None, None)],
        "ssm": [P(None, FSDP, TP, None, None), P(None, FSDP, None, TP, None),
                P(None, None, TP, None, None), P(None, None, None, TP, None),
                P(None, None, None, None, None)],
    }
    return {k: fit_first(cands[k], tuple(cache[k].shape), mesh) for k in cache}


@dataclass
class ShardingPlanner:
    """Bundles mesh + per-tree shardings for one launch configuration."""

    mesh: Mesh
    arch: ArchConfig

    def named(self, spec: P) -> NamedSharding:
        return NamedSharding(self.mesh, spec)

    def params(self, params_or_shapes) -> Any:
        return jax.tree.map(self.named, param_pspecs(params_or_shapes, self.mesh),
                            is_leaf=lambda x: isinstance(x, P))

    def opt_state(self, params_or_shapes) -> Any:
        """Optimizer state shardings: moments mirror the parameter
        shardings (ZeRO: 256-way sharded states), scalars replicated.
        Matches repro.train.optim's {"m": tree, "v": tree, "step": ()}."""
        p = self.params(params_or_shapes)
        return {"m": p, "v": p, "step": self.named(P())}

    def batch(self, leading_scan_dim: bool = False, example_shape=None) -> NamedSharding:
        spec = batch_pspec(self.mesh, leading_scan_dim)
        if example_shape is not None:
            spec = fit_first([spec], tuple(example_shape), self.mesh)
        return self.named(spec)

    def cache(self, cache) -> Any:
        specs = cache_pspecs(self.arch, cache, self.mesh)
        return jax.tree.map(self.named, specs, is_leaf=lambda x: isinstance(x, P))
