"""Distributed runtime: sharding rules, pipeline stage executor,
gradient compression."""

from .sharding import (
    ShardingPlanner,
    batch_pspec,
    cache_pspecs,
    param_pspecs,
)

__all__ = ["ShardingPlanner", "batch_pspec", "cache_pspecs", "param_pspecs"]
