"""Executable pipeline parallelism: GPipe schedule via shard_map + ppermute.

PALM *models* PP (core.scheduler); this module *runs* it on a mesh axis —
on the production mesh the natural choice is ``pp_axis="pod"`` (stages =
pods, Act/Grad Pass = inter-pod collective-permute), exactly the
traffic pattern the paper's Act/Grad Pass events describe.

Mechanics: S stages on the axis, G microbatches, T = G + S - 1 ticks.
Each tick every stage applies its layer block to the activation it holds,
then the ring ``ppermute`` shifts activations one stage forward. Autodiff
through the tick scan yields the interleaved backward schedule for free
(the MaxText pattern), so ``jax.grad`` of a pipelined loss just works.

The schedule's bubble fraction is (S-1)/(G+S-1) — asserted against
PALM's Eq. (1) in tests for the same (S, G).
"""

from __future__ import annotations

import functools
from typing import Any, Callable, Tuple

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from jax.experimental.shard_map import shard_map

__all__ = ["pipeline_apply", "make_pipeline_loss"]


def pipeline_apply(
    stage_fn: Callable[[Any, jax.Array], jax.Array],
    stage_params: Any,           # pytree, leading dim S (sharded over axis)
    microbatches: jax.Array,     # [G, B, ...] (replicated; consumed by stage 0)
    mesh: Mesh,
    axis: str = "pod",
) -> jax.Array:
    """Run the GPipe pipeline; returns outputs [G, B, ...] (replicated)."""
    S = mesh.shape[axis]
    G = microbatches.shape[0]
    T = G + S - 1

    other_axes = [a for a in mesh.axis_names if a != axis]

    param_specs = jax.tree.map(lambda _: P(axis), stage_params)
    in_specs = (param_specs, P())
    out_specs = P()

    def body(params_local, mbs):
        s = lax.axis_index(axis)
        zero = jnp.zeros_like(mbs[0])
        perm = [(i, (i + 1) % S) for i in range(S)]

        def tick(buf, t):
            mb_idx = jnp.clip(t, 0, G - 1)
            inp = jnp.where(s == 0,
                            lax.dynamic_index_in_dim(mbs, mb_idx, keepdims=False),
                            buf)
            local = jax.tree.map(lambda p: p[0], params_local)
            out = stage_fn(local, inp)
            nxt = lax.ppermute(out, axis, perm)
            # only the last stage's output is the pipeline output
            y = jnp.where(s == S - 1, out, jnp.zeros_like(out))
            y = lax.psum(y, axis)          # broadcast to all stages
            return nxt, y

        _, ys = lax.scan(tick, zero, jnp.arange(T))
        # microbatch g exits the last stage at tick g + S - 1
        return ys[S - 1:]

    fn = shard_map(body, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                   check_rep=False)
    return fn(stage_params, microbatches)


def make_pipeline_loss(
    stage_fn: Callable[[Any, jax.Array], jax.Array],
    loss_head: Callable[[jax.Array, jax.Array], jax.Array],
    mesh: Mesh,
    axis: str = "pod",
):
    """Pipelined loss: mean over microbatches of loss_head(pipeline(x), y).
    Differentiable end-to-end (grads flow through the ppermute ring)."""

    def loss_fn(stage_params, microbatches, labels):
        outs = pipeline_apply(stage_fn, stage_params, microbatches, mesh, axis)
        losses = jax.vmap(loss_head)(outs, labels)
        return losses.mean()

    return loss_fn
