"""Gradient compression for slow (cross-pod / DCN) reduction axes.

int8 block-quantized all-reduce with error feedback: each worker keeps
the quantization residual and adds it to the next step's gradient, so
the *accumulated* update is unbiased (the standard EF-SGD trick — makes
1-byte gradients converge like fp32 over time).

``compressed_psum`` is the shard_map building block for a real multi-pod
mesh: quantize -> psum(int32) -> dequantize with the summed scale. On
this container it is exercised on small host meshes in tests.
"""

from __future__ import annotations

from functools import partial
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

__all__ = ["quantize_int8", "dequantize_int8", "ef_compress_tree", "compressed_psum"]

BLOCK = 256


def quantize_int8(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Per-block symmetric int8 quantization over the flattened array.
    Returns (q [N] int8, scales [nblocks] f32)."""
    flat = x.reshape(-1).astype(jnp.float32)
    n = flat.shape[0]
    pad = (-n) % BLOCK
    flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, BLOCK)
    scale = jnp.max(jnp.abs(blocks), axis=1) / 127.0
    safe = jnp.where(scale == 0, 1.0, scale)
    q = jnp.clip(jnp.round(blocks / safe[:, None]), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array, shape, dtype) -> jax.Array:
    deq = q.astype(jnp.float32) * scale[:, None]
    n = 1
    for d in shape:
        n *= d
    return deq.reshape(-1)[:n].reshape(shape).astype(dtype)


def ef_compress_tree(grads, ef_state):
    """Error-feedback int8 round-trip over a gradient pytree (models the
    lossy reduction channel). Returns (compressed grads, new residuals)."""
    if ef_state is None:
        ef_state = jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads)

    def one(g, e):
        corrected = g.astype(jnp.float32) + e
        q, s = quantize_int8(corrected)
        deq = dequantize_int8(q, s, g.shape, jnp.float32)
        return deq.astype(g.dtype), corrected - deq

    out = jax.tree.map(one, grads, ef_state)
    comp = jax.tree.map(lambda t: t[0], out, is_leaf=lambda x: isinstance(x, tuple))
    new_ef = jax.tree.map(lambda t: t[1], out, is_leaf=lambda x: isinstance(x, tuple))
    return comp, new_ef


def compressed_psum(x: jax.Array, axis_name: str) -> jax.Array:
    """int8-compressed psum (inside shard_map): ranks agree on a shared
    per-block scale via a (tiny) pmax, quantize against it, then psum the
    int8 payload (as int32 accumulators — on the wire this is the 1-byte
    format, 4x less DCN traffic than fp32). Pair with error feedback
    across steps for unbiased long-run updates."""
    flat = x.reshape(-1).astype(jnp.float32)
    n = flat.shape[0]
    pad = (-n) % BLOCK
    flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, BLOCK)
    local_scale = jnp.max(jnp.abs(blocks), axis=1) / 127.0
    scale = jax.lax.pmax(local_scale, axis_name)          # shared scale
    safe = jnp.where(scale == 0, 1.0, scale)
    q = jnp.clip(jnp.round(blocks / safe[:, None]), -127, 127).astype(jnp.int8)
    q_sum = jax.lax.psum(q.astype(jnp.int32), axis_name)  # int8-wire reduction
    val = q_sum.astype(jnp.float32) * safe[:, None]
    return val.reshape(-1)[:n].reshape(x.shape).astype(x.dtype)
