"""Sim-domain metric derivation — the deterministic half of ``repro.obs``.

Everything in this module is computed *post hoc* from data both simulator
tiers already agree on bit-for-bit — the plan/mapping structure, the
``compare=True`` scalars of :class:`~repro.core.scheduler.SimResult`
(total time, throughput, byte counters), and the trace's row *multiset*
(identical across tiers; only append order differs, which the canonical
sort here removes). No value depends on wall clock, heap order, executor,
or engine tier, so ``engine=fast`` and ``engine=event`` runs of the same
job — and serial vs pooled sweeps — produce identical documents. That
invariant is what lets ``RunReport.metrics["sim"]`` participate in
parity tests while ``["host"]`` never does.

The document shape (JSON-plain, no registry framing):

* ``total_time`` / ``throughput`` / ``bubble_ratio`` — headline scalars;
* ``bytes`` — NoC / DRAM totals (NoC includes fabric, matching
  ``SimResult.noc_bytes``);
* ``stages`` — per-stage flop totals, roofline utilization vs
  ``tile.flops`` (the paper's per-stage "what fraction of peak"), trace
  busy seconds and busy fractions;
* ``bubble`` — decomposition by cause: ``warmup`` (time before a stage's
  first compute row), ``interior`` (gaps between its rows), ``drain``
  (time after its last row), summed over stages; ``warmup + interior +
  drain + busy == num_stages * total_time`` exactly;
* ``resources`` — per-lane-kind busy time / busy fractions, present only
  when the run recorded resource intervals (``collect_timeline=True``);
* ``payload_by_level`` — fabric traffic per hierarchy level (board /
  node / ...), present only for fabric-backed runs with metrics enabled.

:func:`run_metrics` wraps the sim document with the per-run host domain
(engine tier, machine-readable fast-path rejection) into the
``{"sim": ..., "host": ...}`` shape ``RunReport.metrics`` carries.
"""

from __future__ import annotations

from typing import Any, Dict, List, Optional, Tuple

from ..core.trace import KIND_BD, KIND_DRAM, KIND_FABRIC, KIND_NOC

try:                                    # trace columns are numpy-backed
    import numpy as _np                 # when numpy is present; the
except ImportError:                     # derivation below vectorizes then
    _np = None

__all__ = ["sim_metrics", "run_metrics", "aggregate_run_metrics",
           "serving_sim_metrics"]

_RESOURCE_NAMES = {KIND_NOC: "noc", KIND_DRAM: "dram", KIND_FABRIC: "fabric"}


def _stage_flops(sim) -> List[float]:
    """Per-stage total executed FLOPs per tile for one iteration: M
    forwards, plus M backwards (+ M recompute forwards) when training —
    exactly the compute the FD/BD bodies price via ``_compute_time``."""
    M = sim.plan.num_microbatches
    training = sim.plan.training
    out = []
    for stage in sim.mapped.stages:
        fwd = sum(op.fwd_flops_tile for op in stage.split_ops)
        total = M * fwd
        if training:
            bwd = sum(op.bwd_flops_tile for op in stage.split_ops)
            total += M * bwd
            if sim.recompute:
                total += M * fwd
        out.append(total)
    return out


def _tolist(col):
    # numpy arrays and array.array both expose .tolist(); element-wise
    # zip over numpy columns yields slow numpy scalars, so convert once
    to = getattr(col, "tolist", None)
    return to() if to is not None else list(col)


def _stage_stats(trace, S: int):
    """Per-stage aggregates over the compute rows (``stage >= 0``) in
    canonical ``(stage, start, end, kind, micro)`` order: ``(busy, fdbd,
    first, last, interior)`` where ``fdbd`` counts only FD/BD rows (the
    schedule-level busy definition behind ``SimResult.bubble_ratio``).

    Sums are folded in canonical order, so they are bit-identical across
    engine tiers and executors (the append order is the only thing that
    differs, and the total sort key removes it). The numpy path uses
    numpy's deterministic array reduction; the fallback folds
    sequentially — both are stable within one installation, which is the
    scope of the parity contract.
    """
    busy = [0.0] * S
    fdbd = [0.0] * S
    first: List[Optional[float]] = [None] * S
    last: List[Optional[float]] = [None] * S
    interior = [0.0] * S
    if trace is None or len(trace) == 0:
        return busy, fdbd, first, last, interior

    if _np is not None:
        st = _np.asarray(trace.stage)
        ci = _np.flatnonzero(st >= 0)
        if ci.size == 0:
            return busy, fdbd, first, last, interior
        k = _np.asarray(trace.kind)
        m = _np.asarray(trace.micro)
        s0 = _np.asarray(trace.start)
        e0 = _np.asarray(trace.end)
        order = _np.lexsort((m[ci], k[ci], e0[ci], s0[ci], st[ci]))
        ci = ci[order]
        cs = st[ci]
        ck = k[ci]
        cst = s0[ci]
        cen = e0[ci]
        dur = cen - cst
        bounds = _np.searchsorted(cs, _np.arange(S + 1))
        for s in range(S):
            a, b = int(bounds[s]), int(bounds[s + 1])
            if a == b:
                continue
            seg_dur = dur[a:b]
            busy[s] = float(seg_dur.sum())
            fdbd[s] = float(seg_dur[ck[a:b] <= KIND_BD].sum())
            first[s] = float(cst[a])        # sorted by start within stage
            runmax = _np.maximum.accumulate(cen[a:b])
            last[s] = float(runmax[-1])
            if b - a > 1:
                gaps = cst[a + 1:b] - runmax[:-1]
                pos = gaps[gaps > 0]
                if pos.size:
                    interior[s] = float(pos.sum())
        return busy, fdbd, first, last, interior

    rows = [(s, st_, en, k_, m_)
            for s, k_, m_, st_, en in zip(
                _tolist(trace.stage), _tolist(trace.kind),
                _tolist(trace.micro), _tolist(trace.start),
                _tolist(trace.end))
            if s >= 0]
    rows.sort()
    for s, st_, en, k_, _m in rows:
        d = en - st_
        busy[s] += d
        if k_ <= KIND_BD:
            fdbd[s] += d
        if first[s] is None:
            first[s] = st_
        elif st_ > last[s]:
            interior[s] += st_ - last[s]
        if last[s] is None or en > last[s]:
            last[s] = en
    return busy, fdbd, first, last, interior


def _resource_stats(trace) -> Dict[int, Tuple[float, int]]:
    """Resource-row (``stage < 0``) aggregates in canonical ``(end,
    start, kind, lane)`` order: ``{kind: (busy_time, lane_count)}``."""
    if trace is None or len(trace) == 0:
        return {}

    if _np is not None:
        st = _np.asarray(trace.stage)
        ri = _np.flatnonzero(st < 0)
        if ri.size == 0:
            return {}
        k = _np.asarray(trace.kind)
        r = _np.asarray(trace.resource)
        s0 = _np.asarray(trace.start)
        e0 = _np.asarray(trace.end)
        order = _np.lexsort((r[ri], k[ri], s0[ri], e0[ri]))
        ri = ri[order]
        rk = k[ri]
        rr = r[ri]
        rdur = e0[ri] - s0[ri]
        out: Dict[int, Tuple[float, int]] = {}
        for kind in _np.unique(rk).tolist():
            mask = rk == kind
            out[int(kind)] = (float(rdur[mask].sum()),
                              int(_np.unique(rr[mask]).size))
        return out

    rows = [(k_, r_, st_, en)
            for s, k_, r_, st_, en in zip(
                _tolist(trace.stage), _tolist(trace.kind),
                _tolist(trace.resource), _tolist(trace.start),
                _tolist(trace.end))
            if s < 0]
    rows.sort(key=lambda row: (row[3], row[2], row[0], row[1]))
    busy: Dict[int, float] = {}
    lanes: Dict[int, set] = {}
    for k_, lane, st_, en in rows:
        busy[k_] = busy.get(k_, 0.0) + (en - st_)
        lanes.setdefault(k_, set()).add(lane)
    return {k_: (busy[k_], len(lanes[k_])) for k_ in busy}


def sim_metrics(sim, result) -> Dict[str, Any]:
    """Deterministic sim-domain document for one finished run (see the
    module docstring for the shape and the bit-identity contract)."""
    S = sim.mapped.num_stages
    total = result.total_time
    tile_flops = sim.hw.tile.flops

    flops = _stage_flops(sim)
    denom = total * tile_flops
    roofline = [f / denom if denom > 0 else 0.0 for f in flops]

    busy, fdbd, first, last, interior = _stage_stats(result.trace, S)
    warmup = [f if f is not None else total for f in first]
    drain = [(total - l) if l is not None else 0.0 for l in last]
    busy_total = sum(busy)
    warm_total = sum(warmup)
    int_total = sum(interior)
    drain_total = sum(drain)
    span = S * total
    bubble_fraction = (1.0 - busy_total / span) if span > 0 else 0.0
    # the schedule-level headline scalar: FD+BD busy only, same
    # definition as SimResult.bubble_ratio but folded from the canonical
    # row order instead of a second trace walk
    bubble_ratio = (1.0 - sum(fdbd) / span) if span > 0 else 0.0

    doc: Dict[str, Any] = {
        "total_time": total,
        "throughput": result.throughput,
        # the trace-derived all-kinds occupancy bubble lives under
        # bubble["fraction"]
        "bubble_ratio": bubble_ratio,
        "bytes": {"noc": result.noc_bytes, "dram": result.dram_bytes},
        "stages": {
            "flops": flops,
            "roofline_utilization": roofline,
            "busy_time": busy,
            "busy_fraction": [b / total if total > 0 else 0.0 for b in busy],
        },
        "bubble": {
            "warmup": warm_total,
            "interior": int_total,
            "drain": drain_total,
            "busy": busy_total,
            "fraction": bubble_fraction,
        },
    }

    res_stats = _resource_stats(result.trace)
    if res_stats:
        resources: Dict[str, Any] = {}
        for k in sorted(res_stats):
            name = _RESOURCE_NAMES.get(k, str(k))
            bt, n_lanes = res_stats[k]
            resources[name] = {
                "busy_time": bt,
                "lanes": n_lanes,
                "busy_fraction": (bt / (n_lanes * total)
                                  if total > 0 and n_lanes else 0.0),
            }
        doc["resources"] = resources

    levels = getattr(sim.noc, "level_bytes", None)
    if levels:
        spec = sim.noc.spec
        doc["payload_by_level"] = {
            spec.levels[lvl].name: levels[lvl] for lvl in sorted(levels)}

    return doc


def run_metrics(sim, result) -> Dict[str, Any]:
    """``RunReport.metrics`` document: the sim-domain derivation above
    plus the per-run host domain (engine provenance and, when the fast
    tier declined the run, a machine-readable rejection)."""
    from ..core.fastpath import reason_code

    host: Dict[str, Any] = {"engine": result.engine}
    reason = getattr(sim, "fastpath_reason", None)
    if reason and result.engine != "fast":
        host["fastpath_rejection"] = {"code": reason_code(reason),
                                      "reason": reason}
    return {"sim": sim_metrics(sim, result), "host": host}


def aggregate_run_metrics(outcomes) -> Dict[str, Any]:
    """Sweep-level sim-domain aggregate over ``(tag, payload)`` outcomes
    in job order. Only ``compare=True`` RunReport scalars are folded, in
    job order, so the aggregate is bit-identical across engine tiers and
    serial/pool executors (the parity the sweep tests assert)."""
    from ..api.sweep import _OK, _PRUNED

    runs = pruned = failed = 0
    total_time = noc = dram = 0.0
    best = 0.0
    for tag, payload in outcomes:
        if tag == _OK:
            runs += 1
            total_time += payload.total_time
            noc += payload.noc_bytes
            dram += payload.dram_bytes
            if payload.throughput > best:
                best = payload.throughput
        elif tag == _PRUNED:
            pruned += 1
        else:
            failed += 1
    return {
        "runs": runs,
        "pruned": pruned,
        "failed": failed,
        "best_throughput": best,
        "total_sim_time": total_time,
        "bytes": {"noc": noc, "dram": dram},
    }


def _series_stats(series) -> Optional[Dict[str, float]]:
    if not series:
        return None
    vals = [v for _, v in series]
    return {"mean": sum(vals) / len(vals), "max": max(vals),
            "last": vals[-1], "samples": len(vals)}


def serving_sim_metrics(report) -> Dict[str, Any]:
    """Sim-domain document for a :class:`~repro.serving.system.
    ServingReport`: KV-cache occupancy and queue depth digests plus the
    deterministic step counters — all derived from the seeded simulation,
    never from wall clock."""
    kv: Dict[str, Any] = {"peak_bytes": report.kv_peak_bytes}
    if report.kv_budget_bytes is not None:
        kv["budget_bytes"] = report.kv_budget_bytes
        if report.kv_budget_bytes > 0:
            kv["peak_fraction"] = report.kv_peak_bytes / report.kv_budget_bytes
    occ = _series_stats(report.kv_occupancy_bytes)
    if occ is not None:
        kv["occupancy"] = occ
    doc: Dict[str, Any] = {
        "sim_time": report.sim_time,
        "throughput_rps": report.throughput_rps,
        "goodput_rps": report.goodput_rps,
        "kv_cache": kv,
        "steps": {k: report.steps.get(k, 0)
                  for k in ("prefill", "decode", "cost_sims")},
    }
    queue = _series_stats(report.queue_depth)
    if queue is not None:
        doc["queue_depth"] = queue
    return doc
