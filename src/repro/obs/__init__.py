"""``repro.obs`` — unified metrics & instrumentation layer.

Two strictly separated metric domains (enforced by name prefix in the
registry):

* **sim-domain** (``sim.*``): deterministic values derived only from
  simulated time/bytes — bit-identical across engine tiers
  (``fast``/``event``) and executors (serial/pool). Derived post-hoc by
  :mod:`repro.obs.simmetrics`.
* **host-domain** (``host.*``): wall-clock spans and process-level
  counts — tier selection, fast-path rejection reasons, pool shard
  timing, graph-memo hit rates, search rung timing. Recorded live into
  a :class:`MetricsRegistry` and merged across pool shards.

See ``docs/observability.md`` for the full schema and the overhead
gate.
"""

from .registry import (Counter, Gauge, Histogram, MetricsRegistry,
                       NULL_REGISTRY, make_registry, summarize_metrics)
from .simmetrics import (aggregate_run_metrics, run_metrics,
                         serving_sim_metrics, sim_metrics)
from .tracks import activity_counters, metrics_counters, serving_counters

__all__ = [
    "Counter", "Gauge", "Histogram", "MetricsRegistry", "NULL_REGISTRY",
    "make_registry", "summarize_metrics",
    "sim_metrics", "run_metrics", "aggregate_run_metrics",
    "serving_sim_metrics",
    "activity_counters", "serving_counters", "metrics_counters",
]
