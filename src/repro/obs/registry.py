"""Typed metrics registry — the host-domain half of ``repro.obs``.

A :class:`MetricsRegistry` holds named **counters** (monotonic adds),
**gauges** (last-write / high-water values), **histograms** (count, sum,
min, max — bucket-free so merging across process-pool shards is exact)
and lightweight wall-clock **spans** (a context manager that folds
elapsed microseconds into a ``<name>.us`` counter plus a
``<name>.calls`` counter).

Two strictly separated domains, enforced by name prefix:

* ``sim.*``  — deterministic values derived only from simulated
  time/bytes. These must be bit-identical across engine tiers
  (``fast``/``event``) and executors (serial/pool); see
  :mod:`repro.obs.simmetrics`, which derives them post-hoc from
  :class:`~repro.core.scheduler.SimResult` data rather than from
  instrumentation inside the hot loops.
* ``host.*`` — wall-clock and process-level observations (tier
  selection counts, fast-path rejection reasons, pool shard timing,
  graph-memo hit rates). Never part of result equality.

Zero overhead when disabled: :data:`NULL_REGISTRY` is a falsy no-op
singleton whose metric handles and spans do nothing and allocate
nothing, so instrumented call sites guard with ``if registry:`` (or
just call through — the no-ops are attribute lookups plus a pass).

JSON round-trip: ``to_dict()`` emits a plain
``{"counters": {...}, "gauges": {...}, "histograms": {...}}`` document;
``MetricsRegistry.from_dict`` restores it; ``merge_dict`` folds another
document in (counters add, gauges last-write, histograms combine) —
the operation the sweep engine applies to worker-side registries
shipped back from process-pool shards.
"""

from __future__ import annotations

from time import perf_counter
from typing import Any, Dict, Iterable, List, Optional, Tuple

__all__ = [
    "Counter", "Gauge", "Histogram", "MetricsRegistry",
    "NULL_REGISTRY", "make_registry", "summarize_metrics",
]

_DOMAINS = ("sim.", "host.")


class Counter:
    """Monotonic add-only value (int or float)."""

    __slots__ = ("value",)

    def __init__(self, value: float = 0):
        self.value = value

    def inc(self, n: float = 1) -> None:
        self.value += n


class Gauge:
    """Last-write value with a high-water helper."""

    __slots__ = ("value",)

    def __init__(self, value: float = 0):
        self.value = value

    def set(self, v: float) -> None:
        self.value = v

    def high(self, v: float) -> None:
        if v > self.value:
            self.value = v


class Histogram:
    """Bucket-free distribution digest: count / sum / min / max.

    Exact under merging (no bucket-boundary loss), which is what the
    cross-shard registry merge needs; percentile-grade digests belong
    to the callers that keep raw series (e.g. ``ServingReport``)."""

    __slots__ = ("count", "sum", "min", "max")

    def __init__(self, count: int = 0, total: float = 0.0,
                 vmin: float = 0.0, vmax: float = 0.0):
        self.count = count
        self.sum = total
        self.min = vmin
        self.max = vmax

    def observe(self, x: float) -> None:
        if self.count == 0:
            self.min = self.max = x
        else:
            if x < self.min:
                self.min = x
            if x > self.max:
                self.max = x
        self.count += 1
        self.sum += x

    @property
    def mean(self) -> float:
        return self.sum / self.count if self.count else 0.0

    def to_dict(self) -> Dict[str, float]:
        return {"count": self.count, "sum": self.sum,
                "min": self.min, "max": self.max}


class _Span:
    """Wall-clock span: ``with registry.span("host.sweep.evaluate"):``
    adds elapsed microseconds to ``<name>.us`` and bumps
    ``<name>.calls``."""

    __slots__ = ("_us", "_calls", "_t0")

    def __init__(self, us: Counter, calls: Counter):
        self._us = us
        self._calls = calls
        self._t0 = 0.0

    def __enter__(self) -> "_Span":
        self._t0 = perf_counter()
        return self

    def __exit__(self, *exc) -> None:
        self._us.inc((perf_counter() - self._t0) * 1e6)
        self._calls.inc()


class MetricsRegistry:
    """Ordered name -> typed-metric store with strict domain prefixes."""

    enabled = True

    def __init__(self):
        self._counters: Dict[str, Counter] = {}
        self._gauges: Dict[str, Gauge] = {}
        self._histograms: Dict[str, Histogram] = {}

    def __bool__(self) -> bool:
        return True

    @staticmethod
    def _check(name: str) -> None:
        if not name.startswith(_DOMAINS):
            raise ValueError(
                f"metric name {name!r} must carry a domain prefix "
                f"('sim.' or 'host.')")

    # -- typed accessors (create on first use) ------------------------------
    def counter(self, name: str) -> Counter:
        c = self._counters.get(name)
        if c is None:
            self._check(name)
            c = self._counters[name] = Counter()
        return c

    def gauge(self, name: str) -> Gauge:
        g = self._gauges.get(name)
        if g is None:
            self._check(name)
            g = self._gauges[name] = Gauge()
        return g

    def histogram(self, name: str) -> Histogram:
        h = self._histograms.get(name)
        if h is None:
            self._check(name)
            h = self._histograms[name] = Histogram()
        return h

    def span(self, name: str) -> _Span:
        return _Span(self.counter(name + ".us"),
                     self.counter(name + ".calls"))

    # -- serialization ------------------------------------------------------
    def to_dict(self) -> Dict[str, Any]:
        """Plain JSON-serializable document (sorted names, so documents
        compare equal independent of instrumentation order)."""
        return {
            "counters": {k: self._counters[k].value
                         for k in sorted(self._counters)},
            "gauges": {k: self._gauges[k].value
                       for k in sorted(self._gauges)},
            "histograms": {k: self._histograms[k].to_dict()
                           for k in sorted(self._histograms)},
        }

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "MetricsRegistry":
        reg = cls()
        reg.merge_dict(d)
        return reg

    def merge_dict(self, d: Optional[Dict[str, Any]]) -> None:
        """Fold another registry document in: counters add, gauges take
        the incoming value (last write wins), histograms combine
        exactly."""
        if not d:
            return
        for k, v in d.get("counters", {}).items():
            self.counter(k).inc(v)
        for k, v in d.get("gauges", {}).items():
            self.gauge(k).set(v)
        for k, hv in d.get("histograms", {}).items():
            h = self.histogram(k)
            if hv.get("count"):
                if h.count == 0:
                    h.min, h.max = hv["min"], hv["max"]
                else:
                    h.min = min(h.min, hv["min"])
                    h.max = max(h.max, hv["max"])
                h.count += hv["count"]
                h.sum += hv["sum"]

    def merge(self, other: "MetricsRegistry") -> None:
        self.merge_dict(other.to_dict())

    # -- reporting ----------------------------------------------------------
    def rows(self) -> List[Tuple[str, Any]]:
        """Flat (name, value) rows, sorted; histograms render their
        digest dict."""
        out: List[Tuple[str, Any]] = []
        out += [(k, c.value) for k, c in self._counters.items()]
        out += [(k, g.value) for k, g in self._gauges.items()]
        out += [(k, h.to_dict()) for k, h in self._histograms.items()]
        out.sort(key=lambda kv: kv[0])
        return out

    def summary(self) -> str:
        """Text report grouped by domain."""
        lines: List[str] = []
        rows = self.rows()
        for domain in ("sim", "host"):
            block = [(k, v) for k, v in rows
                     if k.startswith(domain + ".")]
            if not block:
                continue
            lines.append(f"[{domain}]")
            for k, v in block:
                lines.append(f"  {k:<42s} {_fmt_value(k, v)}")
        return "\n".join(lines) if lines else "(no metrics recorded)"


class _NullMetric:
    """Shared do-nothing Counter/Gauge/Histogram stand-in."""

    __slots__ = ()
    value = 0
    count = 0
    sum = 0.0
    min = 0.0
    max = 0.0
    mean = 0.0

    def inc(self, n: float = 1) -> None:
        pass

    def set(self, v: float) -> None:
        pass

    def high(self, v: float) -> None:
        pass

    def observe(self, x: float) -> None:
        pass

    def to_dict(self) -> Dict[str, float]:
        return {}


class _NullSpan:
    __slots__ = ()

    def __enter__(self) -> "_NullSpan":
        return self

    def __exit__(self, *exc) -> None:
        pass


_NULL_METRIC = _NullMetric()
_NULL_SPAN = _NullSpan()


class NullRegistry:
    """Falsy no-op registry: every accessor returns a shared do-nothing
    handle, ``to_dict`` is empty, merging is a pass. The disabled path
    therefore costs one attribute lookup + call per site and adds zero
    rows to any report."""

    enabled = False

    def __bool__(self) -> bool:
        return False

    def counter(self, name: str) -> _NullMetric:
        return _NULL_METRIC

    def gauge(self, name: str) -> _NullMetric:
        return _NULL_METRIC

    def histogram(self, name: str) -> _NullMetric:
        return _NULL_METRIC

    def span(self, name: str) -> _NullSpan:
        return _NULL_SPAN

    def to_dict(self) -> Dict[str, Any]:
        return {}

    def merge_dict(self, d: Optional[Dict[str, Any]]) -> None:
        pass

    def merge(self, other) -> None:
        pass

    def rows(self) -> List[Tuple[str, Any]]:
        return []

    def summary(self) -> str:
        return "(metrics disabled)"


NULL_REGISTRY = NullRegistry()


def make_registry(enabled: bool):
    """The one constructor call sites use: a live registry when enabled,
    the shared no-op singleton otherwise."""
    return MetricsRegistry() if enabled else NULL_REGISTRY


# ---------------------------------------------------------------------------
# text rendering for report-attached metrics documents
# ---------------------------------------------------------------------------

def _fmt_value(name: str, v: Any) -> str:
    if isinstance(v, dict):
        if "count" in v:
            return (f"n={v.get('count', 0)} sum={_fmt_num(v.get('sum', 0))} "
                    f"min={_fmt_num(v.get('min', 0))} "
                    f"max={_fmt_num(v.get('max', 0))}")
        return " ".join(f"{k}={_fmt_num(x)}" for k, x in v.items())
    return _fmt_num(v, us=name.endswith(".us"))


def _fmt_num(v: Any, us: bool = False) -> str:
    if isinstance(v, bool) or not isinstance(v, (int, float)):
        return str(v)
    if us:
        return f"{v / 1e3:.2f}ms" if v >= 1e3 else f"{v:.1f}us"
    if isinstance(v, int) or v == int(v) and abs(v) < 1e15:
        return str(int(v))
    return f"{v:.6g}"


def _walk(prefix: str, node: Any, out: List[str]) -> None:
    if isinstance(node, dict):
        for k in node:
            _walk(f"{prefix}.{k}" if prefix else str(k), node[k], out)
    elif isinstance(node, (list, tuple)):
        vals = ", ".join(_fmt_num(x) for x in node)
        out.append(f"  {prefix:<42s} [{vals}]")
    else:
        out.append(f"  {prefix:<42s} {_fmt_value(prefix, node)}")


def summarize_metrics(metrics: Optional[Dict[str, Any]],
                      title: str = "metrics") -> str:
    """Render a report-attached metrics document — the ``{"sim": ...,
    "host": ...}`` shape carried by ``RunReport.metrics`` /
    ``SweepReport.metrics`` / ``ServingReport.metrics`` — as the text
    report the ``python -m repro metrics`` subcommand prints."""
    if not metrics:
        return f"{title}: (none recorded — run with metrics enabled)"
    lines = [f"== {title} =="]
    for domain in ("sim", "host"):
        node = metrics.get(domain)
        if node is None:
            continue
        lines.append(f"[{domain}]")
        block: List[str] = []
        if isinstance(node, dict) and ("counters" in node
                                       or "gauges" in node
                                       or "histograms" in node):
            reg = MetricsRegistry.from_dict(node)
            block = reg.summary().splitlines()
            block = [ln for ln in block if not ln.startswith("[")]
        else:
            _walk("", node, block)
        lines += block
    extra: Iterable[str] = (k for k in metrics
                            if k not in ("sim", "host"))
    for k in extra:
        block = []
        _walk(k, metrics[k], block)
        lines += block
    return "\n".join(lines)
