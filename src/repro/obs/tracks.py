"""Perfetto counter-track series derived from traces and reports.

A counter track is a Chrome-trace ``"ph": "C"`` event stream: one named
series of ``[t_seconds, value]`` samples that Perfetto renders as a
step-line lane next to the duration lanes :func:`repro.core.trace.
chrome_trace` already emits. This module only *builds* the series
(plain ``{name: [[t, v], ...]}`` dicts); ``chrome_trace(counters=...)``
turns them into events on the dedicated counters pid.

Everything here is derived at export time from data the run already
recorded — trace rows or ``ServingReport`` time series — so enabling
counter tracks changes no simulation state and costs nothing until the
user asks for a trace file.
"""

from __future__ import annotations

from typing import Any, Dict, List, Optional

from ..core.trace import KIND_DRAM, KIND_FABRIC, KIND_GU, KIND_NOC

__all__ = ["activity_counters", "serving_counters", "metrics_counters"]


def _step_series(intervals) -> List[List[float]]:
    """Turn ``(start, end)`` intervals into a step series counting how
    many are active at each change point (classic +1/-1 sweep).
    ``-1`` deltas sort before ``+1`` at equal timestamps so a lane that
    ends exactly when another begins does not double-count."""
    deltas: List[List[float]] = []
    for st, en in intervals:
        deltas.append([st, 1])
        deltas.append([en, -1])
    deltas.sort(key=lambda d: (d[0], d[1]))
    series: List[List[float]] = []
    active = 0
    for t, d in deltas:
        active += d
        if series and series[-1][0] == t:
            series[-1][1] = active
        else:
            series.append([t, float(active)])
    return series


def activity_counters(trace) -> Dict[str, List[List[float]]]:
    """Occupancy counter series from a finished trace: concurrently
    active compute stages plus busy NoC/DRAM/fabric links over time."""
    if trace is None or len(trace) == 0:
        return {}
    compute = []
    resource: Dict[int, list] = {}
    for s, k, st, en in zip(trace.stage, trace.kind,
                            trace.start, trace.end):
        if s >= 0 and k <= KIND_GU:
            compute.append((float(st), float(en)))
        elif s < 0 and k in (KIND_NOC, KIND_DRAM, KIND_FABRIC):
            resource.setdefault(int(k), []).append((float(st), float(en)))
    out: Dict[str, List[List[float]]] = {}
    if compute:
        out["active_stages"] = _step_series(compute)
    for k, name in ((KIND_NOC, "busy_noc_links"),
                    (KIND_DRAM, "busy_dram_ports"),
                    (KIND_FABRIC, "busy_fabric_links")):
        if k in resource:
            out[name] = _step_series(resource[k])
    return out


def serving_counters(report) -> Dict[str, List[List[float]]]:
    """Counter series for a ``ServingReport``: the queue-depth and
    KV-cache-occupancy time series the serving simulator already
    samples, re-shaped for the trace export."""
    out: Dict[str, List[List[float]]] = {}
    if report.queue_depth:
        out["queue_depth"] = [[t, float(v)] for t, v in report.queue_depth]
    if report.kv_occupancy_bytes:
        out["kv_occupancy_bytes"] = [
            [t, float(v)] for t, v in report.kv_occupancy_bytes]
    return out


def metrics_counters(metrics: Optional[Dict[str, Any]],
                     total_time: float) -> Dict[str, List[List[float]]]:
    """Flat-line counter series for headline sim-domain scalars so the
    trace view shows them alongside the lanes (one sample at t=0, one at
    the end — Perfetto draws the constant)."""
    if not metrics:
        return {}
    sim = metrics.get("sim") or {}
    out: Dict[str, List[List[float]]] = {}
    for key, name in (("bubble_ratio", "bubble_ratio"),):
        v = sim.get(key)
        if isinstance(v, (int, float)):
            out[name] = [[0.0, float(v)], [total_time, float(v)]]
    levels = sim.get("payload_by_level")
    if isinstance(levels, dict):
        for lname, b in levels.items():
            out[f"payload_{lname}_bytes"] = [[0.0, float(b)],
                                             [total_time, float(b)]]
    return out
