"""Fault tolerance: straggler detection, restart-with-fault-injection,
gradient compression (error feedback)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.parallel.compression import dequantize_int8, ef_compress_tree, quantize_int8
from repro.train.fault_tolerance import StragglerMonitor, run_with_restart
from proptools import given


def test_straggler_monitor_flags_outlier():
    mon = StragglerMonitor(threshold=2.0, grace_steps=3)
    for step in range(20):
        ev = mon.record(step, 0.1)
        assert ev is None
    ev = mon.record(20, 0.5)
    assert ev is not None and ev["ratio"] == pytest.approx(5.0)
    assert mon.events


def test_run_with_restart_recovers_from_faults(tmp_path):
    saved = {}

    def save_fn(step, state):
        if step % 3 == 0:
            saved["ckpt"] = (step, state)

    def restore_fn():
        return saved.get("ckpt", (None, None))

    faults = {4, 8}

    def injector(step):
        if step in faults:
            faults.remove(step)
            return True
        return False

    def step_fn(step, state):
        return state + 1

    final, info = run_with_restart(step_fn, 0, 10, save_fn, restore_fn,
                                   fault_injector=injector)
    assert info["restarts"] == 2
    assert final == 10   # exactly-once semantics: state == steps applied


@given(n_cases=8)
def test_prop_quantize_roundtrip_bounded_error(rng, case):
    x = jnp.asarray(rng.normal(size=(int(rng.integers(10, 500)),)) * 10)
    q, s = quantize_int8(x)
    back = dequantize_int8(q, s, x.shape, jnp.float32)
    max_scale = float(jnp.max(s))
    assert float(jnp.max(jnp.abs(back - x))) <= max_scale * 0.5 + 1e-6


def test_error_feedback_is_unbiased_over_time():
    """Accumulated compressed updates converge to accumulated true grads."""
    rng = np.random.default_rng(0)
    g_true = jnp.asarray(rng.normal(size=(256,)))
    ef = None
    acc_comp = jnp.zeros_like(g_true)
    for _ in range(50):
        comp, ef = ef_compress_tree(g_true, ef)
        acc_comp = acc_comp + comp
    acc_true = g_true * 50
    # EF bounds the *cumulative* error by one quantization step
    rel = float(jnp.linalg.norm(acc_comp - acc_true) / jnp.linalg.norm(acc_true))
    assert rel < 0.01


def test_ef_compress_tree_shapes():
    grads = {"a": jnp.ones((8, 8)), "b": jnp.ones((3,))}
    comp, ef = ef_compress_tree(grads, None)
    assert jax.tree.structure(comp) == jax.tree.structure(grads)
    assert comp["a"].shape == (8, 8)
