"""Per-kernel allclose sweeps vs the ref.py jnp oracles (interpret mode:
this container is CPU-only; kernels target TPU)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import flash_attention, rmsnorm, ssd_scan
from repro.kernels.ref import flash_attention_ref, rmsnorm_ref, ssd_scan_ref
from repro.models.layers import ssm_decode_step

KEY = jax.random.PRNGKey(7)


def _tol(dtype):
    return dict(rtol=3e-2, atol=3e-2) if dtype == jnp.bfloat16 \
        else dict(rtol=3e-4, atol=3e-4)


@pytest.mark.parametrize("B,S,nh,nkv,hd", [
    (1, 128, 4, 4, 64),     # MHA, exact tile multiple
    (2, 200, 4, 2, 64),     # GQA, padded tail
    (1, 384, 8, 1, 32),     # MQA, hd below lane width
    (2, 256, 6, 3, 128),    # grouped, 128-wide heads
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("window", [0, 96])
def test_flash_attention_sweep(B, S, nh, nkv, hd, dtype, window):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, nh, S, hd), dtype)
    k = jax.random.normal(ks[1], (B, nkv, S, hd), dtype)
    v = jax.random.normal(ks[2], (B, nkv, S, hd), dtype)
    out = flash_attention(q, k, v, causal=True, window=window, interpret=True)
    ref = flash_attention_ref(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **_tol(dtype))


@pytest.mark.parametrize("B,nh,S,hp,N,chunk", [
    (1, 2, 256, 64, 16, 128),
    (2, 3, 300, 32, 64, 64),     # padded tail
    (1, 4, 64, 16, 128, 32),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ssd_scan_sweep(B, nh, S, hp, N, chunk, dtype):
    ks = jax.random.split(KEY, 5)
    x = jax.random.normal(ks[0], (B, nh, S, hp), dtype)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, nh, S))).astype(jnp.float32)
    A = -jnp.exp(jax.random.normal(ks[2], (nh,)) * 0.5)
    Bm = jax.random.normal(ks[3], (B, S, N), dtype)
    Cm = jax.random.normal(ks[4], (B, S, N), dtype)
    out = ssd_scan(x, dt, A, Bm, Cm, chunk=chunk, interpret=True)
    ref = ssd_scan_ref(x, dt, A, Bm, Cm)
    tol = dict(rtol=5e-2, atol=5e-2) if dtype == jnp.bfloat16 \
        else dict(rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **tol)


def test_ssd_kernel_state_equals_sequential_recurrence():
    """The kernel's chunked math must equal the token-by-token SSD
    recurrence used at decode time (train/serve consistency)."""
    B, nh, S, hp, N = 1, 2, 96, 16, 32
    ks = jax.random.split(KEY, 5)
    x = jax.random.normal(ks[0], (B, nh, S, hp))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, nh, S)))
    A = -jnp.exp(jax.random.normal(ks[2], (nh,)) * 0.5)
    Bm = jax.random.normal(ks[3], (B, S, N))
    Cm = jax.random.normal(ks[4], (B, S, N))
    out = ssd_scan(x, dt, A, Bm, Cm, chunk=32, interpret=True)
    state = jnp.zeros((B, nh, hp, N))
    ys = []
    for t in range(S):
        y, state = ssm_decode_step(x[:, :, t], dt[:, :, t], A, Bm[:, t],
                                   Cm[:, t], state)
        ys.append(y)
    ref = jnp.stack(ys, axis=2)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=3e-4, atol=3e-4)


@pytest.mark.parametrize("T,H", [(64, 256), (100, 512), (256, 1024)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rmsnorm_sweep(T, H, dtype):
    ks = jax.random.split(KEY, 2)
    x = jax.random.normal(ks[0], (T, H), dtype)
    w = jax.random.normal(ks[1], (H,), dtype)
    out = rmsnorm(x, w, interpret=True)
    ref = rmsnorm_ref(x, w)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **_tol(dtype))


def test_flash_attention_fully_masked_rows_are_zero():
    """Window smaller than the pad tail: padded/fully-masked rows -> 0."""
    B, nh, S, hd = 1, 2, 130, 64
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, nh, S, hd))
    k = jax.random.normal(ks[1], (B, nh, S, hd))
    v = jax.random.normal(ks[2], (B, nh, S, hd))
    out = flash_attention(q, k, v, causal=True, interpret=True)
    assert bool(jnp.all(jnp.isfinite(out)))
