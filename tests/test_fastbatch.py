"""Batched analytic tier (repro.core.fastbatch) + sweep integration:
grouped vectorized replay must be bit-identical to the scalar fast tier
and the event kernel per job, fall back per job where validation
rejects, rank deterministically across executors, and keep the shared
persistent engine registry coherent."""

import random

import pytest

from repro.api import (
    Experiment,
    RunReport,
    SearchSpace,
    SweepEngine,
    SweepReport,
    close_shared_engines,
    run_rank_key,
    shared_engine,
)
from repro.core import (
    DRAMSpec,
    HardwareSpec,
    MeshSpec,
    NoCMode,
    ParallelPlan,
    PipelineSimulator,
    Schedule,
    TileSpec,
    classify_cached,
    compile_stage_chains,
    map_graph,
    replay_chains,
    run_fast_batch,
    transformer_lm_graph,
)
from repro.core.fastbatch import available
from repro.core.hardware import tiled_cluster

from proptools import given

GB = 1e9


def _mesh_hw(n: int, flops: float = 4e12, dram_bw: float = 64 * GB,
             tile_shape=(2, 2), ports=False) -> HardwareSpec:
    spec = MeshSpec(rows=n, cols=n, intra_bw=64 * GB, inter_bw=16 * GB,
                    link_latency=2e-8, tile_shape=tile_shape)
    topo = spec.compile()
    kw = {}
    if ports:
        kw["dram_ports"] = (topo.device(0, 0),)
    return HardwareSpec(
        name=f"mesh{n}-f{flops:.0e}-d{dram_bw:.0e}", topology=topo,
        tile=TileSpec(flops=flops, sram_bytes=2e6),
        dram=DRAMSpec(bandwidth=dram_bw, response_time=3e-7, channels=4),
        **kw)


def _graph(layers: int, rng=None):
    return transformer_lm_graph("t", layers, 256, 4, 64, 1, vocab=512)


def _sim(hw, graph, plan, mode, engine="auto"):
    return PipelineSimulator(map_graph(graph, hw, plan), noc_mode=mode,
                             engine=engine, collect_timeline=True)


def _assert_identical(a, b, ctx, event_count=True):
    assert a.total_time == b.total_time, ctx
    assert a.throughput == b.throughput, ctx
    assert a.bubble_ratio == b.bubble_ratio, ctx
    assert a.noc_bytes == b.noc_bytes, ctx
    assert a.dram_bytes == b.dram_bytes, ctx
    if event_count:     # a per-tier diagnostic: chain nodes != heap events
        assert a.event_count == b.event_count, ctx
    assert a.trace.canonical() == b.trace.canonical(), ctx


@given(n_cases=1, seed=13)
def test_prop_batched_bit_identical_to_scalar_and_event(rng, case):
    """One mixed batch of >= 20 random (hardware, plan, NoC-mode) combos:
    every batched result must be bit-identical (scalars + canonical
    trace) to the scalar fast tier AND the event kernel; every batched
    fallback must agree with the scalar tier's fallback decision."""
    combos = []
    # hardware families sharing plan/graph structure — these land in the
    # same chain-shape group (only the float leaves differ)
    for pp, dp, tp, mb in ((1, 1, 1, 1), (2, 1, 1, 2), (4, 1, 1, 1),
                           (2, 2, 1, 1)):
        plan = ParallelPlan(pp=pp, dp=dp, tp=tp, microbatch=mb,
                            global_batch=mb * dp * 4,
                            recompute="never",
                            training=bool(rng.random() < 0.7))
        graph = _graph(2)
        for flops in (2e12, 4e12, 8e12):
            combos.append((_mesh_hw(4, flops=flops), graph, plan,
                           NoCMode.ANALYTICAL))
    # random singletons (mesh + tiled_cluster), mixed NoC modes
    for _ in range(12):
        if rng.random() < 0.25:
            hw = tiled_cluster()
            pp, dp, tp = [(1, 2, 2), (2, 1, 2), (2, 2, 2)][rng.integers(3)]
        else:
            n = int(rng.choice([4, 8]))
            hw = _mesh_hw(n, tile_shape=(2, 2) if rng.random() < 0.5
                          else (4, 4), ports=bool(rng.random() < 0.5))
            pp, dp, tp = [(1, 1, 1), (2, 1, 1), (2, 1, 2), (2, 2, 1),
                          (4, 1, 1), (1, 2, 2)][rng.integers(6)]
        graph = _graph(int(rng.integers(1, 3)))
        pp = min(pp, len(graph.ops))
        mb = int(rng.choice([1, 2]))
        plan = ParallelPlan(
            pp=pp, dp=dp, tp=tp, microbatch=mb,
            global_batch=mb * dp * int(rng.choice([2, 4])),
            schedule=Schedule.ONE_F_ONE_B if rng.random() < 0.7
            else Schedule.GPIPE,
            recompute=str(rng.choice(["never", "always"])),
            training=bool(rng.random() < 0.8))
        mode = [NoCMode.ANALYTICAL, NoCMode.MACRO,
                NoCMode.DETAILED][rng.integers(3)]
        combos.append((hw, graph, plan, mode))
    assert len(combos) >= 20

    profile = {}
    batched = run_fast_batch(
        [_sim(hw, g, p, m) for hw, g, p, m in combos], profile=profile)

    hits = 0
    for (hw, graph, plan, mode), (res, reason) in zip(combos, batched):
        ctx = (hw.name, plan.pp, plan.dp, plan.tp, str(mode))
        scalar_sim = _sim(hw, graph, plan, mode)
        if classify_cached(scalar_sim) is not None:
            scalar, s_reason = None, "ineligible"
        else:
            scalar, s_reason = replay_chains(
                scalar_sim, compile_stage_chains(scalar_sim))
        assert (res is None) == (scalar is None), (ctx, reason, s_reason)
        if res is None:
            continue
        hits += 1
        _assert_identical(res, scalar, ctx)
        assert res.trace == scalar.trace, ctx        # raw rows, pre-sort
        event = _sim(hw, graph, plan, mode, engine="event").run()
        _assert_identical(res, event, ctx, event_count=False)
    assert hits >= 5, f"fast tier fired on only {hits} combos — vacuous"
    if available():
        # the hardware families must actually have been *grouped*
        assert profile["batched_jobs"] >= 12
        assert profile["groups"] < profile["batched_jobs"]
        assert profile["jobs"] == len(combos)


def _sweep_exp(engine="auto"):
    return Experiment(
        graph_builder=lambda p: transformer_lm_graph(
            "t", 2, 128, 4, seq_len=64, batch=p.microbatch * p.dp,
            vocab=256),
        hardware=_mesh_hw(4),
        search=SearchSpace(max_plans=2),
        global_batch=8,
        engine=engine)


_MIXED_PLANS = [
    ParallelPlan(pp=2, dp=1, tp=1, microbatch=2, global_batch=8),
    ParallelPlan(pp=1, dp=1, tp=1, microbatch=1, global_batch=8),
    # interleave=2 is classifier-ineligible: falls back to the event
    # kernel mid-batch
    ParallelPlan(pp=2, dp=1, tp=1, microbatch=1, global_batch=8,
                 interleave=2),
    ParallelPlan(pp=4, dp=1, tp=1, microbatch=1, global_batch=8),
    ParallelPlan(pp=2, dp=2, tp=1, microbatch=1, global_batch=8),
]


def test_mixed_sweep_falls_back_mid_batch_and_matches():
    """A sweep mixing fast-eligible and ineligible plans: the batched
    engine's report equals the per-job engine's report exactly, and the
    ranking + total_time match a pure event-tier sweep bit-for-bit."""
    exp = _sweep_exp("auto")
    batched = SweepEngine().sweep(exp, _MIXED_PLANS)
    scalar = SweepEngine(batch_fastpath=False).sweep(exp, _MIXED_PLANS)
    assert batched.runs == scalar.runs
    assert [r.extra.get("engine") for r in batched.runs] == \
           [r.extra.get("engine") for r in scalar.runs]
    # the ineligible plan really took the event kernel, eligible ones the
    # fast tier
    by_plan = {(r.plan.pp, r.plan.interleave, r.plan.dp, r.plan.microbatch):
               r.extra.get("engine") for r in batched.runs}
    assert by_plan[(2, 2, 1, 1)] is None          # event (no attribution)
    assert "fast" in by_plan.values()

    event = SweepEngine().sweep(_sweep_exp("event"), _MIXED_PLANS)
    key = lambda r: (r.hardware, r.plan)
    assert [key(r) for r in batched.runs] == [key(r) for r in event.runs]
    assert [r.total_time for r in batched.runs] == \
           [r.total_time for r in event.runs]
    assert [r.throughput for r in batched.runs] == \
           [r.throughput for r in event.runs]


def test_strict_fast_engine_still_raises_through_batch():
    """engine="fast" on a classifier-ineligible plan must surface
    FastPathIneligible from the batched path, exactly like the scalar
    tier."""
    from repro.core import FastPathIneligible
    exp = _sweep_exp("fast")
    bad = [ParallelPlan(pp=2, dp=1, tp=1, microbatch=1, global_batch=8,
                        interleave=2)]
    with pytest.raises(FastPathIneligible):
        SweepEngine().sweep(exp, bad)


def _run(throughput, plan, hw="hw"):
    return RunReport(arch="a", hardware=hw, plan=plan,
                     total_time=1.0, throughput=throughput,
                     bubble_ratio=0.0, peak_memory_bytes=0.0,
                     recompute=False, event_count=1, noc_bytes=0.0,
                     dram_bytes=0.0)


def test_rank_key_tie_break_is_arrival_order_independent():
    """Equal-throughput runs sort by canonical (hardware, plan) identity,
    not by arrival order — pinned so batched/scalar/pool rankings always
    compare exactly."""
    runs = [_run(2.0, ParallelPlan(pp=1, dp=1, tp=4, global_batch=4)),
            _run(2.0, ParallelPlan(pp=1, dp=2, tp=2, global_batch=4)),
            _run(2.0, ParallelPlan(pp=1, dp=1, tp=4, global_batch=4),
                 hw="hw2"),
            _run(3.0, ParallelPlan(pp=4, dp=1, tp=1, global_batch=4))]
    expect = sorted(runs, key=run_rank_key)
    assert expect[0].throughput == 3.0
    for seed in range(5):
        shuffled = list(runs)
        random.Random(seed).shuffle(shuffled)
        assert sorted(shuffled, key=run_rank_key) == expect
    # tie block: hw before hw2; within hw, dp=1 before dp=2 (the JSON
    # plan key sorts on "dp" before "tp")
    tie = expect[1:]
    assert [(r.hardware, r.plan.dp) for r in tie] == \
           [("hw", 1), ("hw", 2), ("hw2", 1)]


def test_classify_memo_is_hit_on_repeat_configs():
    """classify_cached must key on (hardware, plan) identity and not
    re-run the static classifier for repeats (fidelity rungs sharing a
    truncated plan summary)."""
    graph = _graph(1)
    hw = _mesh_hw(4)
    plan = ParallelPlan(pp=1, dp=1, tp=1, microbatch=1, global_batch=4)
    memo = {}
    assert classify_cached(_sim(hw, graph, plan, NoCMode.MACRO),
                           memo) is None
    assert len(memo) == 1
    # poison the cached value: a second classify of the same config must
    # return it untouched (i.e. the classifier did not run again)
    memo[next(iter(memo))] = "sentinel"
    assert classify_cached(_sim(hw, graph, plan, NoCMode.MACRO),
                           memo) == "sentinel"
    # a different plan misses
    other = ParallelPlan(pp=2, dp=1, tp=1, microbatch=1, global_batch=4)
    classify_cached(_sim(hw, graph, other, NoCMode.MACRO), memo)
    assert len(memo) == 2


def test_run_fast_batch_degrades_without_numpy(monkeypatch):
    """With numpy absent run_fast_batch must degrade to the scalar fast
    tier per job and return identical outcomes (CI bench-smoke runs the
    whole sweep stack numpy-free)."""
    import repro.core.fastbatch as fb
    graph = _graph(2)
    sims = [_sim(_mesh_hw(4, flops=f), graph,
                 ParallelPlan(pp=2, dp=1, tp=1, microbatch=1,
                              global_batch=4, recompute="never"),
                 NoCMode.ANALYTICAL)
            for f in (2e12, 4e12)]
    with_np = fb.run_fast_batch(list(sims))
    monkeypatch.setattr(fb, "_np", None)
    assert not fb.available()
    without_np = fb.run_fast_batch(list(sims))
    for (a, ar), (b, br) in zip(with_np, without_np):
        assert (a is None) == (b is None)
        if a is not None:
            _assert_identical(a, b, "numpy-free degradation")


def test_sweep_profile_attached_and_round_trips():
    exp = _sweep_exp("auto")
    plans = _MIXED_PLANS[:3]
    rep = SweepEngine(profile=True).sweep(exp, plans)
    assert rep.profile is not None
    assert rep.profile.get("jobs") == len(plans)
    back = SweepReport.from_json(rep.to_json())
    assert back.profile == rep.profile
    # profiling off: no field, no JSON key — and reports compare equal to
    # profiled ones (profile is excluded from equality)
    plain = SweepEngine().sweep(exp, plans)
    assert plain.profile is None
    assert "profile" not in plain.to_dict()
    assert plain.runs == rep.runs


def test_shared_engine_registry_and_reuse():
    close_shared_engines()
    try:
        a = shared_engine()
        assert shared_engine() is a             # same flags -> same engine
        assert a._persist                       # already entered
        b = shared_engine(return_timelines=True)
        assert b is not a
        # planners route through the registry: a serial sweep on the
        # shared engine keeps its memos warm without closing anything
        exp = _sweep_exp("auto")
        r1 = a.sweep(exp, _MIXED_PLANS[:2])
        r2 = a.sweep(exp, _MIXED_PLANS[:2])
        assert r1.runs == r2.runs
        assert a._persist
    finally:
        close_shared_engines()
    assert shared_engine() is not a             # registry was cleared
    close_shared_engines()
