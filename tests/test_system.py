"""End-to-end behaviour tests for the paper's system: PALM as the
auto-parallelism planner + the executable substrate it plans for."""

import dataclasses

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import tpu_v5e_pod, wafer_scale
from repro.core.planner import PlannerCfg, plan_parallelism
from repro.core.workload import arch_to_graph
from repro.launch.train import scale_arch
from repro.launch.hlo_analysis import collective_bytes


def test_workload_ir_covers_every_arch():
    from repro.configs import ARCHS, SHAPES
    for name in sorted(ARCHS):
        arch = get_config(name)
        g = arch_to_graph(arch, seq_len=2048, batch=4, training=True)
        assert g.total_fwd_flops() > 0
        # workload IR param count tracks the config estimate
        est = arch.param_count()
        got = g.total_params()
        assert got == pytest.approx(est, rel=0.25), name
        if not arch.is_encoder_only:
            gd = arch_to_graph(arch, seq_len=2048, batch=4, decode=True)
            assert 0 < gd.total_fwd_flops() < g.total_fwd_flops()


def test_planner_returns_feasible_ranked_plans():
    arch = get_config("yi-6b")
    hw = tpu_v5e_pod(4, 4)      # small pod for test speed
    cfg = PlannerCfg(global_batch=64, seq_len=512, max_plans=12,
                     microbatch_sizes=(1, 2))
    results = plan_parallelism(arch, hw, cfg)
    assert len(results) >= 3
    thpts = [r.throughput for r in results]
    assert thpts == sorted(thpts, reverse=True)
    best = results[0].plan
    assert best.pp * best.dp * best.tp == hw.num_devices


def test_planner_prefers_tp_for_moe_all_to_all():
    """Planner runs end-to-end for MoE archs (EP comm modeled)."""
    arch = get_config("granite-moe-3b-a800m")
    hw = tpu_v5e_pod(2, 4)
    results = plan_parallelism(arch, hw, PlannerCfg(
        global_batch=32, seq_len=256, max_plans=8, microbatch_sizes=(1,)))
    assert results and results[0].throughput > 0


def test_hlo_collective_parser():
    text = """
  %all-gather.1 = f32[256,32]{1,0} all-gather(%fusion.50), channel_id=25
  %all-reduce.61 = f32[4,128,128]{2,1,0} all-reduce(%fusion.2), channel_id=23
  %all-to-all.2 = (f32[1,2,128,128]{3,2,1,0}, f32[1,2,128,128]{3,2,1,0}) all-to-all(%a, %b)
  %all-reduce-start.9 = bf16[16]{0} all-reduce-start(%x), channel_id=4
  %all-reduce-done.9 = bf16[16]{0} all-reduce-done(%all-reduce-start.9)
  %collective-permute = s32[2,128,1]{2,1,0} collective-permute(%sel), channel_id=15
"""
    out = collective_bytes(text)
    assert out["all-gather"] == 256 * 32 * 4
    assert out["all-reduce"] == 4 * 128 * 128 * 4 + 16 * 2   # done not double-counted
    assert out["all-to-all"] == 2 * 2 * 128 * 128 * 4
    assert out["collective-permute"] == 2 * 128 * 4
    assert out["total"] == sum(v for k, v in out.items() if k != "total")


def test_dryrun_extrapolation_math():
    from repro.launch.dryrun import _lin1, _lin2
    f = lambda L, G: 3.0 + 2.0 * L + 5.0 * G + 0.5 * L * G
    got = _lin2(f(1, 1), f(2, 1), f(1, 2), f(2, 2), 40, 16)
    assert got == pytest.approx(f(40, 16))
    g = lambda L: 7.0 + 3.0 * L
    assert _lin1(g(1), g(2), 96) == pytest.approx(g(96))
