"""Columnar Trace core: legacy-view parity across NoC modes, round-trips
(dict / npz / bytes / pickle, numpy and fallback backends), serial-vs-pool
trace equality, analytics sanity (utilization bounds, GPipe bubble vs
Eq. (1)), resource-lane occupancy, activation-offload accounting."""

import pickle

import pytest

from repro.api import Experiment, Layout, SearchSpace
from repro.core import (
    COMPUTE_KINDS,
    KIND_BD,
    KIND_DRAM,
    KIND_FD,
    KIND_GU,
    KIND_NOC,
    NoCMode,
    ParallelPlan,
    PipelineSimulator,
    Trace,
    chrome_trace,
    grayskull,
    ideal_pipeline_time,
    simulate,
    transformer_lm_graph,
    tpu_v5e_pod,
    wafer_scale,
)
from repro.core.parallelism import map_graph

import repro.core.trace as trace_mod


def _rig(plan, layers=2, H=256, S=128):
    """Rigged 2-stage pipeline workload."""
    return transformer_lm_graph("t", layers, H, 8, S, plan.microbatch * plan.dp,
                                vocab=2048)


def _plan(**kw):
    base = dict(pp=2, dp=1, tp=2, microbatch=1, global_batch=4)
    base.update(kw)
    return ParallelPlan(**base)


# ---------------------------------------------------------------------------
# columnar <-> legacy-tuple parity, all three NoC modes
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("mode", list(NoCMode))
def test_trace_matches_legacy_tuple_view(mode):
    plan = _plan()
    res = simulate(_rig(plan), wafer_scale(), plan, noc_mode=mode,
                   collect_timeline=True)
    t = res.trace
    M = plan.num_microbatches

    with pytest.deprecated_call():
        legacy = res.timeline
    assert legacy == t.compute_tuples()

    # the compute lanes carry exactly the FD/BD/GU event population
    fd = t.filter(kinds=(KIND_FD,))
    bd = t.filter(kinds=(KIND_BD,))
    gu = t.filter(kinds=(KIND_GU,))
    assert len(fd) == 2 * M and len(bd) == 2 * M and len(gu) == 2
    assert len(t.filter(kinds=COMPUTE_KINDS)) == len(legacy)
    for row in t.filter(kinds=COMPUTE_KINDS).rows():
        assert 0 <= row.stage < 2
        assert row.resource == -1
        assert 0.0 <= row.start <= row.end <= t.total_time + 1e-12
    # per-stage compute events never overlap (stages are serial workers)
    for s in (0, 1):
        iv = sorted((r.start, r.end)
                    for r in t.filter(stages=(s,), kinds=COMPUTE_KINDS).rows())
        for (a0, a1), (b0, b1) in zip(iv, iv[1:]):
            assert a1 <= b0 + 1e-12
    # scalar digests are views over the same columns
    assert res.stage_busy == t.stage_busy()
    assert res.bubble_ratio == t.bubble_fraction()


def test_compute_lanes_always_recorded():
    """Scalar digests (stage busy / bubble) derive from the trace, so the
    compute lanes exist even without collect_timeline."""
    plan = _plan()
    res = simulate(_rig(plan), wafer_scale(), plan)
    assert len(res.trace.filter(kinds=COMPUTE_KINDS)) > 0
    assert len(res.trace.filter(kinds=(KIND_NOC, KIND_DRAM))) == 0
    assert sum(res.stage_busy.values()) > 0


# ---------------------------------------------------------------------------
# round-trips
# ---------------------------------------------------------------------------

def _collected_trace():
    plan = _plan(global_batch=8)
    return simulate(_rig(plan), wafer_scale(), plan,
                    collect_timeline=True).trace


def test_trace_round_trips(tmp_path):
    t = _collected_trace()
    assert len(t.filter(kinds=(KIND_NOC,))) > 0     # resource lanes present
    assert Trace.from_dict(t.to_dict()) == t
    assert Trace.from_bytes(t.to_bytes()) == t
    assert pickle.loads(pickle.dumps(t)) == t
    if trace_mod._np is not None:
        p = tmp_path / "t.npz"
        t.to_npz(p)
        assert Trace.from_npz(p) == t
    # the wire form is substantially smaller than the raw columns
    assert len(t.to_bytes()) < t.nbytes


def test_trace_round_trips_without_numpy(monkeypatch):
    """The simulator core is dependency-free: the array.array backend must
    produce byte-identical wire forms and decode numpy-encoded blobs."""
    t = _collected_trace()
    blob = t.to_bytes()
    monkeypatch.setattr(trace_mod, "_np", None)
    rebuilt = Trace.from_bytes(blob)        # cross-backend decode
    assert [float(v) for v in rebuilt.start] == [float(v) for v in t.start]
    assert [float(v) for v in rebuilt.end] == [float(v) for v in t.end]
    assert [int(v) for v in rebuilt.kind] == [int(v) for v in t.kind]
    fallback = Trace(stage=list(t.stage), kind=list(t.kind),
                     micro=list(t.micro), resource=list(t.resource),
                     start=list(t.start), end=list(t.end),
                     pred=list(t.pred),
                     total_time=t.total_time, num_stages=t.num_stages)
    assert fallback.to_bytes() == blob      # byte-identical encoding
    assert Trace.from_bytes(fallback.to_bytes()) == fallback


def test_trace_views_and_concat():
    t = _collected_trace()
    half = t.slice_time(0.0, t.total_time / 2)
    assert 0 < len(half) < len(t)
    assert all(r.start < t.total_time / 2 for r in half.rows())
    s0 = t.filter(stages=(0,), kinds=COMPUTE_KINDS)
    assert {r.stage for r in s0.rows()} == {0}
    both = Trace.concat([s0, t.filter(stages=(1,), kinds=COMPUTE_KINDS)])
    assert len(both) == len(t.filter(kinds=COMPUTE_KINDS))
    assert both.total_time == t.total_time


# ---------------------------------------------------------------------------
# serial vs pool equality
# ---------------------------------------------------------------------------

def test_serial_and_pool_sweeps_ship_identical_traces():
    exp = Experiment(
        arch="yi-6b", hardware=tpu_v5e_pod(2, 2),
        search=SearchSpace(max_plans=4, microbatch_sizes=(1,),
                           layouts=(Layout.S_SHAPE,)),
        seq_len=128, global_batch=8)
    serial = exp.sweep(workers=0, return_timelines=True)
    pooled = exp.sweep(workers=2, return_timelines=True)
    assert serial.runs and pooled.executor.startswith("process")
    for a, b in zip(serial.runs, pooled.runs):
        assert a.trace is not None and b.trace is not None
        assert a.trace == b.trace           # bit-identical columns
        assert a.sim.trace == a.trace
        assert a.total_time == b.total_time


# ---------------------------------------------------------------------------
# analytics sanity
# ---------------------------------------------------------------------------

def test_utilization_bounds_and_bubble_identity():
    plan = _plan(global_batch=8)
    res = simulate(_rig(plan), wafer_scale(), plan)
    t = res.trace
    util = t.stage_utilization()
    assert set(util) == {0, 1}
    assert all(0.0 <= u <= 1.0 for u in util.values())
    busy = t.stage_busy()
    expect = 1.0 - sum(busy.values()) / len(busy) / t.total_time
    assert t.bubble_fraction() == pytest.approx(expect)


def test_gpipe_bubble_matches_ideal_pipeline_time():
    """On GPipe with local-HBM hardware the simulated total matches the
    Eq. (1) bound built from the trace's own FD/BD durations, and the
    bubble fraction follows."""
    plan = _plan(schedule="gpipe", global_batch=8, tp=1, dp=1)
    # wide layers: compute dominates the act/grad boundary passes Eq. (1)
    # does not model
    res = simulate(_rig(plan, H=2048), tpu_v5e_pod(2, 2), plan,
                   noc_mode=NoCMode.ANALYTICAL)
    t = res.trace
    M = plan.num_microbatches
    fdbd = []
    for s in (0, 1):
        mb0 = t.filter(stages=(s,), kinds=(KIND_FD, KIND_BD), micro=(0,))
        fdbd.append(sum(r.duration for r in mb0.rows()))
    gu = sum(r.duration for r in t.filter(kinds=(KIND_GU,)).rows()) / 2
    ideal = ideal_pipeline_time(fdbd, M, gu_time=gu)
    assert ideal <= t.total_time * (1 + 1e-9)
    assert t.total_time == pytest.approx(ideal, rel=0.1)
    predicted_bubble = 1.0 - M * sum(fdbd) / len(fdbd) / t.total_time
    assert t.bubble_fraction() == pytest.approx(predicted_bubble, abs=0.05)


def test_critical_path_is_a_dependency_chain():
    plan = _plan(global_batch=8)
    res = simulate(_rig(plan), wafer_scale(), plan)
    t = res.trace
    path = t.critical_path()
    assert len(path) >= 2
    ends = [r.end for r in t.filter(kinds=COMPUTE_KINDS).rows()]
    assert path[-1].end == max(ends)                # ends at the last event
    assert path[0].start == pytest.approx(0.0, abs=1e-12)
    for a, b in zip(path, path[1:]):
        assert a.end <= b.start + 1e-12             # chronological chain
    # the chain's busy time cannot exceed the simulated horizon
    assert sum(r.duration for r in path) <= t.total_time * (1 + 1e-9)


def test_summary_is_json_safe():
    import json
    t = _collected_trace()
    s = t.summary()
    json.dumps(s)
    assert s["events"] == len(t)
    assert 0.0 <= s["bubble_fraction"] <= 1.0
    assert s["critical_path"]["length"] >= 1


# ---------------------------------------------------------------------------
# resource lanes & deterministic occupancy reports
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("mode", [NoCMode.MACRO, NoCMode.DETAILED])
def test_resource_occupancy_matches_link_utilization(mode):
    plan = _plan(global_batch=4)
    mapped = map_graph(_rig(plan), grayskull(), plan)
    sim = PipelineSimulator(mapped, noc_mode=mode, collect_timeline=True)
    res = sim.run()
    occ = res.noc_occupancy
    assert occ, "edge-DRAM hardware must exercise NoC links"
    assert list(occ) == sorted(occ)                 # sorted link ids
    report = sim.noc.occupancy_report()
    assert list(report) == sorted(report)
    # interval-derived occupancy equals the busy-time integral per link
    for lid, frac in occ.items():
        assert frac == pytest.approx(report[lid], rel=1e-9, abs=1e-12)
        assert 0.0 <= frac <= 1.0
    dram = res.dram_occupancy
    assert dram and list(dram) == sorted(dram)
    for frac in dram.values():
        assert 0.0 <= frac <= 1.0


def test_chrome_trace_export():
    t = _collected_trace()
    doc = chrome_trace(t, label="test")
    assert doc["traceEvents"]
    x = [e for e in doc["traceEvents"] if e["ph"] == "X"]
    assert len(x) == len(t)
    assert all(e["dur"] >= 0 for e in x)
    pids = {e["pid"] for e in x}
    assert 0 in pids and (1 in pids or 2 in pids)   # stage + resource lanes


# ---------------------------------------------------------------------------
# activation offload (memory-cap follow-on)
# ---------------------------------------------------------------------------

def test_activation_offload_accounting():
    base = dict(pp=2, dp=1, tp=2, microbatch=1, global_batch=16,
                schedule="gpipe", recompute="never")
    resident = ParallelPlan(**base)
    offload = ParallelPlan(activation_offload=True, **base)
    hw = wafer_scale()
    r0 = simulate(_rig(resident, layers=4, H=512), hw, resident)
    r1 = simulate(_rig(offload, layers=4, H=512), hw, offload)
    peak0 = max(m.total for m in r0.stage_memory)
    peak1 = max(m.total for m in r1.stage_memory)
    assert peak1 < peak0                            # footprint shrinks
    assert max(m.offload_bytes for m in r1.stage_memory) > 0
    assert all(m.offload_bytes == 0 for m in r0.stage_memory)
    assert all(m.inflight_microbatches == 1 for m in r1.stage_memory)
    assert r1.dram_bytes > r0.dram_bytes            # store + fetch traffic


def test_offload_pruning_stays_exact():
    """The pre-simulation memory estimate equals the simulated footprint
    for offloaded plans, so memory-cap pruning decisions are exact."""
    from repro.core.scheduler import plan_memory
    plan = ParallelPlan(pp=2, dp=1, tp=2, microbatch=1, global_batch=16,
                        schedule="gpipe", recompute="never",
                        activation_offload=True)
    hw = wafer_scale()
    mapped = map_graph(_rig(plan, layers=4, H=512), hw, plan)
    est, _ = plan_memory(mapped)
    res = simulate(_rig(plan, layers=4, H=512), hw, plan)
    assert [m.total for m in est] == [m.total for m in res.stage_memory]
    assert [m.offload_bytes for m in est] == \
        [m.offload_bytes for m in res.stage_memory]


def test_offload_sweep_axis_and_parity():
    exp = Experiment(
        arch="yi-6b", hardware=tpu_v5e_pod(2, 2),
        search=SearchSpace(max_plans=8, microbatch_sizes=(1,),
                           layouts=(Layout.S_SHAPE,),
                           activation_offload=(False, True)),
        seq_len=128, global_batch=8)
    serial = exp.sweep(workers=0)
    pooled = exp.sweep(workers=2)
    assert any(r.plan.activation_offload for r in serial.runs)
    assert any(not r.plan.activation_offload for r in serial.runs)
    assert [(r.plan, r.throughput) for r in serial.runs] == \
           [(r.plan, r.throughput) for r in pooled.runs]


def test_plan_serving_emits_same_trace_schema():
    """Serving timelines (decode pipelines) carry the same columnar schema
    as training ones, so the two are directly comparable."""
    pytest.importorskip("jax")
    from repro.serving import plan_serving
    mesh_axes, report = plan_serving("yi-6b", hardware="tpu_v5e_2x2",
                                     batch=4, context_len=256,
                                     collect_timeline=True)
    assert set(mesh_axes) == {"data", "model"}
    best = report.best
    assert best.trace is not None
    assert len(best.trace.filter(kinds=(KIND_FD,))) > 0
    assert len(best.trace.filter(kinds=(KIND_BD, KIND_GU))) == 0  # inference
    # collect_timeline=True is honored through the sweep engine: resource
    # busy lanes ride along (local-HBM hardware always touches DRAM)
    assert len(best.trace.filter(kinds=(KIND_DRAM,))) > 0
    doc = chrome_trace(best.trace, label="serve")
    assert any(e.get("cat") == "FD" for e in doc["traceEvents"])


def test_sweep_resource_lanes_opt_in():
    """Default timeline sweeps ship compute lanes only (lean payloads);
    Experiment(collect_timeline=True) opts the sweep into resource lanes,
    identically in serial and pooled execution."""
    kw = dict(
        arch="yi-6b", hardware=tpu_v5e_pod(2, 2),
        search=SearchSpace(max_plans=2, microbatch_sizes=(1,),
                           layouts=(Layout.S_SHAPE,)),
        seq_len=128, global_batch=8)
    lean = Experiment(**kw).sweep(workers=0, return_timelines=True)
    assert all(len(r.trace.filter(kinds=(KIND_NOC, KIND_DRAM))) == 0
               for r in lean.runs)
    assert all(r.sim.noc_occupancy == {} for r in lean.runs)  # digest dropped
    rich_exp = Experiment(collect_timeline=True, **kw)
    rich = rich_exp.sweep(workers=0)
    pooled = rich_exp.sweep(workers=2)
    assert all(len(r.trace.filter(kinds=(KIND_DRAM,))) > 0 for r in rich.runs)
    for a, b in zip(rich.runs, pooled.runs):
        assert a.trace == b.trace


def test_single_run_keeps_scalar_occupancy_digest():
    """simulate() without collect_timeline still reports link occupancy
    (legacy behaviour), via the scalar fallback digest."""
    plan = _plan()
    res = simulate(_rig(plan), grayskull(), plan)
    occ = res.noc_occupancy
    assert occ and list(occ) == sorted(occ)
    assert all(0.0 <= v <= 1.0 for v in occ.values())
    assert len(res.trace.filter(kinds=(KIND_NOC,))) == 0   # no lanes recorded


# ---------------------------------------------------------------------------
# RunReport integration
# ---------------------------------------------------------------------------

def test_run_report_trace_embedding():
    from repro.api import RunReport
    exp = Experiment(
        arch="yi-6b", hardware=tpu_v5e_pod(2, 2),
        plan=ParallelPlan(pp=2, dp=2, tp=1, global_batch=8),
        seq_len=128, global_batch=8, collect_timeline=True)
    rep = exp.run()
    assert rep.trace is not None and rep.trace is rep.sim.trace
    assert rep.trace_summary()["events"] == len(rep.trace)
    # default JSON stays scalar; include_trace embeds the columns
    assert "trace" not in rep.to_dict()
    d = rep.to_dict(include_trace=True)
    assert d["trace"]["stage"]
    back = RunReport.from_dict(d)
    assert back.trace == rep.trace
    assert back == rep                              # trace excluded from eq
