"""Property-test helper: `hypothesis` is unavailable offline, so we use
seeded numpy draws over declared strategies (see DESIGN.md §8)."""

from __future__ import annotations

import functools

import numpy as np


def given(n_cases: int = 25, seed: int = 0):
    """Decorator: call the test with (rng, case_index) n_cases times.
    (Plain wrapper — no functools.wraps — so pytest does not mistake the
    inner rng/case parameters for fixtures.)"""
    def deco(fn):
        def wrapper():
            for i in range(n_cases):
                rng = np.random.default_rng(seed * 10_000 + i)
                fn(rng=rng, case=i)
        wrapper.__name__ = fn.__name__
        wrapper.__doc__ = fn.__doc__
        return wrapper
    return deco
