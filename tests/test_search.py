"""Guided multi-fidelity search (repro.search): encoded-space structure,
strategy determinism and parity, budget enforcement, report round-trips,
and the sweep-engine trace lane filter / payload budget satellites."""

import random

import pytest

from repro.api import (
    Experiment,
    HardwareSearchSpace,
    Layout,
    SearchSpace,
    SweepEngine,
    SweepReport,
)
from repro.core import tpu_v5e_pod
from repro.search import (
    EncodedSpace,
    Evolutionary,
    FULL,
    Fidelity,
    RandomSearch,
    SearchReport,
    SuccessiveHalving,
    default_ladder,
    make_strategy,
    run_search,
)


def _exp(**kw):
    defaults = dict(
        arch="yi-6b",
        hardware=tpu_v5e_pod(2, 2),
        seq_len=128,
        global_batch=8,
        search=SearchSpace(max_plans=4, microbatch_sizes=(1,)),
        hardware_search=HardwareSearchSpace(tile_flops=(100e12, 197e12),
                                            dram_bandwidth=(400e9, 819e9)),
    )
    defaults.update(kw)
    return Experiment(**defaults)


# ---------------------------------------------------------------------------
# EncodedSpace
# ---------------------------------------------------------------------------

def test_encoded_space_matches_exhaustive_enumeration():
    exp = _exp()
    space = EncodedSpace.from_experiment(exp)
    report = exp.sweep(workers=0)
    assert len(space) == report.num_candidates
    assert len(space.specs) == report.num_hardware
    # flat order is the exhaustive job stream: variant-major, plan-minor
    jobs = space.jobs()
    for i, (v, plan) in enumerate(jobs):
        cand = space.from_flat(i)
        assert space.flat_index(cand) == i
        assert space.job(cand) == (v, plan)
    axes = space.describe()["hardware_axes"]
    assert axes == {"tile_flops": 2, "dram_bandwidth": 2}


def test_encoded_space_counts_failed_variants():
    exp = _exp(search=SearchSpace(degrees=[(2, 2, 1)], microbatch_sizes=(1,),
                                  layouts=(Layout.S_SHAPE,)),
               hardware_search=HardwareSearchSpace(mesh_shapes=((2, 2), (1, 2))))
    space = EncodedSpace.from_experiment(exp)
    assert space.extra_failed == 1          # the 2-device 1x2 variant
    assert space.num_enumerated == 2
    assert len(space.specs) == 1


def test_encoded_space_sample_and_mutate_are_seed_deterministic():
    space = EncodedSpace.from_experiment(_exp())
    a, b = random.Random(7), random.Random(7)
    sa = [space.sample(a) for _ in range(20)]
    sb = [space.sample(b) for _ in range(20)]
    assert sa == sb
    ma = [space.mutate(c, a) for c in sa]
    mb = [space.mutate(c, b) for c in sb]
    assert ma == mb
    for src, dst in zip(sa, ma):
        assert dst != src
        v, plan = space.job(dst)            # every mutant decodes to a job
        assert plan in space.plans[v]


def test_fidelity_apply_truncates_microbatches_only():
    from repro.api import ParallelPlan
    plan = ParallelPlan(pp=2, dp=2, tp=1, microbatch=1, global_batch=16)
    assert plan.num_microbatches == 8
    low = Fidelity("mb2", max_microbatches=2).apply(plan)
    assert low.num_microbatches == 2
    assert (low.microbatch, low.dp, low.pp) == (1, 2, 2)
    assert FULL.apply(plan) is plan
    # already-short plans are untouched
    assert Fidelity("mb16", max_microbatches=16).apply(plan) is plan


def test_unnamed_reduced_fidelity_gets_derived_name_and_cannot_poison_cache():
    """A reduced rung left with the default name must not masquerade as
    "full": the accounting name is derived, and run_search keys its
    evaluation cache on the Fidelity object, so a custom ladder with
    sloppy names still dispatches real full-fidelity sims."""
    from repro.api import NoCMode
    f = Fidelity(noc_mode=NoCMode.ANALYTICAL)       # name not given
    assert f.name != "full" and not f.is_full
    exp = _exp()
    rep = run_search(exp, strategy="sh", budget=2, seed=0,
                     ladder=[Fidelity(noc_mode=NoCMode.ANALYTICAL), FULL])
    assert rep.runs, "full-fidelity rung must have dispatched real sims"
    assert rep.search.full_fidelity_sims > 0
    assert rep.search.sims_per_fidelity.get("full") == \
        rep.search.full_fidelity_sims


def test_default_ladder_ends_full_and_steps_down_detailed():
    from repro.api import NoCMode
    ladder = default_ladder(NoCMode.DETAILED)
    assert [f.is_full for f in ladder] == [False, False, True]
    assert ladder[0].noc_mode == NoCMode.ANALYTICAL
    assert ladder[1].noc_mode == NoCMode.MACRO
    assert len(default_ladder(NoCMode.MACRO, num_rungs=2)) == 2


# ---------------------------------------------------------------------------
# strategies: exhaustive parity, budget, determinism
# ---------------------------------------------------------------------------

def test_exhaustive_strategy_is_bit_identical_to_legacy_sweep():
    """Satellite acceptance: --search exhaustive IS today's path."""
    exp = _exp()
    assert exp.sweep(workers=0).to_json() == \
        exp.sweep(workers=0, strategy="exhaustive").to_json()


def test_random_search_respects_budget_and_seed():
    exp = _exp()
    rep = exp.sweep(workers=0, strategy="random", search_budget=5, seed=3)
    s = rep.search
    assert s is not None and s.strategy == "random"
    assert s.full_fidelity_sims <= 5
    assert len(rep.runs) <= 5
    assert sorted(s.sims_per_fidelity) == ["full"]
    again = exp.sweep(workers=0, strategy="random", search_budget=5, seed=3)
    assert again.to_json() == rep.to_json()


def test_sh_finds_rigged_optimum_within_budget():
    """Rigged space: the 197T/819G variant dominates; successive halving
    must find a within-2% point with a fifth of the full-fidelity sims."""
    exp = _exp()
    exhaustive = exp.sweep(workers=0)
    budget = max(1, exhaustive.num_candidates // 5)
    rep = exp.sweep(workers=0, strategy="sh", search_budget=budget, seed=0)
    s = rep.search
    assert s.full_fidelity_sims <= budget
    assert rep.best.throughput >= 0.98 * exhaustive.best.throughput
    # multi-fidelity: the cheap rungs did the bulk of the evaluations
    assert s.sims_per_fidelity.get("analytical-mb2", 0) > s.full_fidelity_sims
    # best-so-far curve is monotone in both coordinates
    curve = s.best_curve
    assert curve and all(a[0] <= b[0] and a[1] <= b[1]
                         for a, b in zip(curve, curve[1:]))


def test_sh_never_promotes_past_rung_budget():
    """Satellite acceptance: each rung promotes at most its successor's
    cohort budget, and the full-fidelity rung never exceeds the budget."""
    space = EncodedSpace.from_experiment(_exp())
    budget = 3
    ladder = default_ladder()
    sh = SuccessiveHalving(space, budget=budget, seed=0, ladder=ladder, eta=2)
    sizes = sh._rung_sizes
    assert sizes[-1] <= budget
    while True:
        asks = sh.ask()
        if not asks:
            break
        rung = sh._rung
        assert len(asks) <= sizes[rung]
        assert all(f.name == ladder[rung].name for _, f in asks)
        # feed synthetic monotone results: higher flat index = faster
        from repro.search import EvalOutcome
        sh.tell([EvalOutcome(c, f, ok=True,
                             throughput=float(space.flat_index(c)))
                 for c, f in asks])
    recs = sh.rung_records()
    assert len(recs) == len(ladder)
    for prev, nxt in zip(recs, recs[1:]):
        assert prev.promoted == nxt.evaluated
        assert prev.promoted <= prev.evaluated
    assert recs[-1].evaluated <= budget
    assert recs[-1].promoted == 0


def test_evolve_respects_budget_and_finds_optimum():
    exp = _exp()
    rep = exp.sweep(workers=0, strategy="evolve", search_budget=10, seed=0)
    s = rep.search
    assert s.full_fidelity_sims <= 10
    assert "197T" in rep.best.hardware
    assert s.rungs and all(r.fidelity == "full" for r in s.rungs)


@pytest.mark.parametrize("strategy", ["random", "sh", "evolve"])
def test_fixed_seed_serial_matches_pool(strategy):
    """Tentpole acceptance: fixed-seed guided runs are bit-reproducible
    across executors (serial vs shared process pool)."""
    exp = _exp()
    serial = exp.sweep(workers=0, strategy=strategy, search_budget=4, seed=1)
    pooled = exp.sweep(workers=2, strategy=strategy, search_budget=4, seed=1)
    assert pooled.executor.startswith("process")
    ds, dp = serial.to_dict(), pooled.to_dict()
    ds.pop("executor"), dp.pop("executor")
    assert ds == dp


def test_empty_space_matches_exhaustive_empty_report():
    """An infeasible space yields an empty ranked report (CLI exit 1),
    not an error — same contract as the exhaustive path."""
    exp = _exp(search=SearchSpace(degrees=[(2, 2, 1)], microbatch_sizes=(1,),
                                  layouts=(Layout.S_SHAPE,)),
               hardware_search=HardwareSearchSpace(mesh_shapes=((1, 2),)))
    exhaustive = exp.sweep(workers=0)
    guided = exp.sweep(workers=0, strategy="random", search_budget=2, seed=0)
    assert exhaustive.runs == [] and guided.runs == []
    assert guided.num_failed == exhaustive.num_failed == 1
    assert guided.num_candidates == exhaustive.num_candidates == 0
    assert guided.hardware == exhaustive.hardware
    assert guided.search.full_fidelity_sims == 0
    assert guided.best is None


def test_make_strategy_rejects_unknown():
    space = EncodedSpace.from_experiment(_exp())
    with pytest.raises(ValueError, match="unknown search strategy"):
        make_strategy("bayes", space, budget=4)


def test_search_budget_without_strategy_raises():
    """Budget/seed on an exhaustive sweep must fail loudly, not silently
    run the whole product — in the API and in the planner alike."""
    exp = _exp()
    with pytest.raises(ValueError, match="guided search"):
        exp.sweep(search_budget=4)
    with pytest.raises(ValueError, match="guided search"):
        exp.sweep(seed=1)
    from repro.api import PlannerCfg, plan_parallelism
    from repro.configs import get_config
    with pytest.raises(ValueError, match="guided search"):
        plan_parallelism(get_config("yi-6b"), tpu_v5e_pod(2, 2),
                         PlannerCfg(global_batch=8, seq_len=128,
                                    max_plans=2, search_budget=4))


def test_search_report_round_trips_inside_sweep_report():
    exp = _exp()
    rep = exp.sweep(workers=0, strategy="sh", search_budget=3, seed=0)
    back = SweepReport.from_json(rep.to_json())
    assert back == rep
    assert isinstance(back.search, SearchReport)
    assert back.search == rep.search
    assert back.search.rungs == rep.search.rungs
    # the winning variant is still recoverable (co-design contract)
    assert rep.best_hardware_dict() is not None


def test_run_search_without_hardware_search():
    """Plan-only spaces search too (single variant, plan axes only)."""
    exp = _exp(hardware_search=None,
               search=SearchSpace(max_plans=6, microbatch_sizes=(1, 2)))
    rep = run_search(exp, strategy="random", budget=3, seed=0)
    assert rep.num_hardware == 1 and rep.hardware == "tpu_v5e_2x2"
    assert rep.search.full_fidelity_sims <= 3 and rep.runs


def test_plan_codesign_with_guided_strategy():
    from repro.api import PlannerCfg, plan_codesign
    from repro.configs import get_config
    cfg = PlannerCfg(
        global_batch=8, seq_len=128, max_plans=3, microbatch_sizes=(1,),
        hardware_search=HardwareSearchSpace(tile_flops=(100e12, 197e12)),
        search_strategy="sh", search_budget=2, search_seed=0)
    res = plan_codesign(get_config("yi-6b"), tpu_v5e_pod(2, 2), cfg)
    assert "197T" in res.hardware.name
    assert res.report.search is not None
    assert res.report.search.full_fidelity_sims <= 2


# ---------------------------------------------------------------------------
# sweep-engine trace lane filter / payload budget (satellite)
# ---------------------------------------------------------------------------

def _timeline_exp():
    return Experiment(arch="yi-6b", hardware=tpu_v5e_pod(2, 2), seq_len=128,
                      global_batch=8, collect_timeline=True,
                      search=SearchSpace(max_plans=3, microbatch_sizes=(1,),
                                         layouts=(Layout.S_SHAPE,)))


def test_trace_lane_filter_keeps_scalars_exact():
    exp = _timeline_exp()
    plans = exp.search.enumerate_plans(exp.hardware_spec, exp.global_batch,
                                       arch=exp.arch_config)
    full = SweepEngine(workers=0, return_timelines=True,
                       trace_resources=True).sweep(exp, plans)
    lean = SweepEngine(workers=0, return_timelines=True, trace_resources=True,
                       trace_lanes=("FD", "BD")).sweep(exp, plans)
    assert [r.plan for r in lean.runs] == [r.plan for r in full.runs]
    assert [r.throughput for r in lean.runs] == \
           [r.throughput for r in full.runs]
    # scalars were digested before filtering: bubble/occupancy stay exact
    assert [r.bubble_ratio for r in lean.runs] == \
           [r.bubble_ratio for r in full.runs]
    for r in lean.runs:
        assert {int(k) for k in r.trace.kind} <= {0, 1}      # FD, BD only
    assert sum(r.trace.nbytes for r in lean.runs) < \
        sum(r.trace.nbytes for r in full.runs)


def test_trace_budget_bounds_payload_and_records_drops():
    exp = _timeline_exp()
    plans = exp.search.enumerate_plans(exp.hardware_spec, exp.global_batch,
                                       arch=exp.arch_config)
    budget = 2000
    rep = SweepEngine(workers=0, return_timelines=True, trace_resources=True,
                      trace_budget_bytes=budget).sweep(exp, plans)
    for r in rep.runs:
        assert r.trace.nbytes <= budget
        dropped = r.extra.get("trace_lanes_dropped", [])
        assert dropped, "tight budget must have dropped lanes"
        assert dropped == sorted(dropped, key=["DRAM", "NOC", "GU", "BD",
                                               "FD"].index)
    # serial and pooled engines apply the identical policy
    pooled = SweepEngine(workers=2, return_timelines=True,
                         trace_resources=True,
                         trace_budget_bytes=budget).sweep(exp, plans)
    assert all(a.trace == b.trace and a.extra == b.extra
               for a, b in zip(rep.runs, pooled.runs))


def test_trace_lanes_rejects_unknown_names():
    with pytest.raises(ValueError, match="unknown trace lane"):
        SweepEngine(trace_lanes=("FD", "PCIE"))
