"""NoC + DRAM bandwidth model (paper §IV-C Eq. 2-5, Fig. 6/7 mechanics)."""

import pytest

from repro.core import (
    DRAMModel,
    DRAMSpec,
    Environment,
    HardwareSpec,
    Mesh2D,
    NoCModel,
    TileSpec,
    grayskull,
    wafer_scale,
)
from repro.core.noc import collective_steps, ring_time
from proptools import given


def _hw(rows=4, cols=4, bw=100e9, lat=1e-7):
    topo = Mesh2D(rows, cols, intra_bw=bw, link_latency=lat)
    return HardwareSpec(name="t", topology=topo,
                        tile=TileSpec(flops=1e12, sram_bytes=1e6),
                        dram=DRAMSpec(bandwidth=50e9, response_time=1e-7),
                        dram_ports=(0,))


def test_transfer_matches_eq2():
    hw = _hw()
    env = Environment()
    noc = NoCModel(env, hw, mode="detailed")
    nbytes = 1e6
    proc = env.process(noc.transfer(0, 3, nbytes))  # 3 hops along row 0
    env.run(until_event=proc)
    expected = 3 * 1e-7 + nbytes / 100e9           # Eq. (2)
    assert env.now == pytest.approx(expected, rel=1e-9)


def test_contention_serializes_shared_link():
    hw = _hw()
    env = Environment()
    noc = NoCModel(env, hw, mode="detailed")
    p1 = env.process(noc.transfer(0, 3, 1e6))
    p2 = env.process(noc.transfer(1, 3, 1e6))      # shares links with p1
    env.run(until_event=env.all_of([p1, p2]))
    single = 3 * 1e-7 + 1e6 / 100e9
    assert env.now > 1.5 * single                   # serialized, not parallel


def test_analytical_ignores_contention():
    hw = _hw()
    env = Environment()
    noc = NoCModel(env, hw, mode="analytical")
    p1 = env.process(noc.transfer(0, 3, 1e6))
    p2 = env.process(noc.transfer(1, 3, 1e6))
    env.run(until_event=env.all_of([p1, p2]))
    single = 3 * 1e-7 + 1e6 / 100e9
    assert env.now == pytest.approx(single, rel=1e-6)


@given(n_cases=8)
def test_prop_congestion_geq_analytical(rng, case):
    """Fig. 7 invariant: event-driven time >= analytical for any task mix."""
    hw = _hw()
    n_tasks = int(rng.integers(2, 5))
    pairs = [(int(rng.integers(0, 16)), int(rng.integers(0, 16)))
             for _ in range(n_tasks)]
    pairs = [(a, b) for a, b in pairs if a != b] or [(0, 3)]
    sizes = rng.uniform(1e5, 5e6, size=len(pairs))
    times = {}
    for mode in ("detailed", "analytical"):
        env = Environment()
        noc = NoCModel(env, hw, mode=mode)
        procs = [env.process(noc.transfer(a, b, float(s)))
                 for (a, b), s in zip(pairs, sizes)]
        env.run(until_event=env.all_of(procs))
        times[mode] = env.now
    assert times["detailed"] >= times["analytical"] - 1e-12


def test_collective_macro_matches_detailed_uncontended():
    hw = _hw(bw=300e9, lat=2e-6)
    group = [0, 1, 2, 3]
    for kind in ("all_reduce", "all_gather", "reduce_scatter", "all_to_all"):
        out = {}
        for mode in ("detailed", "macro"):
            env = Environment()
            noc = NoCModel(env, hw, mode=mode)
            proc = env.process(noc.collective(kind, group, 4e6))
            env.run(until_event=proc)
            out[mode] = env.now
        assert out["macro"] == pytest.approx(out["detailed"], rel=0.35), kind


def test_dram_eq4_eq5():
    hw = _hw()
    env = Environment()
    noc = NoCModel(env, hw, mode="detailed")
    dram = DRAMModel(env, hw, noc)
    nbytes = 1e6
    proc = env.process(dram.access(3, nbytes))      # port at device 0: 3 hops
    env.run(until_event=proc)
    noc_time = 3 * 1e-7 + nbytes / 100e9            # Eq. (5) NoC leg
    access = 1e-7 + nbytes / 50e9                   # Eq. (4)
    assert env.now == pytest.approx(noc_time + access, rel=1e-9)


def test_dram_channel_contention():
    hw = _hw()
    env = Environment()
    noc = NoCModel(env, hw, mode="analytical")
    dram = DRAMModel(env, hw, noc)
    p1 = env.process(dram.access(1, 1e6))
    p2 = env.process(dram.access(2, 1e6))           # same edge channel
    env.run(until_event=env.all_of([p1, p2]))
    single = 1e-7 + 1e6 / 50e9
    assert env.now >= 2 * single                    # channel serializes


def test_local_hbm_group_access_is_parallel():
    hw = _hw()
    hw = hw.with_(dram_ports=())                     # GPU/TPU: private HBM
    env = Environment()
    noc = NoCModel(env, hw, mode="analytical")
    dram = DRAMModel(env, hw, noc)
    proc = env.process(dram.group_access(range(16), 1e6))
    env.run(until_event=proc)
    assert env.now == pytest.approx(1e-7 + 1e6 / 50e9, rel=1e-6)


def test_noc_bytes_accounting():
    hw = _hw()
    env = Environment()
    noc = NoCModel(env, hw, mode="detailed")
    proc = env.process(noc.transfer(0, 3, 123456.0))
    env.run(until_event=proc)
    assert noc.bytes_moved == 123456.0
