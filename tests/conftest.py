import sys
from pathlib import Path

# make `proptools` importable from test modules
sys.path.insert(0, str(Path(__file__).resolve().parent))
