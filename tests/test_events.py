"""DES kernel semantics: ordering, resources, conditions, determinism."""

import pytest

from repro.core.events import AllOf, AnyOf, Environment, PriorityResource, Resource
from proptools import given


def test_timeout_ordering():
    env = Environment()
    log = []

    def proc(delay, tag):
        yield env.timeout(delay)
        log.append((env.now, tag))

    env.process(proc(3, "c"))
    env.process(proc(1, "a"))
    env.process(proc(2, "b"))
    env.run()
    assert log == [(1, "a"), (2, "b"), (3, "c")]


def test_same_time_fifo_determinism():
    env = Environment()
    log = []

    def proc(tag):
        yield env.timeout(1.0)
        log.append(tag)

    for t in "abcde":
        env.process(proc(t))
    env.run()
    assert log == list("abcde")


def test_resource_serializes():
    env = Environment()
    res = Resource(env, capacity=1)
    spans = []

    def user(tag, hold):
        req = res.request()
        yield req
        start = env.now
        yield env.timeout(hold)
        res.release(req)
        spans.append((tag, start, env.now))

    env.process(user("a", 2.0))
    env.process(user("b", 3.0))
    env.run()
    assert spans == [("a", 0.0, 2.0), ("b", 2.0, 5.0)]
    assert res.utilization() == pytest.approx(1.0)


def test_priority_resource_orders_waiters():
    env = Environment()
    res = PriorityResource(env, capacity=1)
    order = []

    def user(tag, prio):
        req = res.request(priority=prio)
        yield req
        order.append(tag)
        yield env.timeout(1.0)
        res.release(req)

    def spawn():
        first = res.request()
        yield first
        env.process(user("fd", 1))
        env.process(user("bd", 0))     # 1F1B: BD beats queued FD
        yield env.timeout(1.0)
        res.release(first)

    env.process(spawn())
    env.run()
    assert order == ["bd", "fd"]


def test_all_of_any_of():
    env = Environment()
    out = {}

    def proc():
        e1, e2 = env.timeout(1.0, value="x"), env.timeout(5.0, value="y")
        yield env.any_of([e1, e2])
        out["any_at"] = env.now
        yield env.all_of([e2])
        out["all_at"] = env.now

    env.process(proc())
    env.run()
    assert out == {"any_at": 1.0, "all_at": 5.0}


def test_process_return_value():
    env = Environment()

    def inner():
        yield env.timeout(2.0)
        return 42

    def outer():
        val = yield env.process(inner())
        assert val == 42

    env.process(outer())
    env.run()
    assert env.now == 2.0


@given(n_cases=10)
def test_prop_resource_capacity_never_exceeded(rng, case):
    env = Environment()
    cap = int(rng.integers(1, 4))
    res = Resource(env, capacity=cap)
    active = [0]
    peak = [0]

    def user(delay, hold):
        yield env.timeout(delay)
        req = res.request()
        yield req
        active[0] += 1
        peak[0] = max(peak[0], active[0])
        yield env.timeout(hold)
        active[0] -= 1
        res.release(req)

    for _ in range(int(rng.integers(5, 20))):
        env.process(user(float(rng.random() * 3), float(rng.random() * 2 + 0.01)))
    env.run()
    assert peak[0] <= cap
    assert res.queue_len == 0 and res.in_use == 0
