"""Smoke tests: the ``python -m repro`` CLI and the quickstart example
run end-to-end on tiny configs (satellite of the Experiment API PR)."""

import json
import os
import subprocess
import sys
from pathlib import Path

import pytest

ROOT = Path(__file__).resolve().parents[1]
ENV = {**os.environ,
       "PYTHONPATH": str(ROOT / "src") + os.pathsep + os.environ.get("PYTHONPATH", "")}


def _run(args, timeout=300):
    return subprocess.run([sys.executable, *args], capture_output=True,
                          text=True, env=ENV, cwd=ROOT, timeout=timeout)


def test_cli_simulate_tiny():
    proc = _run(["-m", "repro", "simulate", "--arch", "yi-6b",
                 "--hardware", "tpu_v5e_2x2", "--pp", "2", "--dp", "2",
                 "--global-batch", "8", "--seq-len", "128", "--json", "-"])
    assert proc.returncode == 0, proc.stderr[-2000:]
    payload = json.loads(proc.stdout[proc.stdout.index("{"):])
    assert payload["arch"] == "yi-6b"
    assert payload["throughput"] > 0
    assert payload["plan"]["pp"] == 2


def test_cli_sweep_tiny(tmp_path):
    out = tmp_path / "sweep.json"
    proc = _run(["-m", "repro", "sweep", "--arch", "yi-6b",
                 "--hardware", "tpu_v5e_2x2", "--global-batch", "16",
                 "--seq-len", "128", "--max-plans", "6",
                 "--microbatch-sizes", "1", "2", "--workers", "2",
                 "--json", str(out)])
    assert proc.returncode == 0, proc.stderr[-2000:]
    report = json.loads(out.read_text())
    assert report["executor"].startswith("process")
    thpts = [r["throughput"] for r in report["runs"]]
    assert thpts == sorted(thpts, reverse=True) and thpts


def test_cli_plan_tiny():
    proc = _run(["-m", "repro", "plan", "--arch", "yi-6b",
                 "--hardware", "tpu_v5e_2x2", "--global-batch", "16",
                 "--seq-len", "128", "--max-plans", "4"])
    assert proc.returncode == 0, proc.stderr[-2000:]
    assert "best plan for yi-6b" in proc.stdout


def test_cli_rejects_unknown_enum_value():
    proc = _run(["-m", "repro", "simulate", "--arch", "yi-6b",
                 "--schedule", "2f2b"])
    assert proc.returncode != 0
    assert "invalid choice" in proc.stderr or "invalid" in proc.stderr


@pytest.mark.slow
def test_quickstart_tiny_runs():
    proc = _run([str(ROOT / "examples" / "quickstart.py"), "--tiny"])
    assert proc.returncode == 0, proc.stderr[-2000:]
    assert "planner ranking" in proc.stdout
