"""Smoke tests: the ``python -m repro`` CLI and the quickstart example
run end-to-end on tiny configs (satellite of the Experiment API PR)."""

import json
import os
import subprocess
import sys
from pathlib import Path

import pytest

ROOT = Path(__file__).resolve().parents[1]
ENV = {**os.environ,
       "PYTHONPATH": str(ROOT / "src") + os.pathsep + os.environ.get("PYTHONPATH", "")}


def _run(args, timeout=300):
    return subprocess.run([sys.executable, *args], capture_output=True,
                          text=True, env=ENV, cwd=ROOT, timeout=timeout)


def test_cli_simulate_tiny():
    proc = _run(["-m", "repro", "simulate", "--arch", "yi-6b",
                 "--hardware", "tpu_v5e_2x2", "--pp", "2", "--dp", "2",
                 "--global-batch", "8", "--seq-len", "128", "--json", "-"])
    assert proc.returncode == 0, proc.stderr[-2000:]
    payload = json.loads(proc.stdout[proc.stdout.index("{"):])
    assert payload["arch"] == "yi-6b"
    assert payload["throughput"] > 0
    assert payload["plan"]["pp"] == 2


def test_cli_sweep_tiny(tmp_path):
    out = tmp_path / "sweep.json"
    proc = _run(["-m", "repro", "sweep", "--arch", "yi-6b",
                 "--hardware", "tpu_v5e_2x2", "--global-batch", "16",
                 "--seq-len", "128", "--max-plans", "6",
                 "--microbatch-sizes", "1", "2", "--workers", "2",
                 "--json", str(out)])
    assert proc.returncode == 0, proc.stderr[-2000:]
    report = json.loads(out.read_text())
    assert report["executor"].startswith("process")
    thpts = [r["throughput"] for r in report["runs"]]
    assert thpts == sorted(thpts, reverse=True) and thpts


def test_cli_plan_tiny():
    proc = _run(["-m", "repro", "plan", "--arch", "yi-6b",
                 "--hardware", "tpu_v5e_2x2", "--global-batch", "16",
                 "--seq-len", "128", "--max-plans", "4"])
    assert proc.returncode == 0, proc.stderr[-2000:]
    assert "best plan for yi-6b" in proc.stdout


def test_cli_simulate_trace_export(tmp_path):
    """--trace-out writes Chrome/Perfetto traceEvents; --trace-npz the
    columnar archive (trace satellite)."""
    out = tmp_path / "trace.json"
    npz = tmp_path / "trace.npz"
    proc = _run(["-m", "repro", "simulate", "--arch", "yi-6b",
                 "--hardware", "tpu_v5e_2x2", "--pp", "2", "--dp", "2",
                 "--global-batch", "8", "--seq-len", "128",
                 "--trace-out", str(out), "--trace-npz", str(npz)])
    assert proc.returncode == 0, proc.stderr[-2000:]
    doc = json.loads(out.read_text())
    slices = [e for e in doc["traceEvents"] if e.get("ph") == "X"]
    assert slices and all(e["dur"] >= 0 for e in slices)
    cats = {e["cat"] for e in slices}
    assert {"FD", "BD", "GU"} <= cats       # compute lanes
    assert cats & {"NOC", "DRAM"}           # resource lanes
    sys.path.insert(0, str(ROOT / "src"))
    try:
        from repro.core import Trace
        t = Trace.from_npz(npz)
        assert len(t) == len(slices)
    finally:
        sys.path.pop(0)


def test_cli_simulate_activation_offload():
    proc = _run(["-m", "repro", "simulate", "--arch", "yi-6b",
                 "--hardware", "tpu_v5e_2x2", "--pp", "2", "--dp", "2",
                 "--global-batch", "8", "--seq-len", "128",
                 "--activation-offload", "--json", "-"])
    assert proc.returncode == 0, proc.stderr[-2000:]
    payload = json.loads(proc.stdout[proc.stdout.index("{"):])
    assert payload["plan"]["activation_offload"] is True


def test_cli_rejects_unknown_enum_value():
    proc = _run(["-m", "repro", "simulate", "--arch", "yi-6b",
                 "--schedule", "2f2b"])
    assert proc.returncode != 0
    assert "invalid choice" in proc.stderr or "invalid" in proc.stderr


@pytest.mark.slow
def test_quickstart_tiny_runs():
    proc = _run([str(ROOT / "examples" / "quickstart.py"), "--tiny"])
    assert proc.returncode == 0, proc.stderr[-2000:]
    assert "planner ranking" in proc.stdout


def test_cli_hardware_dump_and_json_round_trip(tmp_path):
    proc = _run(["-m", "repro", "hardware", "--hardware", "wafer_scale"])
    assert proc.returncode == 0, proc.stderr[-2000:]
    payload = json.loads(proc.stdout)
    assert payload["name"] == "wafer_scale"
    assert payload["topology"]["kind"] == "hierarchical"
    hw_json = tmp_path / "wafer.json"
    hw_json.write_text(proc.stdout)
    proc = _run(["-m", "repro", "simulate", "--arch", "yi-6b",
                 "--hardware-json", str(hw_json), "--pp", "4", "--dp", "2",
                 "--tp", "2", "--global-batch", "16", "--seq-len", "128",
                 "--json", "-"])
    assert proc.returncode == 0, proc.stderr[-2000:]
    payload = json.loads(proc.stdout[proc.stdout.index("{"):])
    assert payload["hardware"] == "wafer_scale"
    assert payload["throughput"] > 0


def test_cli_d_model_calibration():
    proc = _run(["-m", "repro", "hardware", "--hardware", "a100x8",
                 "--d-model", "20480"])
    assert proc.returncode == 0, proc.stderr[-2000:]
    hi = json.loads(proc.stdout)["tile"]["compute_efficiency"]
    proc = _run(["-m", "repro", "hardware", "--hardware", "a100x8"])
    base = json.loads(proc.stdout)["tile"]["compute_efficiency"]
    assert hi > base
    # calibration is a100-only
    proc = _run(["-m", "repro", "hardware", "--hardware", "wafer_scale",
                 "--d-model", "20480"])
    assert proc.returncode != 0 and "a100x<N>" in proc.stderr


def test_cli_plan_codesign(tmp_path):
    """`plan --hw-*` runs the co-design loop: joint ranking plus a
    recommendation document with the winning hardware spec JSON."""
    out = tmp_path / "codesign.json"
    proc = _run(["-m", "repro", "plan", "--arch", "yi-6b",
                 "--hardware", "tpu_v5e_2x2", "--global-batch", "8",
                 "--seq-len", "128", "--max-plans", "3",
                 "--microbatch-sizes", "1", "--layouts", "s_shape",
                 "--hw-flops", "100e12", "197e12",
                 "--codesign-json", str(out)])
    assert proc.returncode == 0, proc.stderr[-2000:]
    assert "co-design over 2 variants" in proc.stdout
    doc = json.loads(out.read_text())
    assert doc["hardware"]["tile"]["flops"] == 197e12    # faster tiles win
    assert doc["num_hardware"] == 2
    assert doc["plan"]["pp"] >= 1 and doc["throughput"] > 0
    # the recommendation's hardware block is --hardware-json compatible
    hw_json = tmp_path / "best_hw.json"
    hw_json.write_text(json.dumps(doc["hardware"]))
    proc = _run(["-m", "repro", "simulate", "--arch", "yi-6b",
                 "--hardware-json", str(hw_json), "--tp", "4",
                 "--global-batch", "8", "--seq-len", "128"])
    assert proc.returncode == 0, proc.stderr[-2000:]


def test_cli_plan_codesign_json_requires_hw_axes():
    proc = _run(["-m", "repro", "plan", "--arch", "yi-6b",
                 "--hardware", "tpu_v5e_2x2", "--global-batch", "8",
                 "--seq-len", "128", "--max-plans", "3",
                 "--codesign-json", "-"])
    assert proc.returncode == 2
    assert "--hw-*" in proc.stderr


def test_cli_hardware_torus_variant_dump(tmp_path):
    proc = _run(["-m", "repro", "hardware", "--hardware", "tpu_v5e_torus_2x2"])
    assert proc.returncode == 0, proc.stderr[-2000:]
    payload = json.loads(proc.stdout)
    assert payload["name"] == "tpu_v5e_torus_2x2"
    assert payload["topology"]["kind"] == "mesh"
    assert payload["topology"]["torus"] is True
    # the dump simulates through --hardware-json like any other spec
    hw_json = tmp_path / "torus.json"
    hw_json.write_text(proc.stdout)
    proc = _run(["-m", "repro", "simulate", "--arch", "yi-6b",
                 "--hardware-json", str(hw_json), "--pp", "2", "--dp", "2",
                 "--global-batch", "8", "--seq-len", "128", "--json", "-"])
    assert proc.returncode == 0, proc.stderr[-2000:]
    payload = json.loads(proc.stdout[proc.stdout.index("{"):])
    assert payload["hardware"] == "tpu_v5e_torus_2x2"
    assert payload["throughput"] > 0


def test_cli_plan_guided_search(tmp_path):
    """`plan --search sh` runs the guided co-design loop: budgeted
    full-fidelity sims, a search accounting note, and a report carrying
    the nested SearchReport."""
    out = tmp_path / "guided.json"
    proc = _run(["-m", "repro", "plan", "--arch", "yi-6b",
                 "--hardware", "tpu_v5e_2x2", "--global-batch", "8",
                 "--seq-len", "128", "--max-plans", "3",
                 "--microbatch-sizes", "1", "--layouts", "s_shape",
                 "--hw-flops", "100e12", "197e12",
                 "--search", "sh", "--search-budget", "2", "--seed", "0",
                 "--json", str(out)])
    assert proc.returncode == 0, proc.stderr[-2000:]
    assert "[search sh" in proc.stdout
    doc = json.loads(out.read_text())
    search = doc["search"]
    assert search["strategy"] == "sh" and search["seed"] == 0
    assert search["full_fidelity_sims"] <= 2
    assert search["rungs"] and search["best_curve"]
    # faster tiles still win under the budgeted search
    assert "197T" in doc["runs"][0]["hardware"]


def test_cli_sweep_guided_search_deterministic():
    args = ["-m", "repro", "sweep", "--arch", "yi-6b",
            "--hardware", "tpu_v5e_2x2", "--global-batch", "8",
            "--seq-len", "128", "--max-plans", "4",
            "--microbatch-sizes", "1",
            "--search", "random", "--search-budget", "3", "--seed", "7",
            "--json", "-"]
    a, b = _run(args), _run(args)
    assert a.returncode == 0, a.stderr[-2000:]
    assert a.stdout[a.stdout.index("{"):] == b.stdout[b.stdout.index("{"):]


def test_cli_search_budget_requires_guided_strategy():
    """--search-budget without --search {random,sh,evolve} must not
    silently run the full exhaustive product."""
    proc = _run(["-m", "repro", "sweep", "--arch", "yi-6b",
                 "--hardware", "tpu_v5e_2x2", "--global-batch", "8",
                 "--seq-len", "128", "--max-plans", "3",
                 "--search-budget", "2"])
    assert proc.returncode == 2
    assert "--search" in proc.stderr


def test_cli_trace_diff(tmp_path):
    """Simulate two plans, diff their timelines (trace-diff satellite)."""
    pytest.importorskip("numpy")        # --trace-npz needs numpy
    npzs = []
    for pp, dp in ((2, 2), (4, 1)):
        npz = tmp_path / f"pp{pp}.npz"
        proc = _run(["-m", "repro", "simulate", "--arch", "yi-6b",
                     "--hardware", "tpu_v5e_2x2", "--pp", str(pp),
                     "--dp", str(dp), "--global-batch", "8",
                     "--seq-len", "128", "--trace-npz", str(npz)])
        assert proc.returncode == 0, proc.stderr[-2000:]
        npzs.append(npz)
    out = tmp_path / "diff.json"
    proc = _run(["-m", "repro", "trace-diff", str(npzs[0]), str(npzs[1]),
                 "--json", str(out)])
    assert proc.returncode == 0, proc.stderr[-2000:]
    assert "total_time:" in proc.stdout and "bubble:" in proc.stdout
    doc = json.loads(out.read_text())
    assert set(doc) >= {"total_time", "bubble_fraction", "stage_busy",
                        "noc_occupancy", "dram_occupancy"}
    # pp=2 ran stages 0-1, pp=4 ran 0-3: union keys, zero-filled
    assert set(doc["stage_busy"]) == {"0", "1", "2", "3"}
    assert doc["total_time"]["delta"] == pytest.approx(
        doc["total_time"]["b"] - doc["total_time"]["a"])


def test_cli_trace_diff_rejects_chrome_export(tmp_path):
    bad = tmp_path / "chrome.json"
    bad.write_text(json.dumps({"traceEvents": []}))
    proc = _run(["-m", "repro", "trace-diff", str(bad), str(bad)])
    assert proc.returncode == 2
    assert "columnar" in proc.stderr


def test_cli_serve_sim_tiny(tmp_path):
    """`serve-sim` runs a seeded Poisson workload end to end: summary,
    JSON report, Chrome trace with per-request lanes, replayable
    workload trace (serving-subsystem PR)."""
    report_json = tmp_path / "report.json"
    trace_json = tmp_path / "trace.json"
    wl_json = tmp_path / "workload.json"
    args = ["-m", "repro", "serve-sim", "--arch", "hymba-1.5b",
            "--hardware", "grayskull", "--rate", "2", "--num-requests", "10",
            "--prompt-mean", "64", "--decode-mean", "8", "--max-batch", "4",
            "--ctx-bucket", "128", "--seed", "3"]
    proc = _run([*args, "--json", str(report_json),
                 "--trace-out", str(trace_json),
                 "--workload-out", str(wl_json)])
    assert proc.returncode == 0, proc.stderr[-2000:]
    assert "goodput:" in proc.stdout and "TTFT" in proc.stdout
    doc = json.loads(report_json.read_text())
    assert doc["completed"] == 10
    assert doc["ttft"]["p50"] > 0 and doc["goodput_rps"] >= 0
    assert [pt["attainment"] for pt in doc["slo_curve"]] == \
        sorted(pt["attainment"] for pt in doc["slo_curve"])
    trace = json.loads(trace_json.read_text())
    req_lanes = [e for e in trace["traceEvents"]
                 if e.get("pid") == 3 and e.get("ph") == "X"]
    assert any(e["name"].startswith("PREFILL") for e in req_lanes)
    # the emitted workload trace replays to the bit-identical report
    proc2 = _run(["-m", "repro", "serve-sim", "--arch", "hymba-1.5b",
                  "--hardware", "grayskull", "--replay", str(wl_json),
                  "--max-batch", "4", "--ctx-bucket", "128", "--json", "-"])
    assert proc2.returncode == 0, proc2.stderr[-2000:]
    replay = json.loads(proc2.stdout[proc2.stdout.index("{"):])
    # replay measures the offered rate from the recorded arrivals instead
    # of echoing the nominal --rate; everything else is bit-identical
    assert replay.pop("offered_rate") > 0
    doc.pop("offered_rate")
    assert replay == doc


def test_cli_serve_plan_tiny():
    proc = _run(["-m", "repro", "serve-plan", "--arch", "yi-6b",
                 "--hardware", "tpu_v5e_2x2", "--batch", "4",
                 "--context-len", "128"])
    assert proc.returncode == 0, proc.stderr[-2000:]
    assert "best serving split" in proc.stdout


def test_cli_serve_plan_explains_infeasibility():
    proc = _run(["-m", "repro", "serve-plan", "--arch", "yi-6b",
                 "--hardware", "tpu_v5e_2x2", "--batch", "4",
                 "--context-len", "128", "--memory-cap", "1e6"])
    assert proc.returncode == 1
    assert "no feasible serving split" in proc.stderr
    assert "memory-pruned" in proc.stderr and "cap by" in proc.stderr


def test_cli_sweep_hardware_variants():
    proc = _run(["-m", "repro", "sweep", "--arch", "yi-6b",
                 "--hardware", "tpu_v5e_2x2", "--global-batch", "8",
                 "--seq-len", "128", "--max-plans", "3",
                 "--microbatch-sizes", "1", "--layouts", "s_shape",
                 "--hw-flops", "100e12", "197e12", "--json", "-"])
    assert proc.returncode == 0, proc.stderr[-2000:]
    report = json.loads(proc.stdout[proc.stdout.index("{"):])
    assert report["num_hardware"] == 2
    hw_names = {r["hardware"] for r in report["runs"]}
    assert len(hw_names) == 2
