"""Two-tier simulator core: the analytic fast tier must be bit-identical
to the event tier wherever the contention classifier accepts it, fall
back (or raise under ``engine="fast"``) where it does not, and the
recorded ``pred`` causality must make ``Trace.critical_path()`` exact on
contended timelines."""

import json
import os
import subprocess
import sys
from pathlib import Path

import pytest

from repro.core import (
    DRAMSpec,
    Environment,
    FastPathIneligible,
    HardwareSpec,
    KIND_BD,
    KIND_FD,
    MeshSpec,
    NoCMode,
    ParallelPlan,
    PipelineSimulator,
    Schedule,
    TileSpec,
    TraceRecorder,
    map_graph,
    simulate,
    transformer_lm_graph,
)
from repro.core.hardware import tiled_cluster

from proptools import given

ROOT = Path(__file__).resolve().parents[1]
GB = 1e9


def _mesh_hw(n: int, tile_shape=(2, 2), ports=False) -> HardwareSpec:
    spec = MeshSpec(rows=n, cols=n, intra_bw=64 * GB, inter_bw=16 * GB,
                    link_latency=2e-8, tile_shape=tile_shape)
    topo = spec.compile()
    kw = {}
    if ports:
        kw["dram_ports"] = (topo.device(0, 0),)
    return HardwareSpec(
        name=f"mesh{n}", topology=topo,
        tile=TileSpec(flops=4e12, sram_bytes=2e6),
        dram=DRAMSpec(bandwidth=64 * GB, response_time=3e-7, channels=4),
        **kw)


def _identical(a, b):
    return (a.total_time == b.total_time
            and a.throughput == b.throughput
            and a.bubble_ratio == b.bubble_ratio
            and a.noc_bytes == b.noc_bytes
            and a.dram_bytes == b.dram_bytes
            and a.trace.canonical() == b.trace.canonical())


_FAST_HITS = []          # fast-tier selections across the property cases


@given(n_cases=20, seed=7)
def test_prop_fast_tier_bit_identity(rng, case):
    """engine="auto" must price every randomly drawn (hardware, plan,
    NoC-mode) point bit-identically to the event kernel — byte-equal
    canonical traces included — whether it takes the fast tier or falls
    back; and across the draw the fast tier must actually fire."""
    if rng.random() < 0.25:
        hw = tiled_cluster()
        pp, dp, tp = [(1, 2, 2), (2, 1, 2), (2, 2, 4),
                      (2, 2, 2)][rng.integers(4)]
    else:
        n = int(rng.choice([4, 8]))
        hw = _mesh_hw(n, tile_shape=(2, 2) if rng.random() < 0.5 else (4, 4),
                      ports=bool(rng.random() < 0.5))
        pp, dp, tp = [(1, 1, 1), (2, 1, 1), (2, 1, 2), (2, 2, 1),
                      (4, 1, 1), (1, 2, 2)][rng.integers(6)]
    layers = int(rng.integers(1, 3))
    graph = transformer_lm_graph("t", layers, 256, 4, 64, 1, vocab=512,
                                 include_embedding=bool(rng.random() < 0.5))
    pp = min(pp, len(graph.ops))         # a stage needs at least one op
    mb = int(rng.choice([1, 2]))
    plan = ParallelPlan(
        pp=pp, dp=dp, tp=tp, microbatch=mb,
        global_batch=mb * dp * int(rng.choice([2, 4])),
        schedule=Schedule.ONE_F_ONE_B if rng.random() < 0.7 else Schedule.GPIPE,
        recompute=str(rng.choice(["never", "always"])),
        training=bool(rng.random() < 0.8))
    mode = [NoCMode.ANALYTICAL, NoCMode.MACRO,
            NoCMode.DETAILED][rng.integers(3)]

    mapped = map_graph(graph, hw, plan)
    ev = PipelineSimulator(mapped, noc_mode=mode, engine="event",
                           collect_timeline=True).run()
    au = PipelineSimulator(mapped, noc_mode=mode, engine="auto",
                           collect_timeline=True).run()
    assert _identical(ev, au), (hw.name, plan, mode, au.engine)
    _FAST_HITS.append(au.engine == "fast")
    if case == 19:
        assert sum(_FAST_HITS) >= 5, (
            f"fast tier fired on only {sum(_FAST_HITS)}/20 cases — the "
            "classifier rejects everything, so the property test is vacuous")


def test_fast_strict_raises_where_classifier_rejects():
    """engine="fast" surfaces ineligibility instead of silently falling
    back; engine="auto" on the same point returns the event tier's exact
    result."""
    hw = _mesh_hw(4)
    graph = transformer_lm_graph("t", 2, 256, 4, 64, 1, vocab=512)
    plan = ParallelPlan(pp=2, dp=1, tp=1, microbatch=1, global_batch=4,
                        schedule=Schedule.ONE_F_ONE_B, interleave=2)
    mapped = map_graph(graph, hw, plan)
    with pytest.raises(FastPathIneligible):
        PipelineSimulator(mapped, noc_mode=NoCMode.ANALYTICAL,
                          engine="fast").run()
    ev = PipelineSimulator(mapped, noc_mode=NoCMode.ANALYTICAL,
                           engine="event").run()
    au = PipelineSimulator(mapped, noc_mode=NoCMode.ANALYTICAL,
                           engine="auto").run()
    assert au.engine == "event"
    assert ev.total_time == au.total_time
    assert ev.throughput == au.throughput


def test_engine_argument_validated():
    hw = _mesh_hw(4)
    graph = transformer_lm_graph("t", 1, 256, 4, 64, 1, vocab=512)
    mapped = map_graph(graph, hw,
                       ParallelPlan(pp=1, dp=1, tp=1, microbatch=1,
                                    global_batch=2))
    with pytest.raises(ValueError):
        PipelineSimulator(mapped, engine="warp")
    res = simulate(graph, hw,
                   ParallelPlan(pp=1, dp=1, tp=1, microbatch=1,
                                global_batch=2), engine="auto")
    assert res.engine in ("fast", "event")


def test_critical_path_exact_on_rigged_contended_trace():
    """With recorded causality the critical path follows the scheduler's
    binding-predecessor edges — here rigged so that stage 1's FD was
    bound by contention (stage 0's *second* FD) rather than by its
    structural upstream, which the heuristic walk would have picked."""
    rec = TraceRecorder()
    r0 = rec.compute(0, KIND_FD, 0, 0.0, 1.0, pred=-1)
    r1 = rec.compute(0, KIND_FD, 1, 1.0, 3.0, pred=r0)
    r2 = rec.compute(1, KIND_FD, 0, 3.0, 5.0, pred=r1)   # contention edge
    rec.compute(1, KIND_BD, 0, 5.0, 5.5, pred=r2)
    trace = rec.freeze(5.5, 2)
    path = [(r.stage, r.kind, r.micro) for r in trace.critical_path()]
    assert path == [(0, KIND_FD, 0), (0, KIND_FD, 1),
                    (1, KIND_FD, 0), (1, KIND_BD, 0)]


def test_critical_path_heuristic_differs_on_rigged_trace():
    """The same rigged timeline *without* pred causality resolves through
    the structural heuristic — FD(s1, mb0) chains to its upstream
    FD(s0, mb0), missing the contention edge. This is exactly the gap
    the recorded pred column closes."""
    rec = TraceRecorder()
    rec.compute(0, KIND_FD, 0, 0.0, 1.0)
    rec.compute(0, KIND_FD, 1, 1.0, 3.0)
    rec.compute(1, KIND_FD, 0, 3.0, 5.0)
    trace = rec.freeze(5.0, 2)
    path = [(r.stage, r.kind, r.micro) for r in trace.critical_path()]
    assert (1, KIND_FD, 0) in path
    assert (0, KIND_FD, 1) not in path       # heuristic misses the edge


def test_run_until_peeks_instead_of_popping():
    """Environment.run(until=...) must not consume the first event past
    the horizon: a paused-and-resumed run replays the identical event
    sequence as an uninterrupted one (fast-tier windows hand back to the
    event kernel mid-timeline, so this is load-bearing)."""
    def trace_run(pauses):
        env = Environment()
        fired = []
        for t in (1.0, 2.0, 3.0):
            env.timeout(t).callbacks.append(
                lambda ev, t=t: fired.append((t, env.now)))
        for p in pauses:
            env.run(until=p)
        env.run()
        return fired, env.now, env.event_count

    plain = trace_run([])
    paused = trace_run([0.5, 1.5, 2.5])
    assert plain[0] == paused[0]
    assert plain[2] == paused[2]
    # the horizon advances the clock even when no event fires
    env = Environment()
    env.timeout(5.0)
    env.run(until=2.0)
    assert env.now == 2.0
    env.run()
    assert env.now == 5.0


def test_cli_engine_flag_smoke():
    env = {**os.environ,
           "PYTHONPATH": str(ROOT / "src") + os.pathsep
           + os.environ.get("PYTHONPATH", "")}
    outs = {}
    for engine in ("event", "auto"):
        proc = subprocess.run(
            [sys.executable, "-m", "repro", "simulate", "--arch", "yi-6b",
             "--hardware", "tpu_v5e_2x2", "--pp", "2", "--dp", "2",
             "--global-batch", "8", "--seq-len", "128",
             "--engine", engine, "--json", "-"],
            capture_output=True, text=True, env=env, cwd=ROOT, timeout=300)
        assert proc.returncode == 0, proc.stderr[-2000:]
        outs[engine] = json.loads(proc.stdout[proc.stdout.index("{"):])
    assert outs["event"]["total_time"] == outs["auto"]["total_time"]
    assert outs["event"]["throughput"] == outs["auto"]["throughput"]
