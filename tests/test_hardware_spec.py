"""Declarative hardware layer: topology spec compilation, cached routing,
torus wraparound, HardwareSpec JSON round-trip, preset equivalence.

Acceptance for the hardware-API PR: compiled routing tables match the
direct ``Mesh2D``/``GPUCluster`` code paths route-by-route, torus routes
never exceed mesh routes, JSON round-trip is lossless for every preset,
and presets rebuilt on spec builders simulate identically to hand-built
hardware.
"""

import json

import pytest

from proptools import given
from repro.core import (
    DRAMSpec,
    GPUCluster,
    GPUClusterSpec,
    HardwareSpec,
    HierarchicalSpec,
    Mesh2D,
    MeshSpec,
    ParallelPlan,
    TileSpec,
    Torus2D,
    a100_cluster,
    grayskull,
    simulate,
    topology_spec_from_dict,
    tpu_v5e_pod,
    transformer_lm_graph,
    wafer_scale,
)

PRESETS = [grayskull, wafer_scale, lambda: a100_cluster(8),
           lambda: tpu_v5e_pod(2, 2)]


# ---------------------------------------------------------------------------
# spec compilation matches the direct topology classes route-by-route
# ---------------------------------------------------------------------------

@given(n_cases=10)
def test_prop_mesh_spec_compiles_to_identical_routing(rng, case):
    rows, cols = int(rng.integers(1, 7)), int(rng.integers(2, 7))
    tile = (1, 1) if case % 2 == 0 else (rows, 1)
    spec = MeshSpec(rows=rows, cols=cols, intra_bw=1e11, inter_bw=5e10,
                    link_latency=3e-8, tile_shape=tile)
    compiled = spec.compile()
    direct = Mesh2D(rows, cols, intra_bw=1e11, inter_bw=5e10,
                    link_latency=3e-8, tile_shape=tile)
    assert compiled.num_links() == direct.num_links()
    for s in range(compiled.num_devices):
        for d in range(compiled.num_devices):
            assert compiled.route(s, d) == direct.route(s, d), (s, d)
    for l in range(compiled.num_links()):
        assert compiled.link_bandwidth(l) == direct.link_bandwidth(l)
        assert compiled.link_latency(l) == direct.link_latency(l)


def test_gpu_cluster_spec_compiles_to_identical_routing():
    spec = GPUClusterSpec(num_gpus=16, gpus_per_node=4)
    compiled, direct = spec.compile(), GPUCluster(16, gpus_per_node=4)
    for s in range(16):
        for d in range(16):
            assert compiled.route(s, d) == direct.route(s, d)
    for l in range(compiled.num_links()):
        assert compiled.link_bandwidth(l) == direct.link_bandwidth(l)
        assert compiled.link_latency(l) == direct.link_latency(l)


def test_hierarchical_spec_flattens_to_two_level_mesh():
    spec = HierarchicalSpec(
        tile=MeshSpec(rows=4, cols=4, intra_bw=1024e9, link_latency=2e-8),
        grid_rows=5, grid_cols=4, inter_bw=256e9)
    topo = spec.compile()
    direct = Mesh2D(20, 16, intra_bw=1024e9, inter_bw=256e9,
                    link_latency=2e-8, tile_shape=(4, 4))
    assert (topo.rows, topo.cols) == (20, 16)
    assert spec.num_devices == 320
    # intra-tile hop fast, tile-boundary hop slow, identical to direct build
    for l in range(topo.num_links()):
        assert topo.link_bandwidth(l) == direct.link_bandwidth(l)
    assert topo.link_bandwidth(topo.route(0, 1)[0]) == 1024e9
    assert topo.link_bandwidth(topo.route(3, 4)[0]) == 256e9   # crosses col 3->4


def test_hierarchical_spec_rejects_nested_structure():
    with pytest.raises(ValueError, match="flat mesh"):
        HierarchicalSpec(tile=MeshSpec(2, 2, intra_bw=1e9, torus=True),
                         grid_rows=2, grid_cols=2, inter_bw=1e9)


# ---------------------------------------------------------------------------
# cached routing: caches agree with fresh computation; metrics agree with
# the route they summarize
# ---------------------------------------------------------------------------

@given(n_cases=8)
def test_prop_cached_routing_matches_uncached(rng, case):
    rows, cols = int(rng.integers(2, 6)), int(rng.integers(2, 6))
    spec = MeshSpec(rows=rows, cols=cols, intra_bw=1e11, torus=bool(case % 2))
    cached = spec.compile(cache_routing=True)
    baseline = spec.compile(cache_routing=False)
    for s in range(cached.num_devices):
        for d in range(cached.num_devices):
            r1 = cached.route(s, d)
            assert r1 == baseline.route(s, d)
            assert cached.route(s, d) is r1          # memoized object
            hops, lat, bw = cached.path_metrics(s, d)
            assert hops == len(r1)
            if r1:
                assert lat == pytest.approx(
                    sum(cached.link_latency(l) for l in r1))
                assert bw == min(cached.link_bandwidth(l) for l in r1)
            else:
                assert (lat, bw) == (0.0, float("inf"))


# ---------------------------------------------------------------------------
# torus routing
# ---------------------------------------------------------------------------

@given(n_cases=10)
def test_prop_torus_routes_never_exceed_mesh_routes(rng, case):
    rows, cols = int(rng.integers(2, 8)), int(rng.integers(2, 8))
    mesh = MeshSpec(rows, cols, intra_bw=1e11).compile()
    torus = MeshSpec(rows, cols, intra_bw=1e11, torus=True).compile()
    for s in range(mesh.num_devices):
        for d in range(mesh.num_devices):
            assert torus.hops(s, d) <= mesh.hops(s, d), (s, d)


def test_torus_wraparound_is_single_hop():
    t = MeshSpec(4, 6, intra_bw=1e11, torus=True).compile()
    assert isinstance(t, Torus2D)
    assert t.hops(0, 5) == 1                      # (0,0) -> (0,5): west wrap
    assert t.hops(5, 0) == 1
    assert t.hops(0, t.device(3, 0)) == 1         # (0,0) -> (3,0): north wrap
    # opposite corners: 1 wrap hop per dimension
    assert t.hops(0, t.device(3, 5)) == 2
    # every route's links exist and have bandwidth
    for s in (0, 5, 17, 23):
        for d in range(t.num_devices):
            for l in t.route(s, d):
                assert 0 <= l < t.num_links()
                assert t.link_bandwidth(l) > 0


def test_torus_wrap_links_cross_tile_boundary_bandwidth():
    t = MeshSpec(4, 4, intra_bw=1e12, inter_bw=1e11, tile_shape=(2, 2),
                 torus=True).compile()
    wrap = t.route(0, 3)                          # (0,0) -> (0,3): west wrap
    assert len(wrap) == 1
    assert t.link_bandwidth(wrap[0]) == 1e11      # tiles (0,0) vs (0,1)


# ---------------------------------------------------------------------------
# JSON round-trip: lossless for every preset + equivalent simulation
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("make", PRESETS)
def test_preset_json_round_trip_is_lossless(make):
    hw = make()
    d = hw.to_dict()
    json.dumps(d)                                 # JSON-clean (no Infinity)
    back = HardwareSpec.from_json(hw.to_json())
    assert back.to_dict() == d
    assert back.name == hw.name
    assert back.num_devices == hw.num_devices
    assert back.dram_ports == hw.dram_ports
    assert back.tile == hw.tile and back.dram == hw.dram


@pytest.mark.parametrize("make", PRESETS)
def test_preset_round_trip_simulates_identically(make):
    hw = make()
    back = HardwareSpec.from_json(hw.to_json())
    g = transformer_lm_graph("t", 2, 128, 4, seq_len=64, batch=2, vocab=256)
    plan = ParallelPlan(pp=2, dp=2, global_batch=4)
    a = simulate(g, hw, plan, noc_mode="detailed")
    b = simulate(g, back, plan, noc_mode="detailed")
    assert a.total_time == b.total_time
    assert a.noc_bytes == b.noc_bytes and a.dram_bytes == b.dram_bytes


def test_topology_spec_dict_dispatch_and_errors():
    spec = MeshSpec(2, 3, intra_bw=1e9)
    assert topology_spec_from_dict(spec.to_dict()) == spec
    h = HierarchicalSpec(tile=MeshSpec(2, 2, intra_bw=1e9),
                         grid_rows=2, grid_cols=2, inter_bw=1e8)
    assert topology_spec_from_dict(h.to_dict()) == h
    with pytest.raises(ValueError, match="unknown topology kind"):
        topology_spec_from_dict({"kind": "hypercube"})
    with pytest.raises(ValueError, match="kind"):
        topology_spec_from_dict({"rows": 2})


def test_custom_topology_without_spec_refuses_to_serialize():
    from repro.core import Topology

    class Foreign(Topology):
        num_devices = 2
    hw = HardwareSpec(name="x", topology=Foreign(),
                      tile=TileSpec(flops=1e12, sram_bytes=1e6),
                      dram=DRAMSpec(bandwidth=1e9))
    with pytest.raises(ValueError, match="no declarative spec"):
        hw.to_dict()


# ---------------------------------------------------------------------------
# presets rebuilt on spec builders == hand-built hardware (old code path)
# ---------------------------------------------------------------------------

def test_spec_built_presets_match_hand_built_hardware():
    """The four presets, re-implemented on spec builders, must simulate
    identically to directly-constructed topology objects (the pre-spec
    code path)."""
    g = transformer_lm_graph("t", 2, 128, 4, seq_len=64, batch=2, vocab=256)
    plan = ParallelPlan(pp=2, dp=2, global_batch=4)
    GB = 1e9

    hand = {
        "grayskull": grayskull().with_(
            topology=Mesh2D(10, 12, intra_bw=192 * GB, link_latency=5e-8)),
        "wafer_scale": wafer_scale().with_(
            topology=Mesh2D(20, 16, intra_bw=1024 * GB, inter_bw=256 * GB,
                            link_latency=2e-8, tile_shape=(4, 4))),
        "a100x8": a100_cluster(8).with_(topology=GPUCluster(8)),
        "tpu_v5e_2x2": tpu_v5e_pod(2, 2).with_(
            topology=Mesh2D(2, 2, intra_bw=50 * GB, link_latency=1e-6)),
    }
    spec_built = {hw.name: hw for hw in (make() for make in PRESETS)}
    for name, hw_hand in hand.items():
        for mode in ("detailed", "macro", "analytical"):
            a = simulate(g, spec_built[name], plan, noc_mode=mode)
            b = simulate(g, hw_hand, plan, noc_mode=mode)
            assert a.total_time == b.total_time, (name, mode)


# ---------------------------------------------------------------------------
# nearest-DRAM-port caching
# ---------------------------------------------------------------------------

def test_nearest_dram_port_cached_and_correct():
    hw = wafer_scale()
    topo = hw.topology
    for dev in (0, 37, 151, 319):
        port = hw.nearest_dram_port(dev)
        assert port in hw.dram_ports
        best = min(topo.hops(dev, p) for p in hw.dram_ports)
        assert topo.hops(dev, port) == best
        assert hw.nearest_dram_port(dev) == port   # cached second read
    assert a100_cluster(4).nearest_dram_port(0) is None
